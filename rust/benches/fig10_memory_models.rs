//! Fig. 10 — Flash and RAM for the speech command recognizer and person
//! detector across MCUs (experiment E5 in DESIGN.md).
//!
//! Expected shape (paper Sec. 6.2.2): MicroFlow consistently smaller; the
//! gap narrows as weights dominate (person: still >15% total Flash saved);
//! the person model no longer fits the smallest devices at all; TFLM only
//! exists on ESP32 + nRF52840.

use microflow::compiler::plan::{CompileOptions, CompiledModel};
use microflow::format::mfb::MfbModel;
use microflow::interp::arena::ArenaPlan;
use microflow::sim::report::{emit, Table};
use microflow::sim::{self, Engine, MCUS};
use microflow::util::fmt_kb;

fn main() -> anyhow::Result<()> {
    let art = microflow::artifacts_dir();

    for model_name in ["speech", "person"] {
        let model = MfbModel::load(art.join(format!("{model_name}.mfb")))?;
        let arena = ArenaPlan::plan(&model)?;
        let mut t = Table::new(
            &format!("Fig. 10 — {model_name} memory (Flash / RAM per MCU)"),
            &["mcu", "TFLM flash", "MF flash", "TFLM ram", "MF ram", "TFLM runs", "MF runs"],
        );
        let mut esp = ((0usize, 0usize), (0usize, 0usize)); // (flash tf/mf, ram tf/mf)
        for mcu in MCUS.iter() {
            let paging = mcu.ram_bytes <= 4 * 1024;
            let compiled = CompiledModel::compile(&model, CompileOptions { paging, ..Default::default() })?;
            let mf = sim::memory_model::microflow_footprint(&compiled, mcu);
            let tf = sim::memory_model::tflm_footprint(&model, &arena, mcu);
            let mf_ok = sim::memory_model::fits(mcu, Engine::MicroFlow, mf).is_ok();
            let tf_ok = sim::memory_model::fits(mcu, Engine::Tflm, tf).is_ok();
            if mcu.name == "ESP32" {
                esp = ((tf.flash, mf.flash), (tf.ram, mf.ram));
            }
            t.row(vec![
                mcu.name.into(),
                fmt_kb(tf.flash),
                fmt_kb(mf.flash),
                fmt_kb(tf.ram),
                fmt_kb(mf.ram),
                if tf_ok { "yes" } else { "NO" }.into(),
                if mf_ok { "yes" } else { "NO" }.into(),
            ]);
        }
        emit(&format!("fig10_memory_{model_name}"), &t);

        let flash_saving = 1.0 - (esp.0 .1 as f64 / esp.0 .0 as f64);
        println!("{model_name}: ESP32 Flash saving {:.0}%", flash_saving * 100.0);
        assert!(
            flash_saving > 0.10,
            "{model_name}: MicroFlow must still save >10% Flash (paper: >15% on person)"
        );
        assert!(esp.1 .1 < esp.1 .0, "{model_name}: MicroFlow RAM must be below TFLM's");
    }

    // the narrowing-gap claim: person saving < sine saving
    let saving = |name: &str| -> anyhow::Result<f64> {
        let model = MfbModel::load(art.join(format!("{name}.mfb")))?;
        let arena = ArenaPlan::plan(&model)?;
        let esp = sim::mcu::by_name("ESP32").unwrap();
        let compiled = CompiledModel::compile(&model, CompileOptions::default())?;
        let mf = sim::memory_model::microflow_footprint(&compiled, esp);
        let tf = sim::memory_model::tflm_footprint(&model, &arena, esp);
        Ok(1.0 - mf.flash as f64 / tf.flash as f64)
    };
    let (s_sine, s_speech, s_person) = (saving("sine")?, saving("speech")?, saving("person")?);
    println!("Flash saving narrows: sine {:.0}% > speech {:.0}% > person {:.0}%",
        s_sine * 100.0, s_speech * 100.0, s_person * 100.0);
    assert!(s_sine > s_speech && s_speech > s_person, "gap must narrow with model size (paper)");
    println!("fig10_memory_models OK");
    Ok(())
}
