//! Kernel micro-benchmarks (experiment E11 in DESIGN.md) — real
//! host-measured wall-clock for the hot-path kernels in both arithmetic
//! variants, at the layer shapes of the three paper models.
//!
//! This is also the §Perf harness: the perf pass iterates on these numbers
//! (EXPERIMENTS.md records before/after). MicroFlow kernels run on the
//! compile-time packed layouts (`compiler::pack`), staged once outside the
//! timed windows, exactly as the plan does — and each MicroFlow timing is
//! taken once per *available* kernel backend (scalar + AVX2/NEON where the
//! host reports them), so the SIMD win lands in the same perf trail that
//! proved the packing win.
//!
//! Outputs:
//! * the human table + CSV via `sim::report::emit`;
//! * machine-readable `BENCH_kernels.json` at the **repo root** (shapes,
//!   medians, a `backend` field per row, microflow-vs-interp ratio) so the
//!   perf trajectory is comparable across PRs.
//!
//! Set `MICROFLOW_BENCH_SMOKE=1` to run a single iteration per shape (the
//! CI layout-regression gate: it proves the packed kernels still run at
//! every bench shape — on every available backend — without paying bench
//! wall-clock).

use microflow::bench_support::{black_box, report_line, smoke_mode, time_iters};
use microflow::compiler::pack;
use microflow::format::mfb::Padding;
use microflow::kernels::microkernel::backend::{self, KernelBackend};
use microflow::kernels::view::ConvGeometry;
use microflow::kernels::{conv2d, depthwise_conv2d, fully_connected};
use microflow::sim::report::{emit, emit_json, Table};
use microflow::tensor::fixedpoint::FixedPointMultiplier;
use microflow::tensor::quant::{FusedAct, PreComputed};
use microflow::util::json::Json;
use microflow::util::{fmt_time, Prng};

struct Row {
    kernel: &'static str,
    backend: &'static str,
    shape: String,
    microflow_s: f64,
    interp_s: f64,
}

fn main() {
    let smoke = smoke_mode();
    let (warmup, iters) = if smoke { (0usize, 1usize) } else { (10, 200) };
    let backends: Vec<&'static dyn KernelBackend> = backend::available()
        .into_iter()
        .map(|n| backend::resolve(n).expect("available backend must resolve"))
        .collect();
    println!(
        "kernel backends under test: [{}] (process default: {})",
        backend::available().join(", "),
        backend::active().name()
    );
    let mut rng = Prng::new(3);
    let mut t = Table::new(
        "kernel micro-benches (host wall-clock, median of 200)",
        &["kernel", "backend", "shape", "microflow", "tflm-interp", "ratio"],
    );
    let mut rows: Vec<Row> = Vec::new();

    // --- FullyConnected at the speech classifier shape (4000 -> 4) and the
    //     sine shapes (16 -> 16)
    for (k, n, label) in [(16usize, 16usize, "sine fc"), (4000, 4, "speech fc"), (256, 128, "generic fc")] {
        let x = rng.i8_vec(k);
        let w = rng.i8_vec(k * n);
        let b = rng.i32_vec(n, -1000, 1000);
        let colsum: Vec<i32> = (0..n).map(|j| (0..k).map(|i| w[i * n + j] as i32).sum()).collect();
        let pc = PreComputed::fold(&b, &colsum, k, 0.05, 3, 0.02, 0, 0.001, 0, 0.08, -5, FusedAct::Relu);
        let m = FixedPointMultiplier::from_real(0.05 * 0.02 / 0.08);
        let mut out = vec![0i8; n];
        let s_tf = time_iters(warmup, iters, || {
            fully_connected::fully_connected_interp(&x, &w, &b, k, n, 3, 0, m, -5, -128, 127, &mut out);
            black_box(&out);
        });
        println!("{}", report_line(&format!("fc {label} ({k}x{n}) interp"), &s_tf));
        for kb in &backends {
            let s_mf = time_iters(warmup, iters, || {
                fully_connected::fully_connected_microflow_with(*kb, &x, &w, k, n, &pc, &mut out);
                black_box(&out);
            });
            println!(
                "{}",
                report_line(&format!("fc {label} ({k}x{n}) microflow/{}", kb.name()), &s_mf)
            );
            t.row(vec![
                "fully_connected".into(),
                kb.name().into(),
                format!("{k}x{n}"),
                fmt_time(s_mf.median),
                fmt_time(s_tf.median),
                format!("{:.2}x", s_tf.median / s_mf.median),
            ]);
            rows.push(Row {
                kernel: "fully_connected",
                backend: kb.name(),
                shape: format!("{k}x{n}"),
                microflow_s: s_mf.median,
                interp_s: s_tf.median,
            });
        }
    }

    // --- DepthwiseConv2D at the TinyConv shape (49x40x1, k10x8, s2, mult 8)
    {
        let geo = ConvGeometry::new(49, 40, 1, 10, 8, 2, 2, Padding::Same).unwrap();
        let cout = 8;
        let x = rng.i8_vec(49 * 40);
        let w = rng.i8_vec(80 * cout);
        let b = rng.i32_vec(cout, -500, 500);
        let colsum: Vec<i32> = (0..cout).map(|co| (0..80).map(|t| w[t * cout + co] as i32).sum()).collect();
        let pc = PreComputed::fold(&b, &colsum, 80, 0.05, -128, 0.02, 0, 0.001, 0, 0.1, -128, FusedAct::Relu);
        let m = FixedPointMultiplier::from_real(0.05 * 0.02 / 0.1);
        let mut view = vec![0i8; 80];
        let mut out = vec![0i8; 25 * 20 * cout];
        // compile-time packing, outside the timed window (as the plan does)
        let w_t = pack::pack_depthwise(&w, 80, cout);
        let s_tf = time_iters(warmup.min(5), iters, || {
            depthwise_conv2d::depthwise_conv2d_interp(
                &x, &w, &b, &geo, 8, -128, 0, m, -128, -128, 127, &mut view, &mut out,
            );
            black_box(&out);
        });
        println!("{}", report_line("dwconv speech (49x40, k10x8, m8) interp", &s_tf));
        for kb in &backends {
            let s_mf = time_iters(warmup.min(5), iters, || {
                depthwise_conv2d::depthwise_conv2d_microflow_with(
                    *kb, &x, &w_t, &geo, 8, -128, &pc, &mut view, &mut out,
                );
                black_box(&out);
            });
            println!(
                "{}",
                report_line(
                    &format!("dwconv speech (49x40, k10x8, m8) microflow/{}", kb.name()),
                    &s_mf
                )
            );
            t.row(vec![
                "depthwise_conv2d".into(),
                kb.name().into(),
                "49x40x1 k10x8 m8".into(),
                fmt_time(s_mf.median),
                fmt_time(s_tf.median),
                format!("{:.2}x", s_tf.median / s_mf.median),
            ]);
            rows.push(Row {
                kernel: "depthwise_conv2d",
                backend: kb.name(),
                shape: "49x40x1 k10x8 m8".into(),
                microflow_s: s_mf.median,
                interp_s: s_tf.median,
            });
        }
    }

    // --- Conv2D at a MobileNet pointwise shape (6x6x128 -> 128) and the
    //     first-layer shape (96x96x1, k3, s2 -> 8)
    for (h, w_, cin, cout, kk, stride, label) in
        [(6usize, 6usize, 128usize, 128usize, 1usize, 1usize, "pw 6x6x128"), (96, 96, 1, 8, 3, 2, "first 96x96")]
    {
        let geo = ConvGeometry::new(h, w_, cin, kk, kk, stride, stride, Padding::Same).unwrap();
        let x = rng.i8_vec(h * w_ * cin);
        let f = rng.i8_vec(cout * kk * kk * cin);
        let b = rng.i32_vec(cout, -500, 500);
        let kkc = kk * kk * cin;
        let colsum: Vec<i32> =
            (0..cout).map(|co| f[co * kkc..(co + 1) * kkc].iter().map(|&v| v as i32).sum()).collect();
        let pc = PreComputed::fold(&b, &colsum, kkc, 0.05, -3, 0.02, 0, 0.001, 0, 0.08, 4, FusedAct::Relu6);
        let m = FixedPointMultiplier::from_real(0.05 * 0.02 / 0.08);
        // compile-time packing, outside the timed window
        let packed = pack::pack_conv2d(&f, cout, kkc);
        let mut view = vec![0i8; kkc];
        let mut out = vec![0i8; geo.out_h * geo.out_w * cout];
        let s_tf = time_iters(warmup.min(5), iters, || {
            conv2d::conv2d_interp(&x, &f, &b, &geo, cout, -3, 0, m, 4, -128, 127, &mut view, &mut out);
            black_box(&out);
        });
        println!("{}", report_line(&format!("conv {label} interp"), &s_tf));
        for kb in &backends {
            let s_mf = time_iters(warmup.min(5), iters, || {
                conv2d::conv2d_microflow_with(*kb, &x, &packed, &geo, -3, &pc, &mut view, &mut out);
                black_box(&out);
            });
            println!("{}", report_line(&format!("conv {label} microflow/{}", kb.name()), &s_mf));
            t.row(vec![
                "conv2d".into(),
                kb.name().into(),
                label.into(),
                fmt_time(s_mf.median),
                fmt_time(s_tf.median),
                format!("{:.2}x", s_tf.median / s_mf.median),
            ]);
            rows.push(Row {
                kernel: "conv2d",
                backend: kb.name(),
                shape: label.into(),
                microflow_s: s_mf.median,
                interp_s: s_tf.median,
            });
        }
    }

    emit("kernels_micro", &t);

    // machine-readable artifact at the repo root: the cross-PR perf trail
    let shapes: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::obj()
                .set("kernel", r.kernel)
                .set("backend", r.backend)
                .set("shape", r.shape.clone())
                .set("microflow_s", r.microflow_s)
                .set("interp_s", r.interp_s)
                .set("ratio_interp_over_microflow", r.interp_s / r.microflow_s)
        })
        .collect();
    let avail: Vec<Json> = backend::available().into_iter().map(Json::from).collect();
    let doc = Json::obj()
        .set("bench", "kernels_micro")
        .set("iters", iters)
        .set("smoke", smoke)
        .set("active_backend", backend::active().name())
        .set("available_backends", avail)
        .set("shapes", shapes);
    // smoke runs go to a distinct (untracked) name so median-of-1 noise
    // can never overwrite the tracked perf trail
    emit_json(if smoke { "BENCH_kernels.smoke" } else { "BENCH_kernels" }, &doc);
    println!("kernels_micro OK");
}
