//! Per-step kernel profile bench — the observability plane's profiling
//! tier over the synthetic zoo.
//!
//! Runs WITHOUT build artifacts: every seeded-zoo model builds a native
//! session, attaches a [`StepProfiler`] (a fixed `[StepStat; MAX_STEPS]`
//! table — the observed hot path stays allocation-free) and runs N
//! profiled inferences. Two invariants are enforced, not just reported:
//!
//! * the profile rows must cover **every** plan step exactly once, in
//!   step order, with exactly N invocations each — a row that drops out
//!   or double-counts means the observer hook missed a step;
//! * the profiled outputs stay bit-exact with unprofiled runs (the
//!   observer is read-only; attaching it must not perturb inference).
//!
//! Besides the human table, writes machine-readable `BENCH_profile.json`
//! at the repo root (per-model step count, per-step ns totals, hottest
//! step) so per-layer cost trajectories are comparable across PRs.
//! `MICROFLOW_BENCH_SMOKE=1` cuts iteration counts for CI smoke runs.

use microflow::api::{Engine, Session};
use microflow::bench_support::smoke_mode;
use microflow::kernels::microkernel::backend;
use microflow::observe::StepProfiler;
use microflow::sim::report::{emit, emit_json, Table};
use microflow::synth;
use microflow::util::json::Json;
use microflow::util::Prng;

fn main() {
    println!("kernel backend: {}", backend::active().name());
    let (warmup, runs) = if smoke_mode() { (1, 10) } else { (10, 200) };
    let mut t = Table::new(
        "per-step kernel profile (native engine, StepProfiler attached)",
        &["model", "steps", "hottest step", "hottest ns/call", "total ns/run"],
    );
    let mut rows: Vec<Json> = Vec::new();
    for (name, m) in synth::zoo(0x0B5E) {
        let mut session = Session::builder(&m).engine(Engine::MicroFlow).build().unwrap();
        let mut rng = Prng::new(0xF00D ^ m.file_bytes as u64);
        let input = rng.i8_vec(session.input_len());
        let mut expected = vec![0i8; session.output_len()];
        session.run_into(&input, &mut expected).unwrap();
        let mut out = vec![0i8; session.output_len()];
        let mut profiler = StepProfiler::new();
        for _ in 0..warmup {
            session.run_into_observed(&input, &mut out, &mut profiler).unwrap();
        }
        profiler.reset();
        for _ in 0..runs {
            session.run_into_observed(&input, &mut out, &mut profiler).unwrap();
        }
        assert_eq!(out, expected, "{name}: profiled run diverged from the unprofiled oracle");
        let kinds = session.step_kinds();
        let profile = profiler.rows(&kinds);
        // coverage invariant: one row per plan step, in order, N calls each
        assert_eq!(profile.len(), kinds.len(), "{name}: profile rows must cover every step");
        assert_eq!(profiler.overflow(), 0, "{name}: zoo models must fit the fixed table");
        for (i, row) in profile.iter().enumerate() {
            assert_eq!(row.step, i, "{name}: rows must be in step order");
            assert_eq!(
                row.invocations, runs as u64,
                "{name} step {i} ({}): expected exactly {runs} invocations",
                row.kind
            );
        }
        let total_ns: u64 = profile.iter().map(|r| r.total_ns).sum();
        let hottest = profile.iter().max_by_key(|r| r.total_ns).unwrap();
        t.row(vec![
            name.clone(),
            profile.len().to_string(),
            format!("#{} {}", hottest.step, hottest.kind),
            hottest.ns_per_call().to_string(),
            format!("{}", total_ns / runs as u64),
        ]);
        let steps: Vec<Json> = profile
            .iter()
            .map(|r| {
                Json::obj()
                    .set("step", r.step)
                    .set("kind", r.kind)
                    .set("invocations", r.invocations as i64)
                    .set("total_ns", r.total_ns as i64)
                    .set("ns_per_call", r.ns_per_call() as i64)
            })
            .collect();
        rows.push(
            Json::obj()
                .set("model", name)
                .set("steps", steps)
                .set("total_ns_per_run", (total_ns / runs as u64) as i64)
                .set("hottest_step", hottest.step)
                .set("hottest_kind", hottest.kind),
        );
    }
    emit("profile_steps", &t);
    let doc = Json::obj()
        .set("bench", "profile_steps")
        .set("kernel_backend", backend::active().name())
        .set("runs", runs)
        .set("smoke", smoke_mode())
        .set("models", rows);
    emit_json(if smoke_mode() { "BENCH_profile.smoke" } else { "BENCH_profile" }, &doc);
    println!("profile_steps OK");
}
