//! Stream latency bench — pulsed per-frame push vs a full-window re-run.
//!
//! Runs WITHOUT build artifacts: every model of the seeded streaming zoo
//! (`microflow::synth::stream_zoo`) is compiled, pulse-planned (certified
//! `V401`–`V405`) and driven to steady state; then the incremental pulsed
//! push and the full-window replay oracle are timed over identical frame
//! sequences. Two invariants are enforced, not just reported:
//!
//! * the plan's MAC accounting (`sim::cost`) must show the pulsed path
//!   doing **strictly less** kernel work than a full-window re-run
//!   (`savings_ratio < 1` — the `V405` obligation, re-asserted here so
//!   the number in the JSON trail is the checked one);
//! * pulsed and replay verdicts stay bit-exact through the timed runs.
//!
//! Besides the human table, writes machine-readable `BENCH_stream.json`
//! at the repo root (per-model window/pulse geometry, planned MACs both
//! ways, measured per-frame latency both ways, speedup) so the streaming
//! perf trajectory is comparable across PRs. `MICROFLOW_BENCH_SMOKE=1`
//! cuts iteration counts for CI smoke runs.

use std::sync::Arc;

use microflow::api::{Engine, Session};
use microflow::bench_support::{black_box, smoke_mode, time_iters};
use microflow::compiler::plan::{CompileOptions, CompiledModel};
use microflow::compiler::PulsePlan;
use microflow::kernels::microkernel::backend;
use microflow::sim::report::{emit, emit_json, Table};
use microflow::stream::StreamSession;
use microflow::synth;
use microflow::util::json::Json;
use microflow::util::Prng;

fn main() {
    println!("kernel backend: {}", backend::active().name());
    let iters = if smoke_mode() { 3 } else { 100 };
    let mut t = Table::new(
        "stream latency: pulsed push vs full-window replay (per frame)",
        &["model", "window", "pulse", "prefix", "pulsed/frame", "replay/frame", "speedup", "mac ratio"],
    );
    let mut rows: Vec<Json> = Vec::new();
    for (name, m) in synth::stream_zoo(0x57AE) {
        let compiled = Arc::new(CompiledModel::compile(&m, CompileOptions::default()).unwrap());
        let plan = PulsePlan::plan(&compiled).unwrap();
        // the V405 obligation, re-checked where the trail is written: the
        // pulsed path must be strictly cheaper by the sim::cost model
        let pulse_macs = plan.pulse_macs(&compiled);
        let full_macs = plan.full_macs(&compiled);
        let mac_ratio = plan.savings_ratio(&compiled);
        assert!(
            pulse_macs < full_macs,
            "{name}: pulsed work ({pulse_macs} MACs) must be strictly below a \
             full-window re-run ({full_macs} MACs)"
        );

        let mut pulsed = StreamSession::pulsed(compiled.clone()).unwrap();
        let oracle = Session::builder(&m).engine(Engine::MicroFlow).build().unwrap();
        let mut replay = StreamSession::replay(oracle, plan.pulse_frames).unwrap();
        let mut rng = Prng::new(0xBEEF ^ plan.window_rows as u64);
        // steady state: fill the window on both paths, verdicts bit-exact
        for _ in 0..plan.window_rows {
            let f = rng.i8_vec(plan.frame_len);
            let a = pulsed.push(&f).unwrap();
            let b = replay.push(&f).unwrap();
            assert_eq!(a, b, "{name}: warmup diverged");
        }
        // one pulse worth of frames, reused for every timed iteration so
        // both paths chew identical inputs
        let frames: Vec<Vec<i8>> =
            (0..plan.pulse_frames).map(|_| rng.i8_vec(plan.frame_len)).collect();
        let sp = time_iters(2, iters, || {
            for f in &frames {
                black_box(pulsed.push(f).unwrap());
            }
        });
        let sr = time_iters(2, iters, || {
            for f in &frames {
                black_box(replay.push(f).unwrap());
            }
        });
        // both sessions consumed the same frame count — they are still in
        // lockstep; prove the timed work stayed bit-exact
        for f in &frames {
            assert_eq!(
                pulsed.push(f).unwrap(),
                replay.push(f).unwrap(),
                "{name}: timed runs diverged"
            );
        }
        let pulsed_frame = sp.median / plan.pulse_frames as f64;
        let replay_frame = sr.median / plan.pulse_frames as f64;
        let speedup = replay_frame / pulsed_frame.max(f64::MIN_POSITIVE);
        t.row(vec![
            name.clone(),
            plan.window_rows.to_string(),
            plan.pulse_frames.to_string(),
            format!("{}/{}", plan.prefix.len(), compiled.steps.len()),
            format!("{:.2}us", pulsed_frame * 1e6),
            format!("{:.2}us", replay_frame * 1e6),
            format!("{speedup:.2}x"),
            format!("{mac_ratio:.3}"),
        ]);
        rows.push(
            Json::obj()
                .set("model", name)
                .set("window_rows", plan.window_rows)
                .set("frame_len", plan.frame_len)
                .set("pulse_frames", plan.pulse_frames)
                .set("prefix_steps", plan.prefix.len())
                .set("total_steps", compiled.steps.len())
                .set("state_bytes", plan.total_state_bytes())
                .set("pulse_macs", pulse_macs as i64)
                .set("full_macs", full_macs as i64)
                .set("mac_ratio", mac_ratio)
                .set("pulsed_frame_s", pulsed_frame)
                .set("replay_frame_s", replay_frame)
                .set("speedup", speedup),
        );
    }
    emit("stream_latency", &t);
    let doc = Json::obj()
        .set("bench", "stream_latency")
        .set("kernel_backend", backend::active().name())
        .set("iters", iters)
        .set("smoke", smoke_mode())
        .set("models", rows);
    emit_json(if smoke_mode() { "BENCH_stream.smoke" } else { "BENCH_stream" }, &doc);
    println!("stream_latency OK");
}
