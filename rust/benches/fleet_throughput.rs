//! Fleet throughput bench — requests/sec vs replica count and pool mix.
//!
//! Runs WITHOUT build artifacts: a deterministic synthetic FC chain
//! (`microflow::synth`) is served by fleets of growing size under a
//! closed-loop multi-threaded client, measuring end-to-end requests/sec
//! through submit → least-outstanding dispatch → dynamic batcher →
//! `run_batch_into`. Scaling is sublinear on small models (the mutex'd
//! queue serializes batch assembly) — the point is to see where it bends.
//!
//! Also reports the warm-session-cache effect: every fleet builds its
//! replicas through one `SessionCache`, so N replicas cost one compile.
//!
//! Besides the human table, writes machine-readable `BENCH_fleet.json` at
//! the repo root (fleet mix, replicas, req/s, scaling vs x1, cache
//! hit/miss) so the serving-throughput trajectory is comparable across
//! PRs. `MICROFLOW_BENCH_SMOKE=1` cuts the request volume for CI smoke
//! runs.

use std::sync::Arc;
use std::time::Instant;

use microflow::api::{Engine, Session, SessionCache};
use microflow::coordinator::{Fleet, PoolSpec};
use microflow::format::mfb::MfbModel;
use microflow::bench_support::smoke_mode;
use microflow::sim::report::{emit, emit_json, Table};
use microflow::synth;
use microflow::util::json::Json;
use microflow::util::Prng;

const CLIENT_THREADS: usize = 8;

fn requests_per_thread() -> usize {
    if smoke_mode() {
        10
    } else {
        250
    }
}

/// Closed-loop: each client thread round-trips its requests as fast as
/// the fleet answers. Returns requests/sec.
fn drive(fleet: &Arc<Fleet>, input: &[i8]) -> f64 {
    let per_thread = requests_per_thread();
    let total = CLIENT_THREADS * per_thread;
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for _ in 0..CLIENT_THREADS {
        let fleet = Arc::clone(fleet);
        let input = input.to_vec();
        handles.push(std::thread::spawn(move || {
            for _ in 0..per_thread {
                fleet.infer(input.clone()).unwrap();
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    total as f64 / t0.elapsed().as_secs_f64()
}

fn pool(m: &MfbModel, cache: &Arc<SessionCache>, engine: Engine, n: usize, name: &str) -> PoolSpec {
    PoolSpec::new(
        name,
        (0..n)
            .map(|i| {
                Session::builder(m)
                    .engine(engine)
                    .label(format!("{name}/{i}"))
                    .cache(cache)
                    .build()
                    .unwrap()
            })
            .collect(),
    )
}

fn main() {
    let mut rng = Prng::new(0xF1EE7);
    // a model heavy enough that workers dominate the queue mutex
    let m = synth::fc_chain(&mut rng, &[64, 128, 128, 32, 4]);
    let input = rng.i8_vec(64);

    let mut t = Table::new(
        "fleet throughput (closed loop, 8 client threads)",
        &["fleet", "replicas", "req/s", "vs x1", "cache hit/miss"],
    );
    let mut base = 0.0f64;
    let mut rows: Vec<Json> = Vec::new();
    for replicas in [1usize, 2, 4] {
        let cache = Arc::new(SessionCache::new());
        let fleet = Arc::new(
            Fleet::start(vec![pool(&m, &cache, Engine::MicroFlow, replicas, "native")]).unwrap(),
        );
        let rps = drive(&fleet, &input);
        if replicas == 1 {
            base = rps;
        }
        t.row(vec![
            format!("native x{replicas}"),
            replicas.to_string(),
            format!("{rps:.0}"),
            format!("{:.2}x", rps / base),
            format!("{}/{}", cache.hits(), cache.misses()),
        ]);
        rows.push(
            Json::obj()
                .set("fleet", format!("native x{replicas}"))
                .set("replicas", replicas)
                .set("req_per_s", rps)
                .set("vs_x1", rps / base)
                .set("cache_hits", cache.hits() as i64)
                .set("cache_misses", cache.misses() as i64),
        );
        if let Ok(fleet) = Arc::try_unwrap(fleet) {
            fleet.shutdown();
        }
    }

    // heterogeneous: 2 native + 2 interp pools — dispatch keeps the slower
    // interpreter pool from becoming the bottleneck
    let cache = Arc::new(SessionCache::new());
    let fleet = Arc::new(
        Fleet::start(vec![
            pool(&m, &cache, Engine::MicroFlow, 2, "native"),
            pool(&m, &cache, Engine::Interp, 2, "interp"),
        ])
        .unwrap(),
    );
    let rps = drive(&fleet, &input);
    t.row(vec![
        "native x2 + interp x2".into(),
        "4".into(),
        format!("{rps:.0}"),
        format!("{:.2}x", rps / base),
        format!("{}/{}", cache.hits(), cache.misses()),
    ]);
    rows.push(
        Json::obj()
            .set("fleet", "native x2 + interp x2")
            .set("replicas", 4usize)
            .set("req_per_s", rps)
            .set("vs_x1", rps / base)
            .set("cache_hits", cache.hits() as i64)
            .set("cache_misses", cache.misses() as i64),
    );
    let snap = fleet.snapshot();
    assert_eq!(
        snap.totals.completed,
        (CLIENT_THREADS * requests_per_thread()) as u64,
        "fleet lost requests"
    );
    for (name, s) in &snap.per_pool {
        println!("  [{name}] {s}");
    }
    if let Ok(fleet) = Arc::try_unwrap(fleet) {
        fleet.shutdown();
    }

    emit("fleet_throughput", &t);

    // machine-readable artifact at the repo root: the cross-PR trail
    let doc = Json::obj()
        .set("bench", "fleet_throughput")
        .set("client_threads", CLIENT_THREADS)
        .set("requests_per_thread", requests_per_thread())
        .set("smoke", smoke_mode())
        .set("fleets", rows);
    emit_json(if smoke_mode() { "BENCH_fleet.smoke" } else { "BENCH_fleet" }, &doc);
    println!("fleet_throughput OK");
}
