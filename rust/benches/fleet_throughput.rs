//! Fleet throughput bench — requests/sec vs replica count and pool mix,
//! plus per-class latency under QoS-aware dispatch.
//!
//! Runs WITHOUT build artifacts: a deterministic synthetic FC chain
//! (`microflow::synth`) is served by fleets of growing size under a
//! closed-loop multi-threaded client, measuring end-to-end requests/sec
//! through submit → class-aware least-outstanding dispatch → dynamic
//! batcher → `run_batch_into`. Scaling is sublinear on small models (the
//! mutex'd queue serializes batch assembly) — the point is to see where it
//! bends.
//!
//! Also reports the warm-session-cache effect (every fleet builds its
//! replicas through one `SessionCache`, so N replicas cost one compile)
//! and, for the heterogeneous fleet, the per-class p50/p95 the QoS routing
//! produces: interactive requests pinned to the native pool, bulk to the
//! interpreter pool.
//!
//! Besides the human table, writes machine-readable `BENCH_fleet.json` at
//! the repo root (fleet mix, replicas, req/s, scaling vs x1, cache
//! hit/miss, per-class p95) so the serving-throughput trajectory is
//! comparable across PRs. `MICROFLOW_BENCH_SMOKE=1` cuts the request
//! volume for CI smoke runs.

use std::sync::Arc;
use std::time::{Duration, Instant};

use microflow::api::{Engine, ReplicaFactory, Session, SessionCache};
use microflow::bench_support::smoke_mode;
use microflow::coordinator::{AutoscalePolicy, Fleet, PoolSpec, QosClass, QosProfile, Request};
use microflow::format::mfb::MfbModel;
use microflow::kernels::microkernel::backend;
use microflow::sim::report::{emit, emit_json, Table};
use microflow::synth;
use microflow::util::json::Json;
use microflow::util::Prng;

const CLIENT_THREADS: usize = 8;

fn requests_per_thread() -> usize {
    if smoke_mode() {
        10
    } else {
        250
    }
}

/// Closed-loop: each client thread round-trips its requests as fast as
/// the fleet answers, tagging them with `class` (Bulk = the legacy
/// semantics; a thread-index-odd blend exercises QoS routing). Returns
/// requests/sec.
fn drive(fleet: &Arc<Fleet>, input: &[i8], mixed_classes: bool) -> f64 {
    let per_thread = requests_per_thread();
    let total = CLIENT_THREADS * per_thread;
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for t in 0..CLIENT_THREADS {
        let fleet = Arc::clone(fleet);
        let input = input.to_vec();
        let class =
            if mixed_classes && t % 2 == 1 { QosClass::Interactive } else { QosClass::Bulk };
        handles.push(std::thread::spawn(move || {
            for _ in 0..per_thread {
                let req = Request::new(input.clone()).with_class(class);
                fleet.submit(req).unwrap().wait().unwrap();
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    total as f64 / t0.elapsed().as_secs_f64()
}

fn pool(m: &MfbModel, cache: &Arc<SessionCache>, engine: Engine, n: usize, name: &str) -> PoolSpec {
    PoolSpec::new(
        name,
        (0..n)
            .map(|i| {
                Session::builder(m)
                    .engine(engine)
                    .label(format!("{name}/{i}"))
                    .cache(cache)
                    .build()
                    .unwrap()
            })
            .collect(),
    )
}

/// One table + JSON row from a finished drive: throughput, scaling and the
/// per-class p95 split (worst pool per class — a pinned class has exactly
/// one serving pool anyway).
#[allow(clippy::too_many_arguments)]
fn push_row(
    t: &mut Table,
    rows: &mut Vec<Json>,
    fleet: &Fleet,
    label: &str,
    replicas: usize,
    rps: f64,
    base: f64,
    cache: &SessionCache,
) {
    let snap = fleet.snapshot();
    let mut int_p95 = 0.0f64;
    let mut bulk_p95 = 0.0f64;
    for p in &snap.per_pool {
        int_p95 = int_p95.max(p.metrics.class(QosClass::Interactive).p95_us);
        bulk_p95 = bulk_p95.max(p.metrics.class(QosClass::Bulk).p95_us);
    }
    t.row(vec![
        label.to_string(),
        replicas.to_string(),
        format!("{rps:.0}"),
        format!("{:.2}x", rps / base),
        format!("{int_p95:.0}"),
        format!("{bulk_p95:.0}"),
        format!("{}/{}", cache.hits(), cache.misses()),
    ]);
    rows.push(
        Json::obj()
            .set("fleet", label)
            .set("replicas", replicas)
            .set("req_per_s", rps)
            .set("vs_x1", rps / base)
            .set("interactive_p95_us", int_p95)
            .set("bulk_p95_us", bulk_p95)
            .set("cache_hits", cache.hits() as i64)
            .set("cache_misses", cache.misses() as i64),
    );
}

fn main() {
    // every native replica below runs on this backend — print it so the
    // throughput numbers in the JSON trail are interpretable
    println!("kernel backend: {}", backend::active().name());
    let mut rng = Prng::new(0xF1EE7);
    // a model heavy enough that workers dominate the queue mutex
    let m = synth::fc_chain(&mut rng, &[64, 128, 128, 32, 4]);
    let input = rng.i8_vec(64);

    let mut t = Table::new(
        "fleet throughput (closed loop, 8 client threads)",
        &["fleet", "replicas", "req/s", "vs x1", "int p95 us", "bulk p95 us", "cache hit/miss"],
    );
    let mut base = 0.0f64;
    let mut rows: Vec<Json> = Vec::new();
    for replicas in [1usize, 2, 4] {
        let cache = Arc::new(SessionCache::new());
        let fleet = Arc::new(
            Fleet::start(vec![pool(&m, &cache, Engine::MicroFlow, replicas, "native")]).unwrap(),
        );
        let rps = drive(&fleet, &input, false);
        if replicas == 1 {
            base = rps;
        }
        let label = format!("native x{replicas}");
        push_row(&mut t, &mut rows, &fleet, &label, replicas, rps, base, &cache);
        if let Ok(fleet) = Arc::try_unwrap(fleet) {
            fleet.shutdown();
        }
    }

    // heterogeneous: 2 native + 2 interp pools — dispatch keeps the slower
    // interpreter pool from becoming the bottleneck
    let cache = Arc::new(SessionCache::new());
    let fleet = Arc::new(
        Fleet::start(vec![
            pool(&m, &cache, Engine::MicroFlow, 2, "native"),
            pool(&m, &cache, Engine::Interp, 2, "interp"),
        ])
        .unwrap(),
    );
    let rps = drive(&fleet, &input, false);
    push_row(&mut t, &mut rows, &fleet, "native x2 + interp x2", 4, rps, base, &cache);
    let snap = fleet.snapshot();
    assert_eq!(
        snap.totals.completed,
        (CLIENT_THREADS * requests_per_thread()) as u64,
        "fleet lost requests"
    );
    for p in &snap.per_pool {
        println!("  [{}] {}", p.name, p.metrics);
    }
    if let Ok(fleet) = Arc::try_unwrap(fleet) {
        fleet.shutdown();
    }

    // the same heterogeneous layout under QoS routing: native declares
    // Interactive, interp declares Bulk, and half the client threads send
    // interactive traffic — per-class p95 shows the latency split the
    // SLO-aware dispatch buys
    let cache = Arc::new(SessionCache::new());
    let fleet = Arc::new(
        Fleet::start(vec![
            pool(&m, &cache, Engine::MicroFlow, 2, "native").profile(QosProfile::Interactive),
            pool(&m, &cache, Engine::Interp, 2, "interp").profile(QosProfile::Bulk),
        ])
        .unwrap(),
    );
    let rps = drive(&fleet, &input, true);
    push_row(&mut t, &mut rows, &fleet, "qos: native=int, interp=bulk", 4, rps, base, &cache);
    let snap = fleet.snapshot();
    let native = snap.pool("native").unwrap();
    let interp = snap.pool("interp").unwrap();
    assert_eq!(
        interp.metrics.class(QosClass::Interactive).submitted,
        0,
        "interactive traffic leaked to the bulk pool"
    );
    assert_eq!(
        native.metrics.class(QosClass::Bulk).submitted,
        0,
        "bulk traffic leaked to the interactive pool"
    );
    for p in &snap.per_pool {
        println!("  [{}] {}", p.name, p.metrics);
    }
    if let Ok(fleet) = Arc::try_unwrap(fleet) {
        fleet.shutdown();
    }

    emit("fleet_throughput", &t);

    // SLO-driven autoscaling under a bursty, phase-shifting workload: the
    // pool starts at one replica; each burst phase drives the closed loop
    // (half interactive) and ticks the controller, whose aggressive 1µs
    // interactive-p95 target makes any served burst a breach — so the
    // trajectory shows the ramp; each idle phase ticks with no traffic
    // until graceful drain walks the pool back to the floor. Rows record
    // req/s and the replica count each drive ran with.
    let cache = Arc::new(SessionCache::new());
    let factory = Arc::new(
        ReplicaFactory::new(&m, Engine::MicroFlow).cache(&cache).label_prefix("native"),
    );
    let policy = AutoscalePolicy::new(1, 4)
        .slo_p95(Duration::from_micros(1))
        .idle_ticks_down(2)
        .cooldown_ticks(0);
    let fleet = Arc::new(
        Fleet::start(vec![PoolSpec::new("native", vec![factory.provision().unwrap()])
            .autoscale(policy, Arc::clone(&factory))])
        .unwrap(),
    );
    let mut t2 = Table::new(
        "autoscale: bursty phase-shifting workload (native 1..4 replicas)",
        &["phase", "replicas", "req/s", "after tick"],
    );
    let mut phases: Vec<Json> = Vec::new();
    let mut trajectory: Vec<usize> = vec![fleet.snapshot().per_pool[0].live_replicas()];
    let mut submitted_total = 0u64;
    for burst in ["burst-a", "burst-b"] {
        // two drives per burst: the second runs on whatever the breach tick
        // provisioned, so the row pair shows the scale-up paying off
        for sub in ["cold", "scaled"] {
            let replicas = fleet.snapshot().per_pool[0].live_replicas();
            let rps = drive(&fleet, &input, true);
            submitted_total += (CLIENT_THREADS * requests_per_thread()) as u64;
            let after = fleet.tick()[0].live_replicas;
            trajectory.push(after);
            t2.row(vec![
                format!("{burst}/{sub}"),
                replicas.to_string(),
                format!("{rps:.0}"),
                format!("x{after}"),
            ]);
            phases.push(
                Json::obj()
                    .set("phase", format!("{burst}/{sub}"))
                    .set("replicas", replicas)
                    .set("req_per_s", rps)
                    .set("replicas_after_tick", after),
            );
        }
        // idle phase: no traffic, tick until the pool is back at the floor
        let mut idle_ticks = 0usize;
        loop {
            let live = fleet.tick()[0].live_replicas;
            trajectory.push(live);
            idle_ticks += 1;
            if live == 1 || idle_ticks > 20 {
                break;
            }
        }
        t2.row(vec![
            format!("{burst}/idle"),
            "1".into(),
            "0".into(),
            format!("{idle_ticks} ticks to floor"),
        ]);
        phases.push(
            Json::obj()
                .set("phase", format!("{burst}/idle"))
                .set("replicas", 1usize)
                .set("req_per_s", 0.0)
                .set("idle_ticks_to_floor", idle_ticks),
        );
    }
    let snap = fleet.snapshot();
    let peak = *trajectory.iter().max().unwrap();
    assert!(peak > 1, "the bursts never scaled the pool up: {trajectory:?}");
    assert_eq!(
        *trajectory.last().unwrap(),
        1,
        "idle phases never drained back to the floor: {trajectory:?}"
    );
    assert_eq!(
        snap.totals.completed + snap.totals.shed + snap.totals.cancelled + snap.totals.failed,
        submitted_total,
        "autoscaled pool lost requests: {snap}"
    );
    println!("  replica trajectory: {trajectory:?}");
    if let Ok(fleet) = Arc::try_unwrap(fleet) {
        fleet.shutdown();
    }
    emit("fleet_throughput_autoscale", &t2);

    // machine-readable artifact at the repo root: the cross-PR trail
    let doc = Json::obj()
        .set("bench", "fleet_throughput")
        .set("kernel_backend", backend::active().name())
        .set("client_threads", CLIENT_THREADS)
        .set("requests_per_thread", requests_per_thread())
        .set("smoke", smoke_mode())
        .set("fleets", rows)
        .set("autoscale_peak_replicas", peak)
        .set(
            "autoscale_trajectory",
            trajectory.iter().map(|&r| Json::Int(r as i64)).collect::<Vec<Json>>(),
        )
        .set("autoscale_phases", phases);
    emit_json(if smoke_mode() { "BENCH_fleet.smoke" } else { "BENCH_fleet" }, &doc);
    println!("fleet_throughput OK");
}
