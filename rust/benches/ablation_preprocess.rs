//! Ablation: compile-time pre-processing (paper Sec. 3.3.3; DESIGN.md E9).
//!
//! Quantifies what the MicroFlow Compiler's constant folding buys: the
//! same float-scale kernel is run (a) with constants folded once at
//! compile time (the shipped path) vs (b) re-deriving the Eq. 4 constants
//! on every inference (what a naive runtime without a pre-processing phase
//! would do). Also reports the end-to-end compile-vs-interpret split on
//! the shipped models: compile cost is paid once, invoke cost every time.

use std::time::Instant;

use microflow::api::{Engine, Session};
use microflow::bench_support::{black_box, time_iters};
use microflow::compiler::plan::{CompileOptions, CompiledModel};
use microflow::format::mfb::MfbModel;
use microflow::kernels::fully_connected::fully_connected_microflow;
use microflow::sim::report::{emit, Table};
use microflow::tensor::quant::{FusedAct, PreComputed};
use microflow::util::{fmt_time, Prng};

fn main() -> anyhow::Result<()> {
    // --- kernel-level: folded vs re-derived constants ---
    let mut rng = Prng::new(4);
    let mut t = Table::new(
        "ablation: pre-processing — folded constants vs per-inference folding",
        &["K x N", "folded", "refold each call", "overhead"],
    );
    for (k, n) in [(16usize, 16usize), (256, 64), (4000, 4)] {
        let x = rng.i8_vec(k);
        let w = rng.i8_vec(k * n);
        let b = rng.i32_vec(n, -500, 500);
        let colsum: Vec<i32> = (0..n).map(|j| (0..k).map(|i| w[i * n + j] as i32).sum()).collect();
        let pc = PreComputed::fold(&b, &colsum, k, 0.05, 3, 0.02, -1, 0.001, 0, 0.08, 0, FusedAct::None);
        let mut out = vec![0i8; n];
        let s_folded = time_iters(10, 100, || {
            fully_connected_microflow(&x, &w, k, n, &pc, &mut out);
            black_box(&out);
        });
        let s_refold = time_iters(10, 100, || {
            // a runtime without Sec. 3.3.3 recomputes the weight column
            // sums and constant terms per inference
            let colsum: Vec<i32> =
                (0..n).map(|j| (0..k).map(|i| w[i * n + j] as i32).sum()).collect();
            let pc2 = PreComputed::fold(&b, &colsum, k, 0.05, 3, 0.02, -1, 0.001, 0, 0.08, 0, FusedAct::None);
            fully_connected_microflow(&x, &w, k, n, &pc2, &mut out);
            black_box(&out);
        });
        t.row(vec![
            format!("{k}x{n}"),
            fmt_time(s_folded.median),
            fmt_time(s_refold.median),
            format!("+{:.0}%", (s_refold.median / s_folded.median - 1.0) * 100.0),
        ]);
    }
    emit("ablation_preprocess_kernel", &t);

    // --- model-level: one-time compile vs per-inference interpret ---
    let art = microflow::artifacts_dir();
    let mut t2 = Table::new(
        "compile-once vs interpret-every-time (host)",
        &["model", "MF compile (once)", "MF invoke", "interp init (once)", "interp invoke"],
    );
    for name in ["sine", "speech", "person"] {
        let path = art.join(format!("{name}.mfb"));
        let bytes = std::fs::read(&path)?;
        let model = MfbModel::parse(&bytes)?;

        // construct the builders (and their model-source copies) OUTSIDE
        // the timed windows: the columns measure compile/prepare work, as
        // the seed did with the bare constructors
        let native_builder = Session::builder(&model).engine(Engine::MicroFlow);
        let t0 = Instant::now();
        let mut engine = native_builder.build()?;
        let compile_t = t0.elapsed().as_secs_f64();

        let interp_builder = Session::builder(bytes.clone()).engine(Engine::Interp);
        let t0 = Instant::now();
        let mut interp = interp_builder.build()?;
        let init_t = t0.elapsed().as_secs_f64();

        let mut rng = Prng::new(2);
        let input = rng.i8_vec(engine.input_len());
        let mut out = vec![0i8; engine.output_len()];
        let mut out_in = vec![0i8; interp.output_len()];
        let iters = if name == "person" { 20 } else { 100 };
        let s_mf = time_iters(3, iters, || engine.run_into(&input, &mut out).unwrap());
        let s_in = time_iters(3, iters, || interp.run_into(&input, &mut out_in).unwrap());
        t2.row(vec![
            name.into(),
            fmt_time(compile_t),
            fmt_time(s_mf.median),
            fmt_time(init_t),
            fmt_time(s_in.median),
        ]);
        // the central claim: compile work is front-loaded, invoke is lean
        let compiled = CompiledModel::compile(&model, CompileOptions::default())?;
        assert!(compiled.total_macs() > 0);
    }
    emit("ablation_preprocess_model", &t2);
    println!("ablation_preprocess OK");
    Ok(())
}
