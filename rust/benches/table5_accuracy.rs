//! Table 5 — accuracy of MicroFlow vs the TFLM-like interpreter on the
//! three models (experiment E3 in DESIGN.md).
//!
//! Protocol exactly as the paper (Sec. 6.2.1): sine on 1000 noisy samples
//! with MSE/RMSE against the true function; speech on 1236 samples with
//! macro-averaged Precision/Recall/F1; person on 406 samples with
//! positive-class Precision/Recall/F1.
//!
//! Expected shape (paper Table 5): the two engines are on par, differing
//! only through the ±1 requantization rounding.

use microflow::api::{Engine, Session};
use microflow::eval::accuracy::{evaluate_classifier, evaluate_sine};
use microflow::format::mds::MdsDataset;
use microflow::sim::report::{emit, Table};

fn pct(v: f64) -> String {
    format!("{:.3}%", v * 100.0)
}

fn main() -> anyhow::Result<()> {
    let art = microflow::artifacts_dir();
    anyhow::ensure!(art.join("sine.mfb").exists(), "run `make artifacts` first");

    let engines = |name: &str| -> anyhow::Result<(Session, Session)> {
        let path = art.join(format!("{name}.mfb"));
        let e = Session::builder(&path).engine(Engine::MicroFlow).build()?;
        let i = Session::builder(&path).engine(Engine::Interp).build()?;
        Ok((e, i))
    };

    // --- sine ---
    let ds = MdsDataset::load(art.join("sine_test.mds"))?;
    let (mut mf, mut tf) = engines("sine")?;
    let s_mf = evaluate_sine(&mut mf, &ds)?;
    let s_tf = evaluate_sine(&mut tf, &ds)?;
    let mut t = Table::new(
        "Table 5 (left) — sine predictor, MSE/RMSE vs true sin(x), n=1000",
        &["metric", "TFLM(interp)", "MicroFlow", "paper TFLM", "paper MicroFlow"],
    );
    t.row(vec!["MSE".into(), format!("{:.4}", s_tf.mse), format!("{:.4}", s_mf.mse), "0.0157".into(), "0.0154".into()]);
    t.row(vec!["RMSE".into(), format!("{:.4}", s_tf.rmse), format!("{:.4}", s_mf.rmse), "0.1253".into(), "0.1241".into()]);
    emit("table5_sine", &t);
    assert!((s_mf.mse - s_tf.mse).abs() < 0.005, "engines must be on par (sine)");

    // --- speech (macro-averaged over 4 classes) ---
    let ds = MdsDataset::load(art.join("speech_test.mds"))?;
    let (mut mf, mut tf) = engines("speech")?;
    let c_mf = evaluate_classifier(&mut mf, &ds, 4, true)?;
    let c_tf = evaluate_classifier(&mut tf, &ds, 4, true)?;
    let mut t = Table::new(
        "Table 5 (middle) — speech command recognizer, macro P/R/F1, n=1236",
        &["metric", "TFLM(interp)", "MicroFlow", "paper TFLM", "paper MicroFlow"],
    );
    t.row(vec!["Precision".into(), pct(c_tf.precision), pct(c_mf.precision), "91.737%".into(), "91.638%".into()]);
    t.row(vec!["Recall".into(), pct(c_tf.recall), pct(c_mf.recall), "88.611%".into(), "88.972%".into()]);
    t.row(vec!["F1".into(), pct(c_tf.f1), pct(c_mf.f1), "90.147%".into(), "90.285%".into()]);
    emit("table5_speech", &t);
    assert!((c_mf.f1 - c_tf.f1).abs() < 0.02, "engines must be on par (speech)");

    // --- person (positive class) ---
    let ds = MdsDataset::load(art.join("person_test.mds"))?;
    let (mut mf, mut tf) = engines("person")?;
    let p_mf = evaluate_classifier(&mut mf, &ds, 2, false)?;
    let p_tf = evaluate_classifier(&mut tf, &ds, 2, false)?;
    let mut t = Table::new(
        "Table 5 (right) — person detector, P/R/F1, n=406",
        &["metric", "TFLM(interp)", "MicroFlow", "paper TFLM", "paper MicroFlow"],
    );
    t.row(vec!["Precision".into(), pct(p_tf.precision), pct(p_mf.precision), "71.843%".into(), "72.003%".into()]);
    t.row(vec!["Recall".into(), pct(p_tf.recall), pct(p_mf.recall), "85.382%".into(), "85.401%".into()]);
    t.row(vec!["F1".into(), pct(p_tf.f1), pct(p_mf.f1), "78.030%".into(), "78.132%".into()]);
    emit("table5_person", &t);
    assert!((p_mf.f1 - p_tf.f1).abs() < 0.03, "engines must be on par (person)");

    // the paper's ordering: speech scores above person (harder task)
    assert!(c_mf.f1 > p_mf.f1, "speech should outscore person, as in the paper");
    println!("table5_accuracy OK");
    Ok(())
}
