//! Ablation: paging (experiment E8 in DESIGN.md; paper Sec. 4.3).
//!
//! Measures the two sides of the paging trade on the real sine model and
//! on synthetic FC layers of growing width:
//!
//! * RAM: per-page working set vs full working set (paper's 163 B vs 5 kB
//!   example, computed by the actual PagePlan);
//! * time: host-measured slowdown of the paged executor (Flash re-reads).

use microflow::api::Session;
use microflow::bench_support::{black_box, time_iters};
use microflow::compiler::paging::PagePlan;
use microflow::kernels::fully_connected::{fully_connected_microflow, fully_connected_paged};
use microflow::sim::report::{emit, Table};
use microflow::tensor::quant::{FusedAct, PreComputed};
use microflow::util::{fmt_time, Prng};

fn main() -> anyhow::Result<()> {
    let mut t = Table::new(
        "ablation: paging — RAM (paper costing) and host time per FC layer",
        &["K x N", "unpaged RAM", "paged RAM/page", "unpaged time", "paged time", "slowdown"],
    );
    let mut rng = Prng::new(9);
    for (k, n) in [(32usize, 32usize), (64, 64), (256, 64), (1024, 32)] {
        let plan = PagePlan::for_fully_connected(k, n);
        let x = rng.i8_vec(k);
        let w = rng.i8_vec(k * n);
        let b = rng.i32_vec(n, -500, 500);
        let colsum: Vec<i32> = (0..n).map(|j| (0..k).map(|i| w[i * n + j] as i32).sum()).collect();
        let pc = PreComputed::fold(&b, &colsum, k, 0.05, 3, 0.02, 0, 0.001, 0, 0.08, 0, FusedAct::None);
        let mut out = vec![0i8; n];
        let mut page = vec![0i8; k];
        let s_un = time_iters(10, 200, || {
            fully_connected_microflow(&x, &w, k, n, &pc, &mut out);
            black_box(&out);
        });
        let s_pg = time_iters(10, 200, || {
            fully_connected_paged(&x, &w, k, n, &pc, &mut page, &mut out);
            black_box(&out);
        });
        t.row(vec![
            format!("{k}x{n}"),
            format!("{} B", plan.unpaged_bytes),
            format!("{} B", plan.page_bytes),
            fmt_time(s_un.median),
            fmt_time(s_pg.median),
            format!("{:.2}x", s_pg.median / s_un.median),
        ]);
    }
    emit("ablation_paging", &t);

    // the paper's exact worked example must hold
    assert_eq!(PagePlan::paged_ram(32), 163);
    assert!(PagePlan::unpaged_ram(32, 32) > 5000);

    // whole-model: paged == unpaged outputs on the shipped sine model
    let art = microflow::artifacts_dir();
    let path = art.join("sine.mfb");
    let mut a = Session::builder(&path).paging(false).build()?;
    let mut b = Session::builder(&path).paging(true).build()?;
    for q in (-120..=120).step_by(7) {
        assert_eq!(a.run(&[q])?, b.run(&[q])?);
    }
    println!("ablation_paging OK");
    Ok(())
}
