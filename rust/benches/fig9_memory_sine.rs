//! Fig. 9 — Flash and RAM for the sine predictor across all five MCUs
//! (experiment E4 in DESIGN.md).
//!
//! Expected shape (paper Sec. 6.2.2): MicroFlow ~65% less Flash than TFLM
//! on ESP32; MicroFlow RAM ~5.3 kB vs TFLM ~45.7 kB on nRF52840; MicroFlow
//! runs on ALL five devices including the 8-bit ATmega328 (~13.6 kB Flash
//! / ~1.7 kB RAM with paging); TFLM only on ESP32 + nRF52840.

use microflow::compiler::plan::{CompileOptions, CompiledModel};
use microflow::format::mfb::MfbModel;
use microflow::interp::arena::ArenaPlan;
use microflow::sim::report::{emit, Table};
use microflow::sim::{self, Engine, MCUS};
use microflow::util::fmt_kb;

fn main() -> anyhow::Result<()> {
    let art = microflow::artifacts_dir();
    let model = MfbModel::load(art.join("sine.mfb"))?;
    let arena = ArenaPlan::plan(&model)?;

    let mut t = Table::new(
        "Fig. 9 — sine predictor memory (Flash / RAM per MCU)",
        &["mcu", "TFLM flash", "MF flash", "TFLM ram", "MF ram", "TFLM runs", "MF runs"],
    );

    let mut esp_flash = (0usize, 0usize);
    let mut nrf_ram = (0usize, 0usize);
    let mut mf_runs_everywhere = true;

    for mcu in MCUS.iter() {
        let paging = mcu.ram_bytes <= 4 * 1024;
        let compiled = CompiledModel::compile(&model, CompileOptions { paging, ..Default::default() })?;
        let mf = sim::memory_model::microflow_footprint(&compiled, mcu);
        let tf = sim::memory_model::tflm_footprint(&model, &arena, mcu);
        let mf_ok = sim::memory_model::fits(mcu, Engine::MicroFlow, mf).is_ok();
        let tf_ok = sim::memory_model::fits(mcu, Engine::Tflm, tf).is_ok();
        mf_runs_everywhere &= mf_ok;
        if mcu.name == "ESP32" {
            esp_flash = (tf.flash, mf.flash);
        }
        if mcu.name == "nRF52840" {
            nrf_ram = (tf.ram, mf.ram);
        }
        t.row(vec![
            mcu.name.into(),
            fmt_kb(tf.flash),
            fmt_kb(mf.flash),
            fmt_kb(tf.ram),
            fmt_kb(mf.ram),
            if tf_ok { "yes" } else { "NO" }.into(),
            if mf_ok { "yes" } else { "NO" }.into(),
        ]);
    }
    emit("fig9_memory_sine", &t);

    // paper-shape assertions
    let flash_saving = 1.0 - esp_flash.1 as f64 / esp_flash.0 as f64;
    println!("ESP32 Flash saving: {:.0}% (paper: ~65%)", flash_saving * 100.0);
    assert!(flash_saving > 0.5, "MicroFlow must save most of the Flash on ESP32");
    let ram_ratio = nrf_ram.0 as f64 / nrf_ram.1 as f64;
    println!("nRF52840 RAM ratio TFLM/MF: {:.1}x (paper: 45.7/5.3 ≈ 8.6x)", ram_ratio);
    assert!(ram_ratio > 4.0, "TFLM RAM must dwarf MicroFlow's on the sine model");
    assert!(mf_runs_everywhere, "MicroFlow must fit all five devices (paper)");
    println!("fig9_memory_sine OK");
    Ok(())
}
