//! Fig. 11 — inference time of the three models on the two MCUs both
//! frameworks support (experiment E6 in DESIGN.md).
//!
//! Two layers of evidence:
//! 1. **Simulated device time** from the calibrated cycle model
//!    (`sim::cost`) — reproduces the paper's ratios: sine ~10x faster on
//!    MicroFlow, speech +9% (ESP32) / +15% (nRF52840), person ~6% in
//!    TFLM's favour, nRF52840 ≈ 3x faster than ESP32 wall-clock.
//! 2. **Host-measured wall-clock** of the two real engines in this repo
//!    (median of 100, the paper's own protocol) — shows the same
//!    *mechanism* (interpreter overhead dominates small models, MAC work
//!    dominates large ones) with real, unmodeled numbers.

use microflow::api::Session;
use microflow::bench_support::{paper_protocol, report_line};
use microflow::compiler::plan::{CompileOptions, CompiledModel};
use microflow::format::mfb::MfbModel;
use microflow::sim::report::{emit, Table};
use microflow::sim::{self, Engine};
use microflow::util::{fmt_time, Prng};

fn main() -> anyhow::Result<()> {
    let art = microflow::artifacts_dir();
    let mcus = ["ESP32", "nRF52840"];
    let models = ["sine", "speech", "person"];

    // --- layer 1: modeled device times (the Fig. 11 series) ---
    let mut t = Table::new(
        "Fig. 11 — modeled inference time (median-equivalent, per device)",
        &["model", "mcu", "TFLM", "MicroFlow", "TFLM/MF ratio", "paper"],
    );
    let paper_note = [
        ("sine", "~10x MicroFlow"),
        ("speech", "+9% ESP32 / +15% nRF"),
        ("person", "~6% TFLM ahead"),
    ];
    let mut ratios = std::collections::HashMap::new();
    for model_name in models {
        let model = MfbModel::load(art.join(format!("{model_name}.mfb")))?;
        let compiled = CompiledModel::compile(&model, CompileOptions::default())?;
        for mcu_name in mcus {
            let mcu = sim::mcu::by_name(mcu_name).unwrap();
            let mf = sim::inference_seconds(&compiled, mcu, Engine::MicroFlow);
            let tf = sim::inference_seconds(&compiled, mcu, Engine::Tflm);
            ratios.insert((model_name, mcu_name), tf / mf);
            t.row(vec![
                model_name.into(),
                mcu_name.into(),
                fmt_time(tf),
                fmt_time(mf),
                format!("{:.2}x", tf / mf),
                paper_note.iter().find(|(m, _)| *m == model_name).unwrap().1.into(),
            ]);
        }
    }
    emit("fig11_runtime_modeled", &t);

    // paper-shape assertions on the modeled ratios
    assert!(ratios[&("sine", "ESP32")] > 5.0, "sine ESP32 ratio {}", ratios[&("sine", "ESP32")]);
    assert!(ratios[&("sine", "nRF52840")] > 5.0);
    let sp_esp = ratios[&("speech", "ESP32")];
    let sp_nrf = ratios[&("speech", "nRF52840")];
    assert!(sp_esp > 1.02 && sp_esp < 1.30, "speech ESP32 ratio {sp_esp} (paper +9%)");
    assert!(sp_nrf > 1.05 && sp_nrf < 1.35, "speech nRF ratio {sp_nrf} (paper +15%)");
    assert!(sp_nrf > sp_esp, "MicroFlow's speech edge is larger on nRF (paper)");
    let pe_esp = ratios[&("person", "ESP32")];
    let pe_nrf = ratios[&("person", "nRF52840")];
    assert!(pe_esp < 1.0 && pe_esp > 0.85, "person ESP32 ratio {pe_esp} (paper: TFLM ~6% ahead)");
    assert!(pe_nrf < 1.0 && pe_nrf > 0.85, "person nRF ratio {pe_nrf}");

    // the counterintuitive cross-device result: nRF (64 MHz) beats ESP32
    // (240 MHz) by ~3x on the larger models
    let model = MfbModel::load(art.join("speech.mfb"))?;
    let compiled = CompiledModel::compile(&model, CompileOptions::default())?;
    let esp = sim::inference_seconds(&compiled, sim::mcu::by_name("ESP32").unwrap(), Engine::MicroFlow);
    let nrf = sim::inference_seconds(&compiled, sim::mcu::by_name("nRF52840").unwrap(), Engine::MicroFlow);
    println!("speech wall-clock ESP32/nRF52840 = {:.2}x (paper: >3x)", esp / nrf);
    assert!(esp / nrf > 2.5, "nRF must outrun ESP32 despite the slower clock");

    // --- layer 2: host-measured wall-clock of the real engines ---
    println!("\nhost wall-clock (median of 100, this machine — mechanism check):");
    let mut t2 = Table::new(
        "Fig. 11 (host) — measured engine time on this machine",
        &["model", "tflm-interp", "microflow", "ratio"],
    );
    for model_name in models {
        let path = art.join(format!("{model_name}.mfb"));
        let mut engine = Session::builder(&path).engine(microflow::api::Engine::MicroFlow).build()?;
        let mut interp = Session::builder(&path).engine(microflow::api::Engine::Interp).build()?;
        let mut rng = Prng::new(1);
        let input = rng.i8_vec(engine.input_len());
        let mut out = vec![0i8; engine.output_len()];
        let mut out_tf = vec![0i8; interp.output_len()];
        // both engines timed on the same allocation-free run_into hot path
        let s_mf = paper_protocol(|| engine.run_into(&input, &mut out).unwrap());
        let s_tf = paper_protocol(|| interp.run_into(&input, &mut out_tf).unwrap());
        println!("{}", report_line(&format!("{model_name} microflow"), &s_mf));
        println!("{}", report_line(&format!("{model_name} tflm-interp"), &s_tf));
        t2.row(vec![
            model_name.into(),
            fmt_time(s_tf.median),
            fmt_time(s_mf.median),
            format!("{:.2}x", s_tf.median / s_mf.median),
        ]);
    }
    emit("fig11_runtime_host", &t2);
    println!("fig11_runtime OK");
    Ok(())
}
