//! Table 6 — energy per inference (experiment E7 in DESIGN.md).
//!
//! Expected shape (paper Sec. 6.2.4): energy is proportional to execution
//! time (average power is engine-independent), so MicroFlow is more
//! energy-efficient everywhere except the person detector, where the
//! optimized TFLM kernels win slightly.

use microflow::compiler::plan::{CompileOptions, CompiledModel};
use microflow::format::mfb::MfbModel;
use microflow::sim::energy::inference_energy_wh;
use microflow::sim::report::{emit, Table};
use microflow::sim::{self, Engine};
use microflow::util::fmt_energy_wh;

fn main() -> anyhow::Result<()> {
    let art = microflow::artifacts_dir();
    let paper = [
        ("sine", "ESP32", "149nWh", "11nWh"),
        ("sine", "nRF52840", "216nWh", "16nWh"),
        ("speech", "ESP32", "23.05mWh", "21.04mWh"),
        ("speech", "nRF52840", "6.58mWh", "5.62mWh"),
        ("person", "ESP32", "691.11mWh", "694.44mWh"),
        ("person", "nRF52840", "116.58mWh", "124.44mWh"),
    ];
    let mut t = Table::new(
        "Table 6 — energy per inference (modeled)",
        &["model", "mcu", "TFLM", "MicroFlow", "paper TFLM", "paper MicroFlow"],
    );
    for model_name in ["sine", "speech", "person"] {
        let model = MfbModel::load(art.join(format!("{model_name}.mfb")))?;
        let compiled = CompiledModel::compile(&model, CompileOptions::default())?;
        for mcu_name in ["ESP32", "nRF52840"] {
            let mcu = sim::mcu::by_name(mcu_name).unwrap();
            let e_mf = inference_energy_wh(&compiled, mcu, Engine::MicroFlow);
            let e_tf = inference_energy_wh(&compiled, mcu, Engine::Tflm);
            let p = paper
                .iter()
                .find(|(m, d, _, _)| *m == model_name && *d == mcu_name)
                .unwrap();
            t.row(vec![
                model_name.into(),
                mcu_name.into(),
                fmt_energy_wh(e_tf),
                fmt_energy_wh(e_mf),
                p.2.into(),
                p.3.into(),
            ]);

            // invariant: energy ratio == time ratio (paper's observation)
            let t_mf = sim::inference_seconds(&compiled, mcu, Engine::MicroFlow);
            let t_tf = sim::inference_seconds(&compiled, mcu, Engine::Tflm);
            assert!(((e_tf / e_mf) - (t_tf / t_mf)).abs() < 1e-9);
            // shape: MicroFlow wins on sine and speech, loses slightly on person
            if model_name == "person" {
                assert!(e_tf < e_mf, "person: TFLM should be slightly ahead");
            } else {
                assert!(e_mf < e_tf, "{model_name}: MicroFlow should be ahead");
            }
        }
    }
    emit("table6_energy", &t);
    println!("table6_energy OK");
    Ok(())
}
