//! Shared helpers for the integration tests.
//!
//! All integration tests run against the real build artifacts
//! (`make artifacts`). When artifacts are missing the tests skip with a
//! visible message instead of failing, so `cargo test` stays usable on a
//! fresh checkout.

use std::path::PathBuf;

pub const MODELS: [&str; 3] = ["sine", "speech", "person"];

pub fn artifacts() -> Option<PathBuf> {
    let dir = microflow::artifacts_dir();
    if MODELS.iter().all(|m| dir.join(format!("{m}.mfb")).exists()) {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts missing — run `make artifacts`");
        None
    }
}

/// Macro: early-return unless artifacts exist.
#[macro_export]
macro_rules! require_artifacts {
    () => {
        match common::artifacts() {
            Some(dir) => dir,
            None => return,
        }
    };
}
