//! The predict path is allocation-free — proven with a counting global
//! allocator, not just pointer stability.
//!
//! History: the wide-output FullyConnected kernel once allocated its
//! accumulator `Vec<i32>` per call; PR 2 threaded an i32 scratch through
//! the plan, and the register-tiled kernel core then deleted that buffer
//! entirely (accumulators live in registers). Weight packing happens at
//! compile time — no per-call transposes or panel staging — so a
//! session's `run_into`/`run_batch_into` must perform **zero** heap
//! allocations once built.
//!
//! PR 10 extends the proof to the observability plane: the same counted
//! window also drives the *observed* predict path with a [`StepProfiler`]
//! attached and records span events into a preallocated [`SpanRing`] —
//! tracing and profiling a request must cost zero heap allocations too.
//!
//! This file holds exactly ONE `#[test]` so no sibling test thread can
//! allocate concurrently between the two counter reads.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use microflow::api::{Engine, Session};
use microflow::observe::{Phase, SpanRing, StepProfiler};
use microflow::synth;
use microflow::util::Prng;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

struct CountingAlloc;

// SAFETY: delegates every operation to `System`; the counter is a plain
// atomic add with no allocation or TLS access (allocator-reentrancy safe).
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static COUNTING: CountingAlloc = CountingAlloc;

#[test]
fn predict_path_never_allocates() {
    // widths force the wide-output (n > 8) FullyConnected path that used
    // to allocate, plus a narrow head like the paper's classifiers
    let mut rng = Prng::new(0xA110C);
    let m = synth::fc_chain(&mut rng, &[16, 32, 24, 4]);

    for engine in [Engine::MicroFlow, Engine::Interp] {
        let mut session = Session::builder(&m).engine(engine).build().unwrap();
        let (ilen, olen) = (session.input_len(), session.output_len());
        let input = rng.i8_vec(ilen);
        let mut out = vec![0i8; olen];
        let batch = 4;
        let batch_in = rng.i8_vec(batch * ilen);
        let mut batch_out = vec![0i8; batch * olen];

        // warm up (first calls may fault pages; they must not allocate
        // either, but keep the measured window unambiguous)
        session.run_into(&input, &mut out).unwrap();
        session.run_batch_into(&batch_in, batch, &mut batch_out).unwrap();

        let before = ALLOC_CALLS.load(Ordering::SeqCst);
        for _ in 0..100 {
            session.run_into(&input, &mut out).unwrap();
            session.run_batch_into(&batch_in, batch, &mut batch_out).unwrap();
        }
        let after = ALLOC_CALLS.load(Ordering::SeqCst);
        assert_eq!(
            after - before,
            0,
            "{engine}: {} heap allocations on the predict path",
            after - before
        );
    }

    // ---- the observed hot path: tracing + profiling attached ----
    // Everything is preallocated before the counted window: the ring's
    // slot buffer at construction, the profiler's fixed table inline.
    let mut session = Session::builder(&m).engine(Engine::MicroFlow).build().unwrap();
    let (ilen, olen) = (session.input_len(), session.output_len());
    let input = rng.i8_vec(ilen);
    let mut out = vec![0i8; olen];
    let mut profiler = StepProfiler::new();
    let ring = SpanRing::new();
    session.run_into_observed(&input, &mut out, &mut profiler).unwrap();

    let before = ALLOC_CALLS.load(Ordering::SeqCst);
    for i in 0..100u64 {
        ring.record(i, 0, Phase::Admit);
        session.run_into_observed(&input, &mut out, &mut profiler).unwrap();
        ring.record(i, 0, Phase::Reply);
    }
    let after = ALLOC_CALLS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "{} heap allocations on the observed predict + span-record path",
        after - before
    );
    // sanity outside the counted window: the instrumentation really ran
    assert_eq!(ring.recorded(), 200);
    assert!(profiler.observed_steps() > 0);
    assert_eq!(profiler.stat(0).unwrap().invocations, 101);
}
