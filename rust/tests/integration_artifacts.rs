//! Integration: the PJRT path — the JAX-AOT'd HLO artifacts load, compile
//! and agree bit-exactly with the golden vectors and the native engine
//! (the three-implementations-one-model gate of DESIGN.md S15).
//!
//! These are the slowest tests (XLA compilation); person is exercised once.

mod common;

use microflow::compiler::plan::CompileOptions;
use microflow::engine::MicroFlowEngine;
use microflow::format::golden::Golden;
use microflow::runtime::oracle::check_against_golden;
use microflow::runtime::PjrtEngine;
use microflow::util::Prng;

#[test]
fn pjrt_sine_bit_exact_vs_golden_and_engine() {
    let art = require_artifacts!();
    let pjrt = PjrtEngine::load(&art, "sine").unwrap();
    assert_eq!(pjrt.batch_sizes(), vec![1, 32]);
    let golden = Golden::load(art.join("sine_golden.bin")).unwrap();
    let a = check_against_golden(&golden, |x| pjrt.predict_q(x)).unwrap();
    assert!(a.is_bit_exact(), "{a:?}");

    // engine and PJRT agree on arbitrary inputs, not just goldens
    let engine = MicroFlowEngine::load(art.join("sine.mfb"), CompileOptions::default()).unwrap();
    let mut rng = Prng::new(3);
    for _ in 0..50 {
        let x = rng.i8_vec(1);
        assert_eq!(engine.predict(&x), pjrt.predict_q(&x).unwrap());
    }
}

#[test]
fn pjrt_speech_batch_variants_agree() {
    let art = require_artifacts!();
    let pjrt = PjrtEngine::load(&art, "speech").unwrap();
    assert_eq!(pjrt.batch_sizes(), vec![1, 8]);
    let golden = Golden::load(art.join("speech_golden.bin")).unwrap();
    let a = check_against_golden(&golden, |x| pjrt.predict_q(x)).unwrap();
    assert!(a.is_bit_exact(), "{a:?}");

    // batched execution == per-sample execution (the b8 variant, filled)
    let n = golden.n.min(8);
    let mut packed = Vec::new();
    for i in 0..n {
        packed.extend_from_slice(golden.input(i));
    }
    let batch_out = pjrt.execute_batch(&packed, n).unwrap();
    for i in 0..n {
        let single = pjrt.predict_q(golden.input(i)).unwrap();
        assert_eq!(
            &batch_out[i * pjrt.output_len()..(i + 1) * pjrt.output_len()],
            single.as_slice(),
            "sample {i}"
        );
    }
}

#[test]
fn pjrt_partial_batches_pad_correctly() {
    let art = require_artifacts!();
    let pjrt = PjrtEngine::load(&art, "speech").unwrap();
    let golden = Golden::load(art.join("speech_golden.bin")).unwrap();
    // n = 3 doesn't match any variant exactly: must pad the b8 executable
    let n = 3;
    let mut packed = Vec::new();
    for i in 0..n {
        packed.extend_from_slice(golden.input(i));
    }
    let out = pjrt.execute_batch(&packed, n).unwrap();
    assert_eq!(out.len(), n * pjrt.output_len());
    for i in 0..n {
        assert_eq!(
            &out[i * pjrt.output_len()..(i + 1) * pjrt.output_len()],
            golden.output(i),
            "sample {i}"
        );
    }
}

#[test]
fn pjrt_person_bit_exact() {
    let art = require_artifacts!();
    let pjrt = PjrtEngine::load(&art, "person").unwrap();
    let golden = Golden::load(art.join("person_golden.bin")).unwrap();
    let a = check_against_golden(&golden, |x| pjrt.predict_q(x)).unwrap();
    assert!(a.is_bit_exact(), "{a:?}");
}

#[test]
fn qparams_come_from_the_container() {
    let art = require_artifacts!();
    let pjrt = PjrtEngine::load(&art, "speech").unwrap();
    let engine = MicroFlowEngine::load(art.join("speech.mfb"), CompileOptions::default()).unwrap();
    assert_eq!(pjrt.input_qparams, engine.input_qparams());
    assert_eq!(pjrt.output_qparams, engine.output_qparams());
}
