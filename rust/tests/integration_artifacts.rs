//! Integration: the PJRT path — the JAX-AOT'd HLO artifacts load, compile
//! and agree bit-exactly with the golden vectors and the native engine
//! (the three-implementations-one-model gate of DESIGN.md S15).
//!
//! PJRT sessions come through `Session::builder(...).engine(Engine::Pjrt)`
//! like every other engine; the batch-variant plumbing (padding, variant
//! selection) is additionally exercised on the runtime layer directly.
//! These are the slowest tests (XLA compilation); person is exercised once.
//! They compile/run only with the `pjrt` feature — on default builds the
//! whole file is compiled out (the stub engine would fail every unwrap).
#![cfg(feature = "pjrt")]

mod common;

use microflow::api::{Engine, Session};
use microflow::format::golden::Golden;
use microflow::runtime::oracle::check_against_golden;
use microflow::runtime::PjrtEngine;
use microflow::util::Prng;

fn pjrt_session(art: &std::path::Path, name: &str) -> Session {
    Session::builder(art.join(format!("{name}.mfb"))).engine(Engine::Pjrt).build().unwrap()
}

#[test]
fn pjrt_sine_bit_exact_vs_golden_and_engine() {
    let art = require_artifacts!();
    let mut pjrt = pjrt_session(&art, "sine");
    let golden = Golden::load(art.join("sine_golden.bin")).unwrap();
    let a = check_against_golden(&golden, |x| pjrt.run(x)).unwrap();
    assert!(a.is_bit_exact(), "{a:?}");

    // engine and PJRT agree on arbitrary inputs, not just goldens
    let mut engine = Session::builder(art.join("sine.mfb")).build().unwrap();
    let mut rng = Prng::new(3);
    for _ in 0..50 {
        let x = rng.i8_vec(1);
        assert_eq!(engine.run(&x).unwrap(), pjrt.run(&x).unwrap());
    }
}

#[test]
fn pjrt_speech_batch_variants_agree() {
    let art = require_artifacts!();
    // runtime layer: the AOT'd batch variants themselves
    let pjrt = PjrtEngine::load(&art, "speech").unwrap();
    assert_eq!(pjrt.batch_sizes(), vec![1, 8]);
    let golden = Golden::load(art.join("speech_golden.bin")).unwrap();
    let a = check_against_golden(&golden, |x| pjrt.predict_q(x)).unwrap();
    assert!(a.is_bit_exact(), "{a:?}");

    // batched session execution == per-sample execution (the b8 variant,
    // filled), through the uniform run_batch_into surface
    let mut session = pjrt_session(&art, "speech");
    let olen = session.output_len();
    let n = golden.n.min(8);
    let mut packed = Vec::new();
    for i in 0..n {
        packed.extend_from_slice(golden.input(i));
    }
    let mut batch_out = vec![0i8; n * olen];
    session.run_batch_into(&packed, n, &mut batch_out).unwrap();
    for i in 0..n {
        let single = session.run(golden.input(i)).unwrap();
        assert_eq!(&batch_out[i * olen..(i + 1) * olen], single.as_slice(), "sample {i}");
    }
}

#[test]
fn pjrt_partial_batches_pad_correctly() {
    let art = require_artifacts!();
    let mut session = pjrt_session(&art, "speech");
    let golden = Golden::load(art.join("speech_golden.bin")).unwrap();
    // n = 3 doesn't match any variant exactly: must pad the b8 executable
    let n = 3;
    let olen = session.output_len();
    let mut packed = Vec::new();
    for i in 0..n {
        packed.extend_from_slice(golden.input(i));
    }
    let out = session.run_batch(&packed, n).unwrap();
    assert_eq!(out.len(), n * olen);
    for i in 0..n {
        assert_eq!(&out[i * olen..(i + 1) * olen], golden.output(i), "sample {i}");
    }
}

#[test]
fn pjrt_person_bit_exact() {
    let art = require_artifacts!();
    let mut pjrt = pjrt_session(&art, "person");
    let golden = Golden::load(art.join("person_golden.bin")).unwrap();
    let a = check_against_golden(&golden, |x| pjrt.run(x)).unwrap();
    assert!(a.is_bit_exact(), "{a:?}");
}

#[test]
fn qparams_come_from_the_container() {
    let art = require_artifacts!();
    let pjrt = pjrt_session(&art, "speech");
    let engine = Session::builder(art.join("speech.mfb")).build().unwrap();
    // one IoSignature to rule all engines
    assert_eq!(pjrt.signature(), engine.signature());
    assert_eq!(pjrt.input_qparams(), engine.input_qparams());
    assert_eq!(pjrt.output_qparams(), engine.output_qparams());
    // PJRT defaults its preferred batch to the largest AOT variant
    assert_eq!(pjrt.preferred_batch(), 8);
}
