//! Scrape smoke: the observability plane end-to-end, artifact-free.
//!
//! A synthetic fleet runs a fixed mixed-class workload (completions in
//! every QoS lane, deterministic sheds via already-expired deadlines,
//! pre-submit cancels) with the per-step profiler attached; `Fleet::tick`
//! drains spans, windows and profiles into the exposition; and a raw HTTP
//! scrape of [`MetricsServer`] is parsed back to prove, on the exported
//! text itself:
//!
//! * the lifecycle identity `completed + shed + cancelled + failed ==
//!   submitted` holds lane-by-lane;
//! * span events cover the request lifecycle — `admit` matches the
//!   submitted lane, `execute`/`reply` match the completed lane, and the
//!   rings dropped nothing;
//! * the per-step profile rows cover every plan step exactly once, each
//!   with one invocation per executed sample.
//!
//! A second test drives the version-agnostic `STAT` wire op through a
//! real ingress: placeholder body before an exposition is attached, the
//! rendered snapshot after.

use std::sync::Arc;
use std::time::{Duration, Instant};

use microflow::api::{Engine, Session};
use microflow::coordinator::{
    BatcherConfig, Client, Fleet, Ingress, PoolSpec, QosClass, Request, Router, Server,
    ServerConfig,
};
use microflow::observe::{parse_exposition, Exposition, MetricsServer, Sample};
use microflow::synth;
use microflow::util::Prng;

fn profiled_config() -> ServerConfig {
    ServerConfig {
        queue_depth: 64,
        batcher: BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) },
        adaptive: false,
        max_retries: 1,
        profile: true,
    }
}

/// Value of the unique sample matching `name` + all `labels`.
fn get(samples: &[Sample], name: &str, labels: &[(&str, &str)]) -> f64 {
    let matches: Vec<&Sample> = samples
        .iter()
        .filter(|s| s.name == name && labels.iter().all(|&(k, v)| s.label(k) == Some(v)))
        .collect();
    assert_eq!(matches.len(), 1, "expected exactly one {name} {labels:?}, got {matches:?}");
    matches[0].value
}

#[test]
fn scrape_exports_lane_identity_and_full_step_coverage() {
    let mut rng = Prng::new(0x5C4A_9E01);
    let m = synth::fc_chain(&mut rng, &[16, 32, 24, 4]);
    let sessions: Vec<Session> = (0..2)
        .map(|_| Session::builder(&m).engine(Engine::MicroFlow).build().unwrap())
        .collect();
    let step_kinds = sessions[0].step_kinds();
    let ilen = sessions[0].input_len();
    let fleet =
        Fleet::start(vec![PoolSpec::new("native", sessions).config(profiled_config())]).unwrap();

    // fixed workload: 10 completions per class, 5 deterministic sheds
    // (expired at submit), 5 pre-submit cancels — every lane exercised
    let mut completions = Vec::new();
    for class in [QosClass::Interactive, QosClass::Bulk, QosClass::Background] {
        for _ in 0..10 {
            let req = Request::new(rng.i8_vec(ilen)).with_class(class);
            completions.push(fleet.submit(req).unwrap());
        }
    }
    for t in completions {
        t.wait().unwrap();
    }
    for _ in 0..5 {
        let req = Request::new(rng.i8_vec(ilen))
            .with_class(QosClass::Bulk)
            .with_deadline(Instant::now());
        let err = fleet.submit(req).and_then(|t| t.wait()).unwrap_err();
        assert!(err.to_string().contains("shed"), "{err:#}");
    }
    for _ in 0..5 {
        let req = Request::new(rng.i8_vec(ilen)).with_class(QosClass::Interactive);
        req.cancel();
        let err = fleet.submit(req).and_then(|t| t.wait()).unwrap_err();
        assert!(err.to_string().contains("cancelled"), "{err:#}");
    }
    // replies resolve at send; give the workers a beat to record the
    // trailing Reply span events before the tick drains the rings
    std::thread::sleep(Duration::from_millis(200));

    let expo = Arc::new(Exposition::new());
    expo.absorb_tick(&fleet.tick());
    assert!(expo.identity_holds(), "quiescent pools must export the identity");

    // raw HTTP scrape — what a real Prometheus would read
    let server = MetricsServer::start("127.0.0.1:0", Arc::clone(&expo)).unwrap();
    let addr = server.local_addr();
    let body = {
        use std::io::{Read, Write};
        let mut conn = std::net::TcpStream::connect(addr).unwrap();
        conn.write_all(b"GET /metrics HTTP/1.0\r\n\r\n").unwrap();
        let mut resp = String::new();
        conn.read_to_string(&mut resp).unwrap();
        assert!(resp.starts_with("HTTP/1.0 200 OK"), "{resp}");
        resp.split_once("\r\n\r\n").unwrap().1.to_string()
    };
    server.shutdown();
    let samples = parse_exposition(&body);

    // lane identity, class by class, on the exported text itself
    let expected = [
        ("interactive", 15.0, 10.0, 0.0, 5.0),
        ("bulk", 15.0, 10.0, 5.0, 0.0),
        ("background", 10.0, 10.0, 0.0, 0.0),
    ];
    for (class, submitted, completed, shed, cancelled) in expected {
        let lane = |outcome: &str| {
            get(
                &samples,
                "microflow_requests_total",
                &[("pool", "native"), ("class", class), ("outcome", outcome)],
            )
        };
        assert_eq!(lane("submitted"), submitted, "{class}");
        assert_eq!(lane("completed"), completed, "{class}");
        assert_eq!(lane("shed"), shed, "{class}");
        assert_eq!(lane("cancelled"), cancelled, "{class}");
        assert_eq!(
            lane("completed") + lane("shed") + lane("cancelled") + lane("failed"),
            lane("submitted"),
            "identity broken for class {class}"
        );
    }

    // span coverage: admit mirrors the submitted lane, execute/reply the
    // completed lane, and the rings dropped nothing
    for (class, submitted, completed, ..) in expected {
        let span = |phase: &str| {
            get(
                &samples,
                "microflow_span_events_total",
                &[("pool", "native"), ("phase", phase), ("class", class)],
            )
        };
        assert_eq!(span("admit"), submitted, "{class} admits");
        assert_eq!(span("execute"), completed, "{class} executes");
        assert_eq!(span("reply"), completed, "{class} replies");
    }
    assert_eq!(get(&samples, "microflow_spans_dropped_total", &[("pool", "native")]), 0.0);

    // per-step profile rows cover every plan step exactly once, each with
    // one invocation per executed sample (30 completions; shed and
    // cancelled requests never execute)
    let rows: Vec<&Sample> = samples
        .iter()
        .filter(|s| {
            s.name == "microflow_step_invocations_total" && s.label("pool") == Some("native")
        })
        .collect();
    assert_eq!(rows.len(), step_kinds.len(), "one exported row per plan step");
    for (i, kind) in step_kinds.iter().enumerate() {
        let step = i.to_string();
        let calls = get(
            &samples,
            "microflow_step_invocations_total",
            &[("pool", "native"), ("step", &step), ("kind", kind)],
        );
        assert_eq!(calls, 30.0, "step {i} ({kind}) must run once per executed sample");
    }

    fleet.shutdown();
}

#[test]
fn stat_wire_op_serves_the_snapshot_version_agnostically() {
    let mut rng = Prng::new(0x5C4A_9E02);
    let m = synth::fc_chain(&mut rng, &[8, 12, 3]);
    let sessions: Vec<Session> =
        vec![Session::builder(&m).engine(Engine::MicroFlow).build().unwrap()];
    let ilen = sessions[0].input_len();
    let server = Server::start(sessions, profiled_config()).unwrap();
    let mut router = Router::new();
    router.add("tiny", server);
    let router = Arc::new(router);
    let ingress = Ingress::start("127.0.0.1:0", Arc::clone(&router)).unwrap();
    let mut c = Client::connect(ingress.addr).unwrap();

    // before an exposition is attached: the placeholder body, not an error
    assert_eq!(c.stats().unwrap(), "# microflow: no exposition attached\n");

    // drive real traffic over the wire, then drain one tick into the sink
    for _ in 0..4 {
        c.infer("tiny", &rng.i8_vec(ilen)).unwrap();
    }
    std::thread::sleep(Duration::from_millis(200));
    let expo = Arc::new(Exposition::new());
    expo.absorb_tick(&router.get("tiny").unwrap().tick());
    router.set_exposition(Arc::clone(&expo));

    // the STAT round pipelines with inference rounds on one connection
    let samples = parse_exposition(&c.stats().unwrap());
    // v1 frames are served with the default class (bulk)
    assert_eq!(
        get(
            &samples,
            "microflow_requests_total",
            &[("pool", "tiny"), ("class", "bulk"), ("outcome", "submitted")],
        ),
        4.0
    );
    assert_eq!(
        get(
            &samples,
            "microflow_requests_total",
            &[("pool", "tiny"), ("class", "bulk"), ("outcome", "completed")],
        ),
        4.0
    );
    // the profiled pool exports step rows over the wire too
    assert!(
        samples.iter().any(|s| s.name == "microflow_step_invocations_total"
            && s.label("pool") == Some("tiny")),
        "step profile rows must survive the wire"
    );
    // and the connection still serves inference after the STAT round
    c.infer("tiny", &rng.i8_vec(ilen)).unwrap();
    drop(c);

    ingress.shutdown();
    match Arc::try_unwrap(router) {
        Ok(r) => r.shutdown(),
        Err(_) => panic!("router still referenced"),
    }
}
