//! Seeded mutation harness for the decoder's never-panic contract.
//!
//! The crate-level guarantee (lib.rs "Certification guarantees"):
//! `MfbModel::parse` is **total** on arbitrary bytes — any input either
//! parses or is rejected with a stable `E4xx`-coded `DecodeError`, and
//! never panics. This harness holds that contract against 1200 seeded
//! mutants of real serialized models (byte flips, truncation, extension,
//! splices, zeroed ranges) plus an exhaustive truncation sweep. Mutants
//! that still parse must then compile-or-reject without panicking either
//! (the compiler front end plus the `verify` certifier are the next line
//! of defense).
//!
//! Deterministic by default; override the seed with
//! `MICROFLOW_STRESS_SEED=<n>` to widen the search. Failures print the
//! seed and mutant index so any find replays exactly.

use std::panic::{catch_unwind, AssertUnwindSafe};

use microflow::compiler::plan::{CompileOptions, CompiledModel};
use microflow::format::builder::serialize;
use microflow::format::mfb::MfbModel;
use microflow::util::Prng;

const DEFAULT_SEED: u64 = 20_260_731;
const MUTANTS: usize = 1200;

fn seed() -> u64 {
    std::env::var("MICROFLOW_STRESS_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(DEFAULT_SEED)
}

/// One seeded mutation of `base`: flip, truncate, extend, splice, or zero.
fn mutate(rng: &mut Prng, base: &[u8]) -> Vec<u8> {
    let mut b = base.to_vec();
    match rng.below(5) {
        0 => {
            // flip 1..=4 random bytes
            for _ in 0..rng.range_i64(1, 4) {
                let i = rng.below(b.len() as u64) as usize;
                b[i] ^= rng.range_i64(1, 255) as u8;
            }
        }
        1 => {
            // truncate to a strict prefix
            b.truncate(rng.below(b.len() as u64) as usize);
        }
        2 => {
            // append random trailing bytes
            for _ in 0..rng.range_i64(1, 16) {
                b.push(rng.below(256) as u8);
            }
        }
        3 => {
            // splice: copy a random source range over a random destination
            let len = rng.range_i64(1, 8.min(b.len() as i64)) as usize;
            let src = rng.below((b.len() - len + 1) as u64) as usize;
            let dst = rng.below((b.len() - len + 1) as u64) as usize;
            let chunk: Vec<u8> = b[src..src + len].to_vec();
            b[dst..dst + len].copy_from_slice(&chunk);
        }
        _ => {
            // zero a random range
            let len = rng.range_i64(1, 16.min(b.len() as i64)) as usize;
            let at = rng.below((b.len() - len + 1) as u64) as usize;
            b[at..at + len].fill(0);
        }
    }
    b
}

#[test]
fn twelve_hundred_mutants_never_panic_and_reject_with_stable_codes() {
    let s = seed();
    let mut rng = Prng::new(s);
    let bases: Vec<Vec<u8>> =
        microflow::synth::zoo(s).iter().map(|(_, m)| serialize(m).unwrap()).collect();

    let (mut parsed, mut rejected) = (0usize, 0usize);
    for i in 0..MUTANTS {
        let mutant = mutate(&mut rng, &bases[i % bases.len()]);
        let outcome = catch_unwind(AssertUnwindSafe(|| MfbModel::parse(&mutant)))
            .unwrap_or_else(|_| panic!("mutant {i} (seed {s}) PANICKED in parse"));
        match outcome {
            Ok(m) => {
                parsed += 1;
                // survivors hit the next line of defense: the compiler
                // front end + certifier must also compile-or-reject cleanly
                catch_unwind(AssertUnwindSafe(|| {
                    let _ = CompiledModel::compile(&m, CompileOptions::default());
                }))
                .unwrap_or_else(|_| panic!("mutant {i} (seed {s}) PANICKED in compile"));
            }
            Err(e) => {
                rejected += 1;
                let msg = e.to_string();
                assert!(
                    msg.starts_with("E4"),
                    "mutant {i} (seed {s}) rejected without a stable E4xx code: {msg}"
                );
            }
        }
    }
    // the harness must actually exercise both outcomes: most mutants break
    // the container, but flips inside big weight payloads survive parsing
    assert!(rejected > MUTANTS / 2, "only {rejected}/{MUTANTS} mutants were rejected (seed {s})");
    assert!(parsed > 0, "no mutant parsed at all (seed {s}) — mutations too destructive");
}

#[test]
fn every_truncation_prefix_is_rejected_cleanly() {
    let zoo = microflow::synth::zoo(seed());
    let (name, model) = &zoo[0];
    let bytes = serialize(model).unwrap();
    for cut in 0..bytes.len() {
        let outcome = catch_unwind(AssertUnwindSafe(|| MfbModel::parse(&bytes[..cut])))
            .unwrap_or_else(|_| panic!("{name}: prefix of {cut} bytes PANICKED"));
        let e = outcome.expect_err("a strict prefix of a valid container must not parse");
        assert!(e.to_string().starts_with("E4"), "{name}: prefix {cut}: uncoded error {e}");
    }
}

#[test]
fn unmutated_bases_parse_and_certify() {
    // control arm: the harness's base corpus is genuinely valid, so every
    // rejection above is caused by the mutation, not a broken generator
    for (name, m) in microflow::synth::zoo(seed()) {
        let bytes = serialize(&m).unwrap();
        let parsed = MfbModel::parse(&bytes).unwrap_or_else(|e| panic!("{name}: {e}"));
        let c = CompiledModel::compile(&parsed, CompileOptions::default())
            .unwrap_or_else(|e| panic!("{name}: {e:#}"));
        assert!(c.certificate.is_some(), "{name}: certify-by-default did not attach a proof");
    }
}
