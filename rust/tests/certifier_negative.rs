//! Negative suite for the static certifier: every tampered plan must be
//! rejected with its documented stable code (`ERROR_CODE_TABLE`), from
//! outside the crate, on real synthesized models.
//!
//! The unit tests in `compiler::verify` cover the analysis passes
//! surgically; this file holds the *integration* contract: compile a
//! genuinely valid model, corrupt one claim the runtime would rely on,
//! and assert the certifier catches it with the exact code a monitoring
//! system would match on.

use microflow::compiler::{
    verify, CompileOptions, CompiledModel, MemoryPlan, Step, StepKind, ERROR_CODE_TABLE,
};
use microflow::synth;
use microflow::util::Prng;

fn compiled_fc(paging: bool) -> CompiledModel {
    let m = synth::fc_chain(&mut Prng::new(7), &[6, 4, 3]);
    CompiledModel::compile(&m, CompileOptions { paging, certify: true }).unwrap()
}

fn compiled_conv() -> CompiledModel {
    let m = synth::random_conv(&mut Prng::new(5));
    CompiledModel::compile(&m, CompileOptions::default()).unwrap()
}

fn assert_rejected_with(c: &CompiledModel, code: &str) {
    let e = verify(c).expect_err("tampered plan must fail certification");
    assert_eq!(e.code, code, "wrong code for: {e}");
    assert!(e.to_string().starts_with(code), "display must lead with the code: {e}");
    assert!(ERROR_CODE_TABLE.contains(code), "{code} is not in the documented table");
}

#[test]
fn untampered_plans_certify_and_report() {
    for paging in [false, true] {
        let c = compiled_fc(paging);
        let cert = c.certificate.as_ref().expect("certify is the default");
        assert_eq!(cert.steps.len(), c.steps.len());
        assert_eq!(cert.peak_ram, c.memory.peak);
        let report = cert.to_string();
        assert!(report.contains("certified") && report.contains("FullyConnected"), "{report}");
    }
}

#[test]
fn lying_peak_ram_is_v201() {
    let mut c = compiled_fc(false);
    c.memory.peak += 1;
    assert_rejected_with(&c, "V201");
}

#[test]
fn tampered_live_set_is_v202() {
    let mut c = compiled_fc(false);
    c.memory.per_step[0].input += 1;
    assert_rejected_with(&c, "V202");
}

#[test]
fn undersized_ping_pong_buffer_is_v203() {
    let mut c = compiled_fc(false);
    c.memory.buf_a -= 1; // the schedule could now alias input and output
    assert_rejected_with(&c, "V203");
}

#[test]
fn undersized_kernel_scratch_is_v204() {
    let mut c = compiled_fc(true); // paged FC stages a K-element page buffer
    assert!(c.memory.scratch > 0);
    c.memory.scratch -= 1;
    assert_rejected_with(&c, "V204");
}

#[test]
fn spliced_shrinking_reshape_is_v205() {
    let mut c = compiled_fc(false);
    let out = c.steps.last().unwrap().out_len;
    c.steps.push(Step { kind: StepKind::Reshape, in_len: out, out_len: out - 1, scratch_len: 0 });
    c.output_shape = vec![out - 1];
    c.memory = MemoryPlan::analyze(&c.steps);
    assert_rejected_with(&c, "V205");
}

#[test]
fn truncated_conv_panel_image_is_v104() {
    let mut c = compiled_conv();
    let Some(StepKind::Conv2D { filters, .. }) =
        c.steps.iter_mut().map(|s| &mut s.kind).find(|k| matches!(k, StepKind::Conv2D { .. }))
    else {
        panic!("random_conv produced no Conv2D step");
    };
    filters.data.pop();
    assert_rejected_with(&c, "V104");
}

#[test]
fn page_plan_coverage_lies_are_v106() {
    let mut c = compiled_fc(true);
    c.page_plan.as_mut().unwrap().pages += 1; // claims a page no FC row has
    assert_rejected_with(&c, "V106");

    let mut c = compiled_fc(true);
    c.page_plan = None; // paged steps with no plan at all
    assert_rejected_with(&c, "V106");
}

#[test]
fn overflow_capable_epilogue_is_v301() {
    let mut c = compiled_fc(false);
    if let StepKind::FullyConnected { pc, .. } = &mut c.steps[0].kind {
        // a folded constant the Eq. 4 epilogue subtracts: i32::MIN pushes
        // the worst-case intermediate past the i32 accumulator
        pc.w_zp_term[0] = i32::MIN;
    }
    assert_rejected_with(&c, "V301");
}

#[test]
fn scratch_claim_mismatch_is_v107() {
    let mut c = compiled_fc(false);
    c.steps[0].scratch_len = 99; // unpaged FC kernels stage nothing
    c.memory = MemoryPlan::analyze(&c.steps);
    assert_rejected_with(&c, "V107");
}

#[test]
fn opting_out_skips_the_proof_but_not_the_analysis() {
    let m = synth::fc_chain(&mut Prng::new(7), &[6, 4, 3]);
    let mut c =
        CompiledModel::compile(&m, CompileOptions { paging: false, certify: false }).unwrap();
    assert!(c.certificate.is_none(), "opt-out must not attach a certificate");
    // the pass is still callable on demand, and still catches tampering
    assert!(verify(&c).is_ok());
    c.memory.peak += 1;
    assert_rejected_with(&c, "V201");
}
