//! Randomized coordinator stress suite — mixed-engine fleets under
//! concurrent load, no build artifacts needed.
//!
//! Every test derives all randomness from one seed so failures reproduce
//! exactly. The seed defaults to a fixed value (CI determinism — see
//! `.github/workflows/ci.yml`) and can be overridden for exploration:
//!
//! ```sh
//! MICROFLOW_STRESS_SEED=12345 cargo test --test stress_coordinator
//! ```
//!
//! The seed is printed at the start of every test and embedded in every
//! assertion message, so a red run names its reproduction command.
//!
//! Gates:
//! * replies under concurrency are **correct**: every reply equals one of
//!   the per-engine single-session ground truths for its input (each
//!   engine is deterministic; a fleet reply comes from exactly one of
//!   them, and native/interp stay within the generator's ±1 bound);
//! * metrics counters **sum to the submitted request count** across pools
//!   (nothing lost, nothing double-counted);
//! * shutdown under load is **clean**: every accepted request is answered
//!   even when shutdown races the queue drain.

use std::sync::Arc;
use std::time::Duration;

use microflow::api::{Engine, Session, SessionCache};
use microflow::coordinator::{BatcherConfig, Fleet, PoolSpec, ServerConfig};
use microflow::synth::random_fc_chain;
use microflow::util::Prng;

const DEFAULT_SEED: u64 = 0x5EED_2026;

fn seed() -> u64 {
    match std::env::var("MICROFLOW_STRESS_SEED") {
        Ok(v) => v.parse().unwrap_or_else(|_| panic!("bad MICROFLOW_STRESS_SEED {v:?}")),
        Err(_) => DEFAULT_SEED,
    }
}

/// A mixed-engine fleet over `model`: native ×2 + interp ×2, small queues
/// so backpressure is exercised, adaptive batching on (the PoolSpec
/// default). Sessions build through a shared warm cache, as a real
/// deployment would.
fn mixed_fleet(m: &microflow::format::mfb::MfbModel, queue_depth: usize) -> Fleet {
    let cache = Arc::new(SessionCache::new());
    let config = ServerConfig {
        queue_depth,
        batcher: BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) },
        adaptive: true,
    };
    let pool = |engine: Engine, name: &str| {
        PoolSpec::new(
            name,
            (0..2)
                .map(|i| {
                    Session::builder(m)
                        .engine(engine)
                        .label(format!("{name}/{i}"))
                        .cache(&cache)
                        .build()
                        .unwrap()
                })
                .collect(),
        )
        .config(config)
    };
    Fleet::start(vec![pool(Engine::MicroFlow, "native"), pool(Engine::Interp, "interp")]).unwrap()
}

#[test]
fn stress_mixed_fleet_replies_correctly_under_concurrency() {
    let seed = seed();
    eprintln!("stress seed = {seed} (override with MICROFLOW_STRESS_SEED)");
    let mut rng = Prng::new(seed);
    let m = random_fc_chain(&mut rng, 3);

    // ground truth per distinct input, from single sessions of each engine
    let mut native = Session::builder(&m).engine(Engine::MicroFlow).build().unwrap();
    let mut interp = Session::builder(&m).engine(Engine::Interp).build().unwrap();
    let ilen = native.input_len();
    const DISTINCT: usize = 32;
    let inputs: Vec<Vec<i8>> = (0..DISTINCT).map(|_| rng.i8_vec(ilen)).collect();
    let truths: Vec<[Vec<i8>; 2]> = inputs
        .iter()
        .map(|x| [native.run(x).unwrap(), interp.run(x).unwrap()])
        .collect();

    const THREADS: usize = 8;
    const PER_THREAD: usize = 50;
    let fleet = Arc::new(mixed_fleet(&m, 16));
    let inputs = Arc::new(inputs);
    let truths = Arc::new(truths);
    let mut handles = Vec::new();
    for t in 0..THREADS {
        let fleet = Arc::clone(&fleet);
        let inputs = Arc::clone(&inputs);
        let truths = Arc::clone(&truths);
        handles.push(std::thread::spawn(move || {
            // per-thread deterministic input schedule
            let mut trng = Prng::new(seed ^ (t as u64).wrapping_mul(0x9E37_79B9));
            for r in 0..PER_THREAD {
                let idx = trng.below(DISTINCT as u64) as usize;
                let got = fleet
                    .infer(inputs[idx].clone())
                    .unwrap_or_else(|e| panic!("seed {seed} thread {t} req {r}: {e:#}"));
                let [nat, itp] = &truths[idx];
                assert!(
                    got == *nat || got == *itp,
                    "seed {seed} thread {t} req {r} input {idx}: reply {got:?} \
                     matches neither native {nat:?} nor interp {itp:?}"
                );
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }

    let total = (THREADS * PER_THREAD) as u64;
    let snap = fleet.snapshot();
    assert_eq!(snap.totals.submitted, total, "seed {seed}: submitted\n{snap}");
    assert_eq!(snap.totals.completed, total, "seed {seed}: completed\n{snap}");
    assert_eq!(snap.totals.errors, 0, "seed {seed}: errors\n{snap}");
    // the per-pool counters are what summed: each pool must be consistent
    for (name, s) in &snap.per_pool {
        assert_eq!(
            s.submitted, s.completed,
            "seed {seed}: pool {name} lost requests\n{snap}"
        );
    }
    // least-outstanding dispatch under sustained load must use both pools
    for (name, s) in &snap.per_pool {
        assert!(s.completed > 0, "seed {seed}: pool {name} served nothing\n{snap}");
    }
    if let Ok(fleet) = Arc::try_unwrap(fleet) {
        fleet.shutdown();
    }
}

#[test]
fn stress_shutdown_under_load_answers_every_accepted_request() {
    let seed = seed() ^ 0xD00D;
    eprintln!("shutdown stress seed = {seed}");
    let mut rng = Prng::new(seed);
    let m = random_fc_chain(&mut rng, 2);
    let fleet = mixed_fleet(&m, 64);
    let ilen = fleet.input_len();

    // flood the queues without consuming any reply, then shut down while
    // the backlog is still draining
    let mut pending = Vec::new();
    for i in 0..96 {
        let x = rng.i8_vec(ilen);
        pending.push((i, fleet.submit(x).unwrap_or_else(|e| panic!("seed {seed} req {i}: {e:#}"))));
    }
    fleet.shutdown(); // drops the queues and joins workers — must drain first
    for (i, rx) in pending {
        let reply = rx
            .recv()
            .unwrap_or_else(|e| panic!("seed {seed} req {i}: reply dropped on shutdown: {e}"));
        assert!(reply.is_ok(), "seed {seed} req {i}: {:#}", reply.unwrap_err());
    }
}

#[test]
fn stress_backpressure_never_drops_or_reorders_per_thread() {
    // tiny queue: submitters block on a full queue; every request must
    // still be answered exactly once with the right output
    let seed = seed() ^ 0xB10C;
    eprintln!("backpressure stress seed = {seed}");
    let mut rng = Prng::new(seed);
    let m = random_fc_chain(&mut rng, 1);
    let mut native = Session::builder(&m).engine(Engine::MicroFlow).build().unwrap();
    let mut interp = Session::builder(&m).engine(Engine::Interp).build().unwrap();
    let ilen = native.input_len();
    let x = rng.i8_vec(ilen);
    let truth = [native.run(&x).unwrap(), interp.run(&x).unwrap()];

    let fleet = Arc::new(mixed_fleet(&m, 2));
    let mut handles = Vec::new();
    for t in 0..6 {
        let fleet = Arc::clone(&fleet);
        let x = x.clone();
        let truth = truth.clone();
        handles.push(std::thread::spawn(move || {
            for r in 0..40 {
                let got = fleet.infer(x.clone()).unwrap();
                assert!(
                    got == truth[0] || got == truth[1],
                    "seed {seed} thread {t} req {r}: {got:?}"
                );
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let snap = fleet.snapshot();
    assert_eq!(snap.totals.submitted, 240, "seed {seed}\n{snap}");
    assert_eq!(snap.totals.completed, 240, "seed {seed}\n{snap}");
    if let Ok(fleet) = Arc::try_unwrap(fleet) {
        fleet.shutdown();
    }
}
