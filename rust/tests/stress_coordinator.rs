//! Randomized coordinator stress suite — mixed-engine fleets under
//! concurrent load, no build artifacts needed.
//!
//! Every test derives all randomness from one seed so failures reproduce
//! exactly. The seed defaults to a fixed value (CI determinism — see
//! `.github/workflows/ci.yml`) and can be overridden for exploration:
//!
//! ```sh
//! MICROFLOW_STRESS_SEED=12345 cargo test --test stress_coordinator
//! ```
//!
//! The seed is printed at the start of every test and embedded in every
//! assertion message, so a red run names its reproduction command.
//!
//! Gates:
//! * replies under concurrency are **correct**: every reply equals one of
//!   the per-engine single-session ground truths for its input (each
//!   engine is deterministic; a fleet reply comes from exactly one of
//!   them, and native/interp stay within the generator's ±1 bound);
//! * metrics counters **sum to the submitted request count** across pools
//!   and across QoS classes (nothing lost, nothing double-counted);
//! * the request lifecycle holds under a **mixed-class workload**:
//!   Interactive requests are served only by Interactive-preferred pools
//!   when one exists, expired-deadline requests are shed (counted, never
//!   executed), and cancelled tickets never execute;
//! * shutdown under load is **clean**: every accepted request is answered
//!   even when shutdown races the queue drain;
//! * the **autoscaler** is safe under concurrency: a bursty
//!   phase-shifting workload scales an elastic pool up on deterministic
//!   SLO breaches and back down when idle, with bit-exact replies and
//!   `completed + shed + cancelled == submitted` across concurrent
//!   scale-up/scale-down events — no accepted request is ever dropped by
//!   a graceful drain;
//! * **fault tolerance** heals without loss: a pool whose replicas fail
//!   by seeded injection (transient errors, a wedged session, a fatal
//!   death) retries, ejects and re-floors itself while the extended
//!   identity `completed + shed + cancelled + failed == submitted` holds
//!   exactly and every completed reply stays bit-exact. (The circuit
//!   breaker's full Closed→Open→HalfOpen cycle is unit-tested
//!   deterministically in `coordinator::fleet`.)

use std::sync::Arc;
use std::time::{Duration, Instant};

use microflow::api::{Engine, FaultPlan, ReplicaFactory, Session, SessionCache};
use microflow::coordinator::{
    AutoscalePolicy, BatcherConfig, Fleet, PoolSpec, QosClass, QosProfile, ReplicaPhase, Request,
    ScaleAction, ServerConfig,
};
use microflow::synth::random_fc_chain;
use microflow::util::Prng;

const DEFAULT_SEED: u64 = 0x5EED_2026;

fn seed() -> u64 {
    match std::env::var("MICROFLOW_STRESS_SEED") {
        Ok(v) => v.parse().unwrap_or_else(|_| panic!("bad MICROFLOW_STRESS_SEED {v:?}")),
        Err(_) => DEFAULT_SEED,
    }
}

/// A mixed-engine fleet over `model`: native ×2 + interp ×2, small queues
/// so backpressure is exercised, adaptive batching on (the PoolSpec
/// default), no declared QoS profiles (pure load balancing — the legacy
/// dispatch). Sessions build through a shared warm cache, as a real
/// deployment would.
fn mixed_fleet(m: &microflow::format::mfb::MfbModel, queue_depth: usize) -> Fleet {
    let cache = Arc::new(SessionCache::new());
    let config = ServerConfig {
        queue_depth,
        batcher: BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) },
        adaptive: true,
        max_retries: 1,
        profile: false,
    };
    let pool = |engine: Engine, name: &str| {
        PoolSpec::new(
            name,
            (0..2)
                .map(|i| {
                    Session::builder(m)
                        .engine(engine)
                        .label(format!("{name}/{i}"))
                        .cache(&cache)
                        .build()
                        .unwrap()
                })
                .collect(),
        )
        .config(config)
    };
    Fleet::start(vec![pool(Engine::MicroFlow, "native"), pool(Engine::Interp, "interp")]).unwrap()
}

#[test]
fn stress_mixed_fleet_replies_correctly_under_concurrency() {
    let seed = seed();
    eprintln!("stress seed = {seed} (override with MICROFLOW_STRESS_SEED)");
    let mut rng = Prng::new(seed);
    let m = random_fc_chain(&mut rng, 3);

    // ground truth per distinct input, from single sessions of each engine
    let mut native = Session::builder(&m).engine(Engine::MicroFlow).build().unwrap();
    let mut interp = Session::builder(&m).engine(Engine::Interp).build().unwrap();
    let ilen = native.input_len();
    const DISTINCT: usize = 32;
    let inputs: Vec<Vec<i8>> = (0..DISTINCT).map(|_| rng.i8_vec(ilen)).collect();
    let truths: Vec<[Vec<i8>; 2]> = inputs
        .iter()
        .map(|x| [native.run(x).unwrap(), interp.run(x).unwrap()])
        .collect();

    const THREADS: usize = 8;
    const PER_THREAD: usize = 50;
    let fleet = Arc::new(mixed_fleet(&m, 16));
    let inputs = Arc::new(inputs);
    let truths = Arc::new(truths);
    let mut handles = Vec::new();
    for t in 0..THREADS {
        let fleet = Arc::clone(&fleet);
        let inputs = Arc::clone(&inputs);
        let truths = Arc::clone(&truths);
        handles.push(std::thread::spawn(move || {
            // per-thread deterministic input schedule
            let mut trng = Prng::new(seed ^ (t as u64).wrapping_mul(0x9E37_79B9));
            for r in 0..PER_THREAD {
                let idx = trng.below(DISTINCT as u64) as usize;
                let got = fleet
                    .infer(inputs[idx].clone())
                    .unwrap_or_else(|e| panic!("seed {seed} thread {t} req {r}: {e:#}"));
                let [nat, itp] = &truths[idx];
                assert!(
                    got == *nat || got == *itp,
                    "seed {seed} thread {t} req {r} input {idx}: reply {got:?} \
                     matches neither native {nat:?} nor interp {itp:?}"
                );
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }

    let total = (THREADS * PER_THREAD) as u64;
    let snap = fleet.snapshot();
    assert_eq!(snap.totals.submitted, total, "seed {seed}: submitted\n{snap}");
    assert_eq!(snap.totals.completed, total, "seed {seed}: completed\n{snap}");
    assert_eq!(snap.totals.failed, 0, "seed {seed}: failed\n{snap}");
    // the per-pool counters are what summed: each pool must be consistent
    for p in &snap.per_pool {
        assert_eq!(
            p.metrics.submitted, p.metrics.completed,
            "seed {seed}: pool {} lost requests\n{snap}",
            p.name
        );
    }
    // least-outstanding dispatch under sustained load must use both pools
    for p in &snap.per_pool {
        assert!(p.metrics.completed > 0, "seed {seed}: pool {} served nothing\n{snap}", p.name);
    }
    if let Ok(fleet) = Arc::try_unwrap(fleet) {
        fleet.shutdown();
    }
}

/// The request-lifecycle gate: a QoS-profiled fleet under a concurrent
/// mixed-class workload with deadlines and cancellations.
///
/// Deterministic by construction, not by timing:
/// * shed requests carry a deadline already expired at submit time, so
///   whatever the scheduling, the batcher must drop them pre-execution;
/// * cancelled requests are cancelled *before* submit (the cancel flag
///   travels with the request), so no worker interleaving can execute
///   them;
/// * Interactive routing is strict when a preferred pool exists, so the
///   interp pool must see zero Interactive submissions — and every
///   Interactive reply must be bit-identical to the native single-session
///   truth (the interp engine is only ±1-close, so a leak would also show
///   up as a wrong payload).
#[test]
fn stress_mixed_class_workload_routes_sheds_and_cancels() {
    let seed = seed() ^ 0xC1A5;
    eprintln!("qos stress seed = {seed}");
    let mut rng = Prng::new(seed);
    let m = random_fc_chain(&mut rng, 2);
    let mut native = Session::builder(&m).engine(Engine::MicroFlow).build().unwrap();
    let mut interp = Session::builder(&m).engine(Engine::Interp).build().unwrap();
    let ilen = native.input_len();
    const DISTINCT: usize = 16;
    let inputs: Vec<Vec<i8>> = (0..DISTINCT).map(|_| rng.i8_vec(ilen)).collect();
    let truths: Vec<[Vec<i8>; 2]> = inputs
        .iter()
        .map(|x| [native.run(x).unwrap(), interp.run(x).unwrap()])
        .collect();

    let cache = Arc::new(SessionCache::new());
    let config = ServerConfig {
        queue_depth: 32,
        batcher: BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) },
        adaptive: true,
        max_retries: 1,
        profile: false,
    };
    let pool = |engine: Engine, name: &str, profile: QosProfile| {
        PoolSpec::new(
            name,
            (0..2)
                .map(|i| {
                    Session::builder(&m)
                        .engine(engine)
                        .label(format!("{name}/{i}"))
                        .cache(&cache)
                        .build()
                        .unwrap()
                })
                .collect(),
        )
        .config(config)
        .profile(profile)
    };
    let fleet = Arc::new(
        Fleet::start(vec![
            pool(Engine::MicroFlow, "native", QosProfile::Interactive),
            pool(Engine::Interp, "interp", QosProfile::Bulk),
        ])
        .unwrap(),
    );

    const THREADS: usize = 6;
    const PER_THREAD: usize = 40;
    let inputs = Arc::new(inputs);
    let truths = Arc::new(truths);
    let mut handles = Vec::new();
    for t in 0..THREADS {
        let fleet = Arc::clone(&fleet);
        let inputs = Arc::clone(&inputs);
        let truths = Arc::clone(&truths);
        handles.push(std::thread::spawn(move || {
            let mut trng = Prng::new(seed ^ (t as u64).wrapping_mul(0x9E37_79B9));
            // (interactive, bulk, shed, cancelled) this thread observed
            let mut tally = (0u64, 0u64, 0u64, 0u64);
            for r in 0..PER_THREAD {
                let idx = trng.below(DISTINCT as u64) as usize;
                let x = inputs[idx].clone();
                let [nat, itp] = &truths[idx];
                match r % 10 {
                    // half the load is interactive: strict-routed to the
                    // native pool, so replies are bit-exact native outputs
                    0..=4 => {
                        let got = fleet
                            .submit(Request::interactive(x))
                            .and_then(|tk| tk.wait())
                            .unwrap_or_else(|e| panic!("seed {seed} thread {t} req {r}: {e:#}"));
                        assert_eq!(
                            &got, nat,
                            "seed {seed} thread {t} req {r}: interactive reply must come \
                             from the native pool (interp truth: {itp:?})"
                        );
                        tally.0 += 1;
                    }
                    // bulk + background: routed to the interp pool
                    5..=7 => {
                        let class =
                            if r % 2 == 0 { QosClass::Bulk } else { QosClass::Background };
                        let got = fleet
                            .submit(Request::new(x).with_class(class))
                            .and_then(|tk| tk.wait())
                            .unwrap_or_else(|e| panic!("seed {seed} thread {t} req {r}: {e:#}"));
                        assert_eq!(
                            &got, itp,
                            "seed {seed} thread {t} req {r}: bulk reply must come from \
                             the interp pool"
                        );
                        tally.1 += 1;
                    }
                    // already-expired deadline: must be shed, never run
                    8 => {
                        let req = Request::new(x)
                            .with_class(QosClass::Bulk)
                            .with_deadline(Instant::now());
                        let err = fleet
                            .submit(req)
                            .and_then(|tk| tk.wait())
                            .expect_err("expired deadline must not produce a reply");
                        assert!(
                            err.to_string().contains("shed"),
                            "seed {seed} thread {t} req {r}: {err:#}"
                        );
                        tally.2 += 1;
                    }
                    // cancelled before submit: must never execute
                    _ => {
                        let req = Request::interactive(x);
                        req.cancel();
                        let err = fleet
                            .submit(req)
                            .and_then(|tk| tk.wait())
                            .expect_err("cancelled ticket must not produce a reply");
                        assert!(
                            err.to_string().contains("cancelled"),
                            "seed {seed} thread {t} req {r}: {err:#}"
                        );
                        tally.3 += 1;
                    }
                }
            }
            tally
        }));
    }
    let mut want = (0u64, 0u64, 0u64, 0u64);
    for h in handles {
        let t = h.join().unwrap();
        want.0 += t.0;
        want.1 += t.1;
        want.2 += t.2;
        want.3 += t.3;
    }

    let total = (THREADS * PER_THREAD) as u64;
    let snap = fleet.snapshot();
    // lifecycle accounting: nothing lost, nothing double-counted
    assert_eq!(snap.totals.submitted, total, "seed {seed}\n{snap}");
    assert_eq!(snap.totals.completed, want.0 + want.1, "seed {seed}\n{snap}");
    assert_eq!(snap.totals.shed, want.2, "seed {seed}: shed must be counted\n{snap}");
    assert_eq!(snap.totals.cancelled, want.3, "seed {seed}: cancelled must be counted\n{snap}");
    assert_eq!(snap.totals.failed, 0, "seed {seed}\n{snap}");
    assert_eq!(
        snap.totals.completed + snap.totals.shed + snap.totals.cancelled,
        total,
        "seed {seed}: every request resolves exactly once\n{snap}"
    );
    // per-class lanes sum to the per-pool totals (and thus to the fleet's)
    for p in &snap.per_pool {
        let pm = &p.metrics;
        for (lane_sum, flat, what) in [
            (pm.per_class.iter().map(|c| c.submitted).sum::<u64>(), pm.submitted, "submitted"),
            (pm.per_class.iter().map(|c| c.completed).sum::<u64>(), pm.completed, "completed"),
            (pm.per_class.iter().map(|c| c.shed).sum::<u64>(), pm.shed, "shed"),
            (pm.per_class.iter().map(|c| c.cancelled).sum::<u64>(), pm.cancelled, "cancelled"),
        ] {
            assert_eq!(lane_sum, flat, "seed {seed}: pool {} {what} lanes\n{snap}", p.name);
        }
    }
    // strict class routing: with an Interactive-preferred pool present, the
    // bulk pool never sees Interactive traffic (and vice versa)
    let native = snap.pool("native").unwrap();
    let interp = snap.pool("interp").unwrap();
    assert_eq!(
        interp.metrics.class(QosClass::Interactive).submitted,
        0,
        "seed {seed}: interactive leaked to the bulk pool\n{snap}"
    );
    assert_eq!(
        native.metrics.class(QosClass::Bulk).submitted
            + native.metrics.class(QosClass::Background).submitted,
        0,
        "seed {seed}: bulk/background leaked to the interactive pool\n{snap}"
    );
    // and the interactive lane did the interactive work
    assert_eq!(
        native.metrics.class(QosClass::Interactive).completed,
        want.0,
        "seed {seed}\n{snap}"
    );
    if let Ok(fleet) = Arc::try_unwrap(fleet) {
        fleet.shutdown();
    }
}

/// The autoscaler gate: an elastic single-pool fleet under a bursty
/// phase-shifting workload with concurrent scale-up and scale-down.
///
/// Deterministic by construction, not by timing:
/// * the SLO-breach signal is carried by requests whose deadline is
///   already expired at submit time — they are shed whatever the
///   scheduling, so *some* tick's window must observe `shed > 0` and
///   scale up (ticks run concurrently with the burst AND once after it
///   joins, so the signal cannot be missed);
/// * normal replies are bit-exact against the single-session native
///   truth — workers joined mid-burst by `add_replica` serve the same
///   warm compiled plan;
/// * scale-downs drain gracefully: the accounting
///   `completed + shed + cancelled == submitted` holds across the whole
///   run, so no accepted request was dropped while workers retired;
/// * after the idle phase the pool is provably back at its floor
///   (asserted on `FleetSnapshot` replica counts).
#[test]
fn stress_autoscale_bursts_scale_up_and_idle_scales_down_without_losses() {
    let seed = seed() ^ 0xE1A5_71C0;
    eprintln!("autoscale stress seed = {seed}");
    let mut rng = Prng::new(seed);
    let m = random_fc_chain(&mut rng, 2);
    let mut native = Session::builder(&m).engine(Engine::MicroFlow).build().unwrap();
    let ilen = native.input_len();
    const DISTINCT: usize = 16;
    let inputs: Vec<Vec<i8>> = (0..DISTINCT).map(|_| rng.i8_vec(ilen)).collect();
    let truths: Vec<Vec<i8>> = inputs.iter().map(|x| native.run(x).unwrap()).collect();

    let cache = Arc::new(SessionCache::new());
    let factory =
        Arc::new(ReplicaFactory::new(&m, Engine::MicroFlow).cache(&cache).label_prefix("native"));
    let policy = AutoscalePolicy::new(1, 4).idle_ticks_down(2).cooldown_ticks(0);
    let config = ServerConfig {
        queue_depth: 32,
        batcher: BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) },
        adaptive: true,
        max_retries: 1,
        profile: false,
    };
    let fleet = Arc::new(
        Fleet::start(vec![PoolSpec::new("native", vec![factory.provision().unwrap()])
            .config(config)
            .autoscale(policy, Arc::clone(&factory))])
        .unwrap(),
    );

    const THREADS: usize = 4;
    const PER_THREAD: usize = 50;
    const CHAOS: usize = 40;
    let inputs = Arc::new(inputs);
    let truths = Arc::new(truths);
    let mut max_live = 1usize;
    let mut want = (0u64, 0u64, 0u64); // (completed, shed, cancelled)

    for phase in 0..2u64 {
        // ---- burst: concurrent clients + deterministic SLO casualties,
        //      with the controller ticking live against the traffic ----
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        std::thread::scope(|s| {
            let mut clients = Vec::new();
            for t in 0..THREADS {
                let fleet = Arc::clone(&fleet);
                let inputs = Arc::clone(&inputs);
                let truths = Arc::clone(&truths);
                clients.push(s.spawn(move || {
                    let mut trng =
                        Prng::new(seed ^ phase ^ (t as u64).wrapping_mul(0x9E37_79B9));
                    for r in 0..PER_THREAD {
                        let idx = trng.below(DISTINCT as u64) as usize;
                        let got = fleet
                            .submit(Request::interactive(inputs[idx].clone()))
                            .and_then(|tk| tk.wait())
                            .unwrap_or_else(|e| {
                                panic!("seed {seed} phase {phase} thread {t} req {r}: {e:#}")
                            });
                        assert_eq!(
                            got, truths[idx],
                            "seed {seed} phase {phase} thread {t} req {r}: reply must be \
                             bit-exact native output"
                        );
                    }
                }));
            }
            // chaos client: expired deadlines (deterministic sheds — the
            // breach signal) and pre-submit cancels, interleaved
            let chaos = {
                let fleet = Arc::clone(&fleet);
                let inputs = Arc::clone(&inputs);
                s.spawn(move || {
                    let mut trng = Prng::new(seed ^ phase ^ 0xC4A0_5000);
                    for r in 0..CHAOS {
                        let idx = trng.below(DISTINCT as u64) as usize;
                        let x = inputs[idx].clone();
                        if r % 2 == 0 {
                            let req = Request::new(x).with_deadline(Instant::now());
                            let err = fleet
                                .submit(req)
                                .and_then(|tk| tk.wait())
                                .expect_err("expired deadline must not produce a reply");
                            assert!(
                                err.to_string().contains("shed"),
                                "seed {seed} phase {phase} chaos {r}: {err:#}"
                            );
                        } else {
                            let req = Request::interactive(x);
                            req.cancel();
                            let err = fleet
                                .submit(req)
                                .and_then(|tk| tk.wait())
                                .expect_err("cancelled ticket must not produce a reply");
                            assert!(
                                err.to_string().contains("cancelled"),
                                "seed {seed} phase {phase} chaos {r}: {err:#}"
                            );
                        }
                    }
                })
            };
            // controller: tick concurrently until every client is done
            let ticker = {
                let fleet = Arc::clone(&fleet);
                let stop = Arc::clone(&stop);
                s.spawn(move || {
                    let mut max_seen = 1usize;
                    while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                        for r in fleet.tick() {
                            max_seen = max_seen.max(r.live_replicas);
                        }
                        std::thread::sleep(Duration::from_millis(1));
                    }
                    max_seen
                })
            };
            for c in clients {
                c.join().unwrap();
            }
            chaos.join().unwrap();
            stop.store(true, std::sync::atomic::Ordering::Relaxed);
            max_live = max_live.max(ticker.join().unwrap());
        });
        want.0 += (THREADS * PER_THREAD) as u64;
        want.1 += (CHAOS / 2) as u64;
        want.2 += (CHAOS / 2) as u64;

        // one guaranteed post-burst tick: even if every concurrent tick
        // missed the shed windows, this one observes the leftover deltas
        // and must scale up (unless a concurrent tick already did)
        let reports = fleet.tick();
        let r = &reports[0];
        max_live = max_live.max(r.live_replicas);
        assert!(
            max_live >= 2,
            "seed {seed} phase {phase}: burst never scaled up (live {}, decision {:?})",
            r.live_replicas,
            r.decision
        );

        // ---- idle: no traffic; ticks must walk the pool back to the
        //      floor via graceful drain ----
        // the concurrent ticker may already have drained the pool to the
        // floor between the last client finishing and the stop flag — in
        // that case reaching the floor IS the scale-down evidence
        let at_floor_already = fleet.snapshot().per_pool[0].live_replicas() == 1;
        let mut saw_down = false;
        for _ in 0..30 {
            let reports = fleet.tick();
            let r = &reports[0];
            if let Some(d) = r.decision {
                saw_down |= matches!(d.action, ScaleAction::Down(_));
            }
            if r.live_replicas == 1 {
                break;
            }
        }
        let snap = fleet.snapshot();
        assert!(
            saw_down || at_floor_already,
            "seed {seed} phase {phase}: idle never scaled down\n{snap}"
        );
        assert_eq!(
            snap.per_pool[0].live_replicas(),
            1,
            "seed {seed} phase {phase}: pool not back at its floor\n{snap}"
        );
        // the burst after this idle phase proves the shrunken pool (and
        // any still-draining victim) keeps serving bit-exactly
    }

    // ---- accounting across all concurrent scale events ----
    let total = want.0 + want.1 + want.2;
    let snap = fleet.snapshot();
    assert_eq!(snap.totals.submitted, total, "seed {seed}\n{snap}");
    assert_eq!(snap.totals.completed, want.0, "seed {seed}\n{snap}");
    assert_eq!(snap.totals.shed, want.1, "seed {seed}\n{snap}");
    assert_eq!(snap.totals.cancelled, want.2, "seed {seed}\n{snap}");
    assert_eq!(snap.totals.failed, 0, "seed {seed}\n{snap}");
    assert_eq!(
        snap.totals.completed + snap.totals.shed + snap.totals.cancelled,
        snap.totals.submitted,
        "seed {seed}: every request resolves exactly once\n{snap}"
    );
    assert!(max_live >= 2, "seed {seed}: autoscaler never grew the pool");
    let status = snap.per_pool[0].autoscale.expect("elastic pool must report its autoscaler");
    assert_eq!((status.min_replicas, status.max_replicas), (1, 4));
    assert!(status.ticks > 0, "seed {seed}: the controller never ticked");
    // replies kept flowing the whole time — and the warm factory never
    // recompiled for any of the concurrent scale-ups
    assert_eq!(factory.warm_cache().misses(), 2, "seed {seed}: scale-up recompiled the model");
    if let Ok(fleet) = Arc::try_unwrap(fleet) {
        fleet.shutdown();
    }
}

#[test]
fn stress_shutdown_under_load_answers_every_accepted_request() {
    let seed = seed() ^ 0xD00D;
    eprintln!("shutdown stress seed = {seed}");
    let mut rng = Prng::new(seed);
    let m = random_fc_chain(&mut rng, 2);
    let fleet = mixed_fleet(&m, 64);
    let ilen = fleet.input_len();

    // flood the queues without consuming any reply, then shut down while
    // the backlog is still draining
    let mut pending = Vec::new();
    for i in 0..96 {
        let x = rng.i8_vec(ilen);
        let ticket = fleet
            .submit(Request::new(x))
            .unwrap_or_else(|e| panic!("seed {seed} req {i}: {e:#}"));
        pending.push((i, ticket));
    }
    fleet.shutdown(); // drops the queues and joins workers — must drain first
    for (i, ticket) in pending {
        let reply = ticket.wait();
        assert!(
            reply.is_ok(),
            "seed {seed} req {i}: dropped or failed on shutdown: {:#}",
            reply.unwrap_err()
        );
    }
}

#[test]
fn stress_backpressure_never_drops_or_reorders_per_thread() {
    // tiny queue: submitters block on a full queue; every request must
    // still be answered exactly once with the right output
    let seed = seed() ^ 0xB10C;
    eprintln!("backpressure stress seed = {seed}");
    let mut rng = Prng::new(seed);
    let m = random_fc_chain(&mut rng, 1);
    let mut native = Session::builder(&m).engine(Engine::MicroFlow).build().unwrap();
    let mut interp = Session::builder(&m).engine(Engine::Interp).build().unwrap();
    let ilen = native.input_len();
    let x = rng.i8_vec(ilen);
    let truth = [native.run(&x).unwrap(), interp.run(&x).unwrap()];

    let fleet = Arc::new(mixed_fleet(&m, 2));
    let mut handles = Vec::new();
    for t in 0..6 {
        let fleet = Arc::clone(&fleet);
        let x = x.clone();
        let truth = truth.clone();
        handles.push(std::thread::spawn(move || {
            for r in 0..40 {
                let got = fleet.infer(x.clone()).unwrap();
                assert!(
                    got == truth[0] || got == truth[1],
                    "seed {seed} thread {t} req {r}: {got:?}"
                );
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let snap = fleet.snapshot();
    assert_eq!(snap.totals.submitted, 240, "seed {seed}\n{snap}");
    assert_eq!(snap.totals.completed, 240, "seed {seed}\n{snap}");
    if let Ok(fleet) = Arc::try_unwrap(fleet) {
        fleet.shutdown();
    }
}

/// The fault-tolerance gate: a four-replica elastic pool where three
/// replicas misbehave by seeded injection — `chaos/1` fails transiently,
/// `chaos/2` wedges (every call fails after a warm-up), `chaos/3` dies
/// fatally — under a concurrent client load with the control loop
/// ticking live.
///
/// Deterministic by construction where it matters:
/// * the extended identity `completed + shed + cancelled + failed ==
///   submitted` is asserted **exactly** — whatever the interleaving,
///   every accepted request resolves exactly once (retries re-enqueue
///   the same request and are counted outside the identity);
/// * every completed reply is **bit-exact** against the single-session
///   native truth (replicas are all native; the injector wraps them
///   without touching payloads);
/// * only the wedged replica is ever ejected (the transient replica can
///   never build an ejection streak — consecutive calls cannot both be
///   casualties of an every-Nth schedule — and the fatal one dies before
///   the health pass sees it);
/// * the pool heals back to its floor: the wedged replica is replaced
///   warm (provision-first, so live never dips below the floor), the
///   dead one is re-floored by the autoscaler's `BelowMin` rule, and the
///   warm cache proves no replacement recompiled the model.
#[test]
fn stress_chaos_replica_failures_heal_without_loss() {
    let seed = seed() ^ 0xFA17;
    eprintln!("chaos stress seed = {seed}");
    let mut rng = Prng::new(seed);
    let m = random_fc_chain(&mut rng, 2);
    let mut native = Session::builder(&m).engine(Engine::MicroFlow).build().unwrap();
    let ilen = native.input_len();
    const DISTINCT: usize = 16;
    let inputs: Vec<Vec<i8>> = (0..DISTINCT).map(|_| rng.i8_vec(ilen)).collect();
    let truths: Vec<Vec<i8>> = inputs.iter().map(|x| native.run(x).unwrap()).collect();

    let cache = Arc::new(SessionCache::new());
    // replica 0 healthy; 1 transient (~every 4th call, phase-shifted by
    // the seed); 2 wedged after 5 calls; 3 fatal on its 8th call.
    // Replacements provision past index 3, so they are always clean.
    let factory = Arc::new(
        ReplicaFactory::new(&m, Engine::MicroFlow)
            .cache(&cache)
            .label_prefix("chaos")
            .fault(1, FaultPlan::new(seed).transient_every(4))
            .fault(2, FaultPlan::new(seed).wedge_after(5))
            .fault(3, FaultPlan::new(seed).fatal_on(8)),
    );
    let config = ServerConfig {
        queue_depth: 32,
        batcher: BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) },
        adaptive: true,
        max_retries: 2,
        profile: false,
    };
    // the autoscaler is the healing actuator: floor 4 re-provisions the
    // fatal death (BelowMin) and the health pass replaces the wedged
    // replica through the same factory
    let policy = AutoscalePolicy::new(4, 6).cooldown_ticks(0).idle_ticks_down(u32::MAX);
    let fleet = Arc::new(
        Fleet::start(vec![PoolSpec::new("chaos", factory.provision_n(4).unwrap())
            .config(config)
            .autoscale(policy, Arc::clone(&factory))
            .no_breaker()])
        .unwrap(),
    );

    const THREADS: usize = 6;
    const PER_THREAD: usize = 40;
    let inputs = Arc::new(inputs);
    let truths = Arc::new(truths);
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let mut tallies = (0u64, 0u64); // (completed, failed)
    let mut ejected_during_load: Vec<String> = Vec::new();
    std::thread::scope(|s| {
        let mut clients = Vec::new();
        for t in 0..THREADS {
            let fleet = Arc::clone(&fleet);
            let inputs = Arc::clone(&inputs);
            let truths = Arc::clone(&truths);
            clients.push(s.spawn(move || {
                let mut trng = Prng::new(seed ^ (t as u64).wrapping_mul(0x9E37_79B9));
                let mut tally = (0u64, 0u64);
                for r in 0..PER_THREAD {
                    let idx = trng.below(DISTINCT as u64) as usize;
                    match fleet.submit(Request::new(inputs[idx].clone())).and_then(|tk| tk.wait())
                    {
                        Ok(got) => {
                            assert_eq!(
                                got, truths[idx],
                                "seed {seed} thread {t} req {r}: completed replies must \
                                 stay bit-exact under chaos"
                            );
                            tally.0 += 1;
                        }
                        // an exhausted retry budget resolves as a typed,
                        // labelled failure — a legitimate outcome here
                        Err(e) if format!("{e:#}").contains("failed on replica") => tally.1 += 1,
                        Err(e) => panic!("seed {seed} thread {t} req {r}: {e:#}"),
                    }
                }
                tally
            }));
        }
        // the control loop ticks live against the failing traffic:
        // health ejection and BelowMin repair race the clients
        let ticker = {
            let fleet = Arc::clone(&fleet);
            let stop = Arc::clone(&stop);
            s.spawn(move || {
                let mut ejected = Vec::new();
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    for r in fleet.tick() {
                        ejected.extend(r.ejected);
                    }
                    std::thread::sleep(Duration::from_millis(1));
                }
                ejected
            })
        };
        for c in clients {
            let t = c.join().unwrap();
            tallies.0 += t.0;
            tallies.1 += t.1;
        }
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        ejected_during_load = ticker.join().unwrap();
    });

    // heal: keep ticking until the wedged replica is ejected, the fatal
    // one is registered dead and the pool is back at its floor
    let deadline = Instant::now() + Duration::from_secs(10);
    let healed = loop {
        let snap = fleet.snapshot();
        let p = &snap.per_pool[0];
        let phase =
            |label: &str| p.replica_health.iter().find(|h| h.label == label).map(|h| h.phase);
        if phase("chaos/2") == Some(ReplicaPhase::Ejected)
            && phase("chaos/3") == Some(ReplicaPhase::Dead)
            && p.live_replicas() == 4
            && p.retiring == 0
        {
            break snap;
        }
        assert!(Instant::now() < deadline, "seed {seed}: pool never healed\n{snap}");
        for r in fleet.tick() {
            ejected_during_load.extend(r.ejected);
        }
        std::thread::sleep(Duration::from_millis(2));
    };

    // only the wedged replica is ever ejected — the transient one cannot
    // streak and stays in service
    assert!(
        ejected_during_load.iter().all(|l| l == "chaos/2"),
        "seed {seed}: unexpected ejections {ejected_during_load:?}"
    );
    let p = &healed.per_pool[0];
    let phase_of = |label: &str| {
        p.replica_health.iter().find(|h| h.label == label).map(|h| h.phase).unwrap()
    };
    assert_eq!(phase_of("chaos/0"), ReplicaPhase::Live, "seed {seed}\n{healed}");
    assert_eq!(phase_of("chaos/1"), ReplicaPhase::Live, "seed {seed}\n{healed}");

    // exact extended identity: every accepted request resolved once
    let total = (THREADS * PER_THREAD) as u64;
    let t = &healed.totals;
    assert_eq!(t.submitted, total, "seed {seed}\n{healed}");
    assert_eq!(t.completed, tallies.0, "seed {seed}\n{healed}");
    assert_eq!(t.failed, tallies.1, "seed {seed}\n{healed}");
    assert_eq!((t.shed, t.cancelled), (0, 0), "seed {seed}\n{healed}");
    assert_eq!(
        t.completed + t.shed + t.cancelled + t.failed,
        t.submitted,
        "seed {seed}: every request resolves exactly once\n{healed}"
    );
    // the injected faults actually exercised the retry path
    assert!(
        t.retried + t.failed > 0,
        "seed {seed}: chaos injected no observable failures\n{healed}"
    );
    // healing reused the warm plan: one bytes miss + one plan miss total,
    // across the initial four replicas AND every replacement
    assert_eq!(factory.warm_cache().misses(), 2, "seed {seed}: a replacement recompiled");
    // serving continues cleanly on the healed pool
    let idx = 3 % DISTINCT;
    assert_eq!(
        fleet.infer(inputs[idx].clone()).unwrap(),
        truths[idx],
        "seed {seed}: healed pool must serve bit-exactly"
    );
    if let Ok(fleet) = Arc::try_unwrap(fleet) {
        fleet.shutdown();
    }
}
