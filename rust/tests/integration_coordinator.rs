//! Integration: the serving coordinator over real models — batching,
//! concurrency, backpressure, multi-model routing, and cross-backend
//! output consistency.

mod common;

use std::sync::Arc;
use std::time::Duration;

use microflow::api::{Engine, Session};
use microflow::coordinator::{BatcherConfig, QosClass, Request, Router, Server, ServerConfig};
use microflow::eval::accuracy::argmax;
use microflow::format::mds::MdsDataset;

fn native_server(art: &std::path::Path, name: &str, replicas: usize, max_batch: usize) -> Server {
    let sessions: Vec<Session> = (0..replicas)
        .map(|_| {
            Session::builder(art.join(format!("{name}.mfb")))
                .engine(Engine::MicroFlow)
                .preferred_batch(max_batch)
                .build()
                .unwrap()
        })
        .collect();
    let cfg = ServerConfig {
        queue_depth: 64,
        batcher: BatcherConfig { max_batch, max_wait: Duration::from_millis(1) },
        adaptive: false,
        max_retries: 1,
        profile: false,
    };
    Server::start(sessions, cfg).unwrap()
}

#[test]
fn serves_speech_with_correct_classes() {
    let art = require_artifacts!();
    let ds = MdsDataset::load(art.join("speech_test.mds")).unwrap();
    let server = native_server(&art, "speech", 2, 8);
    let qp = server.input_qparams();
    let mut hits = 0;
    let n = 60;
    for i in 0..n {
        let out = server.infer(qp.quantize_slice(ds.sample(i))).unwrap();
        if argmax(&out) as i32 == ds.class(i) {
            hits += 1;
        }
    }
    // Table-5-level accuracy on this slice
    assert!(hits as f64 / n as f64 > 0.8, "only {hits}/{n} correct");
    let snap = server.metrics.snapshot();
    assert_eq!(snap.completed, n as u64);
    assert_eq!(snap.failed, 0);
    server.shutdown();
}

#[test]
fn batching_aggregates_under_concurrency() {
    let art = require_artifacts!();
    let server = Arc::new(native_server(&art, "sine", 1, 8));
    let mut handles = Vec::new();
    for t in 0..16 {
        let s = Arc::clone(&server);
        handles.push(std::thread::spawn(move || {
            for i in 0..25 {
                let out = s.infer(vec![(t * 7 + i) as i8]).unwrap();
                assert_eq!(out.len(), 1);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let snap = server.metrics.snapshot();
    assert_eq!(snap.completed, 400);
    // with 16 concurrent clients and a single worker, batches must form
    assert!(snap.mean_batch > 1.2, "mean batch {}", snap.mean_batch);
    if let Ok(server) = Arc::try_unwrap(server) {
        server.shutdown();
    }
}

#[test]
fn batched_results_match_unbatched() {
    let art = require_artifacts!();
    let server = Arc::new(native_server(&art, "sine", 1, 8));
    // reference: sequential (batch of 1)
    let mut expected = Vec::new();
    for q in -20..20i16 {
        expected.push(server.infer(vec![q as i8]).unwrap());
    }
    // concurrent resubmission — batches form, results must be identical
    let mut handles = Vec::new();
    for (idx, q) in (-20..20i16).enumerate() {
        let s = Arc::clone(&server);
        handles.push(std::thread::spawn(move || (idx, s.infer(vec![q as i8]).unwrap())));
    }
    for h in handles {
        let (idx, out) = h.join().unwrap();
        assert_eq!(out, expected[idx], "request {idx}");
    }
    if let Ok(server) = Arc::try_unwrap(server) {
        server.shutdown();
    }
}

#[test]
fn router_serves_multiple_models() {
    let art = require_artifacts!();
    let mut router = Router::new();
    router.add("sine", native_server(&art, "sine", 1, 4));
    router.add("speech", native_server(&art, "speech", 1, 4));
    assert_eq!(router.models(), vec!["sine", "speech"]);
    let sine_q = router.get("sine").unwrap().input_qparams();
    let out = router.infer("sine", vec![sine_q.quantize(1.0)]).unwrap();
    assert_eq!(out.len(), 1);
    let ds = MdsDataset::load(art.join("speech_test.mds")).unwrap();
    let sp_q = router.get("speech").unwrap().input_qparams();
    let out = router.infer("speech", sp_q.quantize_slice(ds.sample(0))).unwrap();
    assert_eq!(out.len(), 4);
    assert!(router.infer("nope", vec![0]).is_err());
    router.shutdown();
}

#[test]
fn interp_backend_serves_equivalently() {
    let art = require_artifacts!();
    let ds = MdsDataset::load(art.join("speech_test.mds")).unwrap();
    let nat = native_server(&art, "speech", 1, 4);
    let sessions = vec![Session::builder(art.join("speech.mfb"))
        .engine(Engine::Interp)
        .build()
        .unwrap()];
    let itp = Server::start(sessions, ServerConfig::default()).unwrap();
    let qp = nat.input_qparams();
    for i in 0..10 {
        let q = qp.quantize_slice(ds.sample(i));
        let a = nat.infer(q.clone()).unwrap();
        let b = itp.infer(q).unwrap();
        assert_eq!(argmax(&a), argmax(&b), "sample {i}");
    }
    nat.shutdown();
    itp.shutdown();
}

#[test]
fn shutdown_is_clean_with_queued_work() {
    let art = require_artifacts!();
    let server = native_server(&art, "sine", 2, 8);
    let mut tickets = Vec::new();
    for q in 0..32i16 {
        tickets.push(server.submit(Request::new(vec![q as i8])).unwrap());
    }
    // all replies must arrive before shutdown returns
    for t in tickets {
        assert!(t.wait().is_ok());
    }
    server.shutdown();
}

#[test]
fn tcp_ingress_serves_and_reports_errors() {
    let art = require_artifacts!();
    let mut router = Router::new();
    router.add("sine", native_server(&art, "sine", 1, 4));
    let router = Arc::new(router);
    let ingress =
        microflow::coordinator::Ingress::start("127.0.0.1:0", Arc::clone(&router)).unwrap();
    let addr = ingress.addr;

    // parallel clients over the wire, checking against in-process results
    let expected = router.infer("sine", vec![5]).unwrap();
    let mut handles = Vec::new();
    for _ in 0..4 {
        let expected = expected.clone();
        handles.push(std::thread::spawn(move || {
            let mut c = microflow::coordinator::Client::connect(addr).unwrap();
            for _ in 0..20 {
                let out = c.infer("sine", &[5]).unwrap();
                assert_eq!(out, expected);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }

    // unknown model -> clean error over the wire, connection stays usable
    let mut c = microflow::coordinator::Client::connect(addr).unwrap();
    let err = c.infer("missing", &[0]).unwrap_err().to_string();
    assert!(err.contains("missing"), "{err}");
    assert_eq!(c.infer("sine", &[5]).unwrap(), expected);
    // the v2 frame serves the same bytes on a real model artifact
    let got = c.infer_with("sine", &[5], QosClass::Interactive, Some(30_000)).unwrap();
    assert_eq!(got, expected);
    drop(c); // close the connection so its handler thread exits

    ingress.shutdown();
    match Arc::try_unwrap(router) {
        Ok(r) => r.shutdown(),
        Err(_) => panic!("router still referenced"),
    }
}
