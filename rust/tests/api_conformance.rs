//! Session-API conformance suite — runs WITHOUT build artifacts.
//!
//! Randomized models (seeded via `util::Prng`, so fully deterministic) are
//! constructed in memory by `microflow::synth`, serialized through
//! `format::builder`, and fed to every engine through the one entry point
//! (`Session::builder(...).engine(...)`). The gates:
//!
//! * native and paged-native sessions are **bit-identical** (paging is a
//!   time/space trade, never an accuracy trade — paper Sec. 4.3);
//! * native and interp sessions agree within **±1 output unit** (the
//!   paper's Sec. 6.2.1 float-scale vs fixed-point observation). The
//!   generator bounds each layer's error gain so the ±1 holds through
//!   multi-layer chains, not just single operators;
//! * `run_batch_into` is allocation-free: internal buffer pointers are
//!   stable across repeated batched calls and batches equal single runs;
//! * **the serving tiers preserve the execution tier's outputs**: the same
//!   model answers identically through `Session::run_into`, a 1-replica
//!   `Server`, and a multi-replica heterogeneous `Fleet`;
//! * **both wire generations round-trip**: a legacy v1 `MFRQ` client and a
//!   v2 `MFR2` client (class + deadline) get identical, execution-tier
//!   outputs from the same ingress, and a malformed v2 class byte is a
//!   clean error frame;
//! * malformed geometry (VALID kernel larger than its input) surfaces as a
//!   build-time `Err` from every engine, never a panic.

use microflow::api::{Engine, Session};
use microflow::coordinator::{
    Client, Fleet, Ingress, IngressConfig, PoolSpec, QosClass, Router, Server, ServerConfig,
};
use microflow::format::mfb::{MfbModel, OpCode, OpOptions, Operator, Padding};
use microflow::synth::{self, random_conv, random_fc_chain};
use microflow::util::Prng;

fn sessions_for(m: &MfbModel) -> (Session, Session, Session) {
    let native = Session::builder(m).engine(Engine::MicroFlow).build().unwrap();
    let paged = Session::builder(m).engine(Engine::MicroFlow).paging(true).build().unwrap();
    let interp = Session::builder(m).engine(Engine::Interp).build().unwrap();
    (native, paged, interp)
}

fn assert_parity(m: &MfbModel, rng: &mut Prng, runs: usize, label: &str) {
    let (mut native, mut paged, mut interp) = sessions_for(m);
    assert_eq!(native.signature(), interp.signature(), "{label}: signatures diverge");
    let ilen = native.input_len();
    for r in 0..runs {
        let x = rng.i8_vec(ilen);
        let a = native.run(&x).unwrap();
        let p = paged.run(&x).unwrap();
        assert_eq!(a, p, "{label} run {r}: paged output diverged");
        let b = interp.run(&x).unwrap();
        for (j, (u, v)) in a.iter().zip(&b).enumerate() {
            assert!(
                (*u as i32 - *v as i32).abs() <= 1,
                "{label} run {r} out[{j}]: native {u} vs interp {v} ({a:?} vs {b:?})"
            );
        }
    }
}

#[test]
fn random_fc_chains_agree_across_engines() {
    let mut rng = Prng::new(2024);
    for case in 0..20 {
        let depth = 1 + (case % 3); // chains of 1, 2 and 3 FC layers
        let m = random_fc_chain(&mut rng, depth);
        assert_parity(&m, &mut rng, 8, &format!("fc case {case} depth {depth}"));
    }
}

#[test]
fn random_convs_agree_across_engines() {
    let mut rng = Prng::new(77);
    for case in 0..12 {
        let m = random_conv(&mut rng);
        assert_parity(&m, &mut rng, 5, &format!("conv case {case}"));
    }
}

#[test]
fn run_batch_into_is_pointer_stable_on_random_models() {
    let mut rng = Prng::new(31);
    let m = random_fc_chain(&mut rng, 2);
    for engine in [Engine::MicroFlow, Engine::Interp] {
        let mut s = Session::builder(&m).engine(engine).build().unwrap();
        let (ilen, olen) = (s.input_len(), s.output_len());
        let n = 6;
        let inputs = rng.i8_vec(n * ilen);
        let mut out = vec![0i8; n * olen];
        s.run_batch_into(&inputs, n, &mut out).unwrap();
        let p0 = s.buffer_ptrs();
        assert!(!p0.is_empty());
        for _ in 0..16 {
            s.run_batch_into(&inputs, n, &mut out).unwrap();
        }
        assert_eq!(s.buffer_ptrs(), p0, "{engine}: buffers reallocated on the batch path");
        // and batching is semantics-preserving
        for i in 0..n {
            let single = s.run(&inputs[i * ilen..(i + 1) * ilen]).unwrap();
            assert_eq!(&out[i * olen..(i + 1) * olen], single.as_slice(), "{engine} sample {i}");
        }
    }
}

/// The fleet conformance gate: the same randomized models must produce
/// identical outputs whether run through `Session::run_into`, a 1-replica
/// `Server`, or a multi-replica heterogeneous `Fleet`. The heterogeneous
/// fleet mixes unpaged and paged native pools (different executors, bit-
/// identical semantics); a mixed native+interp fleet is additionally held
/// to the ±1 engine-agreement bound.
#[test]
fn fleet_path_preserves_single_session_outputs() {
    let mut rng = Prng::new(0xF1EE7);
    for case in 0..6 {
        let m = random_fc_chain(&mut rng, 1 + case % 3);

        // ground truth: the execution tier
        let mut single = Session::builder(&m).engine(Engine::MicroFlow).build().unwrap();
        let ilen = single.input_len();
        let inputs: Vec<Vec<i8>> = (0..8).map(|_| rng.i8_vec(ilen)).collect();
        let truth: Vec<Vec<i8>> = inputs.iter().map(|x| single.run(x).unwrap()).collect();

        // tier 2: a 1-replica server
        let server = Server::start(
            vec![Session::builder(&m).engine(Engine::MicroFlow).build().unwrap()],
            ServerConfig::default(),
        )
        .unwrap();
        for (x, want) in inputs.iter().zip(&truth) {
            assert_eq!(&server.infer(x.clone()).unwrap(), want, "case {case}: server diverged");
        }
        server.shutdown();

        // tier 3: a heterogeneous fleet (unpaged pool + paged pool, two
        // replicas each) — still bit-identical to the single session
        let fleet = Fleet::start(vec![
            PoolSpec::new(
                "unpaged",
                (0..2)
                    .map(|i| {
                        Session::builder(&m)
                            .engine(Engine::MicroFlow)
                            .label(format!("unpaged/{i}"))
                            .build()
                            .unwrap()
                    })
                    .collect(),
            ),
            PoolSpec::new(
                "paged",
                (0..2)
                    .map(|i| {
                        Session::builder(&m)
                            .engine(Engine::MicroFlow)
                            .paging(true)
                            .label(format!("paged/{i}"))
                            .build()
                            .unwrap()
                    })
                    .collect(),
            ),
        ])
        .unwrap();
        for round in 0..3 {
            for (x, want) in inputs.iter().zip(&truth) {
                assert_eq!(
                    &fleet.infer(x.clone()).unwrap(),
                    want,
                    "case {case} round {round}: fleet diverged"
                );
            }
        }
        let snap = fleet.snapshot();
        assert_eq!(snap.totals.completed, 24, "case {case}");
        assert_eq!(snap.totals.failed, 0, "case {case}");
        fleet.shutdown();

        // mixed-engine fleet: replies must stay within the ±1 bound
        let mixed = Fleet::start(vec![
            PoolSpec::new(
                "native",
                vec![Session::builder(&m).engine(Engine::MicroFlow).build().unwrap()],
            ),
            PoolSpec::new(
                "interp",
                vec![Session::builder(&m).engine(Engine::Interp).build().unwrap()],
            ),
        ])
        .unwrap();
        for (x, want) in inputs.iter().zip(&truth) {
            let got = mixed.infer(x.clone()).unwrap();
            for (j, (g, w)) in got.iter().zip(want).enumerate() {
                assert!(
                    (*g as i32 - *w as i32).abs() <= 1,
                    "case {case} out[{j}]: mixed fleet {g} vs native {w}"
                );
            }
        }
        mixed.shutdown();
    }
}

/// The wire-protocol conformance gate: the same randomized model must
/// answer identically through `Session::run_into`, a legacy v1 `MFRQ`
/// client, and a v2 `MFR2` client with explicit class and deadline — the
/// v1 path proving that pre-QoS clients round-trip unchanged against the
/// v2 ingress.
#[test]
fn ingress_serves_v1_and_v2_frames_identically() {
    let mut rng = Prng::new(0x1f6e55);
    let m = random_fc_chain(&mut rng, 2);
    let mut single = Session::builder(&m).engine(Engine::MicroFlow).build().unwrap();
    let ilen = single.input_len();
    let inputs: Vec<Vec<i8>> = (0..4).map(|_| rng.i8_vec(ilen)).collect();
    let truth: Vec<Vec<i8>> = inputs.iter().map(|x| single.run(x).unwrap()).collect();

    let mut router = Router::new();
    router.add(
        "synth",
        Server::start(
            vec![Session::builder(&m).engine(Engine::MicroFlow).build().unwrap()],
            ServerConfig::default(),
        )
        .unwrap(),
    );
    let router = std::sync::Arc::new(router);
    let ingress = Ingress::start_with(
        "127.0.0.1:0",
        std::sync::Arc::clone(&router),
        IngressConfig::default(),
    )
    .unwrap();
    let mut c = Client::connect(ingress.addr).unwrap();
    for (x, want) in inputs.iter().zip(&truth) {
        // legacy v1 frame: no class, no deadline — served with defaults
        assert_eq!(&c.infer("synth", x).unwrap(), want, "v1 frame diverged");
        // v2 frame: explicit class, generous deadline — same output
        let got = c.infer_with("synth", x, QosClass::Interactive, Some(10_000)).unwrap();
        assert_eq!(&got, want, "v2 interactive frame diverged");
        let got = c.infer_with("synth", x, QosClass::Background, None).unwrap();
        assert_eq!(&got, want, "v2 background frame diverged");
    }
    // a malformed v2 class byte is a clean error frame, not a hang
    {
        use std::io::{Read, Write};
        let mut raw = std::net::TcpStream::connect(ingress.addr).unwrap();
        raw.write_all(b"MFR2").unwrap();
        raw.write_all(&[9u8]).unwrap(); // invalid class
        raw.write_all(&0u32.to_le_bytes()).unwrap();
        raw.write_all(&(5u16).to_le_bytes()).unwrap();
        raw.write_all(b"synth").unwrap();
        raw.write_all(&(ilen as u32).to_le_bytes()).unwrap();
        raw.write_all(&vec![0u8; ilen]).unwrap();
        raw.flush().unwrap();
        let mut head = [0u8; 5];
        raw.read_exact(&mut head).unwrap();
        assert_eq!(&head[..4], b"MFRS");
        assert_eq!(head[4], 1, "invalid class byte must be a status-1 error");
        let mut len = [0u8; 4];
        raw.read_exact(&mut len).unwrap();
        let mut msg = vec![0u8; u32::from_le_bytes(len) as usize];
        raw.read_exact(&mut msg).unwrap();
        let msg = String::from_utf8_lossy(&msg);
        assert!(msg.contains("class"), "{msg}");
    }
    // unknown model still errors cleanly on both frame generations
    let err = c.infer("missing", &inputs[0]).unwrap_err().to_string();
    assert!(err.contains("missing"), "{err}");
    let err = c
        .infer_with("missing", &inputs[0], QosClass::Bulk, None)
        .unwrap_err()
        .to_string();
    assert!(err.contains("missing"), "{err}");
    drop(c);
    ingress.shutdown();
    // handler threads drop their router Arc on connection EOF; give them a
    // bounded grace period before unwrapping
    let mut router = router;
    let mut unwrapped = None;
    for _ in 0..500 {
        match std::sync::Arc::try_unwrap(router) {
            Ok(r) => {
                unwrapped = Some(r);
                break;
            }
            Err(r) => {
                router = r;
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
        }
    }
    unwrapped.expect("router still referenced by a handler thread").shutdown();
}

#[test]
fn oversized_valid_kernel_fails_cleanly_in_every_engine() {
    // regression for the out_dims underflow: kh > h under VALID padding
    // must be a build-time Err from both compile paths, never a panic
    let mut rng = Prng::new(5);
    let mut m = random_conv(&mut rng);
    // force geometry kh > h with VALID padding, keeping the rest intact
    let (h, w, c) = (3usize, 3usize, 1usize);
    let (kh, kw) = (5usize, 2usize);
    let c_out = 2usize;
    m.tensors[0] = synth::act_tensor("in", vec![1, h, w, c], 0.05, 0);
    m.tensors[1] = synth::i8_tensor("f", vec![c_out, kh, kw, c], 0.02, vec![1; c_out * kh * kw * c]);
    m.tensors[2] = synth::i32_tensor("b", vec![c_out], 0.001, vec![0; c_out]);
    m.tensors[3] = synth::act_tensor("y", vec![1, 1, 1, c_out], 1.0, 0);
    m.operators[0] = Operator {
        opcode: OpCode::Conv2D,
        version: 1,
        inputs: vec![0, 1, 2],
        outputs: vec![3],
        options: OpOptions::Conv2D { stride: (1, 1), padding: Padding::Valid, fused_act: 0 },
    };
    for engine in [Engine::MicroFlow, Engine::Interp] {
        let err = Session::builder(&m).engine(engine).build().unwrap_err();
        assert!(format!("{err:#}").contains("exceeds input"), "{engine}: {err:#}");
    }
}
