//! Session-API conformance suite — runs WITHOUT build artifacts.
//!
//! Randomized models (seeded via `util::Prng`, so fully deterministic) are
//! constructed in memory, serialized through `format::builder`, and fed to
//! every engine through the one entry point
//! (`Session::builder(...).engine(...)`). The gates:
//!
//! * native and paged-native sessions are **bit-identical** (paging is a
//!   time/space trade, never an accuracy trade — paper Sec. 4.3);
//! * native and interp sessions agree within **±1 output unit** (the
//!   paper's Sec. 6.2.1 float-scale vs fixed-point observation). The
//!   generator bounds each layer's error gain so the ±1 holds through
//!   multi-layer chains, not just single operators;
//! * `run_batch_into` is allocation-free: internal buffer pointers are
//!   stable across repeated batched calls and batches equal single runs;
//! * malformed geometry (VALID kernel larger than its input) surfaces as a
//!   build-time `Err` from every engine, never a panic.

use microflow::api::{Engine, Session};
use microflow::format::mfb::{MfbModel, OpCode, OpOptions, Operator, Padding, TensorDef};
use microflow::kernels::out_dims;
use microflow::tensor::quant::QParams;
use microflow::tensor::DType;
use microflow::util::Prng;

fn act_tensor(name: &str, dims: Vec<usize>, scale: f32, zp: i32) -> TensorDef {
    TensorDef { name: name.into(), dtype: DType::I8, dims, qparams: QParams::new(scale, zp), data: Vec::new() }
}

fn i8_tensor(name: &str, dims: Vec<usize>, scale: f32, data: Vec<i8>) -> TensorDef {
    TensorDef {
        name: name.into(),
        dtype: DType::I8,
        dims,
        qparams: QParams::new(scale, 0),
        data: data.iter().map(|&v| v as u8).collect(),
    }
}

fn i32_tensor(name: &str, dims: Vec<usize>, scale: f32, data: Vec<i32>) -> TensorDef {
    TensorDef {
        name: name.into(),
        dtype: DType::I32,
        dims,
        qparams: QParams::new(scale, 0),
        data: data.iter().flat_map(|v| v.to_le_bytes()).collect(),
    }
}

fn model(tensors: Vec<TensorDef>, operators: Vec<Operator>, out_idx: usize) -> MfbModel {
    MfbModel {
        version: 1,
        producer: "api_conformance".into(),
        tensors,
        operators,
        graph_inputs: vec![0],
        graph_outputs: vec![out_idx],
        metadata: "{}".into(),
        file_bytes: 0, // refreshed when the serialized bytes are reparsed
    }
}

/// Small weights + an output scale that caps each layer's error gain at
/// 0.1: a ±1 input disagreement perturbs the pre-rounding output by at
/// most 0.1 units, so the engines' outputs stay within ±1 at EVERY layer
/// of a chain (gain * 1 + rounding < 2 ⇒ integer diff ≤ 1).
const W_MAX: i64 = 8;
const GAIN: f32 = 0.1;

fn small_weights(rng: &mut Prng, n: usize) -> Vec<i8> {
    (0..n).map(|_| rng.range_i64(-W_MAX, W_MAX) as i8).collect()
}

/// Randomized FC chain: input [1,k0] -> FC*depth, each with random dims,
/// weights, bias and a fused relu on some layers.
fn random_fc_chain(rng: &mut Prng, depth: usize) -> MfbModel {
    let k0 = rng.range_i64(2, 16) as usize;
    let mut tensors = vec![act_tensor("in", vec![1, k0], rng.f32_range(0.02, 0.1), rng.range_i64(-5, 5) as i32)];
    let mut operators = Vec::new();
    let mut k = k0;
    let mut cur = 0usize;
    for layer in 0..depth {
        let n = rng.range_i64(1, 12) as usize;
        let s_x = tensors[cur].qparams.scale;
        let s_w = rng.f32_range(0.01, 0.05);
        // max per-unit sensitivity is W_MAX * k weights: pick s_y for GAIN
        let s_y = s_x * s_w * (W_MAX as f32) * (k as f32) / GAIN;
        let z_y = rng.range_i64(-10, 10) as i32;
        let w_idx = tensors.len();
        tensors.push(i8_tensor(&format!("w{layer}"), vec![k, n], s_w, small_weights(rng, k * n)));
        let b_idx = tensors.len();
        tensors.push(i32_tensor(&format!("b{layer}"), vec![n], s_x * s_w, rng.i32_vec(n, -100, 100)));
        let y_idx = tensors.len();
        tensors.push(act_tensor(&format!("y{layer}"), vec![1, n], s_y, z_y));
        operators.push(Operator {
            opcode: OpCode::FullyConnected,
            version: 1,
            inputs: vec![cur as i32, w_idx as i32, b_idx as i32],
            outputs: vec![y_idx as i32],
            options: OpOptions::FullyConnected { fused_act: (rng.below(2)) as u8 },
        });
        cur = y_idx;
        k = n;
    }
    model(tensors, operators, cur)
}

/// Randomized single Conv2D model (SAME or VALID, stride 1 or 2).
fn random_conv(rng: &mut Prng) -> MfbModel {
    let (h, w) = (rng.range_i64(3, 8) as usize, rng.range_i64(3, 8) as usize);
    let c = rng.range_i64(1, 3) as usize;
    let (kh, kw) = (rng.range_i64(1, h as i64) as usize, rng.range_i64(1, w as i64) as usize);
    let stride = rng.range_i64(1, 2) as usize;
    let padding = if rng.below(2) == 0 { Padding::Same } else { Padding::Valid };
    let c_out = rng.range_i64(1, 4) as usize;
    let (oh, ow) = out_dims(h, w, kh, kw, stride, stride, padding).unwrap();

    let s_x = rng.f32_range(0.02, 0.1);
    let z_x = rng.range_i64(-5, 5) as i32;
    let s_f = rng.f32_range(0.01, 0.05);
    let window = kh * kw * c;
    let s_y = s_x * s_f * (W_MAX as f32) * (window as f32) / GAIN;
    let z_y = rng.range_i64(-10, 10) as i32;

    let tensors = vec![
        act_tensor("in", vec![1, h, w, c], s_x, z_x),
        i8_tensor("f", vec![c_out, kh, kw, c], s_f, small_weights(rng, c_out * window)),
        i32_tensor("b", vec![c_out], s_x * s_f, rng.i32_vec(c_out, -100, 100)),
        act_tensor("y", vec![1, oh, ow, c_out], s_y, z_y),
    ];
    let operators = vec![Operator {
        opcode: OpCode::Conv2D,
        version: 1,
        inputs: vec![0, 1, 2],
        outputs: vec![3],
        options: OpOptions::Conv2D {
            stride: (stride, stride),
            padding,
            fused_act: (rng.below(2)) as u8,
        },
    }];
    model(tensors, operators, 3)
}

fn sessions_for(m: &MfbModel) -> (Session, Session, Session) {
    let native = Session::builder(m).engine(Engine::MicroFlow).build().unwrap();
    let paged = Session::builder(m).engine(Engine::MicroFlow).paging(true).build().unwrap();
    let interp = Session::builder(m).engine(Engine::Interp).build().unwrap();
    (native, paged, interp)
}

fn assert_parity(m: &MfbModel, rng: &mut Prng, runs: usize, label: &str) {
    let (mut native, mut paged, mut interp) = sessions_for(m);
    assert_eq!(native.signature(), interp.signature(), "{label}: signatures diverge");
    let ilen = native.input_len();
    for r in 0..runs {
        let x = rng.i8_vec(ilen);
        let a = native.run(&x).unwrap();
        let p = paged.run(&x).unwrap();
        assert_eq!(a, p, "{label} run {r}: paged output diverged");
        let b = interp.run(&x).unwrap();
        for (j, (u, v)) in a.iter().zip(&b).enumerate() {
            assert!(
                (*u as i32 - *v as i32).abs() <= 1,
                "{label} run {r} out[{j}]: native {u} vs interp {v} ({a:?} vs {b:?})"
            );
        }
    }
}

#[test]
fn random_fc_chains_agree_across_engines() {
    let mut rng = Prng::new(2024);
    for case in 0..20 {
        let depth = 1 + (case % 3); // chains of 1, 2 and 3 FC layers
        let m = random_fc_chain(&mut rng, depth);
        assert_parity(&m, &mut rng, 8, &format!("fc case {case} depth {depth}"));
    }
}

#[test]
fn random_convs_agree_across_engines() {
    let mut rng = Prng::new(77);
    for case in 0..12 {
        let m = random_conv(&mut rng);
        assert_parity(&m, &mut rng, 5, &format!("conv case {case}"));
    }
}

#[test]
fn run_batch_into_is_pointer_stable_on_random_models() {
    let mut rng = Prng::new(31);
    let m = random_fc_chain(&mut rng, 2);
    for engine in [Engine::MicroFlow, Engine::Interp] {
        let mut s = Session::builder(&m).engine(engine).build().unwrap();
        let (ilen, olen) = (s.input_len(), s.output_len());
        let n = 6;
        let inputs = rng.i8_vec(n * ilen);
        let mut out = vec![0i8; n * olen];
        s.run_batch_into(&inputs, n, &mut out).unwrap();
        let p0 = s.buffer_ptrs();
        assert!(!p0.is_empty());
        for _ in 0..16 {
            s.run_batch_into(&inputs, n, &mut out).unwrap();
        }
        assert_eq!(s.buffer_ptrs(), p0, "{engine}: buffers reallocated on the batch path");
        // and batching is semantics-preserving
        for i in 0..n {
            let single = s.run(&inputs[i * ilen..(i + 1) * ilen]).unwrap();
            assert_eq!(&out[i * olen..(i + 1) * olen], single.as_slice(), "{engine} sample {i}");
        }
    }
}

#[test]
fn oversized_valid_kernel_fails_cleanly_in_every_engine() {
    // regression for the out_dims underflow: kh > h under VALID padding
    // must be a build-time Err from both compile paths, never a panic
    let mut rng = Prng::new(5);
    let mut m = random_conv(&mut rng);
    // force geometry kh > h with VALID padding, keeping the rest intact
    let (h, w, c) = (3usize, 3usize, 1usize);
    let (kh, kw) = (5usize, 2usize);
    let c_out = 2usize;
    m.tensors[0] = act_tensor("in", vec![1, h, w, c], 0.05, 0);
    m.tensors[1] = i8_tensor("f", vec![c_out, kh, kw, c], 0.02, vec![1; c_out * kh * kw * c]);
    m.tensors[2] = i32_tensor("b", vec![c_out], 0.001, vec![0; c_out]);
    m.tensors[3] = act_tensor("y", vec![1, 1, 1, c_out], 1.0, 0);
    m.operators[0] = Operator {
        opcode: OpCode::Conv2D,
        version: 1,
        inputs: vec![0, 1, 2],
        outputs: vec![3],
        options: OpOptions::Conv2D { stride: (1, 1), padding: Padding::Valid, fused_act: 0 },
    };
    for engine in [Engine::MicroFlow, Engine::Interp] {
        let err = Session::builder(&m).engine(engine).build().unwrap_err();
        assert!(format!("{err:#}").contains("exceeds input"), "{engine}: {err:#}");
    }
}
