//! Streaming conformance suite — pulsed sessions vs the full-window
//! replay oracle, across engines, under faults, no build artifacts
//! needed.
//!
//! Every test derives all randomness from one seed so failures reproduce
//! exactly. The seed defaults to a fixed value (CI determinism — see
//! `.github/workflows/ci.yml`) and can be overridden for exploration:
//!
//! ```sh
//! MICROFLOW_STRESS_SEED=12345 cargo test --test stream_conformance
//! ```
//!
//! Gates (the streaming contract from `microflow::stream`):
//! * **bit-exact pulses**: for every model of the seeded streaming zoo,
//!   the pulsed native session returns *exactly* what a full-window
//!   re-run of the native engine returns, at **every** push — warmup
//!   `None`s included, across several whole windows of frames;
//! * the replay oracle is **engine-generic**: an interp-backed replay
//!   session equals a one-shot interp run over the materialized window
//!   at every verdict boundary;
//! * **cross-engine** verdicts stay within the established ±1 interp
//!   requantization bound;
//! * every zoo plan **certifies** (`V401`–`V405`) and is **strictly
//!   cheaper** than full recompute by the `sim::cost` MAC model;
//! * the coordinator's streaming lane survives **concurrent streams +
//!   mid-stream replica ejection**: every delivered verdict is bit-exact
//!   to an uninterrupted single-session oracle at the same frame index,
//!   and the per-stream lifecycle identity
//!   `completed + shed + cancelled + failed == submitted` holds exactly.

use std::sync::Arc;
use std::thread;

use microflow::api::{Engine, Session};
use microflow::compiler::plan::{CompileOptions, CompiledModel};
use microflow::compiler::PulsePlan;
use microflow::coordinator::{StreamFault, StreamHost, StreamHostConfig, StreamPush};
use microflow::stream::StreamSession;
use microflow::synth::stream_zoo;
use microflow::util::Prng;

const DEFAULT_SEED: u64 = 0x5EED_2026;

fn seed() -> u64 {
    match std::env::var("MICROFLOW_STRESS_SEED") {
        Ok(v) => v.parse().unwrap_or_else(|_| panic!("bad MICROFLOW_STRESS_SEED {v:?}")),
        Err(_) => DEFAULT_SEED,
    }
}

fn compile(m: &microflow::format::mfb::MfbModel) -> Arc<CompiledModel> {
    Arc::new(CompiledModel::compile(m, CompileOptions::default()).unwrap())
}

/// Pulsed native == full-window native replay at EVERY push, warmup
/// `None`s included, for every member of the streaming zoo.
#[test]
fn pulsed_matches_native_replay_at_every_frame_across_the_zoo() {
    let seed = seed();
    eprintln!("stream seed = {seed} (override with MICROFLOW_STRESS_SEED)");
    for (name, m) in stream_zoo(seed) {
        let compiled = compile(&m);
        let plan = PulsePlan::plan(&compiled).unwrap();
        let mut pulsed = StreamSession::pulsed(compiled.clone()).unwrap();
        let oracle = Session::builder(&m).engine(Engine::MicroFlow).build().unwrap();
        let mut replay = StreamSession::replay(oracle, plan.pulse_frames).unwrap();
        let mut rng = Prng::new(seed ^ 0x11);
        let total = plan.window_rows * 3 + plan.pulse_frames;
        let mut verdicts = 0usize;
        for i in 0..total {
            let f = rng.i8_vec(plan.frame_len);
            let a = pulsed.push(&f).unwrap();
            let b = replay.push(&f).unwrap();
            assert_eq!(a, b, "seed {seed} model {name}: diverged at frame {i}");
            if i + 1 < plan.window_rows {
                assert!(a.is_none(), "seed {seed} model {name}: verdict before the window filled");
            }
            if a.is_some() {
                verdicts += 1;
            }
        }
        assert!(verdicts > 1, "seed {seed} model {name}: pulse cadence never fired twice");
    }
}

/// The replay oracle is engine-generic: an interp-backed replay session
/// equals a one-shot interp run over the materialized window at every
/// verdict boundary.
#[test]
fn interp_replay_matches_interp_one_shot_windows() {
    let seed = seed();
    for (name, m) in stream_zoo(seed) {
        let compiled = compile(&m);
        let plan = PulsePlan::plan(&compiled).unwrap();
        let interp = Session::builder(&m).engine(Engine::Interp).build().unwrap();
        let mut replay = StreamSession::replay(interp, plan.pulse_frames).unwrap();
        let mut one_shot = Session::builder(&m).engine(Engine::Interp).build().unwrap();
        let mut rng = Prng::new(seed ^ 0x22);
        let mut history: Vec<i8> = Vec::new();
        let window_len = plan.window_rows * plan.frame_len;
        for i in 0..plan.window_rows * 3 {
            let f = rng.i8_vec(plan.frame_len);
            history.extend_from_slice(&f);
            if let Some(v) = replay.push(&f).unwrap() {
                let window = &history[history.len() - window_len..];
                let expect = one_shot.run(window).unwrap();
                assert_eq!(v, expect, "seed {seed} model {name}: interp replay != one-shot at frame {i}");
            }
        }
    }
}

/// Pulsed native vs interp replay: the ±1 requantization bound that
/// holds for one-shot runs holds per verdict element on streams too.
#[test]
fn cross_engine_verdicts_agree_within_one_lsb() {
    let seed = seed();
    for (name, m) in stream_zoo(seed) {
        let compiled = compile(&m);
        let plan = PulsePlan::plan(&compiled).unwrap();
        let mut pulsed = StreamSession::pulsed(compiled.clone()).unwrap();
        let interp = Session::builder(&m).engine(Engine::Interp).build().unwrap();
        let mut replay = StreamSession::replay(interp, plan.pulse_frames).unwrap();
        let mut rng = Prng::new(seed ^ 0x33);
        for i in 0..plan.window_rows * 2 {
            let f = rng.i8_vec(plan.frame_len);
            let a = pulsed.push(&f).unwrap();
            let b = replay.push(&f).unwrap();
            assert_eq!(a.is_some(), b.is_some(), "seed {seed} model {name}: cadence split at frame {i}");
            if let (Some(a), Some(b)) = (a, b) {
                for (j, (x, y)) in a.iter().zip(b.iter()).enumerate() {
                    let d = (*x as i16 - *y as i16).abs();
                    assert!(
                        d <= 1,
                        "seed {seed} model {name}: frame {i} elem {j}: native {x} vs interp {y}"
                    );
                }
            }
        }
    }
}

/// Every zoo plan certifies (`PulsePlan::plan` runs the `V4xx` verifier)
/// and is strictly cheaper than a full-window recompute by the
/// `sim::cost` MAC model — the incremental path must pay for itself.
#[test]
fn every_zoo_plan_certifies_and_is_strictly_cheaper_than_full_recompute() {
    let seed = seed();
    for (name, m) in stream_zoo(seed) {
        let compiled = compile(&m);
        let plan = PulsePlan::plan(&compiled).unwrap();
        let pulse = plan.pulse_macs(&compiled);
        let full = plan.full_macs(&compiled);
        assert!(
            pulse < full,
            "seed {seed} model {name}: pulsed work {pulse} MACs not below full {full} MACs"
        );
        assert!(plan.total_state_bytes() > 0, "seed {seed} model {name}: plan carries no state");
    }
}

/// Concurrent streams on a faulty host: worker 0 fails every push and is
/// ejected mid-stream; every stream keeps its lifecycle identity, and
/// every verdict that *was* delivered is bit-exact to an uninterrupted
/// single-session oracle fed the same frames — migration replays the
/// host-side ring, so no frame is ever lost.
#[test]
fn concurrent_streams_survive_ejection_with_identity_and_bit_exact_verdicts() {
    let seed = seed();
    eprintln!("stream seed = {seed} (override with MICROFLOW_STRESS_SEED)");
    let (name, m) = stream_zoo(seed).into_iter().next().unwrap();
    let compiled = compile(&m);
    let plan = PulsePlan::plan(&compiled).unwrap();
    let host = Arc::new(
        StreamHost::start(compiled.clone(), StreamHostConfig { replicas: 2, eject_after: 2 })
            .unwrap(),
    );
    // worker 0 fails every push: two consecutive failures quarantine it,
    // and the next tick ejects + migrates its streams
    host.inject_fault(StreamFault { worker: 0, every: 1 });

    let streams = 4usize;
    let frames = plan.window_rows * 2 + plan.pulse_frames * 4;
    let mut handles = Vec::new();
    for s in 0..streams {
        let host = Arc::clone(&host);
        let compiled = Arc::clone(&compiled);
        let frame_len = plan.frame_len;
        let model_name = name.clone();
        handles.push(thread::spawn(move || {
            // uninterrupted oracle over the same deterministic frames
            let mut oracle = StreamSession::pulsed(compiled).unwrap();
            let mut rng = Prng::new(seed ^ (0x9E3779B9 * (s as u64 + 1)));
            let id = host.open(format!("conf-{s}")).unwrap();
            let mut delivered = 0usize;
            let mut soft = 0usize;
            for i in 0..frames {
                let f = rng.i8_vec(frame_len);
                let expect = oracle.push(&f).unwrap();
                match host.push(id, &f).unwrap() {
                    StreamPush::Verdict(v) => {
                        let e = expect.unwrap_or_else(|| {
                            panic!("seed {seed} model {model_name} stream {s}: spurious verdict at frame {i}")
                        });
                        assert_eq!(
                            v, e,
                            "seed {seed} model {model_name} stream {s}: verdict at frame {i} not bit-exact"
                        );
                        delivered += 1;
                    }
                    StreamPush::Pending => {}
                    StreamPush::Shed | StreamPush::Failed(_) => soft += 1,
                    StreamPush::Closed => panic!("stream {s} closed early"),
                }
            }
            let counters = host.close(id).unwrap();
            assert!(
                counters.identity_holds(),
                "seed {seed} model {model_name} stream {s}: lifecycle identity broken: {counters:?}"
            );
            assert_eq!(
                counters.submitted, frames as u64,
                "seed {seed} stream {s}: submitted != frames pushed"
            );
            (delivered, soft)
        }));
    }
    // tick the health pass while pushes are in flight so ejection and
    // migration race real traffic
    let mut ejected = Vec::new();
    for _ in 0..200 {
        let report = host.tick();
        ejected.extend(report.ejected);
        thread::yield_now();
        if host.snapshot().streams.is_empty() {
            break;
        }
    }
    let mut total_delivered = 0usize;
    let mut total_soft = 0usize;
    for h in handles {
        let (delivered, soft) = h.join().unwrap();
        total_delivered += delivered;
        total_soft += soft;
    }
    // drain any remaining quarantine
    ejected.extend(host.tick().ejected);
    assert!(
        ejected.iter().any(|w| w == "stream-w0"),
        "seed {seed}: the faulty replica was never ejected (ejected = {ejected:?})"
    );
    assert!(total_soft > 0, "seed {seed}: the fault never fired — test lost its teeth");
    assert!(
        total_delivered > 0,
        "seed {seed}: no verdicts survived ejection — migration replay is broken"
    );
}
