//! Integration: the real build artifacts parse correctly and carry the
//! structure the paper's Table 3 describes.

mod common;

use microflow::format::golden::Golden;
use microflow::format::mds::{Labels, MdsDataset};
use microflow::format::mfb::{MfbModel, OpCode};

#[test]
fn all_models_parse_and_have_expected_ops() {
    let art = require_artifacts!();
    for name in common::MODELS {
        let m = MfbModel::load(art.join(format!("{name}.mfb"))).unwrap();
        assert_eq!(m.version, 1);
        assert!(!m.producer.is_empty());
        assert_eq!(m.graph_inputs.len(), 1);
        assert_eq!(m.graph_outputs.len(), 1);
        let ops: Vec<OpCode> = m.operators.iter().map(|o| o.opcode).collect();
        match name {
            "sine" => assert_eq!(ops, vec![OpCode::FullyConnected; 3]),
            "speech" => assert_eq!(
                ops,
                vec![OpCode::DepthwiseConv2D, OpCode::Reshape, OpCode::FullyConnected, OpCode::Softmax]
            ),
            "person" => {
                // MobileNet: conv + 13x(dw+pw) + pool + flatten + fc + softmax
                assert_eq!(ops.len(), 31);
                assert_eq!(ops[0], OpCode::Conv2D);
                assert_eq!(ops.iter().filter(|o| **o == OpCode::DepthwiseConv2D).count(), 13);
                assert_eq!(ops.iter().filter(|o| **o == OpCode::Conv2D).count(), 14);
                assert_eq!(*ops.last().unwrap(), OpCode::Softmax);
            }
            _ => unreachable!(),
        }
    }
}

#[test]
fn model_sizes_match_paper_table3_order() {
    let art = require_artifacts!();
    let size = |n: &str| MfbModel::load(art.join(format!("{n}.mfb"))).unwrap().weights_bytes();
    let (sine, speech, person) = (size("sine"), size("speech"), size("person"));
    // Table 3: 3 kB < 19 kB < 301 kB ordering; ours: ~0.4k < ~17k < ~219k
    assert!(sine < speech && speech < person);
    assert!(speech > 10_000 && speech < 25_000, "speech ~19kB class: {speech}");
    assert!(person > 150_000 && person < 300_000, "person ~300kB class: {person}");
}

#[test]
fn datasets_match_paper_protocol_sizes() {
    let art = require_artifacts!();
    let sine = MdsDataset::load(art.join("sine_test.mds")).unwrap();
    assert_eq!(sine.n, 1000);
    assert!(matches!(sine.labels, Labels::Regression { dim: 1, .. }));
    let speech = MdsDataset::load(art.join("speech_test.mds")).unwrap();
    assert_eq!(speech.n, 1236);
    assert_eq!(speech.sample_shape, vec![49, 40, 1]);
    let person = MdsDataset::load(art.join("person_test.mds")).unwrap();
    assert_eq!(person.n, 406);
    assert_eq!(person.sample_shape, vec![96, 96, 1]);
}

#[test]
fn goldens_are_consistent_with_models() {
    let art = require_artifacts!();
    for name in common::MODELS {
        let g = Golden::load(art.join(format!("{name}_golden.bin"))).unwrap();
        let m = MfbModel::load(art.join(format!("{name}.mfb"))).unwrap();
        assert_eq!(g.in_len(), m.input_shape().iter().product::<usize>());
        assert_eq!(g.out_len(), m.output_shape().iter().product::<usize>());
        assert!(g.n >= 8);
    }
}

#[test]
fn metadata_is_retained_for_the_interpreter() {
    // the interpreter's Flash cost story requires names/metadata present
    let art = require_artifacts!();
    let m = MfbModel::load(art.join("speech.mfb")).unwrap();
    assert!(m.metadata_bytes() > 200, "container must carry metadata: {}", m.metadata_bytes());
    assert!(m.tensors.iter().all(|t| !t.name.is_empty()));
}
