//! Integration: the MicroFlow engine and the TFLM-like interpreter on the
//! real shipped models — correctness, determinism, paging, and the two
//! engines' Sec. 6.2.1 agreement. All sessions are constructed through
//! `microflow::api::Session::builder` (the crate's single entry point).

mod common;

use microflow::api::{Engine, Session};
use microflow::compiler::plan::{CompileOptions, CompiledModel};
use microflow::eval::accuracy::argmax;
use microflow::format::golden::Golden;
use microflow::format::mfb::MfbModel;
use microflow::util::Prng;

#[test]
fn engine_is_bit_exact_vs_jax_golden_on_all_models() {
    let art = require_artifacts!();
    for name in common::MODELS {
        let g = Golden::load(art.join(format!("{name}_golden.bin"))).unwrap();
        let mut s = Session::builder(art.join(format!("{name}.mfb"))).build().unwrap();
        for i in 0..g.n {
            let out = s.run(g.input(i)).unwrap();
            assert_eq!(out.as_slice(), g.output(i), "{name} sample {i}");
        }
    }
}

#[test]
fn engine_is_deterministic() {
    let art = require_artifacts!();
    let mut s = Session::builder(art.join("speech.mfb")).build().unwrap();
    let mut rng = Prng::new(5);
    let x = rng.i8_vec(s.input_len());
    let a = s.run(&x).unwrap();
    for _ in 0..5 {
        assert_eq!(s.run(&x).unwrap(), a);
    }
}

#[test]
fn paged_execution_identical_on_sine() {
    let art = require_artifacts!();
    let path = art.join("sine.mfb");
    let mut unpaged = Session::builder(&path).paging(false).build().unwrap();
    let mut paged = Session::builder(&path).paging(true).build().unwrap();
    for q in -128..=127i16 {
        let x = [q as i8];
        assert_eq!(unpaged.run(&x).unwrap(), paged.run(&x).unwrap(), "q={q}");
    }
}

#[test]
fn interpreter_agrees_with_engine_per_paper() {
    // Sec. 6.2.1: on in-distribution inputs the engines agree within ±1
    // per operator output; through multiple layers the rounding can
    // compound, so the end-to-end gates are ±1 on the shallow speech
    // model's probabilities and decision agreement everywhere.
    let art = require_artifacts!();
    for name in common::MODELS {
        let path = art.join(format!("{name}.mfb"));
        let mut e = Session::builder(&path).engine(Engine::MicroFlow).build().unwrap();
        let mut it = Session::builder(&path).engine(Engine::Interp).build().unwrap();
        let ds = microflow::format::mds::MdsDataset::load(art.join(format!("{name}_test.mds"))).unwrap();
        let qp = e.input_qparams();
        for i in 0..10 {
            let x = qp.quantize_slice(ds.sample(i));
            let a = e.run(&x).unwrap();
            let b = it.run(&x).unwrap();
            match name {
                "speech" => {
                    for (u, v) in a.iter().zip(&b) {
                        assert!((*u as i32 - *v as i32).abs() <= 1, "{name}: {a:?} vs {b:?}");
                    }
                }
                "person" => assert_eq!(argmax(&a), argmax(&b), "{name}: decisions diverged"),
                _ => {
                    // sine: 3 stacked FCs with gain — allow small compounding
                    let d = (a[0] as i32 - b[0] as i32).abs();
                    assert!(d <= 4, "{name}: {a:?} vs {b:?}");
                }
            }
        }
    }
}

#[test]
fn session_batches_match_singles_on_real_models() {
    let art = require_artifacts!();
    let mut s = Session::builder(art.join("speech.mfb")).build().unwrap();
    let (ilen, olen) = (s.input_len(), s.output_len());
    let mut rng = Prng::new(11);
    let inputs = rng.i8_vec(4 * ilen);
    let batched = s.run_batch(&inputs, 4).unwrap();
    for i in 0..4 {
        let single = s.run(&inputs[i * ilen..(i + 1) * ilen]).unwrap();
        assert_eq!(&batched[i * olen..(i + 1) * olen], single.as_slice(), "sample {i}");
    }
    // pointer stability on a real model: no allocation on the batch path
    let p0 = s.buffer_ptrs();
    let mut out = vec![0i8; 4 * olen];
    for _ in 0..5 {
        s.run_batch_into(&inputs, 4, &mut out).unwrap();
    }
    assert_eq!(s.buffer_ptrs(), p0);
}

#[test]
fn memory_plan_peak_is_consistent_with_buffers() {
    let art = require_artifacts!();
    for name in common::MODELS {
        let m = MfbModel::load(art.join(format!("{name}.mfb"))).unwrap();
        let c = CompiledModel::compile(&m, CompileOptions::default()).unwrap();
        let mem = &c.memory;
        // the per-step peak never exceeds what the executor allocates
        assert!(mem.peak <= mem.executor_bytes() + c.input_len().max(c.output_len()));
        // every step's live set is represented
        assert_eq!(mem.per_step.len(), c.steps.len());
        // the paper's claim: the peak step is a real operator, and for the
        // conv models it's an early, wide layer
        assert!(mem.peak_step < c.steps.len());
        if name == "person" {
            assert!(mem.peak_step <= 4, "person peak should be an early wide conv");
        }
    }
}

#[test]
fn compiled_model_strips_what_the_interpreter_keeps() {
    let art = require_artifacts!();
    let m = MfbModel::load(art.join("speech.mfb")).unwrap();
    let c = CompiledModel::compile(&m, CompileOptions::default()).unwrap();
    // compiled weight payload (incl. folded f32 constants) stays below the
    // serialized container size: names/options/versions are gone
    assert!(c.weight_bytes() < m.file_bytes);
}

#[test]
fn speech_macs_match_hand_count() {
    let art = require_artifacts!();
    let m = MfbModel::load(art.join("speech.mfb")).unwrap();
    let c = CompiledModel::compile(&m, CompileOptions::default()).unwrap();
    // dw: 25*20*8 outputs x 10*8 window = 320_000; fc: 4000*4 = 16_000;
    // softmax: 4
    assert_eq!(c.total_macs(), 320_000 + 16_000 + 4);
}
