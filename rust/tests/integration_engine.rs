//! Integration: the MicroFlow engine and the TFLM-like interpreter on the
//! real shipped models — correctness, determinism, paging, and the two
//! engines' Sec. 6.2.1 agreement.

mod common;

use microflow::compiler::plan::{CompileOptions, CompiledModel};
use microflow::engine::MicroFlowEngine;
use microflow::eval::accuracy::argmax;
use microflow::format::golden::Golden;
use microflow::format::mfb::MfbModel;
use microflow::interp::resolver::OpResolver;
use microflow::interp::Interpreter;
use microflow::util::Prng;

#[test]
fn engine_is_bit_exact_vs_jax_golden_on_all_models() {
    let art = require_artifacts!();
    for name in common::MODELS {
        let g = Golden::load(art.join(format!("{name}_golden.bin"))).unwrap();
        let e = MicroFlowEngine::load(art.join(format!("{name}.mfb")), CompileOptions::default()).unwrap();
        for i in 0..g.n {
            let out = e.predict(g.input(i));
            assert_eq!(out.as_slice(), g.output(i), "{name} sample {i}");
        }
    }
}

#[test]
fn engine_is_deterministic() {
    let art = require_artifacts!();
    let e = MicroFlowEngine::load(art.join("speech.mfb"), CompileOptions::default()).unwrap();
    let mut rng = Prng::new(5);
    let x = rng.i8_vec(e.input_len());
    let a = e.predict(&x);
    for _ in 0..5 {
        assert_eq!(e.predict(&x), a);
    }
}

#[test]
fn paged_execution_identical_on_sine() {
    let art = require_artifacts!();
    let m = MfbModel::load(art.join("sine.mfb")).unwrap();
    let unpaged = MicroFlowEngine::new(&m, CompileOptions { paging: false }).unwrap();
    let paged = MicroFlowEngine::new(&m, CompileOptions { paging: true }).unwrap();
    for q in -128..=127i16 {
        let x = [q as i8];
        assert_eq!(unpaged.predict(&x), paged.predict(&x), "q={q}");
    }
}

#[test]
fn interpreter_agrees_with_engine_per_paper() {
    // Sec. 6.2.1: on in-distribution inputs the engines agree within ±1
    // per operator output; through multiple layers the rounding can
    // compound, so the end-to-end gates are ±1 on the shallow speech
    // model's probabilities and decision agreement everywhere.
    let art = require_artifacts!();
    for name in common::MODELS {
        let path = art.join(format!("{name}.mfb"));
        let e = MicroFlowEngine::load(&path, CompileOptions::default()).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let mut it = Interpreter::new(&bytes, &OpResolver::with_all_kernels()).unwrap();
        let ds = microflow::format::mds::MdsDataset::load(art.join(format!("{name}_test.mds"))).unwrap();
        let qp = e.input_qparams();
        for i in 0..10 {
            let x = qp.quantize_slice(ds.sample(i));
            let a = e.predict(&x);
            let b = it.invoke(&x).unwrap();
            match name {
                "speech" => {
                    for (u, v) in a.iter().zip(&b) {
                        assert!((*u as i32 - *v as i32).abs() <= 1, "{name}: {a:?} vs {b:?}");
                    }
                }
                "person" => assert_eq!(argmax(&a), argmax(&b), "{name}: decisions diverged"),
                _ => {
                    // sine: 3 stacked FCs with gain — allow small compounding
                    let d = (a[0] as i32 - b[0] as i32).abs();
                    assert!(d <= 4, "{name}: {a:?} vs {b:?}");
                }
            }
        }
    }
}

#[test]
fn memory_plan_peak_is_consistent_with_buffers() {
    let art = require_artifacts!();
    for name in common::MODELS {
        let m = MfbModel::load(art.join(format!("{name}.mfb"))).unwrap();
        let c = CompiledModel::compile(&m, CompileOptions::default()).unwrap();
        let mem = &c.memory;
        // the per-step peak never exceeds what the executor allocates
        assert!(mem.peak <= mem.executor_bytes() + c.input_len().max(c.output_len()));
        // every step's live set is represented
        assert_eq!(mem.per_step.len(), c.steps.len());
        // the paper's claim: the peak step is a real operator, and for the
        // conv models it's an early, wide layer
        assert!(mem.peak_step < c.steps.len());
        if name == "person" {
            assert!(mem.peak_step <= 4, "person peak should be an early wide conv");
        }
    }
}

#[test]
fn compiled_model_strips_what_the_interpreter_keeps() {
    let art = require_artifacts!();
    let m = MfbModel::load(art.join("speech.mfb")).unwrap();
    let c = CompiledModel::compile(&m, CompileOptions::default()).unwrap();
    // compiled weight payload (incl. folded f32 constants) stays below the
    // serialized container size: names/options/versions are gone
    assert!(c.weight_bytes() < m.file_bytes);
}

#[test]
fn speech_macs_match_hand_count() {
    let art = require_artifacts!();
    let m = MfbModel::load(art.join("speech.mfb")).unwrap();
    let c = CompiledModel::compile(&m, CompileOptions::default()).unwrap();
    // dw: 25*20*8 outputs x 10*8 window = 320_000; fc: 4000*4 = 16_000;
    // softmax: 4
    assert_eq!(c.total_macs(), 320_000 + 16_000 + 4);
}
