//! Property-based tests over coordinator invariants, kernel equivalences,
//! format robustness and planner invariants (DESIGN.md deliverable (c)).
//!
//! proptest is unavailable offline; a seeded xoshiro PRNG (`util::Prng`)
//! drives the case generation — every failure reproduces from its printed
//! seed.

mod common;

use microflow::compiler::pack::pack_conv2d;
use microflow::compiler::plan::{CompileOptions, CompiledModel};
use microflow::format::mfb::{MfbModel, Padding};
use microflow::interp::arena::ArenaPlan;
use microflow::kernels::view::ConvGeometry;
use microflow::kernels::{conv2d, depthwise_conv2d, fully_connected};
use microflow::tensor::fixedpoint::{
    multiply_by_quantized_multiplier, quantize_multiplier, FixedPointMultiplier,
};
use microflow::tensor::quant::{requant_float, FusedAct, PreComputed, QParams};
use microflow::util::Prng;

const CASES: usize = 200;

/// Random qparams in realistic PTQ ranges.
fn rand_qp(rng: &mut Prng) -> (f32, i32) {
    (rng.f32_range(0.005, 0.2), rng.range_i64(-20, 20) as i32)
}

#[test]
fn prop_fc_paged_equals_unpaged() {
    let mut rng = Prng::new(0xF00D);
    for case in 0..CASES {
        let k = rng.range_i64(1, 96) as usize;
        let n = rng.range_i64(1, 48) as usize;
        let x = rng.i8_vec(k);
        let w = rng.i8_vec(k * n);
        let b = rng.i32_vec(n, -2000, 2000);
        let (s_x, z_x) = rand_qp(&mut rng);
        let (s_w, z_w) = rand_qp(&mut rng);
        let (s_y, z_y) = rand_qp(&mut rng);
        let colsum: Vec<i32> = (0..n).map(|j| (0..k).map(|i| w[i * n + j] as i32).sum()).collect();
        let pc = PreComputed::fold(&b, &colsum, k, s_x, z_x, s_w, z_w, s_x * s_w, 0, s_y, z_y, FusedAct::None);
        let mut a = vec![0i8; n];
        let mut p = vec![0i8; n];
        let mut page = vec![0i8; k];
        fully_connected::fully_connected_microflow(&x, &w, k, n, &pc, &mut a);
        fully_connected::fully_connected_paged(&x, &w, k, n, &pc, &mut page, &mut p);
        assert_eq!(a, p, "case {case} (k={k}, n={n})");
    }
}

#[test]
fn prop_fixedpoint_within_one_unit_of_float() {
    // the paper's Sec. 6.2.1 bound as a broad property
    let mut rng = Prng::new(0xBEEF);
    for case in 0..5000 {
        let ratio = rng.f32_range(1e-6, 0.05);
        let z_y = rng.range_i64(-128, 127) as i32;
        let acc = rng.range_i64(-200_000, 200_000) as i32;
        let m = FixedPointMultiplier::from_real(ratio as f64);
        let fixed = m.requant(acc, z_y, -128, 127);
        let float = requant_float(acc, z_y as f32, ratio, -128, 127);
        assert!(
            (fixed as i32 - float as i32).abs() <= 1,
            "case {case}: acc={acc} ratio={ratio} -> {fixed} vs {float}"
        );
    }
}

#[test]
fn prop_quantize_multiplier_reconstructs() {
    let mut rng = Prng::new(0xCAFE);
    for _ in 0..2000 {
        let real = rng.f64() * 10.0 + 1e-9;
        let (qm, shift) = quantize_multiplier(real);
        assert!(qm >= 1 << 30, "mantissa normalized");
        let back = qm as f64 * 2f64.powi(shift - 31);
        assert!((back - real).abs() / real < 1e-8);
    }
}

#[test]
fn prop_mbqm_monotone_in_acc() {
    // requantization must preserve ordering (no inversions from rounding)
    let mut rng = Prng::new(0xAB);
    for _ in 0..500 {
        let m = FixedPointMultiplier::from_real(rng.f64() * 0.01 + 1e-6);
        let a = rng.range_i64(-100_000, 99_000) as i32;
        let b = a + rng.range_i64(1, 1000) as i32;
        let ra = multiply_by_quantized_multiplier(a, m.quantized_multiplier, m.shift);
        let rb = multiply_by_quantized_multiplier(b, m.quantized_multiplier, m.shift);
        assert!(rb >= ra, "monotonicity: {a}->{ra}, {b}->{rb}");
    }
}

#[test]
fn prop_view_extraction_covers_input_exactly_once_stride_k() {
    // with stride == kernel (tiling), every input element appears in
    // exactly one view at exactly one slot (VALID padding)
    let mut rng = Prng::new(0x11);
    for _ in 0..50 {
        let k = rng.range_i64(1, 4) as usize;
        let oh = rng.range_i64(1, 4) as usize;
        let c = rng.range_i64(1, 3) as usize;
        let h = k * oh;
        let geo = ConvGeometry::new(h, h, c, k, k, k, k, Padding::Valid).unwrap();
        let input = rng.i8_vec(h * h * c);
        let mut seen = vec![0u32; input.len()];
        let mut view = vec![0i8; k * k * c];
        // mark coverage by summing views and comparing totals
        let mut total: i64 = 0;
        for oy in 0..geo.out_h {
            for ox in 0..geo.out_w {
                geo.extract_view(&input, oy, ox, 0, &mut view);
                total += view.iter().map(|&v| v as i64).sum::<i64>();
            }
        }
        let want: i64 = input.iter().map(|&v| v as i64).sum();
        assert_eq!(total, want);
        let _ = &mut seen;
    }
}

#[test]
fn prop_conv_1x1_equals_fc_per_pixel() {
    // structural identity: pointwise conv == FC applied per pixel
    let mut rng = Prng::new(0x77);
    for case in 0..50 {
        let (h, w, cin, cout) = (
            rng.range_i64(1, 5) as usize,
            rng.range_i64(1, 5) as usize,
            rng.range_i64(1, 6) as usize,
            rng.range_i64(1, 6) as usize,
        );
        let geo = ConvGeometry::new(h, w, cin, 1, 1, 1, 1, Padding::Valid).unwrap();
        let input = rng.i8_vec(h * w * cin);
        let filters = rng.i8_vec(cout * cin); // [Cout, 1, 1, Cin]
        let bias = rng.i32_vec(cout, -500, 500);
        let (s_x, z_x) = rand_qp(&mut rng);
        let (s_w, z_w) = rand_qp(&mut rng);
        let (s_y, z_y) = rand_qp(&mut rng);
        let colsum: Vec<i32> =
            (0..cout).map(|co| filters[co * cin..(co + 1) * cin].iter().map(|&v| v as i32).sum()).collect();
        let pc = PreComputed::fold(&bias, &colsum, cin, s_x, z_x, s_w, z_w, s_x * s_w, 0, s_y, z_y, FusedAct::None);
        let mut view = vec![0i8; cin];
        let mut conv_out = vec![0i8; h * w * cout];
        let packed = pack_conv2d(&filters, cout, cin);
        conv2d::conv2d_microflow(&input, &packed, &geo, z_x as i8, &pc, &mut view, &mut conv_out);
        // FC with weights [Cin, Cout] (transposed filters)
        let mut wfc = vec![0i8; cin * cout];
        for co in 0..cout {
            for ci in 0..cin {
                wfc[ci * cout + co] = filters[co * cin + ci];
            }
        }
        let mut fc_out = vec![0i8; cout];
        for px in 0..h * w {
            fully_connected::fully_connected_microflow(
                &input[px * cin..(px + 1) * cin],
                &wfc,
                cin,
                cout,
                &pc,
                &mut fc_out,
            );
            assert_eq!(&conv_out[px * cout..(px + 1) * cout], fc_out.as_slice(), "case {case} px {px}");
        }
    }
}

#[test]
fn prop_depthwise_mult1_matches_groupwise_conv() {
    // dw with multiplier 1 on a single channel == dense conv with Cin=1
    let mut rng = Prng::new(0x99);
    for case in 0..30 {
        let h = rng.range_i64(3, 8) as usize;
        let k = rng.range_i64(1, 3) as usize;
        let geo = ConvGeometry::new(h, h, 1, k, k, 1, 1, Padding::Same).unwrap();
        let input = rng.i8_vec(h * h);
        let filters = rng.i8_vec(k * k); // both layouts coincide at C=1
        let bias = rng.i32_vec(1, -500, 500);
        let (s_x, z_x) = rand_qp(&mut rng);
        let (s_w, z_w) = rand_qp(&mut rng);
        let (s_y, z_y) = rand_qp(&mut rng);
        let colsum = vec![filters.iter().map(|&v| v as i32).sum::<i32>()];
        let pc = PreComputed::fold(&bias, &colsum, k * k, s_x, z_x, s_w, z_w, s_x * s_w, 0, s_y, z_y, FusedAct::Relu);
        let mut view = vec![0i8; k * k];
        let mut a = vec![0i8; geo.out_h * geo.out_w];
        let mut b = vec![0i8; geo.out_h * geo.out_w];
        let packed = pack_conv2d(&filters, 1, k * k);
        conv2d::conv2d_microflow(&input, &packed, &geo, z_x as i8, &pc, &mut view, &mut a);
        // dw filters are channel-major for the microflow kernel; with
        // c_out == 1 both layouts coincide
        depthwise_conv2d::depthwise_conv2d_microflow(&input, &filters, &geo, 1, z_x as i8, &pc, &mut view, &mut b);
        assert_eq!(a, b, "case {case}");
    }
}

#[test]
fn prop_mfb_corruption_never_panics() {
    // robustness: random byte flips / truncations must yield Err, not UB
    let art = match common::artifacts() {
        Some(a) => a,
        None => return,
    };
    let bytes = std::fs::read(art.join("sine.mfb")).unwrap();
    let mut rng = Prng::new(0xDEAD);
    for _ in 0..300 {
        let mut bad = bytes.clone();
        match rng.below(3) {
            0 => {
                // flip a random byte
                let i = rng.below(bad.len() as u64) as usize;
                bad[i] ^= 1 << rng.below(8);
            }
            1 => {
                // truncate
                let cut = rng.below(bad.len() as u64) as usize;
                bad.truncate(cut);
            }
            _ => {
                // splice random garbage into the middle
                let i = rng.below(bad.len() as u64) as usize;
                for b in bad[i..].iter_mut().take(16) {
                    *b = rng.next_u64() as u8;
                }
            }
        }
        // parsing may succeed (benign flip) or fail — it must never panic,
        // and a parsed model must still compile or fail cleanly
        if let Ok(m) = MfbModel::parse(&bad) {
            let _ = CompiledModel::compile(&m, CompileOptions::default());
            let _ = ArenaPlan::plan(&m);
        }
    }
}

#[test]
fn prop_arena_placements_never_overlap_while_live() {
    let art = match common::artifacts() {
        Some(a) => a,
        None => return,
    };
    for name in common::MODELS {
        let m = MfbModel::load(art.join(format!("{name}.mfb"))).unwrap();
        let plan = ArenaPlan::plan(&m).unwrap();
        for (i, a) in plan.placements.iter().enumerate() {
            for b in plan.placements.iter().skip(i + 1) {
                let lifetimes_overlap = !(a.last_use < b.first_use || b.last_use < a.first_use);
                let memory_overlap = a.offset < b.offset + b.size && b.offset < a.offset + a.size;
                assert!(
                    !(lifetimes_overlap && memory_overlap),
                    "{name}: tensors {} and {} overlap",
                    a.tensor,
                    b.tensor
                );
            }
            assert!(a.offset + a.size <= plan.arena_size);
        }
    }
}

#[test]
fn prop_quantize_dequantize_error_bounded() {
    let mut rng = Prng::new(0x55);
    for _ in 0..2000 {
        let qp = QParams::new(rng.f32_range(1e-4, 1.0), rng.range_i64(-128, 127) as i32);
        let r = rng.f32_range(-50.0, 50.0);
        let q = qp.quantize(r);
        let back = qp.dequantize(q);
        // in-range values roundtrip within half a step; saturated values
        // clamp monotonically
        let lo = qp.dequantize(i8::MIN);
        let hi = qp.dequantize(i8::MAX);
        if r >= lo && r <= hi {
            assert!((back - r).abs() <= qp.scale * 0.5 + 1e-6, "{r} -> {q} -> {back}");
        } else {
            assert!(q == i8::MIN || q == i8::MAX);
        }
    }
}
