//! Integration: the simulator reproduces the paper's Fig. 9-11 / Table 6
//! *shapes* on the real compiled models (the calibration gate of
//! DESIGN.md §4 — these are the assertions that make the cost model's
//! constants meaningful rather than arbitrary).

mod common;

use microflow::compiler::plan::{CompileOptions, CompiledModel};
use microflow::format::mfb::MfbModel;
use microflow::interp::arena::ArenaPlan;
use microflow::sim::energy::inference_energy_wh;
use microflow::sim::mcu::by_name;
use microflow::sim::{self, Engine};

fn compiled(art: &std::path::Path, name: &str, paging: bool) -> CompiledModel {
    let m = MfbModel::load(art.join(format!("{name}.mfb"))).unwrap();
    CompiledModel::compile(&m, CompileOptions { paging, ..Default::default() }).unwrap()
}

#[test]
fn fig11_sine_ratio_about_10x() {
    let art = require_artifacts!();
    let c = compiled(&art, "sine", false);
    for mcu_name in ["ESP32", "nRF52840"] {
        let mcu = by_name(mcu_name).unwrap();
        let ratio = sim::inference_seconds(&c, mcu, Engine::Tflm)
            / sim::inference_seconds(&c, mcu, Engine::MicroFlow);
        assert!((5.0..25.0).contains(&ratio), "{mcu_name} sine ratio {ratio} (paper ~10x)");
    }
}

#[test]
fn fig11_speech_margins_match_paper() {
    let art = require_artifacts!();
    let c = compiled(&art, "speech", false);
    let esp = by_name("ESP32").unwrap();
    let nrf = by_name("nRF52840").unwrap();
    let r_esp = sim::inference_seconds(&c, esp, Engine::Tflm) / sim::inference_seconds(&c, esp, Engine::MicroFlow);
    let r_nrf = sim::inference_seconds(&c, nrf, Engine::Tflm) / sim::inference_seconds(&c, nrf, Engine::MicroFlow);
    // paper: +9% ESP32, +15% nRF52840
    assert!((1.02..1.30).contains(&r_esp), "ESP32 speech ratio {r_esp}");
    assert!((1.05..1.35).contains(&r_nrf), "nRF speech ratio {r_nrf}");
    assert!(r_nrf > r_esp);
}

#[test]
fn fig11_person_tflm_slightly_ahead() {
    let art = require_artifacts!();
    let c = compiled(&art, "person", false);
    for mcu_name in ["ESP32", "nRF52840"] {
        let mcu = by_name(mcu_name).unwrap();
        let ratio = sim::inference_seconds(&c, mcu, Engine::Tflm)
            / sim::inference_seconds(&c, mcu, Engine::MicroFlow);
        assert!((0.85..1.0).contains(&ratio), "{mcu_name} person ratio {ratio} (paper ~0.94)");
    }
}

#[test]
fn nrf_beats_esp32_wall_clock_despite_slower_clock() {
    let art = require_artifacts!();
    for name in ["speech", "person"] {
        let c = compiled(&art, name, false);
        let esp = sim::inference_seconds(&c, by_name("ESP32").unwrap(), Engine::MicroFlow);
        let nrf = sim::inference_seconds(&c, by_name("nRF52840").unwrap(), Engine::MicroFlow);
        assert!(esp / nrf > 2.5, "{name}: ESP32/nRF = {}", esp / nrf);
    }
}

#[test]
fn fig9_anchor_sine_on_atmega_matches_paper_numbers() {
    // paper: 13.619 kB Flash, 1.706 kB RAM — we assert the same class
    let art = require_artifacts!();
    let c = compiled(&art, "sine", true);
    let atmega = by_name("ATmega328").unwrap();
    let fp = sim::memory_model::microflow_footprint(&c, atmega);
    assert!((9_000..17_000).contains(&fp.flash), "flash {} (paper 13.6 kB)", fp.flash);
    assert!((1_200..2_048).contains(&fp.ram), "ram {} (paper 1.7 kB)", fp.ram);
    assert!(sim::memory_model::fits(atmega, Engine::MicroFlow, fp).is_ok());
}

#[test]
fn fig9_anchor_tflm_ram_on_nrf() {
    // paper: TFLM sine RAM 45.728 kB vs MicroFlow 5.296 kB on nRF52840
    let art = require_artifacts!();
    let m = MfbModel::load(art.join("sine.mfb")).unwrap();
    let arena = ArenaPlan::plan(&m).unwrap();
    let nrf = by_name("nRF52840").unwrap();
    let tf = sim::memory_model::tflm_footprint(&m, &arena, nrf);
    let c = compiled(&art, "sine", false);
    let mf = sim::memory_model::microflow_footprint(&c, nrf);
    assert!((38_000..55_000).contains(&tf.ram), "tflm ram {} (paper 45.7 kB)", tf.ram);
    assert!((4_000..8_000).contains(&mf.ram), "mf ram {} (paper 5.3 kB)", mf.ram);
}

#[test]
fn fig10_person_saving_exceeds_15_percent() {
    let art = require_artifacts!();
    let m = MfbModel::load(art.join("person.mfb")).unwrap();
    let arena = ArenaPlan::plan(&m).unwrap();
    let esp = by_name("ESP32").unwrap();
    let c = compiled(&art, "person", false);
    let mf = sim::memory_model::microflow_footprint(&c, esp);
    let tf = sim::memory_model::tflm_footprint(&m, &arena, esp);
    let saving = 1.0 - mf.flash as f64 / tf.flash as f64;
    assert!(saving > 0.15, "person flash saving {saving} (paper >15%)");
}

#[test]
fn person_does_not_fit_small_devices() {
    // paper Sec. 6.3: flashing the person detector on the ATmega328 fails
    // with "not enough memory". (The paper also excludes the LM3S6965
    // because its 301 kB container exceeds 256 kB Flash; our leaner MFB
    // container is 219 kB, which genuinely fits the 256 kB part — noted
    // in EXPERIMENTS.md §E5 as a substitution artifact.)
    let art = require_artifacts!();
    let c = compiled(&art, "person", false);
    let mcu = by_name("ATmega328").unwrap();
    let fp = sim::memory_model::microflow_footprint(&c, mcu);
    assert!(
        sim::memory_model::fits(mcu, Engine::MicroFlow, fp).is_err(),
        "person must NOT fit ATmega328"
    );
    // speech is likewise excluded from the ATmega328 (paper Sec. 6.2.2)
    let c = compiled(&art, "speech", false);
    let fp = sim::memory_model::microflow_footprint(&c, mcu);
    assert!(sim::memory_model::fits(mcu, Engine::MicroFlow, fp).is_err());
}

#[test]
fn sine_on_atmega_needs_paging() {
    // the Sec. 4.3 narrative on the real model: unpaged staging overflows
    // the 2 kB AVR RAM, paging makes it fit
    let art = require_artifacts!();
    let atmega = by_name("ATmega328").unwrap();
    let unpaged = compiled(&art, "sine", false);
    let fp_u = sim::memory_model::microflow_footprint(&unpaged, atmega);
    assert!(
        sim::memory_model::fits(atmega, Engine::MicroFlow, fp_u).is_err(),
        "unpaged sine should overflow the 2 kB AVR ({} B)",
        fp_u.ram
    );
    let paged = compiled(&art, "sine", true);
    let fp_p = sim::memory_model::microflow_footprint(&paged, atmega);
    assert!(sim::memory_model::fits(atmega, Engine::MicroFlow, fp_p).is_ok(), "{fp_p:?}");
}

#[test]
fn table6_energy_shape() {
    let art = require_artifacts!();
    for (name, mf_wins) in [("sine", true), ("speech", true), ("person", false)] {
        let c = compiled(&art, name, false);
        for mcu_name in ["ESP32", "nRF52840"] {
            let mcu = by_name(mcu_name).unwrap();
            let e_mf = inference_energy_wh(&c, mcu, Engine::MicroFlow);
            let e_tf = inference_energy_wh(&c, mcu, Engine::Tflm);
            assert_eq!(e_mf < e_tf, mf_wins, "{name} on {mcu_name}: {e_mf} vs {e_tf}");
        }
    }
}

#[test]
fn paging_trades_time_for_ram_on_sine() {
    let art = require_artifacts!();
    let unpaged = compiled(&art, "sine", false);
    let paged = compiled(&art, "sine", true);
    let atmega = by_name("ATmega328").unwrap();
    let t_u = sim::inference_seconds(&unpaged, atmega, Engine::MicroFlow);
    let t_p = sim::inference_seconds(&paged, atmega, Engine::MicroFlow);
    assert!(t_p > t_u, "paging must cost time");
    let r_u = sim::memory_model::microflow_footprint(&unpaged, atmega).ram;
    let r_p = sim::memory_model::microflow_footprint(&paged, atmega).ram;
    assert!(r_p <= r_u, "paging must not increase RAM ({r_p} vs {r_u})");
}

#[test]
fn stack_guard_reproduces_sec44() {
    // Sec. 4.4: on Cortex-M with flip-link an overflow becomes a handled
    // hardware exception; with the default layout (or off Cortex-M) it is
    // silent static-data corruption. Exercised with the person model's
    // working set against a shrunken region.
    use microflow::sim::stack_guard::{evaluate, microflow_layout, StackLayout, StackOutcome};
    let art = require_artifacts!();
    let c = compiled(&art, "person", false);
    let nrf = by_name("nRF52840").unwrap();
    let statics = 220 * 1024; // pretend nearly all RAM is statics
    let demand = c.memory.peak;
    let flipped = evaluate(nrf, StackLayout::Flipped, statics, demand);
    let default = evaluate(nrf, StackLayout::Default, statics, demand);
    assert!(matches!(flipped, StackOutcome::DetectedOverflow { .. }), "{flipped:?}");
    assert!(matches!(default, StackOutcome::SilentCorruption { .. }), "{default:?}");
    assert_eq!(microflow_layout(nrf), StackLayout::Flipped);
    // the ESP32 (Xtensa) has no flip-link: flipped layout does not help
    let esp = by_name("ESP32").unwrap();
    let esp_flipped = evaluate(esp, StackLayout::Flipped, 320 * 1024, demand);
    assert!(!esp_flipped.is_safe());
}
