//! Packed kernels are **bit-identical** to the unpacked references.
//!
//! `compiler::pack` + `kernels::microkernel` replaced the naive Eq. 3/6/9
//! loop nests; this suite keeps copies of the *old unpacked kernels* as
//! oracles and holds the packed production kernels to exact equality
//! (`assert_eq!`, not within-one-unit — integer dot products are
//! associative, so layout can never change a bit) across randomized
//! shapes: `c_out % NR != 0` tails, 1x1 pointwise, SAME/VALID padding,
//! stride 2, depth multipliers, and FC widths around every panel/tail
//! split. All cases are seeded (`util::Prng`) and artifact-free.
//!
//! Since the kernel-backend layer landed, each sweep runs once per
//! *available* backend (`microkernel::backend::available()` — always
//! `scalar`, plus AVX2/NEON where the host CPU reports them), re-seeded
//! so every backend sees the identical case mix. Dedicated shapes
//! straddle the SIMD stride remainders (`kkc ∈ {1, 7, 9, 31}` against
//! the 8-wide panel and 16-wide contiguous walks) alongside the
//! existing `c_out % NR` tails — the remainder seams are where SIMD
//! bugs live.

use microflow::compiler::pack::{self, NR};
use microflow::format::mfb::Padding;
use microflow::kernels::microkernel::backend::{self, KernelBackend};
use microflow::kernels::view::ConvGeometry;
use microflow::kernels::{conv2d, depthwise_conv2d, fully_connected};
use microflow::tensor::quant::{requant_float, FusedAct, PreComputed};
use microflow::util::Prng;

const CASES: usize = 120;

/// Every backend selectable on this host, scalar first. Each must
/// resolve — `available()` promising a name that `resolve()` rejects is
/// itself a bug worth failing on.
fn backends() -> Vec<&'static dyn KernelBackend> {
    backend::available()
        .into_iter()
        .map(|n| backend::resolve(n).expect("available backend must resolve"))
        .collect()
}

/// Random qparams in realistic PTQ ranges; z_w drawn from a range that
/// includes 0 so both the fused-viewsum and no-viewsum paths run.
fn rand_qp(rng: &mut Prng) -> (f32, i32) {
    (rng.f32_range(0.005, 0.2), rng.range_i64(-20, 20) as i32)
}

fn fold(rng: &mut Prng, bias: &[i32], colsum: &[i32], k: usize) -> (PreComputed, i32) {
    let (s_x, z_x) = rand_qp(rng);
    let (s_w, z_w) = rand_qp(rng);
    let (s_y, z_y) = rand_qp(rng);
    let act = match rng.below(3) {
        0 => FusedAct::None,
        1 => FusedAct::Relu,
        _ => FusedAct::Relu6,
    };
    (PreComputed::fold(bias, colsum, k, s_x, z_x, s_w, z_w, s_x * s_w, 0, s_y, z_y, act), z_x)
}

/// ORACLE: the pre-pack Conv2D microflow kernel, verbatim — unpacked
/// `[Cout, KH*KW*Cin]` filters, per-channel scalar accumulator, separate
/// view-sum pass, view extracted at every position.
#[allow(clippy::too_many_arguments)]
fn conv2d_unpacked_reference(
    input: &[i8],
    filters: &[i8],
    geo: &ConvGeometry,
    c_out: usize,
    z_x: i8,
    pc: &PreComputed,
    view: &mut [i8],
    out: &mut [i8],
) {
    let kkc = geo.k_h * geo.k_w * geo.in_c;
    for oy in 0..geo.out_h {
        for ox in 0..geo.out_w {
            geo.extract_view(input, oy, ox, z_x, view);
            let viewsum: i32 = if pc.z_w != 0 { view.iter().map(|&v| v as i32).sum() } else { 0 };
            let base = (oy * geo.out_w + ox) * c_out;
            for co in 0..c_out {
                let f = &filters[co * kkc..(co + 1) * kkc];
                let mut dot = 0i32;
                for (v, w) in view.iter().zip(f) {
                    dot += *v as i32 * *w as i32;
                }
                let acc = dot - pc.z_w * viewsum - pc.w_zp_term[co] + pc.kzxzw;
                out[base + co] =
                    requant_float(acc, pc.const_bias[co], pc.scale_ratio, pc.act_min, pc.act_max);
            }
        }
    }
}

/// ORACLE: the pre-pack FullyConnected microflow kernel — column-sweep
/// accumulation over `[K, N]` rows with a full-width accumulator vector.
fn fc_unpacked_reference(x: &[i8], w: &[i8], k: usize, n: usize, pc: &PreComputed, out: &mut [i8]) {
    assert_eq!((x.len(), w.len()), (k, k * n));
    let rowsum: i32 = if pc.z_w != 0 { x.iter().map(|&v| v as i32).sum() } else { 0 };
    let mut acc = vec![0i32; n];
    for (row, &xi) in w.chunks_exact(n).zip(x.iter()) {
        let xv = xi as i32;
        for (a, &wv) in acc.iter_mut().zip(row) {
            *a += xv * wv as i32;
        }
    }
    for j in 0..n {
        let a = acc[j] - pc.z_w * rowsum - pc.w_zp_term[j] + pc.kzxzw;
        out[j] = requant_float(a, pc.const_bias[j], pc.scale_ratio, pc.act_min, pc.act_max);
    }
}

/// ORACLE: DepthwiseConv2D straight off the *container* `[KH*KW, Cout]`
/// layout — what the kernel computed before the compile-time transpose
/// (same arithmetic, strided filter reads).
#[allow(clippy::too_many_arguments)]
fn dw_container_reference(
    input: &[i8],
    filters: &[i8], // [KH*KW, Cout]
    geo: &ConvGeometry,
    mult: usize,
    z_x: i8,
    pc: &PreComputed,
    view: &mut [i8],
    out: &mut [i8],
) {
    let c_in = geo.in_c;
    let c_out = c_in * mult;
    let kk = geo.k_h * geo.k_w;
    for oy in 0..geo.out_h {
        for ox in 0..geo.out_w {
            geo.extract_view(input, oy, ox, z_x, view);
            let base = (oy * geo.out_w + ox) * c_out;
            for ci in 0..c_in {
                let xsum: i32 = if pc.z_w != 0 {
                    (0..kk).map(|t| view[t * c_in + ci] as i32).sum()
                } else {
                    0
                };
                for m in 0..mult {
                    let co = ci * mult + m;
                    let mut dot = 0i32;
                    for t in 0..kk {
                        dot += view[t * c_in + ci] as i32 * filters[t * c_out + co] as i32;
                    }
                    let acc = dot - pc.z_w * xsum - pc.w_zp_term[co] + pc.kzxzw;
                    out[base + co] =
                        requant_float(acc, pc.const_bias[co], pc.scale_ratio, pc.act_min, pc.act_max);
                }
            }
        }
    }
}

#[test]
fn packed_conv2d_bit_identical_to_unpacked_reference() {
    for kb in backends() {
        conv2d_sweep(kb);
    }
}

fn conv2d_sweep(kb: &'static dyn KernelBackend) {
    let mut rng = Prng::new(0x9AC4);
    let mut tails_seen = [false; NR];
    for case in 0..CASES {
        let (h, w) = (rng.range_i64(2, 9) as usize, rng.range_i64(2, 9) as usize);
        let c_in = rng.range_i64(1, 6) as usize;
        // force 1x1 pointwise on a third of the cases
        let (kh, kw) = if case % 3 == 0 {
            (1, 1)
        } else {
            (rng.range_i64(1, h as i64) as usize, rng.range_i64(1, w as i64) as usize)
        };
        let stride = rng.range_i64(1, 2) as usize;
        let padding = if rng.below(2) == 0 { Padding::Same } else { Padding::Valid };
        // 1..=9 sweeps every c_out % NR tail, incl. whole-panel widths
        let c_out = rng.range_i64(1, 9) as usize;
        tails_seen[c_out % NR] = true;
        let geo = ConvGeometry::new(h, w, c_in, kh, kw, stride, stride, padding).unwrap();
        let kkc = kh * kw * c_in;

        let input = rng.i8_vec(h * w * c_in);
        let filters = rng.i8_vec(c_out * kkc);
        let bias = rng.i32_vec(c_out, -1000, 1000);
        let colsum: Vec<i32> = (0..c_out)
            .map(|co| filters[co * kkc..(co + 1) * kkc].iter().map(|&v| v as i32).sum())
            .collect();
        let (pc, z_x) = fold(&mut rng, &bias, &colsum, kkc);

        let mut view = vec![0i8; kkc];
        let mut want = vec![0i8; geo.out_h * geo.out_w * c_out];
        conv2d_unpacked_reference(&input, &filters, &geo, c_out, z_x as i8, &pc, &mut view, &mut want);

        let packed = pack::pack_conv2d(&filters, c_out, kkc);
        let mut got = vec![0i8; want.len()];
        conv2d::conv2d_microflow_with(kb, &input, &packed, &geo, z_x as i8, &pc, &mut view, &mut got);

        assert_eq!(
            got,
            want,
            "[{}] case {case}: {h}x{w}x{c_in} k{kh}x{kw} s{stride} {padding:?} cout {c_out}",
            kb.name()
        );
    }
    assert!(tails_seen.iter().all(|&t| t), "case mix must cover every c_out % NR tail");
}

#[test]
fn conv2d_simd_stride_remainders_bit_identical() {
    // kkc ∈ {1, 7, 9, 31}: pointwise layers whose reduction length
    // straddles the SIMD strides (below one 8-wide step, one step ± 1,
    // just under four steps) — the panel-walk remainder seam. c_out = 5
    // keeps the c_out % NR tail panel in play at the same time, and the
    // SAME-padded 3x3 case makes the boundary (staged-view) path cross
    // the same remainders.
    for kb in backends() {
        let mut rng = Prng::new(0x51D4);
        for &c_in in &[1usize, 7, 9, 31] {
            for &(kh, kw, padding) in &[(1usize, 1usize, Padding::Valid), (3, 3, Padding::Same)] {
                let (h, w, c_out) = (4usize, 5usize, 5usize);
                let geo = ConvGeometry::new(h, w, c_in, kh, kw, 1, 1, padding).unwrap();
                let kkc = kh * kw * c_in;
                let input = rng.i8_vec(h * w * c_in);
                let filters = rng.i8_vec(c_out * kkc);
                let bias = rng.i32_vec(c_out, -1000, 1000);
                let colsum: Vec<i32> = (0..c_out)
                    .map(|co| filters[co * kkc..(co + 1) * kkc].iter().map(|&v| v as i32).sum())
                    .collect();
                let (pc, z_x) = fold(&mut rng, &bias, &colsum, kkc);

                let mut view = vec![0i8; kkc];
                let mut want = vec![0i8; geo.out_h * geo.out_w * c_out];
                conv2d_unpacked_reference(
                    &input, &filters, &geo, c_out, z_x as i8, &pc, &mut view, &mut want,
                );
                let packed = pack::pack_conv2d(&filters, c_out, kkc);
                let mut got = vec![0i8; want.len()];
                conv2d::conv2d_microflow_with(
                    kb, &input, &packed, &geo, z_x as i8, &pc, &mut view, &mut got,
                );
                assert_eq!(got, want, "[{}] kkc {kkc} k{kh}x{kw}", kb.name());
            }
        }
    }
}

#[test]
fn unknown_backend_name_fails_loudly_not_silently() {
    // the env override exists to FORCE a backend in tests/CI; a typo
    // must never silently measure something else
    let err = backend::resolve("sse9-totally-real").unwrap_err();
    assert!(err.contains("unknown kernel backend"), "{err}");
    assert!(err.contains("scalar"), "must list valid names: {err}");
}

#[test]
fn packed_fc_bit_identical_to_unpacked_reference() {
    for kb in backends() {
        fc_sweep(kb);
    }
}

fn fc_sweep(kb: &'static dyn KernelBackend) {
    let mut rng = Prng::new(0xFC04);
    for case in 0..CASES {
        // the randomized k plus the fixed remainder set: the FC column
        // walk pairs rows two at a time, so odd k and the {1,7,9,31}
        // stride-straddlers all hit the SIMD seam
        let k = match case % 5 {
            0 => 1,
            1 => 7,
            2 => 9,
            3 => 31,
            _ => rng.range_i64(1, 80) as usize,
        };
        // 1..=13 sweeps pure-tail, exact-panel and panel+tail widths
        let n = rng.range_i64(1, 13) as usize;
        let x = rng.i8_vec(k);
        let w = rng.i8_vec(k * n);
        let bias = rng.i32_vec(n, -2000, 2000);
        let colsum: Vec<i32> = (0..n).map(|j| (0..k).map(|i| w[i * n + j] as i32).sum()).collect();
        let (pc, _) = fold(&mut rng, &bias, &colsum, k);

        let mut want = vec![0i8; n];
        fc_unpacked_reference(&x, &w, k, n, &pc, &mut want);
        let mut got = vec![0i8; n];
        fully_connected::fully_connected_microflow_with(kb, &x, &w, k, n, &pc, &mut got);
        assert_eq!(got, want, "[{}] case {case}: k {k} n {n}", kb.name());
    }
}

#[test]
fn packed_depthwise_bit_identical_to_container_reference() {
    for kb in backends() {
        depthwise_sweep(kb);
    }
}

fn depthwise_sweep(kb: &'static dyn KernelBackend) {
    let mut rng = Prng::new(0xD304);
    for case in 0..CASES {
        let (h, w) = (rng.range_i64(3, 9) as usize, rng.range_i64(3, 9) as usize);
        // c_in == 1 is the contiguous (stride-1) dot SIMD backends take;
        // force it on a quarter of the cases so the vector path and its
        // kk % 8 remainder get steady coverage alongside the strided path
        let c_in = if case % 4 == 0 { 1 } else { rng.range_i64(1, 5) as usize };
        let (kh, kw) = (rng.range_i64(1, 3) as usize, rng.range_i64(1, 3) as usize);
        let stride = rng.range_i64(1, 2) as usize;
        let padding = if rng.below(2) == 0 { Padding::Same } else { Padding::Valid };
        let mult = rng.range_i64(1, 3) as usize;
        let c_out = c_in * mult;
        let kk = kh * kw;
        let geo = ConvGeometry::new(h, w, c_in, kh, kw, stride, stride, padding).unwrap();

        let input = rng.i8_vec(h * w * c_in);
        let filters = rng.i8_vec(kk * c_out); // container layout [KK, Cout]
        let bias = rng.i32_vec(c_out, -800, 800);
        let colsum: Vec<i32> =
            (0..c_out).map(|co| (0..kk).map(|t| filters[t * c_out + co] as i32).sum()).collect();
        let (pc, z_x) = fold(&mut rng, &bias, &colsum, kk);

        let mut view = vec![0i8; kk * c_in];
        let mut want = vec![0i8; geo.out_h * geo.out_w * c_out];
        dw_container_reference(&input, &filters, &geo, mult, z_x as i8, &pc, &mut view, &mut want);

        let packed = pack::pack_depthwise(&filters, kk, c_out);
        let mut got = vec![0i8; want.len()];
        depthwise_conv2d::depthwise_conv2d_microflow_with(
            kb, &input, &packed, &geo, mult, z_x as i8, &pc, &mut view, &mut got,
        );
        assert_eq!(
            got,
            want,
            "[{}] case {case}: {h}x{w}x{c_in} k{kh}x{kw} s{stride} mult {mult}",
            kb.name()
        );
    }
}

#[test]
fn depthwise_large_contiguous_window_bit_identical() {
    // single-channel 5x7 window (kk = 35, not a multiple of the 8-wide
    // contiguous dot) with a depth multiplier — the speech-model shape
    // family for the stride-1 SIMD path, sized to cross several vector
    // steps plus a remainder
    for kb in backends() {
        let mut rng = Prng::new(0xD355);
        let (h, w, c_in, kh, kw, mult) = (9usize, 9usize, 1usize, 5usize, 7usize, 3usize);
        let c_out = c_in * mult;
        let kk = kh * kw;
        let geo = ConvGeometry::new(h, w, c_in, kh, kw, 1, 1, Padding::Same).unwrap();
        let input = rng.i8_vec(h * w * c_in);
        let filters = rng.i8_vec(kk * c_out);
        let bias = rng.i32_vec(c_out, -800, 800);
        let colsum: Vec<i32> =
            (0..c_out).map(|co| (0..kk).map(|t| filters[t * c_out + co] as i32).sum()).collect();
        let (pc, z_x) = fold(&mut rng, &bias, &colsum, kk);

        let mut view = vec![0i8; kk * c_in];
        let mut want = vec![0i8; geo.out_h * geo.out_w * c_out];
        dw_container_reference(&input, &filters, &geo, mult, z_x as i8, &pc, &mut view, &mut want);
        let packed = pack::pack_depthwise(&filters, kk, c_out);
        let mut got = vec![0i8; want.len()];
        depthwise_conv2d::depthwise_conv2d_microflow_with(
            kb, &input, &packed, &geo, mult, z_x as i8, &pc, &mut view, &mut got,
        );
        assert_eq!(got, want, "[{}] kk {kk}", kb.name());
    }
}
