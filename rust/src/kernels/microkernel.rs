//! Register-tiled quantized micro-kernel core (DESIGN.md S9; the paper's
//! compile-time pre-processing claim applied to *layout*, not just
//! constants).
//!
//! Every weighted MicroFlow kernel reduces to walks of one shape: an i8
//! input segment against a weight **panel** of [`NR`] output channels laid
//! out `[k][NR]` (channel-interleaved, contiguous in the inner loop). One
//! walk keeps `NR` interleaved i32 accumulators in registers, so each
//! input byte is loaded **once** and feeds `NR` output channels — the
//! instruction-level-parallelism angle the naive Eq. 3/6 loop nests leave
//! on the table (one scalar accumulator, input re-read per channel).
//!
//! The panels are built offline by [`crate::compiler::pack`]; the
//! contract between the two sides is this module's types.
//!
//! ## Bit-exactness
//!
//! All accumulation is exact i32 arithmetic on i8 products (max
//! `|x*w| = 16384` per term; reduction lengths in this repo stay far
//! below `i32::MAX / 16384`), and integer addition is associative and
//! commutative — so a register-tiled walk produces **bit-identical**
//! accumulators to the scalar reference order, and the packed kernels
//! inherit the engine's exact-equality contract with the JAX golden path
//! (`tests/pack_equivalence.rs` and the cross-engine conformance suite
//! hold them to `assert_eq!`, not within-one-unit).

/// Runtime-selected kernel backends (scalar reference + `std::arch`
/// SIMD), all bit-identical over this module's walks.
pub mod backend;
#[cfg(target_arch = "aarch64")]
mod simd_aarch64;
#[cfg(target_arch = "x86_64")]
mod simd_x86;

/// Panel width: output channels computed per micro-kernel walk. Four i32
/// accumulators fit the register file of every target this repo models
/// (and SIMD lanes on the host); the compiler's packing pass and the cost
/// model both derive their shapes from this one constant.
pub const NR: usize = 4;

/// Conv2D/pointwise filters re-laid by the compiler into output-channel
/// panels: `data` is `[ceil(c_out/NR)][kkc][NR]` with `kkc = KH*KW*Cin`.
/// Lane `r` of panel `p` holds output channel `p*NR + r`; tail lanes past
/// `c_out` are zero-filled (computed but never written back).
#[derive(Clone, Debug)]
pub struct PackedConvFilters {
    pub c_out: usize,
    pub kkc: usize,
    /// Packed panel image (the step's flash payload, padded tail included).
    pub data: Vec<i8>,
}

impl PackedConvFilters {
    /// Number of `NR`-wide panels (tail panel included).
    pub fn panels(&self) -> usize {
        self.c_out.div_ceil(NR)
    }

    /// Panel `p` as a contiguous `[kkc][NR]` slice.
    ///
    /// An out-of-range `p` (or a short/corrupted panel image) fails
    /// *here*, as a named precondition, rather than as an opaque slice
    /// panic deep in the walk. These are the same invariants the
    /// certifier proves statically (`compiler::verify`, V104) and
    /// `compiler::pack` asserts at construction — this is the last line
    /// of the producer/prover/consumer triangle.
    #[inline]
    pub fn panel(&self, p: usize) -> &[i8] {
        debug_assert!(p < self.panels(), "panel {p} out of range ({} panels)", self.panels());
        debug_assert_eq!(self.data.len(), self.panels() * self.kkc * NR, "panel image size");
        let stride = self.kkc * NR;
        &self.data[p * stride..(p + 1) * stride]
    }

    /// Real (unpadded) output channels in panel `p`: `NR` except possibly
    /// the last panel.
    #[inline]
    pub fn panel_width(&self, p: usize) -> usize {
        (self.c_out - p * NR).min(NR)
    }

    /// Flash bytes of the packed image (padded tail lanes ship too).
    pub fn flash_bytes(&self) -> usize {
        self.data.len()
    }
}

/// The FullyConnected tail-aware panel view over `[K, N]` weights:
/// `(full_panels, tail_width)` with `full_panels = n / NR` register-tiled
/// [`dot4_cols`] walks and one `tail_width = n % NR` [`dot_cols`] walk.
/// Shared by the kernel and the compiler (`compiler::pack` re-exports
/// it), so the two sides cannot disagree about the split.
pub fn fc_panels(n: usize) -> (usize, usize) {
    (n / NR, n % NR)
}

/// One micro-kernel walk: `acc[r] += Σ_k seg[k] * panel[k*NR + r]`.
///
/// `seg` is a contiguous input segment (a full extracted view, a borrowed
/// interior row, or a pointwise pixel); `panel` is the matching `[k][NR]`
/// panel slice. Accumulates so callers can stitch segmented walks (the
/// interior-row conv path) into one set of accumulators.
#[inline(always)]
pub fn dot4(seg: &[i8], panel: &[i8], acc: &mut [i32; NR]) {
    debug_assert_eq!(panel.len(), seg.len() * NR);
    for (x, w) in seg.iter().zip(panel.chunks_exact(NR)) {
        let xv = *x as i32;
        acc[0] += xv * w[0] as i32;
        acc[1] += xv * w[1] as i32;
        acc[2] += xv * w[2] as i32;
        acc[3] += xv * w[3] as i32;
    }
}

/// [`dot4`] with the data-dependent view sum (the `z_W` correction term of
/// Eq. 6) folded into the same walk — the kernels run this on the first
/// panel only and reuse the sum for the rest, deleting the separate
/// view-summation pass the unpacked kernels paid.
#[inline(always)]
pub fn dot4_sum(seg: &[i8], panel: &[i8], acc: &mut [i32; NR], sum: &mut i32) {
    debug_assert_eq!(panel.len(), seg.len() * NR);
    for (x, w) in seg.iter().zip(panel.chunks_exact(NR)) {
        let xv = *x as i32;
        *sum += xv;
        acc[0] += xv * w[0] as i32;
        acc[1] += xv * w[1] as i32;
        acc[2] += xv * w[2] as i32;
        acc[3] += xv * w[3] as i32;
    }
}

/// FullyConnected panel walk over `[K, N]` row-major weights kept in
/// container layout: columns `j0..j0+NR` (each row's `NR` weights are
/// contiguous), `acc[r] += Σ_i x[i] * w[i*n + j0 + r]`.
#[inline(always)]
pub fn dot4_cols(x: &[i8], w: &[i8], n: usize, j0: usize, acc: &mut [i32; NR]) {
    debug_assert!(j0 + NR <= n);
    debug_assert_eq!(w.len(), x.len() * n);
    for (i, &xi) in x.iter().enumerate() {
        let xv = xi as i32;
        let row = &w[i * n + j0..i * n + j0 + NR];
        acc[0] += xv * row[0] as i32;
        acc[1] += xv * row[1] as i32;
        acc[2] += xv * row[2] as i32;
        acc[3] += xv * row[3] as i32;
    }
}

/// Tail-aware variant of [`dot4_cols`] for the last `width < NR` columns
/// (runs once per FC call; lanes `width..NR` stay untouched).
#[inline(always)]
pub fn dot_cols(x: &[i8], w: &[i8], n: usize, j0: usize, width: usize, acc: &mut [i32; NR]) {
    debug_assert!(width <= NR && j0 + width <= n);
    debug_assert_eq!(w.len(), x.len() * n);
    for (i, &xi) in x.iter().enumerate() {
        let xv = xi as i32;
        let row = &w[i * n + j0..i * n + j0 + width];
        for (a, &wv) in acc[..width].iter_mut().zip(row) {
            *a += xv * wv as i32;
        }
    }
}

/// Depthwise per-channel walk: `Σ_t xs[t*stride] * w[t]` over `w.len()`
/// taps. `stride` is the input channel count (`1` for single-channel
/// inputs, where the walk degenerates to a contiguous dot product — the
/// case SIMD backends accelerate).
#[inline(always)]
pub fn dot_strided(xs: &[i8], stride: usize, w: &[i8]) -> i32 {
    debug_assert!(stride > 0);
    debug_assert!(w.is_empty() || (w.len() - 1) * stride < xs.len());
    let mut dot = 0i32;
    for (t, &wv) in w.iter().enumerate() {
        dot += xs[t * stride] as i32 * wv as i32;
    }
    dot
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    /// Scalar reference: one accumulator per channel, input re-read.
    fn dot_scalar(seg: &[i8], weights: &[i8], lanes: usize) -> Vec<i32> {
        (0..lanes)
            .map(|r| seg.iter().enumerate().map(|(k, &x)| x as i32 * weights[k * lanes + r] as i32).sum())
            .collect()
    }

    #[test]
    fn dot4_matches_scalar_reference() {
        let mut rng = Prng::new(1);
        for len in [1usize, 3, 16, 127] {
            let seg = rng.i8_vec(len);
            let panel = rng.i8_vec(len * NR);
            let mut acc = [0i32; NR];
            dot4(&seg, &panel, &mut acc);
            assert_eq!(acc.to_vec(), dot_scalar(&seg, &panel, NR), "len {len}");
        }
    }

    #[test]
    fn dot4_sum_folds_the_segment_sum() {
        let mut rng = Prng::new(2);
        let seg = rng.i8_vec(33);
        let panel = rng.i8_vec(33 * NR);
        let (mut a, mut b) = ([0i32; NR], [0i32; NR]);
        let mut sum = 0i32;
        dot4(&seg, &panel, &mut a);
        dot4_sum(&seg, &panel, &mut b, &mut sum);
        assert_eq!(a, b);
        assert_eq!(sum, seg.iter().map(|&v| v as i32).sum::<i32>());
    }

    #[test]
    fn dot4_accumulates_across_segments() {
        // stitching two half-walks must equal one full walk (the
        // interior-row conv path relies on this)
        let mut rng = Prng::new(3);
        let seg = rng.i8_vec(24);
        let panel = rng.i8_vec(24 * NR);
        let mut whole = [0i32; NR];
        dot4(&seg, &panel, &mut whole);
        let mut halves = [0i32; NR];
        dot4(&seg[..10], &panel[..10 * NR], &mut halves);
        dot4(&seg[10..], &panel[10 * NR..], &mut halves);
        assert_eq!(whole, halves);
    }

    #[test]
    fn dot4_cols_matches_scalar_columns() {
        let mut rng = Prng::new(4);
        let (k, n) = (19usize, 12usize);
        let x = rng.i8_vec(k);
        let w = rng.i8_vec(k * n);
        for j0 in [0usize, 4, 8] {
            let mut acc = [0i32; NR];
            dot4_cols(&x, &w, n, j0, &mut acc);
            for r in 0..NR {
                let want: i32 = (0..k).map(|i| x[i] as i32 * w[i * n + j0 + r] as i32).sum();
                assert_eq!(acc[r], want, "j0 {j0} lane {r}");
            }
        }
    }

    #[test]
    fn dot_cols_handles_every_tail_width() {
        let mut rng = Prng::new(5);
        let (k, n) = (11usize, 7usize);
        let x = rng.i8_vec(k);
        let w = rng.i8_vec(k * n);
        for width in 1..=3usize {
            let j0 = n - width;
            let mut acc = [99i32; NR]; // sentinel: untouched lanes stay 99
            acc[..width].fill(0);
            dot_cols(&x, &w, n, j0, width, &mut acc);
            for r in 0..width {
                let want: i32 = (0..k).map(|i| x[i] as i32 * w[i * n + j0 + r] as i32).sum();
                assert_eq!(acc[r], want);
            }
            for r in width..NR {
                assert_eq!(acc[r], 99, "lane {r} must stay untouched");
            }
        }
    }

    #[test]
    fn packed_filters_panel_accessors() {
        // c_out = 6 -> 2 panels, tail width 2
        let pf = PackedConvFilters { c_out: 6, kkc: 3, data: vec![0; 2 * 3 * NR] };
        assert_eq!(pf.panels(), 2);
        assert_eq!(pf.panel_width(0), 4);
        assert_eq!(pf.panel_width(1), 2);
        assert_eq!(pf.panel(1).len(), 3 * NR);
        assert_eq!(pf.flash_bytes(), 24);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn out_of_range_panel_index_fails_the_named_precondition() {
        let pf = PackedConvFilters { c_out: 6, kkc: 3, data: vec![0; 2 * 3 * NR] };
        let _ = pf.panel(2);
    }

    #[test]
    fn dot_strided_matches_the_naive_walk() {
        let mut rng = Prng::new(6);
        for &(taps, stride) in &[(5usize, 3usize), (8, 1), (1, 4), (10, 2)] {
            let xs = rng.i8_vec((taps - 1) * stride + 1);
            let w = rng.i8_vec(taps);
            let want: i32 = (0..taps).map(|t| xs[t * stride] as i32 * w[t] as i32).sum();
            assert_eq!(dot_strided(&xs, stride, &w), want, "taps {taps} stride {stride}");
        }
    }
}
