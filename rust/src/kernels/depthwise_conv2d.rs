//! DepthwiseConv2D kernels — Eq. (9) / Appendix A.3 (DESIGN.md S9).
//!
//! Filters `[KH, KW, Cout]` row-major with `Cout = Cin * depth_multiplier`
//! (the TFLite `[1, KH, KW, Cout]` layout with the leading 1 dropped).
//! Output channel `co` convolves input channel `co / depth_multiplier`
//! only — channels never merge (paper Sec. 5.3).

use crate::kernels::microkernel::backend::{self, KernelBackend};
use crate::kernels::view::ConvGeometry;
use crate::tensor::fixedpoint::FixedPointMultiplier;
use crate::tensor::quant::{requant_float, PreComputed};

/// MicroFlow DepthwiseConv2D: folded constants + float epilogue.
///
/// `pc` is per-output-channel: `w_zp_term[co] = z_X * Σ W[:,:,co]`,
/// `kzxzw = KH*KW * z_X * z_W`.
///
/// **Filter layout: `[Cout, KH*KW]` channel-major** — the MicroFlow
/// compiler's packing pass ([`crate::compiler::pack::pack_depthwise`])
/// re-lays the container's `[KH*KW, Cout]` weights out once at compile
/// time so every per-channel dot streams its filter contiguously
/// (EXPERIMENTS.md §Perf); no call site transposes at runtime. The
/// interpreter variant below keeps the container layout, as TFLM must.
#[allow(clippy::too_many_arguments)]
pub fn depthwise_conv2d_microflow(
    input: &[i8],
    filters: &[i8],
    geo: &ConvGeometry,
    depth_multiplier: usize,
    z_x: i8,
    pc: &PreComputed,
    view: &mut [i8],
    out: &mut [i8],
) {
    depthwise_conv2d_microflow_with(
        backend::active(),
        input,
        filters,
        geo,
        depth_multiplier,
        z_x,
        pc,
        view,
        out,
    );
}

/// [`depthwise_conv2d_microflow`] on an explicit [`KernelBackend`] (see
/// the note on [`crate::kernels::conv2d::conv2d_microflow_with`]). The
/// per-channel dot is strided by `c_in`; for single-channel inputs (the
/// speech model's first layer) it is contiguous and SIMD backends take
/// their vector path.
#[allow(clippy::too_many_arguments)]
pub fn depthwise_conv2d_microflow_with(
    kb: &dyn KernelBackend,
    input: &[i8],
    filters: &[i8],
    geo: &ConvGeometry,
    depth_multiplier: usize,
    z_x: i8,
    pc: &PreComputed,
    view: &mut [i8],
    out: &mut [i8],
) {
    let c_in = geo.in_c;
    let c_out = c_in * depth_multiplier;
    let kk = geo.k_h * geo.k_w;
    debug_assert_eq!(filters.len(), kk * c_out);
    debug_assert_eq!(view.len(), kk * c_in);
    debug_assert_eq!(out.len(), geo.out_h * geo.out_w * c_out);
    // per-channel tables indexed up to c_out by the epilogue below —
    // same precondition discipline as conv2d_microflow
    debug_assert_eq!(pc.const_bias.len(), c_out);
    debug_assert_eq!(pc.w_zp_term.len(), c_out);

    for oy in 0..geo.out_h {
        for ox in 0..geo.out_w {
            geo.extract_view(input, oy, ox, z_x, view);
            let base = (oy * geo.out_w + ox) * c_out;
            for ci in 0..c_in {
                // per-input-channel window sum (z_W correction, Eq. 9)
                let xsum: i32 = if pc.z_w != 0 {
                    (0..kk).map(|t| view[t * c_in + ci] as i32).sum()
                } else {
                    0
                };
                for m in 0..depth_multiplier {
                    let co = ci * depth_multiplier + m;
                    let f = &filters[co * kk..(co + 1) * kk];
                    let dot = kb.dot_strided(&view[ci..], c_in, f);
                    let acc = dot - pc.z_w * xsum - pc.w_zp_term[co] + pc.kzxzw;
                    out[base + co] =
                        requant_float(acc, pc.const_bias[co], pc.scale_ratio, pc.act_min, pc.act_max);
                }
            }
        }
    }
}

/// TFLM-style DepthwiseConv2D: per-element offsets + fixed point.
#[allow(clippy::too_many_arguments)]
pub fn depthwise_conv2d_interp(
    input: &[i8],
    filters: &[i8],
    bias: &[i32],
    geo: &ConvGeometry,
    depth_multiplier: usize,
    z_x: i32,
    z_w: i32,
    multiplier: FixedPointMultiplier,
    z_y: i32,
    act_min: i8,
    act_max: i8,
    view: &mut [i8],
    out: &mut [i8],
) {
    let c_in = geo.in_c;
    let c_out = c_in * depth_multiplier;
    let kk = geo.k_h * geo.k_w;
    for oy in 0..geo.out_h {
        for ox in 0..geo.out_w {
            geo.extract_view(input, oy, ox, z_x as i8, view);
            let base = (oy * geo.out_w + ox) * c_out;
            for ci in 0..c_in {
                for m in 0..depth_multiplier {
                    let co = ci * depth_multiplier + m;
                    let mut acc = 0i32;
                    for t in 0..kk {
                        acc += (view[t * c_in + ci] as i32 - z_x)
                            * (filters[t * c_out + co] as i32 - z_w);
                    }
                    acc += bias[co];
                    out[base + co] = multiplier.requant(acc, z_y, act_min, act_max);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::pack::pack_depthwise;
    use crate::format::mfb::Padding;
    use crate::tensor::quant::FusedAct;
    use crate::util::Prng;

    #[allow(clippy::too_many_arguments)]
    fn oracle(
        input: &[i8],
        filters: &[i8],
        bias: &[i32],
        geo: &ConvGeometry,
        mult: usize,
        s_x: f32,
        z_x: i32,
        s_w: f32,
        z_w: i32,
        s_y: f32,
        z_y: i32,
        act: FusedAct,
    ) -> Vec<i8> {
        let c_in = geo.in_c;
        let c_out = c_in * mult;
        let kk = geo.k_h * geo.k_w;
        let (lo, hi) = act.bounds(s_y, z_y);
        let mut view = vec![0i8; kk * c_in];
        let mut out = vec![0i8; geo.out_h * geo.out_w * c_out];
        for oy in 0..geo.out_h {
            for ox in 0..geo.out_w {
                geo.extract_view(input, oy, ox, z_x as i8, &mut view);
                for ci in 0..c_in {
                    for m in 0..mult {
                        let co = ci * mult + m;
                        let mut acc = 0i64;
                        for t in 0..kk {
                            acc += (view[t * c_in + ci] as i64 - z_x as i64)
                                * (filters[t * c_out + co] as i64 - z_w as i64);
                        }
                        let cb = z_y as f32 + ((s_x * s_w) / s_y) * bias[co] as f32;
                        let y = cb + (s_x * s_w / s_y) * acc as f32;
                        out[(oy * geo.out_w + ox) * c_out + co] =
                            y.round().clamp(lo as f32, hi as f32) as i8;
                    }
                }
            }
        }
        out
    }

    #[test]
    fn microflow_matches_literal_eq9() {
        let mut rng = Prng::new(21);
        for &(mult, stride) in &[(1usize, 1usize), (2, 1), (8, 2), (1, 2)] {
            let (h, w, cin, k) = (8, 7, 3, 3);
            let cout = cin * mult;
            let geo = ConvGeometry::new(h, w, cin, k, k, stride, stride, Padding::Same).unwrap();
            let input = rng.i8_vec(h * w * cin);
            let filters = rng.i8_vec(k * k * cout);
            let bias = rng.i32_vec(cout, -800, 800);
            let (s_x, z_x, s_w, z_w, s_y, z_y) = (0.03f32, -6, 0.015f32, 2, 0.05f32, 3);
            let kk = k * k;
            let colsum: Vec<i32> = (0..cout)
                .map(|co| (0..kk).map(|t| filters[t * cout + co] as i32).sum())
                .collect();
            let pc = PreComputed::fold(
                &bias, &colsum, kk, s_x, z_x, s_w, z_w, s_x * s_w, 0, s_y, z_y, FusedAct::Relu,
            );
            let mut view = vec![0i8; kk * cin];
            let mut out = vec![0i8; geo.out_h * geo.out_w * cout];
            let filters_t = pack_depthwise(&filters, kk, cout);
            depthwise_conv2d_microflow(&input, &filters_t, &geo, mult, z_x as i8, &pc, &mut view, &mut out);
            let want = oracle(
                &input, &filters, &bias, &geo, mult, s_x, z_x, s_w, z_w, s_y, z_y, FusedAct::Relu,
            );
            assert_eq!(out, want, "mult {mult} stride {stride}");
        }
    }

    #[test]
    fn interp_within_one_unit() {
        let mut rng = Prng::new(33);
        let (h, w, cin, k, mult) = (6, 6, 4, 3, 2);
        let cout = cin * mult;
        let geo = ConvGeometry::new(h, w, cin, k, k, 1, 1, Padding::Valid).unwrap();
        let input = rng.i8_vec(h * w * cin);
        let filters = rng.i8_vec(k * k * cout);
        let bias = rng.i32_vec(cout, -300, 300);
        let (s_x, z_x, s_w, z_w, s_y, z_y) = (0.02f32, 4, 0.01f32, 0, 0.03f32, -2);
        let kk = k * k;
        let colsum: Vec<i32> =
            (0..cout).map(|co| (0..kk).map(|t| filters[t * cout + co] as i32).sum()).collect();
        let pc = PreComputed::fold(&bias, &colsum, kk, s_x, z_x, s_w, z_w, s_x * s_w, 0, s_y, z_y, FusedAct::None);
        let mut view = vec![0i8; kk * cin];
        let mut mf = vec![0i8; geo.out_h * geo.out_w * cout];
        let filters_t = pack_depthwise(&filters, kk, cout);
        depthwise_conv2d_microflow(&input, &filters_t, &geo, mult, z_x as i8, &pc, &mut view, &mut mf);
        let m = FixedPointMultiplier::from_real((s_x as f64 * s_w as f64) / s_y as f64);
        let mut ip = vec![0i8; mf.len()];
        depthwise_conv2d_interp(
            &input, &filters, &bias, &geo, mult, z_x, z_w, m, z_y, -128, 127, &mut view, &mut ip,
        );
        let worst = mf.iter().zip(&ip).map(|(a, b)| (*a as i32 - *b as i32).abs()).max().unwrap();
        assert!(worst <= 1, "worst deviation {worst}");
    }

    #[test]
    fn speech_layer_geometry() {
        // the TinyConv depthwise layer: 49x40x1, k 10x8, s2, mult 8
        let geo = ConvGeometry::new(49, 40, 1, 10, 8, 2, 2, Padding::Same).unwrap();
        assert_eq!((geo.out_h, geo.out_w), (25, 20));
        let mut rng = Prng::new(1);
        let input = rng.i8_vec(49 * 40);
        let filters = rng.i8_vec(10 * 8 * 8);
        let bias = vec![0i32; 8];
        let colsum: Vec<i32> =
            (0..8).map(|co| (0..80).map(|t| filters[t * 8 + co] as i32).sum()).collect();
        let pc = PreComputed::fold(&bias, &colsum, 80, 0.1, -128, 0.02, 0, 0.002, 0, 0.15, -128, FusedAct::Relu);
        let mut view = vec![0i8; 80];
        let mut out = vec![0i8; 25 * 20 * 8];
        let filters_t = pack_depthwise(&filters, 80, 8);
        depthwise_conv2d_microflow(&input, &filters_t, &geo, 8, -128, &pc, &mut view, &mut out);
        // fused ReLU clamps at z_y
        assert!(out.iter().all(|&v| v >= -128));
    }
}
