//! Runtime-dispatched kernel backends (ROADMAP direction 1).
//!
//! The register-tiled walks of [`super`] (`dot4` / `dot4_sum` /
//! `dot4_cols` / `dot_cols`, plus the depthwise strided dot) are the
//! entire arithmetic surface of the MicroFlow hot path. This module puts
//! that surface behind [`KernelBackend`] so one binary can pick, at
//! startup, between:
//!
//! * **`scalar`** — the reference backend: the exact register-tiled
//!   scalar walks of [`super`], compiled on every target, always
//!   selectable. This is the oracle every other backend is held to.
//! * **`avx2`** (x86_64) — `std::arch` AVX2: widening i8→i16 loads and
//!   `vpmaddwd` pair-sums over the `[k][NR]` panels (`super::simd_x86`).
//! * **`neon`** (aarch64) — `std::arch` NEON: `smlal`-style widening
//!   multiply-accumulate (`vmlal_lane_s16`) over the same panels
//!   (`super::simd_aarch64`).
//!
//! ## Selection
//!
//! [`active`] resolves once per process (a [`OnceLock`]): the
//! `MICROFLOW_KERNEL_BACKEND` env var if set (`scalar` | `avx2` |
//! `neon`; an unknown or unavailable name **panics** — the override
//! exists to force a backend in tests and CI, and a typo silently
//! measuring scalar would defeat it), otherwise the best backend CPU
//! feature detection offers ([`is_x86_feature_detected!`] /
//! `is_aarch64_feature_detected!`). Engines resolve the backend at
//! session construction, so the predict path never pays the env lookup
//! and stays allocation-free (`tests/alloc_free.rs`).
//!
//! ## Bit-exactness
//!
//! Every backend accumulates i8×i8 products in exact i32 arithmetic —
//! only the *grouping* of the associative, commutative integer sum
//! differs — so every backend is **bit-identical** to `scalar`
//! (`assert_eq!`, not tolerance). This module's unit sweep holds each
//! walk to the scalar result across SIMD stride remainders, and
//! `tests/pack_equivalence.rs` re-runs the full randomized kernel
//! oracle sweep once per available backend.

use std::sync::OnceLock;

use super::NR;

/// The micro-kernel arithmetic surface. One dynamic call covers a whole
/// `k` walk (an entire panel, FC column strip, or depthwise tap chain),
/// so dispatch cost is amortized to nothing against the loop body.
pub trait KernelBackend: Sync {
    /// Stable selector name (`scalar` | `avx2` | `neon`) — printed by
    /// benches and `microflow serve`, matched by
    /// `MICROFLOW_KERNEL_BACKEND`.
    fn name(&self) -> &'static str;

    /// `acc[r] += Σ_k seg[k] * panel[k*NR + r]` — see [`super::dot4`].
    fn dot4(&self, seg: &[i8], panel: &[i8], acc: &mut [i32; NR]);

    /// [`Self::dot4`] with the segment sum folded in — see
    /// [`super::dot4_sum`].
    fn dot4_sum(&self, seg: &[i8], panel: &[i8], acc: &mut [i32; NR], sum: &mut i32);

    /// FullyConnected walk over `[K, N]` columns `j0..j0+NR` — see
    /// [`super::dot4_cols`].
    fn dot4_cols(&self, x: &[i8], w: &[i8], n: usize, j0: usize, acc: &mut [i32; NR]);

    /// FullyConnected tail walk over the last `width < NR` columns —
    /// see [`super::dot_cols`]. Lanes `width..NR` must stay untouched.
    fn dot_cols(&self, x: &[i8], w: &[i8], n: usize, j0: usize, width: usize, acc: &mut [i32; NR]);

    /// Depthwise per-channel dot: `Σ_t xs[t*stride] * w[t]` over
    /// `w.len()` taps — see [`super::dot_strided`]. `stride == 1` (every
    /// single-channel input, e.g. the speech model's first layer) is the
    /// contiguous case SIMD backends accelerate.
    fn dot_strided(&self, xs: &[i8], stride: usize, w: &[i8]) -> i32;
}

/// The always-available reference backend: delegates straight to the
/// scalar walks of [`super`], so "held bit-exact to scalar" means held
/// to the exact code `tests/pack_equivalence.rs` proved against the
/// unpacked oracles.
pub struct Scalar;

/// Singleton handed out by [`resolve`].
pub static SCALAR: Scalar = Scalar;

impl KernelBackend for Scalar {
    fn name(&self) -> &'static str {
        "scalar"
    }

    fn dot4(&self, seg: &[i8], panel: &[i8], acc: &mut [i32; NR]) {
        super::dot4(seg, panel, acc);
    }

    fn dot4_sum(&self, seg: &[i8], panel: &[i8], acc: &mut [i32; NR], sum: &mut i32) {
        super::dot4_sum(seg, panel, acc, sum);
    }

    fn dot4_cols(&self, x: &[i8], w: &[i8], n: usize, j0: usize, acc: &mut [i32; NR]) {
        super::dot4_cols(x, w, n, j0, acc);
    }

    fn dot_cols(&self, x: &[i8], w: &[i8], n: usize, j0: usize, width: usize, acc: &mut [i32; NR]) {
        super::dot_cols(x, w, n, j0, width, acc);
    }

    fn dot_strided(&self, xs: &[i8], stride: usize, w: &[i8]) -> i32 {
        super::dot_strided(xs, stride, w)
    }
}

/// Backend names selectable on this host, reference backend first.
/// `scalar` is always present; a SIMD name appears only when both
/// compiled for this target *and* reported by the running CPU.
pub fn available() -> Vec<&'static str> {
    let mut names = vec!["scalar"];
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") {
            names.push("avx2");
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            names.push("neon");
        }
    }
    names
}

/// Look a backend up by name. `Err` carries the valid names for this
/// host — an unknown or unavailable name must fail loudly, never fall
/// back (see the module docs on why the override is strict).
pub fn resolve(name: &str) -> Result<&'static dyn KernelBackend, String> {
    match name {
        "scalar" => Ok(&SCALAR),
        #[cfg(target_arch = "x86_64")]
        "avx2" => {
            if is_x86_feature_detected!("avx2") {
                Ok(&super::simd_x86::AVX2)
            } else {
                Err("kernel backend \"avx2\" is compiled in but this CPU does not report AVX2"
                    .to_string())
            }
        }
        #[cfg(target_arch = "aarch64")]
        "neon" => {
            if std::arch::is_aarch64_feature_detected!("neon") {
                Ok(&super::simd_aarch64::NEON)
            } else {
                Err("kernel backend \"neon\" is compiled in but this CPU does not report NEON"
                    .to_string())
            }
        }
        other => Err(format!(
            "unknown kernel backend {other:?}; valid on this host: {}",
            available().join(", ")
        )),
    }
}

/// Best backend this host offers: the last entry of [`available`]
/// (SIMD when detected, the scalar reference otherwise).
fn autodetect() -> &'static dyn KernelBackend {
    let names = available();
    let best = names.last().expect("scalar is always available");
    resolve(best).expect("every name available() lists must resolve")
}

static ACTIVE: OnceLock<&'static dyn KernelBackend> = OnceLock::new();

/// The process-wide backend: `MICROFLOW_KERNEL_BACKEND` if set (panics
/// on an unknown or unavailable name), otherwise [`autodetect`]. The
/// choice is made once and cached for the life of the process; call
/// sites on the predict path see a plain atomic load.
pub fn active() -> &'static dyn KernelBackend {
    *ACTIVE.get_or_init(|| match std::env::var("MICROFLOW_KERNEL_BACKEND") {
        Ok(name) => resolve(name.trim())
            .unwrap_or_else(|e| panic!("MICROFLOW_KERNEL_BACKEND: {e}")),
        Err(std::env::VarError::NotPresent) => autodetect(),
        Err(std::env::VarError::NotUnicode(v)) => {
            panic!("MICROFLOW_KERNEL_BACKEND is not unicode: {v:?}")
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::microkernel as mk;
    use crate::util::Prng;

    fn backends() -> Vec<&'static dyn KernelBackend> {
        available()
            .into_iter()
            .map(|n| resolve(n).expect("listed backend must resolve"))
            .collect()
    }

    #[test]
    fn scalar_is_always_first_and_resolves() {
        let names = available();
        assert_eq!(names[0], "scalar");
        assert_eq!(resolve("scalar").unwrap().name(), "scalar");
        // the active backend is one of the available ones, and stable
        let a = active().name();
        assert!(names.contains(&a), "active {a} not in {names:?}");
        assert_eq!(active().name(), a);
    }

    #[test]
    fn unknown_backend_name_fails_loudly() {
        let e = resolve("warp-drive").unwrap_err();
        assert!(e.contains("unknown kernel backend"), "{e}");
        assert!(e.contains("scalar"), "error must list the valid names: {e}");
        // the override is an exact token, not fuzzy: case and whitespace
        // mistakes must not silently select something else
        assert!(resolve("AVX2").is_err());
        assert!(resolve("Scalar").is_err());
        assert!(resolve("").is_err());
    }

    #[test]
    fn every_backend_matches_scalar_on_remainder_lengths() {
        // lengths straddling every SIMD stride in this repo: the 8-wide
        // panel walks, the 16-wide contiguous dots, odd FC row pairs
        let mut rng = Prng::new(0xB4C2);
        for kb in backends() {
            for &len in &[1usize, 2, 3, 4, 7, 8, 9, 15, 16, 17, 31, 33, 64] {
                let seg = rng.i8_vec(len);
                let panel = rng.i8_vec(len * NR);
                let (mut want, mut got) = ([0i32; NR], [0i32; NR]);
                mk::dot4(&seg, &panel, &mut want);
                kb.dot4(&seg, &panel, &mut got);
                assert_eq!(got, want, "{} dot4 len {len}", kb.name());

                let (mut want2, mut got2) = ([3i32; NR], [3i32; NR]);
                let (mut want_s, mut got_s) = (-5i32, -5i32);
                mk::dot4_sum(&seg, &panel, &mut want2, &mut want_s);
                kb.dot4_sum(&seg, &panel, &mut got2, &mut got_s);
                assert_eq!((got2, got_s), (want2, want_s), "{} dot4_sum len {len}", kb.name());
            }
        }
    }

    #[test]
    fn fc_walks_match_scalar_for_every_backend() {
        let mut rng = Prng::new(0xFC02);
        for kb in backends() {
            for &k in &[1usize, 2, 7, 9, 31, 40] {
                let n = 11; // two full panels + a 3-wide tail
                let x = rng.i8_vec(k);
                let w = rng.i8_vec(k * n);
                for j0 in [0usize, 4] {
                    let (mut want, mut got) = ([0i32; NR], [0i32; NR]);
                    mk::dot4_cols(&x, &w, n, j0, &mut want);
                    kb.dot4_cols(&x, &w, n, j0, &mut got);
                    assert_eq!(got, want, "{} dot4_cols k {k} j0 {j0}", kb.name());
                }
                // sentinel lanes past the tail width must stay untouched
                let (mut want, mut got) = ([7i32; NR], [7i32; NR]);
                mk::dot_cols(&x, &w, n, 8, 3, &mut want);
                kb.dot_cols(&x, &w, n, 8, 3, &mut got);
                assert_eq!(got, want, "{} dot_cols k {k}", kb.name());
            }
        }
    }

    #[test]
    fn strided_dot_matches_scalar_for_every_backend() {
        let mut rng = Prng::new(0xD501);
        let shapes: &[(usize, usize)] =
            &[(1, 1), (7, 1), (16, 1), (33, 1), (80, 1), (9, 3), (12, 5)];
        for kb in backends() {
            for &(taps, stride) in shapes {
                let xs = rng.i8_vec((taps - 1) * stride + 1);
                let w = rng.i8_vec(taps);
                assert_eq!(
                    kb.dot_strided(&xs, stride, &w),
                    mk::dot_strided(&xs, stride, &w),
                    "{} taps {taps} stride {stride}",
                    kb.name()
                );
            }
        }
    }
}
