//! AVX2 micro-kernel backend (x86_64).
//!
//! Same arithmetic as the scalar walks of [`super`], restructured for
//! 256-bit integer SIMD:
//!
//! * panel walks consume 8 input bytes per step: the 32 matching panel
//!   bytes are loaded once, byte-shuffled so adjacent i16 lanes hold two
//!   adjacent `k`s of **one** output channel, widened i8→i16, and
//!   reduced by `vpmaddwd` (`_mm256_madd_epi16`) into eight i32
//!   accumulators (two channel quads, folded once at the end);
//! * FullyConnected column walks pair two `[K, N]` rows per `vpmaddwd`
//!   via a byte interleave (`_mm_unpacklo_epi8`);
//! * contiguous depthwise dots widen 16 bytes of each operand per step.
//!
//! ## Exactness
//!
//! Every product is i8×i8 (|p| ≤ 16384 ⊂ i16), computed in i16 lanes and
//! pair-summed into i32 by `vpmaddwd` — no saturation is reachable. (The
//! u8×i8 `vpmaddubsw` shortcut ROADMAP once suggested is deliberately
//! NOT used: it saturates at i16 and would break bit-exactness.) Only
//! the grouping of the integer sum differs from the scalar walk, so
//! results are bit-identical; `tests/pack_equivalence.rs` and the
//! backend unit sweep hold this with `assert_eq!`.
//!
//! Remainders (`k % 8` panel tails, odd FC row counts, `k % 16`
//! contiguous tails) finish on the scalar walk over the same
//! accumulators — the SIMD/scalar seam is exactly where the remainder
//! lengths in the unit sweep sit.
//!
//! ## Safety
//!
//! The crate is `#![deny(unsafe_code)]`; this module carries the narrow
//! exemption for `std::arch`. Every `#[target_feature(enable = "avx2")]`
//! function is private to the module and reachable only through
//! [`Avx2`], which [`super::backend::resolve`] hands out strictly after
//! `is_x86_feature_detected!("avx2")` succeeds — that runtime check is
//! the safety contract for every call below. All loads go through
//! bounds-checked slices or pointers derived from them with
//! debug-asserted lengths; there are no unaligned-type or overread
//! tricks (tail bytes are never touched by SIMD loads).
#![allow(unsafe_code)]

use core::arch::x86_64::*;

use super::backend::KernelBackend;
use super::NR;

/// The AVX2 backend. Only [`super::backend::resolve`] constructs a
/// reference to [`AVX2`], and only after feature detection.
pub struct Avx2;

/// Singleton handed out by [`super::backend::resolve`].
pub static AVX2: Avx2 = Avx2;

impl KernelBackend for Avx2 {
    fn name(&self) -> &'static str {
        "avx2"
    }

    fn dot4(&self, seg: &[i8], panel: &[i8], acc: &mut [i32; NR]) {
        // SAFETY: AVX2 presence was verified by resolve() before this
        // backend could be obtained (see the module docs).
        unsafe { dot4_avx2(seg, panel, acc) }
    }

    fn dot4_sum(&self, seg: &[i8], panel: &[i8], acc: &mut [i32; NR], sum: &mut i32) {
        // the segment sum is a cheap linear pass; doing it scalar keeps
        // this trivially identical to the reference fold
        *sum += seg.iter().map(|&v| v as i32).sum::<i32>();
        // SAFETY: as in `dot4`.
        unsafe { dot4_avx2(seg, panel, acc) }
    }

    fn dot4_cols(&self, x: &[i8], w: &[i8], n: usize, j0: usize, acc: &mut [i32; NR]) {
        // SAFETY: as in `dot4`.
        unsafe { dot4_cols_avx2(x, w, n, j0, acc) }
    }

    fn dot_cols(&self, x: &[i8], w: &[i8], n: usize, j0: usize, width: usize, acc: &mut [i32; NR]) {
        // runs once per FC call on < NR columns — scalar is the right tool
        super::dot_cols(x, w, n, j0, width, acc);
    }

    fn dot_strided(&self, xs: &[i8], stride: usize, w: &[i8]) -> i32 {
        if stride == 1 {
            // SAFETY: as in `dot4`.
            unsafe { dot_contig_avx2(&xs[..w.len()], w) }
        } else {
            // strided gathers don't pay on AVX2 for these tap counts
            super::dot_strided(xs, stride, w)
        }
    }
}

/// Two i8s as adjacent i16 lanes of one i32 (little-endian lane order:
/// `a` in the low lane), ready for `_mm_set1_epi32` broadcast into the
/// multiplier position of `vpmaddwd`.
#[inline(always)]
fn pair(a: i8, b: i8) -> i32 {
    (a as i16 as u16 as u32 | ((b as i16 as u16 as u32) << 16)) as i32
}

/// Panel walk, 8 ks per iteration over one `[k][NR]` panel.
///
/// Lane plan per iteration (ks `kk..kk+8`, channels `c0..c3`):
/// the 32 panel bytes `[k0c0 k0c1 k0c2 k0c3 | k1c0 ...]` are shuffled
/// per 128-bit lane to `[k0c0 k1c0 k0c1 k1c1 k0c2 k1c2 k0c3 k1c3 |
/// k2c0 k3c0 ...]`, widened to i16, and `vpmaddwd`-ed against the
/// broadcast pair `(seg[k0], seg[k1])` — each resulting i32 lane is
/// `seg[k0]*w[k0][c] + seg[k1]*w[k1][c]`, i.e. the pairwise add stays
/// within one output channel. Two madds cover 8 ks; the two 128-bit
/// halves are two independent channel quads folded at the end.
#[target_feature(enable = "avx2")]
unsafe fn dot4_avx2(seg: &[i8], panel: &[i8], acc: &mut [i32; NR]) {
    debug_assert_eq!(panel.len(), seg.len() * NR);
    let k = seg.len();
    let main = k - (k % 8);
    let interleave = _mm256_setr_epi8(
        0, 4, 1, 5, 2, 6, 3, 7, 8, 12, 9, 13, 10, 14, 11, 15, // low lane: ks 0..4
        0, 4, 1, 5, 2, 6, 3, 7, 8, 12, 9, 13, 10, 14, 11, 15, // high lane: ks 4..8
    );
    let mut acc8 = _mm256_setzero_si256();
    let mut kk = 0usize;
    while kk < main {
        let pb = _mm256_loadu_si256(panel.as_ptr().add(kk * NR) as *const __m256i);
        let il = _mm256_shuffle_epi8(pb, interleave);
        let lo = _mm256_cvtepi8_epi16(_mm256_castsi256_si128(il));
        let hi = _mm256_cvtepi8_epi16(_mm256_extracti128_si256::<1>(il));
        let xa = pairs_2x(pair(seg[kk], seg[kk + 1]), pair(seg[kk + 2], seg[kk + 3]));
        let xb = pairs_2x(pair(seg[kk + 4], seg[kk + 5]), pair(seg[kk + 6], seg[kk + 7]));
        acc8 = _mm256_add_epi32(acc8, _mm256_madd_epi16(lo, xa));
        acc8 = _mm256_add_epi32(acc8, _mm256_madd_epi16(hi, xb));
        kk += 8;
    }
    fold_add(acc8, acc);
    // scalar remainder: same accumulators, same exact i32 arithmetic
    super::dot4(&seg[main..], &panel[main * NR..], acc);
}

/// `[lo ×4 | hi ×4]` as eight i32 lanes (each an i16 pair).
#[target_feature(enable = "avx2")]
unsafe fn pairs_2x(lo: i32, hi: i32) -> __m256i {
    _mm256_inserti128_si256::<1>(_mm256_castsi128_si256(_mm_set1_epi32(lo)), _mm_set1_epi32(hi))
}

/// Fold the two channel quads of `acc8` and add into `acc`.
#[target_feature(enable = "avx2")]
unsafe fn fold_add(acc8: __m256i, acc: &mut [i32; NR]) {
    let quad = _mm_add_epi32(_mm256_castsi256_si128(acc8), _mm256_extracti128_si256::<1>(acc8));
    let mut lanes = [0i32; NR];
    _mm_storeu_si128(lanes.as_mut_ptr() as *mut __m128i, quad);
    for (a, l) in acc.iter_mut().zip(lanes) {
        *a += l;
    }
}

/// FullyConnected column walk, two `[K, N]` rows per `vpmaddwd`:
/// `_mm_unpacklo_epi8(r0, r1)` interleaves the two rows' column bytes to
/// `[r0c0 r1c0 r0c1 r1c1 ...]`, so after widening, the in-pair add of
/// `vpmaddwd` against the broadcast `(x[i], x[i+1])` pair stays within
/// one output column.
#[target_feature(enable = "avx2")]
unsafe fn dot4_cols_avx2(x: &[i8], w: &[i8], n: usize, j0: usize, acc: &mut [i32; NR]) {
    debug_assert!(j0 + NR <= n);
    debug_assert_eq!(w.len(), x.len() * n);
    let k = x.len();
    let main = k - (k % 2);
    let mut acc4 = _mm_setzero_si128();
    let mut i = 0usize;
    while i < main {
        let r0 = load_row4(w, i * n + j0);
        let r1 = load_row4(w, (i + 1) * n + j0);
        let p16 = _mm_cvtepi8_epi16(_mm_unpacklo_epi8(r0, r1));
        let xv = _mm_set1_epi32(pair(x[i], x[i + 1]));
        acc4 = _mm_add_epi32(acc4, _mm_madd_epi16(p16, xv));
        i += 2;
    }
    let mut lanes = [0i32; NR];
    _mm_storeu_si128(lanes.as_mut_ptr() as *mut __m128i, acc4);
    for (a, l) in acc.iter_mut().zip(lanes) {
        *a += l;
    }
    if main < k {
        // odd trailing row, scalar
        let row = &w[main * n + j0..main * n + j0 + NR];
        let xv = x[main] as i32;
        for (a, &wv) in acc.iter_mut().zip(row) {
            *a += xv * wv as i32;
        }
    }
}

/// Four row bytes as the low i32 lane of an XMM register. Goes through a
/// bounds-checked slice and `i32::from_le_bytes` — never a 16-byte load —
/// so the last row of the weight matrix cannot overread.
#[inline(always)]
fn load_row4(w: &[i8], off: usize) -> __m128i {
    let b = &w[off..off + NR];
    let v = i32::from_le_bytes([b[0] as u8, b[1] as u8, b[2] as u8, b[3] as u8]);
    // SAFETY: `_mm_cvtsi32_si128` is SSE2 — baseline on every x86_64.
    unsafe { _mm_cvtsi32_si128(v) }
}

/// Contiguous i8 dot product, 16 bytes of each operand per step.
#[target_feature(enable = "avx2")]
unsafe fn dot_contig_avx2(xs: &[i8], w: &[i8]) -> i32 {
    debug_assert_eq!(xs.len(), w.len());
    let k = w.len();
    let main = k - (k % 16);
    let mut acc8 = _mm256_setzero_si256();
    let mut i = 0usize;
    while i < main {
        let a = _mm256_cvtepi8_epi16(_mm_loadu_si128(xs.as_ptr().add(i) as *const __m128i));
        let b = _mm256_cvtepi8_epi16(_mm_loadu_si128(w.as_ptr().add(i) as *const __m128i));
        acc8 = _mm256_add_epi32(acc8, _mm256_madd_epi16(a, b));
        i += 16;
    }
    let mut quads = [0i32; NR];
    fold_add(acc8, &mut quads);
    let mut dot = quads.iter().sum::<i32>();
    for (xv, wv) in xs[main..].iter().zip(&w[main..]) {
        dot += *xv as i32 * *wv as i32;
    }
    dot
}
