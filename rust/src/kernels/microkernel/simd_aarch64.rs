//! NEON micro-kernel backend (aarch64).
//!
//! Same arithmetic as the scalar walks of [`super`], restructured for
//! 128-bit NEON:
//!
//! * panel walks consume 8 input bytes per step: the 32 matching panel
//!   bytes are widened i8→i16 (`vmovl_s8`) and accumulated with
//!   `smlal`-style widening multiply-accumulate —
//!   `vmlal_lane_s16::<LANE>` multiplies a channel quad by one input
//!   lane and adds into an int32x4 accumulator (two accumulators hide
//!   the MLA latency chain, folded once at the end);
//! * FullyConnected column walks run two `[K, N]` rows per iteration
//!   against a two-lane input vector;
//! * contiguous depthwise dots use `vmull_s8` + `vpadalq_s16`
//!   (pairwise-add-accumulate), 8 taps per step.
//!
//! ## Exactness
//!
//! Every product is i8×i8 (|p| ≤ 16384), formed by *widening* multiplies
//! straight into i16/i32 — NEON's widening MLA family cannot saturate on
//! this range, so every sum is the exact i32 value in a different
//! grouping, and results are bit-identical to the scalar oracle
//! (`assert_eq!` in the backend unit sweep and
//! `tests/pack_equivalence.rs`). Remainders (`k % 8`, odd FC rows,
//! `k % 8` taps) finish on the scalar walk over the same accumulators.
//!
//! ## Safety
//!
//! The crate is `#![deny(unsafe_code)]`; this module carries the narrow
//! exemption for `std::arch`. Every `#[target_feature(enable = "neon")]`
//! function is private and reachable only through [`Neon`], which
//! [`super::backend::resolve`] hands out strictly after
//! `is_aarch64_feature_detected!("neon")` succeeds. All vector loads are
//! derived from slices with debug-asserted lengths and never read past
//! `len` (tails are finished scalar, short FC rows go through a stack
//! buffer).
#![allow(unsafe_code)]

use core::arch::aarch64::*;

use super::backend::KernelBackend;
use super::NR;

/// The NEON backend. Only [`super::backend::resolve`] constructs a
/// reference to [`NEON`], and only after feature detection.
pub struct Neon;

/// Singleton handed out by [`super::backend::resolve`].
pub static NEON: Neon = Neon;

impl KernelBackend for Neon {
    fn name(&self) -> &'static str {
        "neon"
    }

    fn dot4(&self, seg: &[i8], panel: &[i8], acc: &mut [i32; NR]) {
        // SAFETY: NEON presence was verified by resolve() before this
        // backend could be obtained (see the module docs).
        unsafe { dot4_neon(seg, panel, acc) }
    }

    fn dot4_sum(&self, seg: &[i8], panel: &[i8], acc: &mut [i32; NR], sum: &mut i32) {
        // cheap linear pass kept scalar, identical to the reference fold
        *sum += seg.iter().map(|&v| v as i32).sum::<i32>();
        // SAFETY: as in `dot4`.
        unsafe { dot4_neon(seg, panel, acc) }
    }

    fn dot4_cols(&self, x: &[i8], w: &[i8], n: usize, j0: usize, acc: &mut [i32; NR]) {
        // SAFETY: as in `dot4`.
        unsafe { dot4_cols_neon(x, w, n, j0, acc) }
    }

    fn dot_cols(&self, x: &[i8], w: &[i8], n: usize, j0: usize, width: usize, acc: &mut [i32; NR]) {
        // runs once per FC call on < NR columns — scalar is the right tool
        super::dot_cols(x, w, n, j0, width, acc);
    }

    fn dot_strided(&self, xs: &[i8], stride: usize, w: &[i8]) -> i32 {
        if stride == 1 {
            // SAFETY: as in `dot4`.
            unsafe { dot_contig_neon(&xs[..w.len()], w) }
        } else {
            super::dot_strided(xs, stride, w)
        }
    }
}

/// Panel walk, 8 ks per iteration over one `[k][NR]` panel.
///
/// Per iteration: 8 input bytes widen to one int16x8 (`sl`/`sh` halves);
/// the 32 panel bytes are four channel quads per k-pair after widening.
/// `vmlal_lane_s16::<L>(acc, quad_k, s)` adds `quad_k * s[L]` — one k's
/// four channels scaled by that k's input — so each accumulator lane
/// stays a single output channel. Two accumulators split the eight MLAs.
#[target_feature(enable = "neon")]
unsafe fn dot4_neon(seg: &[i8], panel: &[i8], acc: &mut [i32; NR]) {
    debug_assert_eq!(panel.len(), seg.len() * NR);
    let k = seg.len();
    let main = k - (k % 8);
    let mut acc_a = vdupq_n_s32(0);
    let mut acc_b = vdupq_n_s32(0);
    let mut kk = 0usize;
    while kk < main {
        let s16 = vmovl_s8(vld1_s8(seg.as_ptr().add(kk)));
        let sl = vget_low_s16(s16); // seg[kk..kk+4] as i16 lanes
        let sh = vget_high_s16(s16); // seg[kk+4..kk+8]
        let p0 = vld1q_s8(panel.as_ptr().add(kk * NR)); // ks kk..kk+4
        let p1 = vld1q_s8(panel.as_ptr().add((kk + 4) * NR)); // ks kk+4..kk+8
        let p0lo = vmovl_s8(vget_low_s8(p0)); // [k0 quad | k1 quad]
        let p0hi = vmovl_s8(vget_high_s8(p0)); // [k2 quad | k3 quad]
        let p1lo = vmovl_s8(vget_low_s8(p1));
        let p1hi = vmovl_s8(vget_high_s8(p1));
        acc_a = vmlal_lane_s16::<0>(acc_a, vget_low_s16(p0lo), sl);
        acc_b = vmlal_lane_s16::<1>(acc_b, vget_high_s16(p0lo), sl);
        acc_a = vmlal_lane_s16::<2>(acc_a, vget_low_s16(p0hi), sl);
        acc_b = vmlal_lane_s16::<3>(acc_b, vget_high_s16(p0hi), sl);
        acc_a = vmlal_lane_s16::<0>(acc_a, vget_low_s16(p1lo), sh);
        acc_b = vmlal_lane_s16::<1>(acc_b, vget_high_s16(p1lo), sh);
        acc_a = vmlal_lane_s16::<2>(acc_a, vget_low_s16(p1hi), sh);
        acc_b = vmlal_lane_s16::<3>(acc_b, vget_high_s16(p1hi), sh);
        kk += 8;
    }
    let mut lanes = [0i32; NR];
    vst1q_s32(lanes.as_mut_ptr(), vaddq_s32(acc_a, acc_b));
    for (a, l) in acc.iter_mut().zip(lanes) {
        *a += l;
    }
    // scalar remainder: same accumulators, same exact i32 arithmetic
    super::dot4(&seg[main..], &panel[main * NR..], acc);
}

/// FullyConnected column walk, two `[K, N]` rows per iteration: the two
/// rows' column quads sit in the halves of one widened int16x8; each is
/// MLA-ed against its input lane.
#[target_feature(enable = "neon")]
unsafe fn dot4_cols_neon(x: &[i8], w: &[i8], n: usize, j0: usize, acc: &mut [i32; NR]) {
    debug_assert!(j0 + NR <= n);
    debug_assert_eq!(w.len(), x.len() * n);
    let k = x.len();
    let main = k - (k % 2);
    let mut acc4 = vdupq_n_s32(0);
    let mut i = 0usize;
    while i < main {
        // stack-stage the two 4-byte rows: rows of a [K, N] matrix are
        // not 8-contiguous, and a direct 8-byte load could overread the
        // final row of the matrix
        let mut rows = [0i8; 8];
        rows[..NR].copy_from_slice(&w[i * n + j0..i * n + j0 + NR]);
        rows[NR..].copy_from_slice(&w[(i + 1) * n + j0..(i + 1) * n + j0 + NR]);
        let r16 = vmovl_s8(vld1_s8(rows.as_ptr()));
        let xpair = vset_lane_s16::<1>(x[i + 1] as i16, vdup_n_s16(x[i] as i16));
        acc4 = vmlal_lane_s16::<0>(acc4, vget_low_s16(r16), xpair);
        acc4 = vmlal_lane_s16::<1>(acc4, vget_high_s16(r16), xpair);
        i += 2;
    }
    let mut lanes = [0i32; NR];
    vst1q_s32(lanes.as_mut_ptr(), acc4);
    for (a, l) in acc.iter_mut().zip(lanes) {
        *a += l;
    }
    if main < k {
        // odd trailing row, scalar
        let row = &w[main * n + j0..main * n + j0 + NR];
        let xv = x[main] as i32;
        for (a, &wv) in acc.iter_mut().zip(row) {
            *a += xv * wv as i32;
        }
    }
}

/// Contiguous i8 dot product: `vmull_s8` widens 8 products to i16, then
/// `vpadalq_s16` pairwise-adds them into four i32 accumulators.
#[target_feature(enable = "neon")]
unsafe fn dot_contig_neon(xs: &[i8], w: &[i8]) -> i32 {
    debug_assert_eq!(xs.len(), w.len());
    let k = w.len();
    let main = k - (k % 8);
    let mut acc4 = vdupq_n_s32(0);
    let mut i = 0usize;
    while i < main {
        let prod = vmull_s8(vld1_s8(xs.as_ptr().add(i)), vld1_s8(w.as_ptr().add(i)));
        acc4 = vpadalq_s16(acc4, prod);
        i += 8;
    }
    let mut dot = vaddvq_s32(acc4);
    for (xv, wv) in xs[main..].iter().zip(&w[main..]) {
        dot += *xv as i32 * *wv as i32;
    }
    dot
}
