//! AveragePool2D kernels — Eq. (12) / Appendix A.4 (DESIGN.md S9).
//!
//! Per-channel pooling; the channel dimension is preserved. The MicroFlow
//! variant uses the float epilogue of Eq. 12 with the pre-computed
//! `s_X / s_y` ratio (Eq. 13); the interpreter variant mimics TFLM's
//! integer rounding average (only valid when input/output qparams match,
//! which TFLite guarantees for pooling — our exporter preserves that).

use crate::kernels::view::ConvGeometry;
use crate::tensor::quant::round_half_away_i32;

/// MicroFlow AveragePool2D (Eq. 12).
///
/// `y_q = round(z_y + ratio * (mean(view) - z_x))`, `ratio = s_X / s_y`.
#[allow(clippy::too_many_arguments)]
pub fn average_pool2d_microflow(
    input: &[i8],
    geo: &ConvGeometry,
    z_x: i8,
    ratio: f32,
    z_y: i32,
    act_min: i8,
    act_max: i8,
    view: &mut [i8],
    out: &mut [i8],
) {
    let c = geo.in_c;
    let kk = geo.k_h * geo.k_w;
    debug_assert_eq!(view.len(), kk * c);
    debug_assert_eq!(out.len(), geo.out_h * geo.out_w * c);
    let inv_mn = 1.0f32 / kk as f32;
    for oy in 0..geo.out_h {
        for ox in 0..geo.out_w {
            geo.extract_view(input, oy, ox, z_x, view);
            let base = (oy * geo.out_w + ox) * c;
            for ch in 0..c {
                let mut sum = 0i32;
                for t in 0..kk {
                    sum += view[t * c + ch] as i32;
                }
                let mean = sum as f32 * inv_mn;
                // matches ref.average_pool2d: z_y + ratio * (mean - z_x)
                let acc_form = mean - z_x as i32 as f32;
                let y = z_y as f32 + ratio * acc_form;
                out[base + ch] = round_half_away_i32(y).clamp(act_min as i32, act_max as i32) as i8;
            }
        }
    }
}

/// TFLM-style AveragePool2D: integer rounding average (shared in/out
/// qparams, as TFLite requires for pooling).
#[allow(clippy::too_many_arguments)]
pub fn average_pool2d_interp(
    input: &[i8],
    geo: &ConvGeometry,
    z_x: i8,
    act_min: i8,
    act_max: i8,
    view: &mut [i8],
    out: &mut [i8],
) {
    let c = geo.in_c;
    let kk = geo.k_h * geo.k_w;
    for oy in 0..geo.out_h {
        for ox in 0..geo.out_w {
            geo.extract_view(input, oy, ox, z_x, view);
            let base = (oy * geo.out_w + ox) * c;
            for ch in 0..c {
                let mut sum = 0i32;
                for t in 0..kk {
                    sum += view[t * c + ch] as i32;
                }
                // TFLM: rounded integer division, ties away from zero
                let n = kk as i32;
                let avg = if sum >= 0 { (sum + n / 2) / n } else { (sum - n / 2) / n };
                out[base + ch] = avg.clamp(act_min as i32, act_max as i32) as i8;
            }
        }
    }
}

/// The interpreter path also needs the generic requant form when in/out
/// scales differ (kept for robustness; unused on our exported models).
#[allow(clippy::too_many_arguments)]
pub fn average_pool2d_requant(
    input: &[i8],
    geo: &ConvGeometry,
    z_x: i8,
    ratio: f32,
    z_y: i32,
    act_min: i8,
    act_max: i8,
    view: &mut [i8],
    out: &mut [i8],
) {
    // identical math to the microflow variant; the interpreter pays for it
    // in dispatch + parse cost, not arithmetic
    average_pool2d_microflow(input, geo, z_x, ratio, z_y, act_min, act_max, view, out);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::format::mfb::Padding;
    use crate::util::Prng;

    #[test]
    fn constant_input_pools_to_itself_when_qparams_match() {
        let geo = ConvGeometry::new(4, 4, 2, 2, 2, 2, 2, Padding::Valid).unwrap();
        let input = vec![42i8; 4 * 4 * 2];
        let mut view = vec![0i8; 2 * 2 * 2];
        let mut out = vec![0i8; 2 * 2 * 2];
        average_pool2d_microflow(&input, &geo, 0, 1.0, 0, -128, 127, &mut view, &mut out);
        assert!(out.iter().all(|&v| v == 42));
        let mut out2 = vec![0i8; 2 * 2 * 2];
        average_pool2d_interp(&input, &geo, 0, -128, 127, &mut view, &mut out2);
        assert_eq!(out, out2);
    }

    #[test]
    fn mean_is_per_channel() {
        // 2x2 window, 2 channels: ch0 = [0,2,4,6] -> 3; ch1 = [10,10,10,10] -> 10
        let geo = ConvGeometry::new(2, 2, 2, 2, 2, 2, 2, Padding::Valid).unwrap();
        let input = vec![0i8, 10, 2, 10, 4, 10, 6, 10];
        let mut view = vec![0i8; 8];
        let mut out = vec![0i8; 2];
        average_pool2d_microflow(&input, &geo, 0, 1.0, 0, -128, 127, &mut view, &mut out);
        assert_eq!(out, vec![3, 10]);
    }

    #[test]
    fn matches_ref_formula_with_scale_change() {
        let mut rng = Prng::new(2);
        let geo = ConvGeometry::new(6, 6, 3, 3, 3, 3, 3, Padding::Valid).unwrap();
        let input = rng.i8_vec(6 * 6 * 3);
        let (s_x, z_x, s_y, z_y) = (0.05f32, 4, 0.07f32, -3);
        let ratio = s_x / s_y;
        let mut view = vec![0i8; 27];
        let mut out = vec![0i8; 2 * 2 * 3];
        average_pool2d_microflow(&input, &geo, z_x as i8, ratio, z_y, -128, 127, &mut view, &mut out);
        // brute force per the Eq. 12 formula
        for oy in 0..2 {
            for ox in 0..2 {
                for ch in 0..3 {
                    let mut sum = 0f64;
                    for ky in 0..3 {
                        for kx in 0..3 {
                            sum += input[((oy * 3 + ky) * 6 + ox * 3 + kx) * 3 + ch] as f64;
                        }
                    }
                    let mean = sum / 9.0;
                    let y = z_y as f64 + ratio as f64 * (mean - z_x as f64);
                    let want = y.round().clamp(-128.0, 127.0) as i8;
                    let got = out[(oy * 2 + ox) * 3 + ch];
                    assert!((got as i32 - want as i32).abs() <= 1, "({oy},{ox},{ch}): {got} vs {want}");
                }
            }
        }
    }

    #[test]
    fn interp_rounds_negative_sums_away_from_zero() {
        let geo = ConvGeometry::new(2, 2, 1, 2, 2, 2, 2, Padding::Valid).unwrap();
        let input = vec![-1i8, -1, -1, -2]; // sum -5, avg -1.25 -> -1
        let mut view = vec![0i8; 4];
        let mut out = vec![0i8; 1];
        average_pool2d_interp(&input, &geo, 0, -128, 127, &mut view, &mut out);
        assert_eq!(out[0], -1);
        let input2 = vec![-1i8, -2, -2, -1]; // sum -6, avg -1.5 -> -2 (away)
        average_pool2d_interp(&input2, &geo, 0, -128, 127, &mut view, &mut out);
        assert_eq!(out[0], -2);
    }
}
