//! Quantized operator kernels (paper Sec. 5 + Appendix A; DESIGN.md S9-S11).
//!
//! Every operator exists in **two arithmetic variants**, mirroring the two
//! engines the paper compares:
//!
//! * `*_microflow` — the MicroFlow form: all input-independent terms of
//!   Eq. 3/6/9/12 are folded offline into a [`PreComputed`] by the compiler
//!   (Sec. 3.3.3), the inner loop is a raw int8 dot product, and the
//!   epilogue is the float-scale requantization
//!   (`tensor::quant::requant_float`). Bit-compatible with the JAX oracle.
//!
//! * `*_interp` — the TFLM form used by the interpreter baseline: zero
//!   points are applied **per element** inside the MAC loop
//!   (`(x - z_x)(w - z_w)`), the bias joins the int32 accumulator, and the
//!   epilogue is gemmlowp fixed-point (`tensor::fixedpoint`). More work per
//!   MAC and integer-only — exactly the trade TFLM makes, and the source of
//!   the paper's ±1 output differences (Sec. 6.2.1).
//!
//! Kernels are **per-sample** (no batch dimension); the engines loop over
//! the batch. Activations are `[H, W, C]` row-major; Conv2D filters
//! `[Cout, KH, KW, Cin]`; DepthwiseConv2D filters `[KH, KW, Cout]`;
//! FullyConnected weights `[K, N]`.

pub mod activation;
pub mod average_pool2d;
pub mod conv2d;
pub mod depthwise_conv2d;
pub mod fully_connected;
pub mod view;

pub use view::ConvGeometry;

use crate::format::mfb::Padding;

/// Output spatial dims for SAME/VALID padding (TFLite convention; mirrors
/// `ref.out_dims`).
pub fn out_dims(h: usize, w: usize, kh: usize, kw: usize, sh: usize, sw: usize, padding: Padding) -> (usize, usize) {
    match padding {
        Padding::Same => (h.div_ceil(sh), w.div_ceil(sw)),
        Padding::Valid => ((h - kh) / sh + 1, (w - kw) / sw + 1),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn out_dims_same_vs_valid() {
        // 49x40, k 10x8, s 2x2 — the speech model's depthwise layer
        assert_eq!(out_dims(49, 40, 10, 8, 2, 2, Padding::Same), (25, 20));
        assert_eq!(out_dims(49, 40, 10, 8, 2, 2, Padding::Valid), (20, 17));
        // 96x96, k 3x3, s 2x2 — the person model's first conv
        assert_eq!(out_dims(96, 96, 3, 3, 2, 2, Padding::Same), (48, 48));
    }
}
