//! Quantized operator kernels (paper Sec. 5 + Appendix A; DESIGN.md S9-S11).
//!
//! Every operator exists in **two arithmetic variants**, mirroring the two
//! engines the paper compares:
//!
//! * `*_microflow` — the MicroFlow form: all input-independent terms of
//!   Eq. 3/6/9/12 are folded offline into a [`PreComputed`] by the compiler
//!   (Sec. 3.3.3), the inner loop is a raw int8 dot product, and the
//!   epilogue is the float-scale requantization
//!   (`tensor::quant::requant_float`). Bit-compatible with the JAX oracle.
//!
//! * `*_interp` — the TFLM form used by the interpreter baseline: zero
//!   points are applied **per element** inside the MAC loop
//!   (`(x - z_x)(w - z_w)`), the bias joins the int32 accumulator, and the
//!   epilogue is gemmlowp fixed-point (`tensor::fixedpoint`). More work per
//!   MAC and integer-only — exactly the trade TFLM makes, and the source of
//!   the paper's ±1 output differences (Sec. 6.2.1).
//!
//! Kernels are **per-sample** (no batch dimension); the engines loop over
//! the batch. Activations are `[H, W, C]` row-major. The `*_microflow`
//! weighted kernels consume **compile-time packed** layouts produced by
//! [`crate::compiler::pack`] and share the register-tiled
//! [`microkernel`] core: Conv2D filters arrive as `NR`-wide
//! output-channel panels ([`microkernel::PackedConvFilters`]),
//! DepthwiseConv2D filters pre-transposed to `[Cout, KH*KW]`, and
//! FullyConnected weights stay `[K, N]` walked through a tail-aware
//! panel view. The `*_interp` kernels keep the container layouts
//! (`[Cout, KH, KW, Cin]` / `[KH, KW, Cout]` / `[K, N]`), as TFLM must.

pub mod activation;
pub mod average_pool2d;
pub mod conv2d;
pub mod depthwise_conv2d;
pub mod fully_connected;
pub mod microkernel;
pub mod view;

pub use view::ConvGeometry;

use anyhow::{bail, ensure, Result};

use crate::format::mfb::Padding;

/// Output spatial dims for SAME/VALID padding (TFLite convention; mirrors
/// `ref.out_dims`).
///
/// Malformed geometry is an error, never a panic: a VALID kernel larger
/// than its input used to underflow-panic here on untrusted containers;
/// it now surfaces as a compile/prepare-time `Err`.
pub fn out_dims(
    h: usize,
    w: usize,
    kh: usize,
    kw: usize,
    sh: usize,
    sw: usize,
    padding: Padding,
) -> Result<(usize, usize)> {
    ensure!(sh > 0 && sw > 0, "stride {sh}x{sw} must be nonzero");
    ensure!(kh > 0 && kw > 0, "kernel {kh}x{kw} must be nonzero");
    match padding {
        Padding::Same => Ok((h.div_ceil(sh), w.div_ceil(sw))),
        Padding::Valid => match (h.checked_sub(kh), w.checked_sub(kw)) {
            (Some(dh), Some(dw)) => Ok((dh / sh + 1, dw / sw + 1)),
            _ => bail!("VALID padding: kernel {kh}x{kw} exceeds input {h}x{w}"),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn out_dims_same_vs_valid() {
        // 49x40, k 10x8, s 2x2 — the speech model's depthwise layer
        assert_eq!(out_dims(49, 40, 10, 8, 2, 2, Padding::Same).unwrap(), (25, 20));
        assert_eq!(out_dims(49, 40, 10, 8, 2, 2, Padding::Valid).unwrap(), (20, 17));
        // 96x96, k 3x3, s 2x2 — the person model's first conv
        assert_eq!(out_dims(96, 96, 3, 3, 2, 2, Padding::Same).unwrap(), (48, 48));
    }

    #[test]
    fn oversized_valid_kernel_is_an_error_not_a_panic() {
        // regression: kh > h used to underflow-panic
        let e = out_dims(5, 5, 10, 3, 1, 1, Padding::Valid).unwrap_err();
        assert!(e.to_string().contains("exceeds input"), "{e}");
        // kw > w independently
        assert!(out_dims(5, 5, 3, 10, 1, 1, Padding::Valid).is_err());
        // boundary: kernel exactly the input size is fine (1x1 output)
        assert_eq!(out_dims(5, 5, 5, 5, 1, 1, Padding::Valid).unwrap(), (1, 1));
        // SAME padding never underflows regardless of kernel size
        assert_eq!(out_dims(5, 5, 10, 10, 1, 1, Padding::Same).unwrap(), (5, 5));
    }

    #[test]
    fn degenerate_stride_and_kernel_are_errors() {
        assert!(out_dims(8, 8, 3, 3, 0, 1, Padding::Valid).is_err());
        assert!(out_dims(8, 8, 3, 3, 1, 0, Padding::Same).is_err());
        assert!(out_dims(8, 8, 0, 3, 1, 1, Padding::Valid).is_err());
    }
}
