//! View extraction — Algorithm 1 of the paper (DESIGN.md S10).
//!
//! Selects the receptive field feeding one output position, handling SAME
//! padding (fill with `z_x`, the quantized zero — making the `(X - z_X)`
//! factor vanish identically, equivalent to the paper's skip) and VALID
//! padding, with arbitrary strides.

use anyhow::Result;

use crate::format::mfb::Padding;

/// Static geometry of a convolution-like operator, computed once by the
/// compiler (never at inference time in the MicroFlow engine).
#[derive(Clone, Copy, Debug)]
pub struct ConvGeometry {
    pub in_h: usize,
    pub in_w: usize,
    pub in_c: usize,
    pub k_h: usize,
    pub k_w: usize,
    pub stride_h: usize,
    pub stride_w: usize,
    pub out_h: usize,
    pub out_w: usize,
    /// Top/left padding offsets (0 for VALID).
    pub pad_top: isize,
    pub pad_left: isize,
}

impl ConvGeometry {
    /// Validated geometry; errors (rather than panics) on kernels that
    /// exceed a VALID-padded input or zero strides — see
    /// [`super::out_dims`].
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        in_h: usize,
        in_w: usize,
        in_c: usize,
        k_h: usize,
        k_w: usize,
        stride_h: usize,
        stride_w: usize,
        padding: Padding,
    ) -> Result<Self> {
        let (out_h, out_w) = super::out_dims(in_h, in_w, k_h, k_w, stride_h, stride_w, padding)?;
        let (pad_top, pad_left) = match padding {
            Padding::Valid => (0isize, 0isize),
            Padding::Same => {
                // TFLite SAME: total = max((o-1)*s + k - in, 0), low half first
                let pad_h = ((out_h - 1) * stride_h + k_h).saturating_sub(in_h);
                let pad_w = ((out_w - 1) * stride_w + k_w).saturating_sub(in_w);
                ((pad_h / 2) as isize, (pad_w / 2) as isize)
            }
        };
        Ok(ConvGeometry { in_h, in_w, in_c, k_h, k_w, stride_h, stride_w, out_h, out_w, pad_top, pad_left })
    }

    /// Number of MACs per output position per output channel (dense conv).
    pub fn window_len(&self) -> usize {
        self.k_h * self.k_w
    }

    /// Extract the view for output position `(oy, ox)` into `view`
    /// (length `k_h * k_w * in_c`), filling out-of-bounds with `z_x`.
    ///
    /// This is Algorithm 1, specialized to one output position — the form
    /// the runtime kernels call in their hot loop.
    #[inline]
    pub fn extract_view(&self, input: &[i8], oy: usize, ox: usize, z_x: i8, view: &mut [i8]) {
        debug_assert_eq!(view.len(), self.k_h * self.k_w * self.in_c);
        debug_assert_eq!(input.len(), self.in_h * self.in_w * self.in_c);
        let base_y = (oy * self.stride_h) as isize - self.pad_top;
        let base_x = (ox * self.stride_w) as isize - self.pad_left;
        let c = self.in_c;
        let mut vi = 0usize;
        for ky in 0..self.k_h {
            let iy = base_y + ky as isize;
            if iy < 0 || iy >= self.in_h as isize {
                view[vi..vi + self.k_w * c].fill(z_x);
                vi += self.k_w * c;
                continue;
            }
            let row = iy as usize * self.in_w * c;
            for kx in 0..self.k_w {
                let ix = base_x + kx as isize;
                if ix < 0 || ix >= self.in_w as isize {
                    view[vi..vi + c].fill(z_x);
                } else {
                    let src = row + ix as usize * c;
                    view[vi..vi + c].copy_from_slice(&input[src..src + c]);
                }
                vi += c;
            }
        }
    }

    /// Bytes of scratch one view needs (the per-operator working set the
    /// static memory planner charges for conv kernels).
    pub fn view_bytes(&self) -> usize {
        self.k_h * self.k_w * self.in_c
    }

    /// True when the whole receptive field of output `(oy, ox)` lies
    /// inside the input (no padding in play). Interior positions never
    /// need [`extract_view`](Self::extract_view): each kernel row is a
    /// unit-stride span of the input that kernels borrow via
    /// [`row_offset`](Self::row_offset) instead of copying into the view
    /// buffer. Under VALID padding every position is interior.
    #[inline]
    pub fn interior(&self, oy: usize, ox: usize) -> bool {
        let base_y = (oy * self.stride_h) as isize - self.pad_top;
        let base_x = (ox * self.stride_w) as isize - self.pad_left;
        base_y >= 0
            && base_x >= 0
            && base_y + self.k_h as isize <= self.in_h as isize
            && base_x + self.k_w as isize <= self.in_w as isize
    }

    /// True when *any* output position needs padding (a non-interior
    /// receptive field). When false — every VALID-padded conv, and SAME
    /// geometries whose padding happens to be zero — the kernels never
    /// call [`extract_view`](Self::extract_view) and the planner charges
    /// no view scratch at all. The interiority constraints are monotone
    /// in `oy`/`ox` and separable, so checking the two extreme corners
    /// covers every position.
    pub fn has_boundary(&self) -> bool {
        !(self.interior(0, 0) && self.interior(self.out_h - 1, self.out_w - 1))
    }

    /// Flat input offset of kernel row `ky`'s first element for output
    /// `(oy, ox)`; the span `[off, off + k_w * in_c)` is contiguous in the
    /// input. Only valid for positions where [`interior`](Self::interior)
    /// holds (debug-asserted).
    #[inline]
    pub fn row_offset(&self, oy: usize, ox: usize, ky: usize) -> usize {
        debug_assert!(self.interior(oy, ox));
        let iy = ((oy * self.stride_h + ky) as isize - self.pad_top) as usize;
        let ix = ((ox * self.stride_w) as isize - self.pad_left) as usize;
        (iy * self.in_w + ix) * self.in_c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// 1-channel 3x3 input 1..9, identity for hand-checking.
    fn input3x3() -> Vec<i8> {
        (1..=9).collect()
    }

    #[test]
    fn valid_padding_center_view() {
        let g = ConvGeometry::new(3, 3, 1, 2, 2, 1, 1, Padding::Valid).unwrap();
        assert_eq!((g.out_h, g.out_w), (2, 2));
        let mut v = vec![0i8; 4];
        g.extract_view(&input3x3(), 0, 0, 0, &mut v);
        assert_eq!(v, vec![1, 2, 4, 5]);
        g.extract_view(&input3x3(), 1, 1, 0, &mut v);
        assert_eq!(v, vec![5, 6, 8, 9]);
    }

    #[test]
    fn same_padding_fills_zero_point() {
        let g = ConvGeometry::new(3, 3, 1, 3, 3, 1, 1, Padding::Same).unwrap();
        assert_eq!((g.out_h, g.out_w), (3, 3));
        let mut v = vec![0i8; 9];
        // top-left corner: first row and column padded with z_x = -7
        g.extract_view(&input3x3(), 0, 0, -7, &mut v);
        assert_eq!(v, vec![-7, -7, -7, -7, 1, 2, -7, 4, 5]);
    }

    #[test]
    fn stride_two_same_matches_tflite_offsets() {
        // 4x4 input, k3 s2 SAME -> out 2x2, pad_total = (2-1)*2+3-4 = 1 -> pad_top 0
        let g = ConvGeometry::new(4, 4, 1, 3, 3, 2, 2, Padding::Same).unwrap();
        assert_eq!((g.out_h, g.out_w), (2, 2));
        assert_eq!((g.pad_top, g.pad_left), (0, 0));
        let input: Vec<i8> = (1..=16).collect();
        let mut v = vec![0i8; 9];
        g.extract_view(&input, 1, 1, 0, &mut v);
        // base (2,2): rows 2..4, cols 2..4 with bottom/right padding
        assert_eq!(v, vec![11, 12, 0, 15, 16, 0, 0, 0, 0]);
    }

    #[test]
    fn interior_positions_match_extracted_views() {
        // every interior row span must hold exactly the bytes extract_view
        // copies; boundary positions must be flagged non-interior
        let g = ConvGeometry::new(5, 6, 2, 3, 3, 1, 1, Padding::Same).unwrap();
        let input: Vec<i8> = (0..(5 * 6 * 2)).map(|v| (v % 120) as i8).collect();
        let mut view = vec![0i8; 3 * 3 * 2];
        let row_len = g.k_w * g.in_c;
        for oy in 0..g.out_h {
            for ox in 0..g.out_w {
                g.extract_view(&input, oy, ox, -99, &mut view);
                if g.interior(oy, ox) {
                    for ky in 0..g.k_h {
                        let off = g.row_offset(oy, ox, ky);
                        assert_eq!(
                            &input[off..off + row_len],
                            &view[ky * row_len..(ky + 1) * row_len],
                            "({oy},{ox}) row {ky}"
                        );
                    }
                } else {
                    // non-interior: some slot must carry the pad value
                    // (-99 never occurs in the 0..119 input)
                    assert!(view.contains(&-99), "({oy},{ox}) flagged boundary but fully in-bounds");
                }
            }
        }
        // SAME 3x3 stride 1 on 5x6: exactly the 3x4 center is interior
        let n_interior = (0..g.out_h)
            .flat_map(|oy| (0..g.out_w).map(move |ox| (oy, ox)))
            .filter(|&(oy, ox)| g.interior(oy, ox))
            .count();
        assert_eq!(n_interior, 3 * 4);
    }

    #[test]
    fn valid_padding_is_all_interior() {
        let g = ConvGeometry::new(6, 6, 1, 3, 3, 2, 2, Padding::Valid).unwrap();
        for oy in 0..g.out_h {
            for ox in 0..g.out_w {
                assert!(g.interior(oy, ox));
            }
        }
        assert!(!g.has_boundary());
    }

    #[test]
    fn has_boundary_matches_exhaustive_scan() {
        for &(h, w, k, s, padding) in &[
            (5usize, 6usize, 3usize, 1usize, Padding::Same),
            (5, 6, 3, 1, Padding::Valid),
            (4, 4, 3, 2, Padding::Same), // pad_total 1 -> pad_top 0, but bottom overhang
            (4, 4, 1, 1, Padding::Same), // 1x1: SAME needs no padding at all
            (7, 3, 2, 2, Padding::Same),
        ] {
            let g = ConvGeometry::new(h, w, 1, k, k, s, s, padding).unwrap();
            let any_boundary = (0..g.out_h)
                .flat_map(|oy| (0..g.out_w).map(move |ox| (oy, ox)))
                .any(|(oy, ox)| !g.interior(oy, ox));
            assert_eq!(g.has_boundary(), any_boundary, "{h}x{w} k{k} s{s} {padding:?}");
        }
    }

    #[test]
    fn multichannel_view_is_channel_interleaved() {
        // 2x2x2 input: [[(1,2),(3,4)],[(5,6),(7,8)]]
        let input: Vec<i8> = (1..=8).collect();
        let g = ConvGeometry::new(2, 2, 2, 2, 2, 1, 1, Padding::Valid).unwrap();
        let mut v = vec![0i8; 8];
        g.extract_view(&input, 0, 0, 0, &mut v);
        assert_eq!(v, input);
    }
}
