//! Conv2D kernels — Eq. (6) / Appendix A.2 (DESIGN.md S9).
//!
//! Input `[H, W, Cin]`, output `[OH, OW, Cout]`. The MicroFlow variant
//! consumes filters **packed at compile time** by
//! [`crate::compiler::pack::pack_conv2d`] into `NR`-wide output-channel
//! panels and runs on the register-tiled
//! [`microkernel`](crate::kernels::microkernel) core: each input byte is
//! loaded once and feeds `NR` interleaved i32 accumulators, with the
//! Eq. 6 view sum folded into the first panel's walk. Interior output
//! positions (no padding in play) borrow their unit-stride rows straight
//! from the input via [`ConvGeometry::row_offset`]; only boundary
//! positions pay the Algorithm 1 copy into the view buffer.
//!
//! The interpreter variant keeps the container's `[Cout, KH, KW, Cin]`
//! row-major filters and the naive one-accumulator loop nest, as TFLM
//! must.

use crate::kernels::microkernel::backend::{self, KernelBackend};
use crate::kernels::microkernel::{PackedConvFilters, NR};
use crate::kernels::view::ConvGeometry;
use crate::tensor::fixedpoint::FixedPointMultiplier;
use crate::tensor::quant::{requant_float, PreComputed};

/// Requantize one panel's accumulators into the output channels it
/// covers; tail lanes past `panel_width` are computed-but-dropped.
#[inline(always)]
fn finish_panel(
    filters: &PackedConvFilters,
    p: usize,
    acc: &[i32; NR],
    zw_viewsum: i32,
    pc: &PreComputed,
    out: &mut [i8],
) {
    for r in 0..filters.panel_width(p) {
        let co = p * NR + r;
        let a = acc[r] - zw_viewsum - pc.w_zp_term[co] + pc.kzxzw;
        out[co] = requant_float(a, pc.const_bias[co], pc.scale_ratio, pc.act_min, pc.act_max);
    }
}

/// MicroFlow Conv2D: packed panels + folded constants + float epilogue.
///
/// `pc.w_zp_term[co]` folds `z_X * Σ F[co]`; `pc.kzxzw` folds
/// `KH*KW*Cin * z_X * z_F`; `pc.const_bias[co]` folds the bias term.
/// Bit-identical to the unpacked Eq. 6 reference (exact i32 accumulation;
/// see `tests/pack_equivalence.rs`).
pub fn conv2d_microflow(
    input: &[i8],
    filters: &PackedConvFilters,
    geo: &ConvGeometry,
    z_x: i8,
    pc: &PreComputed,
    view: &mut [i8],
    out: &mut [i8],
) {
    conv2d_microflow_with(backend::active(), input, filters, geo, z_x, pc, view, out);
}

/// [`conv2d_microflow`] on an explicit [`KernelBackend`]. The engine
/// passes the process-wide selection resolved at session construction;
/// the conformance sweeps (`tests/pack_equivalence.rs`) force every
/// *available* backend through here and hold each to the same oracle.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_microflow_with(
    kb: &dyn KernelBackend,
    input: &[i8],
    filters: &PackedConvFilters,
    geo: &ConvGeometry,
    z_x: i8,
    pc: &PreComputed,
    view: &mut [i8],
    out: &mut [i8],
) {
    let c_out = filters.c_out;
    let kkc = geo.k_h * geo.k_w * geo.in_c;
    debug_assert_eq!(filters.kkc, kkc);
    // an all-interior geometry (every VALID conv) never stages a view, so
    // the planner passes no scratch at all
    debug_assert!(
        view.len() == kkc || (view.is_empty() && !geo.has_boundary()),
        "view scratch must hold one full view when padding is in play"
    );
    debug_assert_eq!(input.len(), geo.in_h * geo.in_w * geo.in_c);
    debug_assert_eq!(out.len(), geo.out_h * geo.out_w * c_out);
    // both per-channel tables are indexed up to c_out by finish_panel —
    // a mismatched PreComputed must fail here, at the precondition, not
    // deep inside the hot loop
    debug_assert_eq!(pc.const_bias.len(), c_out);
    debug_assert_eq!(pc.w_zp_term.len(), c_out);

    let row_len = geo.k_w * geo.in_c;
    let need_sum = pc.z_w != 0;
    for oy in 0..geo.out_h {
        for ox in 0..geo.out_w {
            let base = (oy * geo.out_w + ox) * c_out;
            let pos_out = &mut out[base..base + c_out];
            // the z_F correction term of Eq. 6, filled by the first
            // panel's fused walk when z_W != 0
            let mut viewsum = 0i32;
            // the interior and boundary branches repeat the panel-walk
            // protocol on purpose: each keeps its hot loop over concrete
            // slice patterns (borrowed rows vs the staged view) so the
            // micro-kernel inlines without an abstraction layer between
            // it and the segment source; pack_equivalence.rs holds both
            // branches to the same oracle
            if geo.interior(oy, ox) {
                // fast path: borrow the unit-stride rows from the input
                for p in 0..filters.panels() {
                    let panel = filters.panel(p);
                    let mut acc = [0i32; NR];
                    for ky in 0..geo.k_h {
                        let off = geo.row_offset(oy, ox, ky);
                        let seg = &input[off..off + row_len];
                        let pseg = &panel[ky * row_len * NR..(ky + 1) * row_len * NR];
                        if need_sum && p == 0 {
                            kb.dot4_sum(seg, pseg, &mut acc, &mut viewsum);
                        } else {
                            kb.dot4(seg, pseg, &mut acc);
                        }
                    }
                    finish_panel(filters, p, &acc, pc.z_w * viewsum, pc, pos_out);
                }
            } else {
                // boundary: Algorithm 1 copy (pads with z_x), then the
                // same panel walks over the staged view
                geo.extract_view(input, oy, ox, z_x, view);
                for p in 0..filters.panels() {
                    let panel = filters.panel(p);
                    let mut acc = [0i32; NR];
                    if need_sum && p == 0 {
                        kb.dot4_sum(view, panel, &mut acc, &mut viewsum);
                    } else {
                        kb.dot4(view, panel, &mut acc);
                    }
                    finish_panel(filters, p, &acc, pc.z_w * viewsum, pc, pos_out);
                }
            }
        }
    }
}

/// TFLM-style Conv2D: per-element offsets + int32 bias + fixed point.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_interp(
    input: &[i8],
    filters: &[i8],
    bias: &[i32],
    geo: &ConvGeometry,
    c_out: usize,
    z_x: i32,
    z_f: i32,
    multiplier: FixedPointMultiplier,
    z_y: i32,
    act_min: i8,
    act_max: i8,
    view: &mut [i8],
    out: &mut [i8],
) {
    let kkc = geo.k_h * geo.k_w * geo.in_c;
    debug_assert_eq!(filters.len(), c_out * kkc);
    debug_assert_eq!(bias.len(), c_out);
    debug_assert_eq!(view.len(), kkc);
    debug_assert_eq!(input.len(), geo.in_h * geo.in_w * geo.in_c);
    debug_assert_eq!(out.len(), geo.out_h * geo.out_w * c_out);
    for oy in 0..geo.out_h {
        for ox in 0..geo.out_w {
            geo.extract_view(input, oy, ox, z_x as i8, view);
            let base = (oy * geo.out_w + ox) * c_out;
            for co in 0..c_out {
                let f = &filters[co * kkc..(co + 1) * kkc];
                let mut acc = 0i32;
                for (v, w) in view.iter().zip(f) {
                    acc += (*v as i32 - z_x) * (*w as i32 - z_f);
                }
                acc += bias[co];
                out[base + co] = multiplier.requant(acc, z_y, act_min, act_max);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::pack::pack_conv2d;
    use crate::format::mfb::Padding;
    use crate::tensor::quant::FusedAct;
    use crate::util::Prng;

    /// f64 brute-force of Eq. (6) over the same view extraction.
    #[allow(clippy::too_many_arguments)]
    fn oracle(
        input: &[i8],
        filters: &[i8],
        bias: &[i32],
        geo: &ConvGeometry,
        c_out: usize,
        s_x: f32,
        z_x: i32,
        s_f: f32,
        z_f: i32,
        s_y: f32,
        z_y: i32,
        act: FusedAct,
    ) -> Vec<i8> {
        let kkc = geo.k_h * geo.k_w * geo.in_c;
        let (lo, hi) = act.bounds(s_y, z_y);
        let mut view = vec![0i8; kkc];
        let mut out = vec![0i8; geo.out_h * geo.out_w * c_out];
        for oy in 0..geo.out_h {
            for ox in 0..geo.out_w {
                geo.extract_view(input, oy, ox, z_x as i8, &mut view);
                for co in 0..c_out {
                    let f = &filters[co * kkc..(co + 1) * kkc];
                    let mut acc = 0i64;
                    for (v, w) in view.iter().zip(f) {
                        acc += (*v as i64 - z_x as i64) * (*w as i64 - z_f as i64);
                    }
                    let cb = z_y as f32 + ((s_x * s_f) / s_y) * bias[co] as f32;
                    let y = cb + (s_x * s_f / s_y) * acc as f32;
                    out[(oy * geo.out_w + ox) * c_out + co] =
                        y.round().clamp(lo as f32, hi as f32) as i8;
                }
            }
        }
        out
    }

    #[test]
    fn microflow_matches_literal_eq6() {
        let mut rng = Prng::new(3);
        for &(padding, stride) in
            &[(Padding::Same, 1), (Padding::Same, 2), (Padding::Valid, 1), (Padding::Valid, 2)]
        {
            // cout = 5 exercises the zero-padded tail panel
            let (h, w, cin, cout, k) = (7, 6, 3, 5, 3);
            let geo = ConvGeometry::new(h, w, cin, k, k, stride, stride, padding).unwrap();
            let input = rng.i8_vec(h * w * cin);
            let filters = rng.i8_vec(cout * k * k * cin);
            let bias = rng.i32_vec(cout, -1000, 1000);
            let (s_x, z_x, s_f, z_f, s_y, z_y) = (0.04f32, -3, 0.02f32, 1, 0.06f32, 7);
            let kkc = k * k * cin;
            let colsum: Vec<i32> = (0..cout)
                .map(|co| filters[co * kkc..(co + 1) * kkc].iter().map(|&v| v as i32).sum())
                .collect();
            let pc = PreComputed::fold(
                &bias, &colsum, kkc, s_x, z_x, s_f, z_f, s_x * s_f, 0, s_y, z_y, FusedAct::Relu6,
            );
            let packed = pack_conv2d(&filters, cout, kkc);
            let mut view = vec![0i8; kkc];
            let mut out = vec![0i8; geo.out_h * geo.out_w * cout];
            conv2d_microflow(&input, &packed, &geo, z_x as i8, &pc, &mut view, &mut out);
            let want = oracle(
                &input, &filters, &bias, &geo, cout, s_x, z_x, s_f, z_f, s_y, z_y, FusedAct::Relu6,
            );
            assert_eq!(out, want, "padding {padding:?} stride {stride}");
        }
    }

    #[test]
    fn interp_within_one_unit() {
        let mut rng = Prng::new(8);
        let (h, w, cin, cout, k) = (6, 6, 2, 3, 3);
        let geo = ConvGeometry::new(h, w, cin, k, k, 1, 1, Padding::Same).unwrap();
        let input = rng.i8_vec(h * w * cin);
        let filters = rng.i8_vec(cout * k * k * cin);
        let bias = rng.i32_vec(cout, -500, 500);
        let (s_x, z_x, s_f, z_f, s_y, z_y) = (0.03f32, 2, 0.01f32, 0, 0.05f32, -9);
        let kkc = k * k * cin;
        let colsum: Vec<i32> = (0..cout)
            .map(|co| filters[co * kkc..(co + 1) * kkc].iter().map(|&v| v as i32).sum())
            .collect();
        let pc =
            PreComputed::fold(&bias, &colsum, kkc, s_x, z_x, s_f, z_f, s_x * s_f, 0, s_y, z_y, FusedAct::None);
        let packed = pack_conv2d(&filters, cout, kkc);
        let mut view = vec![0i8; kkc];
        let mut mf = vec![0i8; geo.out_h * geo.out_w * cout];
        conv2d_microflow(&input, &packed, &geo, z_x as i8, &pc, &mut view, &mut mf);
        let m = FixedPointMultiplier::from_real((s_x as f64 * s_f as f64) / s_y as f64);
        let mut ip = vec![0i8; mf.len()];
        conv2d_interp(
            &input, &filters, &bias, &geo, cout, z_x, z_f, m, z_y, -128, 127, &mut view, &mut ip,
        );
        let worst =
            mf.iter().zip(&ip).map(|(a, b)| (*a as i32 - *b as i32).abs()).max().unwrap();
        assert!(worst <= 1, "worst deviation {worst}");
    }

    #[test]
    fn one_by_one_conv_is_a_per_pixel_matmul() {
        // pointwise conv (the MobileNet pw layers) sanity: k=1, padding
        // irrelevant, each output pixel independent
        let mut rng = Prng::new(4);
        let (h, w, cin, cout) = (3, 3, 4, 5);
        let geo = ConvGeometry::new(h, w, cin, 1, 1, 1, 1, Padding::Same).unwrap();
        assert_eq!((geo.out_h, geo.out_w), (3, 3));
        let input = rng.i8_vec(h * w * cin);
        let filters = rng.i8_vec(cout * cin);
        let bias = vec![0i32; cout];
        let colsum: Vec<i32> = (0..cout)
            .map(|co| filters[co * cin..(co + 1) * cin].iter().map(|&v| v as i32).sum())
            .collect();
        let pc = PreComputed::fold(&bias, &colsum, cin, 0.1, 0, 0.1, 0, 0.01, 0, 0.2, 0, FusedAct::None);
        let packed = pack_conv2d(&filters, cout, cin);
        let mut view = vec![0i8; cin];
        let mut out = vec![0i8; h * w * cout];
        conv2d_microflow(&input, &packed, &geo, 0, &pc, &mut view, &mut out);
        // manual check for pixel (1,1), channel 2
        let px = &input[(1 * w + 1) * cin..(1 * w + 1) * cin + cin];
        let f = &filters[2 * cin..3 * cin];
        let dot: i32 = px.iter().zip(f).map(|(a, b)| *a as i32 * *b as i32).sum();
        let want = (0.05f32 * dot as f32).round().clamp(-128.0, 127.0) as i8;
        assert_eq!(out[(1 * w + 1) * cout + 2], want);
    }
}
