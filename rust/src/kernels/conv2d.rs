//! Conv2D kernels — Eq. (6) / Appendix A.2 (DESIGN.md S9).
//!
//! Input `[H, W, Cin]`, filters `[Cout, KH, KW, Cin]` row-major, output
//! `[OH, OW, Cout]`. View extraction is Algorithm 1 via
//! [`ConvGeometry::extract_view`]; the extracted patch (`KH*KW*Cin`) is the
//! operator's scratch working set charged by the static memory planner.

use crate::kernels::view::ConvGeometry;
use crate::tensor::fixedpoint::FixedPointMultiplier;
use crate::tensor::quant::{requant_float, PreComputed};

/// MicroFlow Conv2D: folded constants + float epilogue.
///
/// `pc.w_zp_term[co]` folds `z_X * Σ F[co]`; `pc.kzxzw` folds
/// `KH*KW*Cin * z_X * z_F`; `pc.const_bias[co]` folds the bias term.
pub fn conv2d_microflow(
    input: &[i8],
    filters: &[i8],
    geo: &ConvGeometry,
    c_out: usize,
    z_x: i8,
    pc: &PreComputed,
    view: &mut [i8],
    out: &mut [i8],
) {
    let kkc = geo.k_h * geo.k_w * geo.in_c;
    debug_assert_eq!(filters.len(), c_out * kkc);
    debug_assert_eq!(view.len(), kkc);
    debug_assert_eq!(out.len(), geo.out_h * geo.out_w * c_out);

    // pointwise fast path: a 1x1 stride-1 conv never needs view
    // extraction — the "view" IS the pixel. This is the dominant layer
    // class of MobileNet (13 of the person model's 14 dense convs);
    // skipping the per-position copy buys ~25% (EXPERIMENTS.md §Perf).
    if geo.k_h == 1 && geo.k_w == 1 && geo.stride_h == 1 && geo.stride_w == 1 {
        let c_in = geo.in_c;
        for (px, pixel) in input.chunks_exact(c_in).enumerate() {
            let viewsum: i32 =
                if pc.z_w != 0 { pixel.iter().map(|&v| v as i32).sum() } else { 0 };
            let base = px * c_out;
            for (co, f) in filters.chunks_exact(c_in).enumerate() {
                let mut dot = 0i32;
                for (v, w) in pixel.iter().zip(f) {
                    dot += *v as i32 * *w as i32;
                }
                let acc = dot - pc.z_w * viewsum - pc.w_zp_term[co] + pc.kzxzw;
                out[base + co] =
                    requant_float(acc, pc.const_bias[co], pc.scale_ratio, pc.act_min, pc.act_max);
            }
        }
        return;
    }

    for oy in 0..geo.out_h {
        for ox in 0..geo.out_w {
            geo.extract_view(input, oy, ox, z_x, view);
            // data-dependent view sum (the z_F correction term of Eq. 6)
            let viewsum: i32 = if pc.z_w != 0 { view.iter().map(|&v| v as i32).sum() } else { 0 };
            let base = (oy * geo.out_w + ox) * c_out;
            for co in 0..c_out {
                let f = &filters[co * kkc..(co + 1) * kkc];
                let mut dot = 0i32;
                for (v, w) in view.iter().zip(f) {
                    dot += *v as i32 * *w as i32;
                }
                let acc = dot - pc.z_w * viewsum - pc.w_zp_term[co] + pc.kzxzw;
                out[base + co] =
                    requant_float(acc, pc.const_bias[co], pc.scale_ratio, pc.act_min, pc.act_max);
            }
        }
    }
}

/// TFLM-style Conv2D: per-element offsets + int32 bias + fixed point.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_interp(
    input: &[i8],
    filters: &[i8],
    bias: &[i32],
    geo: &ConvGeometry,
    c_out: usize,
    z_x: i32,
    z_f: i32,
    multiplier: FixedPointMultiplier,
    z_y: i32,
    act_min: i8,
    act_max: i8,
    view: &mut [i8],
    out: &mut [i8],
) {
    let kkc = geo.k_h * geo.k_w * geo.in_c;
    for oy in 0..geo.out_h {
        for ox in 0..geo.out_w {
            geo.extract_view(input, oy, ox, z_x as i8, view);
            let base = (oy * geo.out_w + ox) * c_out;
            for co in 0..c_out {
                let f = &filters[co * kkc..(co + 1) * kkc];
                let mut acc = 0i32;
                for (v, w) in view.iter().zip(f) {
                    acc += (*v as i32 - z_x) * (*w as i32 - z_f);
                }
                acc += bias[co];
                out[base + co] = multiplier.requant(acc, z_y, act_min, act_max);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::format::mfb::Padding;
    use crate::tensor::quant::FusedAct;
    use crate::util::Prng;

    /// f64 brute-force of Eq. (6) over the same view extraction.
    #[allow(clippy::too_many_arguments)]
    fn oracle(
        input: &[i8],
        filters: &[i8],
        bias: &[i32],
        geo: &ConvGeometry,
        c_out: usize,
        s_x: f32,
        z_x: i32,
        s_f: f32,
        z_f: i32,
        s_y: f32,
        z_y: i32,
        act: FusedAct,
    ) -> Vec<i8> {
        let kkc = geo.k_h * geo.k_w * geo.in_c;
        let (lo, hi) = act.bounds(s_y, z_y);
        let mut view = vec![0i8; kkc];
        let mut out = vec![0i8; geo.out_h * geo.out_w * c_out];
        for oy in 0..geo.out_h {
            for ox in 0..geo.out_w {
                geo.extract_view(input, oy, ox, z_x as i8, &mut view);
                for co in 0..c_out {
                    let f = &filters[co * kkc..(co + 1) * kkc];
                    let mut acc = 0i64;
                    for (v, w) in view.iter().zip(f) {
                        acc += (*v as i64 - z_x as i64) * (*w as i64 - z_f as i64);
                    }
                    let cb = z_y as f32 + ((s_x * s_f) / s_y) * bias[co] as f32;
                    let y = cb + (s_x * s_f / s_y) * acc as f32;
                    out[(oy * geo.out_w + ox) * c_out + co] =
                        y.round().clamp(lo as f32, hi as f32) as i8;
                }
            }
        }
        out
    }

    #[test]
    fn microflow_matches_literal_eq6() {
        let mut rng = Prng::new(3);
        for &(padding, stride) in
            &[(Padding::Same, 1), (Padding::Same, 2), (Padding::Valid, 1), (Padding::Valid, 2)]
        {
            let (h, w, cin, cout, k) = (7, 6, 3, 4, 3);
            let geo = ConvGeometry::new(h, w, cin, k, k, stride, stride, padding).unwrap();
            let input = rng.i8_vec(h * w * cin);
            let filters = rng.i8_vec(cout * k * k * cin);
            let bias = rng.i32_vec(cout, -1000, 1000);
            let (s_x, z_x, s_f, z_f, s_y, z_y) = (0.04f32, -3, 0.02f32, 1, 0.06f32, 7);
            let kkc = k * k * cin;
            let colsum: Vec<i32> = (0..cout)
                .map(|co| filters[co * kkc..(co + 1) * kkc].iter().map(|&v| v as i32).sum())
                .collect();
            let pc = PreComputed::fold(
                &bias, &colsum, kkc, s_x, z_x, s_f, z_f, s_x * s_f, 0, s_y, z_y, FusedAct::Relu6,
            );
            let mut view = vec![0i8; kkc];
            let mut out = vec![0i8; geo.out_h * geo.out_w * cout];
            conv2d_microflow(&input, &filters, &geo, cout, z_x as i8, &pc, &mut view, &mut out);
            let want = oracle(
                &input, &filters, &bias, &geo, cout, s_x, z_x, s_f, z_f, s_y, z_y, FusedAct::Relu6,
            );
            assert_eq!(out, want, "padding {padding:?} stride {stride}");
        }
    }

    #[test]
    fn interp_within_one_unit() {
        let mut rng = Prng::new(8);
        let (h, w, cin, cout, k) = (6, 6, 2, 3, 3);
        let geo = ConvGeometry::new(h, w, cin, k, k, 1, 1, Padding::Same).unwrap();
        let input = rng.i8_vec(h * w * cin);
        let filters = rng.i8_vec(cout * k * k * cin);
        let bias = rng.i32_vec(cout, -500, 500);
        let (s_x, z_x, s_f, z_f, s_y, z_y) = (0.03f32, 2, 0.01f32, 0, 0.05f32, -9);
        let kkc = k * k * cin;
        let colsum: Vec<i32> = (0..cout)
            .map(|co| filters[co * kkc..(co + 1) * kkc].iter().map(|&v| v as i32).sum())
            .collect();
        let pc =
            PreComputed::fold(&bias, &colsum, kkc, s_x, z_x, s_f, z_f, s_x * s_f, 0, s_y, z_y, FusedAct::None);
        let mut view = vec![0i8; kkc];
        let mut mf = vec![0i8; geo.out_h * geo.out_w * cout];
        conv2d_microflow(&input, &filters, &geo, cout, z_x as i8, &pc, &mut view, &mut mf);
        let m = FixedPointMultiplier::from_real((s_x as f64 * s_f as f64) / s_y as f64);
        let mut ip = vec![0i8; mf.len()];
        conv2d_interp(
            &input, &filters, &bias, &geo, cout, z_x, z_f, m, z_y, -128, 127, &mut view, &mut ip,
        );
        let worst =
            mf.iter().zip(&ip).map(|(a, b)| (*a as i32 - *b as i32).abs()).max().unwrap();
        assert!(worst <= 1, "worst deviation {worst}");
    }

    #[test]
    fn one_by_one_conv_is_a_per_pixel_matmul() {
        // pointwise conv (the MobileNet pw layers) sanity: k=1, padding
        // irrelevant, each output pixel independent
        let mut rng = Prng::new(4);
        let (h, w, cin, cout) = (3, 3, 4, 5);
        let geo = ConvGeometry::new(h, w, cin, 1, 1, 1, 1, Padding::Same).unwrap();
        assert_eq!((geo.out_h, geo.out_w), (3, 3));
        let input = rng.i8_vec(h * w * cin);
        let filters = rng.i8_vec(cout * cin);
        let bias = vec![0i32; cout];
        let colsum: Vec<i32> = (0..cout)
            .map(|co| filters[co * cin..(co + 1) * cin].iter().map(|&v| v as i32).sum())
            .collect();
        let pc = PreComputed::fold(&bias, &colsum, cin, 0.1, 0, 0.1, 0, 0.01, 0, 0.2, 0, FusedAct::None);
        let mut view = vec![0i8; cin];
        let mut out = vec![0i8; h * w * cout];
        conv2d_microflow(&input, &filters, &geo, cout, 0, &pc, &mut view, &mut out);
        // manual check for pixel (1,1), channel 2
        let px = &input[(1 * w + 1) * cin..(1 * w + 1) * cin + cin];
        let f = &filters[2 * cin..3 * cin];
        let dot: i32 = px.iter().zip(f).map(|(a, b)| *a as i32 * *b as i32).sum();
        let want = (0.05f32 * dot as f32).round().clamp(-128.0, 127.0) as i8;
        assert_eq!(out[(1 * w + 1) * cout + 2], want);
    }
}
