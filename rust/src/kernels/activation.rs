//! Standalone activation kernels — Eqs. (14), (16), (18) (DESIGN.md S11).
//!
//! Fused activations are just clamp bounds inside the matmul epilogues
//! (Eqs. 15/17; see `FusedAct::bounds`). These standalone kernels cover
//! activations appearing as their own graph ops — in our models only
//! Softmax does, but ReLU/ReLU6 are implemented and exported for
//! completeness (paper Table 2 lists them as operators).

use crate::tensor::quant::{requant_float, round_half_away_i32, INT8_MAX, INT8_MIN};

/// Standalone quantized ReLU (Eq. 14).
pub fn relu(x: &[i8], s_x: f32, z_x: i32, s_y: f32, z_y: i32, out: &mut [i8]) {
    let ratio = s_x / s_y;
    for (o, &xi) in out.iter_mut().zip(x) {
        let xq = xi as i32;
        *o = if xq < z_x {
            z_y.clamp(INT8_MIN, INT8_MAX) as i8
        } else {
            requant_float(xq - z_x, z_y as f32, ratio, INT8_MIN as i8, INT8_MAX as i8)
        };
    }
}

/// Standalone quantized ReLU6 (Eq. 16).
pub fn relu6(x: &[i8], s_x: f32, z_x: i32, s_y: f32, z_y: i32, out: &mut [i8]) {
    let ratio = s_x / s_y;
    let knee = z_x as f32 + 6.0 / s_x;
    let top = z_y as f32 + 6.0 / s_y;
    for (o, &xi) in out.iter_mut().zip(x) {
        let xq = xi as i32;
        let y = if (xq as f32) >= knee {
            top
        } else if xq < z_x {
            z_y as f32
        } else {
            z_y as f32 + ratio * (xq - z_x) as f32
        };
        *o = round_half_away_i32(y).clamp(INT8_MIN, INT8_MAX) as i8;
    }
}

/// Quantized Softmax over the last axis (Eq. 18), max-subtracted for
/// stability — algebraically identical (the max and z_x terms cancel in
/// the ratio). Matches `ref.softmax` bit-exactly.
pub fn softmax(x: &[i8], s_x: f32, z_x: i32, s_y: f32, z_y: i32, out: &mut [i8]) {
    debug_assert_eq!(x.len(), out.len());
    let xf: Vec<f32> = x.iter().map(|&v| s_x * (v as i32 - z_x) as f32).collect();
    let max = xf.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let e: Vec<f32> = xf.iter().map(|&v| (v - max).exp()).collect();
    let sum: f32 = e.iter().sum();
    for (o, ei) in out.iter_mut().zip(&e) {
        let p = ei / sum;
        let y = z_y as f32 + p / s_y;
        *o = round_half_away_i32(y).clamp(INT8_MIN, INT8_MAX) as i8;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn relu_zeros_below_zero_point() {
        let x = [-10i8, -1, 0, 1, 10];
        let mut out = [0i8; 5];
        relu(&x, 0.5, 0, 0.5, 0, &mut out);
        assert_eq!(out, [0, 0, 0, 1, 10]);
    }

    #[test]
    fn relu_rescales_when_scales_differ() {
        let x = [4i8];
        let mut out = [0i8; 1];
        // s_x/s_y = 2, z_x = 2, z_y = -1: y = -1 + 2*(4-2) = 3
        relu(&x, 1.0, 2, 0.5, -1, &mut out);
        assert_eq!(out, [3]);
    }

    #[test]
    fn relu6_saturates_at_six() {
        // s = 0.1, z = 0: 6/s = 60
        let x = [-5i8, 0, 30, 59, 60, 100];
        let mut out = [0i8; 6];
        relu6(&x, 0.1, 0, 0.1, 0, &mut out);
        assert_eq!(out, [0, 0, 30, 59, 60, 60]);
    }

    #[test]
    fn softmax_probabilities_sum_to_one() {
        // TFLite convention: s_y = 1/256, z_y = -128; sum of (q + 128) ≈ 256
        let x = [10i8, 20, 30, -5];
        let mut out = [0i8; 4];
        softmax(&x, 0.1, 0, 1.0 / 256.0, -128, &mut out);
        let total: i32 = out.iter().map(|&q| q as i32 + 128).sum();
        assert!((total - 256).abs() <= 2, "total {total}");
        // monotone: larger logit -> larger prob
        assert!(out[2] > out[1] && out[1] > out[0] && out[0] > out[3]);
    }

    #[test]
    fn softmax_uniform_on_equal_logits() {
        let x = [7i8; 4];
        let mut out = [0i8; 4];
        softmax(&x, 0.1, 0, 1.0 / 256.0, -128, &mut out);
        // p = 0.25 -> q = -128 + 64 = -64
        assert_eq!(out, [-64; 4]);
    }

    #[test]
    fn softmax_is_shift_invariant() {
        let a = [0i8, 10, 20, 30];
        let b = [50i8, 60, 70, 80]; // shifted by +50 quant units
        let (mut oa, mut ob) = ([0i8; 4], [0i8; 4]);
        softmax(&a, 0.05, 0, 1.0 / 256.0, -128, &mut oa);
        softmax(&b, 0.05, 0, 1.0 / 256.0, -128, &mut ob);
        assert_eq!(oa, ob);
    }
}
