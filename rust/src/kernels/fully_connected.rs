//! FullyConnected kernels — Eq. (3) / Appendix A.1 (DESIGN.md S9).
//!
//! Weights are `[K, N]` row-major (TFLite stores `[N, K]`; the exporter
//! emits `[K, N]` so each row holds all `N` per-channel weights
//! contiguously). The MicroFlow variant walks them through the compiler's
//! tail-aware panel view ([`crate::compiler::pack::fc_panels`]): `N/NR`
//! register-tiled column panels on the shared
//! [`microkernel`](crate::kernels::microkernel) core — four i32
//! accumulators in registers per walk, each input byte feeding four
//! output neurons — plus one `N % NR`-wide tail walk. No accumulator
//! scratch exists anywhere: the old wide-output path staged `N` i32s in a
//! plan-threaded buffer; register tiling removed that buffer from the
//! plan, the executor and the memory model entirely.
//!
//! Trade-off, stated explicitly: the panel walk reads `w` column-block
//! by column-block (`N/NR` passes of 4 contiguous bytes per row) instead
//! of the old single sequential row sweep, exchanging the sweep's `N`
//! i32 accumulator loads+stores per row for re-walked weight lines. At
//! this repo's FC shapes (≤ 32 kB of weights) every pass after the first
//! is cache-resident, and on the paper's cache-less MCU targets a layer
//! too big to re-stream from Flash is exactly what the paged executor
//! ([`fully_connected_paged`], one sequential column per pass) is for.
//!
//! Three variants:
//! * [`fully_connected_microflow`] — folded constants + float epilogue;
//! * [`fully_connected_paged`]     — the Sec. 4.3 paging execution: one
//!   output neuron's weights are staged into a page buffer at a time;
//! * [`fully_connected_interp`]    — TFLM-style per-element offsets +
//!   gemmlowp fixed-point epilogue.

use crate::kernels::microkernel::backend::{self, KernelBackend};
use crate::kernels::microkernel::{self, NR};
use crate::tensor::fixedpoint::FixedPointMultiplier;
use crate::tensor::quant::{requant_float, PreComputed};

/// MicroFlow FC: `y[j] = requant(dot[j] - z_w*rowsum - wzp[j] + kzxzw)`.
///
/// `x`: `[K]`, `w`: `[K, N]` row-major, `out`: `[N]`. Register-tiled
/// panel walk; bit-identical to the scalar Eq. 3 reference (exact i32
/// accumulation — see `tests/pack_equivalence.rs`) and allocation-free.
pub fn fully_connected_microflow(
    x: &[i8],
    w: &[i8],
    k: usize,
    n: usize,
    pc: &PreComputed,
    out: &mut [i8],
) {
    fully_connected_microflow_with(backend::active(), x, w, k, n, pc, out);
}

/// [`fully_connected_microflow`] on an explicit [`KernelBackend`] (see
/// the note on [`crate::kernels::conv2d::conv2d_microflow_with`]).
pub fn fully_connected_microflow_with(
    kb: &dyn KernelBackend,
    x: &[i8],
    w: &[i8],
    k: usize,
    n: usize,
    pc: &PreComputed,
    out: &mut [i8],
) {
    debug_assert_eq!(x.len(), k);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(out.len(), n);
    // both per-channel tables are indexed up to n by the epilogues below
    debug_assert_eq!(pc.const_bias.len(), n);
    debug_assert_eq!(pc.w_zp_term.len(), n);

    // data-dependent row sum (the only z_w term that cannot be folded)
    let rowsum: i32 = if pc.z_w != 0 { x.iter().map(|&v| v as i32).sum() } else { 0 };
    let zw_rowsum = pc.z_w * rowsum;

    let (full, tail) = microkernel::fc_panels(n);
    for p in 0..full {
        let j0 = p * NR;
        let mut acc = [0i32; NR];
        kb.dot4_cols(x, w, n, j0, &mut acc);
        for r in 0..NR {
            let j = j0 + r;
            let a = acc[r] - zw_rowsum - pc.w_zp_term[j] + pc.kzxzw;
            out[j] = requant_float(a, pc.const_bias[j], pc.scale_ratio, pc.act_min, pc.act_max);
        }
    }
    if tail > 0 {
        let j0 = full * NR;
        let mut acc = [0i32; NR];
        kb.dot_cols(x, w, n, j0, tail, &mut acc);
        for r in 0..tail {
            let j = j0 + r;
            let a = acc[r] - zw_rowsum - pc.w_zp_term[j] + pc.kzxzw;
            out[j] = requant_float(a, pc.const_bias[j], pc.scale_ratio, pc.act_min, pc.act_max);
        }
    }
}

/// Paged MicroFlow FC (paper Sec. 4.3, Fig. 6).
///
/// One *page* holds the connections feeding a single output neuron:
/// `page_buf` (length `K`) is loaded from the `[K, N]` weight matrix
/// column-by-column — modelling the Flash→RAM stage on a 2 kB device —
/// then reduced with a single accumulator. RAM high-water mark per page:
/// `K` weights + `K` inputs + 1 int32 accumulator + epilogue constants
/// (the paper's 163-byte example for K = 32 — see `sim::memory_model`).
pub fn fully_connected_paged(
    x: &[i8],
    w: &[i8],
    k: usize,
    n: usize,
    pc: &PreComputed,
    page_buf: &mut [i8],
    out: &mut [i8],
) {
    debug_assert_eq!(page_buf.len(), k);
    debug_assert_eq!(x.len(), k);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(out.len(), n);
    // same per-channel-table precondition as the unpaged variant
    debug_assert_eq!(pc.const_bias.len(), n);
    debug_assert_eq!(pc.w_zp_term.len(), n);
    let rowsum: i32 = if pc.z_w != 0 { x.iter().map(|&v| v as i32).sum() } else { 0 };
    for j in 0..n {
        // stage the page: column j of w (strided in Flash, contiguous in RAM)
        for i in 0..k {
            page_buf[i] = w[i * n + j];
        }
        let mut acc = 0i32;
        for i in 0..k {
            acc += x[i] as i32 * page_buf[i] as i32;
        }
        let a = acc - pc.z_w * rowsum - pc.w_zp_term[j] + pc.kzxzw;
        out[j] = requant_float(a, pc.const_bias[j], pc.scale_ratio, pc.act_min, pc.act_max);
    }
}

/// TFLM-style FC: per-element zero-point application + int32 bias + fixed
/// point requantization. No folded constants — this is what an interpreter
/// that cannot pre-process does per inference.
#[allow(clippy::too_many_arguments)]
pub fn fully_connected_interp(
    x: &[i8],
    w: &[i8],
    bias: &[i32],
    k: usize,
    n: usize,
    z_x: i32,
    z_w: i32,
    multiplier: FixedPointMultiplier,
    z_y: i32,
    act_min: i8,
    act_max: i8,
    out: &mut [i8],
) {
    debug_assert_eq!(x.len(), k);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(bias.len(), n);
    debug_assert_eq!(out.len(), n);
    for j in 0..n {
        let mut acc = 0i32;
        for i in 0..k {
            // offsets applied inside the loop — TFLM reference kernel shape
            acc += (x[i] as i32 - z_x) * (w[i * n + j] as i32 - z_w);
        }
        acc += bias[j];
        out[j] = multiplier.requant(acc, z_y, act_min, act_max);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::quant::FusedAct;
    use crate::util::Prng;

    /// Brute-force Eq. (3) evaluated literally in f64 (test oracle).
    #[allow(clippy::too_many_arguments)]
    fn oracle(
        x: &[i8],
        w: &[i8],
        b: &[i32],
        k: usize,
        n: usize,
        s_x: f32,
        z_x: i32,
        s_w: f32,
        z_w: i32,
        s_y: f32,
        z_y: i32,
        act: FusedAct,
    ) -> Vec<i8> {
        let s_b = s_x * s_w;
        let (lo, hi) = act.bounds(s_y, z_y);
        (0..n)
            .map(|j| {
                let mut acc = 0i64;
                for i in 0..k {
                    acc += (x[i] as i64 - z_x as i64) * (w[i * n + j] as i64 - z_w as i64);
                }
                let cb = z_y as f32 + (s_b / s_y) * b[j] as f32;
                let y = cb + (s_x * s_w / s_y) * acc as f32;
                (y.round().clamp(lo as f32, hi as f32)) as i8
            })
            .collect()
    }

    fn setup(seed: u64, k: usize, n: usize) -> (Vec<i8>, Vec<i8>, Vec<i32>) {
        let mut rng = Prng::new(seed);
        (rng.i8_vec(k), rng.i8_vec(k * n), rng.i32_vec(n, -2000, 2000))
    }

    #[test]
    fn microflow_matches_literal_eq3() {
        for seed in 0..10u64 {
            // n = 11 exercises 2 full panels + a 3-wide tail
            let (k, n) = (37, 11);
            let (x, w, b) = setup(seed, k, n);
            let (s_x, z_x, s_w, z_w, s_y, z_y) = (0.05f32, 3, 0.02f32, -2, 0.08f32, -5);
            let colsum: Vec<i32> =
                (0..n).map(|j| (0..k).map(|i| w[i * n + j] as i32).sum()).collect();
            let pc = PreComputed::fold(&b, &colsum, k, s_x, z_x, s_w, z_w, s_x * s_w, 0, s_y, z_y, FusedAct::Relu);
            let mut out = vec![0i8; n];
            fully_connected_microflow(&x, &w, k, n, &pc, &mut out);
            let want = oracle(&x, &w, &b, k, n, s_x, z_x, s_w, z_w, s_y, z_y, FusedAct::Relu);
            assert_eq!(out, want, "seed {seed}");
        }
    }

    #[test]
    fn paged_is_bit_identical_to_unpaged() {
        for seed in 0..10u64 {
            let (k, n) = (64, 32);
            let (x, w, b) = setup(seed, k, n);
            let colsum: Vec<i32> =
                (0..n).map(|j| (0..k).map(|i| w[i * n + j] as i32).sum()).collect();
            let pc = PreComputed::fold(&b, &colsum, k, 0.1, -7, 0.03, 0, 0.003, 0, 0.09, 4, FusedAct::None);
            let mut a = vec![0i8; n];
            let mut p = vec![0i8; n];
            let mut page = vec![0i8; k];
            fully_connected_microflow(&x, &w, k, n, &pc, &mut a);
            fully_connected_paged(&x, &w, k, n, &pc, &mut page, &mut p);
            assert_eq!(a, p, "seed {seed}");
        }
    }

    #[test]
    fn interp_within_one_unit_of_microflow() {
        // the paper's Sec. 6.2.1 property at the kernel level
        let mut worst = 0i32;
        for seed in 100..140u64 {
            let (k, n) = (50, 16);
            let (x, w, b) = setup(seed, k, n);
            let (s_x, z_x, s_w, z_w, s_y, z_y) = (0.04f32, 5, 0.015f32, 0, 0.07f32, -11);
            let colsum: Vec<i32> =
                (0..n).map(|j| (0..k).map(|i| w[i * n + j] as i32).sum()).collect();
            let pc = PreComputed::fold(&b, &colsum, k, s_x, z_x, s_w, z_w, s_x * s_w, 0, s_y, z_y, FusedAct::None);
            let mut mf = vec![0i8; n];
            fully_connected_microflow(&x, &w, k, n, &pc, &mut mf);
            let m = FixedPointMultiplier::from_real((s_x as f64 * s_w as f64) / s_y as f64);
            let mut ip = vec![0i8; n];
            fully_connected_interp(&x, &w, &b, k, n, z_x, z_w, m, z_y, -128, 127, &mut ip);
            for j in 0..n {
                worst = worst.max((mf[j] as i32 - ip[j] as i32).abs());
            }
        }
        assert!(worst <= 1, "worst deviation {worst} > 1 unit");
    }

    #[test]
    fn zero_k_zero_point_skips_rowsum() {
        // z_w == 0 must not change results vs the general path
        let (k, n) = (8, 4);
        let (x, w, b) = setup(7, k, n);
        let colsum: Vec<i32> = (0..n).map(|j| (0..k).map(|i| w[i * n + j] as i32).sum()).collect();
        let pc = PreComputed::fold(&b, &colsum, k, 0.1, 2, 0.1, 0, 0.01, 0, 0.1, 0, FusedAct::None);
        let mut out = vec![0i8; n];
        fully_connected_microflow(&x, &w, k, n, &pc, &mut out);
        let want = oracle(&x, &w, &b, k, n, 0.1, 2, 0.1, 0, 0.1, 0, FusedAct::None);
        assert_eq!(out, want);
    }

    #[test]
    fn every_tail_width_matches_the_oracle() {
        // n = 1..=9 sweeps pure-tail, exact-panel and panel+tail splits
        for n in 1..=9usize {
            let k = 23;
            let (x, w, b) = setup(n as u64 + 40, k, n);
            let colsum: Vec<i32> =
                (0..n).map(|j| (0..k).map(|i| w[i * n + j] as i32).sum()).collect();
            let pc = PreComputed::fold(&b, &colsum, k, 0.05, 3, 0.02, -2, 0.001, 0, 0.08, -5, FusedAct::None);
            let mut out = vec![0i8; n];
            fully_connected_microflow(&x, &w, k, n, &pc, &mut out);
            let want = oracle(&x, &w, &b, k, n, 0.05, 3, 0.02, -2, 0.08, -5, FusedAct::None);
            assert_eq!(out, want, "n = {n}");
        }
    }
}
