//! FullyConnected kernels — Eq. (3) / Appendix A.1 (DESIGN.md S9).
//!
//! Weights are `[K, N]` row-major (TFLite stores `[N, K]`; the exporter
//! emits `[K, N]` so the MicroFlow inner loop streams rows sequentially).
//!
//! Three variants:
//! * [`fully_connected_microflow`] — folded constants + float epilogue;
//! * [`fully_connected_paged`]     — the Sec. 4.3 paging execution: one
//!   output neuron's weights are staged into a page buffer at a time;
//! * [`fully_connected_interp`]    — TFLM-style per-element offsets +
//!   gemmlowp fixed-point epilogue.

use crate::tensor::fixedpoint::FixedPointMultiplier;
use crate::tensor::quant::{requant_float, PreComputed};

/// Widest output that accumulates in the narrow-path stack array; anything
/// wider needs the caller's i32 accumulator scratch. The compiler's
/// memory planner sizes the plan's shared scratch from this same constant
/// (`compiler::memory::step_acc_i32`), so the two sides cannot drift.
pub const FC_NARROW_MAX: usize = 8;

/// MicroFlow FC: `y[j] = requant(dot[j] - z_w*rowsum - wzp[j] + kzxzw)`.
///
/// `x`: `[K]`, `w`: `[K, N]` row-major, `out`: `[N]`.
///
/// `acc` is the caller's i32 accumulator scratch, used only on the
/// wide-output path (`n > 8`, where the accumulators don't fit the stack
/// array) and required to hold at least `n` elements there. The engine
/// threads it from the plan-sized [`Scratch`](crate::engine::Scratch)
/// buffers, keeping the whole predict path allocation-free; narrow
/// outputs may pass `&mut []`.
pub fn fully_connected_microflow(
    x: &[i8],
    w: &[i8],
    k: usize,
    n: usize,
    pc: &PreComputed,
    acc: &mut [i32],
    out: &mut [i8],
) {
    debug_assert_eq!(x.len(), k);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(out.len(), n);
    debug_assert_eq!(pc.const_bias.len(), n);

    // data-dependent row sum (the only z_w term that cannot be folded)
    let rowsum: i32 = if pc.z_w != 0 { x.iter().map(|&v| v as i32).sum() } else { 0 };

    if n <= FC_NARROW_MAX {
        // narrow-output path (the speech classifier head is 4000x4):
        // stack accumulators + chunks_exact (no heap allocation, no
        // per-row bounds checks, no per-row branch) — EXPERIMENTS.md
        // §Perf: fc 4000x4 19.9us -> ~6us
        let mut acc = [0i32; FC_NARROW_MAX];
        for (row, &xi) in w.chunks_exact(n).zip(x.iter()) {
            let xv = xi as i32;
            for (a, &wv) in acc[..n].iter_mut().zip(row) {
                *a += xv * wv as i32;
            }
        }
        for j in 0..n {
            let a = acc[j] - pc.z_w * rowsum - pc.w_zp_term[j] + pc.kzxzw;
            out[j] = requant_float(a, pc.const_bias[j], pc.scale_ratio, pc.act_min, pc.act_max);
        }
        return;
    }

    // wide-output path: accumulate column-wise over rows — w rows are
    // contiguous (chunks_exact: no per-row bounds checks), so this walks
    // w sequentially (cache/flash friendly, the same access pattern the
    // paper's paged variant exploits) and the inner loop auto-vectorizes
    // over the output row
    let acc = &mut acc[..n];
    acc.fill(0);
    for (row, &xi) in w.chunks_exact(n).zip(x.iter()) {
        let xv = xi as i32;
        for (a, &wv) in acc.iter_mut().zip(row) {
            *a += xv * wv as i32;
        }
    }
    for j in 0..n {
        let a = acc[j] - pc.z_w * rowsum - pc.w_zp_term[j] + pc.kzxzw;
        out[j] = requant_float(a, pc.const_bias[j], pc.scale_ratio, pc.act_min, pc.act_max);
    }
}

/// Paged MicroFlow FC (paper Sec. 4.3, Fig. 6).
///
/// One *page* holds the connections feeding a single output neuron:
/// `page_buf` (length `K`) is loaded from the `[K, N]` weight matrix
/// column-by-column — modelling the Flash→RAM stage on a 2 kB device —
/// then reduced with a single accumulator. RAM high-water mark per page:
/// `K` weights + `K` inputs + 1 int32 accumulator + epilogue constants
/// (the paper's 163-byte example for K = 32 — see `sim::memory_model`).
pub fn fully_connected_paged(
    x: &[i8],
    w: &[i8],
    k: usize,
    n: usize,
    pc: &PreComputed,
    page_buf: &mut [i8],
    out: &mut [i8],
) {
    debug_assert_eq!(page_buf.len(), k);
    let rowsum: i32 = if pc.z_w != 0 { x.iter().map(|&v| v as i32).sum() } else { 0 };
    for j in 0..n {
        // stage the page: column j of w (strided in Flash, contiguous in RAM)
        for i in 0..k {
            page_buf[i] = w[i * n + j];
        }
        let mut acc = 0i32;
        for i in 0..k {
            acc += x[i] as i32 * page_buf[i] as i32;
        }
        let a = acc - pc.z_w * rowsum - pc.w_zp_term[j] + pc.kzxzw;
        out[j] = requant_float(a, pc.const_bias[j], pc.scale_ratio, pc.act_min, pc.act_max);
    }
}

/// TFLM-style FC: per-element zero-point application + int32 bias + fixed
/// point requantization. No folded constants — this is what an interpreter
/// that cannot pre-process does per inference.
#[allow(clippy::too_many_arguments)]
pub fn fully_connected_interp(
    x: &[i8],
    w: &[i8],
    bias: &[i32],
    k: usize,
    n: usize,
    z_x: i32,
    z_w: i32,
    multiplier: FixedPointMultiplier,
    z_y: i32,
    act_min: i8,
    act_max: i8,
    out: &mut [i8],
) {
    debug_assert_eq!(x.len(), k);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(bias.len(), n);
    debug_assert_eq!(out.len(), n);
    for j in 0..n {
        let mut acc = 0i32;
        for i in 0..k {
            // offsets applied inside the loop — TFLM reference kernel shape
            acc += (x[i] as i32 - z_x) * (w[i * n + j] as i32 - z_w);
        }
        acc += bias[j];
        out[j] = multiplier.requant(acc, z_y, act_min, act_max);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::quant::FusedAct;
    use crate::util::Prng;

    /// Brute-force Eq. (3) evaluated literally in f64 (test oracle).
    #[allow(clippy::too_many_arguments)]
    fn oracle(
        x: &[i8],
        w: &[i8],
        b: &[i32],
        k: usize,
        n: usize,
        s_x: f32,
        z_x: i32,
        s_w: f32,
        z_w: i32,
        s_y: f32,
        z_y: i32,
        act: FusedAct,
    ) -> Vec<i8> {
        let s_b = s_x * s_w;
        let (lo, hi) = act.bounds(s_y, z_y);
        (0..n)
            .map(|j| {
                let mut acc = 0i64;
                for i in 0..k {
                    acc += (x[i] as i64 - z_x as i64) * (w[i * n + j] as i64 - z_w as i64);
                }
                let cb = z_y as f32 + (s_b / s_y) * b[j] as f32;
                let y = cb + (s_x * s_w / s_y) * acc as f32;
                (y.round().clamp(lo as f32, hi as f32)) as i8
            })
            .collect()
    }

    fn setup(seed: u64, k: usize, n: usize) -> (Vec<i8>, Vec<i8>, Vec<i32>) {
        let mut rng = Prng::new(seed);
        (rng.i8_vec(k), rng.i8_vec(k * n), rng.i32_vec(n, -2000, 2000))
    }

    #[test]
    fn microflow_matches_literal_eq3() {
        for seed in 0..10u64 {
            let (k, n) = (37, 11);
            let (x, w, b) = setup(seed, k, n);
            let (s_x, z_x, s_w, z_w, s_y, z_y) = (0.05f32, 3, 0.02f32, -2, 0.08f32, -5);
            let colsum: Vec<i32> =
                (0..n).map(|j| (0..k).map(|i| w[i * n + j] as i32).sum()).collect();
            let pc = PreComputed::fold(&b, &colsum, k, s_x, z_x, s_w, z_w, s_x * s_w, 0, s_y, z_y, FusedAct::Relu);
            let mut out = vec![0i8; n];
            let mut acc = vec![0i32; n];
            fully_connected_microflow(&x, &w, k, n, &pc, &mut acc, &mut out);
            let want = oracle(&x, &w, &b, k, n, s_x, z_x, s_w, z_w, s_y, z_y, FusedAct::Relu);
            assert_eq!(out, want, "seed {seed}");
        }
    }

    #[test]
    fn paged_is_bit_identical_to_unpaged() {
        for seed in 0..10u64 {
            let (k, n) = (64, 32);
            let (x, w, b) = setup(seed, k, n);
            let colsum: Vec<i32> =
                (0..n).map(|j| (0..k).map(|i| w[i * n + j] as i32).sum()).collect();
            let pc = PreComputed::fold(&b, &colsum, k, 0.1, -7, 0.03, 0, 0.003, 0, 0.09, 4, FusedAct::None);
            let mut a = vec![0i8; n];
            let mut p = vec![0i8; n];
            let mut page = vec![0i8; k];
            let mut acc = vec![0i32; n];
            fully_connected_microflow(&x, &w, k, n, &pc, &mut acc, &mut a);
            fully_connected_paged(&x, &w, k, n, &pc, &mut page, &mut p);
            assert_eq!(a, p, "seed {seed}");
        }
    }

    #[test]
    fn interp_within_one_unit_of_microflow() {
        // the paper's Sec. 6.2.1 property at the kernel level
        let mut worst = 0i32;
        for seed in 100..140u64 {
            let (k, n) = (50, 16);
            let (x, w, b) = setup(seed, k, n);
            let (s_x, z_x, s_w, z_w, s_y, z_y) = (0.04f32, 5, 0.015f32, 0, 0.07f32, -11);
            let colsum: Vec<i32> =
                (0..n).map(|j| (0..k).map(|i| w[i * n + j] as i32).sum()).collect();
            let pc = PreComputed::fold(&b, &colsum, k, s_x, z_x, s_w, z_w, s_x * s_w, 0, s_y, z_y, FusedAct::None);
            let mut mf = vec![0i8; n];
            let mut acc = vec![0i32; n];
            fully_connected_microflow(&x, &w, k, n, &pc, &mut acc, &mut mf);
            let m = FixedPointMultiplier::from_real((s_x as f64 * s_w as f64) / s_y as f64);
            let mut ip = vec![0i8; n];
            fully_connected_interp(&x, &w, &b, k, n, z_x, z_w, m, z_y, -128, 127, &mut ip);
            for j in 0..n {
                worst = worst.max((mf[j] as i32 - ip[j] as i32).abs());
            }
        }
        assert!(worst <= 1, "worst deviation {worst} > 1 unit");
    }

    #[test]
    fn zero_k_zero_point_skips_rowsum() {
        // z_w == 0 must not change results vs the general path
        let (k, n) = (8, 4);
        let (x, w, b) = setup(7, k, n);
        let colsum: Vec<i32> = (0..n).map(|j| (0..k).map(|i| w[i * n + j] as i32).sum()).collect();
        let pc = PreComputed::fold(&b, &colsum, k, 0.1, 2, 0.1, 0, 0.01, 0, 0.1, 0, FusedAct::None);
        let mut out = vec![0i8; n];
        fully_connected_microflow(&x, &w, k, n, &pc, &mut [], &mut out);
        let want = oracle(&x, &w, &b, k, n, 0.1, 2, 0.1, 0, 0.1, 0, FusedAct::None);
        assert_eq!(out, want);
    }

    #[test]
    fn narrow_path_ignores_the_acc_scratch() {
        // n <= 8 runs on the stack-array path; an empty scratch is fine
        let (k, n) = (37, 8);
        let (x, w, b) = setup(3, k, n);
        let colsum: Vec<i32> = (0..n).map(|j| (0..k).map(|i| w[i * n + j] as i32).sum()).collect();
        let pc = PreComputed::fold(&b, &colsum, k, 0.05, 3, 0.02, -2, 0.001, 0, 0.08, -5, FusedAct::None);
        let mut a = vec![0i8; n];
        let mut b2 = vec![0i8; n];
        fully_connected_microflow(&x, &w, k, n, &pc, &mut [], &mut a);
        let mut big = vec![123i32; n]; // dirty scratch must not matter
        fully_connected_microflow(&x, &w, k, n, &pc, &mut big, &mut b2);
        assert_eq!(a, b2);
    }

    #[test]
    fn wide_path_zeroes_a_dirty_acc_scratch() {
        let (k, n) = (16, 24);
        let (x, w, b) = setup(11, k, n);
        let colsum: Vec<i32> = (0..n).map(|j| (0..k).map(|i| w[i * n + j] as i32).sum()).collect();
        let pc = PreComputed::fold(&b, &colsum, k, 0.05, 3, 0.02, -2, 0.001, 0, 0.08, -5, FusedAct::None);
        let mut clean = vec![0i8; n];
        let mut dirty = vec![0i8; n];
        let mut acc = vec![0i32; n];
        fully_connected_microflow(&x, &w, k, n, &pc, &mut acc, &mut clean);
        // acc now holds the previous call's accumulators; reuse must not leak
        fully_connected_microflow(&x, &w, k, n, &pc, &mut acc, &mut dirty);
        assert_eq!(clean, dirty);
    }
}
