//! Minimal JSON writer (serde is unavailable offline — DESIGN.md §7).
//!
//! Only what the report writers need: objects, arrays, strings, numbers.
//! Escaping covers the JSON control set; this is a *writer*, not a parser.

/// A JSON value under construction.
#[derive(Clone, Debug)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Int(i64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Self {
        Json::Obj(Vec::new())
    }

    /// Insert a field (builder style); panics if self is not an object.
    pub fn set(mut self, key: &str, value: impl Into<Json>) -> Self {
        match &mut self {
            Json::Obj(fields) => fields.push((key.to_string(), value.into())),
            _ => panic!("set() on non-object"),
        }
        self
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Int(i) => out.push_str(&i.to_string()),
            Json::Num(f) => {
                if f.is_finite() {
                    out.push_str(&format!("{f}"));
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(f: f64) -> Self {
        Json::Num(f)
    }
}
impl From<i64> for Json {
    fn from(i: i64) -> Self {
        Json::Int(i)
    }
}
impl From<usize> for Json {
    fn from(i: usize) -> Self {
        Json::Int(i as i64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Self {
        Json::Arr(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_nested_object() {
        let j = Json::obj()
            .set("name", "sine")
            .set("n", 3usize)
            .set("ok", true)
            .set("xs", Json::Arr(vec![Json::Int(1), Json::Num(2.5)]));
        assert_eq!(j.render(), r#"{"name":"sine","n":3,"ok":true,"xs":[1,2.5]}"#);
    }

    #[test]
    fn escapes_strings() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(j.render(), r#""a\"b\\c\nd""#);
    }
}
