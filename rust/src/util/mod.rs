//! Small self-contained utilities (no external deps are available offline —
//! see DESIGN.md §7): a seeded PRNG for property tests, streaming statistics
//! for the bench harness, and a tiny JSON/CSV writer for reports.

pub mod json;
pub mod prng;
pub mod stats;

pub use prng::Prng;
pub use stats::Summary;

/// Format a byte count the way the paper's figures do (kB with 3 decimals).
pub fn fmt_kb(bytes: usize) -> String {
    format!("{:.3}kB", bytes as f64 / 1000.0)
}

/// Format a duration in the unit that keeps 3-4 significant digits.
pub fn fmt_time(seconds: f64) -> String {
    if seconds >= 1.0 {
        format!("{seconds:.3}s")
    } else if seconds >= 1e-3 {
        format!("{:.3}ms", seconds * 1e3)
    } else if seconds >= 1e-6 {
        format!("{:.3}us", seconds * 1e6)
    } else {
        format!("{:.1}ns", seconds * 1e9)
    }
}

/// Format an energy quantity (Wh) like Table 6 (nWh / uWh / mWh).
pub fn fmt_energy_wh(wh: f64) -> String {
    if wh >= 1e-3 {
        format!("{:.2}mWh", wh * 1e3)
    } else if wh >= 1e-6 {
        format!("{:.2}uWh", wh * 1e6)
    } else {
        format!("{:.0}nWh", wh * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_kb_matches_paper_style() {
        assert_eq!(fmt_kb(13619), "13.619kB");
        assert_eq!(fmt_kb(1706), "1.706kB");
    }

    #[test]
    fn fmt_time_units() {
        assert_eq!(fmt_time(2.5), "2.500s");
        assert_eq!(fmt_time(0.0125), "12.500ms");
        assert_eq!(fmt_time(3.2e-5), "32.000us");
        assert_eq!(fmt_time(5.0e-8), "50.0ns");
    }

    #[test]
    fn fmt_energy_units() {
        assert_eq!(fmt_energy_wh(149e-9), "149nWh");
        assert_eq!(fmt_energy_wh(23.05e-3), "23.05mWh");
    }
}
