//! Summary statistics for the bench harness (criterion is unavailable
//! offline). Mirrors the paper's own methodology: median of N iterations
//! with a 95% percentile interval (Sec. 6.2.3).

/// Summary of a sample of measurements.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub min: f64,
    pub max: f64,
    pub mean: f64,
    pub median: f64,
    pub p2_5: f64,
    pub p97_5: f64,
    pub std_dev: f64,
}

impl Summary {
    /// Compute from raw samples (need not be sorted).
    pub fn from(samples: &[f64]) -> Self {
        assert!(!samples.is_empty(), "empty sample");
        let mut s = samples.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = s.len();
        let mean = s.iter().sum::<f64>() / n as f64;
        let var = s.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Summary {
            n,
            min: s[0],
            max: s[n - 1],
            mean,
            median: percentile_sorted(&s, 50.0),
            p2_5: percentile_sorted(&s, 2.5),
            p97_5: percentile_sorted(&s, 97.5),
            std_dev: var.sqrt(),
        }
    }
}

/// Linear-interpolated percentile of a **sorted** slice.
pub fn percentile_sorted(sorted: &[f64], pct: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = pct / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] + (sorted[hi] - sorted[lo]) * frac
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_odd() {
        let s = Summary::from(&[3.0, 1.0, 2.0]);
        assert_eq!(s.median, 2.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
    }

    #[test]
    fn median_even_interpolates() {
        let s = Summary::from(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.median, 2.5);
    }

    #[test]
    fn percentiles_bracket_median() {
        let v: Vec<f64> = (0..101).map(|i| i as f64).collect();
        let s = Summary::from(&v);
        assert_eq!(s.median, 50.0);
        assert!((s.p2_5 - 2.5).abs() < 1e-9);
        assert!((s.p97_5 - 97.5).abs() < 1e-9);
    }

    #[test]
    fn mean_and_std() {
        let s = Summary::from(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((s.mean - 5.0).abs() < 1e-12);
        assert!((s.std_dev - 2.0).abs() < 1e-12);
    }
}
