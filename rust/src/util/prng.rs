//! Seeded xoshiro256** PRNG for property tests and workload generation.
//!
//! `proptest`/`rand` are unavailable offline (DESIGN.md §7); this provides
//! the deterministic randomness the property tests and the serving-workload
//! generators need. xoshiro256** is a well-studied generator with 256-bit
//! state; plenty for test-case generation.

/// xoshiro256** generator.
#[derive(Clone, Debug)]
pub struct Prng {
    s: [u64; 4],
}

impl Prng {
    /// Create from a seed; any seed (including 0) is valid — the state is
    /// expanded with splitmix64 as recommended by the xoshiro authors.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Uses rejection sampling to avoid modulo bias.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        lo + self.below(span) as i64
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (self.f64() as f32) * (hi - lo)
    }

    /// Random int8 over the full range.
    pub fn i8(&mut self) -> i8 {
        self.range_i64(-128, 127) as i8
    }

    /// Vector of random int8.
    pub fn i8_vec(&mut self, n: usize) -> Vec<i8> {
        (0..n).map(|_| self.i8()).collect()
    }

    /// Vector of random int32 in `[lo, hi]`.
    pub fn i32_vec(&mut self, n: usize, lo: i32, hi: i32) -> Vec<i32> {
        (0..n).map(|_| self.range_i64(lo as i64, hi as i64) as i32).collect()
    }

    /// Exponentially distributed f64 with the given rate (for Poisson
    /// arrival processes in the serving benches).
    pub fn exp(&mut self, rate: f64) -> f64 {
        let u = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        -u.ln() / rate
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_is_in_range() {
        let mut p = Prng::new(7);
        for _ in 0..1000 {
            assert!(p.below(17) < 17);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut p = Prng::new(3);
        for _ in 0..1000 {
            let v = p.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn i8_covers_extremes() {
        let mut p = Prng::new(1);
        let v = p.i8_vec(20_000);
        assert!(v.contains(&-128));
        assert!(v.contains(&127));
    }

    #[test]
    fn exp_positive_mean_close() {
        let mut p = Prng::new(9);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| p.exp(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }
}
