//! Affine int8 quantization (Eq. 1) and the MicroFlow requantization
//! epilogue (DESIGN.md S1).
//!
//! Bit-exactness contract with the JAX golden path (`python/compile/
//! kernels/ref.py`): int32 accumulation, then
//! `round_half_away(const_bias + scale_ratio * acc)` in **float32**, with
//! `const_bias = z_Y + (s_b / s_Y) * (b_q - z_b)` and
//! `scale_ratio = (s_X * s_W) / s_Y` computed in float32 in this exact
//! operation order. `f32::round` rounds half away from zero, matching the
//! oracle's `sign(x) * floor(|x| + 0.5)`.

pub const INT8_MIN: i32 = -128;
pub const INT8_MAX: i32 = 127;

/// Per-tensor affine quantization parameters: `r = scale * (q - zero_point)`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QParams {
    pub scale: f32,
    pub zero_point: i32,
}

impl QParams {
    /// Placeholder for non-quantized (f32) tensors.
    pub const NONE: QParams = QParams { scale: 1.0, zero_point: 0 };

    pub fn new(scale: f32, zero_point: i32) -> Self {
        QParams { scale, zero_point }
    }

    /// Quantize one float value: `q = clamp(round(r / S) + Z)`.
    pub fn quantize(&self, r: f32) -> i8 {
        let q = round_half_away_i32(r / self.scale) + self.zero_point;
        q.clamp(INT8_MIN, INT8_MAX) as i8
    }

    /// Dequantize one int8 value (Eq. 1).
    pub fn dequantize(&self, q: i8) -> f32 {
        self.scale * (q as i32 - self.zero_point) as f32
    }

    /// Quantize a float slice.
    pub fn quantize_slice(&self, r: &[f32]) -> Vec<i8> {
        r.iter().map(|&v| self.quantize(v)).collect()
    }
}

/// Fused activation kinds (paper Sec. 5.5). In the quantized domain a fused
/// activation is just a clamp (Eqs. 15/17).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FusedAct {
    None,
    Relu,
    Relu6,
}

impl FusedAct {
    pub fn from_code(code: u8) -> anyhow::Result<Self> {
        Ok(match code {
            0 => FusedAct::None,
            1 => FusedAct::Relu,
            2 => FusedAct::Relu6,
            c => anyhow::bail!("unknown fused activation code {c}"),
        })
    }

    /// Quantized clamp bounds (mirrors `ref.act_bounds`).
    pub fn bounds(self, s_y: f32, z_y: i32) -> (i8, i8) {
        match self {
            FusedAct::None => (INT8_MIN as i8, INT8_MAX as i8),
            FusedAct::Relu => (z_y.clamp(INT8_MIN, INT8_MAX) as i8, INT8_MAX as i8),
            FusedAct::Relu6 => {
                let hi = (z_y as f64 + 6.0 / s_y as f64 + 0.5).floor() as i32;
                (
                    z_y.clamp(INT8_MIN, INT8_MAX) as i8,
                    hi.clamp(INT8_MIN, INT8_MAX) as i8,
                )
            }
        }
    }
}

/// Branch-free round-half-away-from-zero to i32.
///
/// Bit-identical to `f32::round() as i32` for every finite `y` whose
/// magnitude is below 2^22 (all requantization outputs — they clamp to
/// int8 anyway), but compiles to a `copysign` bit-op + `cvttss2si`
/// instead of the `roundf` libcall that dominated small-dot kernels
/// (EXPERIMENTS.md §Perf: the 96x96 first-conv regression).
#[inline(always)]
pub fn round_half_away_i32(y: f32) -> i32 {
    (y + 0.5f32.copysign(y)) as i32
}

/// The MicroFlow float-scale requantization epilogue.
///
/// `y_q = clamp(round(const_bias + scale_ratio * acc), act_min, act_max)`
#[inline(always)]
pub fn requant_float(acc: i32, const_bias: f32, scale_ratio: f32, act_min: i8, act_max: i8) -> i8 {
    let y = const_bias + scale_ratio * acc as f32;
    round_half_away_i32(y).clamp(act_min as i32, act_max as i32) as i8
}

/// Pre-processed constants for one operator (the compiler's Eq. 4/7/10/13
/// output). `const_bias[j]` folds `z_Y + (s_b/s_Y)(b_q[j] - z_b)`;
/// `w_zp_term[j]` folds `z_X * Σ W[:, j]`; `kzxzw` folds `n z_X z_W`.
#[derive(Clone, Debug)]
pub struct PreComputed {
    pub const_bias: Vec<f32>,
    pub scale_ratio: f32,
    pub w_zp_term: Vec<i32>,
    pub kzxzw: i32,
    pub z_w: i32,
    pub act_min: i8,
    pub act_max: i8,
}

impl PreComputed {
    /// Fold the constants for a matmul-like operator.
    ///
    /// `w_colsum[j]` must be `Σ_k W_q[k, j]` (or the per-output-channel
    /// filter sum for convs); `k` is the reduction length.
    #[allow(clippy::too_many_arguments)]
    pub fn fold(
        bias_q: &[i32],
        w_colsum: &[i32],
        k: usize,
        s_x: f32,
        z_x: i32,
        s_w: f32,
        z_w: i32,
        s_b: f32,
        z_b: i32,
        s_y: f32,
        z_y: i32,
        act: FusedAct,
    ) -> Self {
        assert_eq!(bias_q.len(), w_colsum.len());
        // float32 op order must match ref.py exactly (see module docs)
        let sb_over_sy = s_b / s_y;
        let const_bias: Vec<f32> = bias_q
            .iter()
            .map(|&b| z_y as f32 + sb_over_sy * (b - z_b) as f32)
            .collect();
        let scale_ratio = s_x * s_w / s_y;
        let w_zp_term: Vec<i32> = w_colsum.iter().map(|&s| z_x.wrapping_mul(s)).collect();
        let kzxzw = (k as i32).wrapping_mul(z_x).wrapping_mul(z_w);
        let (act_min, act_max) = act.bounds(s_y, z_y);
        PreComputed { const_bias, scale_ratio, w_zp_term, kzxzw, z_w, act_min, act_max }
    }

    /// Bytes of RAM the folded constants occupy (for the memory model).
    pub fn nbytes(&self) -> usize {
        self.const_bias.len() * 4 + self.w_zp_term.len() * 4 + 16
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantize_round_half_away() {
        let qp = QParams::new(1.0, 0);
        assert_eq!(qp.quantize(0.5), 1); // away from zero, NOT banker's 0
        assert_eq!(qp.quantize(-0.5), -1);
        assert_eq!(qp.quantize(1.5), 2);
        assert_eq!(qp.quantize(2.5), 3); // banker's would give 2
    }

    #[test]
    fn quantize_saturates() {
        let qp = QParams::new(0.1, 0);
        assert_eq!(qp.quantize(1e9), 127);
        assert_eq!(qp.quantize(-1e9), -128);
    }

    #[test]
    fn dequantize_inverse_of_quantize_within_half_step() {
        let qp = QParams::new(0.05, -7);
        for r in [-3.0f32, -0.51, 0.0, 0.024, 1.99] {
            let q = qp.quantize(r);
            let back = qp.dequantize(q);
            assert!((back - r).abs() <= 0.5 * qp.scale + 1e-6, "{r} -> {q} -> {back}");
        }
    }

    #[test]
    fn relu_bounds_clamp_at_zero_point() {
        let (lo, hi) = FusedAct::Relu.bounds(0.1, -4);
        assert_eq!((lo, hi), (-4, 127));
    }

    #[test]
    fn relu6_bounds() {
        // z=-128, s=6/255 => hi = -128 + 255 = 127
        let (lo, hi) = FusedAct::Relu6.bounds(6.0 / 255.0, -128);
        assert_eq!((lo, hi), (-128, 127));
        // coarser scale: z=0, s=0.1 => hi = 60
        let (lo2, hi2) = FusedAct::Relu6.bounds(0.1, 0);
        assert_eq!((lo2, hi2), (0, 60));
    }

    #[test]
    fn round_half_away_i32_matches_f32_round() {
        // exhaustive over the representable requant range in coarse steps
        // plus the tie points — the libcall-free path must be bit-identical
        for i in -60_000..=60_000 {
            let y = i as f32 * 0.01; // covers ties at *.x5 boundaries
            assert_eq!(round_half_away_i32(y), y.round() as i32, "y={y}");
        }
        for t in [-2.5f32, -1.5, -0.5, 0.5, 1.5, 2.5, 126.5, -126.5] {
            assert_eq!(round_half_away_i32(t), t.round() as i32, "tie {t}");
        }
    }

    #[test]
    fn requant_float_matches_formula() {
        // const_bias=0.3, ratio=0.01, acc=170 -> 2.0 -> 2
        assert_eq!(requant_float(170, 0.3, 0.01, -128, 127), 2);
        // clamps
        assert_eq!(requant_float(1_000_000, 0.0, 1.0, -128, 127), 127);
        assert_eq!(requant_float(-1_000_000, 0.0, 1.0, -128, 127), -128);
        // activation bound
        assert_eq!(requant_float(-50, 0.0, 1.0, 0, 127), 0);
    }

    #[test]
    fn fold_splits_match_paper_terms() {
        // K=4, one output; W colsum = 10; zx=2, zw=3
        let pc = PreComputed::fold(&[100], &[10], 4, 0.5, 2, 0.25, 3, 0.125, 0, 1.0, 5, FusedAct::None);
        assert_eq!(pc.w_zp_term, vec![20]); // z_x * colsum
        assert_eq!(pc.kzxzw, 24); // 4 * 2 * 3
        assert!((pc.scale_ratio - 0.125).abs() < 1e-7);
        assert!((pc.const_bias[0] - (5.0 + 0.125 * 100.0)).abs() < 1e-5);
    }
}
