//! Tensor containers and quantization arithmetic (DESIGN.md S1-S3).
//!
//! The paper's runtime works on statically-shaped int8 tensors with
//! per-tensor affine quantization (Eq. 1). This module provides:
//!
//! * [`Tensor`] — a simple row-major container over int8 / int32 / f32;
//! * [`quant`] — the MicroFlow requantization path: int32 accumulate, then
//!   a float32 epilogue with round-half-away-from-zero (bit-compatible
//!   with the JAX/Pallas golden path);
//! * [`fixedpoint`] — the TFLM/gemmlowp integer-only requantization used by
//!   the interpreter baseline (source of the paper's ±1 output unit
//!   differences, Sec. 6.2.1).

pub mod fixedpoint;
pub mod quant;

pub use quant::QParams;

/// Element type of a tensor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    I8,
    I32,
    F32,
}

impl DType {
    pub fn size_bytes(self) -> usize {
        match self {
            DType::I8 => 1,
            DType::I32 => 4,
            DType::F32 => 4,
        }
    }
}

/// Tensor storage.
#[derive(Clone, Debug, PartialEq)]
pub enum TensorData {
    I8(Vec<i8>),
    I32(Vec<i32>),
    F32(Vec<f32>),
}

impl TensorData {
    pub fn dtype(&self) -> DType {
        match self {
            TensorData::I8(_) => DType::I8,
            TensorData::I32(_) => DType::I32,
            TensorData::F32(_) => DType::F32,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            TensorData::I8(v) => v.len(),
            TensorData::I32(v) => v.len(),
            TensorData::F32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// A row-major n-dimensional tensor with quantization parameters.
///
/// Activation tensors in the engines are int8; biases int32; the float
/// variant exists for dataset features and dequantized outputs.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
    pub qparams: QParams,
}

impl Tensor {
    pub fn new_i8(shape: Vec<usize>, data: Vec<i8>, qparams: QParams) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape, data: TensorData::I8(data), qparams }
    }

    pub fn new_i32(shape: Vec<usize>, data: Vec<i32>, qparams: QParams) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape, data: TensorData::I32(data), qparams }
    }

    pub fn new_f32(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape, data: TensorData::F32(data), qparams: QParams::NONE }
    }

    pub fn zeros_i8(shape: Vec<usize>, qparams: QParams) -> Self {
        let n = shape.iter().product();
        Tensor { shape, data: TensorData::I8(vec![0; n]), qparams }
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn dtype(&self) -> DType {
        self.data.dtype()
    }

    /// Bytes occupied by the payload (the planner's unit of account).
    pub fn nbytes(&self) -> usize {
        self.numel() * self.dtype().size_bytes()
    }

    pub fn as_i8(&self) -> &[i8] {
        match &self.data {
            TensorData::I8(v) => v,
            other => panic!("expected i8 tensor, got {:?}", other.dtype()),
        }
    }

    pub fn as_i8_mut(&mut self) -> &mut [i8] {
        match &mut self.data {
            TensorData::I8(v) => v,
            other => panic!("expected i8 tensor, got {:?}", other.dtype()),
        }
    }

    pub fn as_i32(&self) -> &[i32] {
        match &self.data {
            TensorData::I32(v) => v,
            other => panic!("expected i32 tensor, got {:?}", other.dtype()),
        }
    }

    pub fn as_f32(&self) -> &[f32] {
        match &self.data {
            TensorData::F32(v) => v,
            other => panic!("expected f32 tensor, got {:?}", other.dtype()),
        }
    }

    /// Dequantize an int8 tensor to float (Eq. 1).
    pub fn dequantize(&self) -> Vec<f32> {
        let q = self.as_i8();
        q.iter().map(|&v| self.qparams.dequantize(v)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_accounting() {
        let t = Tensor::zeros_i8(vec![2, 3, 4], QParams::new(0.5, 0));
        assert_eq!(t.numel(), 24);
        assert_eq!(t.nbytes(), 24);
        let t32 = Tensor::new_i32(vec![3], vec![1, 2, 3], QParams::NONE);
        assert_eq!(t32.nbytes(), 12);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn shape_mismatch_panics() {
        Tensor::new_i8(vec![2, 2], vec![0; 3], QParams::NONE);
    }

    #[test]
    fn dequantize_roundtrip() {
        let qp = QParams::new(0.1, -3);
        let t = Tensor::new_i8(vec![3], vec![-3, 7, -13], qp);
        let f = t.dequantize();
        assert!((f[0] - 0.0).abs() < 1e-6);
        assert!((f[1] - 1.0).abs() < 1e-6);
        assert!((f[2] + 1.0).abs() < 1e-6);
    }
}
