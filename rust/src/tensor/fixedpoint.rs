//! gemmlowp-style integer-only requantization (DESIGN.md S2) — the TFLM
//! comparator arithmetic.
//!
//! TFLM never multiplies the accumulator by a float at inference time.
//! Instead the real multiplier `M = s_X s_W / s_Y` (always in (0, 1) for
//! sane models) is decomposed offline into a Q31 fixed-point mantissa and a
//! power-of-two shift, and applied with
//! `SaturatingRoundingDoublingHighMul` + `RoundingDivideByPOT` — exactly
//! the reference gemmlowp/TFLite kernels. The bias is added to the int32
//! accumulator directly (s_b = s_X s_W, so it lives in accumulator scale).
//!
//! This path intentionally differs from [`super::quant::requant_float`] by
//! at most one output unit on rare inputs — the same ±1 discrepancies the
//! paper observed between MicroFlow and TFLM (Sec. 6.2.1). The property
//! test `fixedpoint_vs_float_within_one_unit` pins that bound.

/// Decompose `real` (> 0) into `(quantized_multiplier, shift)` such that
/// `real ≈ qm * 2^(shift - 31)` with `qm` in `[2^30, 2^31)`.
///
/// Matches TFLite's `QuantizeMultiplier`: `shift > 0` is a left shift
/// (real >= 1), `shift <= 0` a right shift.
pub fn quantize_multiplier(real: f64) -> (i32, i32) {
    assert!(real > 0.0, "multiplier must be positive, got {real}");
    let (frac, exp) = frexp(real);
    // frac in [0.5, 1): q = round(frac * 2^31)
    let mut q = (frac * (1i64 << 31) as f64).round() as i64;
    let mut shift = exp;
    if q == (1i64 << 31) {
        q /= 2;
        shift += 1;
    }
    assert!(q <= i32::MAX as i64);
    (q as i32, shift)
}

/// `frexp` for positive finite doubles: returns `(frac, exp)` with
/// `real = frac * 2^exp`, `frac` in `[0.5, 1)`.
fn frexp(x: f64) -> (f64, i32) {
    assert!(x.is_finite() && x > 0.0);
    let bits = x.to_bits();
    let raw_exp = ((bits >> 52) & 0x7ff) as i32;
    if raw_exp == 0 {
        // subnormal: normalize by scaling up 2^64
        let (f, e) = frexp(x * (1u64 << 63) as f64 * 2.0);
        return (f, e - 64);
    }
    let exp = raw_exp - 1022;
    let frac_bits = (bits & 0x000f_ffff_ffff_ffff) | (1022u64 << 52);
    (f64::from_bits(frac_bits), exp)
}

/// gemmlowp `SaturatingRoundingDoublingHighMul`: `(a * b * 2) >> 31` with
/// round-to-nearest and saturation of the single overflow case
/// `a = b = i32::MIN`.
#[inline(always)]
pub fn saturating_rounding_doubling_high_mul(a: i32, b: i32) -> i32 {
    if a == i32::MIN && b == i32::MIN {
        return i32::MAX;
    }
    let ab = a as i64 * b as i64;
    let nudge: i64 = if ab >= 0 { 1 << 30 } else { 1 - (1 << 30) };
    ((ab + nudge) >> 31) as i32
}

/// gemmlowp `RoundingDivideByPOT`: arithmetic right shift with
/// round-to-nearest, ties away from zero (upward on the remainder test).
#[inline(always)]
pub fn rounding_divide_by_pot(x: i32, exponent: i32) -> i32 {
    debug_assert!((0..=31).contains(&exponent));
    if exponent == 0 {
        return x;
    }
    let mask = (1i64 << exponent) - 1;
    let remainder = (x as i64) & mask;
    let threshold = (mask >> 1) + i64::from(x < 0);
    (x >> exponent) + i32::from(remainder > threshold)
}

/// TFLite `MultiplyByQuantizedMultiplier`.
#[inline(always)]
pub fn multiply_by_quantized_multiplier(x: i32, quantized_multiplier: i32, shift: i32) -> i32 {
    let left_shift = shift.max(0);
    let right_shift = (-shift).max(0);
    let shifted = x.saturating_mul(1i32 << left_shift);
    rounding_divide_by_pot(
        saturating_rounding_doubling_high_mul(shifted, quantized_multiplier),
        right_shift,
    )
}

/// Pre-decomposed fixed-point multiplier for one operator (TFLM path).
#[derive(Clone, Copy, Debug)]
pub struct FixedPointMultiplier {
    pub quantized_multiplier: i32,
    pub shift: i32,
}

impl FixedPointMultiplier {
    pub fn from_real(real: f64) -> Self {
        let (quantized_multiplier, shift) = quantize_multiplier(real);
        FixedPointMultiplier { quantized_multiplier, shift }
    }

    /// Requantize an accumulator that already includes the int32 bias:
    /// `y = clamp(z_y + MBQM(acc))`.
    #[inline(always)]
    pub fn requant(&self, acc: i32, z_y: i32, act_min: i8, act_max: i8) -> i8 {
        let scaled = multiply_by_quantized_multiplier(acc, self.quantized_multiplier, self.shift);
        (scaled + z_y).clamp(act_min as i32, act_max as i32) as i8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    #[test]
    fn frexp_basic() {
        let (f, e) = frexp(1.0);
        assert_eq!((f, e), (0.5, 1));
        let (f, e) = frexp(0.75);
        assert_eq!((f, e), (0.75, 0));
        let (f, e) = frexp(6.0);
        assert_eq!((f, e), (0.75, 3));
    }

    #[test]
    fn quantize_multiplier_reconstructs() {
        for real in [0.5, 0.001234, 0.9999, 1.0, 7.25, 1e-6] {
            let (qm, shift) = quantize_multiplier(real);
            let back = qm as f64 * 2f64.powi(shift - 31);
            assert!((back - real).abs() / real < 1e-8, "{real} -> {back}");
        }
    }

    #[test]
    fn srdhm_reference_values() {
        assert_eq!(saturating_rounding_doubling_high_mul(i32::MIN, i32::MIN), i32::MAX);
        assert_eq!(saturating_rounding_doubling_high_mul(1 << 30, 1 << 30), 1 << 29);
        assert_eq!(saturating_rounding_doubling_high_mul(0, 12345), 0);
    }

    #[test]
    fn rdbp_rounds_to_nearest() {
        assert_eq!(rounding_divide_by_pot(7, 1), 4); // 3.5 -> 4
        assert_eq!(rounding_divide_by_pot(5, 1), 3); // 2.5 -> 3 (ties up)
        assert_eq!(rounding_divide_by_pot(-7, 1), -4); // -3.5 -> -4
        assert_eq!(rounding_divide_by_pot(12, 2), 3);
        assert_eq!(rounding_divide_by_pot(100, 0), 100);
    }

    #[test]
    fn multiplier_approximates_float_scaling() {
        let mut rng = Prng::new(11);
        for _ in 0..2000 {
            let real = rng.f64() * 0.01 + 1e-5;
            let m = FixedPointMultiplier::from_real(real);
            let acc = rng.range_i64(-1_000_000, 1_000_000) as i32;
            let fixed = multiply_by_quantized_multiplier(acc, m.quantized_multiplier, m.shift);
            let float = (acc as f64 * real).round();
            assert!(
                (fixed as f64 - float).abs() <= 1.0,
                "acc={acc} real={real} fixed={fixed} float={float}"
            );
        }
    }

    #[test]
    fn fixedpoint_vs_float_within_one_unit() {
        // The paper's Sec. 6.2.1 observation, as an executable property:
        // TFLM-style and MicroFlow-style requantization agree within 1 unit.
        let mut rng = Prng::new(5);
        for _ in 0..5000 {
            let scale_ratio = (rng.f64() * 0.02 + 1e-6) as f32;
            let z_y = rng.range_i64(-128, 127) as i32;
            let acc = rng.range_i64(-40_000, 40_000) as i32;
            let m = FixedPointMultiplier::from_real(scale_ratio as f64);
            let fixed = m.requant(acc, z_y, -128, 127);
            let float = crate::tensor::quant::requant_float(
                acc,
                z_y as f32,
                scale_ratio,
                -128,
                127,
            );
            assert!(
                (fixed as i32 - float as i32).abs() <= 1,
                "acc={acc} ratio={scale_ratio} zy={z_y}: fixed={fixed} float={float}"
            );
        }
    }
}
