//! Static scratch buffers for the plan executor (paper Sec. 4.2).
//!
//! Two ping-pong activation buffers + one i8 kernel scratch buffer
//! (view/page staging), sized by the compiler's
//! [`MemoryPlan`](crate::compiler::memory::MemoryPlan) and allocated
//! exactly once. The register-tiled kernel core keeps all dot-product
//! accumulators in registers, so no i32 accumulator buffer exists — the
//! one PR 2 threaded through the plan for wide-output FullyConnected was
//! deleted when the kernels moved onto `kernels::microkernel`. `split`
//! hands the executor disjoint `(input, output, scratch)` views without
//! any unsafe code, via plain borrows.

use crate::compiler::plan::CompiledModel;

/// Owned executor buffers.
#[derive(Debug)]
pub struct Scratch {
    a: Vec<i8>,
    b: Vec<i8>,
    kernel: Vec<i8>,
    /// Which buffer currently holds the live activations.
    live_in_a: bool,
}

impl Scratch {
    /// Allocate buffers per the compiled memory plan.
    pub fn for_plan(compiled: &CompiledModel) -> Scratch {
        let m = &compiled.memory;
        // both buffers must also hold the model input/output endpoints
        let a = m.buf_a.max(compiled.input_len()).max(compiled.output_len());
        let b = m.buf_b.max(compiled.input_len()).max(compiled.output_len());
        Scratch { a: vec![0; a], b: vec![0; b], kernel: vec![0; m.scratch], live_in_a: true }
    }

    /// Allocate buffers sized so a *range* of the plan can run starting
    /// from either ping-pong side. The streaming executor re-enters the
    /// plan at an arbitrary tail step with its carried activation as the
    /// "input"; the original schedule's buffer parity no longer applies,
    /// so both buffers take the larger of the two plan sizes (and every
    /// step endpoint, which `MemoryPlan` already folds into `buf_*`).
    pub fn for_plan_any_start(compiled: &CompiledModel) -> Scratch {
        let m = &compiled.memory;
        let n = m
            .buf_a
            .max(m.buf_b)
            .max(compiled.input_len())
            .max(compiled.output_len());
        Scratch { a: vec![0; n], b: vec![0; n], kernel: vec![0; m.scratch], live_in_a: true }
    }

    /// Stage the model input into the live buffer.
    pub fn load_input(&mut self, input: &[i8]) {
        self.live_in_a = true;
        self.a[..input.len()].copy_from_slice(input);
    }

    /// Disjoint (input, output, kernel-scratch) views for one step.
    pub fn split(&mut self, in_len: usize, out_len: usize) -> (&[i8], &mut [i8], &mut [i8]) {
        if self.live_in_a {
            (&self.a[..in_len], &mut self.b[..out_len], &mut self.kernel[..])
        } else {
            (&self.b[..in_len], &mut self.a[..out_len], &mut self.kernel[..])
        }
    }

    /// Flip after a step wrote its output.
    pub fn flip(&mut self) {
        self.live_in_a = !self.live_in_a;
    }

    /// The live buffer's first `len` elements (the final output).
    pub fn current(&self, len: usize) -> &[i8] {
        if self.live_in_a {
            &self.a[..len]
        } else {
            &self.b[..len]
        }
    }

    /// The *other* buffer's first `len` elements — the output a step just
    /// wrote, viewed before [`flip`](Self::flip). Used by the plan
    /// runner's per-step observer hook.
    pub fn out_view(&self, len: usize) -> &[i8] {
        if self.live_in_a {
            &self.b[..len]
        } else {
            &self.a[..len]
        }
    }

    /// Buffer base pointers — used by tests to prove pointer stability
    /// (no reallocation on the hot path).
    pub fn buf_ptrs(&self) -> Vec<usize> {
        vec![
            self.a.as_ptr() as usize,
            self.b.as_ptr() as usize,
            self.kernel.as_ptr() as usize,
        ]
    }

    /// Total allocated bytes (must equal the memory plan's executor size,
    /// modulo the input/output endpoint adjustment).
    pub fn total_bytes(&self) -> usize {
        self.a.len() + self.b.len() + self.kernel.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::plan::{CompileOptions, CompiledModel};
    use crate::format::mfb::MfbModel;

    #[test]
    fn split_gives_disjoint_views_and_flip_swaps() {
        let m = MfbModel::parse(&crate::format::mfb::tests::tiny_mfb()).unwrap();
        let c = CompiledModel::compile(&m, CompileOptions::default()).unwrap();
        let mut s = Scratch::for_plan(&c);
        s.load_input(&[5, 6]);
        {
            let (x, y, _) = s.split(2, 3);
            assert_eq!(x, &[5, 6]);
            y[0] = 9;
        }
        s.flip();
        assert_eq!(s.current(3)[0], 9);
    }

    #[test]
    fn sized_at_least_for_endpoints() {
        let m = MfbModel::parse(&crate::format::mfb::tests::tiny_mfb()).unwrap();
        let c = CompiledModel::compile(&m, CompileOptions::default()).unwrap();
        let s = Scratch::for_plan(&c);
        assert!(s.a.len() >= c.input_len());
        assert!(s.b.len() >= c.output_len());
        // register-tiled kernels: no accumulator buffer anywhere
        assert_eq!(s.total_bytes(), s.a.len() + s.b.len() + s.kernel.len());
    }
}
