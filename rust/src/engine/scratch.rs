//! Static scratch buffers for the plan executor (paper Sec. 4.2).
//!
//! Two ping-pong activation buffers + one i8 kernel scratch buffer + one
//! i32 accumulator buffer (for wide-output FullyConnected, whose
//! accumulators don't fit the narrow-path stack array), sized by the
//! compiler's [`MemoryPlan`](crate::compiler::memory::MemoryPlan) and
//! allocated exactly once. `split` hands the executor disjoint
//! `(input, output, scratch, acc)` views without any unsafe code, via
//! `RefCell`-free plain borrows.

use crate::compiler::plan::CompiledModel;

/// Owned executor buffers.
#[derive(Debug)]
pub struct Scratch {
    a: Vec<i8>,
    b: Vec<i8>,
    kernel: Vec<i8>,
    /// i32 accumulator scratch for wide-output FullyConnected — threading
    /// it through the plan keeps the whole predict path allocation-free
    /// (ROADMAP open item closed in this PR).
    acc: Vec<i32>,
    /// Which buffer currently holds the live activations.
    live_in_a: bool,
}

impl Scratch {
    /// Allocate buffers per the compiled memory plan.
    pub fn for_plan(compiled: &CompiledModel) -> Scratch {
        let m = &compiled.memory;
        // both buffers must also hold the model input/output endpoints
        let a = m.buf_a.max(compiled.input_len()).max(compiled.output_len());
        let b = m.buf_b.max(compiled.input_len()).max(compiled.output_len());
        Scratch {
            a: vec![0; a],
            b: vec![0; b],
            kernel: vec![0; m.scratch],
            acc: vec![0; m.acc_i32],
            live_in_a: true,
        }
    }

    /// Stage the model input into the live buffer.
    pub fn load_input(&mut self, input: &[i8]) {
        self.live_in_a = true;
        self.a[..input.len()].copy_from_slice(input);
    }

    /// Disjoint (input, output, kernel-scratch, i32-accumulator) views for
    /// one step.
    pub fn split(&mut self, in_len: usize, out_len: usize) -> (&[i8], &mut [i8], &mut [i8], &mut [i32]) {
        if self.live_in_a {
            (&self.a[..in_len], &mut self.b[..out_len], &mut self.kernel[..], &mut self.acc[..])
        } else {
            (&self.b[..in_len], &mut self.a[..out_len], &mut self.kernel[..], &mut self.acc[..])
        }
    }

    /// Flip after a step wrote its output.
    pub fn flip(&mut self) {
        self.live_in_a = !self.live_in_a;
    }

    /// The live buffer's first `len` elements (the final output).
    pub fn current(&self, len: usize) -> &[i8] {
        if self.live_in_a {
            &self.a[..len]
        } else {
            &self.b[..len]
        }
    }

    /// Buffer base pointers — used by tests to prove pointer stability
    /// (no reallocation on the hot path).
    pub fn buf_ptrs(&self) -> Vec<usize> {
        vec![
            self.a.as_ptr() as usize,
            self.b.as_ptr() as usize,
            self.kernel.as_ptr() as usize,
            self.acc.as_ptr() as usize,
        ]
    }

    /// Total allocated bytes (must equal the memory plan's executor size,
    /// modulo the input/output endpoint adjustment).
    pub fn total_bytes(&self) -> usize {
        self.a.len() + self.b.len() + self.kernel.len() + self.acc.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::plan::{CompileOptions, CompiledModel};
    use crate::format::mfb::MfbModel;

    #[test]
    fn split_gives_disjoint_views_and_flip_swaps() {
        let m = MfbModel::parse(&crate::format::mfb::tests::tiny_mfb()).unwrap();
        let c = CompiledModel::compile(&m, CompileOptions::default()).unwrap();
        let mut s = Scratch::for_plan(&c);
        s.load_input(&[5, 6]);
        {
            let (x, y, _, _) = s.split(2, 3);
            assert_eq!(x, &[5, 6]);
            y[0] = 9;
        }
        s.flip();
        assert_eq!(s.current(3)[0], 9);
    }

    #[test]
    fn sized_at_least_for_endpoints() {
        let m = MfbModel::parse(&crate::format::mfb::tests::tiny_mfb()).unwrap();
        let c = CompiledModel::compile(&m, CompileOptions::default()).unwrap();
        let s = Scratch::for_plan(&c);
        assert!(s.a.len() >= c.input_len());
        assert!(s.b.len() >= c.output_len());
        // the tiny FC is narrow (n = 3): no accumulator scratch needed
        assert_eq!(s.acc.len(), 0);
    }
}
