//! The MicroFlow Runtime engine (paper Sec. 3.4; DESIGN.md S12).
//!
//! Executes a [`CompiledModel`]: a straight-line walk over the plan's
//! steps with two ping-pong activation buffers and one scratch buffer, all
//! sized by the compiler's [`MemoryPlan`] and allocated **once** at engine
//! construction — the host-side equivalent of the paper's static stack
//! allocation (no allocation ever happens on the predict path; asserted by
//! `tests::no_allocation_on_hot_path` via buffer-pointer stability).
//!
//! The paged mode (Sec. 4.3) stages FullyConnected weight pages through the
//! scratch buffer; everything else is identical.

mod scratch;

pub use scratch::Scratch;

use anyhow::Result;

use crate::compiler::plan::{CompiledModel, CompileOptions, StepKind};
use crate::format::mfb::MfbModel;
use crate::kernels::microkernel::backend;
use crate::kernels::{activation, average_pool2d, conv2d, depthwise_conv2d, fully_connected};
use crate::observe::StepObserver;
use crate::tensor::quant::QParams;

/// The MicroFlow inference engine.
///
/// Construction runs the full compiler pipeline; [`MicroFlowEngine::predict`]
/// is the pure runtime of the paper — kernels plus folded constants only.
///
/// This is the engine-internal layer: serving code should construct it
/// through [`crate::api::Session::builder`] (with
/// [`crate::api::Engine::MicroFlow`]), which wraps it behind the uniform
/// [`crate::api::InferenceSession`] surface.
pub struct MicroFlowEngine {
    /// Shared with the warm-session cache: N replicas built from the same
    /// cached plan hold one folded-weights image (the host-side analogue
    /// of N cores streaming the same Flash).
    compiled: std::sync::Arc<CompiledModel>,
    scratch: std::cell::RefCell<Scratch>,
}

impl MicroFlowEngine {
    /// Compile a parsed MFB model.
    pub fn new(model: &MfbModel, options: CompileOptions) -> Result<Self> {
        let compiled = CompiledModel::compile(model, options)?;
        Ok(Self::from_compiled(std::sync::Arc::new(compiled)))
    }

    /// Wrap an already-compiled plan (the warm-cache path): only the
    /// per-engine scratch buffers are allocated here.
    pub fn from_compiled(compiled: std::sync::Arc<CompiledModel>) -> Self {
        // resolve the kernel backend NOW (env lookup + feature detection
        // allocate) so the predict path below only pays a cached load —
        // tests/alloc_free.rs counts allocations from the first warm call
        let _ = backend::active();
        let scratch = Scratch::for_plan(&compiled);
        MicroFlowEngine { compiled, scratch: std::cell::RefCell::new(scratch) }
    }

    /// Load + compile from an `.mfb` file.
    pub fn load(path: impl AsRef<std::path::Path>, options: CompileOptions) -> Result<Self> {
        let model = MfbModel::load(path)?;
        Self::new(&model, options)
    }

    pub fn compiled(&self) -> &CompiledModel {
        &self.compiled
    }

    pub fn input_len(&self) -> usize {
        self.compiled.input_len()
    }

    pub fn output_len(&self) -> usize {
        self.compiled.output_len()
    }

    pub fn input_qparams(&self) -> QParams {
        self.compiled.input_qparams
    }

    pub fn output_qparams(&self) -> QParams {
        self.compiled.output_qparams
    }

    /// Base addresses of the static buffers — pointer-stability
    /// diagnostics for the no-allocation conformance tests.
    pub fn buffer_ptrs(&self) -> Vec<usize> {
        self.scratch.borrow().buf_ptrs()
    }

    /// Quantized inference: int8 in, int8 out, written into `out`.
    ///
    /// This is the hot path: no allocation, no parsing, no dispatch beyond
    /// one match per step.
    pub fn predict_into(&self, input: &[i8], out: &mut [i8]) {
        assert_eq!(input.len(), self.compiled.input_len(), "input length");
        assert_eq!(out.len(), self.compiled.output_len(), "output length");
        let mut scratch = self.scratch.borrow_mut();
        let result = run_plan(&self.compiled, input, &mut scratch);
        out.copy_from_slice(result);
    }

    /// [`MicroFlowEngine::predict_into`] with a per-step observer attached
    /// — the profiling path (`audit --profile`, `ServerConfig::profile`).
    /// Same hot-path guarantees: the observer hooks add two `Instant`
    /// reads and two integer adds per step and allocate nothing.
    pub fn predict_into_observed(&self, input: &[i8], out: &mut [i8], observer: &mut dyn StepObserver) {
        assert_eq!(input.len(), self.compiled.input_len(), "input length");
        assert_eq!(out.len(), self.compiled.output_len(), "output length");
        let mut scratch = self.scratch.borrow_mut();
        let result = run_plan_from(&self.compiled, 0, input, &mut scratch, Some(observer));
        out.copy_from_slice(result);
    }

    /// Quantized inference, allocating the output (convenience).
    pub fn predict(&self, input: &[i8]) -> Vec<i8> {
        let mut out = vec![0i8; self.compiled.output_len()];
        self.predict_into(input, &mut out);
        out
    }

    /// Float convenience wrapper: quantizes the input with the model's
    /// input qparams, dequantizes the output.
    pub fn predict_f32(&self, input: &[f32]) -> Vec<f32> {
        let q = self.compiled.input_qparams.quantize_slice(input);
        let out = self.predict(&q);
        let oq = self.compiled.output_qparams;
        out.iter().map(|&v| oq.dequantize(v)).collect()
    }
}

/// Execute the plan over the scratch buffers; returns the slice holding the
/// final activations (one of the ping-pong buffers).
pub(crate) fn run_plan<'a>(
    compiled: &CompiledModel,
    input: &[i8],
    scratch: &'a mut Scratch,
) -> &'a [i8] {
    run_plan_from(compiled, 0, input, scratch, None)
}

/// Execute the plan from `first_step` to the end, with `input` staged as
/// the activation entering `first_step` (the model input when 0, an
/// intermediate activation otherwise — the streaming executor's tail
/// re-entry). `observe` is a [`StepObserver`] hooked around every executed
/// step: `on_step_start` right before the kernel, `on_step` with the step
/// index and its freshly written output right after (streaming uses the
/// latter to capture per-layer state while priming; profilers time the
/// pair). Plain `FnMut(usize, &[i8])` closures still satisfy the trait via
/// its blanket impl. Range runs must use a scratch sized by
/// [`Scratch::for_plan_any_start`], since the original ping-pong parity
/// does not apply mid-plan.
pub(crate) fn run_plan_from<'a>(
    compiled: &CompiledModel,
    first_step: usize,
    input: &[i8],
    scratch: &'a mut Scratch,
    mut observe: Option<&mut dyn StepObserver>,
) -> &'a [i8] {
    debug_assert_eq!(
        input.len(),
        compiled.steps.get(first_step).map_or(compiled.input_len(), |s| s.in_len),
        "range-run input length"
    );
    scratch.load_input(input);
    // one cached OnceLock load per predict; the per-step kernel calls
    // below thread the same backend explicitly
    let kb = backend::active();
    for (i, step) in compiled.steps.iter().enumerate().skip(first_step) {
        let in_len = step.in_len;
        let out_len = step.out_len;
        if let Some(obs) = observe.as_mut() {
            obs.on_step_start(i);
        }
        match &step.kind {
            StepKind::Reshape => {
                // pure metadata: the buffer is reinterpreted, nothing runs
                if let Some(obs) = observe.as_mut() {
                    obs.on_step(i, scratch.current(out_len));
                }
                continue;
            }
            StepKind::FullyConnected { k, n, weights, pc, paged } => {
                let (x, y, page) = scratch.split(in_len, out_len);
                if *paged {
                    // paged mode models the Flash→RAM page stage; its one
                    // column at a time is deliberately left scalar
                    fully_connected::fully_connected_paged(x, weights, *k, *n, pc, &mut page[..*k], y);
                } else {
                    fully_connected::fully_connected_microflow_with(kb, x, weights, *k, *n, pc, y);
                }
            }
            StepKind::Conv2D { geo, filters, z_x, pc } => {
                let (x, y, view) = scratch.split(in_len, out_len);
                conv2d::conv2d_microflow_with(
                    kb,
                    x,
                    filters,
                    geo,
                    *z_x,
                    pc,
                    &mut view[..step.scratch_len],
                    y,
                );
            }
            StepKind::DepthwiseConv2D { geo, depth_multiplier, filters, z_x, pc } => {
                let (x, y, view) = scratch.split(in_len, out_len);
                depthwise_conv2d::depthwise_conv2d_microflow_with(
                    kb,
                    x,
                    filters,
                    geo,
                    *depth_multiplier,
                    *z_x,
                    pc,
                    &mut view[..step.scratch_len],
                    y,
                );
            }
            StepKind::AveragePool2D { geo, z_x, ratio, z_y, act_min, act_max } => {
                let (x, y, view) = scratch.split(in_len, out_len);
                average_pool2d::average_pool2d_microflow(
                    x,
                    geo,
                    *z_x,
                    *ratio,
                    *z_y,
                    *act_min,
                    *act_max,
                    &mut view[..step.scratch_len],
                    y,
                );
            }
            StepKind::Softmax { s_x, z_x, s_y, z_y } => {
                let (x, y, _) = scratch.split(in_len, out_len);
                activation::softmax(x, *s_x, *z_x, *s_y, *z_y, y);
            }
            StepKind::Relu { s_x, z_x, s_y, z_y } => {
                let (x, y, _) = scratch.split(in_len, out_len);
                activation::relu(x, *s_x, *z_x, *s_y, *z_y, y);
            }
            StepKind::Relu6 { s_x, z_x, s_y, z_y } => {
                let (x, y, _) = scratch.split(in_len, out_len);
                activation::relu6(x, *s_x, *z_x, *s_y, *z_y, y);
            }
        }
        if let Some(obs) = observe.as_mut() {
            obs.on_step(i, scratch.out_view(out_len));
        }
        scratch.flip();
    }
    scratch.current(compiled.output_len())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::format::mfb::MfbModel;

    fn tiny_engine(paging: bool) -> MicroFlowEngine {
        let m = MfbModel::parse(&crate::format::mfb::tests::tiny_mfb()).unwrap();
        MicroFlowEngine::new(&m, CompileOptions { paging, ..Default::default() }).unwrap()
    }

    #[test]
    fn tiny_fc_forward_is_correct() {
        // model: FC [2 -> 3], W (K,N) = [[1,2,3],[-1,-2,-3]], b = [10,-20,30]
        // s_x=0.5 z_x=-1, s_w=0.25 z_w=0, s_y=1.0 z_y=0, fused relu
        let e = tiny_engine(false);
        let x = [3i8, 1]; // dequant: (3-(-1))*0.5 = 2.0, (1+1)*0.5 = 1.0
        let out = e.predict(&x);
        // acc_j = sum (x - zx)(w): real = 0.5*0.25 * [(4*1+2*-1), (4*2+2*-2), (4*3+2*-3)]
        //       = 0.125 * [2, 4, 6] = [0.25, 0.5, 0.75]
        // bias real = 0.125 * [10,-20,30] = [1.25, -2.5, 3.75]
        // y = relu([1.5, -2, 4.5]) / s_y = [2, 0, 5] after round (1.5 -> 2)
        assert_eq!(out, vec![2, 0, 5]);
    }

    #[test]
    fn paged_equals_unpaged() {
        let a = tiny_engine(false);
        let b = tiny_engine(true);
        for x in [[0i8, 0], [127, -128], [-5, 99]] {
            assert_eq!(a.predict(&x), b.predict(&x));
        }
    }

    #[test]
    fn predict_f32_roundtrips_quantization() {
        let e = tiny_engine(false);
        let y = e.predict_f32(&[2.0, 1.0]);
        assert_eq!(y.len(), 3);
        assert!((y[0] - 2.0).abs() < 1e-5);
    }

    #[test]
    fn no_allocation_on_hot_path() {
        // buffer pointers must be stable across predict calls — the static
        // allocation story of Sec. 4.2
        let e = tiny_engine(false);
        let p0 = e.scratch.borrow().buf_ptrs();
        for _ in 0..10 {
            e.predict(&[1, 2]);
        }
        let p1 = e.scratch.borrow().buf_ptrs();
        assert_eq!(p0, p1);
    }

    #[test]
    #[should_panic(expected = "input length")]
    fn wrong_input_length_panics() {
        tiny_engine(false).predict(&[1, 2, 3]);
    }

    #[test]
    fn observed_predict_matches_and_profiles_every_step() {
        let e = tiny_engine(false);
        let mut prof = crate::observe::StepProfiler::new();
        let mut out = [0i8; 3];
        e.predict_into_observed(&[3, 1], &mut out, &mut prof);
        assert_eq!(out, [2, 0, 5], "observer must not change results");
        assert_eq!(prof.observed_steps(), e.compiled().steps.len());
        assert!(prof.stats()[..prof.observed_steps()].iter().all(|s| s.invocations == 1));
    }
}
