//! Binary container formats (DESIGN.md S4, §6): readers for the three
//! build-time artifacts produced by `python/compile/export_mfb.py`.
//!
//! * [`mfb`]     — the MFB model container (TFLite-equivalent; byte layout
//!   documented in the Python exporter and mirrored in `mfb::MfbModel`);
//! * [`builder`] — the MFB writer (inverse of the reader; used by
//!   `api::ModelSource::Parsed` and the synthetic-model test suites);
//! * [`mds`]     — evaluation datasets;
//! * [`golden`]  — int8 golden input/output pairs from the JAX oracle.
//!
//! All formats are little-endian. Any layout change must be made in both
//! the exporter and these readers/writers, bumping the embedded version
//! field.

pub mod builder;
pub mod error;
pub mod golden;
pub mod mds;
pub mod mfb;
pub mod reader;

pub use error::DecodeError;
pub use golden::Golden;
pub use mds::{Labels, MdsDataset};
pub use mfb::{MfbModel, OpCode, Operator, Padding, TensorDef};
