//! GLD golden vector reader (DESIGN.md S4): int8 input/output pairs
//! produced by the JAX oracle at build time. The Rust engines must
//! reproduce these **bit-exactly** (MicroFlow float-scale path) or within
//! ±1 output unit (TFLM fixed-point path) — asserted in
//! `rust/tests/integration_artifacts.rs`.
//!
//! ```text
//! magic "GLD1" | u32 version=1 | u32 n
//! u8 in_ndims | u32* dims        (per-sample)
//! u8 out_ndims | u32* dims
//! i8* X (n * prod(in))  | i8* Y (n * prod(out))
//! ```

use anyhow::{bail, Context, Result};

use super::reader::Reader;

/// Golden input/output pairs.
#[derive(Clone, Debug)]
pub struct Golden {
    pub n: usize,
    pub in_shape: Vec<usize>,
    pub out_shape: Vec<usize>,
    pub x: Vec<i8>,
    pub y: Vec<i8>,
}

impl Golden {
    pub fn parse(buf: &[u8]) -> Result<Golden> {
        let mut r = Reader::new(buf);
        r.magic(b"GLD1")?;
        let version = r.u32()?;
        if version != 1 {
            bail!("unsupported GLD version {version}");
        }
        let n = r.u32()? as usize;
        let in_nd = r.u8()? as usize;
        let mut in_shape = Vec::with_capacity(in_nd);
        for _ in 0..in_nd {
            in_shape.push(r.u32()? as usize);
        }
        let out_nd = r.u8()? as usize;
        let mut out_shape = Vec::with_capacity(out_nd);
        for _ in 0..out_nd {
            out_shape.push(r.u32()? as usize);
        }
        let in_len: usize = in_shape.iter().product();
        let out_len: usize = out_shape.iter().product();
        let x = r.i8_vec(n * in_len)?;
        let y = r.i8_vec(n * out_len)?;
        Ok(Golden { n, in_shape, out_shape, x, y })
    }

    pub fn load(path: impl AsRef<std::path::Path>) -> Result<Golden> {
        let buf = std::fs::read(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::parse(&buf)
    }

    pub fn in_len(&self) -> usize {
        self.in_shape.iter().product()
    }

    pub fn out_len(&self) -> usize {
        self.out_shape.iter().product()
    }

    pub fn input(&self, i: usize) -> &[i8] {
        let len = self.in_len();
        &self.x[i * len..(i + 1) * len]
    }

    pub fn output(&self, i: usize) -> &[i8] {
        let len = self.out_len();
        &self.y[i * len..(i + 1) * len]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn build() -> Vec<u8> {
        let mut b: Vec<u8> = Vec::new();
        b.extend_from_slice(b"GLD1");
        b.extend_from_slice(&1u32.to_le_bytes());
        b.extend_from_slice(&2u32.to_le_bytes()); // n = 2
        b.push(1);
        b.extend_from_slice(&3u32.to_le_bytes()); // in [3]
        b.push(1);
        b.extend_from_slice(&1u32.to_le_bytes()); // out [1]
        b.extend_from_slice(&[1u8, 2, 255, 4, 5, 6]); // X
        b.extend_from_slice(&[10u8, 246]); // Y: 10, -10
        b
    }

    #[test]
    fn parses_and_indexes() {
        let g = Golden::parse(&build()).unwrap();
        assert_eq!(g.n, 2);
        assert_eq!(g.input(0), &[1, 2, -1]);
        assert_eq!(g.input(1), &[4, 5, 6]);
        assert_eq!(g.output(0), &[10]);
        assert_eq!(g.output(1), &[-10]);
    }

    #[test]
    fn truncation_is_error() {
        let b = build();
        assert!(Golden::parse(&b[..b.len() - 1]).is_err());
    }
}
