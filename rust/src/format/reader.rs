//! Little-endian byte cursor shared by the format readers.
//!
//! Deliberately fallible everywhere (no panics on truncated input): the
//! interpreter baseline parses models at runtime like TFLM does, so a
//! malformed file must surface as an error, not UB or a crash — that is
//! the paper's robustness argument in executable form. Every rejection
//! carries a stable `E4xx` code ([`super::error::DecodeError`]) so the
//! mutation harness can assert the *kind* of failure.

use super::error::{DecodeError, E_MAGIC, E_TRUNCATED, E_UTF8};

/// Cursor over a byte slice with checked little-endian reads.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn position(&self) -> usize {
        self.pos
    }

    pub fn take(&mut self, n: usize) -> Result<&'a [u8], DecodeError> {
        if self.remaining() < n {
            return Err(DecodeError::new(
                E_TRUNCATED,
                format!(
                    "truncated input: need {n} bytes at offset {}, have {}",
                    self.pos,
                    self.remaining()
                ),
            ));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8, DecodeError> {
        Ok(self.take(1)?[0])
    }

    pub fn u16(&mut self) -> Result<u16, DecodeError> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    pub fn u32(&mut self) -> Result<u32, DecodeError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn i32(&mut self) -> Result<i32, DecodeError> {
        let b = self.take(4)?;
        Ok(i32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn u64(&mut self) -> Result<u64, DecodeError> {
        let b = self.take(8)?;
        let arr: [u8; 8] = b.try_into().expect("take(8) returned 8 bytes");
        Ok(u64::from_le_bytes(arr))
    }

    pub fn f32(&mut self) -> Result<f32, DecodeError> {
        let b = self.take(4)?;
        Ok(f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    /// `str := u16 len | utf8 bytes`
    pub fn string(&mut self) -> Result<String, DecodeError> {
        let len = self.u16()? as usize;
        let at = self.pos;
        let raw = self.take(len)?;
        String::from_utf8(raw.to_vec())
            .map_err(|_| DecodeError::new(E_UTF8, format!("invalid utf8 in string at offset {at}")))
    }

    pub fn magic(&mut self, expect: &[u8; 4]) -> Result<(), DecodeError> {
        let m = self.take(4)?;
        if m != expect {
            return Err(DecodeError::new(
                E_MAGIC,
                format!(
                    "bad magic: expected {:?} got {:?}",
                    String::from_utf8_lossy(expect),
                    String::from_utf8_lossy(m)
                ),
            ));
        }
        Ok(())
    }

    pub fn i8_vec(&mut self, n: usize) -> Result<Vec<i8>, DecodeError> {
        let raw = self.take(n)?;
        Ok(raw.iter().map(|&b| b as i8).collect())
    }

    pub fn i32_vec(&mut self, n: usize) -> Result<Vec<i32>, DecodeError> {
        let raw = self.take(checked_len(n, 4)?)?;
        Ok(raw.chunks_exact(4).map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
    }

    pub fn f32_vec(&mut self, n: usize) -> Result<Vec<f32>, DecodeError> {
        let raw = self.take(checked_len(n, 4)?)?;
        Ok(raw.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
    }
}

fn checked_len(n: usize, elem: usize) -> Result<usize, DecodeError> {
    n.checked_mul(elem).ok_or_else(|| {
        DecodeError::new(
            super::error::E_COUNT,
            format!("element count {n} x {elem} bytes overflows usize"),
        )
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reads_scalars_in_order() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&7u16.to_le_bytes());
        buf.extend_from_slice(&0xdead_beefu32.to_le_bytes());
        buf.extend_from_slice(&(-5i32).to_le_bytes());
        buf.extend_from_slice(&1.5f32.to_le_bytes());
        let mut r = Reader::new(&buf);
        assert_eq!(r.u16().unwrap(), 7);
        assert_eq!(r.u32().unwrap(), 0xdead_beef);
        assert_eq!(r.i32().unwrap(), -5);
        assert_eq!(r.f32().unwrap(), 1.5);
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn truncation_is_a_coded_error_not_a_panic() {
        let mut r = Reader::new(&[1, 2]);
        assert_eq!(r.u32().unwrap_err().code, "E402");
    }

    #[test]
    fn string_roundtrip() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&5u16.to_le_bytes());
        buf.extend_from_slice(b"hello");
        let mut r = Reader::new(&buf);
        assert_eq!(r.string().unwrap(), "hello");
    }

    #[test]
    fn invalid_utf8_is_e403() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&2u16.to_le_bytes());
        buf.extend_from_slice(&[0xff, 0xfe]);
        let mut r = Reader::new(&buf);
        assert_eq!(r.string().unwrap_err().code, "E403");
    }

    #[test]
    fn bad_magic_reports_both_and_is_e401() {
        let mut r = Reader::new(b"XXXXrest");
        let err = r.magic(b"MFB1").unwrap_err();
        assert_eq!(err.code, "E401");
        let msg = err.to_string();
        assert!(msg.contains("MFB1") && msg.contains("XXXX"), "{msg}");
    }

    #[test]
    fn vec_length_overflow_is_e404() {
        let mut r = Reader::new(&[0u8; 16]);
        assert_eq!(r.i32_vec(usize::MAX / 2).unwrap_err().code, "E404");
    }
}
