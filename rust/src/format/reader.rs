//! Little-endian byte cursor shared by the format readers.
//!
//! Deliberately fallible everywhere (no panics on truncated input): the
//! interpreter baseline parses models at runtime like TFLM does, so a
//! malformed file must surface as an error, not UB or a crash — that is
//! the paper's robustness argument in executable form.

use anyhow::{bail, Context, Result};

/// Cursor over a byte slice with checked little-endian reads.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn position(&self) -> usize {
        self.pos
    }

    pub fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            bail!("truncated input: need {n} bytes at offset {}, have {}", self.pos, self.remaining());
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u16(&mut self) -> Result<u16> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    pub fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn i32(&mut self) -> Result<i32> {
        let b = self.take(4)?;
        Ok(i32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes(b.try_into().unwrap()))
    }

    pub fn f32(&mut self) -> Result<f32> {
        let b = self.take(4)?;
        Ok(f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    /// `str := u16 len | utf8 bytes`
    pub fn string(&mut self) -> Result<String> {
        let len = self.u16()? as usize;
        let raw = self.take(len)?;
        String::from_utf8(raw.to_vec()).context("invalid utf8 in string field")
    }

    pub fn magic(&mut self, expect: &[u8; 4]) -> Result<()> {
        let m = self.take(4)?;
        if m != expect {
            bail!(
                "bad magic: expected {:?} got {:?}",
                String::from_utf8_lossy(expect),
                String::from_utf8_lossy(m)
            );
        }
        Ok(())
    }

    pub fn i8_vec(&mut self, n: usize) -> Result<Vec<i8>> {
        let raw = self.take(n)?;
        Ok(raw.iter().map(|&b| b as i8).collect())
    }

    pub fn i32_vec(&mut self, n: usize) -> Result<Vec<i32>> {
        let raw = self.take(n.checked_mul(4).context("i32 vec overflow")?)?;
        Ok(raw.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    pub fn f32_vec(&mut self, n: usize) -> Result<Vec<f32>> {
        let raw = self.take(n.checked_mul(4).context("f32 vec overflow")?)?;
        Ok(raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reads_scalars_in_order() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&7u16.to_le_bytes());
        buf.extend_from_slice(&0xdead_beefu32.to_le_bytes());
        buf.extend_from_slice(&(-5i32).to_le_bytes());
        buf.extend_from_slice(&1.5f32.to_le_bytes());
        let mut r = Reader::new(&buf);
        assert_eq!(r.u16().unwrap(), 7);
        assert_eq!(r.u32().unwrap(), 0xdead_beef);
        assert_eq!(r.i32().unwrap(), -5);
        assert_eq!(r.f32().unwrap(), 1.5);
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn truncation_is_an_error_not_a_panic() {
        let mut r = Reader::new(&[1, 2]);
        assert!(r.u32().is_err());
    }

    #[test]
    fn string_roundtrip() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&5u16.to_le_bytes());
        buf.extend_from_slice(b"hello");
        let mut r = Reader::new(&buf);
        assert_eq!(r.string().unwrap(), "hello");
    }

    #[test]
    fn bad_magic_reports_both() {
        let mut r = Reader::new(b"XXXXrest");
        let err = r.magic(b"MFB1").unwrap_err().to_string();
        assert!(err.contains("MFB1") && err.contains("XXXX"), "{err}");
    }
}
