//! MFB model container reader (DESIGN.md S4).
//!
//! Byte layout (little-endian) — must stay in lockstep with
//! `python/compile/export_mfb.py`:
//!
//! ```text
//! magic "MFB1" | u32 version=1 | str producer
//! u32 n_tensors | tensor*
//! u32 n_ops     | op*
//! u8 n_graph_in  | i32*   (tensor indices)
//! u8 n_graph_out | i32*
//! str metadata
//!
//! str    := u16 len | utf8 bytes
//! tensor := str name | u8 dtype(0=i8,1=i32,2=f32) | u8 ndims | u32* dims
//!           | f32 scale | i32 zero_point | u64 nbytes | bytes data
//! op     := u8 opcode | u32 version | u8 n_in | i32* | u8 n_out | i32*
//!           | u16 opt_len | opts
//! ```
//!
//! The container intentionally mirrors what a TFLite FlatBuffer carries
//! (names, versions, metadata, full tensor tables) so the interpreter
//! baseline has the same amount of runtime parsing to do as TFLM, while
//! the MicroFlow compiler strips everything it can (paper Sec. 6.2.2).
//!
//! ## Decoder contract
//!
//! [`MfbModel::parse`] is **strict and total** on arbitrary bytes: every
//! count, length, index and enum code is validated before use, nothing is
//! trusted for allocation sizing, trailing bytes (in the container and in
//! every options sub-stream) are rejected, and every failure is a typed
//! [`DecodeError`] with a stable `E4xx` code — never a panic. The seeded
//! mutation harness (`tests/mfb_fuzz.rs`) holds the no-panic line.

use anyhow::{bail, Result};

use super::error::{DecodeError, E_COUNT, E_ENUM, E_INDEX, E_MAGIC, E_PAYLOAD, E_TRAILING};
use super::reader::Reader;
use crate::tensor::{DType, QParams};

/// Operator codes (mirrors the exporter's `OPCODES`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OpCode {
    FullyConnected,
    Conv2D,
    DepthwiseConv2D,
    AveragePool2D,
    Reshape,
    Softmax,
    Relu,
    Relu6,
}

impl OpCode {
    pub fn from_u8(v: u8) -> Result<Self, DecodeError> {
        Ok(match v {
            0 => OpCode::FullyConnected,
            1 => OpCode::Conv2D,
            2 => OpCode::DepthwiseConv2D,
            3 => OpCode::AveragePool2D,
            4 => OpCode::Reshape,
            5 => OpCode::Softmax,
            6 => OpCode::Relu,
            7 => OpCode::Relu6,
            other => return Err(DecodeError::new(E_ENUM, format!("unknown opcode {other}"))),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            OpCode::FullyConnected => "FullyConnected",
            OpCode::Conv2D => "Conv2D",
            OpCode::DepthwiseConv2D => "DepthwiseConv2D",
            OpCode::AveragePool2D => "AveragePool2D",
            OpCode::Reshape => "Reshape",
            OpCode::Softmax => "Softmax",
            OpCode::Relu => "Relu",
            OpCode::Relu6 => "Relu6",
        }
    }
}

/// Padding modes (TFLite convention).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Padding {
    Same,
    Valid,
}

impl Padding {
    pub fn from_u8(v: u8) -> Result<Self, DecodeError> {
        Ok(match v {
            0 => Padding::Same,
            1 => Padding::Valid,
            other => return Err(DecodeError::new(E_ENUM, format!("unknown padding code {other}"))),
        })
    }
}

/// One tensor table entry. Weight/bias tensors carry `data`; activation
/// tensors have empty `data` and are materialized by the engines.
#[derive(Clone, Debug)]
pub struct TensorDef {
    pub name: String,
    pub dtype: DType,
    pub dims: Vec<usize>,
    pub qparams: QParams,
    /// Raw payload bytes, stored as `i8` (the dominant view: int8 weights
    /// borrow it directly; wider dtypes reassemble from the bytes).
    pub data: Vec<i8>,
}

impl TensorDef {
    /// Element count; saturates instead of overflowing on hostile dims
    /// (the parser independently bounds payload-carrying tensors).
    pub fn numel(&self) -> usize {
        self.dims.iter().fold(1usize, |a, &b| a.saturating_mul(b))
    }

    /// Payload reinterpreted as int8 (weights).
    pub fn data_i8(&self) -> Result<Vec<i8>> {
        Ok(self.data_i8_ref()?.to_vec())
    }

    /// Borrowed int8 view of the payload — no copy, so per-invoke weight
    /// reads (the interpreter's "weights stay in Flash" story) don't
    /// allocate.
    pub fn data_i8_ref(&self) -> Result<&[i8]> {
        if self.dtype != DType::I8 {
            bail!("tensor {} is not i8", self.name);
        }
        Ok(&self.data)
    }

    /// Payload reinterpreted as int32 (biases).
    pub fn data_i32(&self) -> Result<Vec<i32>> {
        if self.dtype != DType::I32 {
            bail!("tensor {} is not i32", self.name);
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0] as u8, c[1] as u8, c[2] as u8, c[3] as u8]))
            .collect())
    }
}

/// Parsed operator options.
#[derive(Clone, Debug, PartialEq)]
pub enum OpOptions {
    FullyConnected { fused_act: u8 },
    Conv2D { stride: (usize, usize), padding: Padding, fused_act: u8 },
    DepthwiseConv2D { stride: (usize, usize), padding: Padding, fused_act: u8, depth_multiplier: usize },
    AveragePool2D { filter: (usize, usize), stride: (usize, usize), padding: Padding, fused_act: u8 },
    Reshape { dims: Vec<usize> },
    Softmax { beta: f32 },
    None,
}

/// One operator list entry: opcode, version, tensor indices and options.
#[derive(Clone, Debug)]
pub struct Operator {
    pub opcode: OpCode,
    pub version: u32,
    pub inputs: Vec<i32>,
    pub outputs: Vec<i32>,
    pub options: OpOptions,
}

impl Operator {
    pub fn input(&self, i: usize) -> Result<usize> {
        let idx = *self.inputs.get(i).ok_or_else(|| anyhow::anyhow!("missing operator input"))?;
        usize::try_from(idx).map_err(|_| anyhow::anyhow!("operator input {i} is absent"))
    }

    pub fn output(&self, i: usize) -> Result<usize> {
        let idx = *self.outputs.get(i).ok_or_else(|| anyhow::anyhow!("missing operator output"))?;
        usize::try_from(idx).map_err(|_| anyhow::anyhow!("operator output {i} is absent"))
    }
}

/// Smallest possible serialized tensor entry (empty name, 0 dims, no
/// payload): used to reject impossible `n_tensors` before allocating.
const TENSOR_MIN_BYTES: usize = 2 + 1 + 1 + 4 + 4 + 8;
/// Smallest possible serialized operator (no tensors, no options).
const OP_MIN_BYTES: usize = 1 + 4 + 1 + 1 + 2;

/// A parsed MFB model: the lossless internal representation of Fig. 4.
#[derive(Clone, Debug)]
pub struct MfbModel {
    pub version: u32,
    pub producer: String,
    pub tensors: Vec<TensorDef>,
    pub operators: Vec<Operator>,
    pub graph_inputs: Vec<usize>,
    pub graph_outputs: Vec<usize>,
    pub metadata: String,
    /// Total serialized size (the Flash cost of storing the file as TFLM
    /// stores the FlatBuffer; used by the memory model).
    pub file_bytes: usize,
}

impl MfbModel {
    /// Parse an MFB byte buffer (strict; see the module-level decoder
    /// contract).
    pub fn parse(buf: &[u8]) -> Result<MfbModel, DecodeError> {
        let mut r = Reader::new(buf);
        r.magic(b"MFB1")?;
        let version = r.u32()?;
        if version != 1 {
            return Err(DecodeError::new(E_MAGIC, format!("unsupported MFB version {version}")));
        }
        let producer = r.string()?;

        let n_tensors = checked_count(r.u32()?, "tensor count", r.remaining(), TENSOR_MIN_BYTES)?;
        let mut tensors = Vec::with_capacity(n_tensors);
        for ti in 0..n_tensors {
            let at_tensor = |e: DecodeError| e.wrap(format!("tensor #{ti}"));
            let name = r.string().map_err(at_tensor)?;
            let dtype = match r.u8()? {
                0 => DType::I8,
                1 => DType::I32,
                2 => DType::F32,
                other => {
                    return Err(DecodeError::new(
                        E_ENUM,
                        format!("unknown dtype code {other} in tensor {name}"),
                    ))
                }
            };
            let ndims = r.u8()? as usize;
            let mut dims = Vec::with_capacity(ndims);
            for _ in 0..ndims {
                dims.push(to_usize(r.u32()? as u64, "tensor dim")?);
            }
            let scale = r.f32()?;
            let zero_point = r.i32()?;
            let nbytes = to_usize(r.u64()?, "tensor payload length")?;
            let data = r.i8_vec(nbytes).map_err(at_tensor)?;
            if !data.is_empty() {
                let elems = dims
                    .iter()
                    .try_fold(1usize, |a, &b| a.checked_mul(b))
                    .and_then(|n| n.checked_mul(dtype.size_bytes()))
                    .ok_or_else(|| {
                        DecodeError::new(E_COUNT, format!("tensor {name}: dims overflow usize"))
                    })?;
                if data.len() != elems {
                    return Err(DecodeError::new(
                        E_PAYLOAD,
                        format!("tensor {name}: payload {} bytes, dims say {elems}", data.len()),
                    ));
                }
            }
            tensors.push(TensorDef { name, dtype, dims, qparams: QParams::new(scale, zero_point), data });
        }

        let n_ops = checked_count(r.u32()?, "operator count", r.remaining(), OP_MIN_BYTES)?;
        let mut operators = Vec::with_capacity(n_ops);
        for oi in 0..n_ops {
            let opcode = OpCode::from_u8(r.u8()?)
                .map_err(|e| e.wrap(format!("operator #{oi}")))?;
            let version = r.u32()?;
            let n_in = r.u8()? as usize;
            let mut inputs = Vec::with_capacity(n_in);
            for _ in 0..n_in {
                inputs.push(r.i32()?);
            }
            let n_out = r.u8()? as usize;
            let mut outputs = Vec::with_capacity(n_out);
            for _ in 0..n_out {
                outputs.push(r.i32()?);
            }
            let opt_len = r.u16()? as usize;
            let opts_raw = r.take(opt_len)?;
            let options = parse_options(opcode, opts_raw)
                .map_err(|e| e.wrap(format!("operator #{oi} ({})", opcode.name())))?;
            // validate indices now so downstream code can trust them
            // (negative means "absent" and is allowed by the container)
            for &idx in inputs.iter().chain(outputs.iter()) {
                if let Ok(t) = usize::try_from(idx) {
                    if t >= tensors.len() {
                        return Err(DecodeError::new(
                            E_INDEX,
                            format!(
                                "operator #{oi}: tensor index {idx} out of range ({} tensors)",
                                tensors.len()
                            ),
                        ));
                    }
                }
            }
            operators.push(Operator { opcode, version, inputs, outputs, options });
        }

        let graph_inputs = parse_graph_io(&mut r, tensors.len(), "input")?;
        let graph_outputs = parse_graph_io(&mut r, tensors.len(), "output")?;
        let metadata = r.string()?;
        if r.remaining() != 0 {
            return Err(DecodeError::new(
                E_TRAILING,
                format!("{} trailing bytes after a complete container", r.remaining()),
            ));
        }

        Ok(MfbModel {
            version,
            producer,
            tensors,
            operators,
            graph_inputs,
            graph_outputs,
            metadata,
            file_bytes: buf.len(),
        })
    }

    /// Load from a file path.
    pub fn load(path: impl AsRef<std::path::Path>) -> Result<MfbModel> {
        let buf = std::fs::read(path.as_ref())
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.as_ref().display()))?;
        Ok(Self::parse(&buf)?)
    }

    /// Sum of weight/bias payload bytes (the paper's model "Size").
    pub fn weights_bytes(&self) -> usize {
        self.tensors.iter().map(|t| t.data.len()).sum()
    }

    /// Bytes of *metadata* TFLM must keep in Flash but MicroFlow strips:
    /// names, options, versions, table structure — everything except the
    /// raw payloads.
    pub fn metadata_bytes(&self) -> usize {
        self.file_bytes - self.weights_bytes()
    }

    /// Per-sample input shape (graph input dims minus the batch dim).
    /// Total (never panics): scalar or missing io degrades to `[]`.
    pub fn input_shape(&self) -> Vec<usize> {
        self.io_shape(self.graph_inputs.first())
    }

    pub fn output_shape(&self) -> Vec<usize> {
        self.io_shape(self.graph_outputs.first())
    }

    fn io_shape(&self, idx: Option<&usize>) -> Vec<usize> {
        idx.and_then(|&i| self.tensors.get(i))
            .map(|t| t.dims.get(1..).unwrap_or_default().to_vec())
            .unwrap_or_default()
    }

    pub fn input_qparams(&self) -> QParams {
        self.io_qparams(self.graph_inputs.first())
    }

    pub fn output_qparams(&self) -> QParams {
        self.io_qparams(self.graph_outputs.first())
    }

    fn io_qparams(&self, idx: Option<&usize>) -> QParams {
        idx.and_then(|&i| self.tensors.get(i)).map(|t| t.qparams).unwrap_or(QParams::NONE)
    }
}

/// Validate an untrusted count field before allocating: `n` entries of at
/// least `min_bytes` each must fit in the remaining buffer.
fn checked_count(
    v: u32,
    what: &str,
    remaining: usize,
    min_bytes: usize,
) -> Result<usize, DecodeError> {
    let n = to_usize(v as u64, what)?;
    match n.checked_mul(min_bytes) {
        Some(need) if need <= remaining => Ok(n),
        _ => Err(DecodeError::new(
            E_COUNT,
            format!("{what} {n} impossible: needs >= {min_bytes} bytes each, {remaining} remain"),
        )),
    }
}

fn to_usize(v: u64, what: &str) -> Result<usize, DecodeError> {
    usize::try_from(v)
        .map_err(|_| DecodeError::new(E_COUNT, format!("{what} {v} overflows usize")))
}

fn parse_graph_io(
    r: &mut Reader<'_>,
    n_tensors: usize,
    what: &str,
) -> Result<Vec<usize>, DecodeError> {
    let n = r.u8()? as usize;
    if n == 0 {
        return Err(DecodeError::new(E_COUNT, format!("graph has no {what} tensors")));
    }
    let mut io = Vec::with_capacity(n);
    for _ in 0..n {
        let idx = r.i32()?;
        let t = usize::try_from(idx).ok().filter(|&t| t < n_tensors).ok_or_else(|| {
            DecodeError::new(E_INDEX, format!("graph {what} index {idx} out of range"))
        })?;
        io.push(t);
    }
    Ok(io)
}

fn parse_options(opcode: OpCode, raw: &[u8]) -> Result<OpOptions, DecodeError> {
    let mut r = Reader::new(raw);
    let options = match opcode {
        OpCode::FullyConnected => OpOptions::FullyConnected { fused_act: r.u8()? },
        OpCode::Conv2D => OpOptions::Conv2D {
            stride: (r.u8()? as usize, r.u8()? as usize),
            padding: Padding::from_u8(r.u8()?)?,
            fused_act: r.u8()?,
        },
        OpCode::DepthwiseConv2D => {
            let stride = (r.u8()? as usize, r.u8()? as usize);
            let padding = Padding::from_u8(r.u8()?)?;
            let fused_act = r.u8()?;
            let depth_multiplier = to_usize(r.u32()? as u64, "depth multiplier")?;
            OpOptions::DepthwiseConv2D { stride, padding, fused_act, depth_multiplier }
        }
        OpCode::AveragePool2D => OpOptions::AveragePool2D {
            filter: (r.u8()? as usize, r.u8()? as usize),
            stride: (r.u8()? as usize, r.u8()? as usize),
            padding: Padding::from_u8(r.u8()?)?,
            fused_act: r.u8()?,
        },
        OpCode::Reshape => {
            let ndims = r.u8()? as usize;
            let mut dims = Vec::with_capacity(ndims);
            for _ in 0..ndims {
                dims.push(to_usize(r.u32()? as u64, "reshape dim")?);
            }
            OpOptions::Reshape { dims }
        }
        OpCode::Softmax => OpOptions::Softmax { beta: r.f32()? },
        OpCode::Relu | OpCode::Relu6 => OpOptions::None,
    };
    if r.remaining() != 0 {
        return Err(DecodeError::new(
            E_TRAILING,
            format!("{} trailing bytes in options", r.remaining()),
        ));
    }
    Ok(options)
}

/// Test-only access to the private options parser (the writer's round-trip
/// tests exercise every `OpOptions` variant against it).
#[cfg(test)]
pub(crate) fn parse_options_for_test(opcode: OpCode, raw: &[u8]) -> Result<OpOptions, DecodeError> {
    parse_options(opcode, raw)
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;

    /// Hand-build a tiny valid MFB buffer (1 FC op) for parser tests.
    pub(crate) fn tiny_mfb() -> Vec<u8> {
        let mut b: Vec<u8> = Vec::new();
        let s = |b: &mut Vec<u8>, s: &str| {
            b.extend_from_slice(&(s.len() as u16).to_le_bytes());
            b.extend_from_slice(s.as_bytes());
        };
        b.extend_from_slice(b"MFB1");
        b.extend_from_slice(&1u32.to_le_bytes());
        s(&mut b, "test");
        b.extend_from_slice(&4u32.to_le_bytes()); // 4 tensors
        // t0: input act [1,2] i8
        s(&mut b, "in");
        b.push(0);
        b.push(2);
        b.extend_from_slice(&1u32.to_le_bytes());
        b.extend_from_slice(&2u32.to_le_bytes());
        b.extend_from_slice(&0.5f32.to_le_bytes());
        b.extend_from_slice(&(-1i32).to_le_bytes());
        b.extend_from_slice(&0u64.to_le_bytes());
        // t1: weights [2,3] i8 with data
        s(&mut b, "w");
        b.push(0);
        b.push(2);
        b.extend_from_slice(&2u32.to_le_bytes());
        b.extend_from_slice(&3u32.to_le_bytes());
        b.extend_from_slice(&0.25f32.to_le_bytes());
        b.extend_from_slice(&0i32.to_le_bytes());
        b.extend_from_slice(&6u64.to_le_bytes());
        b.extend_from_slice(&[1, 2, 3, 255, 254, 253]); // -1,-2,-3 as i8
        // t2: bias [3] i32
        s(&mut b, "b");
        b.push(1);
        b.push(1);
        b.extend_from_slice(&3u32.to_le_bytes());
        b.extend_from_slice(&0.125f32.to_le_bytes());
        b.extend_from_slice(&0i32.to_le_bytes());
        b.extend_from_slice(&12u64.to_le_bytes());
        for v in [10i32, -20, 30] {
            b.extend_from_slice(&v.to_le_bytes());
        }
        // t3: output act [1,3] i8
        s(&mut b, "out");
        b.push(0);
        b.push(2);
        b.extend_from_slice(&1u32.to_le_bytes());
        b.extend_from_slice(&3u32.to_le_bytes());
        b.extend_from_slice(&1.0f32.to_le_bytes());
        b.extend_from_slice(&0i32.to_le_bytes());
        b.extend_from_slice(&0u64.to_le_bytes());
        // 1 op: FC(in=0, w=1, b=2) -> 3, fused relu
        b.extend_from_slice(&1u32.to_le_bytes());
        b.push(0); // opcode FC
        b.extend_from_slice(&1u32.to_le_bytes()); // version
        b.push(3);
        for idx in [0i32, 1, 2] {
            b.extend_from_slice(&idx.to_le_bytes());
        }
        b.push(1);
        b.extend_from_slice(&3i32.to_le_bytes());
        b.extend_from_slice(&1u16.to_le_bytes());
        b.push(1); // fused_act = relu
        // graph io
        b.push(1);
        b.extend_from_slice(&0i32.to_le_bytes());
        b.push(1);
        b.extend_from_slice(&3i32.to_le_bytes());
        s(&mut b, "{}");
        b
    }

    #[test]
    fn parses_tiny_model() {
        let buf = tiny_mfb();
        let m = MfbModel::parse(&buf).unwrap();
        assert_eq!(m.producer, "test");
        assert_eq!(m.tensors.len(), 4);
        assert_eq!(m.operators.len(), 1);
        assert_eq!(m.operators[0].opcode, OpCode::FullyConnected);
        assert_eq!(m.operators[0].options, OpOptions::FullyConnected { fused_act: 1 });
        assert_eq!(m.tensors[1].data_i8().unwrap(), vec![1, 2, 3, -1, -2, -3]);
        assert_eq!(m.tensors[2].data_i32().unwrap(), vec![10, -20, 30]);
        assert_eq!(m.input_shape(), vec![2]);
        assert_eq!(m.output_shape(), vec![3]);
        assert_eq!(m.weights_bytes(), 18);
        assert_eq!(m.file_bytes, buf.len());
    }

    #[test]
    fn rejects_bad_magic_with_e401() {
        let mut buf = tiny_mfb();
        buf[0] = b'X';
        assert_eq!(MfbModel::parse(&buf).unwrap_err().code, "E401");
        let mut buf = tiny_mfb();
        buf[4] = 9; // version 9
        assert_eq!(MfbModel::parse(&buf).unwrap_err().code, "E401");
    }

    #[test]
    fn rejects_truncation_everywhere() {
        let buf = tiny_mfb();
        // every strict prefix must fail cleanly, never panic
        for cut in 0..buf.len() {
            assert!(MfbModel::parse(&buf[..cut]).is_err(), "prefix {cut} parsed");
        }
    }

    #[test]
    fn rejects_trailing_bytes_with_e406() {
        let mut buf = tiny_mfb();
        buf.push(0);
        assert_eq!(MfbModel::parse(&buf).unwrap_err().code, "E406");
    }

    #[test]
    fn rejects_out_of_range_tensor_index_with_e405() {
        let buf = tiny_mfb();
        let m = MfbModel::parse(&buf).unwrap();
        assert_eq!(m.graph_outputs, vec![3]);
        // corrupt: find the graph-output index bytes (3i32 near the tail)
        let mut bad = buf.clone();
        let tail = bad.len() - 4 - 2; // before metadata str "{}"
        bad[tail - 4..tail].copy_from_slice(&99i32.to_le_bytes());
        assert_eq!(MfbModel::parse(&bad).unwrap_err().code, "E405");
    }

    #[test]
    fn rejects_empty_graph_io_with_e404() {
        let mut buf = tiny_mfb();
        // n_graph_in byte sits 14 bytes from the end:
        // n_gin(1) gin(4) n_gout(1) gout(4) metadata(2+2)
        let pos = buf.len() - 14;
        assert_eq!(buf[pos], 1);
        buf[pos] = 0;
        assert_eq!(MfbModel::parse(&buf).unwrap_err().code, "E404");
    }

    #[test]
    fn rejects_impossible_tensor_count_with_e404() {
        let mut buf = tiny_mfb();
        // n_tensors field sits at offset 14 (magic 4, version 4, "test" 6)
        buf[14..18].copy_from_slice(&u32::MAX.to_le_bytes());
        assert_eq!(MfbModel::parse(&buf).unwrap_err().code, "E404");
    }

    #[test]
    fn rejects_unknown_dtype_with_e407() {
        let mut buf = tiny_mfb();
        // tensor t0's dtype byte: header 18 bytes + name "in" (2+2)
        assert_eq!(buf[22], 0);
        buf[22] = 9;
        assert_eq!(MfbModel::parse(&buf).unwrap_err().code, "E407");
    }

    #[test]
    fn wrong_payload_size_is_rejected_with_e408() {
        let mut buf = tiny_mfb();
        // tensor t1 declares [2,3] i8 = 6 bytes; claim 5
        // find the 6u64 length field: it's right before the 6 data bytes
        let pos = buf.windows(8).position(|w| w == 6u64.to_le_bytes()).unwrap();
        buf[pos..pos + 8].copy_from_slice(&5u64.to_le_bytes());
        buf.remove(pos + 8); // drop one payload byte to keep framing
        assert_eq!(MfbModel::parse(&buf).unwrap_err().code, "E408");
    }

    #[test]
    fn option_substream_trailing_bytes_are_e406() {
        let e = parse_options_for_test(OpCode::FullyConnected, &[0, 0]).unwrap_err();
        assert_eq!(e.code, "E406");
    }

    #[test]
    fn unknown_padding_in_options_is_e407() {
        let e = parse_options_for_test(OpCode::Conv2D, &[1, 1, 9, 0]).unwrap_err();
        assert_eq!(e.code, "E407");
    }

    #[test]
    fn accessors_are_total_on_degenerate_models() {
        let mut m = MfbModel::parse(&tiny_mfb()).unwrap();
        m.tensors[0].dims.clear(); // scalar graph input
        assert_eq!(m.input_shape(), Vec::<usize>::new());
        m.graph_inputs.clear(); // hostile hand-built model
        assert_eq!(m.input_shape(), Vec::<usize>::new());
        assert_eq!(m.input_qparams(), QParams::NONE);
        // numel saturates instead of overflowing
        m.tensors[1].dims = vec![usize::MAX, 3];
        assert_eq!(m.tensors[1].numel(), usize::MAX);
    }
}
