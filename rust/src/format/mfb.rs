//! MFB model container reader (DESIGN.md S4).
//!
//! Byte layout (little-endian) — must stay in lockstep with
//! `python/compile/export_mfb.py`:
//!
//! ```text
//! magic "MFB1" | u32 version=1 | str producer
//! u32 n_tensors | tensor*
//! u32 n_ops     | op*
//! u8 n_graph_in  | i32*   (tensor indices)
//! u8 n_graph_out | i32*
//! str metadata
//!
//! str    := u16 len | utf8 bytes
//! tensor := str name | u8 dtype(0=i8,1=i32,2=f32) | u8 ndims | u32* dims
//!           | f32 scale | i32 zero_point | u64 nbytes | bytes data
//! op     := u8 opcode | u32 version | u8 n_in | i32* | u8 n_out | i32*
//!           | u16 opt_len | opts
//! ```
//!
//! The container intentionally mirrors what a TFLite FlatBuffer carries
//! (names, versions, metadata, full tensor tables) so the interpreter
//! baseline has the same amount of runtime parsing to do as TFLM, while
//! the MicroFlow compiler strips everything it can (paper Sec. 6.2.2).

use anyhow::{bail, Context, Result};

use super::reader::Reader;
use crate::tensor::{DType, QParams};

/// Operator codes (mirrors the exporter's `OPCODES`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OpCode {
    FullyConnected,
    Conv2D,
    DepthwiseConv2D,
    AveragePool2D,
    Reshape,
    Softmax,
    Relu,
    Relu6,
}

impl OpCode {
    pub fn from_u8(v: u8) -> Result<Self> {
        Ok(match v {
            0 => OpCode::FullyConnected,
            1 => OpCode::Conv2D,
            2 => OpCode::DepthwiseConv2D,
            3 => OpCode::AveragePool2D,
            4 => OpCode::Reshape,
            5 => OpCode::Softmax,
            6 => OpCode::Relu,
            7 => OpCode::Relu6,
            other => bail!("unknown opcode {other}"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            OpCode::FullyConnected => "FullyConnected",
            OpCode::Conv2D => "Conv2D",
            OpCode::DepthwiseConv2D => "DepthwiseConv2D",
            OpCode::AveragePool2D => "AveragePool2D",
            OpCode::Reshape => "Reshape",
            OpCode::Softmax => "Softmax",
            OpCode::Relu => "Relu",
            OpCode::Relu6 => "Relu6",
        }
    }
}

/// Padding modes (TFLite convention).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Padding {
    Same,
    Valid,
}

impl Padding {
    pub fn from_u8(v: u8) -> Result<Self> {
        Ok(match v {
            0 => Padding::Same,
            1 => Padding::Valid,
            other => bail!("unknown padding code {other}"),
        })
    }
}

/// One tensor table entry. Weight/bias tensors carry `data`; activation
/// tensors have empty `data` and are materialized by the engines.
#[derive(Clone, Debug)]
pub struct TensorDef {
    pub name: String,
    pub dtype: DType,
    pub dims: Vec<usize>,
    pub qparams: QParams,
    pub data: Vec<u8>,
}

impl TensorDef {
    pub fn numel(&self) -> usize {
        self.dims.iter().product()
    }

    /// Payload reinterpreted as int8 (weights).
    pub fn data_i8(&self) -> Result<Vec<i8>> {
        Ok(self.data_i8_ref()?.to_vec())
    }

    /// Borrowed int8 view of the payload — no copy, so per-invoke weight
    /// reads (the interpreter's "weights stay in Flash" story) don't
    /// allocate.
    pub fn data_i8_ref(&self) -> Result<&[i8]> {
        if self.dtype != DType::I8 {
            bail!("tensor {} is not i8", self.name);
        }
        // SAFETY: i8 and u8 have identical size, alignment and validity.
        Ok(unsafe { std::slice::from_raw_parts(self.data.as_ptr() as *const i8, self.data.len()) })
    }

    /// Payload reinterpreted as int32 (biases).
    pub fn data_i32(&self) -> Result<Vec<i32>> {
        if self.dtype != DType::I32 {
            bail!("tensor {} is not i32", self.name);
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
}

/// Parsed operator options.
#[derive(Clone, Debug, PartialEq)]
pub enum OpOptions {
    FullyConnected { fused_act: u8 },
    Conv2D { stride: (usize, usize), padding: Padding, fused_act: u8 },
    DepthwiseConv2D { stride: (usize, usize), padding: Padding, fused_act: u8, depth_multiplier: usize },
    AveragePool2D { filter: (usize, usize), stride: (usize, usize), padding: Padding, fused_act: u8 },
    Reshape { dims: Vec<usize> },
    Softmax { beta: f32 },
    None,
}

/// One operator list entry: opcode, version, tensor indices and options.
#[derive(Clone, Debug)]
pub struct Operator {
    pub opcode: OpCode,
    pub version: u32,
    pub inputs: Vec<i32>,
    pub outputs: Vec<i32>,
    pub options: OpOptions,
}

impl Operator {
    pub fn input(&self, i: usize) -> Result<usize> {
        let idx = *self.inputs.get(i).context("missing operator input")?;
        if idx < 0 {
            bail!("operator input {i} is absent");
        }
        Ok(idx as usize)
    }

    pub fn output(&self, i: usize) -> Result<usize> {
        let idx = *self.outputs.get(i).context("missing operator output")?;
        if idx < 0 {
            bail!("operator output {i} is absent");
        }
        Ok(idx as usize)
    }
}

/// A parsed MFB model: the lossless internal representation of Fig. 4.
#[derive(Clone, Debug)]
pub struct MfbModel {
    pub version: u32,
    pub producer: String,
    pub tensors: Vec<TensorDef>,
    pub operators: Vec<Operator>,
    pub graph_inputs: Vec<usize>,
    pub graph_outputs: Vec<usize>,
    pub metadata: String,
    /// Total serialized size (the Flash cost of storing the file as TFLM
    /// stores the FlatBuffer; used by the memory model).
    pub file_bytes: usize,
}

impl MfbModel {
    /// Parse an MFB byte buffer.
    pub fn parse(buf: &[u8]) -> Result<MfbModel> {
        let mut r = Reader::new(buf);
        r.magic(b"MFB1")?;
        let version = r.u32()?;
        if version != 1 {
            bail!("unsupported MFB version {version}");
        }
        let producer = r.string()?;

        let n_tensors = r.u32()? as usize;
        // cap pre-allocation by remaining bytes: n_tensors is untrusted
        let mut tensors = Vec::with_capacity(n_tensors.min(r.remaining()));
        for _ in 0..n_tensors {
            let name = r.string()?;
            let dtype = match r.u8()? {
                0 => DType::I8,
                1 => DType::I32,
                2 => DType::F32,
                other => bail!("unknown dtype code {other} in tensor {name}"),
            };
            let ndims = r.u8()? as usize;
            let mut dims = Vec::with_capacity(ndims);
            for _ in 0..ndims {
                dims.push(r.u32()? as usize);
            }
            let scale = r.f32()?;
            let zero_point = r.i32()?;
            let nbytes = r.u64()? as usize;
            let data = r.take(nbytes)?.to_vec();
            if !data.is_empty() {
                let expect = dims.iter().product::<usize>() * dtype.size_bytes();
                if data.len() != expect {
                    bail!("tensor {name}: payload {} bytes, dims say {expect}", data.len());
                }
            }
            tensors.push(TensorDef { name, dtype, dims, qparams: QParams::new(scale, zero_point), data });
        }

        let n_ops = r.u32()? as usize;
        let mut operators = Vec::with_capacity(n_ops.min(r.remaining()));
        for oi in 0..n_ops {
            let opcode = OpCode::from_u8(r.u8()?)?;
            let version = r.u32()?;
            let n_in = r.u8()? as usize;
            let mut inputs = Vec::with_capacity(n_in);
            for _ in 0..n_in {
                inputs.push(r.i32()?);
            }
            let n_out = r.u8()? as usize;
            let mut outputs = Vec::with_capacity(n_out);
            for _ in 0..n_out {
                outputs.push(r.i32()?);
            }
            let opt_len = r.u16()? as usize;
            let opts_raw = r.take(opt_len)?;
            let options = parse_options(opcode, opts_raw)
                .with_context(|| format!("operator #{oi} ({})", opcode.name()))?;
            // validate indices now so downstream code can trust them
            for &idx in inputs.iter().chain(outputs.iter()) {
                if idx >= 0 && idx as usize >= n_tensors {
                    bail!("operator #{oi}: tensor index {idx} out of range ({n_tensors} tensors)");
                }
            }
            operators.push(Operator { opcode, version, inputs, outputs, options });
        }

        let n_gin = r.u8()? as usize;
        let mut graph_inputs = Vec::with_capacity(n_gin);
        for _ in 0..n_gin {
            let idx = r.i32()?;
            if idx < 0 || idx as usize >= n_tensors {
                bail!("graph input index {idx} out of range");
            }
            graph_inputs.push(idx as usize);
        }
        let n_gout = r.u8()? as usize;
        let mut graph_outputs = Vec::with_capacity(n_gout);
        for _ in 0..n_gout {
            let idx = r.i32()?;
            if idx < 0 || idx as usize >= n_tensors {
                bail!("graph output index {idx} out of range");
            }
            graph_outputs.push(idx as usize);
        }
        let metadata = r.string()?;

        Ok(MfbModel {
            version,
            producer,
            tensors,
            operators,
            graph_inputs,
            graph_outputs,
            metadata,
            file_bytes: buf.len(),
        })
    }

    /// Load from a file path.
    pub fn load(path: impl AsRef<std::path::Path>) -> Result<MfbModel> {
        let buf = std::fs::read(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::parse(&buf)
    }

    /// Sum of weight/bias payload bytes (the paper's model "Size").
    pub fn weights_bytes(&self) -> usize {
        self.tensors.iter().map(|t| t.data.len()).sum()
    }

    /// Bytes of *metadata* TFLM must keep in Flash but MicroFlow strips:
    /// names, options, versions, table structure — everything except the
    /// raw payloads.
    pub fn metadata_bytes(&self) -> usize {
        self.file_bytes - self.weights_bytes()
    }

    /// Per-sample input shape (graph input dims minus the batch dim).
    pub fn input_shape(&self) -> Vec<usize> {
        self.tensors[self.graph_inputs[0]].dims[1..].to_vec()
    }

    pub fn output_shape(&self) -> Vec<usize> {
        self.tensors[self.graph_outputs[0]].dims[1..].to_vec()
    }

    pub fn input_qparams(&self) -> QParams {
        self.tensors[self.graph_inputs[0]].qparams
    }

    pub fn output_qparams(&self) -> QParams {
        self.tensors[self.graph_outputs[0]].qparams
    }
}

fn parse_options(opcode: OpCode, raw: &[u8]) -> Result<OpOptions> {
    let mut r = Reader::new(raw);
    Ok(match opcode {
        OpCode::FullyConnected => OpOptions::FullyConnected { fused_act: r.u8()? },
        OpCode::Conv2D => OpOptions::Conv2D {
            stride: (r.u8()? as usize, r.u8()? as usize),
            padding: Padding::from_u8(r.u8()?)?,
            fused_act: r.u8()?,
        },
        OpCode::DepthwiseConv2D => {
            let stride = (r.u8()? as usize, r.u8()? as usize);
            let padding = Padding::from_u8(r.u8()?)?;
            let fused_act = r.u8()?;
            let depth_multiplier = r.u32()? as usize;
            OpOptions::DepthwiseConv2D { stride, padding, fused_act, depth_multiplier }
        }
        OpCode::AveragePool2D => OpOptions::AveragePool2D {
            filter: (r.u8()? as usize, r.u8()? as usize),
            stride: (r.u8()? as usize, r.u8()? as usize),
            padding: Padding::from_u8(r.u8()?)?,
            fused_act: r.u8()?,
        },
        OpCode::Reshape => {
            let ndims = r.u8()? as usize;
            let mut dims = Vec::with_capacity(ndims);
            for _ in 0..ndims {
                dims.push(r.u32()? as usize);
            }
            OpOptions::Reshape { dims }
        }
        OpCode::Softmax => OpOptions::Softmax { beta: r.f32()? },
        OpCode::Relu | OpCode::Relu6 => OpOptions::None,
    })
}

/// Test-only access to the private options parser (the writer's round-trip
/// tests exercise every `OpOptions` variant against it).
#[cfg(test)]
pub(crate) fn parse_options_for_test(opcode: OpCode, raw: &[u8]) -> Result<OpOptions> {
    parse_options(opcode, raw)
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;

    /// Hand-build a tiny valid MFB buffer (1 FC op) for parser tests.
    pub(crate) fn tiny_mfb() -> Vec<u8> {
        let mut b: Vec<u8> = Vec::new();
        let s = |b: &mut Vec<u8>, s: &str| {
            b.extend_from_slice(&(s.len() as u16).to_le_bytes());
            b.extend_from_slice(s.as_bytes());
        };
        b.extend_from_slice(b"MFB1");
        b.extend_from_slice(&1u32.to_le_bytes());
        s(&mut b, "test");
        b.extend_from_slice(&4u32.to_le_bytes()); // 4 tensors
        // t0: input act [1,2] i8
        s(&mut b, "in");
        b.push(0);
        b.push(2);
        b.extend_from_slice(&1u32.to_le_bytes());
        b.extend_from_slice(&2u32.to_le_bytes());
        b.extend_from_slice(&0.5f32.to_le_bytes());
        b.extend_from_slice(&(-1i32).to_le_bytes());
        b.extend_from_slice(&0u64.to_le_bytes());
        // t1: weights [2,3] i8 with data
        s(&mut b, "w");
        b.push(0);
        b.push(2);
        b.extend_from_slice(&2u32.to_le_bytes());
        b.extend_from_slice(&3u32.to_le_bytes());
        b.extend_from_slice(&0.25f32.to_le_bytes());
        b.extend_from_slice(&0i32.to_le_bytes());
        b.extend_from_slice(&6u64.to_le_bytes());
        b.extend_from_slice(&[1, 2, 3, 255, 254, 253]); // -1,-2,-3 as i8
        // t2: bias [3] i32
        s(&mut b, "b");
        b.push(1);
        b.push(1);
        b.extend_from_slice(&3u32.to_le_bytes());
        b.extend_from_slice(&0.125f32.to_le_bytes());
        b.extend_from_slice(&0i32.to_le_bytes());
        b.extend_from_slice(&12u64.to_le_bytes());
        for v in [10i32, -20, 30] {
            b.extend_from_slice(&v.to_le_bytes());
        }
        // t3: output act [1,3] i8
        s(&mut b, "out");
        b.push(0);
        b.push(2);
        b.extend_from_slice(&1u32.to_le_bytes());
        b.extend_from_slice(&3u32.to_le_bytes());
        b.extend_from_slice(&1.0f32.to_le_bytes());
        b.extend_from_slice(&0i32.to_le_bytes());
        b.extend_from_slice(&0u64.to_le_bytes());
        // 1 op: FC(in=0, w=1, b=2) -> 3, fused relu
        b.extend_from_slice(&1u32.to_le_bytes());
        b.push(0); // opcode FC
        b.extend_from_slice(&1u32.to_le_bytes()); // version
        b.push(3);
        for idx in [0i32, 1, 2] {
            b.extend_from_slice(&idx.to_le_bytes());
        }
        b.push(1);
        b.extend_from_slice(&3i32.to_le_bytes());
        b.extend_from_slice(&1u16.to_le_bytes());
        b.push(1); // fused_act = relu
        // graph io
        b.push(1);
        b.extend_from_slice(&0i32.to_le_bytes());
        b.push(1);
        b.extend_from_slice(&3i32.to_le_bytes());
        s(&mut b, "{}");
        b
    }

    #[test]
    fn parses_tiny_model() {
        let buf = tiny_mfb();
        let m = MfbModel::parse(&buf).unwrap();
        assert_eq!(m.producer, "test");
        assert_eq!(m.tensors.len(), 4);
        assert_eq!(m.operators.len(), 1);
        assert_eq!(m.operators[0].opcode, OpCode::FullyConnected);
        assert_eq!(m.operators[0].options, OpOptions::FullyConnected { fused_act: 1 });
        assert_eq!(m.tensors[1].data_i8().unwrap(), vec![1, 2, 3, -1, -2, -3]);
        assert_eq!(m.tensors[2].data_i32().unwrap(), vec![10, -20, 30]);
        assert_eq!(m.input_shape(), vec![2]);
        assert_eq!(m.output_shape(), vec![3]);
        assert_eq!(m.weights_bytes(), 18);
        assert_eq!(m.file_bytes, buf.len());
    }

    #[test]
    fn rejects_bad_magic() {
        let mut buf = tiny_mfb();
        buf[0] = b'X';
        assert!(MfbModel::parse(&buf).is_err());
    }

    #[test]
    fn rejects_truncation_everywhere() {
        let buf = tiny_mfb();
        // every strict prefix must fail cleanly, never panic
        for cut in 0..buf.len() {
            assert!(MfbModel::parse(&buf[..cut]).is_err(), "prefix {cut} parsed");
        }
    }

    #[test]
    fn rejects_out_of_range_tensor_index() {
        let buf = tiny_mfb();
        let m = MfbModel::parse(&buf).unwrap();
        assert_eq!(m.graph_outputs, vec![3]);
        // corrupt: find the graph-output index bytes (3i32 near the tail)
        let mut bad = buf.clone();
        let tail = bad.len() - 4 - 2; // before metadata str "{}"
        bad[tail - 4..tail].copy_from_slice(&99i32.to_le_bytes());
        assert!(MfbModel::parse(&bad).is_err());
    }

    #[test]
    fn wrong_payload_size_is_rejected() {
        let mut buf = tiny_mfb();
        // tensor t1 declares [2,3] i8 = 6 bytes; claim 5
        // find the 6u64 length field: it's right before the 6 data bytes
        let pos = buf.windows(8).position(|w| w == 6u64.to_le_bytes()).unwrap();
        buf[pos..pos + 8].copy_from_slice(&5u64.to_le_bytes());
        buf.remove(pos + 8); // drop one payload byte to keep framing
        assert!(MfbModel::parse(&buf).is_err());
    }
}
