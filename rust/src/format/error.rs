//! Typed, stable-coded decode errors (the `E4xx` family of the
//! certification error table — see [`crate::compiler::verify::ERROR_CODE_TABLE`]).
//!
//! The decode front door ([`super::reader`], [`super::mfb`]) is strict and
//! never panics on arbitrary bytes; every rejection carries one of these
//! codes so callers (and the mutation harness in `tests/mfb_fuzz.rs`) can
//! assert *which* contract was violated, not just that decoding failed.

use std::fmt;

/// Bad magic or unsupported container version.
pub const E_MAGIC: &str = "E401";
/// Truncated input: a read ran past the end of the buffer.
pub const E_TRUNCATED: &str = "E402";
/// Invalid UTF-8 in a string field.
pub const E_UTF8: &str = "E403";
/// Invalid count/length field (overflow or impossible for the buffer).
pub const E_COUNT: &str = "E404";
/// Tensor index out of range.
pub const E_INDEX: &str = "E405";
/// Trailing bytes after a complete structure.
pub const E_TRAILING: &str = "E406";
/// Unknown enum code (opcode / dtype / padding).
pub const E_ENUM: &str = "E407";
/// Tensor payload size disagrees with dims × dtype.
pub const E_PAYLOAD: &str = "E408";

/// A decode rejection with a stable `E4xx` code.
#[derive(Clone, Debug)]
pub struct DecodeError {
    pub code: &'static str,
    pub msg: String,
}

impl DecodeError {
    pub fn new(code: &'static str, msg: impl Into<String>) -> Self {
        DecodeError { code, msg: msg.into() }
    }

    /// Prefix the message with location context, keeping the code.
    pub fn wrap(self, prefix: impl fmt::Display) -> Self {
        DecodeError { code: self.code, msg: format!("{prefix}: {}", self.msg) }
    }
}

impl fmt::Display for DecodeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}: {}", self.code, self.msg)
    }
}

impl std::error::Error for DecodeError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_leads_with_the_code() {
        let e = DecodeError::new(E_TRUNCATED, "need 4 bytes");
        assert_eq!(e.to_string(), "E402: need 4 bytes");
        let wrapped = e.wrap("tensor #3");
        assert_eq!(wrapped.code, E_TRUNCATED);
        assert_eq!(wrapped.to_string(), "E402: tensor #3: need 4 bytes");
    }

    #[test]
    fn converts_into_anyhow() {
        fn inner() -> anyhow::Result<()> {
            Err(DecodeError::new(E_MAGIC, "nope"))?;
            Ok(())
        }
        let err = inner().unwrap_err();
        assert!(err.to_string().contains("E401"), "{err}");
    }
}
