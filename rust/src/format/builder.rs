//! MFB container writer — the exact inverse of [`super::mfb::MfbModel::parse`].
//!
//! Two consumers:
//!
//! * [`crate::api::ModelSource::Parsed`] — the interpreter parses the
//!   container itself (that runtime parsing *is* the TFLM cost being
//!   modeled), so an in-memory `MfbModel` handed to an interp session is
//!   serialized through here first;
//! * synthetic-model tests — the cross-engine conformance suite generates
//!   randomized FC/Conv chains in memory and feeds every engine the same
//!   bytes, with no build-time artifacts needed.
//!
//! Layout is documented in [`super::mfb`]; any change there must land here
//! in the same commit (guarded by the round-trip tests below).

use anyhow::{Context, Result};

use crate::format::mfb::{MfbModel, OpCode, OpOptions, Operator, Padding, TensorDef};
use crate::tensor::DType;

/// The writer refuses (rather than truncates) values that don't fit the
/// container's narrow fields — a truncated stride or wrapped string
/// length would desynchronize the whole byte stream on reparse.
fn narrow_u8(v: usize, what: &str) -> Result<u8> {
    u8::try_from(v).ok().with_context(|| format!("{what} {v} exceeds the container's u8 field"))
}

fn narrow_u16(v: usize, what: &str) -> Result<u16> {
    u16::try_from(v).ok().with_context(|| format!("{what} {v} exceeds the container's u16 field"))
}

fn narrow_u32(v: usize, what: &str) -> Result<u32> {
    u32::try_from(v).ok().with_context(|| format!("{what} {v} exceeds the container's u32 field"))
}

fn put_str(buf: &mut Vec<u8>, s: &str) -> Result<()> {
    buf.extend_from_slice(&narrow_u16(s.len(), "string length")?.to_le_bytes());
    buf.extend_from_slice(s.as_bytes());
    Ok(())
}

fn dtype_code(d: DType) -> u8 {
    match d {
        DType::I8 => 0,
        DType::I32 => 1,
        DType::F32 => 2,
    }
}

fn padding_code(p: Padding) -> u8 {
    match p {
        Padding::Same => 0,
        Padding::Valid => 1,
    }
}

fn put_tensor(buf: &mut Vec<u8>, t: &TensorDef) -> Result<()> {
    put_str(buf, &t.name)?;
    buf.push(dtype_code(t.dtype));
    buf.push(narrow_u8(t.dims.len(), "tensor rank")?);
    for &d in &t.dims {
        buf.extend_from_slice(&narrow_u32(d, "tensor dim")?.to_le_bytes());
    }
    buf.extend_from_slice(&t.qparams.scale.to_le_bytes());
    buf.extend_from_slice(&t.qparams.zero_point.to_le_bytes());
    buf.extend_from_slice(&(t.data.len() as u64).to_le_bytes());
    buf.extend(t.data.iter().map(|&v| v as u8));
    Ok(())
}

fn options_bytes(options: &OpOptions) -> Result<Vec<u8>> {
    let mut b = Vec::new();
    match options {
        OpOptions::FullyConnected { fused_act } => b.push(*fused_act),
        OpOptions::Conv2D { stride, padding, fused_act } => {
            b.push(narrow_u8(stride.0, "stride")?);
            b.push(narrow_u8(stride.1, "stride")?);
            b.push(padding_code(*padding));
            b.push(*fused_act);
        }
        OpOptions::DepthwiseConv2D { stride, padding, fused_act, depth_multiplier } => {
            b.push(narrow_u8(stride.0, "stride")?);
            b.push(narrow_u8(stride.1, "stride")?);
            b.push(padding_code(*padding));
            b.push(*fused_act);
            b.extend_from_slice(&narrow_u32(*depth_multiplier, "depth multiplier")?.to_le_bytes());
        }
        OpOptions::AveragePool2D { filter, stride, padding, fused_act } => {
            b.push(narrow_u8(filter.0, "pool filter")?);
            b.push(narrow_u8(filter.1, "pool filter")?);
            b.push(narrow_u8(stride.0, "stride")?);
            b.push(narrow_u8(stride.1, "stride")?);
            b.push(padding_code(*padding));
            b.push(*fused_act);
        }
        OpOptions::Reshape { dims } => {
            b.push(narrow_u8(dims.len(), "reshape rank")?);
            for &d in dims {
                b.extend_from_slice(&narrow_u32(d, "reshape dim")?.to_le_bytes());
            }
        }
        OpOptions::Softmax { beta } => b.extend_from_slice(&beta.to_le_bytes()),
        OpOptions::None => {}
    }
    Ok(b)
}

fn put_op(buf: &mut Vec<u8>, op: &Operator) -> Result<()> {
    buf.push(match op.opcode {
        OpCode::FullyConnected => 0,
        OpCode::Conv2D => 1,
        OpCode::DepthwiseConv2D => 2,
        OpCode::AveragePool2D => 3,
        OpCode::Reshape => 4,
        OpCode::Softmax => 5,
        OpCode::Relu => 6,
        OpCode::Relu6 => 7,
    });
    buf.extend_from_slice(&op.version.to_le_bytes());
    buf.push(narrow_u8(op.inputs.len(), "operator input count")?);
    for &idx in &op.inputs {
        buf.extend_from_slice(&idx.to_le_bytes());
    }
    buf.push(narrow_u8(op.outputs.len(), "operator output count")?);
    for &idx in &op.outputs {
        buf.extend_from_slice(&idx.to_le_bytes());
    }
    let opts = options_bytes(&op.options)?;
    buf.extend_from_slice(&narrow_u16(opts.len(), "options length")?.to_le_bytes());
    buf.extend_from_slice(&opts);
    Ok(())
}

/// Serialize a model to MFB container bytes (reparseable by
/// [`MfbModel::parse`]; `file_bytes` of the round-tripped model reflects
/// the new buffer, everything else is preserved). Errors if any field
/// exceeds its narrow container encoding.
pub fn serialize(model: &MfbModel) -> Result<Vec<u8>> {
    let mut buf = Vec::with_capacity(model.file_bytes.max(256));
    buf.extend_from_slice(b"MFB1");
    buf.extend_from_slice(&model.version.to_le_bytes());
    put_str(&mut buf, &model.producer)?;

    buf.extend_from_slice(&narrow_u32(model.tensors.len(), "tensor count")?.to_le_bytes());
    for t in &model.tensors {
        put_tensor(&mut buf, t)?;
    }

    buf.extend_from_slice(&narrow_u32(model.operators.len(), "operator count")?.to_le_bytes());
    for op in &model.operators {
        put_op(&mut buf, op)?;
    }

    buf.push(narrow_u8(model.graph_inputs.len(), "graph input count")?);
    for &idx in &model.graph_inputs {
        buf.extend_from_slice(&(idx as i32).to_le_bytes());
    }
    buf.push(narrow_u8(model.graph_outputs.len(), "graph output count")?);
    for &idx in &model.graph_outputs {
        buf.extend_from_slice(&(idx as i32).to_le_bytes());
    }
    put_str(&mut buf, &model.metadata)?;
    Ok(buf)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::format::mfb::tests::tiny_mfb;

    #[test]
    fn serializer_is_byte_identical_on_the_tiny_model() {
        // the writer inverts the parser exactly, not just semantically
        let bytes = tiny_mfb();
        let m = MfbModel::parse(&bytes).unwrap();
        assert_eq!(serialize(&m).unwrap(), bytes);
    }

    #[test]
    fn round_trip_preserves_semantics() {
        let m = MfbModel::parse(&tiny_mfb()).unwrap();
        let again = MfbModel::parse(&serialize(&m).unwrap()).unwrap();
        assert_eq!(again.producer, m.producer);
        assert_eq!(again.tensors.len(), m.tensors.len());
        assert_eq!(again.operators[0].options, m.operators[0].options);
        assert_eq!(again.graph_inputs, m.graph_inputs);
        assert_eq!(again.graph_outputs, m.graph_outputs);
        assert_eq!(again.tensors[1].data, m.tensors[1].data);
        assert_eq!(again.input_qparams(), m.input_qparams());
    }

    #[test]
    fn every_option_variant_round_trips() {
        for options in [
            OpOptions::FullyConnected { fused_act: 2 },
            OpOptions::Conv2D { stride: (2, 3), padding: Padding::Valid, fused_act: 1 },
            OpOptions::DepthwiseConv2D {
                stride: (1, 2),
                padding: Padding::Same,
                fused_act: 0,
                depth_multiplier: 4,
            },
            OpOptions::AveragePool2D {
                filter: (2, 2),
                stride: (2, 2),
                padding: Padding::Valid,
                fused_act: 0,
            },
            OpOptions::Reshape { dims: vec![1, 4, 4, 2] },
            OpOptions::Softmax { beta: 1.5 },
        ] {
            let opcode = match options {
                OpOptions::FullyConnected { .. } => OpCode::FullyConnected,
                OpOptions::Conv2D { .. } => OpCode::Conv2D,
                OpOptions::DepthwiseConv2D { .. } => OpCode::DepthwiseConv2D,
                OpOptions::AveragePool2D { .. } => OpCode::AveragePool2D,
                OpOptions::Reshape { .. } => OpCode::Reshape,
                OpOptions::Softmax { .. } => OpCode::Softmax,
                OpOptions::None => OpCode::Relu,
            };
            let raw = options_bytes(&options).unwrap();
            let parsed = crate::format::mfb::parse_options_for_test(opcode, &raw).unwrap();
            assert_eq!(parsed, options);
        }
    }

    #[test]
    fn out_of_range_fields_error_instead_of_truncating() {
        // a stride of 256 would wrap to 0 under a silent `as u8` cast and
        // desynchronize the stream; the writer must refuse it
        let mut m = MfbModel::parse(&tiny_mfb()).unwrap();
        m.operators[0].options = OpOptions::Conv2D {
            stride: (256, 1),
            padding: Padding::Valid,
            fused_act: 0,
        };
        let err = serialize(&m).unwrap_err();
        assert!(err.to_string().contains("u8"), "{err:#}");

        let mut m = MfbModel::parse(&tiny_mfb()).unwrap();
        m.metadata = "x".repeat(usize::from(u16::MAX) + 1);
        assert!(serialize(&m).is_err());
    }
}
