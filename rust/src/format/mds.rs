//! MDS dataset reader (DESIGN.md S17): evaluation datasets exported by
//! `python/compile/export_mfb.py::write_mds`.
//!
//! ```text
//! magic "MDS1" | u32 version=1 | str name
//! u8 ndims | u32* dims                 (per-sample feature shape)
//! u8 label_kind (0 regression, 1 class) | u32 label_dim
//! u32 n
//! f32* X   (n * prod(dims))
//! f32*|i32* Y (n * label_dim)
//! ```

use anyhow::{bail, Context, Result};

use super::reader::Reader;

/// Labels: float regression targets or integer class ids.
#[derive(Clone, Debug)]
pub enum Labels {
    Regression { dim: usize, values: Vec<f32> },
    Classes(Vec<i32>),
}

/// An evaluation dataset.
#[derive(Clone, Debug)]
pub struct MdsDataset {
    pub name: String,
    pub sample_shape: Vec<usize>,
    pub n: usize,
    /// Row-major features: `n * prod(sample_shape)` floats.
    pub x: Vec<f32>,
    pub labels: Labels,
}

impl MdsDataset {
    pub fn parse(buf: &[u8]) -> Result<MdsDataset> {
        let mut r = Reader::new(buf);
        r.magic(b"MDS1")?;
        let version = r.u32()?;
        if version != 1 {
            bail!("unsupported MDS version {version}");
        }
        let name = r.string()?;
        let ndims = r.u8()? as usize;
        let mut sample_shape = Vec::with_capacity(ndims);
        for _ in 0..ndims {
            sample_shape.push(r.u32()? as usize);
        }
        let label_kind = r.u8()?;
        let label_dim = r.u32()? as usize;
        let n = r.u32()? as usize;
        let sample_len: usize = sample_shape.iter().product();
        let x = r.f32_vec(n * sample_len)?;
        let labels = match label_kind {
            0 => Labels::Regression { dim: label_dim, values: r.f32_vec(n * label_dim)? },
            1 => Labels::Classes(r.i32_vec(n)?),
            other => bail!("unknown label kind {other}"),
        };
        Ok(MdsDataset { name, sample_shape, n, x, labels })
    }

    pub fn load(path: impl AsRef<std::path::Path>) -> Result<MdsDataset> {
        let buf = std::fs::read(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::parse(&buf)
    }

    /// Elements per sample.
    pub fn sample_len(&self) -> usize {
        self.sample_shape.iter().product()
    }

    /// Feature slice for sample `i`.
    pub fn sample(&self, i: usize) -> &[f32] {
        let len = self.sample_len();
        &self.x[i * len..(i + 1) * len]
    }

    /// Class label for sample `i` (classification datasets only).
    pub fn class(&self, i: usize) -> i32 {
        match &self.labels {
            Labels::Classes(c) => c[i],
            _ => panic!("not a classification dataset"),
        }
    }

    /// Regression target row for sample `i`.
    pub fn target(&self, i: usize) -> &[f32] {
        match &self.labels {
            Labels::Regression { dim, values } => &values[i * dim..(i + 1) * dim],
            _ => panic!("not a regression dataset"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn build(label_kind: u8) -> Vec<u8> {
        let mut b: Vec<u8> = Vec::new();
        b.extend_from_slice(b"MDS1");
        b.extend_from_slice(&1u32.to_le_bytes());
        b.extend_from_slice(&4u16.to_le_bytes());
        b.extend_from_slice(b"mini");
        b.push(1); // ndims
        b.extend_from_slice(&2u32.to_le_bytes()); // dim = 2
        b.push(label_kind);
        b.extend_from_slice(&1u32.to_le_bytes()); // label_dim
        b.extend_from_slice(&3u32.to_le_bytes()); // n
        for v in [0.0f32, 1.0, 2.0, 3.0, 4.0, 5.0] {
            b.extend_from_slice(&v.to_le_bytes());
        }
        if label_kind == 0 {
            for v in [0.5f32, 1.5, 2.5] {
                b.extend_from_slice(&v.to_le_bytes());
            }
        } else {
            for v in [0i32, 1, 0] {
                b.extend_from_slice(&v.to_le_bytes());
            }
        }
        b
    }

    #[test]
    fn parses_regression() {
        let ds = MdsDataset::parse(&build(0)).unwrap();
        assert_eq!(ds.name, "mini");
        assert_eq!(ds.n, 3);
        assert_eq!(ds.sample(1), &[2.0, 3.0]);
        assert_eq!(ds.target(2), &[2.5]);
    }

    #[test]
    fn parses_classification() {
        let ds = MdsDataset::parse(&build(1)).unwrap();
        assert_eq!(ds.class(0), 0);
        assert_eq!(ds.class(1), 1);
    }

    #[test]
    fn truncation_is_error() {
        let b = build(1);
        assert!(MdsDataset::parse(&b[..b.len() - 2]).is_err());
    }
}
