//! The Table-5 accuracy protocol (paper Sec. 6.2.1; DESIGN.md S20).
//!
//! * sine predictor — 1000 noisy samples; MSE/RMSE computed **against the
//!   actual sin(x) values**, exactly as the paper does;
//! * speech command recognizer — 1236 samples, macro-averaged P/R/F1 over
//!   the four classes;
//! * person detector — 406 samples, positive-class P/R/F1.
//!
//! Any engine implementing [`QuantPredictor`] can be evaluated: the native
//! MicroFlow engine, the TFLM-like interpreter, and the PJRT oracle all
//! plug in — the bench compares them side by side like the paper compares
//! MicroFlow to TFLM.

use anyhow::Result;

use super::metrics::{binary_prf, macro_prf, mse, rmse};
use crate::format::mds::{Labels, MdsDataset};
use crate::tensor::quant::QParams;

/// A quantized single-sample predictor (any engine).
pub trait QuantPredictor {
    fn input_qparams(&self) -> QParams;
    fn output_qparams(&self) -> QParams;
    fn predict_q(&mut self, input_q: &[i8]) -> Result<Vec<i8>>;

    /// Float-in / float-out convenience used by the evaluators.
    fn predict_f(&mut self, input: &[f32]) -> Result<Vec<f32>> {
        let q = self.input_qparams().quantize_slice(input);
        let out = self.predict_q(&q)?;
        let oq = self.output_qparams();
        Ok(out.iter().map(|&v| oq.dequantize(v)).collect())
    }
}

impl QuantPredictor for crate::api::Session {
    fn input_qparams(&self) -> QParams {
        crate::api::Session::input_qparams(self)
    }
    fn output_qparams(&self) -> QParams {
        crate::api::Session::output_qparams(self)
    }
    fn predict_q(&mut self, input_q: &[i8]) -> Result<Vec<i8>> {
        self.run(input_q)
    }
}

impl QuantPredictor for crate::engine::MicroFlowEngine {
    fn input_qparams(&self) -> QParams {
        crate::engine::MicroFlowEngine::input_qparams(self)
    }
    fn output_qparams(&self) -> QParams {
        crate::engine::MicroFlowEngine::output_qparams(self)
    }
    fn predict_q(&mut self, input_q: &[i8]) -> Result<Vec<i8>> {
        Ok(crate::engine::MicroFlowEngine::predict(self, input_q))
    }
}

impl QuantPredictor for crate::interp::Interpreter {
    fn input_qparams(&self) -> QParams {
        crate::interp::Interpreter::input_qparams(self)
    }
    fn output_qparams(&self) -> QParams {
        crate::interp::Interpreter::output_qparams(self)
    }
    fn predict_q(&mut self, input_q: &[i8]) -> Result<Vec<i8>> {
        self.invoke(input_q)
    }
}

/// Sine predictor scores (Table 5, left).
#[derive(Clone, Copy, Debug)]
pub struct SineScores {
    pub mse: f64,
    pub rmse: f64,
    pub n: usize,
}

/// Evaluate a sine predictor against the true function values.
pub fn evaluate_sine(pred: &mut dyn QuantPredictor, ds: &MdsDataset) -> Result<SineScores> {
    assert!(matches!(ds.labels, Labels::Regression { .. }), "sine dataset must be regression");
    let mut yhat = Vec::with_capacity(ds.n);
    let mut truth = Vec::with_capacity(ds.n);
    for i in 0..ds.n {
        let x = ds.sample(i);
        let y = pred.predict_f(x)?;
        yhat.push(y[0]);
        truth.push(x[0].sin()); // actual function value, not the noisy target
    }
    Ok(SineScores { mse: mse(&yhat, &truth), rmse: rmse(&yhat, &truth), n: ds.n })
}

/// Classifier scores (Table 5, middle/right).
#[derive(Clone, Copy, Debug)]
pub struct ClassifierScores {
    pub precision: f64,
    pub recall: f64,
    pub f1: f64,
    pub accuracy: f64,
    pub n: usize,
}

/// Evaluate a classifier; `macro_avg` selects the speech protocol
/// (macro-average over all classes) vs the person protocol (positive
/// class only).
pub fn evaluate_classifier(
    pred: &mut dyn QuantPredictor,
    ds: &MdsDataset,
    n_classes: usize,
    macro_avg: bool,
) -> Result<ClassifierScores> {
    let mut yhat = Vec::with_capacity(ds.n);
    let mut truth = Vec::with_capacity(ds.n);
    let mut hits = 0usize;
    for i in 0..ds.n {
        let q = pred.input_qparams().quantize_slice(ds.sample(i));
        let out = pred.predict_q(&q)?;
        let arg = argmax(&out);
        yhat.push(arg as i32);
        truth.push(ds.class(i));
        if arg as i32 == ds.class(i) {
            hits += 1;
        }
    }
    let (precision, recall, f1) = if macro_avg {
        macro_prf(&yhat, &truth, n_classes)
    } else {
        binary_prf(&yhat, &truth)
    };
    Ok(ClassifierScores { precision, recall, f1, accuracy: hits as f64 / ds.n as f64, n: ds.n })
}

/// Index of the maximum element (first wins ties — deterministic).
pub fn argmax(v: &[i8]) -> usize {
    let mut best = 0usize;
    for (i, &x) in v.iter().enumerate() {
        if x > v[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_first_wins_ties() {
        assert_eq!(argmax(&[1, 5, 5, 2]), 1);
        assert_eq!(argmax(&[-3]), 0);
    }

    struct Echo;
    impl QuantPredictor for Echo {
        fn input_qparams(&self) -> QParams {
            QParams::new(1.0, 0)
        }
        fn output_qparams(&self) -> QParams {
            QParams::new(1.0, 0)
        }
        fn predict_q(&mut self, input_q: &[i8]) -> Result<Vec<i8>> {
            Ok(input_q.to_vec())
        }
    }

    #[test]
    fn predict_f_roundtrips_qparams() {
        let mut e = Echo;
        let y = e.predict_f(&[3.0, -2.0]).unwrap();
        assert_eq!(y, vec![3.0, -2.0]);
    }
}
