//! Accuracy metrics (paper Sec. 6.2.1): MSE/RMSE for the sine predictor,
//! Precision/Recall/F1 for the classifiers. Multi-class metrics are
//! macro-averaged across classes, matching the paper's protocol for the
//! speech command recognizer ("averaged to provide an overall accuracy
//! across all of them").

/// Mean squared error between predictions and targets.
pub fn mse(pred: &[f32], target: &[f32]) -> f64 {
    assert_eq!(pred.len(), target.len());
    assert!(!pred.is_empty());
    pred.iter()
        .zip(target)
        .map(|(p, t)| {
            let d = (*p - *t) as f64;
            d * d
        })
        .sum::<f64>()
        / pred.len() as f64
}

/// Root mean squared error.
pub fn rmse(pred: &[f32], target: &[f32]) -> f64 {
    mse(pred, target).sqrt()
}

/// Per-class precision and recall for `n_classes` (one-vs-rest).
pub fn precision_recall(pred: &[i32], truth: &[i32], n_classes: usize) -> Vec<(f64, f64)> {
    assert_eq!(pred.len(), truth.len());
    let mut tp = vec![0usize; n_classes];
    let mut fp = vec![0usize; n_classes];
    let mut fnn = vec![0usize; n_classes];
    for (&p, &t) in pred.iter().zip(truth) {
        let (p, t) = (p as usize, t as usize);
        if p == t {
            tp[p] += 1;
        } else {
            fp[p] += 1;
            fnn[t] += 1;
        }
    }
    (0..n_classes)
        .map(|c| {
            let prec = if tp[c] + fp[c] > 0 { tp[c] as f64 / (tp[c] + fp[c]) as f64 } else { 0.0 };
            let rec = if tp[c] + fnn[c] > 0 { tp[c] as f64 / (tp[c] + fnn[c]) as f64 } else { 0.0 };
            (prec, rec)
        })
        .collect()
}

/// F1 from precision and recall.
pub fn f1_score(precision: f64, recall: f64) -> f64 {
    if precision + recall == 0.0 {
        0.0
    } else {
        2.0 * precision * recall / (precision + recall)
    }
}

/// Macro-averaged (precision, recall, F1).
pub fn macro_prf(pred: &[i32], truth: &[i32], n_classes: usize) -> (f64, f64, f64) {
    let pr = precision_recall(pred, truth, n_classes);
    let n = n_classes as f64;
    let p = pr.iter().map(|x| x.0).sum::<f64>() / n;
    let r = pr.iter().map(|x| x.1).sum::<f64>() / n;
    (p, r, f1_score(p, r))
}

/// Binary-task (positive class = 1) precision/recall/F1 — the person
/// detector protocol.
pub fn binary_prf(pred: &[i32], truth: &[i32]) -> (f64, f64, f64) {
    let pr = precision_recall(pred, truth, 2);
    let (p, r) = pr[1];
    (p, r, f1_score(p, r))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mse_of_identical_is_zero() {
        assert_eq!(mse(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
    }

    #[test]
    fn mse_hand_value() {
        // errors: 1, -2 -> (1+4)/2 = 2.5
        assert!((mse(&[2.0, 0.0], &[1.0, 2.0]) - 2.5).abs() < 1e-12);
        assert!((rmse(&[2.0, 0.0], &[1.0, 2.0]) - 2.5f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn perfect_classifier_prf() {
        let y = [0, 1, 2, 1, 0];
        let (p, r, f1) = macro_prf(&y, &y, 3);
        assert_eq!((p, r, f1), (1.0, 1.0, 1.0));
    }

    #[test]
    fn binary_prf_hand_example() {
        // truth:  1 1 1 0 0
        // pred:   1 0 1 1 0  -> tp=2 fp=1 fn=1 => P=2/3, R=2/3
        let truth = [1, 1, 1, 0, 0];
        let pred = [1, 0, 1, 1, 0];
        let (p, r, f1) = binary_prf(&pred, &truth);
        assert!((p - 2.0 / 3.0).abs() < 1e-12);
        assert!((r - 2.0 / 3.0).abs() < 1e-12);
        assert!((f1 - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn absent_class_gets_zero_precision() {
        let truth = [0, 0, 1];
        let pred = [0, 0, 0]; // class 1 never predicted
        let pr = precision_recall(&pred, &truth, 2);
        assert_eq!(pr[1], (0.0, 0.0));
    }
}
