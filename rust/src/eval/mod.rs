//! Evaluation: datasets, metrics and the Table-5 accuracy runner
//! (DESIGN.md S17, S20).

pub mod accuracy;
pub mod metrics;

pub use accuracy::{evaluate_classifier, evaluate_sine, ClassifierScores, SineScores};
pub use metrics::{f1_score, mse, precision_recall, rmse};
