//! Synthetic in-memory model generators — seeded, deterministic, and
//! artifact-free.
//!
//! The cross-engine conformance suite, the coordinator stress suite and
//! the fleet throughput bench all need real models without `make
//! artifacts`: models are constructed here as [`MfbModel`] values,
//! serialized through `format::builder`, and fed to every engine through
//! `Session::builder` — the same bytes everywhere.
//!
//! The generators bound each layer's error gain (see [`GAIN`]) so the
//! paper's Sec. 6.2.1 ±1-unit agreement between the float-scale and
//! fixed-point requantization paths survives multi-layer chains, which is
//! what lets the suites assert exact/±1 parity on randomized models.

use crate::format::mfb::{MfbModel, OpCode, OpOptions, Operator, Padding, TensorDef};
use crate::kernels::out_dims;
use crate::tensor::quant::QParams;
use crate::tensor::DType;
use crate::util::Prng;

/// Activation tensor (no payload; materialized by the engines).
pub fn act_tensor(name: &str, dims: Vec<usize>, scale: f32, zp: i32) -> TensorDef {
    TensorDef {
        name: name.into(),
        dtype: DType::I8,
        dims,
        qparams: QParams::new(scale, zp),
        data: Vec::new(),
    }
}

/// Weight tensor with int8 payload.
pub fn i8_tensor(name: &str, dims: Vec<usize>, scale: f32, data: Vec<i8>) -> TensorDef {
    TensorDef {
        name: name.into(),
        dtype: DType::I8,
        dims,
        qparams: QParams::new(scale, 0),
        data,
    }
}

/// Bias tensor with int32 payload.
pub fn i32_tensor(name: &str, dims: Vec<usize>, scale: f32, data: Vec<i32>) -> TensorDef {
    TensorDef {
        name: name.into(),
        dtype: DType::I32,
        dims,
        qparams: QParams::new(scale, 0),
        data: data.iter().flat_map(|v| v.to_le_bytes()).map(|b| b as i8).collect(),
    }
}

/// Assemble a single-input single-output model around a tensor table.
pub fn model(tensors: Vec<TensorDef>, operators: Vec<Operator>, out_idx: usize) -> MfbModel {
    MfbModel {
        version: 1,
        producer: "synth".into(),
        tensors,
        operators,
        graph_inputs: vec![0],
        graph_outputs: vec![out_idx],
        metadata: "{}".into(),
        file_bytes: 0, // refreshed when the serialized bytes are reparsed
    }
}

/// Weight magnitude cap: together with [`GAIN`] it bounds each layer's
/// error amplification.
pub const W_MAX: i64 = 8;
/// Per-layer error gain cap: a ±1 input disagreement perturbs the
/// pre-rounding output by at most 0.1 units, so engine outputs stay within
/// ±1 at EVERY layer of a chain (gain * 1 + rounding < 2 ⇒ diff ≤ 1).
pub const GAIN: f32 = 0.1;

fn small_weights(rng: &mut Prng, n: usize) -> Vec<i8> {
    (0..n).map(|_| rng.range_i64(-W_MAX, W_MAX) as i8).collect()
}

/// FC chain with the given layer widths: input `[1, widths[0]]`, then one
/// FullyConnected per remaining width (fused relu on random layers).
/// Weights/biases/qparams are drawn from `rng` under the error-gain bound.
pub fn fc_chain(rng: &mut Prng, widths: &[usize]) -> MfbModel {
    assert!(widths.len() >= 2, "need an input width and at least one layer");
    let k0 = widths[0];
    let mut tensors =
        vec![act_tensor("in", vec![1, k0], rng.f32_range(0.02, 0.1), rng.range_i64(-5, 5) as i32)];
    let mut operators = Vec::new();
    let mut k = k0;
    let mut cur = 0usize;
    for (layer, &n) in widths[1..].iter().enumerate() {
        let s_x = tensors[cur].qparams.scale;
        let s_w = rng.f32_range(0.01, 0.05);
        // max per-unit sensitivity is W_MAX * k weights: pick s_y for GAIN
        let s_y = s_x * s_w * (W_MAX as f32) * (k as f32) / GAIN;
        let z_y = rng.range_i64(-10, 10) as i32;
        let w_idx = tensors.len();
        tensors.push(i8_tensor(&format!("w{layer}"), vec![k, n], s_w, small_weights(rng, k * n)));
        let b_idx = tensors.len();
        let bias = rng.i32_vec(n, -100, 100);
        tensors.push(i32_tensor(&format!("b{layer}"), vec![n], s_x * s_w, bias));
        let y_idx = tensors.len();
        tensors.push(act_tensor(&format!("y{layer}"), vec![1, n], s_y, z_y));
        operators.push(Operator {
            opcode: OpCode::FullyConnected,
            version: 1,
            inputs: vec![cur as i32, w_idx as i32, b_idx as i32],
            outputs: vec![y_idx as i32],
            options: OpOptions::FullyConnected { fused_act: (rng.below(2)) as u8 },
        });
        cur = y_idx;
        k = n;
    }
    model(tensors, operators, cur)
}

/// Randomized FC chain: input `[1, k0]` → FC × depth, each with random
/// dims, weights, bias and a fused relu on some layers.
pub fn random_fc_chain(rng: &mut Prng, depth: usize) -> MfbModel {
    let mut widths = vec![rng.range_i64(2, 16) as usize];
    for _ in 0..depth {
        widths.push(rng.range_i64(1, 12) as usize);
    }
    fc_chain(rng, &widths)
}

/// Randomized single Conv2D model (SAME or VALID, stride 1 or 2).
pub fn random_conv(rng: &mut Prng) -> MfbModel {
    let (h, w) = (rng.range_i64(3, 8) as usize, rng.range_i64(3, 8) as usize);
    let c = rng.range_i64(1, 3) as usize;
    let (kh, kw) = (rng.range_i64(1, h as i64) as usize, rng.range_i64(1, w as i64) as usize);
    let stride = rng.range_i64(1, 2) as usize;
    let padding = if rng.below(2) == 0 { Padding::Same } else { Padding::Valid };
    let c_out = rng.range_i64(1, 4) as usize;
    let (oh, ow) = out_dims(h, w, kh, kw, stride, stride, padding).unwrap();

    let s_x = rng.f32_range(0.02, 0.1);
    let z_x = rng.range_i64(-5, 5) as i32;
    let s_f = rng.f32_range(0.01, 0.05);
    let window = kh * kw * c;
    let s_y = s_x * s_f * (W_MAX as f32) * (window as f32) / GAIN;
    let z_y = rng.range_i64(-10, 10) as i32;

    let tensors = vec![
        act_tensor("in", vec![1, h, w, c], s_x, z_x),
        i8_tensor("f", vec![c_out, kh, kw, c], s_f, small_weights(rng, c_out * window)),
        i32_tensor("b", vec![c_out], s_x * s_f, rng.i32_vec(c_out, -100, 100)),
        act_tensor("y", vec![1, oh, ow, c_out], s_y, z_y),
    ];
    let operators = vec![Operator {
        opcode: OpCode::Conv2D,
        version: 1,
        inputs: vec![0, 1, 2],
        outputs: vec![3],
        options: OpOptions::Conv2D {
            stride: (stride, stride),
            padding,
            fused_act: (rng.below(2)) as u8,
        },
    }];
    model(tensors, operators, 3)
}

/// The seeded synthetic model zoo: a labelled sample of everything the
/// generators produce (FC chains of several depths plus conv models).
/// `microflow audit --synth-zoo` certifies every member, and CI runs that
/// over the default seed so an uncertifiable plan fails the build.
pub fn zoo(seed: u64) -> Vec<(String, MfbModel)> {
    let mut rng = Prng::new(seed);
    let mut out = Vec::new();
    for depth in [1usize, 2, 4] {
        out.push((format!("fc-depth{depth}"), random_fc_chain(&mut rng, depth)));
    }
    out.push(("fc-wide".to_string(), fc_chain(&mut rng, &[64, 128, 10])));
    for i in 0..4 {
        out.push((format!("conv{i}"), random_conv(&mut rng)));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{Engine, Session};

    #[test]
    fn generated_chains_compile_and_run_on_every_host_engine() {
        let mut rng = Prng::new(7);
        let m = random_fc_chain(&mut rng, 3);
        for engine in [Engine::MicroFlow, Engine::Interp] {
            let mut s = Session::builder(&m).engine(engine).build().unwrap();
            let x = rng.i8_vec(s.input_len());
            assert_eq!(s.run(&x).unwrap().len(), s.output_len());
        }
    }

    #[test]
    fn fc_chain_honors_requested_widths() {
        let mut rng = Prng::new(1);
        let m = fc_chain(&mut rng, &[16, 32, 4]);
        assert_eq!(m.input_shape(), vec![16]);
        assert_eq!(m.output_shape(), vec![4]);
        assert_eq!(m.operators.len(), 2);
    }

    #[test]
    fn zoo_members_round_trip_and_certify() {
        for (name, m) in zoo(20260731) {
            let bytes = crate::format::builder::serialize(&m).unwrap();
            let parsed = MfbModel::parse(&bytes).unwrap_or_else(|e| panic!("{name}: {e}"));
            let c = crate::compiler::CompiledModel::compile(&parsed, Default::default())
                .unwrap_or_else(|e| panic!("{name}: {e:#}"));
            assert!(c.certificate.is_some(), "{name} missing certificate");
        }
    }

    #[test]
    fn generation_is_deterministic_per_seed() {
        let a = random_fc_chain(&mut Prng::new(42), 2);
        let b = random_fc_chain(&mut Prng::new(42), 2);
        assert_eq!(
            crate::format::builder::serialize(&a).unwrap(),
            crate::format::builder::serialize(&b).unwrap()
        );
    }
}
