//! Synthetic in-memory model generators — seeded, deterministic, and
//! artifact-free.
//!
//! The cross-engine conformance suite, the coordinator stress suite and
//! the fleet throughput bench all need real models without `make
//! artifacts`: models are constructed here as [`MfbModel`] values,
//! serialized through `format::builder`, and fed to every engine through
//! `Session::builder` — the same bytes everywhere.
//!
//! The generators bound each layer's error gain (see [`GAIN`]) so the
//! paper's Sec. 6.2.1 ±1-unit agreement between the float-scale and
//! fixed-point requantization paths survives multi-layer chains, which is
//! what lets the suites assert exact/±1 parity on randomized models.

use crate::format::mfb::{MfbModel, OpCode, OpOptions, Operator, Padding, TensorDef};
use crate::kernels::out_dims;
use crate::tensor::quant::QParams;
use crate::tensor::DType;
use crate::util::Prng;

/// Activation tensor (no payload; materialized by the engines).
pub fn act_tensor(name: &str, dims: Vec<usize>, scale: f32, zp: i32) -> TensorDef {
    TensorDef {
        name: name.into(),
        dtype: DType::I8,
        dims,
        qparams: QParams::new(scale, zp),
        data: Vec::new(),
    }
}

/// Weight tensor with int8 payload.
pub fn i8_tensor(name: &str, dims: Vec<usize>, scale: f32, data: Vec<i8>) -> TensorDef {
    TensorDef {
        name: name.into(),
        dtype: DType::I8,
        dims,
        qparams: QParams::new(scale, 0),
        data,
    }
}

/// Bias tensor with int32 payload.
pub fn i32_tensor(name: &str, dims: Vec<usize>, scale: f32, data: Vec<i32>) -> TensorDef {
    TensorDef {
        name: name.into(),
        dtype: DType::I32,
        dims,
        qparams: QParams::new(scale, 0),
        data: data.iter().flat_map(|v| v.to_le_bytes()).map(|b| b as i8).collect(),
    }
}

/// Assemble a single-input single-output model around a tensor table.
pub fn model(tensors: Vec<TensorDef>, operators: Vec<Operator>, out_idx: usize) -> MfbModel {
    MfbModel {
        version: 1,
        producer: "synth".into(),
        tensors,
        operators,
        graph_inputs: vec![0],
        graph_outputs: vec![out_idx],
        metadata: "{}".into(),
        file_bytes: 0, // refreshed when the serialized bytes are reparsed
    }
}

/// Weight magnitude cap: together with [`GAIN`] it bounds each layer's
/// error amplification.
pub const W_MAX: i64 = 8;
/// Per-layer error gain cap: a ±1 input disagreement perturbs the
/// pre-rounding output by at most 0.1 units, so engine outputs stay within
/// ±1 at EVERY layer of a chain (gain * 1 + rounding < 2 ⇒ diff ≤ 1).
pub const GAIN: f32 = 0.1;

fn small_weights(rng: &mut Prng, n: usize) -> Vec<i8> {
    (0..n).map(|_| rng.range_i64(-W_MAX, W_MAX) as i8).collect()
}

/// FC chain with the given layer widths: input `[1, widths[0]]`, then one
/// FullyConnected per remaining width (fused relu on random layers).
/// Weights/biases/qparams are drawn from `rng` under the error-gain bound.
pub fn fc_chain(rng: &mut Prng, widths: &[usize]) -> MfbModel {
    assert!(widths.len() >= 2, "need an input width and at least one layer");
    let k0 = widths[0];
    let mut tensors =
        vec![act_tensor("in", vec![1, k0], rng.f32_range(0.02, 0.1), rng.range_i64(-5, 5) as i32)];
    let mut operators = Vec::new();
    let mut k = k0;
    let mut cur = 0usize;
    for (layer, &n) in widths[1..].iter().enumerate() {
        let s_x = tensors[cur].qparams.scale;
        let s_w = rng.f32_range(0.01, 0.05);
        // max per-unit sensitivity is W_MAX * k weights: pick s_y for GAIN
        let s_y = s_x * s_w * (W_MAX as f32) * (k as f32) / GAIN;
        let z_y = rng.range_i64(-10, 10) as i32;
        let w_idx = tensors.len();
        tensors.push(i8_tensor(&format!("w{layer}"), vec![k, n], s_w, small_weights(rng, k * n)));
        let b_idx = tensors.len();
        let bias = rng.i32_vec(n, -100, 100);
        tensors.push(i32_tensor(&format!("b{layer}"), vec![n], s_x * s_w, bias));
        let y_idx = tensors.len();
        tensors.push(act_tensor(&format!("y{layer}"), vec![1, n], s_y, z_y));
        operators.push(Operator {
            opcode: OpCode::FullyConnected,
            version: 1,
            inputs: vec![cur as i32, w_idx as i32, b_idx as i32],
            outputs: vec![y_idx as i32],
            options: OpOptions::FullyConnected { fused_act: (rng.below(2)) as u8 },
        });
        cur = y_idx;
        k = n;
    }
    model(tensors, operators, cur)
}

/// Randomized FC chain: input `[1, k0]` → FC × depth, each with random
/// dims, weights, bias and a fused relu on some layers.
pub fn random_fc_chain(rng: &mut Prng, depth: usize) -> MfbModel {
    let mut widths = vec![rng.range_i64(2, 16) as usize];
    for _ in 0..depth {
        widths.push(rng.range_i64(1, 12) as usize);
    }
    fc_chain(rng, &widths)
}

/// Randomized single Conv2D model (SAME or VALID, stride 1 or 2).
pub fn random_conv(rng: &mut Prng) -> MfbModel {
    let (h, w) = (rng.range_i64(3, 8) as usize, rng.range_i64(3, 8) as usize);
    let c = rng.range_i64(1, 3) as usize;
    let (kh, kw) = (rng.range_i64(1, h as i64) as usize, rng.range_i64(1, w as i64) as usize);
    let stride = rng.range_i64(1, 2) as usize;
    let padding = if rng.below(2) == 0 { Padding::Same } else { Padding::Valid };
    let c_out = rng.range_i64(1, 4) as usize;
    let (oh, ow) = out_dims(h, w, kh, kw, stride, stride, padding).unwrap();

    let s_x = rng.f32_range(0.02, 0.1);
    let z_x = rng.range_i64(-5, 5) as i32;
    let s_f = rng.f32_range(0.01, 0.05);
    let window = kh * kw * c;
    let s_y = s_x * s_f * (W_MAX as f32) * (window as f32) / GAIN;
    let z_y = rng.range_i64(-10, 10) as i32;

    let tensors = vec![
        act_tensor("in", vec![1, h, w, c], s_x, z_x),
        i8_tensor("f", vec![c_out, kh, kw, c], s_f, small_weights(rng, c_out * window)),
        i32_tensor("b", vec![c_out], s_x * s_f, rng.i32_vec(c_out, -100, 100)),
        act_tensor("y", vec![1, oh, ow, c_out], s_y, z_y),
    ];
    let operators = vec![Operator {
        opcode: OpCode::Conv2D,
        version: 1,
        inputs: vec![0, 1, 2],
        outputs: vec![3],
        options: OpOptions::Conv2D {
            stride: (stride, stride),
            padding,
            fused_act: (rng.below(2)) as u8,
        },
    }];
    model(tensors, operators, 3)
}

/// Append one VALID-padded Conv2D under the error-gain bound; returns the
/// new activation tensor index. VALID + stride `(sh, 1)` keeps the layer
/// pulse-streamable (no top pad, no bottom overhang), which is what the
/// streaming generators below rely on.
fn push_valid_conv(
    tensors: &mut Vec<TensorDef>,
    operators: &mut Vec<Operator>,
    rng: &mut Prng,
    cur: usize,
    name: &str,
    kh: usize,
    kw: usize,
    sh: usize,
    c_out: usize,
) -> usize {
    let [_, h, w, c] = tensors[cur].dims[..] else { panic!("conv input must be [1,H,W,C]") };
    let (oh, ow) = out_dims(h, w, kh, kw, sh, 1, Padding::Valid).unwrap();
    let s_x = tensors[cur].qparams.scale;
    let s_f = rng.f32_range(0.01, 0.05);
    let window = kh * kw * c;
    let s_y = s_x * s_f * (W_MAX as f32) * (window as f32) / GAIN;
    let z_y = rng.range_i64(-10, 10) as i32;
    let f_idx = tensors.len();
    tensors.push(i8_tensor(
        &format!("{name}.f"),
        vec![c_out, kh, kw, c],
        s_f,
        small_weights(rng, c_out * window),
    ));
    let b_idx = tensors.len();
    tensors.push(i32_tensor(&format!("{name}.b"), vec![c_out], s_x * s_f, rng.i32_vec(c_out, -100, 100)));
    let y_idx = tensors.len();
    tensors.push(act_tensor(&format!("{name}.y"), vec![1, oh, ow, c_out], s_y, z_y));
    operators.push(Operator {
        opcode: OpCode::Conv2D,
        version: 1,
        inputs: vec![cur as i32, f_idx as i32, b_idx as i32],
        outputs: vec![y_idx as i32],
        options: OpOptions::Conv2D {
            stride: (sh, 1),
            padding: Padding::Valid,
            fused_act: (rng.below(2)) as u8,
        },
    });
    y_idx
}

/// Append a FullyConnected head flattening the current activation to `n`
/// logits.
fn push_fc_head(
    tensors: &mut Vec<TensorDef>,
    operators: &mut Vec<Operator>,
    rng: &mut Prng,
    cur: usize,
    n: usize,
) -> usize {
    let k: usize = tensors[cur].dims[1..].iter().product();
    let s_x = tensors[cur].qparams.scale;
    let s_w = rng.f32_range(0.01, 0.05);
    let s_y = s_x * s_w * (W_MAX as f32) * (k as f32) / GAIN;
    let w_idx = tensors.len();
    tensors.push(i8_tensor("head.w", vec![k, n], s_w, small_weights(rng, k * n)));
    let b_idx = tensors.len();
    tensors.push(i32_tensor("head.b", vec![n], s_x * s_w, rng.i32_vec(n, -100, 100)));
    let y_idx = tensors.len();
    tensors.push(act_tensor("head.y", vec![1, n], s_y, rng.range_i64(-10, 10) as i32));
    operators.push(Operator {
        opcode: OpCode::FullyConnected,
        version: 1,
        inputs: vec![cur as i32, w_idx as i32, b_idx as i32],
        outputs: vec![y_idx as i32],
        options: OpOptions::FullyConnected { fused_act: 0 },
    });
    y_idx
}

/// Append a standalone Relu (scale-preserving, so it never amplifies the
/// ±1 agreement bound).
fn push_relu(tensors: &mut Vec<TensorDef>, operators: &mut Vec<Operator>, cur: usize, name: &str) -> usize {
    let dims = tensors[cur].dims.clone();
    let qp = tensors[cur].qparams;
    let y_idx = tensors.len();
    tensors.push(act_tensor(name, dims, qp.scale, qp.zero_point));
    operators.push(Operator {
        opcode: OpCode::Relu,
        version: 1,
        inputs: vec![cur as i32],
        outputs: vec![y_idx as i32],
        options: OpOptions::None,
    });
    y_idx
}

/// Streamable conv chain: `[1,H,W,C]` input, `depth` VALID Conv2D layers
/// (occasionally stride 2 along H, sometimes with a standalone Relu in
/// between), then a FullyConnected head. Every spatial layer is pad-free
/// in H, so the whole conv prefix pulses — these are the streaming
/// subsystem's conformance workhorses.
pub fn stream_conv_chain(rng: &mut Prng, depth: usize) -> MfbModel {
    let h = 12 + rng.below(8) as usize;
    let w = rng.range_i64(3, 5) as usize;
    let c = rng.range_i64(1, 2) as usize;
    let mut tensors =
        vec![act_tensor("in", vec![1, h, w, c], rng.f32_range(0.02, 0.1), rng.range_i64(-5, 5) as i32)];
    let mut operators = Vec::new();
    let mut cur = 0usize;
    for layer in 0..depth {
        let [_, ch, cw, _] = tensors[cur].dims[..] else { unreachable!() };
        let kh = 2 + rng.below(2) as usize;
        let kw = rng.range_i64(1, cw as i64) as usize;
        // stride 2 only while the map stays tall enough for deeper layers
        let sh = if (ch - kh) / 2 + 1 >= 4 && rng.below(2) == 0 { 2 } else { 1 };
        let c_out = rng.range_i64(1, 3) as usize;
        cur = push_valid_conv(&mut tensors, &mut operators, rng, cur, &format!("c{layer}"), kh, kw, sh, c_out);
        if rng.below(3) == 0 {
            cur = push_relu(&mut tensors, &mut operators, cur, &format!("r{layer}"));
        }
    }
    let classes = rng.range_i64(3, 6) as usize;
    cur = push_fc_head(&mut tensors, &mut operators, rng, cur, classes);
    model(tensors, operators, cur)
}

/// Mixed streamable chain: Conv2D → Relu → DepthwiseConv2D → AveragePool2D
/// → FC head, all VALID / pad-free in H (depthwise and pooling both carry
/// pulse state).
pub fn stream_mixed(rng: &mut Prng) -> MfbModel {
    let (h, w) = (14 + rng.below(4) as usize, rng.range_i64(3, 4) as usize);
    let c = rng.range_i64(1, 2) as usize;
    let mut tensors =
        vec![act_tensor("in", vec![1, h, w, c], rng.f32_range(0.02, 0.1), rng.range_i64(-5, 5) as i32)];
    let mut operators = Vec::new();
    let mut cur = push_valid_conv(&mut tensors, &mut operators, rng, 0, "c0", 3, 2, 1, 2);
    cur = push_relu(&mut tensors, &mut operators, cur, "r0");

    // depthwise: [1,KH,KW,Cout] filters, mult 1, VALID, stride 1
    let [_, dh, dw, dc] = tensors[cur].dims[..] else { unreachable!() };
    let (kh, kw) = (2usize, 2.min(dw));
    let (oh, ow) = out_dims(dh, dw, kh, kw, 1, 1, Padding::Valid).unwrap();
    let s_x = tensors[cur].qparams.scale;
    let s_f = rng.f32_range(0.01, 0.05);
    let s_y = s_x * s_f * (W_MAX as f32) * ((kh * kw) as f32) / GAIN;
    let f_idx = tensors.len();
    tensors.push(i8_tensor("dw.f", vec![1, kh, kw, dc], s_f, small_weights(rng, kh * kw * dc)));
    let b_idx = tensors.len();
    tensors.push(i32_tensor("dw.b", vec![dc], s_x * s_f, rng.i32_vec(dc, -100, 100)));
    let y_idx = tensors.len();
    tensors.push(act_tensor("dw.y", vec![1, oh, ow, dc], s_y, rng.range_i64(-10, 10) as i32));
    operators.push(Operator {
        opcode: OpCode::DepthwiseConv2D,
        version: 1,
        inputs: vec![cur as i32, f_idx as i32, b_idx as i32],
        outputs: vec![y_idx as i32],
        options: OpOptions::DepthwiseConv2D {
            stride: (1, 1),
            padding: Padding::Valid,
            fused_act: 0,
            depth_multiplier: 1,
        },
    });
    cur = y_idx;

    // average pool: VALID 2x1 window, stride (2,1) — scale-preserving
    let [_, ph, pw, pc] = tensors[cur].dims[..] else { unreachable!() };
    let (poh, pow_) = out_dims(ph, pw, 2, 1, 2, 1, Padding::Valid).unwrap();
    let qp = tensors[cur].qparams;
    let y_idx = tensors.len();
    tensors.push(act_tensor("pool.y", vec![1, poh, pow_, pc], qp.scale, qp.zero_point));
    operators.push(Operator {
        opcode: OpCode::AveragePool2D,
        version: 1,
        inputs: vec![cur as i32],
        outputs: vec![y_idx as i32],
        options: OpOptions::AveragePool2D {
            filter: (2, 1),
            stride: (2, 1),
            padding: Padding::Valid,
            fused_act: 0,
        },
    });
    cur = y_idx;

    cur = push_fc_head(&mut tensors, &mut operators, rng, cur, rng.range_i64(3, 5) as usize);
    model(tensors, operators, cur)
}

/// Degenerate-by-design: one VALID conv whose kernel spans the whole
/// window (`k_h == H`), so a pulse recomputes everything — the planner
/// must reject it with `V405` (no strict savings).
pub fn stream_full_height_conv(rng: &mut Prng) -> MfbModel {
    let (h, w, c) = (8usize, 3usize, 1usize);
    let mut tensors =
        vec![act_tensor("in", vec![1, h, w, c], rng.f32_range(0.02, 0.1), rng.range_i64(-5, 5) as i32)];
    let mut operators = Vec::new();
    let cur = push_valid_conv(&mut tensors, &mut operators, rng, 0, "c0", h, 2, 1, 2);
    model(tensors, operators, cur)
}

/// The seeded streaming model zoo: every member has a certifiable pulse
/// plan. The streaming conformance suite and `benches/stream_latency.rs`
/// both iterate this set.
pub fn stream_zoo(seed: u64) -> Vec<(String, MfbModel)> {
    let mut rng = Prng::new(seed);
    let mut out = Vec::new();
    for depth in [1usize, 2, 3] {
        out.push((format!("stream-conv-d{depth}"), stream_conv_chain(&mut rng, depth)));
    }
    out.push(("stream-mixed".to_string(), stream_mixed(&mut rng)));
    // guaranteed stride-2 member (pulse_frames > 1): k3 s2 conv, then k2 s1
    let mut tensors =
        vec![act_tensor("in", vec![1, 16, 3, 1], 0.05, rng.range_i64(-5, 5) as i32)];
    let mut operators = Vec::new();
    let mut cur = push_valid_conv(&mut tensors, &mut operators, &mut rng, 0, "c0", 3, 2, 2, 2);
    cur = push_valid_conv(&mut tensors, &mut operators, &mut rng, cur, "c1", 2, 2, 1, 2);
    cur = push_fc_head(&mut tensors, &mut operators, &mut rng, cur, 4);
    out.push(("stream-stride2".to_string(), model(tensors, operators, cur)));
    out
}

/// The seeded synthetic model zoo: a labelled sample of everything the
/// generators produce (FC chains of several depths plus conv models).
/// `microflow audit --synth-zoo` certifies every member, and CI runs that
/// over the default seed so an uncertifiable plan fails the build.
pub fn zoo(seed: u64) -> Vec<(String, MfbModel)> {
    let mut rng = Prng::new(seed);
    let mut out = Vec::new();
    for depth in [1usize, 2, 4] {
        out.push((format!("fc-depth{depth}"), random_fc_chain(&mut rng, depth)));
    }
    out.push(("fc-wide".to_string(), fc_chain(&mut rng, &[64, 128, 10])));
    for i in 0..4 {
        out.push((format!("conv{i}"), random_conv(&mut rng)));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{Engine, Session};

    #[test]
    fn generated_chains_compile_and_run_on_every_host_engine() {
        let mut rng = Prng::new(7);
        let m = random_fc_chain(&mut rng, 3);
        for engine in [Engine::MicroFlow, Engine::Interp] {
            let mut s = Session::builder(&m).engine(engine).build().unwrap();
            let x = rng.i8_vec(s.input_len());
            assert_eq!(s.run(&x).unwrap().len(), s.output_len());
        }
    }

    #[test]
    fn fc_chain_honors_requested_widths() {
        let mut rng = Prng::new(1);
        let m = fc_chain(&mut rng, &[16, 32, 4]);
        assert_eq!(m.input_shape(), vec![16]);
        assert_eq!(m.output_shape(), vec![4]);
        assert_eq!(m.operators.len(), 2);
    }

    #[test]
    fn zoo_members_round_trip_and_certify() {
        for (name, m) in zoo(20260731) {
            let bytes = crate::format::builder::serialize(&m).unwrap();
            let parsed = MfbModel::parse(&bytes).unwrap_or_else(|e| panic!("{name}: {e}"));
            let c = crate::compiler::CompiledModel::compile(&parsed, Default::default())
                .unwrap_or_else(|e| panic!("{name}: {e:#}"));
            assert!(c.certificate.is_some(), "{name} missing certificate");
        }
    }

    #[test]
    fn generation_is_deterministic_per_seed() {
        let a = random_fc_chain(&mut Prng::new(42), 2);
        let b = random_fc_chain(&mut Prng::new(42), 2);
        assert_eq!(
            crate::format::builder::serialize(&a).unwrap(),
            crate::format::builder::serialize(&b).unwrap()
        );
    }
}
