//! Op resolver — TFLM's `MicroMutableOpResolver` analogue (DESIGN.md S13).
//!
//! Kernels register `(prepare, invoke)` function pointers keyed by opcode.
//! `prepare` runs once per node at `AllocateTensors` time (computing the
//! gemmlowp fixed-point multipliers and geometry — exactly what TFLM
//! kernels do in their `Prepare`); `invoke` runs per inference, reading
//! weights from the *resident model* and activations from the arena.
//!
//! The crucial contrast with the MicroFlow compiler: nothing model-specific
//! is specialized — every registered kernel is "linked in" whether the
//! model uses it or not (the Flash story of Fig. 9/10), options are
//! re-read from the container, and the arithmetic applies zero points per
//! element with no folded constants.
//!
//! `invoke` is allocation-free, as TFLM's is: weights are *borrowed* from
//! the resident container (TFLM reads them from Flash in place) and the
//! bias is unpacked once at `Prepare` into [`NodeData`] (TFLM kernels
//! likewise stash prepared per-channel data in their node userdata).

use anyhow::{bail, Context, Result};

use super::arena::ArenaPlan;
use crate::format::mfb::{MfbModel, OpCode, OpOptions};
use crate::kernels::view::ConvGeometry;
use crate::kernels::{activation, average_pool2d, conv2d, depthwise_conv2d, fully_connected};
use crate::tensor::fixedpoint::FixedPointMultiplier;
use crate::tensor::quant::FusedAct;

/// Prepared per-node state.
#[derive(Clone, Debug)]
pub enum NodeData {
    Fc {
        k: usize,
        n: usize,
        z_x: i32,
        z_w: i32,
        mult: FixedPointMultiplier,
        z_y: i32,
        act_min: i8,
        act_max: i8,
        scratch: usize,
        /// Bias unpacked from the container at prepare time (invoke must
        /// not allocate).
        bias: Vec<i32>,
    },
    Conv {
        geo: ConvGeometry,
        c_out: usize,
        depth_multiplier: usize, // 0 for dense conv
        z_x: i32,
        z_w: i32,
        mult: FixedPointMultiplier,
        z_y: i32,
        act_min: i8,
        act_max: i8,
        scratch: usize,
        bias: Vec<i32>,
    },
    Pool {
        geo: ConvGeometry,
        z_x: i32,
        act_min: i8,
        act_max: i8,
        scratch: usize,
    },
    Elementwise,
}

impl NodeData {
    pub fn scratch_len(&self) -> usize {
        match self {
            NodeData::Fc { scratch, .. }
            | NodeData::Conv { scratch, .. }
            | NodeData::Pool { scratch, .. } => *scratch,
            NodeData::Elementwise => 0,
        }
    }
}

pub type PrepareFn = fn(&MfbModel, usize) -> Result<NodeData>;
pub type InvokeFn =
    fn(&MfbModel, usize, &NodeData, &ArenaPlan, &mut [i8], &mut [i8]) -> Result<()>;

/// A registered kernel.
#[derive(Clone, Copy)]
pub struct RegisteredKernel {
    pub opcode: OpCode,
    pub prepare: PrepareFn,
    pub invoke: InvokeFn,
}

/// The registry.
#[derive(Default)]
pub struct OpResolver {
    kernels: Vec<RegisteredKernel>,
}

impl OpResolver {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register every built-in kernel (what the paper's TFLM firmware does
    /// with `AllOpsResolver`).
    pub fn with_all_kernels() -> Self {
        let mut r = Self::new();
        r.register(OpCode::FullyConnected, prepare_fc, invoke_fc);
        r.register(OpCode::Conv2D, prepare_conv, invoke_conv);
        r.register(OpCode::DepthwiseConv2D, prepare_dwconv, invoke_conv);
        r.register(OpCode::AveragePool2D, prepare_pool, invoke_pool);
        r.register(OpCode::Reshape, prepare_elementwise, invoke_reshape);
        r.register(OpCode::Softmax, prepare_elementwise, invoke_softmax);
        r.register(OpCode::Relu, prepare_elementwise, invoke_relu);
        r.register(OpCode::Relu6, prepare_elementwise, invoke_relu6);
        r
    }

    pub fn register(&mut self, opcode: OpCode, prepare: PrepareFn, invoke: InvokeFn) {
        self.kernels.push(RegisteredKernel { opcode, prepare, invoke });
    }

    pub fn lookup(&self, opcode: OpCode) -> Option<RegisteredKernel> {
        self.kernels.iter().find(|k| k.opcode == opcode).copied()
    }

    pub fn registered_count(&self) -> usize {
        self.kernels.len()
    }
}

// ---------------------------------------------------------------------------
// shared helpers
// ---------------------------------------------------------------------------

fn fused_act(model: &MfbModel, oi: usize) -> Result<FusedAct> {
    crate::compiler::preprocess::fused_act_of(&model.operators[oi])
}

/// Disjoint (input, output) arena views for one node.
fn arena_io<'a>(
    model: &MfbModel,
    oi: usize,
    plan: &ArenaPlan,
    arena: &'a mut [i8],
) -> Result<(&'a [i8], &'a mut [i8])> {
    let op = &model.operators[oi];
    let in_idx = op.input(0)?;
    let out_idx = op.output(0)?;
    let in_len = model.tensors[in_idx].numel();
    let out_len = model.tensors[out_idx].numel();
    let in_off = plan.offset_of(in_idx).context("input not in arena")?;
    let out_off = plan.offset_of(out_idx).context("output not in arena")?;
    if in_off + in_len <= out_off {
        let (a, b) = arena.split_at_mut(out_off);
        Ok((&a[in_off..in_off + in_len], &mut b[..out_len]))
    } else if out_off + out_len <= in_off {
        let (a, b) = arena.split_at_mut(in_off);
        Ok((&b[..in_len], &mut a[out_off..out_off + out_len]))
    } else {
        bail!("op #{oi}: overlapping arena placements (planner bug)");
    }
}

// ---------------------------------------------------------------------------
// FullyConnected
// ---------------------------------------------------------------------------

fn prepare_fc(model: &MfbModel, oi: usize) -> Result<NodeData> {
    let op = &model.operators[oi];
    let x_t = &model.tensors[op.input(0)?];
    let w_t = &model.tensors[op.input(1)?];
    let y_t = &model.tensors[op.output(0)?];
    let [k, n] = w_t.dims[..] else { bail!("FC weights must be 2-D") };
    let real = (x_t.qparams.scale as f64 * w_t.qparams.scale as f64) / y_t.qparams.scale as f64;
    let act = fused_act(model, oi)?;
    let (act_min, act_max) = act.bounds(y_t.qparams.scale, y_t.qparams.zero_point);
    Ok(NodeData::Fc {
        k,
        n,
        z_x: x_t.qparams.zero_point,
        z_w: w_t.qparams.zero_point,
        mult: FixedPointMultiplier::from_real(real),
        z_y: y_t.qparams.zero_point,
        act_min,
        act_max,
        scratch: 0,
        bias: model.tensors[op.input(2)?].data_i32()?,
    })
}

fn invoke_fc(
    model: &MfbModel,
    oi: usize,
    data: &NodeData,
    plan: &ArenaPlan,
    arena: &mut [i8],
    _scratch: &mut [i8],
) -> Result<()> {
    let NodeData::Fc { k, n, z_x, z_w, mult, z_y, act_min, act_max, bias, .. } = data else {
        bail!("node data mismatch")
    };
    let op = &model.operators[oi];
    // weights read (borrowed) from the resident container every invoke
    let w = model.tensors[op.input(1)?].data_i8_ref()?;
    let (x, y) = arena_io(model, oi, plan, arena)?;
    fully_connected::fully_connected_interp(
        x, w, bias, *k, *n, *z_x, *z_w, *mult, *z_y, *act_min, *act_max, y,
    );
    Ok(())
}

// ---------------------------------------------------------------------------
// Conv2D / DepthwiseConv2D (shared invoke, distinct prepare)
// ---------------------------------------------------------------------------

fn prepare_conv(model: &MfbModel, oi: usize) -> Result<NodeData> {
    let op = &model.operators[oi];
    let x_t = &model.tensors[op.input(0)?];
    let f_t = &model.tensors[op.input(1)?];
    let y_t = &model.tensors[op.output(0)?];
    let OpOptions::Conv2D { stride, padding, .. } = op.options else {
        bail!("bad Conv2D options")
    };
    let [c_out, kh, kw, c_in] = f_t.dims[..] else { bail!("Conv2D filters must be 4-D") };
    let [_, h, w, _] = x_t.dims[..] else { bail!("Conv2D input must be [1,H,W,C]") };
    let geo = ConvGeometry::new(h, w, c_in, kh, kw, stride.0, stride.1, padding)?;
    let real = (x_t.qparams.scale as f64 * f_t.qparams.scale as f64) / y_t.qparams.scale as f64;
    let act = fused_act(model, oi)?;
    let (act_min, act_max) = act.bounds(y_t.qparams.scale, y_t.qparams.zero_point);
    Ok(NodeData::Conv {
        geo,
        c_out,
        depth_multiplier: 0,
        z_x: x_t.qparams.zero_point,
        z_w: f_t.qparams.zero_point,
        mult: FixedPointMultiplier::from_real(real),
        z_y: y_t.qparams.zero_point,
        act_min,
        act_max,
        scratch: kh * kw * c_in,
        bias: model.tensors[op.input(2)?].data_i32()?,
    })
}

fn prepare_dwconv(model: &MfbModel, oi: usize) -> Result<NodeData> {
    let op = &model.operators[oi];
    let x_t = &model.tensors[op.input(0)?];
    let w_t = &model.tensors[op.input(1)?];
    let y_t = &model.tensors[op.output(0)?];
    let OpOptions::DepthwiseConv2D { stride, padding, depth_multiplier, .. } = op.options else {
        bail!("bad DepthwiseConv2D options")
    };
    let [_, kh, kw, c_out] = w_t.dims[..] else { bail!("DW filters must be [1,KH,KW,Cout]") };
    let [_, h, w, c_in] = x_t.dims[..] else { bail!("DW input must be [1,H,W,C]") };
    let geo = ConvGeometry::new(h, w, c_in, kh, kw, stride.0, stride.1, padding)?;
    let real = (x_t.qparams.scale as f64 * w_t.qparams.scale as f64) / y_t.qparams.scale as f64;
    let act = fused_act(model, oi)?;
    let (act_min, act_max) = act.bounds(y_t.qparams.scale, y_t.qparams.zero_point);
    Ok(NodeData::Conv {
        geo,
        c_out,
        depth_multiplier,
        z_x: x_t.qparams.zero_point,
        z_w: w_t.qparams.zero_point,
        mult: FixedPointMultiplier::from_real(real),
        z_y: y_t.qparams.zero_point,
        act_min,
        act_max,
        scratch: kh * kw * c_in,
        bias: model.tensors[op.input(2)?].data_i32()?,
    })
}

fn invoke_conv(
    model: &MfbModel,
    oi: usize,
    data: &NodeData,
    plan: &ArenaPlan,
    arena: &mut [i8],
    scratch: &mut [i8],
) -> Result<()> {
    let NodeData::Conv {
        geo, c_out, depth_multiplier, z_x, z_w, mult, z_y, act_min, act_max, scratch: slen, bias,
    } = data
    else {
        bail!("node data mismatch")
    };
    let op = &model.operators[oi];
    let filters = model.tensors[op.input(1)?].data_i8_ref()?;
    let (x, y) = arena_io(model, oi, plan, arena)?;
    let view = &mut scratch[..*slen];
    if *depth_multiplier == 0 {
        conv2d::conv2d_interp(
            x, filters, bias, geo, *c_out, *z_x, *z_w, *mult, *z_y, *act_min, *act_max, view, y,
        );
    } else {
        depthwise_conv2d::depthwise_conv2d_interp(
            x,
            filters,
            bias,
            geo,
            *depth_multiplier,
            *z_x,
            *z_w,
            *mult,
            *z_y,
            *act_min,
            *act_max,
            view,
            y,
        );
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// AveragePool2D
// ---------------------------------------------------------------------------

fn prepare_pool(model: &MfbModel, oi: usize) -> Result<NodeData> {
    let op = &model.operators[oi];
    let x_t = &model.tensors[op.input(0)?];
    let y_t = &model.tensors[op.output(0)?];
    let OpOptions::AveragePool2D { filter, stride, padding, .. } = op.options else {
        bail!("bad AveragePool2D options")
    };
    let [_, h, w, c] = x_t.dims[..] else { bail!("pool input must be [1,H,W,C]") };
    let geo = ConvGeometry::new(h, w, c, filter.0, filter.1, stride.0, stride.1, padding)?;
    let act = fused_act(model, oi)?;
    let (act_min, act_max) = act.bounds(y_t.qparams.scale, y_t.qparams.zero_point);
    Ok(NodeData::Pool {
        geo,
        z_x: x_t.qparams.zero_point,
        act_min,
        act_max,
        scratch: filter.0 * filter.1 * c,
    })
}

fn invoke_pool(
    model: &MfbModel,
    oi: usize,
    data: &NodeData,
    plan: &ArenaPlan,
    arena: &mut [i8],
    scratch: &mut [i8],
) -> Result<()> {
    let NodeData::Pool { geo, z_x, act_min, act_max, scratch: slen } = data else {
        bail!("node data mismatch")
    };
    let (x, y) = arena_io(model, oi, plan, arena)?;
    average_pool2d::average_pool2d_interp(
        x,
        geo,
        *z_x as i8,
        *act_min,
        *act_max,
        &mut scratch[..*slen],
        y,
    );
    Ok(())
}

// ---------------------------------------------------------------------------
// element-wise ops
// ---------------------------------------------------------------------------

fn prepare_elementwise(_model: &MfbModel, _oi: usize) -> Result<NodeData> {
    Ok(NodeData::Elementwise)
}

fn invoke_reshape(
    model: &MfbModel,
    oi: usize,
    _data: &NodeData,
    plan: &ArenaPlan,
    arena: &mut [i8],
    _scratch: &mut [i8],
) -> Result<()> {
    // the interpreter copies: it has no compile-time aliasing knowledge
    let (x, y) = arena_io(model, oi, plan, arena)?;
    y.copy_from_slice(x);
    Ok(())
}

macro_rules! elementwise_invoke {
    ($name:ident, $kernel:path) => {
        fn $name(
            model: &MfbModel,
            oi: usize,
            _data: &NodeData,
            plan: &ArenaPlan,
            arena: &mut [i8],
            _scratch: &mut [i8],
        ) -> Result<()> {
            let op = &model.operators[oi];
            let xq = model.tensors[op.input(0)?].qparams;
            let yq = model.tensors[op.output(0)?].qparams;
            let (x, y) = arena_io(model, oi, plan, arena)?;
            $kernel(x, xq.scale, xq.zero_point, yq.scale, yq.zero_point, y);
            Ok(())
        }
    };
}

elementwise_invoke!(invoke_softmax, activation::softmax);
elementwise_invoke!(invoke_relu, activation::relu);
elementwise_invoke!(invoke_relu6, activation::relu6);

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_kernels_resolver_covers_every_opcode() {
        let r = OpResolver::with_all_kernels();
        for code in 0..8u8 {
            let op = OpCode::from_u8(code).unwrap();
            assert!(r.lookup(op).is_some(), "{op:?} missing");
        }
        assert_eq!(r.registered_count(), 8);
    }

    #[test]
    fn empty_resolver_resolves_nothing() {
        let r = OpResolver::new();
        assert!(r.lookup(OpCode::FullyConnected).is_none());
    }
}
