//! Tensor arena planner — TFLM's greedy memory planner (DESIGN.md S13).
//!
//! TFLM pre-allocates one arena sized to the worst simultaneous set of
//! activation tensors, holds it for the interpreter's lifetime and never
//! frees it (paper Sec. 4.2). This module reproduces the planning:
//! lifetime analysis over the operator list, then greedy first-fit offset
//! assignment (largest-first, like TFLM's `GreedyMemoryPlanner`).
//!
//! The resulting `arena_size` is the TFLM-side RAM number in Fig. 9/10
//! (plus the interpreter's fixed structures, charged by `sim`).

use anyhow::{bail, Result};

use crate::format::mfb::MfbModel;

/// Placement of one activation tensor in the arena.
#[derive(Clone, Copy, Debug)]
pub struct Placement {
    pub tensor: usize,
    pub offset: usize,
    pub size: usize,
    pub first_use: usize,
    pub last_use: usize,
}

/// The planned arena.
#[derive(Clone, Debug)]
pub struct ArenaPlan {
    pub placements: Vec<Placement>,
    pub arena_size: usize,
}

impl ArenaPlan {
    /// Plan the arena for a model: every activation tensor (graph inputs,
    /// outputs and intermediates — tensors without constant payloads) gets
    /// an offset; weights stay in "Flash" (the resident model).
    pub fn plan(model: &MfbModel) -> Result<ArenaPlan> {
        let n = model.tensors.len();
        let mut first = vec![usize::MAX; n];
        let mut last = vec![0usize; n];
        // graph inputs are live from the start; outputs to the end
        for &gi in &model.graph_inputs {
            first[gi] = 0;
        }
        for (oi, op) in model.operators.iter().enumerate() {
            for &t in op.inputs.iter().chain(op.outputs.iter()) {
                if t < 0 {
                    continue;
                }
                let t = t as usize;
                if first[t] == usize::MAX {
                    first[t] = oi;
                }
                last[t] = last[t].max(oi);
            }
        }
        for &go in &model.graph_outputs {
            last[go] = model.operators.len();
        }

        // candidates: activation tensors (no constant payload)
        let mut cands: Vec<Placement> = (0..n)
            .filter(|&t| model.tensors[t].data.is_empty())
            .map(|t| Placement {
                tensor: t,
                offset: 0,
                size: model.tensors[t].numel() * model.tensors[t].dtype.size_bytes(),
                first_use: first[t],
                last_use: last[t],
            })
            .collect();
        for c in &cands {
            if c.first_use == usize::MAX {
                bail!("activation tensor {} is never used", c.tensor);
            }
        }
        // TFLM greedy: biggest tensors first, first-fit at the lowest
        // offset that doesn't overlap a live conflicting placement
        cands.sort_by(|a, b| b.size.cmp(&a.size).then(a.tensor.cmp(&b.tensor)));
        let mut placed: Vec<Placement> = Vec::with_capacity(cands.len());
        let mut arena_size = 0usize;
        for mut c in cands {
            let conflicts: Vec<&Placement> = placed
                .iter()
                .filter(|p| !(p.last_use < c.first_use || c.last_use < p.first_use))
                .collect();
            // first-fit scan over candidate offsets
            let mut offset = 0usize;
            loop {
                let clash = conflicts
                    .iter()
                    .find(|p| offset < p.offset + p.size && p.offset < offset + c.size);
                match clash {
                    Some(p) => offset = p.offset + p.size,
                    None => break,
                }
            }
            c.offset = offset;
            arena_size = arena_size.max(offset + c.size);
            placed.push(c);
        }
        placed.sort_by_key(|p| p.tensor);
        Ok(ArenaPlan { placements: placed, arena_size })
    }

    /// Arena offset of a tensor (None for weights).
    pub fn offset_of(&self, tensor: usize) -> Option<usize> {
        self.placements.iter().find(|p| p.tensor == tensor).map(|p| p.offset)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::format::mfb::MfbModel;

    #[test]
    fn tiny_model_arena_holds_in_and_out() {
        let m = MfbModel::parse(&crate::format::mfb::tests::tiny_mfb()).unwrap();
        let plan = ArenaPlan::plan(&m).unwrap();
        // two activation tensors: input [1,2] and output [1,3]
        assert_eq!(plan.placements.len(), 2);
        // both live simultaneously during op 0 -> must not overlap
        let a = plan.offset_of(0).unwrap();
        let b = plan.offset_of(3).unwrap();
        let (sa, sb) = (2, 3);
        assert!(a + sa <= b || b + sb <= a, "overlap: {a}+{sa} vs {b}+{sb}");
        assert!(plan.arena_size >= 5);
    }

    #[test]
    fn disjoint_lifetimes_share_space() {
        // synthetic: chain of 3 FCs; tensor 0 (in) and tensor of op2's
        // output never overlap op0's intermediate -> arena < sum of sizes
        // (covered more thoroughly in the integration tests on real
        // models; here we check the planner reuses offsets at all)
        let m = MfbModel::parse(&crate::format::mfb::tests::tiny_mfb()).unwrap();
        let plan = ArenaPlan::plan(&m).unwrap();
        let total: usize = plan.placements.iter().map(|p| p.size).sum();
        assert!(plan.arena_size <= total);
    }
}
