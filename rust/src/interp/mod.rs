//! TFLM-like interpreter baseline (DESIGN.md S13) — the comparator the
//! paper evaluates MicroFlow against.
//!
//! Faithfully reproduces the *mechanisms* the paper attributes TFLM's costs
//! to (Sec. 2.3, 4.2, 6.2.2):
//!
//! * the **whole model container stays resident** (names, versions,
//!   options — `MfbModel` is kept alive, like TFLM keeps the FlatBuffer
//!   mapped in Flash);
//! * parsing/validation happen at **runtime** (`Interpreter::new` is the
//!   `AllocateTensors` moment, re-run per deployment);
//! * activations live in a **tensor arena** sized for the worst case and
//!   held for the interpreter's lifetime ([`arena`]);
//! * kernels are resolved through an **op-resolver registry** of function
//!   pointers ([`resolver`]) and invoked via per-node dispatch;
//! * kernel arithmetic is integer-only gemmlowp fixed-point with
//!   per-element zero-point application — more work per MAC, no folded
//!   constants (`kernels::*_interp`).

pub mod arena;
pub mod resolver;

use anyhow::{bail, Context, Result};

use crate::format::mfb::MfbModel;
use crate::tensor::quant::QParams;
use arena::ArenaPlan;
use resolver::{NodeData, OpResolver, RegisteredKernel};

/// The interpreter instance (TFLM's `MicroInterpreter` analogue).
pub struct Interpreter {
    /// The full model stays resident — the interpreter reads options and
    /// tensor metadata from it during prepare/invoke (Flash cost!).
    model: MfbModel,
    /// Prepared per-node state (fixed-point multipliers etc. — TFLM
    /// computes these in each kernel's `Prepare`).
    nodes: Vec<PreparedNode>,
    /// The tensor arena: one allocation for the lifetime, never shrunk.
    arena: Vec<i8>,
    /// Kernel scratch (TFLM allocates these inside the arena at prepare;
    /// kept separate here but sized once and counted by the memory model).
    scratch: Vec<i8>,
    plan: ArenaPlan,
}

struct PreparedNode {
    kernel: RegisteredKernel,
    data: NodeData,
    op_index: usize,
}

impl Interpreter {
    /// Parse + prepare (TFLM: `GetModel` + `AllocateTensors`).
    ///
    /// `resolver` lists the kernels linked into the binary. TFLM links
    /// whatever the resolver registers regardless of the model — the
    /// memory model charges Flash for all of them.
    pub fn new(model_bytes: &[u8], resolver: &OpResolver) -> Result<Self> {
        // 1. runtime parsing — every byte of metadata is walked here
        let model = MfbModel::parse(model_bytes).context("interpreter: model parse")?;

        // 2. arena planning (TFLM's greedy memory planner)
        let plan = ArenaPlan::plan(&model)?;
        let arena = vec![0i8; plan.arena_size];

        // 3. per-node prepare: resolve kernels, precompute multipliers
        let mut nodes = Vec::with_capacity(model.operators.len());
        for (oi, op) in model.operators.iter().enumerate() {
            let kernel = resolver
                .lookup(op.opcode)
                .with_context(|| format!("op #{oi}: {} not registered", op.opcode.name()))?;
            let data = (kernel.prepare)(&model, oi)
                .with_context(|| format!("op #{oi}: prepare failed"))?;
            nodes.push(PreparedNode { kernel, data, op_index: oi });
        }
        if model.graph_inputs.len() != 1 || model.graph_outputs.len() != 1 {
            bail!("interpreter supports single-input single-output graphs");
        }
        let scratch_len = nodes.iter().map(|n| n.data.scratch_len()).max().unwrap_or(0);
        let scratch = vec![0i8; scratch_len];
        Ok(Interpreter { model, nodes, arena, scratch, plan })
    }

    pub fn arena_size(&self) -> usize {
        self.plan.arena_size
    }

    pub fn model(&self) -> &MfbModel {
        &self.model
    }

    pub fn input_len(&self) -> usize {
        self.model.tensors[self.model.graph_inputs[0]].numel()
    }

    pub fn output_len(&self) -> usize {
        self.model.tensors[self.model.graph_outputs[0]].numel()
    }

    pub fn input_qparams(&self) -> QParams {
        self.model.input_qparams()
    }

    pub fn output_qparams(&self) -> QParams {
        self.model.output_qparams()
    }

    /// Run one inference (TFLM's `Invoke`): per-node dispatch through the
    /// registered kernel function pointers, reading/writing arena slices.
    pub fn invoke(&mut self, input: &[i8]) -> Result<Vec<i8>> {
        let mut out = vec![0i8; self.output_len()];
        self.invoke_into(input, &mut out)?;
        Ok(out)
    }

    /// Allocation-free `Invoke`: the result is copied from the arena into
    /// `out`. This is the hot path the batched serving layers use —
    /// weights are borrowed from the resident container and prepared
    /// per-node state (bias, multipliers) was cached at `AllocateTensors`
    /// time, so no heap allocation happens here.
    pub fn invoke_into(&mut self, input: &[i8], out: &mut [i8]) -> Result<()> {
        if input.len() != self.input_len() {
            bail!("input length {} != {}", input.len(), self.input_len());
        }
        if out.len() != self.output_len() {
            bail!("output length {} != {}", out.len(), self.output_len());
        }
        let in_idx = self.model.graph_inputs[0];
        let off = self.plan.offset_of(in_idx).context("input tensor not in arena")?;
        self.arena[off..off + input.len()].copy_from_slice(input);

        for node in &self.nodes {
            (node.kernel.invoke)(
                &self.model,
                node.op_index,
                &node.data,
                &self.plan,
                &mut self.arena,
                &mut self.scratch,
            )
            .with_context(|| format!("invoke op #{}", node.op_index))?;
        }

        let out_idx = self.model.graph_outputs[0];
        let off = self.plan.offset_of(out_idx).context("output tensor not in arena")?;
        out.copy_from_slice(&self.arena[off..off + out.len()]);
        Ok(())
    }

    /// Arena + scratch base addresses — pointer-stability diagnostics for
    /// the no-allocation conformance tests.
    pub fn buffer_ptrs(&self) -> (usize, usize) {
        (self.arena.as_ptr() as usize, self.scratch.as_ptr() as usize)
    }

    /// Float convenience (same contract as the MicroFlow engine).
    pub fn invoke_f32(&mut self, input: &[f32]) -> Result<Vec<f32>> {
        let q = self.input_qparams().quantize_slice(input);
        let out = self.invoke(&q)?;
        let oq = self.output_qparams();
        Ok(out.iter().map(|&v| oq.dequantize(v)).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::plan::CompileOptions;
    use crate::engine::MicroFlowEngine;

    fn tiny_bytes() -> Vec<u8> {
        crate::format::mfb::tests::tiny_mfb()
    }

    #[test]
    fn interpreter_runs_tiny_model() {
        let resolver = OpResolver::with_all_kernels();
        let mut it = Interpreter::new(&tiny_bytes(), &resolver).unwrap();
        let out = it.invoke(&[3, 1]).unwrap();
        assert_eq!(out.len(), 3);
        // fixed-point path: within 1 unit of the MicroFlow float path
        let m = crate::format::mfb::MfbModel::parse(&tiny_bytes()).unwrap();
        let e = MicroFlowEngine::new(&m, CompileOptions::default()).unwrap();
        let mf = e.predict(&[3, 1]);
        for (a, b) in out.iter().zip(&mf) {
            assert!((*a as i32 - *b as i32).abs() <= 1, "{out:?} vs {mf:?}");
        }
    }

    #[test]
    fn missing_kernel_is_a_prepare_time_error() {
        let resolver = OpResolver::new(); // nothing registered
        assert!(Interpreter::new(&tiny_bytes(), &resolver).is_err());
    }

    #[test]
    fn arena_is_stable_across_invokes() {
        let resolver = OpResolver::with_all_kernels();
        let mut it = Interpreter::new(&tiny_bytes(), &resolver).unwrap();
        let p0 = it.arena.as_ptr() as usize;
        let size0 = it.arena_size();
        for _ in 0..5 {
            it.invoke(&[1, 2]).unwrap();
        }
        assert_eq!(it.arena.as_ptr() as usize, p0);
        assert_eq!(it.arena_size(), size0);
    }

    #[test]
    fn invoke_rejects_wrong_input_length() {
        let resolver = OpResolver::with_all_kernels();
        let mut it = Interpreter::new(&tiny_bytes(), &resolver).unwrap();
        assert!(it.invoke(&[1]).is_err());
    }
}
