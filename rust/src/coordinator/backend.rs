//! Execution backends for the coordinator (DESIGN.md S16).
//!
//! One trait, three implementations:
//!
//! * [`NativeBackend`] — the MicroFlow engine (this paper's system);
//! * [`InterpBackend`] — the TFLM-like interpreter (baseline serving);
//! * [`PjrtBackend`]   — the JAX-AOT'd HLO running on the XLA CPU client
//!   (true batched execution, one executable per batch variant).

use anyhow::Result;

use crate::compiler::plan::CompileOptions;
use crate::engine::MicroFlowEngine;
use crate::format::mfb::MfbModel;
use crate::interp::resolver::OpResolver;
use crate::interp::Interpreter;
use crate::runtime::PjrtEngine;
use crate::tensor::quant::QParams;

/// A quantized batched execution backend.
pub trait Backend: Send {
    fn kind(&self) -> &'static str;
    fn input_len(&self) -> usize;
    fn output_len(&self) -> usize;
    fn input_qparams(&self) -> QParams;
    fn output_qparams(&self) -> QParams;
    /// Largest batch worth submitting at once (the batcher's target).
    fn preferred_batch(&self) -> usize;
    /// Execute `n` samples packed in `inputs`; returns `n * output_len`
    /// values.
    fn execute(&mut self, inputs: &[i8], n: usize) -> Result<Vec<i8>>;
}

/// MicroFlow engine backend (per-sample kernel loop).
pub struct NativeBackend {
    engine: MicroFlowEngine,
}

impl NativeBackend {
    pub fn new(model: &MfbModel, options: CompileOptions) -> Result<Self> {
        Ok(NativeBackend { engine: MicroFlowEngine::new(model, options)? })
    }

    pub fn load(path: impl AsRef<std::path::Path>) -> Result<Self> {
        Ok(NativeBackend { engine: MicroFlowEngine::load(path, CompileOptions::default())? })
    }
}

impl Backend for NativeBackend {
    fn kind(&self) -> &'static str {
        "microflow"
    }
    fn input_len(&self) -> usize {
        self.engine.input_len()
    }
    fn output_len(&self) -> usize {
        self.engine.output_len()
    }
    fn input_qparams(&self) -> QParams {
        self.engine.input_qparams()
    }
    fn output_qparams(&self) -> QParams {
        self.engine.output_qparams()
    }
    fn preferred_batch(&self) -> usize {
        8
    }
    fn execute(&mut self, inputs: &[i8], n: usize) -> Result<Vec<i8>> {
        let ilen = self.input_len();
        let olen = self.output_len();
        let mut out = vec![0i8; n * olen];
        for i in 0..n {
            self.engine
                .predict_into(&inputs[i * ilen..(i + 1) * ilen], &mut out[i * olen..(i + 1) * olen]);
        }
        Ok(out)
    }
}

/// TFLM-like interpreter backend.
pub struct InterpBackend {
    interp: Interpreter,
}

impl InterpBackend {
    pub fn new(model_bytes: &[u8]) -> Result<Self> {
        Ok(InterpBackend { interp: Interpreter::new(model_bytes, &OpResolver::with_all_kernels())? })
    }

    pub fn load(path: impl AsRef<std::path::Path>) -> Result<Self> {
        let bytes = std::fs::read(path)?;
        Self::new(&bytes)
    }
}

impl Backend for InterpBackend {
    fn kind(&self) -> &'static str {
        "tflm-interp"
    }
    fn input_len(&self) -> usize {
        self.interp.input_len()
    }
    fn output_len(&self) -> usize {
        self.interp.output_len()
    }
    fn input_qparams(&self) -> QParams {
        self.interp.input_qparams()
    }
    fn output_qparams(&self) -> QParams {
        self.interp.output_qparams()
    }
    fn preferred_batch(&self) -> usize {
        8
    }
    fn execute(&mut self, inputs: &[i8], n: usize) -> Result<Vec<i8>> {
        let ilen = self.input_len();
        let olen = self.output_len();
        let mut out = Vec::with_capacity(n * olen);
        for i in 0..n {
            out.extend(self.interp.invoke(&inputs[i * ilen..(i + 1) * ilen])?);
        }
        Ok(out)
    }
}

/// PJRT backend (batched HLO execution).
pub struct PjrtBackend {
    engine: PjrtEngine,
}

// SAFETY: the xla crate's client/executable handles hold `Rc`s, making the
// type !Send by default. A `PjrtBackend` owns its client AND every
// executable holding clones of that `Rc`; the whole object graph moves to
// exactly one worker thread at `Server::start` and is never aliased across
// threads afterwards (each worker owns its backend exclusively; the trait
// takes `&mut self`).
unsafe impl Send for PjrtBackend {}

impl PjrtBackend {
    pub fn load(artifacts: &std::path::Path, model: &str) -> Result<Self> {
        Ok(PjrtBackend { engine: PjrtEngine::load(artifacts, model)? })
    }

    pub fn engine(&self) -> &PjrtEngine {
        &self.engine
    }
}

impl Backend for PjrtBackend {
    fn kind(&self) -> &'static str {
        "pjrt"
    }
    fn input_len(&self) -> usize {
        self.engine.input_len()
    }
    fn output_len(&self) -> usize {
        self.engine.output_len()
    }
    fn input_qparams(&self) -> QParams {
        self.engine.input_qparams
    }
    fn output_qparams(&self) -> QParams {
        self.engine.output_qparams
    }
    fn preferred_batch(&self) -> usize {
        *self.engine.batch_sizes().last().unwrap_or(&1)
    }
    fn execute(&mut self, inputs: &[i8], n: usize) -> Result<Vec<i8>> {
        self.engine.execute_batch(inputs, n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_backend_batches_by_looping() {
        let m = MfbModel::parse(&crate::format::mfb::tests::tiny_mfb()).unwrap();
        let mut b = NativeBackend::new(&m, CompileOptions::default()).unwrap();
        let one = b.execute(&[3, 1], 1).unwrap();
        let two = b.execute(&[3, 1, 3, 1], 2).unwrap();
        assert_eq!(two[..3], one[..]);
        assert_eq!(two[3..], one[..]);
    }

    #[test]
    fn interp_backend_matches_native_within_one() {
        let bytes = crate::format::mfb::tests::tiny_mfb();
        let m = MfbModel::parse(&bytes).unwrap();
        let mut nat = NativeBackend::new(&m, CompileOptions::default()).unwrap();
        let mut itp = InterpBackend::new(&bytes).unwrap();
        let a = nat.execute(&[5, -9], 1).unwrap();
        let b = itp.execute(&[5, -9], 1).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert!((*x as i32 - *y as i32).abs() <= 1);
        }
    }
}
