//! Dynamic batcher (DESIGN.md S16) — now QoS-aware.
//!
//! Requests accumulate until the batch target is reached or the oldest
//! waiting request has been queued for the class's wait budget — the
//! standard latency/throughput trade (vLLM-router style, scaled to
//! TinyML). The batcher runs inside each worker thread: it owns the
//! receive side of the bounded request channel.
//!
//! Request-lifecycle rules (`coordinator::request`):
//!
//! * **never mix classes in one batch** — the first live request fixes the
//!   batch's [`QosClass`]; a request of another class ends the batch and
//!   is carried over (the worker's one-slot stash) to lead the next one;
//! * **Interactive batches cut at the latency posture** — their wait is
//!   capped at `max_wait /` [`LATENCY_WAIT_DIV`] regardless of adaptive
//!   tuning, while Bulk/Background fill `max_batch` under the effective
//!   (possibly adaptively restored) wait;
//! * **shed before execution** — cancelled entries are dropped (their
//!   ticket resolves to a "cancelled" error; the slot is never executed)
//!   and expired-deadline entries are answered with a shed error; both are
//!   counted per class in [`Metrics`](super::metrics::Metrics);
//! * **graceful worker retirement** — a
//!   [`QueueEntry::Retire`](super::request::QueueEntry) sentinel on the
//!   queue ends the claiming worker's batch assembly ([`Cut::Retire`]):
//!   the worker executes what it gathered, then exits, and entries behind
//!   the sentinel stay queued for the surviving workers. This is how the
//!   elastic [`Server`](super::server::Server) scales down without
//!   dropping accepted requests;
//! * **retries lead batches** — a transiently-failed request re-enqueued
//!   by a sibling worker sits in the shared retry buffer, which every
//!   worker checks *before* the channel, so a retried request is never
//!   starved behind fresh arrivals. The buffer is a plain
//!   `Mutex<VecDeque>` rather than a second channel sender on purpose:
//!   worker-held senders would keep the request channel connected after
//!   the server drops its side, and shutdown would deadlock.
//!
//! [`AdaptiveBatcher`] layers per-replica tuning on top: each worker
//! observes the queue depth at every batch cut (via
//! [`Metrics::outstanding`](super::metrics::Metrics::outstanding)) and
//! moves its own effective `BatcherConfig` between a latency posture
//! (don't hold a lone request hostage for `max_wait`) and a throughput
//! posture (the configured target) — the fleet's replica pools enable it
//! per replica because `preferred_batch` is per-session config.

use std::collections::VecDeque;
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::anyhow;

use super::metrics::Metrics;
use super::request::{Pending, QosClass, QueueEntry};

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Target batch size (usually the session's `preferred_batch`).
    pub max_batch: usize,
    /// Longest a request may wait for peers before the batch is cut.
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(2) }
    }
}

/// Check one claimed entry's lifecycle: pass it through if live, otherwise
/// resolve it (count + reply) and return `None`.
///
/// A cancelled entry is dropped without a reply — dropping the sender
/// resolves its ticket to a "cancelled" error, and the slot is never
/// executed. An expired-deadline entry is answered with a shed error so
/// the caller learns its fate rather than waiting forever.
fn admit(p: Pending, metrics: &Metrics) -> Option<Pending> {
    if p.is_cancelled() {
        metrics.record_cancelled(p.request.class);
        return None;
    }
    if p.deadline_expired(Instant::now()) {
        metrics.record_shed(p.request.class);
        let id = p.request.id;
        let _ =
            p.reply.send(Err(anyhow!("request {id} shed: deadline expired before execution")));
        return None;
    }
    Some(p)
}

/// How long an idle worker blocks on the channel before surfacing to
/// re-check its quarantine flag and the shared retry buffer. Bounds the
/// latency of both targeted ejection and retry pickup when the request
/// channel is quiet.
pub const IDLE_POLL: Duration = Duration::from_millis(1);

/// What one `next_batch` call decided for its worker.
#[derive(Debug)]
pub enum Cut {
    /// Execute this batch, then keep serving.
    Batch(Vec<Pending>),
    /// The worker claimed a [`QueueEntry::Retire`] sentinel: execute this
    /// (possibly empty) batch, then exit. In-flight requests are never
    /// dropped — the sentinel only ends *assembly*, not delivery.
    Retire(Vec<Pending>),
    /// Nothing arrived within [`IDLE_POLL`]: the worker should re-check
    /// its quarantine flag (and anything else control wants checked
    /// between batches), then call again. Without this, a worker blocked
    /// in `recv()` could never be ejected until traffic arrived.
    Idle,
    /// The channel is closed and drained: server shutdown.
    Shutdown,
}

/// Pop the oldest retried request, if any. Kept tiny so the lock is held
/// for a pop, never across channel waits.
fn claim_retry(retry: &Mutex<VecDeque<Pending>>) -> Option<Pending> {
    retry.lock().expect("retry buffer poisoned").pop_front()
}

/// Collect the next single-class batch from `rx`, preferring `retry`.
///
/// The first slot is claimed in a fixed order: the carry stash, then the
/// shared retry buffer, then the channel. Waiting on the channel is
/// bounded by [`IDLE_POLL`] — an empty poll returns [`Cut::Idle`] so the
/// worker can re-check its quarantine flag and the retry buffer instead
/// of blocking forever. A closed, drained channel returns
/// [`Cut::Shutdown`] only once the retry buffer is also empty; a worker
/// that pushed a retry always passes back through this claim order before
/// exiting, so retried requests drain even during shutdown. After the first
/// request arrives, keeps pulling until the class's batch target or wait
/// budget is hit; a request of a *different* class is stashed in `carry`
/// (it leads the next batch) so a batch never mixes classes. Cancelled and
/// expired-deadline entries are shed as they surface and never occupy a
/// batch slot. A [`QueueEntry::Retire`] sentinel ends assembly immediately
/// and turns the cut into [`Cut::Retire`] — the claiming worker executes
/// what it already gathered, then retires; entries still queued behind the
/// sentinel are left for the surviving workers. The carry slot is only
/// ever filled by a class boundary, which also ends the cut, so a retiring
/// cut can never strand a carried request (`carry` is `None` whenever
/// `Retire` is returned).
///
/// `base` is the configured policy, `effective` the (possibly adaptively
/// tuned) one: Interactive batches wait at most `base.max_wait /`
/// [`LATENCY_WAIT_DIV`] even when the adaptive tuner is in its throughput
/// posture.
pub fn next_batch(
    rx: &Receiver<QueueEntry>,
    carry: &mut Option<Pending>,
    retry: &Mutex<VecDeque<Pending>>,
    base: &BatcherConfig,
    effective: &BatcherConfig,
    metrics: &Metrics,
) -> Cut {
    let first = loop {
        let entry = match carry.take() {
            // the class boundary stashed by the previous cut
            Some(p) => QueueEntry::Req(p),
            None => match claim_retry(retry) {
                // a sibling's transient failure: retried ahead of arrivals
                Some(p) => QueueEntry::Req(p),
                None => match rx.recv_timeout(IDLE_POLL) {
                    Ok(e) => e,
                    Err(RecvTimeoutError::Timeout) => return Cut::Idle,
                    // the server hung up; a retry pushed since the check
                    // above must still be served before this worker exits
                    Err(RecvTimeoutError::Disconnected) => match claim_retry(retry) {
                        Some(p) => QueueEntry::Req(p),
                        None => return Cut::Shutdown,
                    },
                },
            },
        };
        match entry {
            QueueEntry::Retire => return Cut::Retire(Vec::new()),
            QueueEntry::Req(p) => {
                if let Some(p) = admit(p, metrics) {
                    break p;
                }
            }
        }
    };
    let class = first.request.class;
    let max_wait = match class {
        QosClass::Interactive => effective.max_wait.min(base.max_wait / LATENCY_WAIT_DIV),
        QosClass::Bulk | QosClass::Background => effective.max_wait,
    };
    let deadline = Instant::now() + max_wait;
    let mut batch = vec![first];
    while batch.len() < effective.max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(QueueEntry::Retire) => return Cut::Retire(batch),
            Ok(QueueEntry::Req(p)) => {
                let Some(p) = admit(p, metrics) else { continue };
                if p.request.class != class {
                    *carry = Some(p);
                    break;
                }
                batch.push(p);
            }
            Err(_) => break, // timeout, or disconnected with the batch non-empty
        }
    }
    Cut::Batch(batch)
}

/// Per-replica batcher tuning driven by observed queue depth.
///
/// Deterministic rules (unit-tested below):
///
/// * a **deep** observation (queue depth ≥ the configured `max_batch`)
///   after a cut means the replica is throughput-bound: after
///   [`ADAPT_STREAK`] consecutive deep cuts the full `max_wait` is
///   restored so batches fill;
/// * a **shallow** observation (queue depth ≤ 1) means waiting only adds
///   latency: after [`ADAPT_STREAK`] consecutive shallow cuts the wait
///   shrinks to `max_wait / `[`LATENCY_WAIT_DIV`];
/// * anything in between decays both streaks without changing posture.
///
/// `max_batch` itself never exceeds the configured ceiling (which the
/// server already clamps to the session's `preferred_batch`).
#[derive(Clone, Copy, Debug)]
pub struct AdaptiveBatcher {
    base: BatcherConfig,
    current: BatcherConfig,
    deep_streak: u32,
    shallow_streak: u32,
}

/// Consecutive same-sign observations before the posture flips.
pub const ADAPT_STREAK: u32 = 2;
/// Wait divisor in the latency posture (also the Interactive class's
/// batching cap — an Interactive batch never waits longer than this
/// fraction of the configured `max_wait`).
pub const LATENCY_WAIT_DIV: u32 = 8;

impl AdaptiveBatcher {
    /// Start in the throughput posture (the configured `base`).
    pub fn new(base: BatcherConfig) -> AdaptiveBatcher {
        AdaptiveBatcher { base, current: base, deep_streak: 0, shallow_streak: 0 }
    }

    /// The effective config for the next batch cut.
    pub fn config(&self) -> BatcherConfig {
        self.current
    }

    /// Feed one observation: the queue depth (outstanding requests) seen
    /// right after a batch was cut.
    pub fn observe(&mut self, queue_depth: u64) {
        if queue_depth >= self.base.max_batch as u64 {
            self.deep_streak += 1;
            self.shallow_streak = 0;
        } else if queue_depth <= 1 {
            self.shallow_streak += 1;
            self.deep_streak = 0;
        } else {
            self.deep_streak = self.deep_streak.saturating_sub(1);
            self.shallow_streak = self.shallow_streak.saturating_sub(1);
        }
        if self.deep_streak >= ADAPT_STREAK {
            self.current = self.base;
        } else if self.shallow_streak >= ADAPT_STREAK {
            self.current = BatcherConfig {
                max_batch: self.base.max_batch,
                max_wait: self.base.max_wait / LATENCY_WAIT_DIV,
            };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Request;
    use std::sync::mpsc::sync_channel;
    use std::time::Instant as StdInstant;

    fn req(v: i8) -> QueueEntry {
        let (p, _t) = Request::new(vec![v]).into_pending();
        QueueEntry::Req(p)
    }

    fn classed(v: i8, class: QosClass) -> QueueEntry {
        let (p, _t) = Request::new(vec![v]).with_class(class).into_pending();
        QueueEntry::Req(p)
    }

    /// `next_batch` with an untuned config (base == effective) and an
    /// empty retry buffer.
    fn cut(
        rx: &Receiver<QueueEntry>,
        carry: &mut Option<Pending>,
        cfg: &BatcherConfig,
        metrics: &Metrics,
    ) -> Cut {
        let retry = Mutex::new(VecDeque::new());
        next_batch(rx, carry, &retry, cfg, cfg, metrics)
    }

    /// Unwrap a [`Cut::Batch`] (panics on retire/shutdown).
    fn must_batch(c: Cut) -> Vec<Pending> {
        match c {
            Cut::Batch(b) => b,
            other => panic!("expected Cut::Batch, got {other:?}"),
        }
    }

    #[test]
    fn cuts_batch_at_max_size() {
        let (tx, rx) = sync_channel(16);
        for i in 0..5 {
            tx.send(req(i)).unwrap();
        }
        let cfg = BatcherConfig { max_batch: 3, max_wait: Duration::from_secs(1) };
        let m = Metrics::new();
        let mut carry = None;
        let b = must_batch(cut(&rx, &mut carry, &cfg, &m));
        assert_eq!(b.len(), 3);
        let b2 = must_batch(cut(&rx, &mut carry, &cfg, &m));
        assert_eq!(b2.len(), 2); // drains the rest after timeout
    }

    #[test]
    fn cuts_batch_at_deadline() {
        let (tx, rx) = sync_channel::<QueueEntry>(16);
        tx.send(req(1)).unwrap();
        let cfg = BatcherConfig { max_batch: 100, max_wait: Duration::from_millis(5) };
        let t0 = StdInstant::now();
        let b = must_batch(cut(&rx, &mut None, &cfg, &Metrics::new()));
        assert_eq!(b.len(), 1);
        assert!(t0.elapsed() < Duration::from_millis(500));
    }

    #[test]
    fn returns_shutdown_on_closed_channel() {
        let (tx, rx) = sync_channel::<QueueEntry>(1);
        drop(tx);
        let cfg = BatcherConfig::default();
        assert!(matches!(cut(&rx, &mut None, &cfg, &Metrics::new()), Cut::Shutdown));
    }

    #[test]
    fn returns_idle_when_nothing_arrives() {
        let (_tx, rx) = sync_channel::<QueueEntry>(1);
        let cfg = BatcherConfig::default();
        let t0 = StdInstant::now();
        assert!(matches!(cut(&rx, &mut None, &cfg, &Metrics::new()), Cut::Idle));
        // idle polls are bounded — the worker surfaces quickly to re-check
        // its quarantine flag, it does not block until traffic arrives
        assert!(t0.elapsed() < Duration::from_millis(500));
    }

    #[test]
    fn retried_requests_lead_the_next_batch() {
        let (tx, rx) = sync_channel(8);
        tx.send(req(2)).unwrap();
        let (retried, _t) = Request::new(vec![1]).into_pending();
        let retry = Mutex::new(VecDeque::from([retried]));
        let cfg = BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(2) };
        let m = Metrics::new();
        let b = match next_batch(&rx, &mut None, &retry, &cfg, &cfg, &m) {
            Cut::Batch(b) => b,
            other => panic!("expected Cut::Batch, got {other:?}"),
        };
        // the retried request is claimed before the fresh arrival
        assert_eq!(b[0].request.payload, vec![1]);
        assert!(retry.lock().unwrap().is_empty());
    }

    #[test]
    fn retry_pushed_after_disconnect_is_still_served() {
        let (tx, rx) = sync_channel::<QueueEntry>(1);
        drop(tx);
        let (retried, _t) = Request::new(vec![7]).into_pending();
        let retry = Mutex::new(VecDeque::from([retried]));
        let cfg = BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(2) };
        let m = Metrics::new();
        match next_batch(&rx, &mut None, &retry, &cfg, &cfg, &m) {
            Cut::Batch(b) => assert_eq!(b[0].request.payload, vec![7]),
            other => panic!("expected Cut::Batch, got {other:?}"),
        }
        // only once the retry buffer is drained does shutdown surface
        assert!(matches!(next_batch(&rx, &mut None, &retry, &cfg, &cfg, &m), Cut::Shutdown));
    }

    #[test]
    fn retried_requests_are_rechecked_for_cancellation_and_deadline() {
        let (tx, rx) = sync_channel(8);
        tx.send(req(3)).unwrap();
        let (cancelled, cancelled_ticket) = Request::new(vec![1]).into_pending();
        cancelled_ticket.cancel();
        let (expired, expired_ticket) =
            Request::new(vec![2]).with_deadline(StdInstant::now()).into_pending();
        let retry = Mutex::new(VecDeque::from([cancelled, expired]));
        let cfg = BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(2) };
        let m = Metrics::new();
        let b = match next_batch(&rx, &mut None, &retry, &cfg, &cfg, &m) {
            Cut::Batch(b) => b,
            other => panic!("expected Cut::Batch, got {other:?}"),
        };
        assert_eq!(b.len(), 1, "dead retries must never occupy a batch slot");
        assert_eq!(b[0].request.payload, vec![3]);
        assert_eq!(m.snapshot().cancelled, 1);
        assert_eq!(m.snapshot().shed, 1);
        assert!(expired_ticket.wait().unwrap_err().to_string().contains("shed"));
    }

    #[test]
    fn retire_sentinel_alone_retires_with_an_empty_batch() {
        let (tx, rx) = sync_channel::<QueueEntry>(4);
        tx.send(QueueEntry::Retire).unwrap();
        tx.send(req(1)).unwrap(); // queued behind the sentinel
        let cfg = BatcherConfig::default();
        let m = Metrics::new();
        let mut carry = None;
        match cut(&rx, &mut carry, &cfg, &m) {
            Cut::Retire(b) => assert!(b.is_empty()),
            other => panic!("expected Cut::Retire, got {other:?}"),
        }
        assert!(carry.is_none());
        // the request behind the sentinel is untouched: a surviving worker
        // claims it on its next cut
        let b = must_batch(cut(&rx, &mut carry, &cfg, &m));
        assert_eq!(b.len(), 1);
    }

    #[test]
    fn retire_mid_assembly_cuts_the_batch_and_retires() {
        let (tx, rx) = sync_channel(8);
        tx.send(req(1)).unwrap();
        tx.send(req(2)).unwrap();
        tx.send(QueueEntry::Retire).unwrap();
        tx.send(req(3)).unwrap(); // behind the sentinel: stays queued
        let cfg = BatcherConfig { max_batch: 8, max_wait: Duration::from_secs(1) };
        let m = Metrics::new();
        let mut carry = None;
        match cut(&rx, &mut carry, &cfg, &m) {
            Cut::Retire(b) => {
                // the assembled batch is executed by the retiring worker —
                // accepted requests are never dropped by a scale-down
                assert_eq!(b.len(), 2);
                assert!(carry.is_none());
            }
            other => panic!("expected Cut::Retire, got {other:?}"),
        }
        let b = must_batch(cut(&rx, &mut carry, &cfg, &m));
        assert_eq!(b.len(), 1);
        assert_eq!(b[0].request.payload, vec![3]);
    }

    #[test]
    fn carried_boundary_survives_a_later_retire() {
        // bulk batch ends on an interactive boundary (carried); the retire
        // sentinel is claimed on the NEXT cut, which still executes the
        // carried request first — retirement can never strand the carry
        let (tx, rx) = sync_channel(8);
        tx.send(classed(1, QosClass::Bulk)).unwrap();
        tx.send(classed(2, QosClass::Interactive)).unwrap();
        tx.send(QueueEntry::Retire).unwrap();
        let cfg = BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(5) };
        let m = Metrics::new();
        let mut carry = None;
        let b1 = must_batch(cut(&rx, &mut carry, &cfg, &m));
        assert_eq!(b1.len(), 1);
        assert!(carry.is_some());
        match cut(&rx, &mut carry, &cfg, &m) {
            Cut::Retire(b2) => {
                assert_eq!(b2.len(), 1, "the carried request leads the retiring cut");
                assert_eq!(b2[0].request.payload, vec![2]);
                assert!(carry.is_none());
            }
            other => panic!("expected Cut::Retire, got {other:?}"),
        }
    }

    #[test]
    fn batches_never_mix_classes() {
        let (tx, rx) = sync_channel(16);
        tx.send(classed(1, QosClass::Bulk)).unwrap();
        tx.send(classed(2, QosClass::Bulk)).unwrap();
        tx.send(classed(3, QosClass::Interactive)).unwrap();
        tx.send(classed(4, QosClass::Interactive)).unwrap();
        let cfg = BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(5) };
        let m = Metrics::new();
        let mut carry = None;
        let b1 = must_batch(cut(&rx, &mut carry, &cfg, &m));
        assert_eq!(b1.len(), 2, "the class boundary must end the batch");
        assert!(b1.iter().all(|p| p.request.class == QosClass::Bulk));
        assert!(carry.is_some(), "the boundary request is carried, not dropped");
        let b2 = must_batch(cut(&rx, &mut carry, &cfg, &m));
        assert_eq!(b2.len(), 2, "the carried request leads the next batch");
        assert!(b2.iter().all(|p| p.request.class == QosClass::Interactive));
        assert!(carry.is_none());
    }

    #[test]
    fn interactive_batches_cut_at_the_latency_posture() {
        let (tx, rx) = sync_channel::<QueueEntry>(4);
        tx.send(classed(1, QosClass::Interactive)).unwrap();
        // a generous throughput-posture wait: Interactive must not pay it
        let cfg = BatcherConfig { max_batch: 100, max_wait: Duration::from_millis(400) };
        let t0 = StdInstant::now();
        let b = must_batch(cut(&rx, &mut None, &cfg, &Metrics::new()));
        assert_eq!(b.len(), 1);
        // budget is 400/8 = 50ms; anything well under 400ms proves the cap
        assert!(
            t0.elapsed() < Duration::from_millis(300),
            "interactive batch waited {:?}",
            t0.elapsed()
        );
    }

    #[test]
    fn sheds_expired_deadline_requests_before_execution() {
        let (tx, rx) = sync_channel(8);
        // deterministic: the deadline is already in the past at cut time
        let (dead, dead_ticket) =
            Request::new(vec![1]).with_deadline(StdInstant::now()).into_pending();
        tx.send(QueueEntry::Req(dead)).unwrap();
        tx.send(req(2)).unwrap();
        let cfg = BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(2) };
        let m = Metrics::new();
        let b = must_batch(cut(&rx, &mut None, &cfg, &m));
        assert_eq!(b.len(), 1, "the expired request must not occupy a batch slot");
        assert_eq!(b[0].request.payload, vec![2]);
        assert_eq!(m.snapshot().shed, 1);
        let err = dead_ticket.wait().unwrap_err().to_string();
        assert!(err.contains("shed"), "{err}");
    }

    #[test]
    fn cancelled_requests_are_never_executed() {
        let (tx, rx) = sync_channel(8);
        let (p1, t1) = Request::new(vec![1]).into_pending();
        let (p2, t2) = Request::new(vec![2]).into_pending();
        t1.cancel(); // cancelled while queued — before the batcher claims it
        tx.send(QueueEntry::Req(p1)).unwrap();
        tx.send(QueueEntry::Req(p2)).unwrap();
        let cfg = BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(2) };
        let m = Metrics::new();
        let b = must_batch(cut(&rx, &mut None, &cfg, &m));
        assert_eq!(b.len(), 1, "the cancelled slot must never reach execution");
        assert_eq!(b[0].request.payload, vec![2]);
        assert_eq!(m.snapshot().cancelled, 1);
        let err = t1.wait().unwrap_err().to_string();
        assert!(err.contains("cancelled"), "{err}");
        drop(b); // t2's entry resolves as dropped, not cancelled
        let err2 = t2.wait().unwrap_err().to_string();
        assert!(err2.contains("dropped"), "{err2}");
    }

    #[test]
    fn carried_request_is_rechecked_for_cancellation() {
        let (tx, rx) = sync_channel(8);
        tx.send(classed(1, QosClass::Bulk)).unwrap();
        let (boundary, boundary_ticket) =
            Request::new(vec![9]).with_class(QosClass::Interactive).into_pending();
        tx.send(QueueEntry::Req(boundary)).unwrap();
        tx.send(classed(2, QosClass::Interactive)).unwrap();
        let cfg = BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(5) };
        let m = Metrics::new();
        let mut carry = None;
        let b1 = must_batch(cut(&rx, &mut carry, &cfg, &m));
        assert_eq!(b1.len(), 1);
        // cancel while it sits in the carry slot
        boundary_ticket.cancel();
        let b2 = must_batch(cut(&rx, &mut carry, &cfg, &m));
        assert_eq!(b2.len(), 1, "the cancelled carry must be shed at the next cut");
        assert_eq!(b2[0].request.payload, vec![2]);
        assert_eq!(m.snapshot().cancelled, 1);
        assert!(boundary_ticket.wait().unwrap_err().to_string().contains("cancelled"));
    }

    #[test]
    fn adaptive_shrinks_wait_when_queue_is_shallow() {
        let base = BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(8) };
        let mut a = AdaptiveBatcher::new(base);
        assert_eq!(a.config().max_wait, base.max_wait);
        a.observe(0);
        assert_eq!(a.config().max_wait, base.max_wait, "one observation must not flip");
        a.observe(1);
        assert_eq!(a.config().max_wait, Duration::from_millis(1), "latency posture after streak");
        assert_eq!(a.config().max_batch, 8, "batch ceiling unchanged");
    }

    #[test]
    fn adaptive_restores_wait_when_queue_is_deep() {
        let base = BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(8) };
        let mut a = AdaptiveBatcher::new(base);
        a.observe(0);
        a.observe(0); // latency posture
        assert!(a.config().max_wait < base.max_wait);
        a.observe(4);
        a.observe(9); // deep streak: throughput posture
        assert_eq!(a.config().max_wait, base.max_wait);
    }

    #[test]
    fn adaptive_middle_depths_decay_streaks() {
        let base = BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(8) };
        let mut a = AdaptiveBatcher::new(base);
        a.observe(1); // shallow (streak 1)
        a.observe(3); // middle: decays
        a.observe(1); // shallow again (streak 1, not 2)
        assert_eq!(a.config().max_wait, base.max_wait, "decayed streak must not flip");
    }
}
