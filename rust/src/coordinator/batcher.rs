//! Dynamic batcher (DESIGN.md S16).
//!
//! Requests accumulate until the batch target is reached or the oldest
//! waiting request has been queued for `max_wait` — the standard
//! latency/throughput trade (vLLM-router style, scaled to TinyML). The
//! batcher runs inside each worker thread: it owns the receive side of the
//! bounded request channel.

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

use super::server::Request;

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Target batch size (usually the session's `preferred_batch`).
    pub max_batch: usize,
    /// Longest a request may wait for peers before the batch is cut.
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(2) }
    }
}

/// Collect the next batch from `rx`.
///
/// Blocks for the first request (or returns `None` when the channel is
/// closed and drained — shutdown). After the first request arrives, keeps
/// pulling until `max_batch` or the first request's age exceeds
/// `max_wait`.
pub fn next_batch(rx: &Receiver<Request>, cfg: &BatcherConfig) -> Option<Vec<Request>> {
    let first = rx.recv().ok()?;
    let deadline = Instant::now() + cfg.max_wait;
    let mut batch = vec![first];
    while batch.len() < cfg.max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(req) => batch.push(req),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    Some(batch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::sync_channel;
    use std::time::Instant as StdInstant;

    fn req(v: i8) -> Request {
        let (tx, _rx) = std::sync::mpsc::channel();
        Request { input: vec![v], enqueued: StdInstant::now(), reply: tx }
    }

    #[test]
    fn cuts_batch_at_max_size() {
        let (tx, rx) = sync_channel(16);
        for i in 0..5 {
            tx.send(req(i)).unwrap();
        }
        let cfg = BatcherConfig { max_batch: 3, max_wait: Duration::from_secs(1) };
        let b = next_batch(&rx, &cfg).unwrap();
        assert_eq!(b.len(), 3);
        let b2 = next_batch(&rx, &cfg).unwrap();
        assert_eq!(b2.len(), 2); // drains the rest after timeout
    }

    #[test]
    fn cuts_batch_at_deadline() {
        let (tx, rx) = sync_channel::<Request>(16);
        tx.send(req(1)).unwrap();
        let cfg = BatcherConfig { max_batch: 100, max_wait: Duration::from_millis(5) };
        let t0 = StdInstant::now();
        let b = next_batch(&rx, &cfg).unwrap();
        assert_eq!(b.len(), 1);
        assert!(t0.elapsed() < Duration::from_millis(500));
    }

    #[test]
    fn returns_none_on_shutdown() {
        let (tx, rx) = sync_channel::<Request>(1);
        drop(tx);
        assert!(next_batch(&rx, &BatcherConfig::default()).is_none());
    }
}
