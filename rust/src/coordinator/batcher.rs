//! Dynamic batcher (DESIGN.md S16).
//!
//! Requests accumulate until the batch target is reached or the oldest
//! waiting request has been queued for `max_wait` — the standard
//! latency/throughput trade (vLLM-router style, scaled to TinyML). The
//! batcher runs inside each worker thread: it owns the receive side of the
//! bounded request channel.
//!
//! [`AdaptiveBatcher`] layers per-replica tuning on top: each worker
//! observes the queue depth at every batch cut (via
//! [`Metrics::outstanding`](super::metrics::Metrics::outstanding)) and
//! moves its own effective `BatcherConfig` between a latency posture
//! (don't hold a lone request hostage for `max_wait`) and a throughput
//! posture (the configured target) — the fleet's replica pools enable it
//! per replica because `preferred_batch` is per-session config.

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

use super::server::Request;

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Target batch size (usually the session's `preferred_batch`).
    pub max_batch: usize,
    /// Longest a request may wait for peers before the batch is cut.
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(2) }
    }
}

/// Collect the next batch from `rx`.
///
/// Blocks for the first request (or returns `None` when the channel is
/// closed and drained — shutdown). After the first request arrives, keeps
/// pulling until `max_batch` or the first request's age exceeds
/// `max_wait`.
pub fn next_batch(rx: &Receiver<Request>, cfg: &BatcherConfig) -> Option<Vec<Request>> {
    let first = rx.recv().ok()?;
    let deadline = Instant::now() + cfg.max_wait;
    let mut batch = vec![first];
    while batch.len() < cfg.max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(req) => batch.push(req),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    Some(batch)
}

/// Per-replica batcher tuning driven by observed queue depth.
///
/// Deterministic rules (unit-tested below):
///
/// * a **deep** observation (queue depth ≥ the configured `max_batch`)
///   after a cut means the replica is throughput-bound: after
///   [`ADAPT_STREAK`] consecutive deep cuts the full `max_wait` is
///   restored so batches fill;
/// * a **shallow** observation (queue depth ≤ 1) means waiting only adds
///   latency: after [`ADAPT_STREAK`] consecutive shallow cuts the wait
///   shrinks to `max_wait / `[`LATENCY_WAIT_DIV`];
/// * anything in between decays both streaks without changing posture.
///
/// `max_batch` itself never exceeds the configured ceiling (which the
/// server already clamps to the session's `preferred_batch`).
#[derive(Clone, Copy, Debug)]
pub struct AdaptiveBatcher {
    base: BatcherConfig,
    current: BatcherConfig,
    deep_streak: u32,
    shallow_streak: u32,
}

/// Consecutive same-sign observations before the posture flips.
pub const ADAPT_STREAK: u32 = 2;
/// Wait divisor in the latency posture.
pub const LATENCY_WAIT_DIV: u32 = 8;

impl AdaptiveBatcher {
    /// Start in the throughput posture (the configured `base`).
    pub fn new(base: BatcherConfig) -> AdaptiveBatcher {
        AdaptiveBatcher { base, current: base, deep_streak: 0, shallow_streak: 0 }
    }

    /// The effective config for the next batch cut.
    pub fn config(&self) -> BatcherConfig {
        self.current
    }

    /// Feed one observation: the queue depth (outstanding requests) seen
    /// right after a batch was cut.
    pub fn observe(&mut self, queue_depth: u64) {
        if queue_depth >= self.base.max_batch as u64 {
            self.deep_streak += 1;
            self.shallow_streak = 0;
        } else if queue_depth <= 1 {
            self.shallow_streak += 1;
            self.deep_streak = 0;
        } else {
            self.deep_streak = self.deep_streak.saturating_sub(1);
            self.shallow_streak = self.shallow_streak.saturating_sub(1);
        }
        if self.deep_streak >= ADAPT_STREAK {
            self.current = self.base;
        } else if self.shallow_streak >= ADAPT_STREAK {
            self.current = BatcherConfig {
                max_batch: self.base.max_batch,
                max_wait: self.base.max_wait / LATENCY_WAIT_DIV,
            };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::sync_channel;
    use std::time::Instant as StdInstant;

    fn req(v: i8) -> Request {
        let (tx, _rx) = std::sync::mpsc::channel();
        Request { input: vec![v], enqueued: StdInstant::now(), reply: tx }
    }

    #[test]
    fn cuts_batch_at_max_size() {
        let (tx, rx) = sync_channel(16);
        for i in 0..5 {
            tx.send(req(i)).unwrap();
        }
        let cfg = BatcherConfig { max_batch: 3, max_wait: Duration::from_secs(1) };
        let b = next_batch(&rx, &cfg).unwrap();
        assert_eq!(b.len(), 3);
        let b2 = next_batch(&rx, &cfg).unwrap();
        assert_eq!(b2.len(), 2); // drains the rest after timeout
    }

    #[test]
    fn cuts_batch_at_deadline() {
        let (tx, rx) = sync_channel::<Request>(16);
        tx.send(req(1)).unwrap();
        let cfg = BatcherConfig { max_batch: 100, max_wait: Duration::from_millis(5) };
        let t0 = StdInstant::now();
        let b = next_batch(&rx, &cfg).unwrap();
        assert_eq!(b.len(), 1);
        assert!(t0.elapsed() < Duration::from_millis(500));
    }

    #[test]
    fn returns_none_on_shutdown() {
        let (tx, rx) = sync_channel::<Request>(1);
        drop(tx);
        assert!(next_batch(&rx, &BatcherConfig::default()).is_none());
    }

    #[test]
    fn adaptive_shrinks_wait_when_queue_is_shallow() {
        let base = BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(8) };
        let mut a = AdaptiveBatcher::new(base);
        assert_eq!(a.config().max_wait, base.max_wait);
        a.observe(0);
        assert_eq!(a.config().max_wait, base.max_wait, "one observation must not flip");
        a.observe(1);
        assert_eq!(a.config().max_wait, Duration::from_millis(1), "latency posture after streak");
        assert_eq!(a.config().max_batch, 8, "batch ceiling unchanged");
    }

    #[test]
    fn adaptive_restores_wait_when_queue_is_deep() {
        let base = BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(8) };
        let mut a = AdaptiveBatcher::new(base);
        a.observe(0);
        a.observe(0); // latency posture
        assert!(a.config().max_wait < base.max_wait);
        a.observe(4);
        a.observe(9); // deep streak: throughput posture
        assert_eq!(a.config().max_wait, base.max_wait);
    }

    #[test]
    fn adaptive_middle_depths_decay_streaks() {
        let base = BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(8) };
        let mut a = AdaptiveBatcher::new(base);
        a.observe(1); // shallow (streak 1)
        a.observe(3); // middle: decays
        a.observe(1); // shallow again (streak 1, not 2)
        assert_eq!(a.config().max_wait, base.max_wait, "decayed streak must not flip");
    }
}
