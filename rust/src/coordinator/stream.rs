//! Streaming affinity lane ([`StreamHost`]): stateful sessions over the
//! replica pool.
//!
//! Request/response serving can spray a model's requests across replicas
//! because every request is self-contained. A stream is not: its verdicts
//! depend on per-session state (the input ring, per-layer pulse states).
//! The affinity rules here keep that sound:
//!
//! * **Pinning** — a stream is assigned one replica at `open` and every
//!   `push` executes there; the batcher is bypassed entirely, so a stream
//!   is never split across replicas (frames of one stream serialize on
//!   its replica; distinct streams on distinct replicas run in parallel).
//! * **Durable truth** — the host keeps its own per-stream [`RingBuffer`]
//!   of the last `window + pulse - 1` frames, written *before* the
//!   replica attempt. Future verdicts are a pure function of ring
//!   contents, so any replica's session state can be rebuilt by replay.
//! * **Health + migration** — replica push failures are counted
//!   (seeded, deterministic injection via [`StreamFault`]); a streak of
//!   [`StreamHostConfig::eject_after`] quarantines the replica. The next
//!   [`StreamHost::tick`] provisions a replacement *first* (mirroring
//!   [`super::fleet::Fleet::tick`]), migrates every pinned stream to it,
//!   then retires the sick replica. A migrated (or failure-desynced)
//!   stream is lazily **re-primed from the host ring** — the boundary
//!   window plus any mid-pulse pending frames — which lands the fresh
//!   session on the same cadence with bit-exact verdicts.
//! * **Lifecycle identity** — every accepted push resolves exactly once:
//!   `completed + shed + cancelled + failed == submitted`, *per stream*
//!   (asserted under seeded chaos by `tests/stream_conformance.rs`).
//!   `shed` = push arrived while the pinned replica sat quarantined
//!   awaiting migration (the frame still enters the host ring — no data
//!   loss); `failed` = the replica attempt itself failed (frame likewise
//!   retained); `cancelled` = push after [`StreamHost::cancel`].

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use anyhow::{bail, Context, Result};

use crate::compiler::plan::CompiledModel;
use crate::compiler::pulse::PulsePlan;
use crate::stream::{RingBuffer, StreamSession};

/// Process-wide stream id source (globally unique, like request ids).
static NEXT_STREAM_ID: AtomicU64 = AtomicU64::new(1);

/// Host policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct StreamHostConfig {
    /// Replicas to provision at start (streams spread by least-loaded).
    pub replicas: usize,
    /// Consecutive push failures that quarantine a replica.
    pub eject_after: u32,
}

impl Default for StreamHostConfig {
    fn default() -> Self {
        StreamHostConfig { replicas: 2, eject_after: 3 }
    }
}

/// Deterministic push-fault schedule: on replica `worker`, every
/// `every`-th push (counted per replica) fails. Seeded chaos for the
/// stress/conformance suites — same schedule, same failures, same
/// verdicts.
#[derive(Clone, Copy, Debug)]
pub struct StreamFault {
    pub worker: usize,
    pub every: u64,
}

/// Outcome of one [`StreamHost::push`] — each maps to exactly one
/// lifecycle lane.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum StreamPush {
    /// Full window + pulse boundary: a verdict (`completed`).
    Verdict(Vec<i8>),
    /// Processed, no verdict yet — warmup or mid-pulse (`completed`).
    Pending,
    /// Stream was cancelled (`cancelled`).
    Closed,
    /// Pinned replica quarantined awaiting migration; frame retained in
    /// the host ring (`shed`).
    Shed,
    /// Replica attempt failed; frame retained, session re-primed from
    /// the ring on the next successful push (`failed`).
    Failed(String),
}

/// Per-stream lifecycle counters (`completed + shed + cancelled +
/// failed == submitted` always).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StreamCounters {
    pub submitted: u64,
    pub completed: u64,
    pub shed: u64,
    pub cancelled: u64,
    pub failed: u64,
    /// Verdicts emitted (a subset of `completed`; outside the identity).
    pub verdicts: u64,
}

impl StreamCounters {
    /// The exactly-once identity.
    pub fn identity_holds(&self) -> bool {
        self.completed + self.shed + self.cancelled + self.failed == self.submitted
    }
}

/// One pinned replica: its stream sessions plus health state.
struct StreamWorker {
    label: String,
    sessions: HashMap<u64, StreamSession>,
    /// Total pushes attempted here (drives the fault schedule).
    pushes: u64,
    consecutive_failures: u32,
    /// Over the failure threshold; sheds pushes until `tick` migrates.
    quarantined: bool,
    /// Migrated away and permanently out of rotation.
    retired: bool,
}

/// The host-side record of one stream (the durable truth).
struct StreamEntry {
    id: u64,
    name: String,
    worker: usize,
    ring: RingBuffer,
    counters: StreamCounters,
    closed: bool,
    /// Replica session is behind the ring (failed/shed push, or fresh
    /// after migration): rebuild it by replay before the next execute.
    needs_reprime: bool,
}

/// Point-in-time view of one stream.
#[derive(Clone, Debug)]
pub struct StreamSnapshot {
    pub id: u64,
    pub name: String,
    pub worker: String,
    pub counters: StreamCounters,
}

/// Point-in-time view of one replica.
#[derive(Clone, Debug)]
pub struct StreamWorkerSnapshot {
    pub label: String,
    pub streams: usize,
    pub pushes: u64,
    pub consecutive_failures: u32,
    pub quarantined: bool,
    pub retired: bool,
}

/// Everything [`StreamHost::snapshot`] reports.
#[derive(Clone, Debug)]
pub struct StreamHostSnapshot {
    pub streams: Vec<StreamSnapshot>,
    pub workers: Vec<StreamWorkerSnapshot>,
}

impl StreamHostSnapshot {
    /// Sum of every *open* stream's counters. Closed streams hand their
    /// final counters back at [`StreamHost::close`] and leave the
    /// snapshot, so this is a point-in-time aggregate, not a lifetime
    /// total — the per-stream identity still holds for every lane shown.
    pub fn totals(&self) -> StreamCounters {
        let mut t = StreamCounters::default();
        for s in &self.streams {
            t.submitted += s.counters.submitted;
            t.completed += s.counters.completed;
            t.shed += s.counters.shed;
            t.cancelled += s.counters.cancelled;
            t.failed += s.counters.failed;
            t.verdicts += s.counters.verdicts;
        }
        t
    }

    /// Workers currently accepting pushes.
    pub fn live_workers(&self) -> usize {
        self.workers.iter().filter(|w| !w.quarantined && !w.retired).count()
    }
}

impl std::fmt::Display for StreamHostSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let t = self.totals();
        write!(
            f,
            "{} streams on {}/{} live workers | pushes {}/{} done ({} shed, {} canc, {} failed), {} verdicts",
            self.streams.len(),
            self.live_workers(),
            self.workers.len(),
            t.completed,
            t.submitted,
            t.shed,
            t.cancelled,
            t.failed,
            t.verdicts,
        )?;
        for s in &self.streams {
            let c = &s.counters;
            write!(
                f,
                "\n    #{} {} @{}: {}/{} done ({} shed, {} canc, {} failed), {} verdicts",
                s.id, s.name, s.worker, c.completed, c.submitted, c.shed, c.cancelled, c.failed, c.verdicts,
            )?;
        }
        Ok(())
    }
}

/// What one health pass did.
#[derive(Clone, Debug, Default)]
pub struct StreamTickReport {
    /// Labels of replicas retired this tick.
    pub ejected: Vec<String>,
    /// Streams migrated to replacement replicas.
    pub migrated: usize,
}

/// Stateful streaming over a pinned replica pool (module docs have the
/// affinity/migration contract). Shareable: all methods take `&self`.
pub struct StreamHost {
    compiled: Arc<CompiledModel>,
    window_rows: usize,
    frame_len: usize,
    pulse_frames: usize,
    eject_after: u32,
    workers: RwLock<Vec<Arc<Mutex<StreamWorker>>>>,
    streams: RwLock<HashMap<u64, Arc<Mutex<StreamEntry>>>>,
    faults: Mutex<Vec<StreamFault>>,
}

impl StreamHost {
    /// Plan (and certify — `V4xx`) the pulse pass once, then provision
    /// the replica pool. Errors if the model has no streamable prefix.
    pub fn start(compiled: Arc<CompiledModel>, cfg: StreamHostConfig) -> Result<StreamHost> {
        if cfg.replicas == 0 {
            bail!("stream host needs at least one replica");
        }
        let plan = PulsePlan::plan(&compiled).context("planning stream host pulse pass")?;
        let workers = (0..cfg.replicas)
            .map(|i| {
                Arc::new(Mutex::new(StreamWorker {
                    label: format!("stream-w{i}"),
                    sessions: HashMap::new(),
                    pushes: 0,
                    consecutive_failures: 0,
                    quarantined: false,
                    retired: false,
                }))
            })
            .collect();
        Ok(StreamHost {
            window_rows: plan.window_rows,
            frame_len: plan.frame_len,
            pulse_frames: plan.pulse_frames,
            eject_after: cfg.eject_after.max(1),
            compiled,
            workers: RwLock::new(workers),
            streams: RwLock::new(HashMap::new()),
            faults: Mutex::new(Vec::new()),
        })
    }

    pub fn window_rows(&self) -> usize {
        self.window_rows
    }

    pub fn frame_len(&self) -> usize {
        self.frame_len
    }

    pub fn pulse_frames(&self) -> usize {
        self.pulse_frames
    }

    /// Install a deterministic fault schedule (before traffic, in tests).
    pub fn inject_fault(&self, fault: StreamFault) {
        self.faults.lock().unwrap().push(fault);
    }

    /// Open a stream: pin it to the least-loaded live replica, provision
    /// its session there, and register the host-side ring. Returns the
    /// globally unique stream id.
    pub fn open(&self, name: impl Into<String>) -> Result<u64> {
        let id = NEXT_STREAM_ID.fetch_add(1, Ordering::Relaxed);
        let workers = self.workers.read().unwrap();
        let widx = workers
            .iter()
            .enumerate()
            .filter(|(_, w)| {
                let w = w.lock().unwrap();
                !w.quarantined && !w.retired
            })
            .min_by_key(|(_, w)| w.lock().unwrap().sessions.len())
            .map(|(i, _)| i)
            .context("no live stream replica")?;
        let session = StreamSession::pulsed(self.compiled.clone())?;
        workers[widx].lock().unwrap().sessions.insert(id, session);
        drop(workers);
        let entry = StreamEntry {
            id,
            name: name.into(),
            worker: widx,
            // boundary window + worst-case mid-pulse pending frames:
            // exactly what a migration re-prime needs
            ring: RingBuffer::new(self.window_rows + self.pulse_frames - 1, self.frame_len),
            counters: StreamCounters::default(),
            closed: false,
            needs_reprime: false,
        };
        self.streams.write().unwrap().insert(id, Arc::new(Mutex::new(entry)));
        Ok(id)
    }

    /// Feed one frame to a stream. Exactly one lifecycle lane is counted
    /// per call; see [`StreamPush`] for the mapping.
    pub fn push(&self, id: u64, frame: &[i8]) -> Result<StreamPush> {
        if frame.len() != self.frame_len {
            bail!("frame length {} != {}", frame.len(), self.frame_len);
        }
        let entry = self
            .streams
            .read()
            .unwrap()
            .get(&id)
            .cloned()
            .with_context(|| format!("unknown stream {id}"))?;
        let mut e = entry.lock().unwrap();
        e.counters.submitted += 1;
        if e.closed {
            e.counters.cancelled += 1;
            return Ok(StreamPush::Closed);
        }
        // durable truth first: the ring sees every accepted frame, so a
        // failed or shed replica attempt loses nothing
        e.ring.push(frame);
        let worker = self.workers.read().unwrap()[e.worker].clone();
        let mut wk = worker.lock().unwrap();
        if wk.quarantined || wk.retired {
            e.counters.shed += 1;
            e.needs_reprime = true;
            return Ok(StreamPush::Shed);
        }
        wk.pushes += 1;
        let injected = {
            let faults = self.faults.lock().unwrap();
            faults.iter().any(|f| f.worker == e.worker && f.every > 0 && wk.pushes % f.every == 0)
        };
        if injected {
            wk.consecutive_failures += 1;
            if wk.consecutive_failures >= self.eject_after {
                wk.quarantined = true;
            }
            e.counters.failed += 1;
            e.needs_reprime = true;
            return Ok(StreamPush::Failed(format!(
                "injected fault on {} (push {})",
                wk.label, wk.pushes
            )));
        }
        let result = if e.needs_reprime {
            self.reprime(&mut e, &mut wk)
        } else {
            let sess = wk.sessions.get_mut(&id).expect("pinned session");
            sess.push(frame)
        };
        match result {
            Ok(v) => {
                wk.consecutive_failures = 0;
                e.needs_reprime = false;
                e.counters.completed += 1;
                match v {
                    Some(out) => {
                        e.counters.verdicts += 1;
                        Ok(StreamPush::Verdict(out))
                    }
                    None => Ok(StreamPush::Pending),
                }
            }
            Err(err) => {
                wk.consecutive_failures += 1;
                if wk.consecutive_failures >= self.eject_after {
                    wk.quarantined = true;
                }
                e.counters.failed += 1;
                e.needs_reprime = true;
                Ok(StreamPush::Failed(err.to_string()))
            }
        }
    }

    /// Rebuild the replica session by replay from the host ring: the
    /// boundary window plus any mid-pulse pending frames (the current
    /// frame is already in the ring, so its own result falls out of the
    /// replay — the final `push` below). Bit-exact by the streaming
    /// contract: verdicts are a pure function of ring contents.
    fn reprime(&self, e: &mut StreamEntry, wk: &mut StreamWorker) -> Result<Option<Vec<i8>>> {
        let mut fresh = StreamSession::pulsed(self.compiled.clone())?;
        let seen = e.ring.seen();
        let w = self.window_rows as u64;
        let feed = if seen < w {
            e.ring.filled()
        } else {
            self.window_rows + ((seen - w) % self.pulse_frames as u64) as usize
        };
        let frames = e.ring.last_frames(feed);
        let mut last = None;
        for f in frames.chunks(self.frame_len) {
            last = fresh.push(f)?;
        }
        wk.sessions.insert(e.id, fresh);
        Ok(last)
    }

    /// Mark a stream cancelled: later pushes count `cancelled` and
    /// return [`StreamPush::Closed`]; `close` reaps it.
    pub fn cancel(&self, id: u64) -> Result<()> {
        let entry = self
            .streams
            .read()
            .unwrap()
            .get(&id)
            .cloned()
            .with_context(|| format!("unknown stream {id}"))?;
        entry.lock().unwrap().closed = true;
        Ok(())
    }

    /// End-of-stream: drop the replica session and the host record,
    /// returning the final counters.
    pub fn close(&self, id: u64) -> Result<StreamCounters> {
        let entry = self
            .streams
            .write()
            .unwrap()
            .remove(&id)
            .with_context(|| format!("unknown stream {id}"))?;
        let e = entry.lock().unwrap();
        let workers = self.workers.read().unwrap();
        if let Some(w) = workers.get(e.worker) {
            w.lock().unwrap().sessions.remove(&id);
        }
        Ok(e.counters)
    }

    /// Health pass: for every quarantined replica, provision a
    /// replacement *first*, migrate its streams (lazy ring re-prime on
    /// their next push), then retire it. Deterministic and synchronous —
    /// the control loop owns the cadence, mirroring `Fleet::tick`.
    pub fn tick(&self) -> StreamTickReport {
        let mut report = StreamTickReport::default();
        let sick: Vec<usize> = {
            let workers = self.workers.read().unwrap();
            workers
                .iter()
                .enumerate()
                .filter(|(_, w)| {
                    let w = w.lock().unwrap();
                    w.quarantined && !w.retired
                })
                .map(|(i, _)| i)
                .collect()
        };
        for widx in sick {
            // provision the replacement before touching the sick replica
            let new_idx = {
                let mut workers = self.workers.write().unwrap();
                let n = workers.len();
                workers.push(Arc::new(Mutex::new(StreamWorker {
                    label: format!("stream-w{n}"),
                    sessions: HashMap::new(),
                    pushes: 0,
                    consecutive_failures: 0,
                    quarantined: false,
                    retired: false,
                })));
                n
            };
            // migrate: repin every stream; state follows via ring replay
            {
                let streams = self.streams.read().unwrap();
                for entry in streams.values() {
                    let mut e = entry.lock().unwrap();
                    if e.worker == widx {
                        e.worker = new_idx;
                        e.needs_reprime = true;
                        report.migrated += 1;
                    }
                }
            }
            // retire the sick replica (sessions die with it)
            let worker = self.workers.read().unwrap()[widx].clone();
            let mut wk = worker.lock().unwrap();
            wk.retired = true;
            wk.sessions.clear();
            report.ejected.push(wk.label.clone());
        }
        report
    }

    pub fn snapshot(&self) -> StreamHostSnapshot {
        let workers = self.workers.read().unwrap();
        let worker_snaps: Vec<StreamWorkerSnapshot> = workers
            .iter()
            .map(|w| {
                let w = w.lock().unwrap();
                StreamWorkerSnapshot {
                    label: w.label.clone(),
                    streams: w.sessions.len(),
                    pushes: w.pushes,
                    consecutive_failures: w.consecutive_failures,
                    quarantined: w.quarantined,
                    retired: w.retired,
                }
            })
            .collect();
        let mut stream_snaps: Vec<StreamSnapshot> = self
            .streams
            .read()
            .unwrap()
            .values()
            .map(|entry| {
                let e = entry.lock().unwrap();
                StreamSnapshot {
                    id: e.id,
                    name: e.name.clone(),
                    worker: worker_snaps
                        .get(e.worker)
                        .map(|w| w.label.clone())
                        .unwrap_or_default(),
                    counters: e.counters,
                }
            })
            .collect();
        stream_snaps.sort_by_key(|s| s.id);
        StreamHostSnapshot { streams: stream_snaps, workers: worker_snaps }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::plan::CompileOptions;
    use crate::util::Prng;

    fn host(cfg: StreamHostConfig) -> StreamHost {
        let m = crate::synth::stream_conv_chain(&mut Prng::new(11), 2);
        let c = CompiledModel::compile(&m, CompileOptions::default()).unwrap();
        StreamHost::start(Arc::new(c), cfg).unwrap()
    }

    /// Direct (uncoordinated) session over the same model — the oracle.
    fn oracle(h: &StreamHost) -> StreamSession {
        StreamSession::pulsed(h.compiled.clone()).unwrap()
    }

    #[test]
    fn pinned_streams_keep_the_lifecycle_identity() {
        let h = host(StreamHostConfig::default());
        let mut rng = Prng::new(21);
        let ids: Vec<u64> = (0..3).map(|i| h.open(format!("s{i}")).unwrap()).collect();
        let frames = h.window_rows() + 3 * h.pulse_frames();
        for _ in 0..frames {
            for &id in &ids {
                let f = rng.i8_vec(h.frame_len());
                assert!(!matches!(h.push(id, &f).unwrap(), StreamPush::Failed(_)));
            }
        }
        let snap = h.snapshot();
        assert_eq!(snap.streams.len(), 3);
        for s in &snap.streams {
            assert!(s.counters.identity_holds(), "{s:?}");
            assert_eq!(s.counters.submitted, frames as u64);
            assert_eq!(s.counters.verdicts, 4); // prime + 3 pulses
        }
        for &id in &ids {
            assert!(h.close(id).unwrap().identity_holds());
        }
    }

    #[test]
    fn host_verdicts_match_a_direct_session() {
        let h = host(StreamHostConfig::default());
        let mut direct = oracle(&h);
        let id = h.open("s").unwrap();
        let mut rng = Prng::new(22);
        for _ in 0..h.window_rows() * 3 {
            let f = rng.i8_vec(h.frame_len());
            let want = direct.push(&f).unwrap();
            match h.push(id, &f).unwrap() {
                StreamPush::Verdict(v) => assert_eq!(Some(v), want),
                StreamPush::Pending => assert_eq!(None, want),
                other => panic!("unexpected {other:?}"),
            }
        }
    }

    #[test]
    fn failed_pushes_recover_bit_exact_via_ring_replay() {
        let h = host(StreamHostConfig { replicas: 1, eject_after: 100 });
        h.inject_fault(StreamFault { worker: 0, every: 7 });
        let mut direct = oracle(&h);
        let id = h.open("s").unwrap();
        let mut rng = Prng::new(23);
        let (mut failed, mut matched) = (0u64, 0u64);
        for _ in 0..h.window_rows() * 4 {
            let f = rng.i8_vec(h.frame_len());
            let want = direct.push(&f).unwrap();
            match h.push(id, &f).unwrap() {
                StreamPush::Verdict(v) => {
                    assert_eq!(Some(v), want);
                    matched += 1;
                }
                StreamPush::Pending => assert_eq!(None, want),
                StreamPush::Failed(_) => failed += 1,
                other => panic!("unexpected {other:?}"),
            }
        }
        assert!(failed > 0, "fault schedule never fired");
        assert!(matched > 1, "no verdicts survived to compare");
        let c = h.close(id).unwrap();
        assert!(c.identity_holds());
        assert_eq!(c.failed, failed);
    }

    #[test]
    fn ejection_migrates_streams_and_verdicts_continue_bit_exact() {
        let h = host(StreamHostConfig { replicas: 1, eject_after: 2 });
        h.inject_fault(StreamFault { worker: 0, every: 1 }); // every push fails
        let mut direct = oracle(&h);
        let id = h.open("s").unwrap();
        let mut rng = Prng::new(24);
        // two failures quarantine w0; one more push sheds
        for _ in 0..2 {
            let f = rng.i8_vec(h.frame_len());
            let _ = direct.push(&f).unwrap();
            assert!(matches!(h.push(id, &f).unwrap(), StreamPush::Failed(_)));
        }
        let f = rng.i8_vec(h.frame_len());
        let _ = direct.push(&f).unwrap();
        assert_eq!(h.push(id, &f).unwrap(), StreamPush::Shed);
        let report = h.tick();
        assert_eq!(report.ejected, vec!["stream-w0".to_string()]);
        assert_eq!(report.migrated, 1);
        // all further pushes land on the replacement, re-primed from the
        // host ring, and every verdict matches the uninterrupted oracle
        let mut verdicts = 0;
        for _ in 0..h.window_rows() * 3 {
            let f = rng.i8_vec(h.frame_len());
            let want = direct.push(&f).unwrap();
            match h.push(id, &f).unwrap() {
                StreamPush::Verdict(v) => {
                    assert_eq!(Some(v), want);
                    verdicts += 1;
                }
                StreamPush::Pending => assert_eq!(None, want),
                other => panic!("unexpected {other:?}"),
            }
        }
        assert!(verdicts > 1);
        let snap = h.snapshot();
        assert!(snap.workers[0].retired);
        assert!(snap.streams[0].counters.identity_holds());
    }

    #[test]
    fn snapshot_totals_aggregate_open_streams_and_render() {
        let h = host(StreamHostConfig::default());
        let a = h.open("left").unwrap();
        let b = h.open("right").unwrap();
        let f = vec![0i8; h.frame_len()];
        for _ in 0..3 {
            h.push(a, &f).unwrap();
        }
        h.push(b, &f).unwrap();
        let snap = h.snapshot();
        let t = snap.totals();
        assert_eq!(t.submitted, 4);
        assert_eq!(t.completed, 4);
        assert!(t.identity_holds());
        assert_eq!(snap.live_workers(), 2);
        let text = format!("{snap}");
        assert!(text.contains("2 streams on 2/2 live workers"), "{text}");
        assert!(text.contains("left"), "{text}");
        assert!(text.contains("right"), "{text}");
        assert!(h.close(a).unwrap().identity_holds());
        assert!(h.close(b).unwrap().identity_holds());
    }

    #[test]
    fn cancelled_streams_count_the_cancelled_lane() {
        let h = host(StreamHostConfig::default());
        let id = h.open("s").unwrap();
        let f = vec![0i8; h.frame_len()];
        assert!(matches!(h.push(id, &f).unwrap(), StreamPush::Pending));
        h.cancel(id).unwrap();
        assert_eq!(h.push(id, &f).unwrap(), StreamPush::Closed);
        let c = h.close(id).unwrap();
        assert!(c.identity_holds());
        assert_eq!(c.cancelled, 1);
        assert!(h.push(id, &f).is_err(), "closed stream must be unknown");
    }
}
