//! Fleet scheduler — heterogeneous replica pools with load-aware dispatch.
//!
//! PR 1's session API made the three executors interchangeable; this
//! module makes them *composable under load*. A [`Fleet`] serves one model
//! from several **replica pools** — each pool a [`Server`]: a group of
//! session replicas sharing a bounded queue, its own
//! [`BatcherConfig`](super::batcher::BatcherConfig) and its own
//! [`Metrics`](super::metrics::Metrics) — so a deployment can mix, say, a
//! PJRT pool (true batched execution, high throughput) with a native
//! MicroFlow pool (lowest single-request latency), the multicore-style
//! parallel dispatch Ariel-ML explores for RIOT targets.
//!
//! Dispatch is **least-outstanding-requests**: every submit reads each
//! pool's `Metrics::outstanding()` (submitted − completed − errors, all
//! existing counters) and enqueues on the least-loaded pool; ties rotate
//! round-robin so an idle fleet still spreads work. Per-replica batcher
//! tuning (`ServerConfig::adaptive`) is on by default for fleet pools:
//! each worker shifts between latency and throughput posture from the
//! queue depth it observes.
//!
//! Session construction for pools typically goes through the warm
//! [`SessionCache`](crate::api::SessionCache): replicas of the same model
//! hash reuse the compiled plan instead of re-running the compiler.

use anyhow::{ensure, Context, Result};

use super::metrics::MetricsSnapshot;
use super::server::{Server, ServerConfig};
use crate::api::Session;
use crate::tensor::quant::QParams;

/// One replica pool spec: a name (shown in metrics), the session replicas
/// (one worker thread each) and the pool's server/batcher configuration.
pub struct PoolSpec {
    pub name: String,
    pub sessions: Vec<Session>,
    pub config: ServerConfig,
}

impl PoolSpec {
    /// Pool with the default config, adaptive batching on.
    pub fn new(name: impl Into<String>, sessions: Vec<Session>) -> PoolSpec {
        let config = ServerConfig { adaptive: true, ..ServerConfig::default() };
        PoolSpec { name: name.into(), sessions, config }
    }

    pub fn config(mut self, config: ServerConfig) -> PoolSpec {
        self.config = config;
        self
    }
}

/// A named running pool.
struct Pool {
    name: String,
    server: Server,
}

/// A multi-pool serving endpoint for one model.
pub struct Fleet {
    pools: Vec<Pool>,
    /// Round-robin cursor for dispatch tie-breaking.
    rr: std::sync::atomic::AtomicUsize,
}

impl Fleet {
    /// Start a fleet over one or more replica pools. All pools must serve
    /// the same model signature (engines and batcher configs may differ).
    pub fn start(pools: Vec<PoolSpec>) -> Result<Fleet> {
        ensure!(!pools.is_empty(), "need at least one pool");
        let mut running = Vec::with_capacity(pools.len());
        for spec in pools {
            let server = Server::start(spec.sessions, spec.config)
                .with_context(|| format!("starting pool {:?}", spec.name))?;
            running.push(Pool { name: spec.name, server });
        }
        let sig = running[0].server.signature().clone();
        for p in &running[1..] {
            ensure!(
                *p.server.signature() == sig,
                "pool {:?} signature diverges from pool {:?}: {:?} vs {:?}",
                p.name,
                running[0].name,
                p.server.signature(),
                sig
            );
        }
        Ok(Fleet { pools: running, rr: std::sync::atomic::AtomicUsize::new(0) })
    }

    /// Wrap an already-running server as a single-pool fleet (the router's
    /// compatibility path).
    pub fn from_server(name: impl Into<String>, server: Server) -> Fleet {
        Fleet {
            pools: vec![Pool { name: name.into(), server }],
            rr: std::sync::atomic::AtomicUsize::new(0),
        }
    }

    pub fn input_qparams(&self) -> QParams {
        self.pools[0].server.input_qparams()
    }

    pub fn output_qparams(&self) -> QParams {
        self.pools[0].server.output_qparams()
    }

    pub fn input_len(&self) -> usize {
        self.pools[0].server.input_len()
    }

    /// Pool names in dispatch order.
    pub fn pool_names(&self) -> Vec<&str> {
        self.pools.iter().map(|p| p.name.as_str()).collect()
    }

    /// Total session replicas across all pools.
    pub fn replicas(&self) -> usize {
        self.pools.iter().map(|p| p.server.replicas()).sum()
    }

    /// Least-outstanding-requests pool selection. Ties rotate through a
    /// round-robin cursor so an idle fleet spreads work across pools
    /// instead of always hammering pool 0.
    fn select_pool(&self) -> &Pool {
        let n = self.pools.len();
        let start = self.rr.fetch_add(1, std::sync::atomic::Ordering::Relaxed) % n;
        let mut best = start;
        let mut best_load = self.pools[start].server.metrics.outstanding();
        for off in 1..n {
            let i = (start + off) % n;
            let load = self.pools[i].server.metrics.outstanding();
            if load < best_load {
                best = i;
                best_load = load;
            }
        }
        &self.pools[best]
    }

    /// Submit a quantized request to the least-loaded pool; returns the
    /// reply channel. Blocks when that pool's queue is full
    /// (backpressure).
    pub fn submit(&self, input: Vec<i8>) -> Result<std::sync::mpsc::Receiver<Result<Vec<i8>>>> {
        self.select_pool().server.submit(input)
    }

    /// Submit and wait (convenience).
    pub fn infer(&self, input: Vec<i8>) -> Result<Vec<i8>> {
        let rx = self.submit(input)?;
        rx.recv().context("worker dropped reply")?
    }

    /// Per-pool and aggregated metrics.
    pub fn snapshot(&self) -> FleetSnapshot {
        let per_pool: Vec<(String, MetricsSnapshot)> =
            self.pools.iter().map(|p| (p.name.clone(), p.server.metrics.snapshot())).collect();
        let mut agg = Totals::default();
        for (_, s) in &per_pool {
            agg.submitted += s.submitted;
            agg.completed += s.completed;
            agg.errors += s.errors;
        }
        FleetSnapshot { totals: agg, per_pool }
    }

    /// Graceful shutdown: every pool drains its queue and joins workers.
    pub fn shutdown(self) {
        for p in self.pools {
            p.server.shutdown();
        }
    }
}

/// Aggregated request counters across pools.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Totals {
    pub submitted: u64,
    pub completed: u64,
    pub errors: u64,
}

/// A point-in-time fleet metrics view.
#[derive(Clone, Debug)]
pub struct FleetSnapshot {
    pub totals: Totals,
    pub per_pool: Vec<(String, MetricsSnapshot)>,
}

impl std::fmt::Display for FleetSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "fleet: {}/{} done ({} err) across {} pools",
            self.totals.completed,
            self.totals.submitted,
            self.totals.errors,
            self.per_pool.len()
        )?;
        for (name, s) in &self.per_pool {
            writeln!(f, "  {name:16} {s}")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{Engine, Session};

    fn tiny_session(engine: Engine, paging: bool) -> Session {
        Session::builder(crate::format::mfb::tests::tiny_mfb())
            .engine(engine)
            .paging(paging)
            .build()
            .unwrap()
    }

    fn two_pool_fleet() -> Fleet {
        Fleet::start(vec![
            PoolSpec::new("native", vec![tiny_session(Engine::MicroFlow, false)]),
            PoolSpec::new("interp", vec![tiny_session(Engine::Interp, false)]),
        ])
        .unwrap()
    }

    #[test]
    fn dispatches_and_answers_within_engine_tolerance() {
        let f = two_pool_fleet();
        assert_eq!(f.pool_names(), vec!["native", "interp"]);
        assert_eq!(f.replicas(), 2);
        for _ in 0..20 {
            let out = f.infer(vec![3, 1]).unwrap();
            // engines agree within ±1 (paper Sec. 6.2.1)
            for (got, want) in out.iter().zip(&[2i8, 0, 5]) {
                assert!((*got as i32 - *want as i32).abs() <= 1, "{out:?}");
            }
        }
        let snap = f.snapshot();
        assert_eq!(snap.totals.submitted, 20);
        assert_eq!(snap.totals.completed, 20);
        assert_eq!(snap.totals.errors, 0);
        f.shutdown();
    }

    #[test]
    fn round_robin_tiebreak_spreads_an_idle_fleet() {
        // sequential round trips leave every pool idle at submit time —
        // outstanding ties at 0, so the cursor must alternate pools
        let f = two_pool_fleet();
        for _ in 0..10 {
            f.infer(vec![3, 1]).unwrap();
        }
        let snap = f.snapshot();
        for (name, s) in &snap.per_pool {
            assert_eq!(s.submitted, 5, "pool {name} got {} of 10", s.submitted);
        }
        f.shutdown();
    }

    #[test]
    fn start_validates_pool_layout() {
        // agreeing signatures across differently-configured pools: ok
        let ok = Fleet::start(vec![
            PoolSpec::new("a", vec![tiny_session(Engine::MicroFlow, false)]),
            PoolSpec::new("b", vec![tiny_session(Engine::MicroFlow, true)]),
        ]);
        assert!(ok.is_ok());
        ok.unwrap().shutdown();
        // an empty fleet is rejected
        assert!(Fleet::start(vec![]).is_err());
        // an empty pool is rejected (by the pool's own Server::start)
        assert!(Fleet::start(vec![PoolSpec::new("empty", vec![])]).is_err());
    }

    #[test]
    fn single_pool_fleet_wraps_a_server() {
        let server =
            Server::start(vec![tiny_session(Engine::MicroFlow, false)], ServerConfig::default())
                .unwrap();
        let f = Fleet::from_server("solo", server);
        assert_eq!(f.infer(vec![3, 1]).unwrap(), vec![2, 0, 5]);
        f.shutdown();
    }
}
