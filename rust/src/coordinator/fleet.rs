//! Fleet scheduler — heterogeneous replica pools with SLO-aware dispatch.
//!
//! PR 1's session API made the three executors interchangeable; this
//! module makes them *composable under load*. A [`Fleet`] serves one model
//! from several **replica pools** — each pool a [`Server`]: a group of
//! session replicas sharing a bounded queue, its own
//! [`BatcherConfig`](super::batcher::BatcherConfig) and its own
//! [`Metrics`](super::metrics::Metrics) — so a deployment can mix, say, a
//! PJRT pool (true batched execution, high throughput) with a native
//! MicroFlow pool (lowest single-request latency), the multicore-style
//! parallel dispatch Ariel-ML explores for RIOT targets.
//!
//! Dispatch is **class-aware, then load-aware**. Each [`PoolSpec`]
//! declares a [`QosProfile`] (native → Interactive-preferred, PJRT/interp
//! → Bulk; [`QosProfile::Any`] by default). A request's
//! [`QosClass`](super::request::QosClass) selects the candidate set in
//! tiers — pools preferring the class, else `Any` pools, else every pool —
//! and **least-outstanding-requests** picks within that set: every submit
//! reads each candidate's `Metrics::outstanding()` (submitted − resolved)
//! and enqueues on the least-loaded pool; ties rotate round-robin so an
//! idle fleet still spreads work. With all pools at the default `Any`
//! profile this degenerates to the PR 2 pure load balancing.
//!
//! `try_submit` adds explicit backpressure with spill: candidates are
//! tried in load order and a request only fails with
//! [`SubmitError::QueueFull`] when *every* candidate queue is full.
//!
//! Per-replica batcher tuning (`ServerConfig::adaptive`) is on by default
//! for fleet pools. Session construction for pools typically goes through
//! the warm [`SessionCache`](crate::api::SessionCache): replicas of the
//! same model hash reuse the compiled plan instead of re-running the
//! compiler.
//!
//! **Autoscaling** (PR 5): a pool declared with
//! [`PoolSpec::autoscale`] carries an
//! [`AutoscalePolicy`](super::autoscale::AutoscalePolicy) and a warm
//! [`ReplicaFactory`]. [`Fleet::tick`] is the control loop body: per
//! pool, it consumes the metrics window
//! ([`Metrics::window`](super::metrics::Metrics::window) — tick is the
//! window's single consumer), steps the pure policy, and applies the
//! decision through the elastic server (`add_replica` from the factory /
//! `remove_replica` via the drain sentinel). Every decision is exposed in
//! [`FleetSnapshot`] (per-pool replica count, last action, reason). The
//! caller picks the cadence — the CLI's serve loop, the bench's phase
//! loop, and the tests each drive `tick()` explicitly, which is what
//! keeps the controller deterministic.
//!
//! **Fault tolerance** (PR 8): `tick()` also drives the pool's
//! resilience policies ([`resilience`](super::resilience)), both on by
//! default. The **health pass** asks the [`HealthPolicy`] which live
//! replicas look wedged or error-prone, provisions a warm replacement
//! through the pool's factory *first*, then ejects the sick replica via
//! [`Server::eject_replica`] — the pool never dips below its floor, and
//! an ejection that cannot be backed by a replacement simply does not
//! happen (it needs an autoscaled pool; static pools track health but
//! never eject). The **circuit breaker** steps once per tick on the same
//! consumed window (`resolved = completed + failed`; admission sheds are
//! deliberately excluded so the breaker's own brownout cannot hold it
//! open) and mirrors its state into a lock-free atomic that the admission
//! path reads: while a pool's breaker is **open**, Background and Bulk
//! requests are shed *at admission* (counted `submitted` + `shed`,
//! resolved with [`SubmitError::BreakerOpen`]) while Interactive traffic
//! still flows and doubles as the probe. When several pools exist,
//! dispatch simply skips open pools for background work and only sheds
//! when no admitting candidate remains.

use std::sync::atomic::{AtomicU8, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{ensure, Context, Result};

use super::autoscale::{
    AutoscalePolicy, AutoscaleStatus, Decision, PolicyState, ScaleAction, ScaleReason, TickSignals,
};
use super::metrics::{MetricsSnapshot, ReplicaHealthSnapshot, WindowConsumer, WindowSnapshot};
use super::request::{QosClass, QosProfile, Request, SubmitError, Ticket};
use super::resilience::{BreakerCore, BreakerPolicy, BreakerState, HealthPolicy};
use super::server::{Server, ServerConfig};
use super::stream::{StreamHost, StreamHostSnapshot};
use crate::api::{ReplicaFactory, Session};
use crate::observe::{SpanWindow, StepProfileRow};
use crate::tensor::quant::QParams;

/// One replica pool spec: a name (shown in metrics), the session replicas
/// (one worker thread each), the pool's server/batcher configuration, its
/// declared traffic profile, and (optionally) its autoscaler.
pub struct PoolSpec {
    pub name: String,
    pub sessions: Vec<Session>,
    pub config: ServerConfig,
    pub profile: QosProfile,
    pub autoscale: Option<(AutoscalePolicy, Arc<ReplicaFactory>)>,
    /// Circuit breaker thresholds; `None` disables breaking. On by
    /// default with [`BreakerPolicy`]'s defaults.
    pub breaker: Option<BreakerPolicy>,
    /// Replica ejection thresholds; `None` disables the health pass. On
    /// by default (ejection itself additionally requires an autoscaled
    /// pool — replacements come from its factory).
    pub health: Option<HealthPolicy>,
}

impl PoolSpec {
    /// Pool with the default config: adaptive batching on, no declared
    /// traffic affinity ([`QosProfile::Any`]), no autoscaler, default
    /// circuit-breaker and replica-health policies.
    pub fn new(name: impl Into<String>, sessions: Vec<Session>) -> PoolSpec {
        let config = ServerConfig { adaptive: true, ..ServerConfig::default() };
        PoolSpec {
            name: name.into(),
            sessions,
            config,
            profile: QosProfile::Any,
            autoscale: None,
            breaker: Some(BreakerPolicy::new()),
            health: Some(HealthPolicy::new()),
        }
    }

    pub fn config(mut self, config: ServerConfig) -> PoolSpec {
        self.config = config;
        self
    }

    /// Declare the pool's traffic affinity (see
    /// [`QosProfile::for_engine`] for the natural per-engine choice).
    pub fn profile(mut self, profile: QosProfile) -> PoolSpec {
        self.profile = profile;
        self
    }

    /// Make the pool elastic: [`Fleet::tick`] will grow it through
    /// `factory` and shrink it via graceful drain, within `policy`'s
    /// bounds.
    pub fn autoscale(mut self, policy: AutoscalePolicy, factory: Arc<ReplicaFactory>) -> PoolSpec {
        self.autoscale = Some((policy, factory));
        self
    }

    /// Replace the default circuit-breaker thresholds.
    pub fn breaker(mut self, policy: BreakerPolicy) -> PoolSpec {
        self.breaker = Some(policy);
        self
    }

    /// Disable circuit breaking for this pool (every class always
    /// admitted, whatever the error rate).
    pub fn no_breaker(mut self) -> PoolSpec {
        self.breaker = None;
        self
    }

    /// Replace the default replica-health thresholds.
    pub fn health(mut self, policy: HealthPolicy) -> PoolSpec {
        self.health = Some(policy);
        self
    }

    /// Disable health-driven ejection for this pool.
    pub fn no_health(mut self) -> PoolSpec {
        self.health = None;
        self
    }
}

/// A pool's controller: the policy, its state, the replica supply, and
/// the last applied decision (for snapshots).
struct PoolScaler {
    policy: AutoscalePolicy,
    state: PolicyState,
    factory: Arc<ReplicaFactory>,
    ticks: u64,
    last: Option<Decision>,
}

/// A named running pool.
struct Pool {
    name: String,
    profile: QosProfile,
    server: Server,
    scaler: Option<Mutex<PoolScaler>>,
    /// Breaker thresholds + state machine (stepped only by `tick()`).
    breaker: Option<(BreakerPolicy, Mutex<BreakerCore>)>,
    /// Lock-free mirror of the breaker state for the admission hot path
    /// (stored by `tick()`, read by every submit).
    breaker_state: AtomicU8,
    health: Option<HealthPolicy>,
    /// The claim on this pool's single-consumer metrics window cursor —
    /// `tick()` drains it through this token and nothing else may.
    window_consumer: WindowConsumer,
}

impl Pool {
    /// The breaker state admission currently sees.
    fn breaker_now(&self) -> BreakerState {
        BreakerState::from_u8(self.breaker_state.load(Ordering::Relaxed))
    }

    /// Whether admission accepts `class` right now: an open breaker sheds
    /// Background and Bulk, never Interactive (the probe traffic).
    fn admits(&self, class: QosClass) -> bool {
        class == QosClass::Interactive || self.breaker_now().admits_background_work()
    }
}

/// A multi-pool serving endpoint for one model.
pub struct Fleet {
    pools: Vec<Pool>,
    /// Round-robin cursor for dispatch tie-breaking.
    rr: std::sync::atomic::AtomicUsize,
    /// Stream hosts attached for observability: their per-stream counters
    /// ride along in [`FleetSnapshot::streams`]. Purely read-side — the
    /// fleet never drives a host's control loop.
    stream_hosts: Mutex<Vec<(String, Arc<StreamHost>)>>,
}

impl Fleet {
    /// Start a fleet over one or more replica pools. All pools must serve
    /// the same model signature (engines, profiles and batcher configs may
    /// differ).
    pub fn start(pools: Vec<PoolSpec>) -> Result<Fleet> {
        ensure!(!pools.is_empty(), "need at least one pool");
        let mut running = Vec::with_capacity(pools.len());
        for spec in pools {
            let server = Server::start(spec.sessions, spec.config)
                .with_context(|| format!("starting pool {:?}", spec.name))?;
            let scaler = spec.autoscale.map(|(policy, factory)| {
                Mutex::new(PoolScaler {
                    policy,
                    state: PolicyState::default(),
                    factory,
                    ticks: 0,
                    last: None,
                })
            });
            let breaker = spec.breaker.map(|p| (p, Mutex::new(BreakerCore::new())));
            let window_consumer = server.metrics.window_consumer();
            running.push(Pool {
                name: spec.name,
                profile: spec.profile,
                server,
                scaler,
                breaker,
                breaker_state: AtomicU8::new(BreakerState::Closed.as_u8()),
                health: spec.health,
                window_consumer,
            });
        }
        let sig = running[0].server.signature().clone();
        for p in &running[1..] {
            ensure!(
                *p.server.signature() == sig,
                "pool {:?} signature diverges from pool {:?}: {:?} vs {:?}",
                p.name,
                running[0].name,
                p.server.signature(),
                sig
            );
        }
        Ok(Fleet {
            pools: running,
            rr: std::sync::atomic::AtomicUsize::new(0),
            stream_hosts: Mutex::new(Vec::new()),
        })
    }

    /// Attach a stream host so its per-stream counters surface in
    /// [`Fleet::snapshot`] (under `label`). Observability-only: the fleet
    /// reads `host.snapshot()` and nothing else.
    pub fn attach_stream_host(&self, label: impl Into<String>, host: Arc<StreamHost>) {
        self.stream_hosts.lock().unwrap().push((label.into(), host));
    }

    /// Wrap an already-running server as a single-pool fleet (the router's
    /// compatibility path).
    pub fn from_server(name: impl Into<String>, server: Server) -> Fleet {
        let window_consumer = server.metrics.window_consumer();
        Fleet {
            pools: vec![Pool {
                name: name.into(),
                profile: QosProfile::Any,
                server,
                scaler: None,
                // the compatibility wrapper adds no control-plane behavior
                breaker: None,
                breaker_state: AtomicU8::new(BreakerState::Closed.as_u8()),
                health: None,
                window_consumer,
            }],
            rr: std::sync::atomic::AtomicUsize::new(0),
            stream_hosts: Mutex::new(Vec::new()),
        }
    }

    pub fn input_qparams(&self) -> QParams {
        self.pools[0].server.input_qparams()
    }

    pub fn output_qparams(&self) -> QParams {
        self.pools[0].server.output_qparams()
    }

    pub fn input_len(&self) -> usize {
        self.pools[0].server.input_len()
    }

    /// Pool names in dispatch order.
    pub fn pool_names(&self) -> Vec<&str> {
        self.pools.iter().map(|p| p.name.as_str()).collect()
    }

    /// Total session replicas across all pools.
    pub fn replicas(&self) -> usize {
        self.pools.iter().map(|p| p.server.replicas()).sum()
    }

    /// The candidate pool set for a class, in declaration order. Tiered:
    /// pools whose profile *prefers* the class win outright; otherwise
    /// undeclared ([`QosProfile::Any`]) pools; otherwise every pool (a
    /// fleet of pure specialists still serves everything).
    fn candidates(&self, class: QosClass) -> Vec<usize> {
        let preferred: Vec<usize> = (0..self.pools.len())
            .filter(|&i| self.pools[i].profile.prefers(class))
            .collect();
        if !preferred.is_empty() {
            return preferred;
        }
        let any: Vec<usize> = (0..self.pools.len())
            .filter(|&i| self.pools[i].profile == QosProfile::Any)
            .collect();
        if !any.is_empty() {
            return any;
        }
        (0..self.pools.len()).collect()
    }

    /// Dispatch sort key for pool `i` under `class`: the tier rank first
    /// (preferring pools win outright, then `Any`, then the rest — the
    /// same tiers as [`Fleet::candidates`]), load within the tier.
    fn pool_key(&self, i: usize, class: QosClass) -> (u8, u64) {
        let p = &self.pools[i];
        let rank = if p.profile.prefers(class) {
            0
        } else if p.profile == QosProfile::Any {
            1
        } else {
            2
        };
        (rank, p.server.metrics.outstanding())
    }

    /// Pick the pool for one submit: a single rotated scan for the
    /// lexicographically smallest `(tier rank, outstanding)` key, ties
    /// keeping the round-robin rotation so an idle fleet still spreads
    /// work. Allocation-free — this is the per-request hot path.
    fn select_pool(&self, class: QosClass) -> usize {
        let n = self.pools.len();
        let start = self.rr.fetch_add(1, std::sync::atomic::Ordering::Relaxed) % n;
        let mut best = start;
        let mut best_key = self.pool_key(start, class);
        for off in 1..n {
            let i = (start + off) % n;
            let key = self.pool_key(i, class);
            if key < best_key {
                best = i;
                best_key = key;
            }
        }
        best
    }

    /// Candidate pools for `class` in spill order: the candidate tier
    /// rotated by the round-robin cursor, then stably sorted by load (ties
    /// keep the rotation). Only the `try_submit` spill path pays for the
    /// full ordering; blocking submits use the allocation-free
    /// [`Fleet::select_pool`] scan.
    fn dispatch_order(&self, class: QosClass) -> Vec<usize> {
        let cand = self.candidates(class);
        let n = cand.len();
        let start = self.rr.fetch_add(1, std::sync::atomic::Ordering::Relaxed) % n;
        let mut order: Vec<usize> = (0..n).map(|off| cand[(start + off) % n]).collect();
        // stable sort over loads sampled once: equal loads preserve the
        // rotated order (the tiebreak), and the comparator stays total
        // even while workers drain queues concurrently
        order.sort_by_cached_key(|&i| self.pools[i].server.metrics.outstanding());
        order
    }

    /// Like [`Fleet::select_pool`], restricted to pools whose breaker
    /// admits the class; `None` when every pool is browned out for it.
    fn select_admitting_pool(&self, class: QosClass) -> Option<usize> {
        let n = self.pools.len();
        let start = self.rr.fetch_add(1, Ordering::Relaxed) % n;
        let mut best: Option<(usize, (u8, u64))> = None;
        for off in 0..n {
            let i = (start + off) % n;
            if !self.pools[i].admits(class) {
                continue;
            }
            let key = self.pool_key(i, class);
            if best.map_or(true, |(_, bk)| key < bk) {
                best = Some((i, key));
            }
        }
        best.map(|(i, _)| i)
    }

    /// Resolve a browned-out request: count it `submitted` + `shed` on
    /// the pool dispatch would have chosen (the accounting identity stays
    /// exact — the request is resolved, not handed back) and produce the
    /// typed admission error.
    fn shed_at_admission(&self, req: Request) -> SubmitError {
        let i = self.select_pool(req.class);
        let pool = &self.pools[i];
        pool.server.metrics.record_submitted(req.class);
        pool.server.metrics.record_shed(req.class);
        SubmitError::BreakerOpen { id: req.id, class: req.class, pool: pool.name.clone() }
    }

    /// Submit a typed request to the best-matching, least-loaded pool
    /// whose breaker admits it; returns its [`Ticket`]. Blocks when that
    /// pool's queue is full (backpressure) — use [`Fleet::try_submit`] to
    /// spill instead. With every pool browned out for the class, the
    /// request is shed at admission ([`SubmitError::BreakerOpen`]).
    pub fn submit(&self, req: Request) -> Result<Ticket> {
        match self.select_admitting_pool(req.class) {
            Some(i) => self.pools[i].server.submit(req),
            None => Err(self.shed_at_admission(req).into()),
        }
    }

    /// Non-blocking submit with spill: admitting candidates are tried in
    /// load order and the request only comes back as
    /// [`SubmitError::QueueFull`] (or [`SubmitError::Shutdown`], if a
    /// shut-down pool was hit) when every candidate rejected it — the
    /// payload is always handed back. With every candidate browned out,
    /// the request is shed at admission instead
    /// ([`SubmitError::BreakerOpen`] — resolved, not handed back).
    pub fn try_submit(&self, mut req: Request) -> std::result::Result<Ticket, SubmitError> {
        let mut saw_shutdown = false;
        let order: Vec<usize> = self
            .dispatch_order(req.class)
            .into_iter()
            .filter(|&i| self.pools[i].admits(req.class))
            .collect();
        if order.is_empty() {
            return Err(self.shed_at_admission(req));
        }
        for i in order {
            match self.pools[i].server.try_submit(req) {
                Ok(ticket) => return Ok(ticket),
                // spill to the next candidate in both rejection cases
                Err(SubmitError::QueueFull(r)) => req = r,
                Err(SubmitError::Shutdown(r)) => {
                    saw_shutdown = true;
                    req = r;
                }
                Err(e) => return Err(e),
            }
        }
        if saw_shutdown {
            Err(SubmitError::Shutdown(req))
        } else {
            Err(SubmitError::QueueFull(req))
        }
    }

    /// Submit and wait (blocking convenience; Bulk class, no deadline —
    /// the legacy semantics).
    pub fn infer(&self, input: Vec<i8>) -> Result<Vec<i8>> {
        self.submit(Request::new(input))?.wait()
    }

    /// One autoscaler control step across all pools — the body of the
    /// deployment's tick loop (the caller picks the cadence). Per pool:
    /// consume the metrics window (tick is the window's single consumer),
    /// step the policy, apply the decision through the elastic server,
    /// and report what happened. Static pools (no
    /// [`PoolSpec::autoscale`]) still consume and report their window but
    /// never act.
    ///
    /// A scale-up provisions replicas through the pool's
    /// [`ReplicaFactory`]; if provisioning fails mid-step the partial
    /// progress is kept and the decision is reported as
    /// [`ScaleReason::ProvisionFailed`]. A scale-down enqueues one drain
    /// sentinel per retired replica — accepted requests are never dropped
    /// (see the server drain protocol).
    /// Per-pool autoscale + health-ejection step (everything that needs
    /// the scaler lock). Returns the consumed window, the applied
    /// decision (`None` for static pools), and the labels ejected.
    fn tick_control(&self, p: &Pool) -> (WindowSnapshot, Option<Decision>, Vec<String>) {
        let Some(scaler) = &p.scaler else {
            // static pool: nothing can act, so the window needs no lock
            // (concurrent tick() callers were always the caller's bug —
            // the window cursor is single-consumer by contract)
            return (p.server.metrics.window(&p.window_consumer), None, Vec::new());
        };
        let mut guard = scaler.lock().unwrap();
        // consume the window only under the scaler lock: two
        // concurrent tick() callers would otherwise each see half
        // of one window's deltas and could both miss a breach
        let window = p.server.metrics.window(&p.window_consumer);
        let PoolScaler { policy, state, factory, ticks, last } = &mut *guard;
        let signals = TickSignals::observe(
            &window,
            p.server.metrics.outstanding(),
            p.server.live_replicas(),
        );
        let decision = state.step(policy, &signals);
        let applied = match decision.action {
            ScaleAction::Up(want) => {
                let mut added = 0;
                for _ in 0..want {
                    let ok = factory
                        .provision()
                        .and_then(|sess| p.server.add_replica(sess))
                        .is_ok();
                    if !ok {
                        break;
                    }
                    added += 1;
                }
                if added == 0 {
                    Decision { action: ScaleAction::Hold, reason: ScaleReason::ProvisionFailed }
                } else {
                    Decision { action: ScaleAction::Up(added), reason: decision.reason }
                }
            }
            ScaleAction::Down(want) => {
                let mut removed = 0;
                for _ in 0..want {
                    if p.server.remove_replica().is_err() {
                        break;
                    }
                    removed += 1;
                }
                if removed == 0 {
                    Decision { action: ScaleAction::Hold, reason: ScaleReason::AtMin }
                } else {
                    Decision { action: ScaleAction::Down(removed), reason: decision.reason }
                }
            }
            ScaleAction::Hold => decision,
        };
        // health pass, still under the scaler lock (the per-replica
        // windows drained by `unhealthy` are single-consumer, and the
        // replacements come from this scaler's factory)
        let mut ejected = Vec::new();
        if let Some(hp) = &p.health {
            for label in hp.unhealthy(&p.server.metrics.replica_handles()) {
                // replacement FIRST, then ejection: the pool never dips
                // below its floor, and a sick replica outlives a failed
                // provision rather than shrinking the pool
                match factory.provision().and_then(|sess| p.server.add_replica(sess)) {
                    Ok(()) => match p.server.eject_replica(&label) {
                        Ok(()) => ejected.push(label),
                        // raced (e.g. the replica died fatally between the
                        // health read and here): undo the extra replica
                        Err(_) => {
                            let _ = p.server.remove_replica();
                        }
                    },
                    Err(_) => break,
                }
            }
        }
        *ticks += 1;
        *last = Some(applied);
        (window, Some(applied), ejected)
    }

    pub fn tick(&self) -> Vec<PoolTickReport> {
        self.pools
            .iter()
            .map(|p| {
                let (window, decision, ejected) = self.tick_control(p);
                // breaker step on the SAME consumed window, then publish
                // the state to the lock-free admission mirror
                let breaker = p.breaker.as_ref().map(|(policy, core)| {
                    let mut core = core.lock().unwrap();
                    let state = core.step(policy, window.resolved(), window.failed());
                    p.breaker_state.store(state.as_u8(), Ordering::Relaxed);
                    state
                });
                PoolTickReport {
                    pool: p.name.clone(),
                    live_replicas: p.server.live_replicas(),
                    decision,
                    breaker,
                    ejected,
                    window,
                    // tick is also the span rings' single drain point: the
                    // exposition tier only ever sees already-drained data
                    spans: p.server.metrics.spans.drain_window(),
                    profile: p.server.metrics.step_profile().rows(p.server.step_kinds()),
                }
            })
            .collect()
    }

    /// Per-pool and aggregated metrics.
    pub fn snapshot(&self) -> FleetSnapshot {
        let per_pool: Vec<PoolSnapshot> = self
            .pools
            .iter()
            .map(|p| PoolSnapshot {
                name: p.name.clone(),
                profile: p.profile,
                replicas: p.server.replicas(),
                retiring: p.server.retiring(),
                autoscale: p.scaler.as_ref().map(|s| {
                    let s = s.lock().unwrap();
                    AutoscaleStatus {
                        min_replicas: s.policy.min_replicas,
                        max_replicas: s.policy.max_replicas,
                        ticks: s.ticks,
                        last: s.last,
                    }
                }),
                breaker: p.breaker.as_ref().map(|_| p.breaker_now()),
                replica_health: p.server.metrics.replica_health(),
                metrics: p.server.metrics.snapshot(),
            })
            .collect();
        let mut agg = Totals::default();
        for p in &per_pool {
            agg.submitted += p.metrics.submitted;
            agg.completed += p.metrics.completed;
            agg.failed += p.metrics.failed;
            agg.retried += p.metrics.retried;
            agg.shed += p.metrics.shed;
            agg.cancelled += p.metrics.cancelled;
            agg.deadline_missed += p.metrics.deadline_missed;
        }
        let streams = self
            .stream_hosts
            .lock()
            .unwrap()
            .iter()
            .map(|(label, host)| (label.clone(), host.snapshot()))
            .collect();
        FleetSnapshot { totals: agg, per_pool, streams }
    }

    /// Graceful shutdown: every pool drains its queue and joins workers.
    /// Pools drain **concurrently** (one closer thread each, joined at
    /// the end), so shutdown latency is bounded by the slowest pool's
    /// backlog rather than the sum of all pools'.
    pub fn shutdown(self) {
        let closers: Vec<_> = self
            .pools
            .into_iter()
            .map(|p| std::thread::spawn(move || p.server.shutdown()))
            .collect();
        for c in closers {
            let _ = c.join();
        }
    }
}

/// Aggregated request-lifecycle counters across pools. The identity
/// `completed + shed + cancelled + failed == submitted` holds fleet-wide
/// once all tickets have resolved; `retried` and `deadline_missed` are
/// observations outside the identity (a retried request is still
/// outstanding; a late request still completed).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Totals {
    pub submitted: u64,
    pub completed: u64,
    pub failed: u64,
    pub retried: u64,
    pub shed: u64,
    pub cancelled: u64,
    pub deadline_missed: u64,
}

/// One pool's report from a [`Fleet::tick`] control step.
#[derive(Debug)]
pub struct PoolTickReport {
    pub pool: String,
    /// Committed live replicas after this tick's action.
    pub live_replicas: usize,
    /// The decision applied (`None` for pools without an autoscaler).
    pub decision: Option<Decision>,
    /// Breaker state after this tick (`None` when breaking is disabled).
    pub breaker: Option<BreakerState>,
    /// Replicas the health pass ejected (and replaced) this tick.
    pub ejected: Vec<String>,
    /// The metrics window this tick consumed (rates, windowed p95).
    pub window: WindowSnapshot,
    /// Span events drained from the pool's rings by this tick (per-phase
    /// × per-class counts, plus any overwrite loss — never silent).
    pub spans: SpanWindow,
    /// The pool's cumulative per-step kernel profile, one row per plan
    /// step (empty unless the pool runs with `ServerConfig::profile`).
    pub profile: Vec<StepProfileRow>,
}

impl PoolTickReport {
    /// Did this tick change the pool's size or membership?
    pub fn acted(&self) -> bool {
        self.decision.is_some_and(|d| d.action != ScaleAction::Hold) || !self.ejected.is_empty()
    }
}

impl std::fmt::Display for PoolTickReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "[{}] x{}", self.pool, self.live_replicas)?;
        if let Some(d) = self.decision {
            write!(f, " {d}")?;
        }
        if let Some(b) = self.breaker {
            if b != BreakerState::Closed {
                write!(f, " breaker={b}")?;
            }
        }
        for label in &self.ejected {
            write!(f, " ejected={label}")?;
        }
        write!(f, " | {}", self.window)
    }
}

/// One pool's slice of a [`FleetSnapshot`].
#[derive(Clone, Debug)]
pub struct PoolSnapshot {
    pub name: String,
    pub profile: QosProfile,
    /// Worker threads currently running (retiring workers count until
    /// their drain completes).
    pub replicas: usize,
    /// Retire sentinels still draining.
    pub retiring: usize,
    /// Autoscaler bounds + last decision, for elastic pools.
    pub autoscale: Option<AutoscaleStatus>,
    /// Breaker state at snapshot time (`None` when breaking is disabled).
    pub breaker: Option<BreakerState>,
    /// Every replica ever registered on this pool, with its phase and
    /// lifetime batch/failure counts (ejected and dead ones included —
    /// the registry is the pool's incident log).
    pub replica_health: Vec<ReplicaHealthSnapshot>,
    pub metrics: MetricsSnapshot,
}

impl PoolSnapshot {
    /// Committed steady-state replica count (running minus mid-drain).
    pub fn live_replicas(&self) -> usize {
        self.replicas.saturating_sub(self.retiring)
    }
}

/// A point-in-time fleet metrics view.
#[derive(Clone, Debug)]
pub struct FleetSnapshot {
    pub totals: Totals,
    pub per_pool: Vec<PoolSnapshot>,
    /// Attached stream hosts' per-stream counters, labelled as attached
    /// (empty unless [`Fleet::attach_stream_host`] was called).
    pub streams: Vec<(String, StreamHostSnapshot)>,
}

impl FleetSnapshot {
    pub fn pool(&self, name: &str) -> Option<&PoolSnapshot> {
        self.per_pool.iter().find(|p| p.name == name)
    }

    /// An attached stream host's snapshot by label.
    pub fn stream_host(&self, label: &str) -> Option<&StreamHostSnapshot> {
        self.streams.iter().find(|(l, _)| l == label).map(|(_, s)| s)
    }
}

impl std::fmt::Display for FleetSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "fleet: {}/{} done ({} failed, {} retried, {} shed, {} canc, {} late) across {} pools",
            self.totals.completed,
            self.totals.submitted,
            self.totals.failed,
            self.totals.retried,
            self.totals.shed,
            self.totals.cancelled,
            self.totals.deadline_missed,
            self.per_pool.len()
        )?;
        for p in &self.per_pool {
            write!(f, "  {:16} [{:11}] x{}", p.name, p.profile.name(), p.replicas)?;
            if p.retiring > 0 {
                write!(f, " (-{} draining)", p.retiring)?;
            }
            if let Some(b) = p.breaker {
                if b != BreakerState::Closed {
                    write!(f, " breaker={b}")?;
                }
            }
            if let Some(a) = &p.autoscale {
                write!(f, " [{}..{}]", a.min_replicas, a.max_replicas)?;
                if let Some(last) = a.last {
                    write!(f, " last {last}")?;
                }
            }
            writeln!(f, " {}", p.metrics)?;
        }
        for (label, s) in &self.streams {
            writeln!(f, "  streams[{label}]: {s}")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::super::metrics::ReplicaPhase;
    use super::*;
    use crate::api::{Engine, FaultPlan, Session};

    fn tiny_session(engine: Engine, paging: bool) -> Session {
        Session::builder(crate::format::mfb::tests::tiny_mfb())
            .engine(engine)
            .paging(paging)
            .build()
            .unwrap()
    }

    fn two_pool_fleet() -> Fleet {
        Fleet::start(vec![
            PoolSpec::new("native", vec![tiny_session(Engine::MicroFlow, false)]),
            PoolSpec::new("interp", vec![tiny_session(Engine::Interp, false)]),
        ])
        .unwrap()
    }

    #[test]
    fn dispatches_and_answers_within_engine_tolerance() {
        let f = two_pool_fleet();
        assert_eq!(f.pool_names(), vec!["native", "interp"]);
        assert_eq!(f.replicas(), 2);
        for _ in 0..20 {
            let out = f.infer(vec![3, 1]).unwrap();
            // engines agree within ±1 (paper Sec. 6.2.1)
            for (got, want) in out.iter().zip(&[2i8, 0, 5]) {
                assert!((*got as i32 - *want as i32).abs() <= 1, "{out:?}");
            }
        }
        let snap = f.snapshot();
        assert_eq!(snap.totals.submitted, 20);
        assert_eq!(snap.totals.completed, 20);
        assert_eq!(snap.totals.failed, 0);
        // static pools still carry a breaker, closed at rest
        assert!(snap.per_pool.iter().all(|p| p.breaker == Some(BreakerState::Closed)));
        f.shutdown();
    }

    #[test]
    fn round_robin_tiebreak_spreads_an_idle_fleet() {
        // sequential round trips leave every pool idle at submit time —
        // outstanding ties at 0, so the cursor must alternate pools
        let f = two_pool_fleet();
        for _ in 0..10 {
            f.infer(vec![3, 1]).unwrap();
        }
        let snap = f.snapshot();
        for p in &snap.per_pool {
            assert_eq!(p.metrics.submitted, 5, "pool {} got {} of 10", p.name, p.metrics.submitted);
        }
        f.shutdown();
    }

    #[test]
    fn class_routing_prefers_matching_profiles() {
        // native declares Interactive, interp declares Bulk: strict routing
        let f = Fleet::start(vec![
            PoolSpec::new("native", vec![tiny_session(Engine::MicroFlow, false)])
                .profile(QosProfile::Interactive),
            PoolSpec::new("interp", vec![tiny_session(Engine::Interp, false)])
                .profile(QosProfile::Bulk),
        ])
        .unwrap();
        for _ in 0..6 {
            // Interactive → native pool only: replies are bit-exact
            let t = f.submit(Request::interactive(vec![3, 1])).unwrap();
            assert_eq!(t.wait().unwrap(), vec![2, 0, 5]);
            // Bulk and Background → interp pool
            for class in [QosClass::Bulk, QosClass::Background] {
                f.submit(Request::new(vec![3, 1]).with_class(class)).unwrap().wait().unwrap();
            }
        }
        let snap = f.snapshot();
        let native = snap.pool("native").unwrap();
        let interp = snap.pool("interp").unwrap();
        assert_eq!(native.metrics.class(QosClass::Interactive).submitted, 6);
        assert_eq!(native.metrics.class(QosClass::Bulk).submitted, 0);
        assert_eq!(native.metrics.class(QosClass::Background).submitted, 0);
        assert_eq!(interp.metrics.class(QosClass::Interactive).submitted, 0);
        assert_eq!(interp.metrics.class(QosClass::Bulk).submitted, 6);
        assert_eq!(interp.metrics.class(QosClass::Background).submitted, 6);
        f.shutdown();
    }

    #[test]
    fn specialist_fleet_still_serves_unmatched_classes() {
        // only an Interactive pool exists: Bulk falls through to it rather
        // than being unroutable
        let f = Fleet::start(vec![PoolSpec::new(
            "native",
            vec![tiny_session(Engine::MicroFlow, false)],
        )
        .profile(QosProfile::Interactive)])
        .unwrap();
        assert_eq!(f.infer(vec![3, 1]).unwrap(), vec![2, 0, 5]);
        f.shutdown();
    }

    #[test]
    fn try_submit_spills_and_reports_full_fleet() {
        let f = two_pool_fleet();
        // an idle fleet accepts immediately
        let t = f.try_submit(Request::new(vec![3, 1])).unwrap();
        assert_eq!(t.wait().unwrap().len(), 3);
        // wrong input length is an explicit typed error, not a panic
        match f.try_submit(Request::new(vec![1])) {
            Err(SubmitError::InputLength { expected, got }) => assert_eq!((expected, got), (2, 1)),
            other => panic!("expected InputLength, got {other:?}"),
        }
        f.shutdown();
    }

    #[test]
    fn start_validates_pool_layout() {
        // agreeing signatures across differently-configured pools: ok
        let ok = Fleet::start(vec![
            PoolSpec::new("a", vec![tiny_session(Engine::MicroFlow, false)]),
            PoolSpec::new("b", vec![tiny_session(Engine::MicroFlow, true)]),
        ]);
        assert!(ok.is_ok());
        ok.unwrap().shutdown();
        // an empty fleet is rejected
        assert!(Fleet::start(vec![]).is_err());
        // an empty pool is rejected (by the pool's own Server::start)
        assert!(Fleet::start(vec![PoolSpec::new("empty", vec![])]).is_err());
    }

    #[test]
    fn single_pool_fleet_wraps_a_server() {
        let server =
            Server::start(vec![tiny_session(Engine::MicroFlow, false)], ServerConfig::default())
                .unwrap();
        let f = Fleet::from_server("solo", server);
        assert_eq!(f.infer(vec![3, 1]).unwrap(), vec![2, 0, 5]);
        f.shutdown();
    }

    #[test]
    fn tick_scales_up_on_breach_and_back_down_when_idle() {
        let factory = Arc::new(ReplicaFactory::new(
            crate::format::mfb::tests::tiny_mfb(),
            Engine::MicroFlow,
        ));
        let policy = AutoscalePolicy::new(1, 3).idle_ticks_down(2).cooldown_ticks(0);
        let f = Fleet::start(vec![PoolSpec::new("elastic", vec![factory.provision().unwrap()])
            .autoscale(policy, Arc::clone(&factory))])
        .unwrap();
        // deterministic SLO breach: an already-expired deadline is shed by
        // the batcher before execution, whatever the thread scheduling
        let t = f
            .submit(Request::new(vec![3, 1]).with_deadline(std::time::Instant::now()))
            .unwrap();
        assert!(t.wait().unwrap_err().to_string().contains("shed"));
        let r = f.tick();
        assert_eq!(
            r[0].decision.unwrap(),
            Decision { action: ScaleAction::Up(1), reason: ScaleReason::SloBreach }
        );
        assert_eq!(r[0].live_replicas, 2);
        let snap = f.snapshot();
        assert_eq!(snap.per_pool[0].live_replicas(), 2, "\n{snap}");
        let status = snap.per_pool[0].autoscale.unwrap();
        assert_eq!((status.min_replicas, status.max_replicas), (1, 3));
        assert_eq!(status.last.unwrap().action, ScaleAction::Up(1));
        // the scaled-up pool serves correctly (warm replica, same model)
        assert_eq!(f.infer(vec![3, 1]).unwrap(), vec![2, 0, 5]);
        // that served window is not idle; then two idle windows shrink it
        assert!(!f.tick()[0].acted());
        assert!(!f.tick()[0].acted()); // idle 1
        let r = f.tick(); // idle 2: sustained-idle window complete
        assert_eq!(
            r[0].decision.unwrap(),
            Decision { action: ScaleAction::Down(1), reason: ScaleReason::SustainedIdle }
        );
        assert_eq!(r[0].live_replicas, 1);
        // at min the pool never shrinks further
        assert!(!f.tick()[0].acted()); // streak restarted: idle 1
        let r = f.tick(); // idle 2 wants down, clamped
        assert_eq!(r[0].decision.unwrap().reason, ScaleReason::AtMin);
        assert_eq!(r[0].live_replicas, 1);
        assert_eq!(f.infer(vec![3, 1]).unwrap(), vec![2, 0, 5]);
        f.shutdown();
    }

    #[test]
    fn static_pools_report_windows_but_never_act() {
        let f = two_pool_fleet();
        f.infer(vec![3, 1]).unwrap();
        let reports = f.tick();
        assert_eq!(reports.len(), 2);
        assert!(reports.iter().all(|r| r.decision.is_none() && !r.acted()));
        assert_eq!(reports.iter().map(|r| r.window.submitted()).sum::<u64>(), 1);
        let snap = f.snapshot();
        assert!(snap.per_pool.iter().all(|p| p.autoscale.is_none()));
        f.shutdown();
    }

    #[test]
    fn tick_drains_spans_and_snapshot_surfaces_attached_stream_hosts() {
        use crate::observe::Phase;
        let f = Fleet::start(vec![PoolSpec::new(
            "native",
            vec![tiny_session(Engine::MicroFlow, false)],
        )
        .config(ServerConfig { adaptive: true, profile: true, ..ServerConfig::default() })])
        .unwrap();
        for _ in 0..5 {
            f.infer(vec![3, 1]).unwrap();
        }
        let r = f.tick();
        assert_eq!(r[0].spans.dropped, 0);
        for phase in Phase::ALL {
            assert_eq!(r[0].spans.by_phase(phase), 5, "phase {phase}");
        }
        assert!(!r[0].profile.is_empty(), "a profiled native pool must export rows");
        assert!(r[0].profile.iter().all(|row| row.invocations == 5), "{:?}", r[0].profile);
        // the tick drained the rings: a quiet second window is empty
        assert_eq!(f.tick()[0].spans.recorded, 0);

        // attach a stream host: its per-stream counters ride the snapshot
        let m = crate::synth::stream_conv_chain(&mut crate::util::Prng::new(31), 2);
        let c = crate::compiler::plan::CompiledModel::compile(
            &m,
            crate::compiler::plan::CompileOptions::default(),
        )
        .unwrap();
        let host = Arc::new(
            StreamHost::start(
                Arc::new(c),
                crate::coordinator::stream::StreamHostConfig::default(),
            )
            .unwrap(),
        );
        let id = host.open("obs").unwrap();
        let frame = vec![0i8; host.frame_len()];
        for _ in 0..3 {
            host.push(id, &frame).unwrap();
        }
        f.attach_stream_host("kws", Arc::clone(&host));
        let snap = f.snapshot();
        let hs = snap.stream_host("kws").unwrap();
        assert_eq!(hs.streams.len(), 1);
        assert_eq!(hs.totals().submitted, 3);
        assert!(hs.totals().identity_holds());
        assert!(format!("{snap}").contains("streams[kws]"), "\n{snap}");
        f.shutdown();
    }

    #[test]
    fn provision_failure_is_reported_not_fatal() {
        // the factory's source is garbage: scale-up cannot build a session
        let broken = Arc::new(ReplicaFactory::new(vec![9u8, 9, 9], Engine::MicroFlow));
        let policy = AutoscalePolicy::new(1, 2).cooldown_ticks(0);
        let f = Fleet::start(vec![PoolSpec::new(
            "elastic",
            vec![tiny_session(Engine::MicroFlow, false)],
        )
        .autoscale(policy, broken)])
        .unwrap();
        let t = f
            .submit(Request::new(vec![3, 1]).with_deadline(std::time::Instant::now()))
            .unwrap();
        assert!(t.wait().is_err());
        let r = f.tick();
        let d = r[0].decision.unwrap();
        assert_eq!(d.action, ScaleAction::Hold);
        assert_eq!(d.reason, ScaleReason::ProvisionFailed);
        assert_eq!(r[0].live_replicas, 1);
        // the pool keeps serving despite the failed scale-up
        assert_eq!(f.infer(vec![3, 1]).unwrap(), vec![2, 0, 5]);
        f.shutdown();
    }

    #[test]
    fn breaker_opens_ejects_the_wedged_replica_and_recloses_after_probe() {
        // replica index 0 is wedged from its first call; every later
        // provision (the warm replacement) is clean
        let factory = Arc::new(
            ReplicaFactory::new(crate::format::mfb::tests::tiny_mfb(), Engine::MicroFlow)
                .label_prefix("frail")
                .fault(0, FaultPlan::new(0).wedge_after(0)),
        );
        // autoscaling only as the health pass's actuator: breaches and
        // idle windows are tuned to never move the pool on their own
        let policy = AutoscalePolicy::new(1, 2)
            .cooldown_ticks(0)
            .breach_tolerance(u64::MAX)
            .idle_ticks_down(u32::MAX);
        let f = Fleet::start(vec![PoolSpec::new("frail", vec![factory.provision().unwrap()])
            .config(ServerConfig { max_retries: 0, adaptive: true, ..ServerConfig::default() })
            .autoscale(policy, Arc::clone(&factory))
            .breaker(BreakerPolicy::new().min_window_requests(2).open_ticks(1))
            .health(HealthPolicy::new().eject_consecutive_failures(2))])
        .unwrap();

        // four bulk requests all fail on the wedged replica (no retry
        // budget), each resolving as a typed, labelled replica error
        for _ in 0..4 {
            let t = f.submit(Request::new(vec![3, 1]).with_class(QosClass::Bulk)).unwrap();
            let err = t.wait().unwrap_err();
            assert!(format!("{err:#}").contains("frail/0"), "{err:#}");
        }

        // tick 1: the window shows 4/4 failed — the breaker trips Open
        // and the health pass swaps the wedged replica for a warm one
        let r = f.tick();
        assert_eq!(r[0].breaker, Some(BreakerState::Open));
        assert_eq!(r[0].ejected, vec!["frail/0".to_string()]);
        assert!(r[0].acted());
        // wait for frail/0's drain to complete so only frail/1 serves
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        loop {
            let p = &f.snapshot().per_pool[0];
            if p.replicas == 1 && p.retiring == 0 {
                break;
            }
            assert!(std::time::Instant::now() < deadline, "frail/0 never drained");
            std::thread::sleep(std::time::Duration::from_millis(1));
        }

        // brownout: background work is shed at admission while open...
        let err = f.submit(Request::new(vec![3, 1]).with_class(QosClass::Bulk)).unwrap_err();
        assert!(format!("{err:#}").contains("shed at admission"), "{err:#}");
        match f.try_submit(Request::new(vec![3, 1]).with_class(QosClass::Background)) {
            Err(SubmitError::BreakerOpen { class, .. }) => {
                assert_eq!(class, QosClass::Background);
            }
            other => panic!("expected BreakerOpen, got {other:?}"),
        }
        // ...but interactive traffic still flows, bit-exact, and doubles
        // as the recovery probe
        let t = f.submit(Request::interactive(vec![3, 1])).unwrap();
        assert_eq!(t.wait().unwrap(), vec![2, 0, 5]);

        // tick 2: the open interval has elapsed — probing resumes
        let r = f.tick();
        assert_eq!(r[0].breaker, Some(BreakerState::HalfOpen));
        // a clean probe window closes the breaker on the next tick
        let t = f.submit(Request::interactive(vec![3, 1])).unwrap();
        assert_eq!(t.wait().unwrap(), vec![2, 0, 5]);
        let r = f.tick();
        assert_eq!(r[0].breaker, Some(BreakerState::Closed));
        // background admission is restored
        let t = f.submit(Request::new(vec![3, 1]).with_class(QosClass::Bulk)).unwrap();
        assert_eq!(t.wait().unwrap(), vec![2, 0, 5]);

        let snap = f.snapshot();
        let t = &snap.totals;
        assert_eq!(
            t.completed + t.shed + t.cancelled + t.failed,
            t.submitted,
            "resolution identity must hold\n{snap}"
        );
        assert_eq!((t.failed, t.shed, t.completed), (4, 2, 3));
        // the incident log keeps the ejected replica's record
        let log = &snap.per_pool[0].replica_health;
        let frail0 = log.iter().find(|h| h.label == "frail/0").unwrap();
        assert_eq!(frail0.phase, ReplicaPhase::Ejected);
        assert!(log.iter().any(|h| h.label == "frail/1" && h.phase == ReplicaPhase::Live));
        // the replacement came from the warm cache: one bytes miss + one
        // plan miss across both provisions
        assert_eq!(factory.warm_cache().misses(), 2);
        f.shutdown();
    }
}
