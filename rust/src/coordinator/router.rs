//! Model router (DESIGN.md S16): name → [`Fleet`] for multi-model
//! deployments (the fleet example serves sine + speech + person from one
//! process).
//!
//! Each model is served by a [`Fleet`] of replica pools; a bare [`Server`]
//! registers as a single-pool fleet, so simple deployments keep working
//! unchanged while heterogeneous ones add pools. Requests route by name,
//! then by QoS class and load inside the fleet; [`Router::submit`] returns
//! the request's [`Ticket`] (the ingress holds it per connection), while
//! [`Router::infer`] stays as the blocking convenience wrapper.

use std::collections::HashMap;

use anyhow::{Context, Result};

use super::fleet::Fleet;
use super::request::{Request, Ticket};
use super::server::Server;

/// A multi-model routing table.
#[derive(Default)]
pub struct Router {
    fleets: HashMap<String, Fleet>,
}

impl Router {
    pub fn new() -> Router {
        Router::default()
    }

    /// Register a single-pool deployment (wraps the server in a fleet).
    pub fn add(&mut self, name: &str, server: Server) {
        self.fleets.insert(name.to_string(), Fleet::from_server(name, server));
    }

    /// Register a multi-pool deployment.
    pub fn add_fleet(&mut self, name: &str, fleet: Fleet) {
        self.fleets.insert(name.to_string(), fleet);
    }

    pub fn get(&self, name: &str) -> Result<&Fleet> {
        self.fleets.get(name).with_context(|| format!("no model {name:?} registered"))
    }

    pub fn models(&self) -> Vec<&str> {
        let mut m: Vec<&str> = self.fleets.keys().map(|s| s.as_str()).collect();
        m.sort();
        m
    }

    /// Route a typed request by model name (class-aware pool selection in
    /// the model's fleet); returns its [`Ticket`].
    pub fn submit(&self, model: &str, req: Request) -> Result<Ticket> {
        self.get(model)?.submit(req)
    }

    /// Route and wait (blocking convenience; Bulk class, no deadline).
    pub fn infer(&self, model: &str, input: Vec<i8>) -> Result<Vec<i8>> {
        self.submit(model, Request::new(input))?.wait()
    }

    /// Shut down every fleet.
    pub fn shutdown(self) {
        for (_, f) in self.fleets {
            f.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{Engine, Session};
    use crate::coordinator::fleet::PoolSpec;
    use crate::coordinator::request::QosClass;
    use crate::coordinator::server::ServerConfig;

    fn tiny_server() -> Server {
        let s = Session::builder(crate::format::mfb::tests::tiny_mfb()).build().unwrap();
        Server::start(vec![s], ServerConfig::default()).unwrap()
    }

    #[test]
    fn routes_by_name() {
        let mut r = Router::new();
        r.add("tiny", tiny_server());
        assert_eq!(r.models(), vec!["tiny"]);
        assert_eq!(r.infer("tiny", vec![3, 1]).unwrap(), vec![2, 0, 5]);
        assert!(r.infer("missing", vec![0, 0]).is_err());
        r.shutdown();
    }

    #[test]
    fn submit_returns_a_ticket_with_request_identity() {
        let mut r = Router::new();
        r.add("tiny", tiny_server());
        let req = Request::new(vec![3, 1]).with_class(QosClass::Interactive);
        let id = req.id;
        let ticket = r.submit("tiny", req).unwrap();
        assert_eq!(ticket.id(), id);
        assert_eq!(ticket.wait().unwrap(), vec![2, 0, 5]);
        assert!(r.submit("missing", Request::new(vec![0, 0])).is_err());
        r.shutdown();
    }

    #[test]
    fn routes_to_a_multi_pool_fleet() {
        let bytes = crate::format::mfb::tests::tiny_mfb();
        let fleet = Fleet::start(vec![
            PoolSpec::new(
                "fast",
                vec![Session::builder(bytes.clone()).engine(Engine::MicroFlow).build().unwrap()],
            ),
            PoolSpec::new(
                "paged",
                vec![Session::builder(bytes)
                    .engine(Engine::MicroFlow)
                    .paging(true)
                    .build()
                    .unwrap()],
            ),
        ])
        .unwrap();
        let mut r = Router::new();
        r.add_fleet("tiny", fleet);
        // both pools are the native engine — outputs are bit-identical
        for _ in 0..6 {
            assert_eq!(r.infer("tiny", vec![3, 1]).unwrap(), vec![2, 0, 5]);
        }
        let snap = r.get("tiny").unwrap().snapshot();
        assert_eq!(snap.totals.completed, 6);
        r.shutdown();
    }
}
