//! Model router (DESIGN.md S16): name → [`Fleet`] for multi-model
//! deployments (the fleet example serves sine + speech + person from one
//! process).
//!
//! Each model is served by a [`Fleet`] of replica pools; a bare [`Server`]
//! registers as a single-pool fleet, so simple deployments keep working
//! unchanged while heterogeneous ones add pools. Requests route by name,
//! then by QoS class and load inside the fleet; [`Router::submit`] returns
//! the request's [`Ticket`] (the ingress holds it per connection), while
//! [`Router::infer`] stays as the blocking convenience wrapper.

use std::collections::HashMap;
use std::sync::{Arc, RwLock};

use anyhow::{Context, Result};

use super::fleet::Fleet;
use super::request::{Request, Ticket};
use super::server::Server;
use super::stream::{StreamCounters, StreamHost, StreamPush};
use crate::observe::Exposition;

/// A multi-model routing table.
#[derive(Default)]
pub struct Router {
    fleets: HashMap<String, Fleet>,
    /// model name → streaming lane (models served with `--stream`).
    stream_hosts: HashMap<String, Arc<StreamHost>>,
    /// open stream id → model name (ids are globally unique, so the
    /// router can route `push`/`close` without re-stating the model).
    stream_index: RwLock<HashMap<u64, String>>,
    /// The deployment's metrics sink, when serving with an exposition
    /// tier attached. The router never writes to it — it only renders
    /// snapshots for the `STAT` wire op.
    exposition: RwLock<Option<Arc<Exposition>>>,
}

impl Router {
    pub fn new() -> Router {
        Router::default()
    }

    /// Register a single-pool deployment (wraps the server in a fleet).
    pub fn add(&mut self, name: &str, server: Server) {
        self.fleets.insert(name.to_string(), Fleet::from_server(name, server));
    }

    /// Register a multi-pool deployment.
    pub fn add_fleet(&mut self, name: &str, fleet: Fleet) {
        self.fleets.insert(name.to_string(), fleet);
    }

    pub fn get(&self, name: &str) -> Result<&Fleet> {
        self.fleets.get(name).with_context(|| format!("no model {name:?} registered"))
    }

    pub fn models(&self) -> Vec<&str> {
        let mut m: Vec<&str> = self.fleets.keys().map(|s| s.as_str()).collect();
        m.sort();
        m
    }

    /// Route a typed request by model name (class-aware pool selection in
    /// the model's fleet); returns its [`Ticket`].
    pub fn submit(&self, model: &str, req: Request) -> Result<Ticket> {
        self.get(model)?.submit(req)
    }

    /// Route and wait (blocking convenience; Bulk class, no deadline).
    pub fn infer(&self, model: &str, input: Vec<i8>) -> Result<Vec<i8>> {
        self.submit(model, Request::new(input))?.wait()
    }

    /// Attach the deployment's metrics sink, enabling the `STAT` wire op
    /// to answer with a rendered exposition snapshot.
    pub fn set_exposition(&self, expo: Arc<Exposition>) {
        *self.exposition.write().unwrap() = Some(expo);
    }

    /// Render the attached exposition snapshot (Prometheus text format),
    /// or a one-comment placeholder body when no exposition is attached —
    /// the `STAT` op always answers rather than erroring, so probes can
    /// distinguish "no metrics tier" from "server down".
    pub fn render_metrics(&self) -> String {
        match self.exposition.read().unwrap().as_ref() {
            Some(expo) => expo.render(),
            None => "# microflow: no exposition attached\n".to_string(),
        }
    }

    /// Register a streaming lane for a model (alongside or instead of its
    /// request/response fleet). If a fleet with the same name is already
    /// registered, the lane is also attached to it so the fleet's snapshot
    /// surfaces the per-stream counters.
    pub fn add_stream_host(&mut self, name: &str, host: Arc<StreamHost>) {
        if let Some(fleet) = self.fleets.get(name) {
            fleet.attach_stream_host(name, Arc::clone(&host));
        }
        self.stream_hosts.insert(name.to_string(), host);
    }

    pub fn stream_host(&self, name: &str) -> Result<&Arc<StreamHost>> {
        self.stream_hosts
            .get(name)
            .with_context(|| format!("no streaming lane for model {name:?}"))
    }

    /// Models with a streaming lane registered.
    pub fn stream_models(&self) -> Vec<&str> {
        let mut m: Vec<&str> = self.stream_hosts.keys().map(|s| s.as_str()).collect();
        m.sort();
        m
    }

    /// Open a stream on a model's streaming lane; the returned id routes
    /// all subsequent [`Router::stream_push`] / [`Router::stream_close`]
    /// calls.
    pub fn stream_open(&self, model: &str) -> Result<u64> {
        let id = self.stream_host(model)?.open(model)?;
        self.stream_index.write().unwrap().insert(id, model.to_string());
        Ok(id)
    }

    /// Route one frame to an open stream.
    pub fn stream_push(&self, id: u64, frame: &[i8]) -> Result<StreamPush> {
        let model = self
            .stream_index
            .read()
            .unwrap()
            .get(&id)
            .cloned()
            .with_context(|| format!("unknown stream {id}"))?;
        self.stream_host(&model)?.push(id, frame)
    }

    /// Close an open stream, returning its final lifecycle counters.
    pub fn stream_close(&self, id: u64) -> Result<StreamCounters> {
        let model = self
            .stream_index
            .write()
            .unwrap()
            .remove(&id)
            .with_context(|| format!("unknown stream {id}"))?;
        self.stream_host(&model)?.close(id)
    }

    /// Shut down every fleet.
    pub fn shutdown(self) {
        for (_, f) in self.fleets {
            f.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{Engine, Session};
    use crate::coordinator::fleet::PoolSpec;
    use crate::coordinator::request::QosClass;
    use crate::coordinator::server::ServerConfig;

    fn tiny_server() -> Server {
        let s = Session::builder(crate::format::mfb::tests::tiny_mfb()).build().unwrap();
        Server::start(vec![s], ServerConfig::default()).unwrap()
    }

    #[test]
    fn routes_by_name() {
        let mut r = Router::new();
        r.add("tiny", tiny_server());
        assert_eq!(r.models(), vec!["tiny"]);
        assert_eq!(r.infer("tiny", vec![3, 1]).unwrap(), vec![2, 0, 5]);
        assert!(r.infer("missing", vec![0, 0]).is_err());
        r.shutdown();
    }

    #[test]
    fn submit_returns_a_ticket_with_request_identity() {
        let mut r = Router::new();
        r.add("tiny", tiny_server());
        let req = Request::new(vec![3, 1]).with_class(QosClass::Interactive);
        let id = req.id;
        let ticket = r.submit("tiny", req).unwrap();
        assert_eq!(ticket.id(), id);
        assert_eq!(ticket.wait().unwrap(), vec![2, 0, 5]);
        assert!(r.submit("missing", Request::new(vec![0, 0])).is_err());
        r.shutdown();
    }

    #[test]
    fn routes_to_a_multi_pool_fleet() {
        let bytes = crate::format::mfb::tests::tiny_mfb();
        let fleet = Fleet::start(vec![
            PoolSpec::new(
                "fast",
                vec![Session::builder(bytes.clone()).engine(Engine::MicroFlow).build().unwrap()],
            ),
            PoolSpec::new(
                "paged",
                vec![Session::builder(bytes)
                    .engine(Engine::MicroFlow)
                    .paging(true)
                    .build()
                    .unwrap()],
            ),
        ])
        .unwrap();
        let mut r = Router::new();
        r.add_fleet("tiny", fleet);
        // both pools are the native engine — outputs are bit-identical
        for _ in 0..6 {
            assert_eq!(r.infer("tiny", vec![3, 1]).unwrap(), vec![2, 0, 5]);
        }
        let snap = r.get("tiny").unwrap().snapshot();
        assert_eq!(snap.totals.completed, 6);
        r.shutdown();
    }

    #[test]
    fn metrics_render_falls_back_then_serves_the_attached_exposition() {
        let r = Router::new();
        assert_eq!(r.render_metrics(), "# microflow: no exposition attached\n");
        let expo = Arc::new(Exposition::new());
        r.set_exposition(Arc::clone(&expo));
        assert!(r.render_metrics().is_empty(), "empty sink renders empty body");
        // absorbing state through the shared handle is visible via the router
        expo.absorb_streams(
            "kws",
            &crate::coordinator::stream::StreamHostSnapshot {
                streams: Vec::new(),
                workers: Vec::new(),
            },
        );
        assert!(r.render_metrics().contains("microflow_stream_pushes_total"));
    }

    #[test]
    fn stream_host_attaches_to_the_same_name_fleet() {
        use crate::compiler::plan::{CompileOptions, CompiledModel};
        use crate::coordinator::stream::StreamHostConfig;
        use crate::util::Prng;
        let m = crate::synth::stream_conv_chain(&mut Prng::new(41), 1);
        let c = CompiledModel::compile(&m, CompileOptions::default()).unwrap();
        let host =
            Arc::new(StreamHost::start(Arc::new(c), StreamHostConfig::default()).unwrap());
        let mut r = Router::new();
        r.add("tiny", tiny_server());
        r.add_stream_host("tiny", host);
        let snap = r.get("tiny").unwrap().snapshot();
        assert!(
            snap.stream_host("tiny").is_some(),
            "fleet snapshot must surface the attached lane"
        );
        r.shutdown();
    }

    #[test]
    fn stream_lane_routes_by_id() {
        use crate::compiler::plan::{CompileOptions, CompiledModel};
        use crate::coordinator::stream::StreamHostConfig;
        use crate::util::Prng;
        let m = crate::synth::stream_conv_chain(&mut Prng::new(31), 1);
        let c = CompiledModel::compile(&m, CompileOptions::default()).unwrap();
        let host =
            Arc::new(StreamHost::start(Arc::new(c), StreamHostConfig::default()).unwrap());
        let mut r = Router::new();
        r.add_stream_host("kw", host.clone());
        assert_eq!(r.stream_models(), vec!["kw"]);
        assert!(r.stream_open("missing").is_err());
        let id = r.stream_open("kw").unwrap();
        let mut rng = Prng::new(32);
        let mut verdicts = 0;
        for _ in 0..host.window_rows() + host.pulse_frames() {
            match r.stream_push(id, &rng.i8_vec(host.frame_len())).unwrap() {
                StreamPush::Verdict(_) => verdicts += 1,
                StreamPush::Pending => {}
                other => panic!("unexpected {other:?}"),
            }
        }
        assert_eq!(verdicts, 2, "prime + one pulse");
        let counters = r.stream_close(id).unwrap();
        assert!(counters.identity_holds());
        assert!(r.stream_push(id, &[0]).is_err(), "closed id must unroute");
    }
}
