//! Model router (DESIGN.md S16): name → [`Server`] for multi-model
//! deployments (the fleet example serves sine + speech + person from one
//! process).

use std::collections::HashMap;

use anyhow::{Context, Result};

use super::server::Server;

/// A multi-model routing table.
#[derive(Default)]
pub struct Router {
    servers: HashMap<String, Server>,
}

impl Router {
    pub fn new() -> Router {
        Router::default()
    }

    pub fn add(&mut self, name: &str, server: Server) {
        self.servers.insert(name.to_string(), server);
    }

    pub fn get(&self, name: &str) -> Result<&Server> {
        self.servers.get(name).with_context(|| format!("no model {name:?} registered"))
    }

    pub fn models(&self) -> Vec<&str> {
        let mut m: Vec<&str> = self.servers.keys().map(|s| s.as_str()).collect();
        m.sort();
        m
    }

    /// Route an inference request by model name.
    pub fn infer(&self, model: &str, input: Vec<i8>) -> Result<Vec<i8>> {
        self.get(model)?.infer(input)
    }

    /// Shut down every server.
    pub fn shutdown(self) {
        for (_, s) in self.servers {
            s.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::Session;
    use crate::coordinator::server::ServerConfig;

    fn tiny_server() -> Server {
        let s = Session::builder(crate::format::mfb::tests::tiny_mfb()).build().unwrap();
        Server::start(vec![s], ServerConfig::default()).unwrap()
    }

    #[test]
    fn routes_by_name() {
        let mut r = Router::new();
        r.add("tiny", tiny_server());
        assert_eq!(r.models(), vec!["tiny"]);
        assert_eq!(r.infer("tiny", vec![3, 1]).unwrap(), vec![2, 0, 5]);
        assert!(r.infer("missing", vec![0, 0]).is_err());
        r.shutdown();
    }
}
