//! SLO-driven autoscaler — the control plane over the elastic serving
//! tier.
//!
//! TFLM's static-arena philosophy (David et al., 2020) fixes capacity
//! once at startup; under variable load that is either waste (idle
//! replicas burning memory and threads) or an SLO breach (too few
//! replicas when a burst lands). This module closes the loop the ROADMAP
//! left open: the per-class `shed` / `deadline_missed` counters and
//! latency quantiles landed in PR 4 are exactly the SLO signal to scale
//! on, and PR 5's elastic [`Server`](super::server::Server) gives the
//! actuator (`add_replica` / `remove_replica`).
//!
//! ## Design: a pure, tick-driven policy
//!
//! The controller is **deterministic by construction**. All state lives
//! in [`PolicyState`]; one [`PolicyState::step`] call consumes one
//! [`TickSignals`] observation (windowed *deltas*, from
//! [`Metrics::window`](super::metrics::Metrics::window), never lifetime
//! totals) and returns one [`Decision`]. Time is counted in **ticks**,
//! not wall-clock: cooldowns and idle windows are `N consecutive step()
//! calls`, so every policy transition is unit-testable without threads,
//! clocks or sleeps. The driving cadence is the caller's choice —
//! [`Fleet::tick`](super::fleet::Fleet::tick) is the production driver.
//!
//! ## The rules (all thresholds explicit in [`AutoscalePolicy`])
//!
//! * **raise to the floor**: a pool observed below `min_replicas` (it
//!   started smaller than the floor — nothing validates the initial
//!   size against the policy) is brought up to `min_replicas`
//!   regardless of load ([`ScaleReason::BelowMin`]);
//! * **scale up** when the window shows an SLO breach — more than
//!   `breach_tolerance` shed + deadline-missed requests, or an
//!   Interactive window p95 above `slo_p95` — by `scale_up_step`
//!   replicas, clamped to `max_replicas`;
//! * **scale down** by one replica after `idle_ticks_down` consecutive
//!   idle ticks (no submissions in the window and nothing outstanding),
//!   clamped to `min_replicas`;
//! * **cooldown**: after any scale action, `cooldown_ticks` ticks must
//!   pass before the next action — breaches during cooldown are
//!   suppressed (reported as [`ScaleReason::Cooldown`]) so one burst
//!   cannot staircase the pool to `max` before the new replicas have had
//!   a window to absorb load. Idle ticks still accumulate during
//!   cooldown, so a pool that went quiet right after a scale-up is not
//!   penalized with an extra full idle window.
//!
//! The drain side of scale-down (why removing a replica can never drop an
//! accepted request) is specified in the
//! [`server`](super::server#elasticity-and-the-drain-protocol) module
//! docs.

use std::time::Duration;

use super::metrics::WindowSnapshot;
use super::request::QosClass;

/// Per-pool autoscaling thresholds. Every knob is explicit; no wall-clock
/// randomness anywhere — windows and cooldowns are measured in ticks.
#[derive(Clone, Copy, Debug)]
pub struct AutoscalePolicy {
    /// Never retire below this many live replicas (≥ 1).
    pub min_replicas: usize,
    /// Never provision above this many live replicas.
    pub max_replicas: usize,
    /// Interactive-class windowed p95 target; a window whose p95 exceeds
    /// it is an SLO breach. `None` scales on shed/missed counts only.
    pub slo_p95: Option<Duration>,
    /// Shed + deadline-missed requests tolerated per window before the
    /// window counts as a breach (default 0: any shed/miss is a breach).
    pub breach_tolerance: u64,
    /// Replicas added per scale-up action (clamped to `max_replicas`).
    pub scale_up_step: usize,
    /// Consecutive idle ticks before one replica is retired.
    pub idle_ticks_down: u32,
    /// Ticks after any scale action during which further actions are
    /// suppressed.
    pub cooldown_ticks: u32,
}

impl AutoscalePolicy {
    /// A policy scaling between `min` and `max` replicas with the default
    /// thresholds (breach on any shed/miss, no p95 target, +1 per action,
    /// 3 idle ticks to shrink, 2 cooldown ticks).
    pub fn new(min: usize, max: usize) -> AutoscalePolicy {
        AutoscalePolicy {
            min_replicas: min.max(1),
            max_replicas: max.max(min.max(1)),
            slo_p95: None,
            breach_tolerance: 0,
            scale_up_step: 1,
            idle_ticks_down: 3,
            cooldown_ticks: 2,
        }
    }

    /// Set the Interactive windowed-p95 SLO target.
    pub fn slo_p95(mut self, target: Duration) -> AutoscalePolicy {
        self.slo_p95 = Some(target);
        self
    }

    pub fn breach_tolerance(mut self, n: u64) -> AutoscalePolicy {
        self.breach_tolerance = n;
        self
    }

    pub fn scale_up_step(mut self, n: usize) -> AutoscalePolicy {
        self.scale_up_step = n.max(1);
        self
    }

    pub fn idle_ticks_down(mut self, n: u32) -> AutoscalePolicy {
        self.idle_ticks_down = n;
        self
    }

    pub fn cooldown_ticks(mut self, n: u32) -> AutoscalePolicy {
        self.cooldown_ticks = n;
        self
    }
}

impl Default for AutoscalePolicy {
    fn default() -> Self {
        AutoscalePolicy::new(1, 4)
    }
}

/// One tick's observation of a pool — windowed deltas plus the pool's
/// instantaneous state.
#[derive(Clone, Copy, Debug, Default)]
pub struct TickSignals {
    /// Committed live replicas (running minus mid-drain retirements).
    pub live_replicas: usize,
    /// Requests accepted during the window (all classes).
    pub submitted: u64,
    /// Expired-deadline requests shed during the window.
    pub shed: u64,
    /// Requests delivered past their deadline during the window.
    pub deadline_missed: u64,
    /// Requests queued or in flight right now.
    pub outstanding: u64,
    /// Interactive-class p95 over the window, µs (0 when no samples).
    pub interactive_p95_us: f64,
}

impl TickSignals {
    /// Assemble the signals from a consumed metrics window plus the
    /// pool's instantaneous counters.
    pub fn observe(window: &WindowSnapshot, outstanding: u64, live_replicas: usize) -> TickSignals {
        TickSignals {
            live_replicas,
            submitted: window.submitted(),
            shed: window.shed(),
            deadline_missed: window.deadline_missed(),
            outstanding,
            interactive_p95_us: window.class(QosClass::Interactive).p95_us,
        }
    }
}

/// What the policy decided to do this tick.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScaleAction {
    /// Provision `n` more replicas.
    Up(usize),
    /// Retire `n` replicas.
    Down(usize),
    /// No change.
    Hold,
}

/// Why the policy decided it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScaleReason {
    /// The pool is running below `min_replicas` (e.g. started smaller
    /// than the floor): raised to the floor regardless of load.
    BelowMin,
    /// The window breached the SLO (shed/missed over tolerance, or
    /// Interactive p95 over target).
    SloBreach,
    /// `idle_ticks_down` consecutive idle windows passed.
    SustainedIdle,
    /// An action was wanted but suppressed by the post-action cooldown.
    Cooldown,
    /// Breach with the pool already at `max_replicas`.
    AtMax,
    /// Sustained idle with the pool already at `min_replicas`.
    AtMin,
    /// Nothing to do: the pool is healthy and not idle long enough.
    Steady,
    /// The applying layer could not provision a replica (build error) —
    /// recorded by [`Fleet::tick`](super::fleet::Fleet::tick), never
    /// produced by the pure policy.
    ProvisionFailed,
}

impl ScaleReason {
    /// Stable lowercase name (logs, snapshots, JSON).
    pub fn name(self) -> &'static str {
        match self {
            ScaleReason::BelowMin => "below-min",
            ScaleReason::SloBreach => "slo-breach",
            ScaleReason::SustainedIdle => "sustained-idle",
            ScaleReason::Cooldown => "cooldown",
            ScaleReason::AtMax => "at-max",
            ScaleReason::AtMin => "at-min",
            ScaleReason::Steady => "steady",
            ScaleReason::ProvisionFailed => "provision-failed",
        }
    }
}

/// One tick's decision: the action plus the rule that fired.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Decision {
    pub action: ScaleAction,
    pub reason: ScaleReason,
}

impl Decision {
    fn hold(reason: ScaleReason) -> Decision {
        Decision { action: ScaleAction::Hold, reason }
    }
}

impl std::fmt::Display for Decision {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self.action {
            ScaleAction::Up(n) => write!(f, "up+{n} ({})", self.reason.name()),
            ScaleAction::Down(n) => write!(f, "down-{n} ({})", self.reason.name()),
            ScaleAction::Hold => write!(f, "hold ({})", self.reason.name()),
        }
    }
}

/// The controller's entire mutable state — two counters. Everything else
/// is derived from the per-tick signals, which is what keeps every
/// transition unit-testable.
#[derive(Clone, Copy, Debug, Default)]
pub struct PolicyState {
    idle_streak: u32,
    cooldown: u32,
}

impl PolicyState {
    /// Consume one observation, emit one decision. Pure with respect to
    /// everything but `self`.
    pub fn step(&mut self, policy: &AutoscalePolicy, s: &TickSignals) -> Decision {
        let breach = s.shed + s.deadline_missed > policy.breach_tolerance
            || policy.slo_p95.is_some_and(|t| {
                s.interactive_p95_us > 0.0 && s.interactive_p95_us > t.as_micros() as f64
            });
        // idle = a healthy window with no new work and nothing in flight
        let idle = !breach && s.submitted == 0 && s.outstanding == 0;
        if idle {
            self.idle_streak = self.idle_streak.saturating_add(1);
        } else {
            self.idle_streak = 0;
        }
        if self.cooldown > 0 {
            self.cooldown -= 1;
            return Decision::hold(ScaleReason::Cooldown);
        }
        // a pool below its floor (started smaller than min, or min was
        // raised) is brought up to it regardless of load
        if s.live_replicas < policy.min_replicas {
            self.cooldown = policy.cooldown_ticks;
            return Decision {
                action: ScaleAction::Up(policy.min_replicas - s.live_replicas),
                reason: ScaleReason::BelowMin,
            };
        }
        if breach {
            if s.live_replicas >= policy.max_replicas {
                return Decision::hold(ScaleReason::AtMax);
            }
            let add = policy.scale_up_step.min(policy.max_replicas - s.live_replicas);
            self.cooldown = policy.cooldown_ticks;
            return Decision { action: ScaleAction::Up(add), reason: ScaleReason::SloBreach };
        }
        if idle && self.idle_streak >= policy.idle_ticks_down {
            if s.live_replicas <= policy.min_replicas {
                return Decision::hold(ScaleReason::AtMin);
            }
            self.cooldown = policy.cooldown_ticks;
            self.idle_streak = 0;
            return Decision { action: ScaleAction::Down(1), reason: ScaleReason::SustainedIdle };
        }
        Decision::hold(ScaleReason::Steady)
    }
}

/// A pool's autoscaler as reported in a
/// [`FleetSnapshot`](super::fleet::FleetSnapshot): the configured bounds,
/// how many control ticks have run, and the last decision applied.
#[derive(Clone, Copy, Debug)]
pub struct AutoscaleStatus {
    pub min_replicas: usize,
    pub max_replicas: usize,
    /// Control ticks evaluated so far.
    pub ticks: u64,
    /// The decision applied on the most recent tick (`None` before the
    /// first tick).
    pub last: Option<Decision>,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quiet(live: usize) -> TickSignals {
        TickSignals { live_replicas: live, ..TickSignals::default() }
    }

    fn busy(live: usize) -> TickSignals {
        TickSignals { live_replicas: live, submitted: 10, ..TickSignals::default() }
    }

    fn shedding(live: usize, shed: u64) -> TickSignals {
        TickSignals { live_replicas: live, submitted: 10, shed, ..TickSignals::default() }
    }

    #[test]
    fn breach_scales_up() {
        let p = AutoscalePolicy::new(1, 4);
        let mut st = PolicyState::default();
        let d = st.step(&p, &shedding(1, 3));
        assert_eq!(d, Decision { action: ScaleAction::Up(1), reason: ScaleReason::SloBreach });
    }

    #[test]
    fn deadline_misses_also_breach() {
        let p = AutoscalePolicy::new(1, 4);
        let mut st = PolicyState::default();
        let s = TickSignals {
            live_replicas: 1,
            submitted: 5,
            deadline_missed: 1,
            ..TickSignals::default()
        };
        assert_eq!(st.step(&p, &s).action, ScaleAction::Up(1));
    }

    #[test]
    fn p95_over_target_breaches_only_when_set() {
        let hot = TickSignals {
            live_replicas: 1,
            submitted: 10,
            interactive_p95_us: 9_000.0,
            ..TickSignals::default()
        };
        // no p95 target: a slow-but-unshed window is merely Steady
        let mut st = PolicyState::default();
        let d = st.step(&AutoscalePolicy::new(1, 4), &hot);
        assert_eq!(d.reason, ScaleReason::Steady);
        // with a 5ms target the same window is a breach
        let p = AutoscalePolicy::new(1, 4).slo_p95(Duration::from_millis(5));
        let mut st = PolicyState::default();
        assert_eq!(st.step(&p, &hot).action, ScaleAction::Up(1));
        // an empty window (p95 = 0) never breaches the p95 rule
        let mut st = PolicyState::default();
        assert_eq!(st.step(&p, &quiet(1)).reason, ScaleReason::Steady);
    }

    #[test]
    fn breach_tolerance_absorbs_small_shed_counts() {
        let p = AutoscalePolicy::new(1, 4).breach_tolerance(2);
        let mut st = PolicyState::default();
        assert_eq!(st.step(&p, &shedding(1, 2)).reason, ScaleReason::Steady);
        assert_eq!(st.step(&p, &shedding(1, 3)).action, ScaleAction::Up(1));
    }

    #[test]
    fn scale_up_clamps_to_max() {
        let p = AutoscalePolicy::new(1, 3).scale_up_step(4).cooldown_ticks(0);
        let mut st = PolicyState::default();
        // step 4 wants +4 but only 2 slots remain below max
        assert_eq!(st.step(&p, &shedding(1, 1)).action, ScaleAction::Up(2));
        // at max, a breach is reported but nothing is provisioned
        assert_eq!(st.step(&p, &shedding(3, 1)), Decision::hold(ScaleReason::AtMax));
    }

    #[test]
    fn sustained_idle_scales_down_after_the_window() {
        let p = AutoscalePolicy::new(1, 4).idle_ticks_down(3).cooldown_ticks(0);
        let mut st = PolicyState::default();
        assert_eq!(st.step(&p, &quiet(3)).reason, ScaleReason::Steady);
        assert_eq!(st.step(&p, &quiet(3)).reason, ScaleReason::Steady);
        // third consecutive idle tick completes the window
        assert_eq!(
            st.step(&p, &quiet(3)),
            Decision { action: ScaleAction::Down(1), reason: ScaleReason::SustainedIdle }
        );
        // the streak reset: shrinking further takes another full window
        assert_eq!(st.step(&p, &quiet(2)).reason, ScaleReason::Steady);
    }

    #[test]
    fn idle_never_shrinks_below_min() {
        let p = AutoscalePolicy::new(2, 4).idle_ticks_down(1).cooldown_ticks(0);
        let mut st = PolicyState::default();
        assert_eq!(st.step(&p, &quiet(2)), Decision::hold(ScaleReason::AtMin));
    }

    #[test]
    fn traffic_resets_the_idle_streak() {
        let p = AutoscalePolicy::new(1, 4).idle_ticks_down(2).cooldown_ticks(0);
        let mut st = PolicyState::default();
        assert_eq!(st.step(&p, &quiet(2)).reason, ScaleReason::Steady);
        // one busy window: the idle streak starts over
        assert_eq!(st.step(&p, &busy(2)).reason, ScaleReason::Steady);
        assert_eq!(st.step(&p, &quiet(2)).reason, ScaleReason::Steady);
        assert_eq!(st.step(&p, &quiet(2)).action, ScaleAction::Down(1));
    }

    #[test]
    fn outstanding_work_is_not_idle() {
        let p = AutoscalePolicy::new(1, 4).idle_ticks_down(1).cooldown_ticks(0);
        let mut st = PolicyState::default();
        // nothing submitted this window, but a backlog is still draining
        let draining =
            TickSignals { live_replicas: 2, outstanding: 5, ..TickSignals::default() };
        assert_eq!(st.step(&p, &draining).reason, ScaleReason::Steady);
    }

    #[test]
    fn cooldown_suppresses_consecutive_actions() {
        let p = AutoscalePolicy::new(1, 4).cooldown_ticks(2);
        let mut st = PolicyState::default();
        assert_eq!(st.step(&p, &shedding(1, 1)).action, ScaleAction::Up(1));
        // two breaching ticks land inside the cooldown: suppressed
        assert_eq!(st.step(&p, &shedding(2, 1)), Decision::hold(ScaleReason::Cooldown));
        assert_eq!(st.step(&p, &shedding(2, 1)), Decision::hold(ScaleReason::Cooldown));
        // cooldown over: the persisting breach acts again
        assert_eq!(st.step(&p, &shedding(2, 1)).action, ScaleAction::Up(1));
    }

    #[test]
    fn idle_streak_accumulates_through_cooldown() {
        // a pool that goes quiet right after scaling up should not pay
        // the cooldown AND a full fresh idle window
        let p = AutoscalePolicy::new(1, 4).idle_ticks_down(2).cooldown_ticks(2);
        let mut st = PolicyState::default();
        assert_eq!(st.step(&p, &shedding(1, 1)).action, ScaleAction::Up(1));
        assert_eq!(st.step(&p, &quiet(2)).reason, ScaleReason::Cooldown); // idle 1
        assert_eq!(st.step(&p, &quiet(2)).reason, ScaleReason::Cooldown); // idle 2
        assert_eq!(st.step(&p, &quiet(2)).action, ScaleAction::Down(1));
    }

    #[test]
    fn below_min_pool_is_raised_to_the_floor() {
        // nothing validates a pool's starting size against the policy, so
        // the policy itself must repair a pool below its floor
        let p = AutoscalePolicy::new(3, 6).cooldown_ticks(1);
        let mut st = PolicyState::default();
        assert_eq!(
            st.step(&p, &busy(1)),
            Decision { action: ScaleAction::Up(2), reason: ScaleReason::BelowMin }
        );
        // the raise is an action like any other: cooldown applies
        assert_eq!(st.step(&p, &busy(3)).reason, ScaleReason::Cooldown);
        assert_eq!(st.step(&p, &busy(3)).reason, ScaleReason::Steady);
    }

    #[test]
    fn policy_constructor_clamps_degenerate_bounds() {
        let p = AutoscalePolicy::new(0, 0);
        assert_eq!((p.min_replicas, p.max_replicas), (1, 1));
        let p = AutoscalePolicy::new(3, 1);
        assert!(p.max_replicas >= p.min_replicas);
    }

    #[test]
    fn signals_observe_reads_the_window() {
        let m = crate::coordinator::metrics::Metrics::new();
        m.record_submitted(QosClass::Interactive);
        m.record(QosClass::Interactive, Duration::from_micros(800));
        m.record_submitted(QosClass::Bulk);
        m.record_shed(QosClass::Bulk);
        let c = m.window_consumer();
        let w = m.window(&c);
        let s = TickSignals::observe(&w, m.outstanding(), 2);
        assert_eq!(s.live_replicas, 2);
        assert_eq!(s.submitted, 2);
        assert_eq!(s.shed, 1);
        assert_eq!(s.outstanding, 0);
        assert_eq!(s.interactive_p95_us, 800.0);
    }
}
