//! Serving metrics (DESIGN.md S16): per-class latency quantiles, lifecycle
//! counters and throughput.
//!
//! Every counter and latency reservoir is kept **per [`QosClass`]**; the
//! totals in a [`MetricsSnapshot`] are computed as the sum of the class
//! lanes, so per-class counters sum to totals by construction (the stress
//! suite still asserts it end-to-end). Lock-guarded reservoir of recent
//! latencies plus monotonic atomics — cheap enough for the request path
//! (one mutex lock per completion; the e2e bench shows the coordinator is
//! not the bottleneck — EXPERIMENTS.md §Perf).
//!
//! Lifecycle counters beyond the classic submitted/completed:
//!
//! * `shed` — expired-deadline requests dropped by the batcher before
//!   execution (they consumed queue space, never a batch slot), plus
//!   requests shed at admission by an open circuit breaker;
//! * `cancelled` — cancelled tickets dropped before execution;
//! * `failed` — requests whose ticket resolved to a replica execution
//!   error (after any retry budget was spent). The accounting identity
//!   every suite asserts is `completed + shed + cancelled + failed ==
//!   submitted`: every accepted request resolves exactly once;
//! * `retried` — redispatches after a transient replica failure. A
//!   retried request is still outstanding (it resolves later into one of
//!   the identity lanes), so `retried` sits *outside* the identity, like
//!   `deadline_missed`;
//! * `deadline_missed` — requests that executed but completed after their
//!   deadline (delivered late, the SLO signal autoscaling reads).
//!
//! Beyond the per-class lanes, `Metrics` keeps a **per-replica health
//! registry** ([`ReplicaHealth`]): each worker registers its replica
//! label and records batch successes/failures, giving the fleet tick
//! loop the consecutive-failure and windowed error-rate signals that
//! drive quarantine + ejection — with no wall clock anywhere in the
//! decision.
//!
//! Two read surfaces serve two consumers:
//!
//! * [`Metrics::snapshot`] — **lifetime** totals, pure (any number of
//!   callers, no state advanced). What tests assert and final reports
//!   print.
//! * [`Metrics::window`] — **deltas since the previous `window()` call**
//!   plus the window's own latency quantiles. This is what a *controller*
//!   wants: the autoscaler scales on "shed/missed *this window*", not on
//!   lifetime counters that only ever grow (a long-running `serve` session
//!   would otherwise look permanently unhealthy after one bad minute).
//!   The call advances the cursor, so keep a single consumer per
//!   deployment — the fleet's tick loop.

use std::sync::atomic::{AtomicU32, AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::request::QosClass;
use crate::observe::{SharedStepProfile, SpanRecorder};
use crate::util::stats::percentile_sorted;

const RESERVOIR: usize = 65_536;
/// Cap on the per-window latency buffer (drained by every [`Metrics::window`]
/// call; the cap only matters if windows are left unconsumed for a long
/// stretch of heavy traffic).
const WINDOW_RESERVOIR: usize = 16_384;

/// One QoS class's counters + latency reservoirs.
struct ClassMetrics {
    submitted: AtomicU64,
    completed: AtomicU64,
    failed: AtomicU64,
    retried: AtomicU64,
    shed: AtomicU64,
    cancelled: AtomicU64,
    deadline_missed: AtomicU64,
    latencies_us: Mutex<Vec<u64>>,
    /// Latencies recorded since the last `window()` call (drained there).
    window_latencies_us: Mutex<Vec<u64>>,
}

impl ClassMetrics {
    fn new() -> Self {
        ClassMetrics {
            submitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            retried: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            cancelled: AtomicU64::new(0),
            deadline_missed: AtomicU64::new(0),
            latencies_us: Mutex::new(Vec::new()),
            window_latencies_us: Mutex::new(Vec::new()),
        }
    }

    fn counters(&self) -> ClassCounters {
        ClassCounters {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            retried: self.retried.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            cancelled: self.cancelled.load(Ordering::Relaxed),
            deadline_missed: self.deadline_missed.load(Ordering::Relaxed),
        }
    }
}

/// A plain copy of one class lane's counters (window-cursor bookkeeping).
#[derive(Clone, Copy, Debug, Default)]
struct ClassCounters {
    submitted: u64,
    completed: u64,
    failed: u64,
    retried: u64,
    shed: u64,
    cancelled: u64,
    deadline_missed: u64,
}

/// Where the previous `window()` call left off.
struct WindowCursor {
    prev: [ClassCounters; 3],
    last_at: Instant,
    /// Id of the [`WindowConsumer`] that first called `window()` — the
    /// cursor is single-consumer, and debug builds enforce it loudly.
    consumer: Option<u64>,
}

/// Capability token for [`Metrics::window`]. The window cursor is a
/// consume-once delta stream: two independent drainers would silently
/// halve each other's deltas (each sees only the traffic since the
/// *other's* last call), which corrupts autoscaling and breaker signals
/// without any error. Minting is explicit ([`Metrics::window_consumer`])
/// and the token is deliberately neither `Clone` nor `Copy`; in debug
/// builds a second distinct token draining the same cursor panics.
#[derive(Debug)]
pub struct WindowConsumer {
    id: u64,
}

/// Shared metrics sink — one per replica pool.
pub struct Metrics {
    start: Instant,
    classes: [ClassMetrics; 3],
    batches: AtomicU64,
    batched_samples: AtomicU64,
    window: Mutex<WindowCursor>,
    /// Monotonic id source for [`Metrics::window_consumer`].
    consumer_ids: AtomicU64,
    /// Per-replica health entries, appended as workers register. Entries
    /// are never removed — a retired/dead replica's final state stays
    /// visible in snapshots (and its label is never reused anyway).
    replicas: Mutex<Vec<Arc<ReplicaHealth>>>,
    /// Hot-path span recorder for this pool (admit ring + one ring per
    /// worker). Recording is wait-free; the fleet tick loop is the single
    /// drain point, and no policy decision ever reads it.
    pub spans: SpanRecorder,
    /// Pool-wide per-step kernel profile, fed by workers running the
    /// observed batch path when profiling is enabled.
    step_profile: Arc<SharedStepProfile>,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        Metrics {
            start: Instant::now(),
            classes: std::array::from_fn(|_| ClassMetrics::new()),
            batches: AtomicU64::new(0),
            batched_samples: AtomicU64::new(0),
            window: Mutex::new(WindowCursor {
                prev: [ClassCounters::default(); 3],
                last_at: Instant::now(),
                consumer: None,
            }),
            consumer_ids: AtomicU64::new(0),
            replicas: Mutex::new(Vec::new()),
            spans: SpanRecorder::new(),
            step_profile: Arc::new(SharedStepProfile::new()),
        }
    }

    /// Mint the capability token [`Metrics::window`] requires. Mint one
    /// per deployment and hand it to the component that owns the control
    /// loop (the fleet's pool state); minting a second token is allowed —
    /// using it on an already-claimed cursor is the debug-build error.
    pub fn window_consumer(&self) -> WindowConsumer {
        WindowConsumer { id: self.consumer_ids.fetch_add(1, Ordering::Relaxed) }
    }

    /// Shared per-step profile accumulator for this pool (what workers
    /// feed and [`PoolTickReport`](super::fleet::PoolTickReport) exports).
    pub fn step_profile(&self) -> Arc<SharedStepProfile> {
        Arc::clone(&self.step_profile)
    }

    fn lane(&self, class: QosClass) -> &ClassMetrics {
        &self.classes[class.index()]
    }

    /// Record one accepted (enqueued) request.
    pub fn record_submitted(&self, class: QosClass) {
        self.lane(class).submitted.fetch_add(1, Ordering::Relaxed);
    }

    /// Undo one `record_submitted` — the `try_submit` path counts before
    /// the non-blocking send (completed must never exceed submitted), then
    /// retracts when the send is rejected (queue full or shut down) and
    /// the request is handed back to the caller.
    pub fn retract_submitted(&self, class: QosClass) {
        self.lane(class).submitted.fetch_sub(1, Ordering::Relaxed);
    }

    /// Requests accepted but not yet resolved (queued + in flight) — the
    /// load signal the fleet's least-outstanding-requests dispatch and the
    /// adaptive batcher read. Shed and cancelled requests are resolved:
    /// they left the queue without completing.
    pub fn outstanding(&self) -> u64 {
        let mut submitted = 0u64;
        let mut resolved = 0u64;
        for lane in &self.classes {
            submitted += lane.submitted.load(Ordering::Relaxed);
            // `retried` is deliberately absent: a retried request is
            // still in flight until it completes, sheds or fails
            resolved += lane.completed.load(Ordering::Relaxed)
                + lane.failed.load(Ordering::Relaxed)
                + lane.shed.load(Ordering::Relaxed)
                + lane.cancelled.load(Ordering::Relaxed);
        }
        submitted.saturating_sub(resolved)
    }

    /// Record one completed request with its end-to-end latency.
    pub fn record(&self, class: QosClass, latency: Duration) {
        let lane = self.lane(class);
        lane.completed.fetch_add(1, Ordering::Relaxed);
        let us = latency.as_micros() as u64;
        {
            let mut l = lane.latencies_us.lock().unwrap();
            if l.len() < RESERVOIR {
                l.push(us);
            }
        }
        let mut w = lane.window_latencies_us.lock().unwrap();
        if w.len() < WINDOW_RESERVOIR {
            w.push(us);
        }
    }

    /// Record one request resolved as failed (its ticket received a
    /// replica error after the retry budget, if any, was spent).
    pub fn record_failed(&self, class: QosClass) {
        self.lane(class).failed.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one redispatch of a transiently-failed request. The request
    /// stays outstanding; only its eventual resolution touches the
    /// accounting identity.
    pub fn record_retried(&self, class: QosClass) {
        self.lane(class).retried.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one expired-deadline request dropped before execution.
    pub fn record_shed(&self, class: QosClass) {
        self.lane(class).shed.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one cancelled request dropped before execution.
    pub fn record_cancelled(&self, class: QosClass) {
        self.lane(class).cancelled.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one request that executed but finished past its deadline
    /// (also counted in `completed`; the reply is still delivered).
    pub fn record_deadline_missed(&self, class: QosClass) {
        self.lane(class).deadline_missed.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one executed batch of `n` samples.
    pub fn record_batch(&self, n: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_samples.fetch_add(n as u64, Ordering::Relaxed);
    }

    /// Register a replica in the health registry (called by the worker at
    /// spawn); the returned handle is what the worker records batch
    /// outcomes on, and what the fleet's health pass reads.
    pub fn register_replica(&self, label: &str) -> Arc<ReplicaHealth> {
        let h = Arc::new(ReplicaHealth::new(label));
        self.replicas.lock().unwrap().push(Arc::clone(&h));
        h
    }

    /// Point-in-time health of every replica ever registered (including
    /// ejected/dead ones — their terminal state is part of the story).
    pub fn replica_health(&self) -> Vec<ReplicaHealthSnapshot> {
        self.replicas.lock().unwrap().iter().map(|h| h.snapshot()).collect()
    }

    /// Live handles for the fleet's health pass (which needs to drain
    /// per-replica windows and flip quarantine flags, not just read).
    pub(crate) fn replica_handles(&self) -> Vec<Arc<ReplicaHealth>> {
        self.replicas.lock().unwrap().clone()
    }

    /// Find one replica's health entry by label.
    pub fn find_replica(&self, label: &str) -> Option<Arc<ReplicaHealth>> {
        self.replicas.lock().unwrap().iter().find(|h| h.label() == label).cloned()
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let quantiles = |lat: &mut Vec<u64>| {
            lat.sort_unstable();
            let latf: Vec<f64> = lat.iter().map(|&v| v as f64).collect();
            let q = |p: f64| if latf.is_empty() { 0.0 } else { percentile_sorted(&latf, p) };
            (q(50.0), q(95.0), q(99.0))
        };
        let mut all_lat: Vec<u64> = Vec::new();
        let per_class: [ClassSnapshot; 3] = std::array::from_fn(|i| {
            let lane = &self.classes[i];
            let mut lat = lane.latencies_us.lock().unwrap().clone();
            all_lat.extend_from_slice(&lat);
            let (p50_us, p95_us, p99_us) = quantiles(&mut lat);
            ClassSnapshot {
                class: QosClass::ALL[i],
                submitted: lane.submitted.load(Ordering::Relaxed),
                completed: lane.completed.load(Ordering::Relaxed),
                failed: lane.failed.load(Ordering::Relaxed),
                retried: lane.retried.load(Ordering::Relaxed),
                shed: lane.shed.load(Ordering::Relaxed),
                cancelled: lane.cancelled.load(Ordering::Relaxed),
                deadline_missed: lane.deadline_missed.load(Ordering::Relaxed),
                p50_us,
                p95_us,
                p99_us,
            }
        });
        let (p50_us, p95_us, p99_us) = quantiles(&mut all_lat);
        let sum = |f: fn(&ClassSnapshot) -> u64| per_class.iter().map(f).sum::<u64>();
        let batches = self.batches.load(Ordering::Relaxed);
        let samples = self.batched_samples.load(Ordering::Relaxed);
        MetricsSnapshot {
            submitted: sum(|c| c.submitted),
            completed: sum(|c| c.completed),
            failed: sum(|c| c.failed),
            retried: sum(|c| c.retried),
            shed: sum(|c| c.shed),
            cancelled: sum(|c| c.cancelled),
            deadline_missed: sum(|c| c.deadline_missed),
            elapsed: self.start.elapsed(),
            p50_us,
            p95_us,
            p99_us,
            mean_batch: if batches > 0 { samples as f64 / batches as f64 } else { 0.0 },
            per_class,
        }
    }

    /// Per-class **deltas since the previous `window()` call** plus the
    /// window's own latency quantiles — the rate view a controller scales
    /// on. Advances the window cursor and drains the window latency
    /// buffers: the cursor is **single-consumer** (the fleet tick loop),
    /// and the [`WindowConsumer`] token makes that explicit — the first
    /// token to drain claims the cursor, and in debug builds a different
    /// token draining afterwards panics instead of silently splitting the
    /// delta stream.
    pub fn window(&self, consumer: &WindowConsumer) -> WindowSnapshot {
        let mut cursor = self.window.lock().unwrap();
        match cursor.consumer {
            None => cursor.consumer = Some(consumer.id),
            Some(owner) => debug_assert_eq!(
                owner, consumer.id,
                "Metrics::window is single-consumer: the cursor was claimed by consumer \
                 #{owner}, and draining it from a second consumer would silently split \
                 the delta stream both controllers depend on"
            ),
        }
        let elapsed = cursor.last_at.elapsed();
        cursor.last_at = Instant::now();
        let per_class: [ClassWindow; 3] = std::array::from_fn(|i| {
            let lane = &self.classes[i];
            let now = lane.counters();
            let prev = cursor.prev[i];
            cursor.prev[i] = now;
            let mut lat = std::mem::take(&mut *lane.window_latencies_us.lock().unwrap());
            lat.sort_unstable();
            let latf: Vec<f64> = lat.iter().map(|&v| v as f64).collect();
            let q = |p: f64| if latf.is_empty() { 0.0 } else { percentile_sorted(&latf, p) };
            ClassWindow {
                class: QosClass::ALL[i],
                // saturating: a `retract_submitted` racing the window edge
                // may make a counter read lower than the cursor's copy
                submitted: now.submitted.saturating_sub(prev.submitted),
                completed: now.completed.saturating_sub(prev.completed),
                failed: now.failed.saturating_sub(prev.failed),
                retried: now.retried.saturating_sub(prev.retried),
                shed: now.shed.saturating_sub(prev.shed),
                cancelled: now.cancelled.saturating_sub(prev.cancelled),
                deadline_missed: now.deadline_missed.saturating_sub(prev.deadline_missed),
                p50_us: q(50.0),
                p95_us: q(95.0),
            }
        });
        WindowSnapshot { elapsed, per_class }
    }
}

/// One class's lane in a [`WindowSnapshot`]: counter deltas over the
/// window plus the window's own latency quantiles.
#[derive(Clone, Copy, Debug)]
pub struct ClassWindow {
    pub class: QosClass,
    pub submitted: u64,
    pub completed: u64,
    pub failed: u64,
    pub retried: u64,
    pub shed: u64,
    pub cancelled: u64,
    pub deadline_missed: u64,
    pub p50_us: f64,
    pub p95_us: f64,
}

/// Deltas since the previous [`Metrics::window`] call — what the
/// autoscaler (and any periodic health line) reads instead of lifetime
/// totals.
#[derive(Clone, Copy, Debug)]
pub struct WindowSnapshot {
    /// Wall time covered by this window.
    pub elapsed: Duration,
    pub per_class: [ClassWindow; 3],
}

impl WindowSnapshot {
    pub fn class(&self, class: QosClass) -> &ClassWindow {
        &self.per_class[class.index()]
    }

    fn sum(&self, f: fn(&ClassWindow) -> u64) -> u64 {
        self.per_class.iter().map(f).sum()
    }

    /// Requests accepted during the window (all classes).
    pub fn submitted(&self) -> u64 {
        self.sum(|c| c.submitted)
    }

    /// Requests completed during the window (all classes).
    pub fn completed(&self) -> u64 {
        self.sum(|c| c.completed)
    }

    /// Expired-deadline requests shed during the window (all classes).
    pub fn shed(&self) -> u64 {
        self.sum(|c| c.shed)
    }

    /// Requests delivered past their deadline during the window.
    pub fn deadline_missed(&self) -> u64 {
        self.sum(|c| c.deadline_missed)
    }

    /// Requests resolved as failed during the window (all classes) — the
    /// circuit breaker's trip signal.
    pub fn failed(&self) -> u64 {
        self.sum(|c| c.failed)
    }

    /// Redispatches during the window (all classes).
    pub fn retried(&self) -> u64 {
        self.sum(|c| c.retried)
    }

    /// Requests *resolved by execution* during the window: completed or
    /// failed. Sheds and cancels are excluded on purpose — an open
    /// breaker sheds at admission, and those sheds must not keep the
    /// breaker open once the pool is actually executing cleanly again.
    pub fn resolved(&self) -> u64 {
        self.completed() + self.failed()
    }

    /// `count` as a per-second rate over this window's wall time.
    pub fn per_sec(&self, count: u64) -> f64 {
        count as f64 / self.elapsed.as_secs_f64().max(1e-9)
    }
}

impl std::fmt::Display for WindowSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "window {:.2}s | {:.0} req/s in, {:.0} req/s done | {} shed, {} late, {} failed",
            self.elapsed.as_secs_f64(),
            self.per_sec(self.submitted()),
            self.per_sec(self.completed()),
            self.shed(),
            self.deadline_missed(),
            self.failed(),
        )?;
        for c in self.per_class.iter().filter(|c| c.submitted > 0 || c.completed > 0) {
            write!(f, " | {} {}/{} p95 {:.0}us", c.class.name(), c.completed, c.submitted, c.p95_us)?;
        }
        Ok(())
    }
}

/// Lifecycle phase of one replica in the health registry.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReplicaPhase {
    /// Serving normally.
    Live,
    /// Marked for ejection by the fleet's health pass; the worker exits
    /// at its next batch boundary (a targeted graceful drain).
    Quarantined,
    /// The quarantined worker has exited — the ejection is realized.
    Ejected,
    /// The worker exited on a fatal replica failure (it did not drain; its
    /// in-flight batch was failed to the tickets first).
    Dead,
}

impl ReplicaPhase {
    pub fn name(self) -> &'static str {
        match self {
            ReplicaPhase::Live => "live",
            ReplicaPhase::Quarantined => "quarantined",
            ReplicaPhase::Ejected => "ejected",
            ReplicaPhase::Dead => "dead",
        }
    }

    fn from_u8(v: u8) -> ReplicaPhase {
        match v {
            0 => ReplicaPhase::Live,
            1 => ReplicaPhase::Quarantined,
            2 => ReplicaPhase::Ejected,
            _ => ReplicaPhase::Dead,
        }
    }
}

impl std::fmt::Display for ReplicaPhase {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Per-replica health accounting: the worker holding the replica records
/// each batch outcome; the fleet's tick-driven health pass reads the
/// consecutive-failure streak and drains the windowed error-rate counters
/// to decide quarantine. All counters are batch-grained — a replica fault
/// fails the whole batch, so batches are the natural failure unit.
pub struct ReplicaHealth {
    label: String,
    phase: AtomicU8,
    consecutive_failures: AtomicU32,
    batches: AtomicU64,
    failures: AtomicU64,
    /// Batches/failures since the last `drain_window()` (the health
    /// pass's per-tick error-rate signal).
    window_batches: AtomicU64,
    window_failures: AtomicU64,
}

impl ReplicaHealth {
    fn new(label: &str) -> ReplicaHealth {
        ReplicaHealth {
            label: label.to_string(),
            phase: AtomicU8::new(0),
            consecutive_failures: AtomicU32::new(0),
            batches: AtomicU64::new(0),
            failures: AtomicU64::new(0),
            window_batches: AtomicU64::new(0),
            window_failures: AtomicU64::new(0),
        }
    }

    pub fn label(&self) -> &str {
        &self.label
    }

    pub fn phase(&self) -> ReplicaPhase {
        ReplicaPhase::from_u8(self.phase.load(Ordering::Relaxed))
    }

    /// One successfully executed batch: breaks the failure streak.
    pub fn record_success(&self) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.window_batches.fetch_add(1, Ordering::Relaxed);
        self.consecutive_failures.store(0, Ordering::Relaxed);
    }

    /// One failed batch: extends the streak and the window error count.
    pub fn record_failure(&self) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.failures.fetch_add(1, Ordering::Relaxed);
        self.window_batches.fetch_add(1, Ordering::Relaxed);
        self.window_failures.fetch_add(1, Ordering::Relaxed);
        self.consecutive_failures.fetch_add(1, Ordering::Relaxed);
    }

    pub fn consecutive_failures(&self) -> u32 {
        self.consecutive_failures.load(Ordering::Relaxed)
    }

    /// Take the per-window (batches, failures) counts, resetting them —
    /// one consumer: the fleet's health pass.
    pub fn drain_window(&self) -> (u64, u64) {
        (
            self.window_batches.swap(0, Ordering::Relaxed),
            self.window_failures.swap(0, Ordering::Relaxed),
        )
    }

    /// Flip Live → Quarantined; false if the replica already left Live
    /// (quarantine is one-shot — the health pass never double-ejects).
    pub fn quarantine(&self) -> bool {
        self.phase
            .compare_exchange(0, 1, Ordering::Relaxed, Ordering::Relaxed)
            .is_ok()
    }

    pub fn is_quarantined(&self) -> bool {
        self.phase() == ReplicaPhase::Quarantined
    }

    /// The quarantined worker exited (set by the worker itself).
    pub fn mark_ejected(&self) {
        self.phase.store(2, Ordering::Relaxed);
    }

    /// The worker died on a fatal replica failure.
    pub fn mark_dead(&self) {
        self.phase.store(3, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> ReplicaHealthSnapshot {
        ReplicaHealthSnapshot {
            label: self.label.clone(),
            phase: self.phase(),
            consecutive_failures: self.consecutive_failures(),
            batches: self.batches.load(Ordering::Relaxed),
            failures: self.failures.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time copy of one replica's health entry.
#[derive(Clone, Debug)]
pub struct ReplicaHealthSnapshot {
    pub label: String,
    pub phase: ReplicaPhase,
    pub consecutive_failures: u32,
    /// Lifetime executed batches (successes + failures).
    pub batches: u64,
    /// Lifetime failed batches.
    pub failures: u64,
}

impl std::fmt::Display for ReplicaHealthSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} [{}] {}/{} batches failed (streak {})",
            self.label, self.phase, self.failures, self.batches, self.consecutive_failures
        )
    }
}

/// One class's lane in a [`MetricsSnapshot`].
#[derive(Clone, Copy, Debug)]
pub struct ClassSnapshot {
    pub class: QosClass,
    pub submitted: u64,
    pub completed: u64,
    pub failed: u64,
    pub retried: u64,
    pub shed: u64,
    pub cancelled: u64,
    pub deadline_missed: u64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
}

impl ClassSnapshot {
    /// Any traffic in this lane at all?
    pub fn is_active(&self) -> bool {
        self.submitted > 0
    }
}

/// A point-in-time metrics view. The flat fields are totals, always equal
/// to the sum of the `per_class` lanes, and always satisfying
/// `completed + shed + cancelled + failed == submitted` once the pool is
/// quiescent (`retried` and `deadline_missed` sit outside the identity).
#[derive(Clone, Copy, Debug)]
pub struct MetricsSnapshot {
    pub submitted: u64,
    pub completed: u64,
    pub failed: u64,
    pub retried: u64,
    pub shed: u64,
    pub cancelled: u64,
    pub deadline_missed: u64,
    pub elapsed: Duration,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub mean_batch: f64,
    pub per_class: [ClassSnapshot; 3],
}

impl MetricsSnapshot {
    pub fn throughput_rps(&self) -> f64 {
        self.completed as f64 / self.elapsed.as_secs_f64().max(1e-9)
    }

    pub fn class(&self, class: QosClass) -> &ClassSnapshot {
        &self.per_class[class.index()]
    }
}

impl std::fmt::Display for MetricsSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}/{} done ({} failed, {} retried, {} shed, {} canc, {} late) in {:.2}s | {:.0} req/s | p50 {:.0}us p95 {:.0}us p99 {:.0}us | mean batch {:.2}",
            self.completed,
            self.submitted,
            self.failed,
            self.retried,
            self.shed,
            self.cancelled,
            self.deadline_missed,
            self.elapsed.as_secs_f64(),
            self.throughput_rps(),
            self.p50_us,
            self.p95_us,
            self.p99_us,
            self.mean_batch
        )?;
        for c in self.per_class.iter().filter(|c| c.is_active()) {
            write!(
                f,
                " | {} {}/{} p95 {:.0}us",
                c.class.name(),
                c.completed,
                c.submitted,
                c.p95_us
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new();
        for us in [100u64, 200, 300, 400, 500] {
            m.record_submitted(QosClass::Bulk);
            m.record(QosClass::Bulk, Duration::from_micros(us));
        }
        m.record_batch(5);
        let s = m.snapshot();
        assert_eq!(s.submitted, 5);
        assert_eq!(s.completed, 5);
        assert_eq!(s.p50_us, 300.0);
        assert_eq!(s.mean_batch, 5.0);
        assert!(s.throughput_rps() > 0.0);
        assert_eq!(s.class(QosClass::Bulk).completed, 5);
        assert_eq!(s.class(QosClass::Interactive).completed, 0);
    }

    #[test]
    fn per_class_lanes_sum_to_totals() {
        let m = Metrics::new();
        m.record_submitted(QosClass::Interactive);
        m.record(QosClass::Interactive, Duration::from_micros(50));
        m.record_submitted(QosClass::Bulk);
        m.record_shed(QosClass::Bulk);
        m.record_submitted(QosClass::Background);
        m.record_cancelled(QosClass::Background);
        m.record_submitted(QosClass::Bulk);
        m.record(QosClass::Bulk, Duration::from_micros(900));
        m.record_deadline_missed(QosClass::Bulk);
        let s = m.snapshot();
        assert_eq!(s.submitted, 4);
        assert_eq!(s.completed, 2);
        assert_eq!(s.shed, 1);
        assert_eq!(s.cancelled, 1);
        assert_eq!(s.deadline_missed, 1);
        let lane_sum = |f: fn(&ClassSnapshot) -> u64| s.per_class.iter().map(f).sum::<u64>();
        assert_eq!(lane_sum(|c| c.submitted), s.submitted);
        assert_eq!(lane_sum(|c| c.completed), s.completed);
        assert_eq!(lane_sum(|c| c.failed), s.failed);
        assert_eq!(lane_sum(|c| c.retried), s.retried);
        assert_eq!(lane_sum(|c| c.shed), s.shed);
        assert_eq!(lane_sum(|c| c.cancelled), s.cancelled);
        assert_eq!(lane_sum(|c| c.deadline_missed), s.deadline_missed);
    }

    #[test]
    fn outstanding_counts_shed_cancelled_and_failed_as_resolved() {
        let m = Metrics::new();
        for _ in 0..5 {
            m.record_submitted(QosClass::Bulk);
        }
        assert_eq!(m.outstanding(), 5);
        m.record(QosClass::Bulk, Duration::from_micros(10));
        m.record_failed(QosClass::Bulk);
        assert_eq!(m.outstanding(), 3);
        m.record_shed(QosClass::Bulk);
        m.record_cancelled(QosClass::Bulk);
        assert_eq!(m.outstanding(), 1);
        assert_eq!(m.snapshot().submitted, 5);
    }

    #[test]
    fn retried_requests_stay_outstanding() {
        let m = Metrics::new();
        m.record_submitted(QosClass::Interactive);
        m.record_retried(QosClass::Interactive);
        m.record_retried(QosClass::Interactive);
        assert_eq!(m.outstanding(), 1, "a retried request has not resolved");
        // the retried request eventually fails: identity closes
        m.record_failed(QosClass::Interactive);
        assert_eq!(m.outstanding(), 0);
        let s = m.snapshot();
        assert_eq!(s.retried, 2);
        assert_eq!(s.completed + s.shed + s.cancelled + s.failed, s.submitted);
    }

    #[test]
    fn retract_submitted_balances_a_rejected_try_submit() {
        let m = Metrics::new();
        m.record_submitted(QosClass::Interactive);
        m.retract_submitted(QosClass::Interactive);
        assert_eq!(m.outstanding(), 0);
        assert_eq!(m.snapshot().submitted, 0);
    }

    #[test]
    fn empty_snapshot_is_zeroes() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.completed, 0);
        assert_eq!(s.p99_us, 0.0);
        assert_eq!(s.shed, 0);
    }

    #[test]
    fn window_reads_deltas_not_lifetime_totals() {
        let m = Metrics::new();
        for _ in 0..3 {
            m.record_submitted(QosClass::Bulk);
            m.record_shed(QosClass::Bulk);
        }
        let c = m.window_consumer();
        let w1 = m.window(&c);
        assert_eq!(w1.submitted(), 3);
        assert_eq!(w1.shed(), 3);
        // a quiet second window reports zero even though lifetime totals
        // still carry the earlier sheds
        let w2 = m.window(&c);
        assert_eq!(w2.submitted(), 0);
        assert_eq!(w2.shed(), 0, "window must not re-report consumed sheds");
        assert_eq!(m.snapshot().shed, 3, "lifetime totals are untouched");
        // fresh activity shows up in the next window only
        m.record_submitted(QosClass::Interactive);
        m.record_deadline_missed(QosClass::Interactive);
        let w3 = m.window(&c);
        assert_eq!(w3.class(QosClass::Interactive).submitted, 1);
        assert_eq!(w3.deadline_missed(), 1);
        assert_eq!(w3.class(QosClass::Bulk).shed, 0);
    }

    #[test]
    fn window_latency_quantiles_cover_only_the_window() {
        let m = Metrics::new();
        m.record_submitted(QosClass::Interactive);
        m.record(QosClass::Interactive, Duration::from_micros(10_000));
        let c = m.window_consumer();
        let w1 = m.window(&c);
        assert_eq!(w1.class(QosClass::Interactive).p95_us, 10_000.0);
        // the slow request must not haunt later windows (lifetime p95 keeps it)
        m.record_submitted(QosClass::Interactive);
        m.record(QosClass::Interactive, Duration::from_micros(100));
        let w2 = m.window(&c);
        assert_eq!(w2.class(QosClass::Interactive).p95_us, 100.0);
        assert_eq!(w2.completed(), 1);
        assert!(m.snapshot().p95_us >= 100.0);
    }

    #[test]
    fn window_survives_a_retract_across_the_edge() {
        let m = Metrics::new();
        m.record_submitted(QosClass::Bulk);
        let c = m.window_consumer();
        let w1 = m.window(&c);
        assert_eq!(w1.submitted(), 1);
        // a rejected try_submit retracts after the cursor advanced: the
        // next delta saturates at zero instead of underflowing
        m.retract_submitted(QosClass::Bulk);
        let w2 = m.window(&c);
        assert_eq!(w2.submitted(), 0);
    }

    #[test]
    fn window_reports_failed_and_retried_deltas() {
        let m = Metrics::new();
        for _ in 0..4 {
            m.record_submitted(QosClass::Bulk);
        }
        m.record(QosClass::Bulk, Duration::from_micros(10));
        m.record_retried(QosClass::Bulk);
        m.record_failed(QosClass::Bulk);
        let c = m.window_consumer();
        let w = m.window(&c);
        assert_eq!(w.failed(), 1);
        assert_eq!(w.retried(), 1);
        assert_eq!(w.resolved(), 2, "resolved = completed + failed");
        let w2 = m.window(&c);
        assert_eq!(w2.failed(), 0, "consumed by the previous window");
        assert_eq!(w2.resolved(), 0);
    }

    #[test]
    fn first_window_consumer_claims_the_cursor() {
        let m = Metrics::new();
        let c = m.window_consumer();
        let _unused = m.window_consumer(); // minting more tokens is fine
        m.window(&c);
        m.window(&c); // the claiming token may drain forever
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "single-consumer")]
    fn second_window_consumer_fails_loudly() {
        let m = Metrics::new();
        let first = m.window_consumer();
        let second = m.window_consumer();
        m.window(&first);
        m.window(&second); // must panic: the cursor belongs to `first`
    }

    #[test]
    fn replica_health_tracks_streaks_and_windows() {
        let m = Metrics::new();
        let h = m.register_replica("native/0");
        h.record_success();
        h.record_failure();
        h.record_failure();
        assert_eq!(h.consecutive_failures(), 2);
        assert_eq!(h.drain_window(), (3, 2));
        assert_eq!(h.drain_window(), (0, 0), "window counters reset on drain");
        h.record_success();
        assert_eq!(h.consecutive_failures(), 0, "a success breaks the streak");
        let snaps = m.replica_health();
        assert_eq!(snaps.len(), 1);
        assert_eq!(snaps[0].label, "native/0");
        assert_eq!(snaps[0].batches, 4);
        assert_eq!(snaps[0].failures, 2);
        assert_eq!(snaps[0].phase, ReplicaPhase::Live);
    }

    #[test]
    fn quarantine_is_one_shot_and_phases_are_terminal() {
        let m = Metrics::new();
        let h = m.register_replica("native/1");
        assert!(h.quarantine(), "first quarantine wins");
        assert!(!h.quarantine(), "second attempt must not re-eject");
        assert!(h.is_quarantined());
        h.mark_ejected();
        assert_eq!(h.phase(), ReplicaPhase::Ejected);
        assert!(!h.quarantine(), "an ejected replica never re-enters service");
        let dead = m.register_replica("native/2");
        dead.mark_dead();
        assert_eq!(dead.phase(), ReplicaPhase::Dead);
        assert_eq!(m.find_replica("native/2").unwrap().phase(), ReplicaPhase::Dead);
        assert!(m.find_replica("nope").is_none());
    }
}
