//! Serving metrics (DESIGN.md S16): per-class latency quantiles, lifecycle
//! counters and throughput.
//!
//! Every counter and latency reservoir is kept **per [`QosClass`]**; the
//! totals in a [`MetricsSnapshot`] are computed as the sum of the class
//! lanes, so per-class counters sum to totals by construction (the stress
//! suite still asserts it end-to-end). Lock-guarded reservoir of recent
//! latencies plus monotonic atomics — cheap enough for the request path
//! (one mutex lock per completion; the e2e bench shows the coordinator is
//! not the bottleneck — EXPERIMENTS.md §Perf).
//!
//! Lifecycle counters beyond the classic submitted/completed/errors:
//!
//! * `shed` — expired-deadline requests dropped by the batcher before
//!   execution (they consumed queue space, never a batch slot);
//! * `cancelled` — cancelled tickets dropped before execution;
//! * `deadline_missed` — requests that executed but completed after their
//!   deadline (delivered late, the SLO signal autoscaling reads).
//!
//! Two read surfaces serve two consumers:
//!
//! * [`Metrics::snapshot`] — **lifetime** totals, pure (any number of
//!   callers, no state advanced). What tests assert and final reports
//!   print.
//! * [`Metrics::window`] — **deltas since the previous `window()` call**
//!   plus the window's own latency quantiles. This is what a *controller*
//!   wants: the autoscaler scales on "shed/missed *this window*", not on
//!   lifetime counters that only ever grow (a long-running `serve` session
//!   would otherwise look permanently unhealthy after one bad minute).
//!   The call advances the cursor, so keep a single consumer per
//!   deployment — the fleet's tick loop.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use super::request::QosClass;
use crate::util::stats::percentile_sorted;

const RESERVOIR: usize = 65_536;
/// Cap on the per-window latency buffer (drained by every [`Metrics::window`]
/// call; the cap only matters if windows are left unconsumed for a long
/// stretch of heavy traffic).
const WINDOW_RESERVOIR: usize = 16_384;

/// One QoS class's counters + latency reservoirs.
struct ClassMetrics {
    submitted: AtomicU64,
    completed: AtomicU64,
    errors: AtomicU64,
    shed: AtomicU64,
    cancelled: AtomicU64,
    deadline_missed: AtomicU64,
    latencies_us: Mutex<Vec<u64>>,
    /// Latencies recorded since the last `window()` call (drained there).
    window_latencies_us: Mutex<Vec<u64>>,
}

impl ClassMetrics {
    fn new() -> Self {
        ClassMetrics {
            submitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            cancelled: AtomicU64::new(0),
            deadline_missed: AtomicU64::new(0),
            latencies_us: Mutex::new(Vec::new()),
            window_latencies_us: Mutex::new(Vec::new()),
        }
    }

    fn counters(&self) -> ClassCounters {
        ClassCounters {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            cancelled: self.cancelled.load(Ordering::Relaxed),
            deadline_missed: self.deadline_missed.load(Ordering::Relaxed),
        }
    }
}

/// A plain copy of one class lane's counters (window-cursor bookkeeping).
#[derive(Clone, Copy, Debug, Default)]
struct ClassCounters {
    submitted: u64,
    completed: u64,
    errors: u64,
    shed: u64,
    cancelled: u64,
    deadline_missed: u64,
}

/// Where the previous `window()` call left off.
struct WindowCursor {
    prev: [ClassCounters; 3],
    last_at: Instant,
}

/// Shared metrics sink — one per replica pool.
pub struct Metrics {
    start: Instant,
    classes: [ClassMetrics; 3],
    batches: AtomicU64,
    batched_samples: AtomicU64,
    window: Mutex<WindowCursor>,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        Metrics {
            start: Instant::now(),
            classes: std::array::from_fn(|_| ClassMetrics::new()),
            batches: AtomicU64::new(0),
            batched_samples: AtomicU64::new(0),
            window: Mutex::new(WindowCursor {
                prev: [ClassCounters::default(); 3],
                last_at: Instant::now(),
            }),
        }
    }

    fn lane(&self, class: QosClass) -> &ClassMetrics {
        &self.classes[class.index()]
    }

    /// Record one accepted (enqueued) request.
    pub fn record_submitted(&self, class: QosClass) {
        self.lane(class).submitted.fetch_add(1, Ordering::Relaxed);
    }

    /// Undo one `record_submitted` — the `try_submit` path counts before
    /// the non-blocking send (completed must never exceed submitted), then
    /// retracts when the send is rejected (queue full or shut down) and
    /// the request is handed back to the caller.
    pub fn retract_submitted(&self, class: QosClass) {
        self.lane(class).submitted.fetch_sub(1, Ordering::Relaxed);
    }

    /// Requests accepted but not yet resolved (queued + in flight) — the
    /// load signal the fleet's least-outstanding-requests dispatch and the
    /// adaptive batcher read. Shed and cancelled requests are resolved:
    /// they left the queue without completing.
    pub fn outstanding(&self) -> u64 {
        let mut submitted = 0u64;
        let mut resolved = 0u64;
        for lane in &self.classes {
            submitted += lane.submitted.load(Ordering::Relaxed);
            resolved += lane.completed.load(Ordering::Relaxed)
                + lane.errors.load(Ordering::Relaxed)
                + lane.shed.load(Ordering::Relaxed)
                + lane.cancelled.load(Ordering::Relaxed);
        }
        submitted.saturating_sub(resolved)
    }

    /// Record one completed request with its end-to-end latency.
    pub fn record(&self, class: QosClass, latency: Duration) {
        let lane = self.lane(class);
        lane.completed.fetch_add(1, Ordering::Relaxed);
        let us = latency.as_micros() as u64;
        {
            let mut l = lane.latencies_us.lock().unwrap();
            if l.len() < RESERVOIR {
                l.push(us);
            }
        }
        let mut w = lane.window_latencies_us.lock().unwrap();
        if w.len() < WINDOW_RESERVOIR {
            w.push(us);
        }
    }

    pub fn record_error(&self, class: QosClass) {
        self.lane(class).errors.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one expired-deadline request dropped before execution.
    pub fn record_shed(&self, class: QosClass) {
        self.lane(class).shed.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one cancelled request dropped before execution.
    pub fn record_cancelled(&self, class: QosClass) {
        self.lane(class).cancelled.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one request that executed but finished past its deadline
    /// (also counted in `completed`; the reply is still delivered).
    pub fn record_deadline_missed(&self, class: QosClass) {
        self.lane(class).deadline_missed.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one executed batch of `n` samples.
    pub fn record_batch(&self, n: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_samples.fetch_add(n as u64, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let quantiles = |lat: &mut Vec<u64>| {
            lat.sort_unstable();
            let latf: Vec<f64> = lat.iter().map(|&v| v as f64).collect();
            let q = |p: f64| if latf.is_empty() { 0.0 } else { percentile_sorted(&latf, p) };
            (q(50.0), q(95.0), q(99.0))
        };
        let mut all_lat: Vec<u64> = Vec::new();
        let per_class: [ClassSnapshot; 3] = std::array::from_fn(|i| {
            let lane = &self.classes[i];
            let mut lat = lane.latencies_us.lock().unwrap().clone();
            all_lat.extend_from_slice(&lat);
            let (p50_us, p95_us, p99_us) = quantiles(&mut lat);
            ClassSnapshot {
                class: QosClass::ALL[i],
                submitted: lane.submitted.load(Ordering::Relaxed),
                completed: lane.completed.load(Ordering::Relaxed),
                errors: lane.errors.load(Ordering::Relaxed),
                shed: lane.shed.load(Ordering::Relaxed),
                cancelled: lane.cancelled.load(Ordering::Relaxed),
                deadline_missed: lane.deadline_missed.load(Ordering::Relaxed),
                p50_us,
                p95_us,
                p99_us,
            }
        });
        let (p50_us, p95_us, p99_us) = quantiles(&mut all_lat);
        let sum = |f: fn(&ClassSnapshot) -> u64| per_class.iter().map(f).sum::<u64>();
        let batches = self.batches.load(Ordering::Relaxed);
        let samples = self.batched_samples.load(Ordering::Relaxed);
        MetricsSnapshot {
            submitted: sum(|c| c.submitted),
            completed: sum(|c| c.completed),
            errors: sum(|c| c.errors),
            shed: sum(|c| c.shed),
            cancelled: sum(|c| c.cancelled),
            deadline_missed: sum(|c| c.deadline_missed),
            elapsed: self.start.elapsed(),
            p50_us,
            p95_us,
            p99_us,
            mean_batch: if batches > 0 { samples as f64 / batches as f64 } else { 0.0 },
            per_class,
        }
    }

    /// Per-class **deltas since the previous `window()` call** plus the
    /// window's own latency quantiles — the rate view a controller scales
    /// on. Advances the window cursor and drains the window latency
    /// buffers: keep one consumer per deployment (the fleet tick loop).
    pub fn window(&self) -> WindowSnapshot {
        let mut cursor = self.window.lock().unwrap();
        let elapsed = cursor.last_at.elapsed();
        cursor.last_at = Instant::now();
        let per_class: [ClassWindow; 3] = std::array::from_fn(|i| {
            let lane = &self.classes[i];
            let now = lane.counters();
            let prev = cursor.prev[i];
            cursor.prev[i] = now;
            let mut lat = std::mem::take(&mut *lane.window_latencies_us.lock().unwrap());
            lat.sort_unstable();
            let latf: Vec<f64> = lat.iter().map(|&v| v as f64).collect();
            let q = |p: f64| if latf.is_empty() { 0.0 } else { percentile_sorted(&latf, p) };
            ClassWindow {
                class: QosClass::ALL[i],
                // saturating: a `retract_submitted` racing the window edge
                // may make a counter read lower than the cursor's copy
                submitted: now.submitted.saturating_sub(prev.submitted),
                completed: now.completed.saturating_sub(prev.completed),
                errors: now.errors.saturating_sub(prev.errors),
                shed: now.shed.saturating_sub(prev.shed),
                cancelled: now.cancelled.saturating_sub(prev.cancelled),
                deadline_missed: now.deadline_missed.saturating_sub(prev.deadline_missed),
                p50_us: q(50.0),
                p95_us: q(95.0),
            }
        });
        WindowSnapshot { elapsed, per_class }
    }
}

/// One class's lane in a [`WindowSnapshot`]: counter deltas over the
/// window plus the window's own latency quantiles.
#[derive(Clone, Copy, Debug)]
pub struct ClassWindow {
    pub class: QosClass,
    pub submitted: u64,
    pub completed: u64,
    pub errors: u64,
    pub shed: u64,
    pub cancelled: u64,
    pub deadline_missed: u64,
    pub p50_us: f64,
    pub p95_us: f64,
}

/// Deltas since the previous [`Metrics::window`] call — what the
/// autoscaler (and any periodic health line) reads instead of lifetime
/// totals.
#[derive(Clone, Copy, Debug)]
pub struct WindowSnapshot {
    /// Wall time covered by this window.
    pub elapsed: Duration,
    pub per_class: [ClassWindow; 3],
}

impl WindowSnapshot {
    pub fn class(&self, class: QosClass) -> &ClassWindow {
        &self.per_class[class.index()]
    }

    fn sum(&self, f: fn(&ClassWindow) -> u64) -> u64 {
        self.per_class.iter().map(f).sum()
    }

    /// Requests accepted during the window (all classes).
    pub fn submitted(&self) -> u64 {
        self.sum(|c| c.submitted)
    }

    /// Requests completed during the window (all classes).
    pub fn completed(&self) -> u64 {
        self.sum(|c| c.completed)
    }

    /// Expired-deadline requests shed during the window (all classes).
    pub fn shed(&self) -> u64 {
        self.sum(|c| c.shed)
    }

    /// Requests delivered past their deadline during the window.
    pub fn deadline_missed(&self) -> u64 {
        self.sum(|c| c.deadline_missed)
    }

    /// Errors during the window (all classes).
    pub fn errors(&self) -> u64 {
        self.sum(|c| c.errors)
    }

    /// `count` as a per-second rate over this window's wall time.
    pub fn per_sec(&self, count: u64) -> f64 {
        count as f64 / self.elapsed.as_secs_f64().max(1e-9)
    }
}

impl std::fmt::Display for WindowSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "window {:.2}s | {:.0} req/s in, {:.0} req/s done | {} shed, {} late",
            self.elapsed.as_secs_f64(),
            self.per_sec(self.submitted()),
            self.per_sec(self.completed()),
            self.shed(),
            self.deadline_missed(),
        )?;
        for c in self.per_class.iter().filter(|c| c.submitted > 0 || c.completed > 0) {
            write!(f, " | {} {}/{} p95 {:.0}us", c.class.name(), c.completed, c.submitted, c.p95_us)?;
        }
        Ok(())
    }
}

/// One class's lane in a [`MetricsSnapshot`].
#[derive(Clone, Copy, Debug)]
pub struct ClassSnapshot {
    pub class: QosClass,
    pub submitted: u64,
    pub completed: u64,
    pub errors: u64,
    pub shed: u64,
    pub cancelled: u64,
    pub deadline_missed: u64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
}

impl ClassSnapshot {
    /// Any traffic in this lane at all?
    pub fn is_active(&self) -> bool {
        self.submitted > 0
    }
}

/// A point-in-time metrics view. The flat fields are totals, always equal
/// to the sum of the `per_class` lanes.
#[derive(Clone, Copy, Debug)]
pub struct MetricsSnapshot {
    pub submitted: u64,
    pub completed: u64,
    pub errors: u64,
    pub shed: u64,
    pub cancelled: u64,
    pub deadline_missed: u64,
    pub elapsed: Duration,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub mean_batch: f64,
    pub per_class: [ClassSnapshot; 3],
}

impl MetricsSnapshot {
    pub fn throughput_rps(&self) -> f64 {
        self.completed as f64 / self.elapsed.as_secs_f64().max(1e-9)
    }

    pub fn class(&self, class: QosClass) -> &ClassSnapshot {
        &self.per_class[class.index()]
    }
}

impl std::fmt::Display for MetricsSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}/{} done ({} err, {} shed, {} canc, {} late) in {:.2}s | {:.0} req/s | p50 {:.0}us p95 {:.0}us p99 {:.0}us | mean batch {:.2}",
            self.completed,
            self.submitted,
            self.errors,
            self.shed,
            self.cancelled,
            self.deadline_missed,
            self.elapsed.as_secs_f64(),
            self.throughput_rps(),
            self.p50_us,
            self.p95_us,
            self.p99_us,
            self.mean_batch
        )?;
        for c in self.per_class.iter().filter(|c| c.is_active()) {
            write!(
                f,
                " | {} {}/{} p95 {:.0}us",
                c.class.name(),
                c.completed,
                c.submitted,
                c.p95_us
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new();
        for us in [100u64, 200, 300, 400, 500] {
            m.record_submitted(QosClass::Bulk);
            m.record(QosClass::Bulk, Duration::from_micros(us));
        }
        m.record_batch(5);
        let s = m.snapshot();
        assert_eq!(s.submitted, 5);
        assert_eq!(s.completed, 5);
        assert_eq!(s.p50_us, 300.0);
        assert_eq!(s.mean_batch, 5.0);
        assert!(s.throughput_rps() > 0.0);
        assert_eq!(s.class(QosClass::Bulk).completed, 5);
        assert_eq!(s.class(QosClass::Interactive).completed, 0);
    }

    #[test]
    fn per_class_lanes_sum_to_totals() {
        let m = Metrics::new();
        m.record_submitted(QosClass::Interactive);
        m.record(QosClass::Interactive, Duration::from_micros(50));
        m.record_submitted(QosClass::Bulk);
        m.record_shed(QosClass::Bulk);
        m.record_submitted(QosClass::Background);
        m.record_cancelled(QosClass::Background);
        m.record_submitted(QosClass::Bulk);
        m.record(QosClass::Bulk, Duration::from_micros(900));
        m.record_deadline_missed(QosClass::Bulk);
        let s = m.snapshot();
        assert_eq!(s.submitted, 4);
        assert_eq!(s.completed, 2);
        assert_eq!(s.shed, 1);
        assert_eq!(s.cancelled, 1);
        assert_eq!(s.deadline_missed, 1);
        let lane_sum = |f: fn(&ClassSnapshot) -> u64| s.per_class.iter().map(f).sum::<u64>();
        assert_eq!(lane_sum(|c| c.submitted), s.submitted);
        assert_eq!(lane_sum(|c| c.completed), s.completed);
        assert_eq!(lane_sum(|c| c.errors), s.errors);
        assert_eq!(lane_sum(|c| c.shed), s.shed);
        assert_eq!(lane_sum(|c| c.cancelled), s.cancelled);
        assert_eq!(lane_sum(|c| c.deadline_missed), s.deadline_missed);
    }

    #[test]
    fn outstanding_counts_shed_and_cancelled_as_resolved() {
        let m = Metrics::new();
        for _ in 0..5 {
            m.record_submitted(QosClass::Bulk);
        }
        assert_eq!(m.outstanding(), 5);
        m.record(QosClass::Bulk, Duration::from_micros(10));
        m.record_error(QosClass::Bulk);
        assert_eq!(m.outstanding(), 3);
        m.record_shed(QosClass::Bulk);
        m.record_cancelled(QosClass::Bulk);
        assert_eq!(m.outstanding(), 1);
        assert_eq!(m.snapshot().submitted, 5);
    }

    #[test]
    fn retract_submitted_balances_a_rejected_try_submit() {
        let m = Metrics::new();
        m.record_submitted(QosClass::Interactive);
        m.retract_submitted(QosClass::Interactive);
        assert_eq!(m.outstanding(), 0);
        assert_eq!(m.snapshot().submitted, 0);
    }

    #[test]
    fn empty_snapshot_is_zeroes() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.completed, 0);
        assert_eq!(s.p99_us, 0.0);
        assert_eq!(s.shed, 0);
    }

    #[test]
    fn window_reads_deltas_not_lifetime_totals() {
        let m = Metrics::new();
        for _ in 0..3 {
            m.record_submitted(QosClass::Bulk);
            m.record_shed(QosClass::Bulk);
        }
        let w1 = m.window();
        assert_eq!(w1.submitted(), 3);
        assert_eq!(w1.shed(), 3);
        // a quiet second window reports zero even though lifetime totals
        // still carry the earlier sheds
        let w2 = m.window();
        assert_eq!(w2.submitted(), 0);
        assert_eq!(w2.shed(), 0, "window must not re-report consumed sheds");
        assert_eq!(m.snapshot().shed, 3, "lifetime totals are untouched");
        // fresh activity shows up in the next window only
        m.record_submitted(QosClass::Interactive);
        m.record_deadline_missed(QosClass::Interactive);
        let w3 = m.window();
        assert_eq!(w3.class(QosClass::Interactive).submitted, 1);
        assert_eq!(w3.deadline_missed(), 1);
        assert_eq!(w3.class(QosClass::Bulk).shed, 0);
    }

    #[test]
    fn window_latency_quantiles_cover_only_the_window() {
        let m = Metrics::new();
        m.record_submitted(QosClass::Interactive);
        m.record(QosClass::Interactive, Duration::from_micros(10_000));
        let w1 = m.window();
        assert_eq!(w1.class(QosClass::Interactive).p95_us, 10_000.0);
        // the slow request must not haunt later windows (lifetime p95 keeps it)
        m.record_submitted(QosClass::Interactive);
        m.record(QosClass::Interactive, Duration::from_micros(100));
        let w2 = m.window();
        assert_eq!(w2.class(QosClass::Interactive).p95_us, 100.0);
        assert_eq!(w2.completed(), 1);
        assert!(m.snapshot().p95_us >= 100.0);
    }

    #[test]
    fn window_survives_a_retract_across_the_edge() {
        let m = Metrics::new();
        m.record_submitted(QosClass::Bulk);
        let w1 = m.window();
        assert_eq!(w1.submitted(), 1);
        // a rejected try_submit retracts after the cursor advanced: the
        // next delta saturates at zero instead of underflowing
        m.retract_submitted(QosClass::Bulk);
        let w2 = m.window();
        assert_eq!(w2.submitted(), 0);
    }
}
