//! Serving metrics (DESIGN.md S16): latency quantiles + throughput.
//!
//! Lock-guarded reservoir of recent latencies plus monotonic counters.
//! Cheap enough for the request path (one mutex lock per completion; the
//! e2e bench shows the coordinator is not the bottleneck — EXPERIMENTS.md
//! §Perf).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::util::stats::percentile_sorted;

const RESERVOIR: usize = 65_536;

/// Shared metrics sink.
pub struct Metrics {
    start: Instant,
    submitted: AtomicU64,
    completed: AtomicU64,
    errors: AtomicU64,
    batches: AtomicU64,
    batched_samples: AtomicU64,
    latencies_us: Mutex<Vec<u64>>,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        Metrics {
            start: Instant::now(),
            submitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batched_samples: AtomicU64::new(0),
            latencies_us: Mutex::new(Vec::with_capacity(4096)),
        }
    }

    /// Record one accepted (enqueued) request.
    pub fn record_submitted(&self) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
    }

    /// Requests accepted but not yet answered (queued + in flight) — the
    /// load signal the fleet's least-outstanding-requests dispatch and the
    /// adaptive batcher read.
    pub fn outstanding(&self) -> u64 {
        let submitted = self.submitted.load(Ordering::Relaxed);
        let done = self.completed.load(Ordering::Relaxed) + self.errors.load(Ordering::Relaxed);
        submitted.saturating_sub(done)
    }

    /// Record one completed request with its end-to-end latency.
    pub fn record(&self, latency: Duration) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        let mut l = self.latencies_us.lock().unwrap();
        if l.len() < RESERVOIR {
            l.push(latency.as_micros() as u64);
        }
    }

    pub fn record_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one executed batch of `n` samples.
    pub fn record_batch(&self, n: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_samples.fetch_add(n as u64, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let mut lat = self.latencies_us.lock().unwrap().clone();
        lat.sort_unstable();
        let latf: Vec<f64> = lat.iter().map(|&v| v as f64).collect();
        let q = |p: f64| if latf.is_empty() { 0.0 } else { percentile_sorted(&latf, p) };
        let completed = self.completed.load(Ordering::Relaxed);
        let batches = self.batches.load(Ordering::Relaxed);
        let samples = self.batched_samples.load(Ordering::Relaxed);
        MetricsSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed,
            errors: self.errors.load(Ordering::Relaxed),
            elapsed: self.start.elapsed(),
            p50_us: q(50.0),
            p95_us: q(95.0),
            p99_us: q(99.0),
            mean_batch: if batches > 0 { samples as f64 / batches as f64 } else { 0.0 },
        }
    }
}

/// A point-in-time metrics view.
#[derive(Clone, Copy, Debug)]
pub struct MetricsSnapshot {
    pub submitted: u64,
    pub completed: u64,
    pub errors: u64,
    pub elapsed: Duration,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub mean_batch: f64,
}

impl MetricsSnapshot {
    pub fn throughput_rps(&self) -> f64 {
        self.completed as f64 / self.elapsed.as_secs_f64().max(1e-9)
    }
}

impl std::fmt::Display for MetricsSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}/{} done ({} err) in {:.2}s | {:.0} req/s | p50 {:.0}us p95 {:.0}us p99 {:.0}us | mean batch {:.2}",
            self.completed,
            self.submitted,
            self.errors,
            self.elapsed.as_secs_f64(),
            self.throughput_rps(),
            self.p50_us,
            self.p95_us,
            self.p99_us,
            self.mean_batch
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new();
        for us in [100u64, 200, 300, 400, 500] {
            m.record_submitted();
            m.record(Duration::from_micros(us));
        }
        m.record_batch(5);
        let s = m.snapshot();
        assert_eq!(s.submitted, 5);
        assert_eq!(s.completed, 5);
        assert_eq!(s.p50_us, 300.0);
        assert_eq!(s.mean_batch, 5.0);
        assert!(s.throughput_rps() > 0.0);
    }

    #[test]
    fn outstanding_tracks_submitted_minus_done() {
        let m = Metrics::new();
        for _ in 0..5 {
            m.record_submitted();
        }
        assert_eq!(m.outstanding(), 5);
        m.record(Duration::from_micros(10));
        m.record_error();
        assert_eq!(m.outstanding(), 3);
        assert_eq!(m.snapshot().submitted, 5);
    }

    #[test]
    fn empty_snapshot_is_zeroes() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.completed, 0);
        assert_eq!(s.p99_us, 0.0);
    }
}
