//! Pool-level resilience policies: circuit breaking and replica health.
//!
//! Both policies follow the autoscaler's design contract
//! ([`autoscale`](super::autoscale)): **pure, tick-driven state machines**
//! with no wall clock anywhere — one `step` consumes one windowed
//! observation and time is counted in consecutive `step` calls, so every
//! transition is unit-testable without threads or sleeps. The production
//! driver is [`Fleet::tick`](super::fleet::Fleet::tick), which feeds both
//! policies from the *same* consumed metrics window it hands the
//! autoscaler (the window cursor has a single consumer).
//!
//! ## The circuit breaker ([`BreakerCore`])
//!
//! Classic three-state breaker, one per pool:
//!
//! * **Closed** (normal) → **Open** when a window resolves at least
//!   [`BreakerPolicy::min_window_requests`] requests and the failed
//!   fraction reaches [`BreakerPolicy::open_error_rate`]. "Resolved"
//!   deliberately means `completed + failed` — admission sheds are
//!   excluded, otherwise the brownout the breaker itself causes (shedding
//!   Background/Bulk at admission) would hold it open forever;
//! * **Open** → **HalfOpen** after [`BreakerPolicy::open_ticks`]
//!   consecutive ticks. While open, the pool browns out: Background and
//!   Bulk are shed at admission, Interactive still flows (the live
//!   traffic doubles as the probe);
//! * **HalfOpen** → **Open** on any windowed failure, → **Closed** on a
//!   clean window with at least one resolved request, and stays put on a
//!   window with no traffic at all (no evidence either way).
//!
//! ## Replica health ([`HealthPolicy`])
//!
//! Decides which *individual* replicas to eject, from the per-replica
//! counters workers feed into
//! [`ReplicaHealth`](super::metrics::ReplicaHealth): a replica is
//! unhealthy on an unbroken run of
//! [`HealthPolicy::eject_consecutive_failures`] failed batches (the
//! wedged-replica signature), or on a windowed batch error rate at or
//! over [`HealthPolicy::eject_error_rate`] once the window has at least
//! [`HealthPolicy::min_window_batches`] batches. The fleet's tick ejects
//! the named replicas via
//! [`Server::eject_replica`](super::server::Server::eject_replica), after
//! provisioning warm replacements so the pool never dips below its floor.

use std::sync::Arc;

use super::metrics::{ReplicaHealth, ReplicaPhase};

/// Per-pool circuit-breaker thresholds. All windows are metric windows,
/// all durations are control ticks — no wall clock.
#[derive(Clone, Copy, Debug)]
pub struct BreakerPolicy {
    /// Failed fraction of resolved (`completed + failed`) requests in one
    /// window at which a closed breaker opens (0.0–1.0].
    pub open_error_rate: f64,
    /// Windows with fewer resolved requests than this never trip the
    /// breaker (one early failure must not brown out an idle pool).
    pub min_window_requests: u64,
    /// Consecutive ticks a breaker stays open before probing (half-open).
    pub open_ticks: u32,
}

impl BreakerPolicy {
    /// Defaults: open at a 50% windowed error rate over at least 4
    /// resolved requests, probe after 2 open ticks.
    pub fn new() -> BreakerPolicy {
        BreakerPolicy { open_error_rate: 0.5, min_window_requests: 4, open_ticks: 2 }
    }

    pub fn open_error_rate(mut self, rate: f64) -> BreakerPolicy {
        self.open_error_rate = rate.clamp(f64::MIN_POSITIVE, 1.0);
        self
    }

    pub fn min_window_requests(mut self, n: u64) -> BreakerPolicy {
        self.min_window_requests = n.max(1);
        self
    }

    pub fn open_ticks(mut self, n: u32) -> BreakerPolicy {
        self.open_ticks = n.max(1);
        self
    }
}

impl Default for BreakerPolicy {
    fn default() -> Self {
        BreakerPolicy::new()
    }
}

/// The breaker's position. Mirrored into an atomic on the pool so the
/// admission path reads it lock-free.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BreakerState {
    /// Normal service: every class admitted.
    Closed,
    /// Brownout: Background and Bulk shed at admission; Interactive still
    /// admitted (it is the probe traffic).
    Open,
    /// Probation: admission behaves as Closed while the next windows
    /// decide between re-closing and re-opening.
    HalfOpen,
}

impl BreakerState {
    /// Stable lowercase name (logs, snapshots, JSON).
    pub fn name(self) -> &'static str {
        match self {
            BreakerState::Closed => "closed",
            BreakerState::Open => "open",
            BreakerState::HalfOpen => "half-open",
        }
    }

    /// Encoding for the pool's lock-free admission mirror.
    pub fn as_u8(self) -> u8 {
        match self {
            BreakerState::Closed => 0,
            BreakerState::Open => 1,
            BreakerState::HalfOpen => 2,
        }
    }

    pub fn from_u8(v: u8) -> BreakerState {
        match v {
            1 => BreakerState::Open,
            2 => BreakerState::HalfOpen,
            _ => BreakerState::Closed,
        }
    }

    /// Whether a request of the given class passes admission under this
    /// breaker state. Only an *open* breaker sheds, and it never sheds
    /// Interactive — brownout degrades batch work first.
    pub fn admits_background_work(self) -> bool {
        self != BreakerState::Open
    }
}

impl std::fmt::Display for BreakerState {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// The breaker's entire mutable state: its position plus how many ticks
/// it has been open. One [`BreakerCore::step`] per control tick.
#[derive(Clone, Copy, Debug)]
pub struct BreakerCore {
    state: BreakerState,
    ticks_open: u32,
}

impl BreakerCore {
    pub fn new() -> BreakerCore {
        BreakerCore { state: BreakerState::Closed, ticks_open: 0 }
    }

    pub fn state(&self) -> BreakerState {
        self.state
    }

    /// Consume one window's `resolved` (= completed + failed, sheds
    /// excluded) and `failed` counts; return the state the breaker is in
    /// *after* this tick. Pure with respect to everything but `self`.
    pub fn step(&mut self, policy: &BreakerPolicy, resolved: u64, failed: u64) -> BreakerState {
        match self.state {
            BreakerState::Closed => {
                if resolved >= policy.min_window_requests
                    && failed as f64 >= policy.open_error_rate * resolved as f64
                {
                    self.state = BreakerState::Open;
                    self.ticks_open = 0;
                }
            }
            BreakerState::Open => {
                self.ticks_open += 1;
                if self.ticks_open >= policy.open_ticks {
                    self.state = BreakerState::HalfOpen;
                }
            }
            BreakerState::HalfOpen => {
                if failed > 0 {
                    // the probe window failed: back to open, full timer
                    self.state = BreakerState::Open;
                    self.ticks_open = 0;
                } else if resolved > 0 {
                    self.state = BreakerState::Closed;
                }
                // a window with no traffic proves nothing: stay half-open
            }
        }
        self.state
    }
}

impl Default for BreakerCore {
    fn default() -> Self {
        BreakerCore::new()
    }
}

/// Per-replica ejection thresholds.
#[derive(Clone, Copy, Debug)]
pub struct HealthPolicy {
    /// Unbroken run of failed batches at which a replica is ejected (the
    /// wedged-replica signature — a wedge never succeeds again, so the
    /// streak only grows).
    pub eject_consecutive_failures: u32,
    /// Windowed batch failure fraction at which a replica is ejected.
    pub eject_error_rate: f64,
    /// Windows with fewer batches than this never trip the rate rule.
    pub min_window_batches: u64,
}

impl HealthPolicy {
    /// Defaults: eject on 3 consecutive failed batches, or a 50% windowed
    /// batch error rate over at least 4 batches.
    pub fn new() -> HealthPolicy {
        HealthPolicy {
            eject_consecutive_failures: 3,
            eject_error_rate: 0.5,
            min_window_batches: 4,
        }
    }

    pub fn eject_consecutive_failures(mut self, n: u32) -> HealthPolicy {
        self.eject_consecutive_failures = n.max(1);
        self
    }

    pub fn eject_error_rate(mut self, rate: f64) -> HealthPolicy {
        self.eject_error_rate = rate.clamp(f64::MIN_POSITIVE, 1.0);
        self
    }

    pub fn min_window_batches(mut self, n: u64) -> HealthPolicy {
        self.min_window_batches = n.max(1);
        self
    }

    /// Labels of the live replicas this tick finds unhealthy. Drains
    /// every live replica's batch window (the per-tick delta) — single
    /// consumer, like the metrics window cursor: only the fleet tick may
    /// call this.
    pub fn unhealthy(&self, replicas: &[Arc<ReplicaHealth>]) -> Vec<String> {
        let mut out = Vec::new();
        for h in replicas {
            if h.phase() != ReplicaPhase::Live {
                continue;
            }
            let (batches, failures) = h.drain_window();
            let streak = h.consecutive_failures() >= self.eject_consecutive_failures;
            let rate = batches >= self.min_window_batches
                && failures as f64 >= self.eject_error_rate * batches as f64;
            if streak || rate {
                out.push(h.label().to_string());
            }
        }
        out
    }
}

impl Default for HealthPolicy {
    fn default() -> Self {
        HealthPolicy::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::metrics::Metrics;

    #[test]
    fn closed_breaker_opens_only_on_a_qualified_window() {
        let p = BreakerPolicy::new(); // rate 0.5, min 4
        let mut b = BreakerCore::new();
        assert_eq!(b.step(&p, 0, 0), BreakerState::Closed, "no traffic");
        assert_eq!(b.step(&p, 3, 3), BreakerState::Closed, "under min resolved");
        assert_eq!(b.step(&p, 10, 4), BreakerState::Closed, "40% < 50%");
        assert_eq!(b.step(&p, 10, 5), BreakerState::Open, "50% trips at the threshold");
    }

    #[test]
    fn open_breaker_half_opens_after_its_timer() {
        let p = BreakerPolicy::new().open_ticks(2);
        let mut b = BreakerCore::new();
        b.step(&p, 4, 4);
        assert_eq!(b.state(), BreakerState::Open);
        // traffic during the open phase is irrelevant: only ticks count
        assert_eq!(b.step(&p, 9, 9), BreakerState::Open, "one open tick");
        assert_eq!(b.step(&p, 0, 0), BreakerState::HalfOpen, "second open tick probes");
    }

    #[test]
    fn half_open_closes_on_a_clean_window() {
        let p = BreakerPolicy::new().open_ticks(1);
        let mut b = BreakerCore::new();
        b.step(&p, 4, 4); // open
        b.step(&p, 0, 0); // half-open
        assert_eq!(b.state(), BreakerState::HalfOpen);
        assert_eq!(b.step(&p, 0, 0), BreakerState::HalfOpen, "no traffic proves nothing");
        assert_eq!(b.step(&p, 1, 0), BreakerState::Closed, "one clean resolve closes");
    }

    #[test]
    fn half_open_reopens_on_any_failure() {
        let p = BreakerPolicy::new().open_ticks(1);
        let mut b = BreakerCore::new();
        b.step(&p, 4, 4); // open
        b.step(&p, 0, 0); // half-open
        // a single failure re-opens even though the window is tiny —
        // probation has no min-traffic grace
        assert_eq!(b.step(&p, 3, 1), BreakerState::Open);
        // and the open timer starts over
        assert_eq!(b.step(&p, 0, 0), BreakerState::HalfOpen);
    }

    #[test]
    fn only_the_open_state_sheds_and_never_interactive() {
        assert!(BreakerState::Closed.admits_background_work());
        assert!(BreakerState::HalfOpen.admits_background_work());
        assert!(!BreakerState::Open.admits_background_work());
    }

    #[test]
    fn breaker_state_round_trips_through_the_atomic_encoding() {
        for s in [BreakerState::Closed, BreakerState::Open, BreakerState::HalfOpen] {
            assert_eq!(BreakerState::from_u8(s.as_u8()), s);
        }
        assert_eq!(BreakerState::from_u8(250), BreakerState::Closed, "garbage decodes closed");
    }

    #[test]
    fn policy_builders_clamp_degenerate_values() {
        let p = BreakerPolicy::new().open_error_rate(0.0).min_window_requests(0).open_ticks(0);
        assert!(p.open_error_rate > 0.0);
        assert_eq!(p.min_window_requests, 1);
        assert_eq!(p.open_ticks, 1);
        let h = HealthPolicy::new()
            .eject_consecutive_failures(0)
            .eject_error_rate(7.0)
            .min_window_batches(0);
        assert_eq!(h.eject_consecutive_failures, 1);
        assert!(h.eject_error_rate <= 1.0);
        assert_eq!(h.min_window_batches, 1);
    }

    #[test]
    fn health_policy_flags_a_failure_streak() {
        let m = Metrics::new();
        let flaky = m.register_replica("p/flaky");
        let fine = m.register_replica("p/fine");
        for _ in 0..3 {
            flaky.record_failure();
            fine.record_success();
        }
        let hp = HealthPolicy::new().eject_consecutive_failures(3);
        assert_eq!(hp.unhealthy(&m.replica_handles()), vec!["p/flaky".to_string()]);
    }

    #[test]
    fn health_policy_flags_a_windowed_error_rate() {
        let m = Metrics::new();
        let h = m.register_replica("p/0");
        // failures interleaved with successes: the streak never reaches 3,
        // but the windowed rate is 50%
        for _ in 0..2 {
            h.record_failure();
            h.record_success();
        }
        let hp = HealthPolicy::new().eject_consecutive_failures(3).min_window_batches(4);
        assert_eq!(hp.unhealthy(&m.replica_handles()), vec!["p/0".to_string()]);
    }

    #[test]
    fn health_windows_are_per_tick_deltas() {
        let m = Metrics::new();
        let h = m.register_replica("p/0");
        h.record_failure();
        h.record_success();
        let hp = HealthPolicy::new().min_window_batches(2).eject_error_rate(0.5);
        assert_eq!(hp.unhealthy(&m.replica_handles()), vec!["p/0".to_string()]);
        // the flagged replica was NOT ejected (policy only names; the
        // fleet decides) — next tick sees a fresh, sub-minimum window
        assert!(hp.unhealthy(&m.replica_handles()).is_empty());
    }

    #[test]
    fn non_live_replicas_are_never_re_flagged() {
        let m = Metrics::new();
        let h = m.register_replica("p/0");
        for _ in 0..5 {
            h.record_failure();
        }
        h.quarantine();
        let hp = HealthPolicy::new();
        assert!(hp.unhealthy(&m.replica_handles()).is_empty(), "quarantined is already handled");
        h.mark_ejected();
        assert!(hp.unhealthy(&m.replica_handles()).is_empty());
    }
}
