//! Worker-pool serving loop (DESIGN.md S16).
//!
//! `Server` owns one worker thread per [`Session`] replica, fed by a
//! bounded channel of [`Pending`] request entries. Submission is typed
//! ([`Request`] in, [`Ticket`] out): `submit` keeps the classic blocking
//! backpressure, `try_submit` surfaces a full queue as
//! [`SubmitError::QueueFull`] instead of blocking. Each worker runs the
//! QoS-aware dynamic batcher (single-class batches; expired-deadline and
//! cancelled entries shed before execution) and executes the batch with
//! the session's allocation-free `run_batch_into` — the packed input and
//! output staging buffers are reused across batches, so the steady-state
//! request path allocates only the per-request reply vectors.
//! std::thread + mpsc (no tokio offline — DESIGN.md §7).

use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::Result;

use super::batcher::{next_batch, AdaptiveBatcher, BatcherConfig};
use super::metrics::Metrics;
use super::request::{Pending, Request, SubmitError, Ticket};
use crate::api::{IoSignature, Session};
use crate::tensor::quant::QParams;

/// Server configuration.
#[derive(Clone, Copy, Debug)]
pub struct ServerConfig {
    pub queue_depth: usize,
    pub batcher: BatcherConfig,
    /// Let each worker tune its own effective [`BatcherConfig`] from the
    /// observed queue depth (see
    /// [`AdaptiveBatcher`](super::batcher::AdaptiveBatcher)). Off by
    /// default; the fleet turns it on for its replica pools.
    pub adaptive: bool,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { queue_depth: 256, batcher: BatcherConfig::default(), adaptive: false }
    }
}

/// A serving endpoint for one model — one replica pool: worker threads
/// sharing a bounded queue. A [`Fleet`](super::fleet::Fleet) holds several
/// of these and dispatches across them.
pub struct Server {
    tx: SyncSender<Pending>,
    workers: Vec<JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
    signature: IoSignature,
    input_len: usize,
    input_qparams: QParams,
    output_qparams: QParams,
    replicas: usize,
}

impl Server {
    /// Start a server over a set of session replicas (one worker each).
    ///
    /// Replicas are built with [`crate::api::Session::builder`]; mixing
    /// engines across replicas is allowed as long as they serve the same
    /// model signature.
    pub fn start(sessions: Vec<Session>, cfg: ServerConfig) -> Result<Server> {
        anyhow::ensure!(!sessions.is_empty(), "need at least one session");
        let sig = sessions[0].signature().clone();
        let input_len = sig.input_len();
        let input_qparams = sig.input.qparams;
        let output_qparams = sig.output.qparams;
        let replicas = sessions.len();
        for s in &sessions[1..] {
            anyhow::ensure!(
                *s.signature() == sig,
                "replica signatures diverge: {:?} vs {:?}",
                s.signature(),
                sig
            );
        }
        let metrics = Arc::new(Metrics::new());
        let (tx, rx) = sync_channel::<Pending>(cfg.queue_depth);
        let shared_rx = Arc::new(std::sync::Mutex::new(rx));
        let mut workers = Vec::new();
        for mut session in sessions {
            let rx = Arc::clone(&shared_rx);
            let metrics = Arc::clone(&metrics);
            let bcfg = BatcherConfig {
                max_batch: cfg.batcher.max_batch.min(session.preferred_batch().max(1)),
                max_wait: cfg.batcher.max_wait,
            };
            let adaptive = cfg.adaptive;
            workers.push(std::thread::spawn(move || {
                worker_loop(&mut session, &rx, &bcfg, adaptive, replicas, &metrics);
            }));
        }
        Ok(Server {
            tx,
            workers,
            metrics,
            signature: sig,
            input_len,
            input_qparams,
            output_qparams,
            replicas,
        })
    }

    pub fn signature(&self) -> &IoSignature {
        &self.signature
    }

    pub fn input_len(&self) -> usize {
        self.input_len
    }

    /// Number of session replicas (worker threads) serving this pool.
    pub fn replicas(&self) -> usize {
        self.replicas
    }

    pub fn input_qparams(&self) -> QParams {
        self.input_qparams
    }

    pub fn output_qparams(&self) -> QParams {
        self.output_qparams
    }

    /// Submit a typed request; returns its [`Ticket`]. Blocks when the
    /// queue is full (backpressure) — use [`Server::try_submit`] for an
    /// explicit [`SubmitError::QueueFull`] instead.
    pub fn submit(&self, req: Request) -> Result<Ticket> {
        anyhow::ensure!(
            req.payload.len() == self.input_len,
            "input length {} != model input length {}",
            req.payload.len(),
            self.input_len
        );
        let class = req.class;
        let (pending, ticket) = req.into_pending();
        // count BEFORE the send: a worker may complete the request before
        // this thread resumes, and completed must never exceed submitted
        // (outstanding() would under-report and misroute fleet dispatch)
        self.metrics.record_submitted(class);
        if self.tx.send(pending).is_err() {
            // balance the counter so outstanding() stays accurate
            self.metrics.record_error(class);
            anyhow::bail!("server is shut down");
        }
        Ok(ticket)
    }

    /// Non-blocking submit: a full queue is an explicit
    /// [`SubmitError::QueueFull`] handing the request back to the caller
    /// (retry, spill to another pool, or shed).
    pub fn try_submit(&self, req: Request) -> std::result::Result<Ticket, SubmitError> {
        if req.payload.len() != self.input_len {
            return Err(SubmitError::InputLength {
                expected: self.input_len,
                got: req.payload.len(),
            });
        }
        let class = req.class;
        let (pending, ticket) = req.into_pending();
        self.metrics.record_submitted(class);
        match self.tx.try_send(pending) {
            Ok(()) => Ok(ticket),
            Err(TrySendError::Full(p)) => {
                // the request never entered the queue: retract the count
                // and hand it back for retry/spill
                self.metrics.retract_submitted(class);
                Err(SubmitError::QueueFull(p.into_request()))
            }
            Err(TrySendError::Disconnected(p)) => {
                self.metrics.retract_submitted(class);
                Err(SubmitError::Shutdown(p.into_request()))
            }
        }
    }

    /// Submit and wait (blocking convenience; Bulk class, no deadline —
    /// the legacy semantics).
    pub fn infer(&self, input: Vec<i8>) -> Result<Vec<i8>> {
        self.submit(Request::new(input))?.wait()
    }

    /// Graceful shutdown: close the queue and join workers.
    pub fn shutdown(self) {
        drop(self.tx);
        for w in self.workers {
            let _ = w.join();
        }
    }
}

fn worker_loop(
    session: &mut Session,
    rx: &std::sync::Mutex<Receiver<Pending>>,
    cfg: &BatcherConfig,
    adaptive: bool,
    replicas: usize,
    metrics: &Metrics,
) {
    let ilen = session.input_len();
    let olen = session.output_len();
    let mut tuner = AdaptiveBatcher::new(*cfg);
    // one-slot stash for the request that ended the previous batch on a
    // class boundary; it leads this worker's next batch
    let mut carry: Option<Pending> = None;
    // staging buffers grow to the largest batch once, then are reused
    let mut inputs: Vec<i8> = Vec::new();
    let mut outputs: Vec<i8> = Vec::new();
    loop {
        // hold the lock only while assembling a batch; workers alternate
        let effective = if adaptive { tuner.config() } else { *cfg };
        let batch = {
            let rx = rx.lock().unwrap();
            next_batch(&rx, &mut carry, cfg, &effective, metrics)
        };
        let Some(batch) = batch else { return };
        if adaptive {
            // queue-depth proxy right after the cut: outstanding beyond
            // the batch this worker just claimed, averaged per replica —
            // the pool-wide counter includes sibling workers' in-flight
            // batches, which would otherwise read as phantom queue depth
            let beyond = metrics.outstanding().saturating_sub(batch.len() as u64);
            tuner.observe(beyond / (replicas as u64).max(1));
        }
        let n = batch.len();
        metrics.record_batch(n);
        inputs.clear();
        for p in &batch {
            inputs.extend_from_slice(&p.request.payload);
        }
        outputs.resize(n * olen, 0);
        debug_assert_eq!(inputs.len(), n * ilen);
        match session.run_batch_into(&inputs, n, &mut outputs[..n * olen]) {
            Ok(()) => {
                let done = Instant::now();
                for (i, p) in batch.into_iter().enumerate() {
                    let out = outputs[i * olen..(i + 1) * olen].to_vec();
                    if p.request.deadline.is_some_and(|d| done > d) {
                        // executed but late: delivered anyway, counted as
                        // an SLO miss
                        metrics.record_deadline_missed(p.request.class);
                    }
                    metrics.record(p.request.class, p.enqueued.elapsed());
                    let _ = p.reply.send(Ok(out));
                }
            }
            Err(e) => {
                let msg = format!("batch execution failed: {e:#}");
                for p in batch {
                    metrics.record_error(p.request.class);
                    let _ = p.reply.send(Err(anyhow::anyhow!(msg.clone())));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{Engine, Session};
    use crate::coordinator::request::QosClass;

    fn tiny_server(replicas: usize) -> Server {
        let sessions: Vec<Session> = (0..replicas)
            .map(|_| {
                Session::builder(crate::format::mfb::tests::tiny_mfb())
                    .engine(Engine::MicroFlow)
                    .build()
                    .unwrap()
            })
            .collect();
        Server::start(sessions, ServerConfig::default()).unwrap()
    }

    #[test]
    fn serves_requests_correctly() {
        let s = tiny_server(1);
        let out = s.infer(vec![3, 1]).unwrap();
        assert_eq!(out, vec![2, 0, 5]); // same as the engine unit test
        s.shutdown();
    }

    #[test]
    fn serves_typed_requests_with_ticket_identity() {
        let s = tiny_server(1);
        let req = Request::interactive(vec![3, 1]);
        let id = req.id;
        let ticket = s.submit(req).unwrap();
        assert_eq!(ticket.id(), id);
        assert_eq!(ticket.class(), QosClass::Interactive);
        assert_eq!(ticket.wait().unwrap(), vec![2, 0, 5]);
        let snap = s.metrics.snapshot();
        assert_eq!(snap.class(QosClass::Interactive).completed, 1);
        assert_eq!(snap.class(QosClass::Bulk).completed, 0);
        s.shutdown();
    }

    #[test]
    fn concurrent_submissions_all_complete() {
        let s = Arc::new(tiny_server(2));
        let mut handles = Vec::new();
        for t in 0..8 {
            let s = Arc::clone(&s);
            handles.push(std::thread::spawn(move || {
                for _ in 0..50 {
                    let out = s.infer(vec![t as i8, 1]).unwrap();
                    assert_eq!(out.len(), 3);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let snap = s.metrics.snapshot();
        assert_eq!(snap.completed, 400);
        assert_eq!(snap.errors, 0);
        if let Ok(s) = Arc::try_unwrap(s) {
            s.shutdown();
        }
    }

    #[test]
    fn adaptive_batching_serves_correctly() {
        let sessions = vec![Session::builder(crate::format::mfb::tests::tiny_mfb())
            .engine(Engine::MicroFlow)
            .build()
            .unwrap()];
        let cfg = ServerConfig { adaptive: true, ..ServerConfig::default() };
        let s = Server::start(sessions, cfg).unwrap();
        for _ in 0..30 {
            assert_eq!(s.infer(vec![3, 1]).unwrap(), vec![2, 0, 5]);
        }
        let snap = s.metrics.snapshot();
        assert_eq!(snap.submitted, 30);
        assert_eq!(snap.completed, 30);
        s.shutdown();
    }

    #[test]
    fn rejects_wrong_input_length() {
        let s = tiny_server(1);
        assert!(s.submit(Request::new(vec![1, 2, 3])).is_err());
        match s.try_submit(Request::new(vec![1, 2, 3])) {
            Err(SubmitError::InputLength { expected, got }) => {
                assert_eq!((expected, got), (2, 3));
            }
            other => panic!("expected InputLength, got {other:?}"),
        }
        // rejected submissions never touch the counters
        assert_eq!(s.metrics.snapshot().submitted, 0);
        s.shutdown();
    }

    #[test]
    fn cancelled_before_submit_is_never_executed() {
        let s = tiny_server(1);
        let req = Request::new(vec![3, 1]);
        req.cancel(); // deterministic: cancelled before the queue sees it
        let ticket = s.submit(req).unwrap();
        let err = ticket.wait().unwrap_err().to_string();
        assert!(err.contains("cancelled"), "{err}");
        let snap = s.metrics.snapshot();
        assert_eq!(snap.cancelled, 1);
        assert_eq!(snap.completed, 0);
        s.shutdown();
    }

    #[test]
    fn expired_deadline_is_shed_not_executed() {
        let s = tiny_server(1);
        let ticket =
            s.submit(Request::new(vec![3, 1]).with_deadline(std::time::Instant::now())).unwrap();
        let err = ticket.wait().unwrap_err().to_string();
        assert!(err.contains("shed"), "{err}");
        let snap = s.metrics.snapshot();
        assert_eq!(snap.shed, 1);
        assert_eq!(snap.completed, 0);
        s.shutdown();
    }

    #[test]
    fn generous_deadline_completes_normally() {
        let s = tiny_server(1);
        let ticket = s
            .submit(
                Request::interactive(vec![3, 1])
                    .with_deadline_in(std::time::Duration::from_secs(60)),
            )
            .unwrap();
        assert_eq!(ticket.wait().unwrap(), vec![2, 0, 5]);
        let snap = s.metrics.snapshot();
        assert_eq!(snap.shed, 0);
        assert_eq!(snap.deadline_missed, 0);
        assert_eq!(snap.class(QosClass::Interactive).completed, 1);
        s.shutdown();
    }

    #[test]
    fn mixed_engine_replicas_serve_together() {
        let bytes = crate::format::mfb::tests::tiny_mfb();
        let sessions = vec![
            Session::builder(bytes.clone()).engine(Engine::MicroFlow).build().unwrap(),
            Session::builder(bytes).engine(Engine::Interp).build().unwrap(),
        ];
        let s = Server::start(sessions, ServerConfig::default()).unwrap();
        for _ in 0..20 {
            let out = s.infer(vec![3, 1]).unwrap();
            // engines agree within ±1 (paper Sec. 6.2.1)
            for (got, want) in out.iter().zip(&[2i8, 0, 5]) {
                assert!((*got as i32 - *want as i32).abs() <= 1, "{out:?}");
            }
        }
        s.shutdown();
    }
}
