//! Worker-pool serving loop (DESIGN.md S16).
//!
//! `Server` owns one worker thread per backend instance, fed by a bounded
//! request channel (backpressure: `submit` blocks when the queue is full).
//! Each worker runs the dynamic batcher, executes the batch on its backend
//! and replies through per-request channels. std::thread + mpsc (no tokio
//! offline — DESIGN.md §7).

use std::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{Context, Result};

use super::backend::Backend;
use super::batcher::{next_batch, BatcherConfig};
use super::metrics::Metrics;
use crate::tensor::quant::QParams;

/// One in-flight request.
pub struct Request {
    pub input: Vec<i8>,
    pub enqueued: Instant,
    pub reply: Sender<Result<Vec<i8>>>,
}

/// Server configuration.
#[derive(Clone, Copy, Debug)]
pub struct ServerConfig {
    pub queue_depth: usize,
    pub batcher: BatcherConfig,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { queue_depth: 256, batcher: BatcherConfig::default() }
    }
}

/// A serving endpoint for one model.
pub struct Server {
    tx: SyncSender<Request>,
    workers: Vec<JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
    input_len: usize,
    input_qparams: QParams,
    output_qparams: QParams,
}

impl Server {
    /// Start a server over a set of backend replicas (one worker each).
    pub fn start(backends: Vec<Box<dyn Backend>>, cfg: ServerConfig) -> Result<Server> {
        anyhow::ensure!(!backends.is_empty(), "need at least one backend");
        let input_len = backends[0].input_len();
        let input_qparams = backends[0].input_qparams();
        let output_qparams = backends[0].output_qparams();
        let metrics = Arc::new(Metrics::new());
        let (tx, rx) = sync_channel::<Request>(cfg.queue_depth);
        let shared_rx = Arc::new(std::sync::Mutex::new(rx));
        let mut workers = Vec::new();
        for mut backend in backends {
            let rx = Arc::clone(&shared_rx);
            let metrics = Arc::clone(&metrics);
            let bcfg = BatcherConfig {
                max_batch: cfg.batcher.max_batch.min(backend.preferred_batch().max(1)),
                max_wait: cfg.batcher.max_wait,
            };
            workers.push(std::thread::spawn(move || {
                worker_loop(&mut *backend, &rx, &bcfg, &metrics);
            }));
        }
        Ok(Server { tx, workers, metrics, input_len, input_qparams, output_qparams })
    }

    pub fn input_qparams(&self) -> QParams {
        self.input_qparams
    }

    pub fn output_qparams(&self) -> QParams {
        self.output_qparams
    }

    /// Submit a quantized request; returns the reply channel. Blocks when
    /// the queue is full (backpressure).
    pub fn submit(&self, input: Vec<i8>) -> Result<Receiver<Result<Vec<i8>>>> {
        anyhow::ensure!(input.len() == self.input_len, "input length");
        let (reply_tx, reply_rx) = std::sync::mpsc::channel();
        self.tx
            .send(Request { input, enqueued: Instant::now(), reply: reply_tx })
            .context("server is shut down")?;
        Ok(reply_rx)
    }

    /// Submit and wait (convenience).
    pub fn infer(&self, input: Vec<i8>) -> Result<Vec<i8>> {
        let rx = self.submit(input)?;
        rx.recv().context("worker dropped reply")?
    }

    /// Graceful shutdown: close the queue and join workers.
    pub fn shutdown(self) {
        drop(self.tx);
        for w in self.workers {
            let _ = w.join();
        }
    }
}

fn worker_loop(
    backend: &mut dyn Backend,
    rx: &std::sync::Mutex<Receiver<Request>>,
    cfg: &BatcherConfig,
    metrics: &Metrics,
) {
    let ilen = backend.input_len();
    let olen = backend.output_len();
    loop {
        // hold the lock only while assembling a batch; workers alternate
        let batch = {
            let rx = rx.lock().unwrap();
            next_batch(&rx, cfg)
        };
        let Some(batch) = batch else { return };
        let n = batch.len();
        metrics.record_batch(n);
        let mut inputs = Vec::with_capacity(n * ilen);
        for r in &batch {
            inputs.extend_from_slice(&r.input);
        }
        match backend.execute(&inputs, n) {
            Ok(outputs) => {
                for (i, r) in batch.into_iter().enumerate() {
                    let out = outputs[i * olen..(i + 1) * olen].to_vec();
                    metrics.record(r.enqueued.elapsed());
                    let _ = r.reply.send(Ok(out));
                }
            }
            Err(e) => {
                let msg = format!("batch execution failed: {e:#}");
                for r in batch {
                    metrics.record_error();
                    let _ = r.reply.send(Err(anyhow::anyhow!(msg.clone())));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::plan::CompileOptions;
    use crate::coordinator::backend::NativeBackend;
    use crate::format::mfb::MfbModel;

    fn tiny_server(replicas: usize) -> Server {
        let m = MfbModel::parse(&crate::format::mfb::tests::tiny_mfb()).unwrap();
        let backends: Vec<Box<dyn Backend>> = (0..replicas)
            .map(|_| {
                Box::new(NativeBackend::new(&m, CompileOptions::default()).unwrap())
                    as Box<dyn Backend>
            })
            .collect();
        Server::start(backends, ServerConfig::default()).unwrap()
    }

    #[test]
    fn serves_requests_correctly() {
        let s = tiny_server(1);
        let out = s.infer(vec![3, 1]).unwrap();
        assert_eq!(out, vec![2, 0, 5]); // same as the engine unit test
        s.shutdown();
    }

    #[test]
    fn concurrent_submissions_all_complete() {
        let s = Arc::new(tiny_server(2));
        let mut handles = Vec::new();
        for t in 0..8 {
            let s = Arc::clone(&s);
            handles.push(std::thread::spawn(move || {
                for _ in 0..50 {
                    let out = s.infer(vec![t as i8, 1]).unwrap();
                    assert_eq!(out.len(), 3);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let snap = s.metrics.snapshot();
        assert_eq!(snap.completed, 400);
        assert_eq!(snap.errors, 0);
        Arc::try_unwrap(s).ok().map(|s| s.shutdown());
    }

    #[test]
    fn rejects_wrong_input_length() {
        let s = tiny_server(1);
        assert!(s.submit(vec![1, 2, 3]).is_err());
        s.shutdown();
    }
}
