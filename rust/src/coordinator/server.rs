//! Worker-pool serving loop (DESIGN.md S16) — now an **elastic** pool.
//!
//! `Server` owns one worker thread per [`Session`] replica, fed by a
//! bounded channel of [`QueueEntry`] items. Submission is typed
//! ([`Request`] in, [`Ticket`] out): `submit` keeps the classic blocking
//! backpressure, `try_submit` surfaces a full queue as
//! [`SubmitError::QueueFull`] instead of blocking. Each worker runs the
//! QoS-aware dynamic batcher (single-class batches; expired-deadline and
//! cancelled entries shed before execution) and executes the batch with
//! the session's allocation-free `run_batch_into` — the packed input and
//! output staging buffers are reused across batches, so the steady-state
//! request path allocates only the per-request reply vectors.
//! std::thread + mpsc (no tokio offline — DESIGN.md §7).
//!
//! ## Elasticity and the drain protocol
//!
//! The worker set is dynamic — the autoscaler
//! ([`coordinator::autoscale`](super::autoscale)) grows and shrinks it at
//! runtime:
//!
//! * [`Server::add_replica`] joins a new session worker onto the
//!   **existing** shared bounded queue (no new queue, no rebalancing:
//!   the new worker simply starts claiming batches);
//! * [`Server::remove_replica`] retires one worker by enqueuing a
//!   [`QueueEntry::Retire`] sentinel. Exactly one worker claims it (the
//!   queue is MPSC-consumed under a lock), finishes the batch it was
//!   assembling, executes it, and exits.
//!
//! Drain invariants (tested here and in the stress suite):
//!
//! 1. **No accepted request is ever dropped by a scale-down** — the
//!    sentinel ends batch *assembly*, never delivery, and requests queued
//!    behind the sentinel remain for the surviving workers;
//! 2. **the last live worker can never be retired** — `remove_replica`
//!    reserves its victim against `replicas − pending_retires` and
//!    refuses when one worker would remain, so the queue always has a
//!    consumer;
//! 3. **counts are honest** — [`Server::replicas`] reports workers still
//!    running (a retiring worker counts until it actually exits);
//!    [`Server::live_replicas`] reports the committed steady state
//!    (`replicas − pending retires`) and is what the autoscaler and the
//!    fleet snapshot reason about, so a decision made mid-drain sees the
//!    post-drain size instead of double-retiring.
//!
//! ## Failure handling
//!
//! A failed batch no longer collapses into one stringly error: every
//! affected request resolves to a typed
//! [`ReplicaError`](super::request::ReplicaError) naming the replica, the
//! request id, and the failure kind. **Transient** failures are retried —
//! the request goes to the shared retry buffer (attempt count
//! incremented, `retried` lane recorded) where a *sibling* worker claims
//! it ahead of fresh arrivals; a request is never retried past
//! [`ServerConfig::max_retries`], past its deadline, or after
//! cancellation. Exhausted or non-retryable failures land in the `failed`
//! metric lane, keeping the accounting identity exact:
//! `completed + shed + cancelled + failed == submitted`. **Fatal**
//! failures kill the worker itself: it marks its health entry dead,
//! re-queues any carried request, and exits — the pool's autoscaler floor
//! provisions the replacement. Targeted removal of a *specific* unhealthy
//! replica goes through [`Server::eject_replica`]: it flips the replica's
//! one-shot quarantine flag, and the worker notices between batches (the
//! batcher's [`Cut::Idle`] poll bounds the latency even on a quiet queue),
//! marks itself ejected, and exits under the same reservation rules as a
//! drain — so ejection, like retirement, never drops an accepted request.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::Result;

use super::batcher::{next_batch, AdaptiveBatcher, BatcherConfig, Cut};
use super::metrics::{Metrics, ReplicaHealth};
use super::request::{Pending, QueueEntry, ReplicaError, Request, SubmitError, Ticket};
use crate::api::{FailureKind, InjectedFault, IoSignature, Session};
use crate::observe::{Phase, SharedProfileObserver};
use crate::tensor::quant::QParams;

/// Server configuration.
#[derive(Clone, Copy, Debug)]
pub struct ServerConfig {
    pub queue_depth: usize,
    pub batcher: BatcherConfig,
    /// Let each worker tune its own effective [`BatcherConfig`] from the
    /// observed queue depth (see
    /// [`AdaptiveBatcher`](super::batcher::AdaptiveBatcher)). Off by
    /// default; the fleet turns it on for its replica pools.
    pub adaptive: bool,
    /// Times a transiently-failed request may be redispatched to a
    /// sibling replica before it resolves as failed. Retries never cross
    /// the request's deadline or QoS class (the request itself travels,
    /// class intact, and the deadline is re-checked at claim and at
    /// redispatch).
    pub max_retries: u32,
    /// Run batches through the observed session path, accumulating
    /// per-step kernel timings into the pool's shared
    /// [`SharedStepProfile`](crate::observe::SharedStepProfile) (exported
    /// by the fleet tick as `PoolTickReport::profile`). Off by default:
    /// profiling costs one monotonic-clock read per plan step.
    pub profile: bool,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            queue_depth: 256,
            batcher: BatcherConfig::default(),
            adaptive: false,
            max_retries: 1,
            profile: false,
        }
    }
}

/// State every worker thread shares with the server handle.
#[derive(Clone)]
struct WorkerCtx {
    rx: Arc<Mutex<Receiver<QueueEntry>>>,
    metrics: Arc<Metrics>,
    /// Workers currently running (a retiring worker decrements on exit).
    replicas: Arc<AtomicUsize>,
    /// Retire sentinels sent but not yet claimed-and-exited.
    pending_retires: Arc<AtomicUsize>,
    /// Transiently-failed requests awaiting a sibling replica, plus
    /// carried requests orphaned by a worker death. Deliberately a shared
    /// deque, not a second channel: worker-held senders would keep the
    /// request channel alive past shutdown (see the batcher module docs).
    retry: Arc<Mutex<VecDeque<Pending>>>,
    /// Redispatch budget per request ([`ServerConfig::max_retries`]).
    max_retries: u32,
    /// Route batches through the observed session path
    /// ([`ServerConfig::profile`]).
    profile: bool,
}

/// A serving endpoint for one model — one **elastic** replica pool:
/// worker threads sharing a bounded queue, joined and retired at runtime
/// (see the module docs for the drain protocol). A
/// [`Fleet`](super::fleet::Fleet) holds several of these and dispatches
/// across them.
pub struct Server {
    tx: SyncSender<QueueEntry>,
    workers: Mutex<Vec<JoinHandle<()>>>,
    ctx: WorkerCtx,
    pub metrics: Arc<Metrics>,
    signature: IoSignature,
    input_len: usize,
    input_qparams: QParams,
    output_qparams: QParams,
    /// Base batcher policy handed to every worker, present and future.
    batcher: BatcherConfig,
    adaptive: bool,
    /// Plan step kind names of the served model, in execution order
    /// (captured from the first replica; replicas share one signature, so
    /// engines with a step plan agree). What profile rows are labelled
    /// with — empty for opaque executors.
    step_kinds: Vec<&'static str>,
}

impl Server {
    /// Start a server over a set of session replicas (one worker each).
    ///
    /// Replicas are built with [`crate::api::Session::builder`]; mixing
    /// engines across replicas is allowed as long as they serve the same
    /// model signature.
    pub fn start(sessions: Vec<Session>, cfg: ServerConfig) -> Result<Server> {
        anyhow::ensure!(!sessions.is_empty(), "need at least one session");
        let sig = sessions[0].signature().clone();
        let input_len = sig.input_len();
        let input_qparams = sig.input.qparams;
        let output_qparams = sig.output.qparams;
        for s in &sessions[1..] {
            anyhow::ensure!(
                *s.signature() == sig,
                "replica signatures diverge: {:?} vs {:?}",
                s.signature(),
                sig
            );
        }
        let step_kinds = sessions[0].step_kinds();
        let metrics = Arc::new(Metrics::new());
        let (tx, rx) = sync_channel::<QueueEntry>(cfg.queue_depth);
        let ctx = WorkerCtx {
            rx: Arc::new(Mutex::new(rx)),
            metrics: Arc::clone(&metrics),
            replicas: Arc::new(AtomicUsize::new(0)),
            pending_retires: Arc::new(AtomicUsize::new(0)),
            retry: Arc::new(Mutex::new(VecDeque::new())),
            max_retries: cfg.max_retries,
            profile: cfg.profile,
        };
        let server = Server {
            tx,
            workers: Mutex::new(Vec::new()),
            ctx,
            metrics,
            signature: sig,
            input_len,
            input_qparams,
            output_qparams,
            batcher: cfg.batcher,
            adaptive: cfg.adaptive,
            step_kinds,
        };
        for session in sessions {
            server.spawn_worker(session);
        }
        Ok(server)
    }

    /// Spawn one worker over `session` on the shared queue (signature
    /// already validated by the caller).
    fn spawn_worker(&self, mut session: Session) {
        let bcfg = BatcherConfig {
            max_batch: self.batcher.max_batch.min(session.preferred_batch().max(1)),
            max_wait: self.batcher.max_wait,
        };
        let adaptive = self.adaptive;
        let ctx = self.ctx.clone();
        let health = self.metrics.register_replica(session.label());
        // counted before the thread runs so replicas() never under-reports
        ctx.replicas.fetch_add(1, Ordering::SeqCst);
        let handle = std::thread::spawn(move || {
            worker_loop(&mut session, &ctx, &bcfg, adaptive, &health);
        });
        let mut workers = self.workers.lock().unwrap();
        // reap workers that already retired, so the handle set stays
        // bounded by the number of live workers over the server's lifetime
        let (done, live): (Vec<_>, Vec<_>) =
            workers.drain(..).partition(|h| h.is_finished());
        for h in done {
            let _ = h.join();
        }
        *workers = live;
        workers.push(handle);
    }

    /// Join a new session replica onto the existing shared queue — the
    /// autoscaler's scale-up primitive. The new worker starts claiming
    /// batches immediately; nothing is rebalanced or re-queued.
    pub fn add_replica(&self, session: Session) -> Result<()> {
        anyhow::ensure!(
            *session.signature() == self.signature,
            "replica signature diverges: {:?} vs {:?}",
            session.signature(),
            self.signature
        );
        self.spawn_worker(session);
        Ok(())
    }

    /// Retire one worker via a [`QueueEntry::Retire`] sentinel — the
    /// autoscaler's scale-down primitive. The victim (whichever worker
    /// claims the sentinel) finishes and executes the batch it was
    /// assembling, then exits: accepted requests are never dropped.
    ///
    /// Refuses to retire the last live worker (the queue must always have
    /// a consumer); the reservation is atomic, so concurrent callers
    /// cannot race the pool down to zero.
    pub fn remove_replica(&self) -> Result<()> {
        // reserve the victim first: live-after = replicas − (reserved + 1)
        let reserved = self.ctx.pending_retires.fetch_add(1, Ordering::SeqCst);
        let running = self.ctx.replicas.load(Ordering::SeqCst);
        if running.saturating_sub(reserved + 1) < 1 {
            self.ctx.pending_retires.fetch_sub(1, Ordering::SeqCst);
            anyhow::bail!("cannot retire the last live replica");
        }
        if self.tx.send(QueueEntry::Retire).is_err() {
            self.ctx.pending_retires.fetch_sub(1, Ordering::SeqCst);
            anyhow::bail!("server is shut down");
        }
        Ok(())
    }

    /// Quarantine and retire one *specific* replica by label — the health
    /// policy's targeted scale-down. Unlike [`Server::remove_replica`]
    /// (whose sentinel is claimed by whichever worker gets there first),
    /// ejection flips the named replica's one-shot quarantine flag; that
    /// worker notices between batches, re-queues anything it was carrying
    /// onto the retry buffer, marks itself ejected, and exits.
    ///
    /// Uses the same last-live-worker reservation as `remove_replica`:
    /// ejecting the only live replica is refused (provision the
    /// replacement first — the fleet's health pass does). A replica
    /// already quarantined, ejected, or dead cannot be ejected twice.
    pub fn eject_replica(&self, label: &str) -> Result<()> {
        let health = self
            .metrics
            .find_replica(label)
            .ok_or_else(|| anyhow::anyhow!("no replica labeled {label:?} in this pool"))?;
        let reserved = self.ctx.pending_retires.fetch_add(1, Ordering::SeqCst);
        let running = self.ctx.replicas.load(Ordering::SeqCst);
        if running.saturating_sub(reserved + 1) < 1 {
            self.ctx.pending_retires.fetch_sub(1, Ordering::SeqCst);
            anyhow::bail!("cannot eject the last live replica {label:?}");
        }
        if !health.quarantine() {
            self.ctx.pending_retires.fetch_sub(1, Ordering::SeqCst);
            anyhow::bail!("replica {label:?} is already {}", health.phase());
        }
        Ok(())
    }

    pub fn signature(&self) -> &IoSignature {
        &self.signature
    }

    pub fn input_len(&self) -> usize {
        self.input_len
    }

    /// Worker threads currently running (a retiring worker counts until
    /// its drain completes and it exits).
    pub fn replicas(&self) -> usize {
        self.ctx.replicas.load(Ordering::SeqCst)
    }

    /// The committed steady-state worker count: running workers minus
    /// retire sentinels still in flight. This is the number the
    /// autoscaler reasons about — it is stable across a drain (reserved
    /// at `remove_replica` time, realized when the victim exits).
    pub fn live_replicas(&self) -> usize {
        let running = self.ctx.replicas.load(Ordering::SeqCst);
        running.saturating_sub(self.ctx.pending_retires.load(Ordering::SeqCst))
    }

    /// Retire sentinels sent but not yet drained (workers mid-retirement).
    pub fn retiring(&self) -> usize {
        self.ctx.pending_retires.load(Ordering::SeqCst)
    }

    /// Plan step kind names of the served model (see the field docs) —
    /// what [`SharedStepProfile::rows`](crate::observe::SharedStepProfile)
    /// labels the pool's profile with.
    pub fn step_kinds(&self) -> &[&'static str] {
        &self.step_kinds
    }

    pub fn input_qparams(&self) -> QParams {
        self.input_qparams
    }

    pub fn output_qparams(&self) -> QParams {
        self.output_qparams
    }

    /// Submit a typed request; returns its [`Ticket`]. Blocks when the
    /// queue is full (backpressure) — use [`Server::try_submit`] for an
    /// explicit [`SubmitError::QueueFull`] instead.
    pub fn submit(&self, req: Request) -> Result<Ticket> {
        anyhow::ensure!(
            req.payload.len() == self.input_len,
            "input length {} != model input length {}",
            req.payload.len(),
            self.input_len
        );
        let class = req.class;
        let id = req.id;
        let (pending, ticket) = req.into_pending();
        // count BEFORE the send: a worker may complete the request before
        // this thread resumes, and completed must never exceed submitted
        // (outstanding() would under-report and misroute fleet dispatch)
        self.metrics.record_submitted(class);
        if self.tx.send(QueueEntry::Req(pending)).is_err() {
            // balance the counter so outstanding() stays accurate
            self.metrics.record_failed(class);
            anyhow::bail!("server is shut down");
        }
        // span events mark accepted requests only, after the send commits
        self.metrics.spans.record_admit(id, class.as_u8(), Phase::Admit);
        Ok(ticket)
    }

    /// Non-blocking submit: a full queue is an explicit
    /// [`SubmitError::QueueFull`] handing the request back to the caller
    /// (retry, spill to another pool, or shed).
    pub fn try_submit(&self, req: Request) -> std::result::Result<Ticket, SubmitError> {
        if req.payload.len() != self.input_len {
            return Err(SubmitError::InputLength {
                expected: self.input_len,
                got: req.payload.len(),
            });
        }
        let class = req.class;
        let id = req.id;
        let (pending, ticket) = req.into_pending();
        self.metrics.record_submitted(class);
        match self.tx.try_send(QueueEntry::Req(pending)) {
            Ok(()) => {
                self.metrics.spans.record_admit(id, class.as_u8(), Phase::Admit);
                Ok(ticket)
            }
            Err(TrySendError::Full(QueueEntry::Req(p))) => {
                // the request never entered the queue: retract the count
                // and hand it back for retry/spill
                self.metrics.retract_submitted(class);
                Err(SubmitError::QueueFull(p.into_request()))
            }
            Err(TrySendError::Disconnected(QueueEntry::Req(p))) => {
                self.metrics.retract_submitted(class);
                Err(SubmitError::Shutdown(p.into_request()))
            }
            // we only ever try_send a Req entry, so a bounced sentinel
            // would mean the channel handed back something it was never
            // given. Panicking here would poison the caller's thread over
            // a request that was already retracted — answer with a typed
            // internal error and keep serving instead.
            Err(TrySendError::Full(QueueEntry::Retire))
            | Err(TrySendError::Disconnected(QueueEntry::Retire)) => {
                self.metrics.retract_submitted(class);
                Err(SubmitError::Internal { reason: "try_send bounced an entry it was not given" })
            }
        }
    }

    /// Submit and wait (blocking convenience; Bulk class, no deadline —
    /// the legacy semantics).
    pub fn infer(&self, input: Vec<i8>) -> Result<Vec<i8>> {
        self.submit(Request::new(input))?.wait()
    }

    /// Graceful shutdown: close the queue and join workers.
    pub fn shutdown(self) {
        drop(self.tx);
        let workers = self.workers.into_inner().unwrap();
        for w in workers {
            let _ = w.join();
        }
    }
}

/// Resolve one worker's failed batch: retry what may be retried, fail the
/// rest with a typed [`ReplicaError`]. Returns `true` when the failure
/// was fatal (the caller must mark itself dead and exit).
fn fail_batch(
    batch: Vec<Pending>,
    error: &anyhow::Error,
    label: &str,
    ctx: &WorkerCtx,
    health: &ReplicaHealth,
) -> bool {
    let metrics = &*ctx.metrics;
    health.record_failure();
    // injected faults carry their kind; anything else (a real engine
    // error) is conservatively transient — the sibling replicas serve the
    // same model, so a deterministic model error will simply exhaust the
    // retry budget and resolve as failed
    let kind = match error.downcast_ref::<InjectedFault>() {
        Some(f) => f.kind,
        None => FailureKind::Transient,
    };
    let detail = format!("{error:#}");
    let now = Instant::now();
    for mut p in batch {
        let retryable = kind == FailureKind::Transient
            && p.request.attempt < ctx.max_retries
            && !p.is_cancelled()
            && !p.deadline_expired(now);
        if retryable {
            // redispatch to a sibling: still outstanding, not resolved —
            // submitted was already counted, so only the retry lane moves
            p.request.attempt += 1;
            metrics.record_retried(p.request.class);
            ctx.retry.lock().expect("retry buffer poisoned").push_back(p);
        } else {
            metrics.record_failed(p.request.class);
            let err = ReplicaError {
                replica_label: label.to_string(),
                request_id: p.request.id,
                kind,
                detail: detail.clone(),
            };
            let _ = p.reply.send(Err(anyhow::Error::new(err)));
        }
    }
    kind == FailureKind::Fatal
}

/// Hand a carried request back to the pool before this worker exits —
/// exits must never strand the one-slot stash. The request has not
/// failed; it just needs a new owner, so no lane moves.
fn requeue_carry(carry: &mut Option<Pending>, ctx: &WorkerCtx) {
    if let Some(p) = carry.take() {
        ctx.retry.lock().expect("retry buffer poisoned").push_back(p);
    }
}

fn worker_loop(
    session: &mut Session,
    ctx: &WorkerCtx,
    cfg: &BatcherConfig,
    adaptive: bool,
    health: &ReplicaHealth,
) {
    let metrics = &*ctx.metrics;
    let label = session.label().to_string();
    let ilen = session.input_len();
    let olen = session.output_len();
    // this worker's single-writer span ring (drained by the fleet tick)
    // and the pool-shared per-step profile it feeds when profiling is on
    let ring = metrics.spans.register_worker();
    let step_profile = metrics.step_profile();
    let mut tuner = AdaptiveBatcher::new(*cfg);
    // one-slot stash for the request that ended the previous batch on a
    // class boundary; it leads this worker's next batch
    let mut carry: Option<Pending> = None;
    // staging buffers grow to the largest batch once, then are reused
    let mut inputs: Vec<i8> = Vec::new();
    let mut outputs: Vec<i8> = Vec::new();
    loop {
        // a health-policy ejection lands here: the quarantine flag is
        // checked between batches (Cut::Idle bounds the wait on a quiet
        // queue), so the in-flight batch always completes first
        if health.is_quarantined() {
            requeue_carry(&mut carry, ctx);
            health.mark_ejected();
            // realize the reservation eject_replica made, replicas first
            // so live_replicas() never transiently over-reports
            ctx.replicas.fetch_sub(1, Ordering::SeqCst);
            ctx.pending_retires.fetch_sub(1, Ordering::SeqCst);
            return;
        }
        // hold the lock only while assembling a batch; workers alternate
        let effective = if adaptive { tuner.config() } else { *cfg };
        let cut = {
            let rx = ctx.rx.lock().unwrap();
            next_batch(&rx, &mut carry, &ctx.retry, cfg, &effective, metrics)
        };
        let (batch, retiring) = match cut {
            Cut::Shutdown => return,
            Cut::Idle => continue,
            Cut::Batch(b) => (b, false),
            Cut::Retire(b) => (b, true),
        };
        if adaptive && !batch.is_empty() {
            // queue-depth proxy right after the cut: outstanding beyond
            // the batch this worker just claimed, averaged per replica —
            // the pool-wide counter includes sibling workers' in-flight
            // batches, which would otherwise read as phantom queue depth
            let beyond = metrics.outstanding().saturating_sub(batch.len() as u64);
            let replicas = ctx.replicas.load(Ordering::Relaxed) as u64;
            tuner.observe(beyond / replicas.max(1));
        }
        let n = batch.len();
        if n > 0 {
            metrics.record_batch(n);
            inputs.clear();
            for p in &batch {
                // Queue closes (the request left the queue at this cut) and
                // Batch opens (it holds a slot in the assembled batch)
                ring.record(p.request.id, p.request.class.as_u8(), Phase::Queue);
                ring.record(p.request.id, p.request.class.as_u8(), Phase::Batch);
                inputs.extend_from_slice(&p.request.payload);
            }
            outputs.resize(n * olen, 0);
            debug_assert_eq!(inputs.len(), n * ilen);
            let executed = if ctx.profile {
                let mut obs = SharedProfileObserver::new(&step_profile);
                session.run_batch_into_observed(&inputs, n, &mut outputs[..n * olen], &mut obs)
            } else {
                session.run_batch_into(&inputs, n, &mut outputs[..n * olen])
            };
            match executed {
                Ok(()) => {
                    health.record_success();
                    let done = Instant::now();
                    for (i, p) in batch.into_iter().enumerate() {
                        let (id, class) = (p.request.id, p.request.class.as_u8());
                        ring.record(id, class, Phase::Execute);
                        let out = outputs[i * olen..(i + 1) * olen].to_vec();
                        if p.request.deadline.is_some_and(|d| done > d) {
                            // executed but late: delivered anyway, counted
                            // as an SLO miss
                            metrics.record_deadline_missed(p.request.class);
                        }
                        metrics.record(p.request.class, p.enqueued.elapsed());
                        let _ = p.reply.send(Ok(out));
                        ring.record(id, class, Phase::Reply);
                    }
                }
                Err(e) => {
                    if fail_batch(batch, &e, &label, ctx, health) {
                        // fatal: this replica is gone. No reservation was
                        // made for a death, so only the running count
                        // moves; the carry is handed to the siblings and
                        // the autoscaler floor provisions a replacement.
                        health.mark_dead();
                        requeue_carry(&mut carry, ctx);
                        ctx.replicas.fetch_sub(1, Ordering::SeqCst);
                        if retiring {
                            // dying while holding a claimed sentinel still
                            // realizes that drain reservation
                            ctx.pending_retires.fetch_sub(1, Ordering::SeqCst);
                        }
                        return;
                    }
                }
            }
        }
        if retiring {
            // the batcher never returns Retire with a stashed carry (a
            // class boundary ends the cut before a sentinel can be pulled)
            debug_assert!(carry.is_none(), "retiring with a stranded carry");
            // drain complete: realize the reservation made by
            // remove_replica, in one order (replicas first) so
            // live_replicas() never transiently over-reports
            ctx.replicas.fetch_sub(1, Ordering::SeqCst);
            ctx.pending_retires.fetch_sub(1, Ordering::SeqCst);
            // a quarantine that raced the sentinel claim is also realized
            // by this exit (this worker is the one being removed either
            // way); mark the phase so the registry stays truthful
            if health.is_quarantined() {
                health.mark_ejected();
                ctx.pending_retires.fetch_sub(1, Ordering::SeqCst);
            }
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{Engine, Session};
    use crate::coordinator::request::QosClass;

    fn tiny_server(replicas: usize) -> Server {
        let sessions: Vec<Session> = (0..replicas)
            .map(|_| {
                Session::builder(crate::format::mfb::tests::tiny_mfb())
                    .engine(Engine::MicroFlow)
                    .build()
                    .unwrap()
            })
            .collect();
        Server::start(sessions, ServerConfig::default()).unwrap()
    }

    #[test]
    fn serves_requests_correctly() {
        let s = tiny_server(1);
        let out = s.infer(vec![3, 1]).unwrap();
        assert_eq!(out, vec![2, 0, 5]); // same as the engine unit test
        s.shutdown();
    }

    #[test]
    fn serves_typed_requests_with_ticket_identity() {
        let s = tiny_server(1);
        let req = Request::interactive(vec![3, 1]);
        let id = req.id;
        let ticket = s.submit(req).unwrap();
        assert_eq!(ticket.id(), id);
        assert_eq!(ticket.class(), QosClass::Interactive);
        assert_eq!(ticket.wait().unwrap(), vec![2, 0, 5]);
        let snap = s.metrics.snapshot();
        assert_eq!(snap.class(QosClass::Interactive).completed, 1);
        assert_eq!(snap.class(QosClass::Bulk).completed, 0);
        s.shutdown();
    }

    #[test]
    fn concurrent_submissions_all_complete() {
        let s = Arc::new(tiny_server(2));
        let mut handles = Vec::new();
        for t in 0..8 {
            let s = Arc::clone(&s);
            handles.push(std::thread::spawn(move || {
                for _ in 0..50 {
                    let out = s.infer(vec![t as i8, 1]).unwrap();
                    assert_eq!(out.len(), 3);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let snap = s.metrics.snapshot();
        assert_eq!(snap.completed, 400);
        assert_eq!(snap.failed, 0);
        if let Ok(s) = Arc::try_unwrap(s) {
            s.shutdown();
        }
    }

    #[test]
    fn adaptive_batching_serves_correctly() {
        let sessions = vec![Session::builder(crate::format::mfb::tests::tiny_mfb())
            .engine(Engine::MicroFlow)
            .build()
            .unwrap()];
        let cfg = ServerConfig { adaptive: true, ..ServerConfig::default() };
        let s = Server::start(sessions, cfg).unwrap();
        for _ in 0..30 {
            assert_eq!(s.infer(vec![3, 1]).unwrap(), vec![2, 0, 5]);
        }
        let snap = s.metrics.snapshot();
        assert_eq!(snap.submitted, 30);
        assert_eq!(snap.completed, 30);
        s.shutdown();
    }

    #[test]
    fn spans_and_profile_cover_the_request_lifecycle() {
        let sessions = vec![Session::builder(crate::format::mfb::tests::tiny_mfb())
            .engine(Engine::MicroFlow)
            .build()
            .unwrap()];
        let cfg = ServerConfig { profile: true, ..ServerConfig::default() };
        let s = Server::start(sessions, cfg).unwrap();
        for _ in 0..10 {
            assert_eq!(s.infer(vec![3, 1]).unwrap(), vec![2, 0, 5]);
        }
        // every completed request leaves one event per lifecycle phase
        let w = s.metrics.spans.drain_window();
        assert_eq!(w.dropped, 0);
        for phase in Phase::ALL {
            assert_eq!(w.by_phase(phase), 10, "phase {phase}");
        }
        // and the profiled pool accounts every plan step exactly once per
        // inference, labelled with the plan's own step kinds
        let rows = s.metrics.step_profile().rows(s.step_kinds());
        assert!(!rows.is_empty(), "a native pool must expose step kinds");
        assert_eq!(rows.len(), s.step_kinds().len());
        for r in &rows {
            assert_eq!(r.invocations, 10, "step {} ({})", r.step, r.kind);
        }
        s.shutdown();
    }

    #[test]
    fn rejects_wrong_input_length() {
        let s = tiny_server(1);
        assert!(s.submit(Request::new(vec![1, 2, 3])).is_err());
        match s.try_submit(Request::new(vec![1, 2, 3])) {
            Err(SubmitError::InputLength { expected, got }) => {
                assert_eq!((expected, got), (2, 3));
            }
            other => panic!("expected InputLength, got {other:?}"),
        }
        // rejected submissions never touch the counters
        assert_eq!(s.metrics.snapshot().submitted, 0);
        s.shutdown();
    }

    #[test]
    fn cancelled_before_submit_is_never_executed() {
        let s = tiny_server(1);
        let req = Request::new(vec![3, 1]);
        req.cancel(); // deterministic: cancelled before the queue sees it
        let ticket = s.submit(req).unwrap();
        let err = ticket.wait().unwrap_err().to_string();
        assert!(err.contains("cancelled"), "{err}");
        let snap = s.metrics.snapshot();
        assert_eq!(snap.cancelled, 1);
        assert_eq!(snap.completed, 0);
        s.shutdown();
    }

    #[test]
    fn expired_deadline_is_shed_not_executed() {
        let s = tiny_server(1);
        let ticket =
            s.submit(Request::new(vec![3, 1]).with_deadline(std::time::Instant::now())).unwrap();
        let err = ticket.wait().unwrap_err().to_string();
        assert!(err.contains("shed"), "{err}");
        let snap = s.metrics.snapshot();
        assert_eq!(snap.shed, 1);
        assert_eq!(snap.completed, 0);
        s.shutdown();
    }

    #[test]
    fn generous_deadline_completes_normally() {
        let s = tiny_server(1);
        let ticket = s
            .submit(
                Request::interactive(vec![3, 1])
                    .with_deadline_in(std::time::Duration::from_secs(60)),
            )
            .unwrap();
        assert_eq!(ticket.wait().unwrap(), vec![2, 0, 5]);
        let snap = s.metrics.snapshot();
        assert_eq!(snap.shed, 0);
        assert_eq!(snap.deadline_missed, 0);
        assert_eq!(snap.class(QosClass::Interactive).completed, 1);
        s.shutdown();
    }

    /// Spin until the server's running-worker count reaches `want` (drain
    /// completion is asynchronous but guaranteed; bounded wait keeps a
    /// regression from hanging the suite).
    fn wait_for_replicas(s: &Server, want: usize) {
        let t0 = std::time::Instant::now();
        while s.replicas() != want {
            assert!(
                t0.elapsed() < std::time::Duration::from_secs(10),
                "replicas stuck at {} (want {want})",
                s.replicas()
            );
            std::thread::yield_now();
        }
    }

    #[test]
    fn add_replica_joins_the_shared_queue() {
        let s = tiny_server(1);
        assert_eq!((s.replicas(), s.live_replicas()), (1, 1));
        let extra = Session::builder(crate::format::mfb::tests::tiny_mfb())
            .engine(Engine::MicroFlow)
            .build()
            .unwrap();
        s.add_replica(extra).unwrap();
        assert_eq!((s.replicas(), s.live_replicas()), (2, 2));
        // both workers serve the same queue: replies stay correct
        for _ in 0..40 {
            assert_eq!(s.infer(vec![3, 1]).unwrap(), vec![2, 0, 5]);
        }
        assert_eq!(s.metrics.snapshot().completed, 40);
        s.shutdown();
    }

    #[test]
    fn add_replica_rejects_a_mismatched_signature() {
        let s = tiny_server(1);
        // a different model: signature diverges, the pool must refuse it
        let mut rng = crate::util::Prng::new(9);
        let other = crate::synth::fc_chain(&mut rng, &[4, 4]);
        let bad = Session::builder(&other).build().unwrap();
        assert!(s.add_replica(bad).is_err());
        assert_eq!(s.replicas(), 1);
        s.shutdown();
    }

    #[test]
    fn remove_replica_drains_gracefully_under_backlog() {
        let s = tiny_server(2);
        // flood the queue, then retire one worker while the backlog is
        // still draining: every accepted request must be answered
        let tickets: Vec<Ticket> =
            (0..64).map(|_| s.submit(Request::new(vec![3, 1])).unwrap()).collect();
        s.remove_replica().unwrap();
        assert_eq!(s.live_replicas(), 1, "the retirement is committed immediately");
        for t in tickets {
            assert_eq!(t.wait().unwrap(), vec![2, 0, 5], "scale-down dropped a request");
        }
        wait_for_replicas(&s, 1);
        assert_eq!(s.retiring(), 0);
        // the surviving worker still serves
        assert_eq!(s.infer(vec![3, 1]).unwrap(), vec![2, 0, 5]);
        let snap = s.metrics.snapshot();
        assert_eq!(snap.completed, 65);
        assert_eq!(snap.failed, 0);
        s.shutdown();
    }

    #[test]
    fn the_last_live_replica_can_never_be_retired() {
        let s = tiny_server(1);
        assert!(s.remove_replica().is_err(), "a 1-worker pool must refuse retirement");
        let s2 = tiny_server(2);
        s2.remove_replica().unwrap();
        // the second retire would leave zero live workers — refused even
        // though the first victim may not have exited yet
        assert!(s2.remove_replica().is_err());
        wait_for_replicas(&s2, 1);
        assert_eq!(s2.infer(vec![3, 1]).unwrap(), vec![2, 0, 5]);
        s2.shutdown();
        s.shutdown();
    }

    #[test]
    fn scale_up_down_cycle_keeps_serving() {
        let s = tiny_server(1);
        for round in 0..3 {
            let extra = Session::builder(crate::format::mfb::tests::tiny_mfb())
                .engine(Engine::MicroFlow)
                .build()
                .unwrap();
            s.add_replica(extra).unwrap();
            for _ in 0..10 {
                assert_eq!(s.infer(vec![3, 1]).unwrap(), vec![2, 0, 5], "round {round}");
            }
            s.remove_replica().unwrap();
            wait_for_replicas(&s, 1);
        }
        let snap = s.metrics.snapshot();
        assert_eq!(snap.completed, 30);
        assert_eq!(snap.failed, 0);
        s.shutdown();
    }

    #[test]
    fn transient_failure_retries_to_completion() {
        use crate::api::FaultPlan;
        // seed 999 + period 1000: exactly call 1 fails, transiently — the
        // retry (call 2) succeeds on the same schedule, deterministically
        let session = Session::builder(crate::format::mfb::tests::tiny_mfb())
            .engine(Engine::MicroFlow)
            .label("flaky/0")
            .build()
            .unwrap();
        let flaky = FaultPlan::new(999).transient_every(1000).wrap(session);
        let s = Server::start(vec![flaky], ServerConfig::default()).unwrap();
        assert_eq!(s.infer(vec![3, 1]).unwrap(), vec![2, 0, 5], "retry must stay bit-exact");
        let snap = s.metrics.snapshot();
        assert_eq!(snap.completed, 1);
        assert_eq!(snap.retried, 1);
        assert_eq!(snap.failed, 0);
        assert_eq!(snap.completed + snap.shed + snap.cancelled + snap.failed, snap.submitted);
        assert_eq!(s.metrics.outstanding(), 0);
        s.shutdown();
    }

    #[test]
    fn exhausted_retry_budget_resolves_as_a_typed_replica_error() {
        use crate::api::FaultPlan;
        let session = Session::builder(crate::format::mfb::tests::tiny_mfb())
            .engine(Engine::MicroFlow)
            .label("wedged/0")
            .build()
            .unwrap();
        let wedged = FaultPlan::new(0).transient_every(1).wrap(session); // fails every call
        let cfg = ServerConfig { max_retries: 2, ..ServerConfig::default() };
        let s = Server::start(vec![wedged], cfg).unwrap();
        let req = Request::interactive(vec![3, 1]);
        let id = req.id;
        let err = s.submit(req).unwrap().wait().unwrap_err();
        let re = err.downcast_ref::<ReplicaError>().expect("typed replica error");
        assert_eq!(re.replica_label, "wedged/0");
        assert_eq!(re.request_id, id);
        assert_eq!(re.kind, FailureKind::Transient);
        let snap = s.metrics.snapshot();
        assert_eq!(snap.retried, 2, "budget of 2 means two redispatches");
        assert_eq!(snap.failed, 1);
        assert_eq!(snap.completed + snap.shed + snap.cancelled + snap.failed, snap.submitted);
        assert_eq!(s.metrics.outstanding(), 0);
        s.shutdown();
    }

    #[test]
    fn fatal_fault_kills_the_worker_and_resolves_its_batch() {
        use crate::api::FaultPlan;
        use crate::coordinator::metrics::ReplicaPhase;
        let session = Session::builder(crate::format::mfb::tests::tiny_mfb())
            .engine(Engine::MicroFlow)
            .label("doomed/0")
            .build()
            .unwrap();
        let doomed = FaultPlan::new(0).fatal_on(1).wrap(session);
        let s = Server::start(vec![doomed], ServerConfig::default()).unwrap();
        let err = s.submit(Request::new(vec![3, 1])).unwrap().wait().unwrap_err();
        let re = err.downcast_ref::<ReplicaError>().expect("typed replica error");
        assert_eq!(re.kind, FailureKind::Fatal);
        assert_eq!(re.replica_label, "doomed/0");
        wait_for_replicas(&s, 0);
        let health = s.metrics.replica_health();
        assert_eq!(health.len(), 1);
        assert_eq!(health[0].phase, ReplicaPhase::Dead);
        let snap = s.metrics.snapshot();
        assert_eq!(snap.retried, 0, "fatal failures are never retried against anyone");
        assert_eq!(snap.failed, 1);
        // replica-death satellite: with no worker left, a queued ticket's
        // deadline wait returns instead of hanging
        let mut orphan = s.submit(Request::new(vec![3, 1])).unwrap();
        let soon = std::time::Instant::now() + std::time::Duration::from_millis(50);
        assert!(orphan.wait_deadline(soon).unwrap().is_none(), "must time out, not hang");
        s.shutdown();
    }

    #[test]
    fn eject_replica_retires_exactly_the_named_worker() {
        use crate::coordinator::metrics::ReplicaPhase;
        let mk = |label: &str| {
            Session::builder(crate::format::mfb::tests::tiny_mfb())
                .engine(Engine::MicroFlow)
                .label(label)
                .build()
                .unwrap()
        };
        let s = Server::start(vec![mk("ej/a"), mk("ej/b")], ServerConfig::default()).unwrap();
        assert!(s.eject_replica("ej/nope").is_err(), "unknown label must be refused");
        s.eject_replica("ej/a").unwrap();
        assert_eq!(s.live_replicas(), 1, "the ejection is committed immediately");
        wait_for_replicas(&s, 1);
        assert_eq!(s.retiring(), 0, "the reservation is realized by the ejected worker");
        assert!(s.eject_replica("ej/a").is_err(), "a replica is ejected at most once");
        assert!(s.eject_replica("ej/b").is_err(), "the last live replica is protected");
        // the survivor is exactly ej/b, still serving
        assert_eq!(s.infer(vec![3, 1]).unwrap(), vec![2, 0, 5]);
        for h in s.metrics.replica_health() {
            match h.label.as_str() {
                "ej/a" => assert_eq!(h.phase, ReplicaPhase::Ejected),
                "ej/b" => assert_eq!(h.phase, ReplicaPhase::Live),
                other => panic!("unexpected replica {other}"),
            }
        }
        s.shutdown();
    }

    #[test]
    fn dropping_a_ticket_does_not_leak_an_outstanding_slot() {
        let s = tiny_server(1);
        let ticket = s.submit(Request::new(vec![3, 1])).unwrap();
        drop(ticket); // caller walked away; the worker still executes
        let t0 = std::time::Instant::now();
        while s.metrics.snapshot().completed != 1 {
            assert!(t0.elapsed() < std::time::Duration::from_secs(10), "request never resolved");
            std::thread::yield_now();
        }
        assert_eq!(s.metrics.outstanding(), 0, "a dropped ticket must not leak its slot");
        s.shutdown();
    }

    #[test]
    fn mixed_engine_replicas_serve_together() {
        let bytes = crate::format::mfb::tests::tiny_mfb();
        let sessions = vec![
            Session::builder(bytes.clone()).engine(Engine::MicroFlow).build().unwrap(),
            Session::builder(bytes).engine(Engine::Interp).build().unwrap(),
        ];
        let s = Server::start(sessions, ServerConfig::default()).unwrap();
        for _ in 0..20 {
            let out = s.infer(vec![3, 1]).unwrap();
            // engines agree within ±1 (paper Sec. 6.2.1)
            for (got, want) in out.iter().zip(&[2i8, 0, 5]) {
                assert!((*got as i32 - *want as i32).abs() <= 1, "{out:?}");
            }
        }
        s.shutdown();
    }
}
