//! Worker-pool serving loop (DESIGN.md S16).
//!
//! `Server` owns one worker thread per [`Session`] replica, fed by a
//! bounded request channel (backpressure: `submit` blocks when the queue is
//! full). Each worker runs the dynamic batcher and executes the batch with
//! the session's allocation-free `run_batch_into` — the packed input and
//! output staging buffers are reused across batches, so the steady-state
//! request path allocates only the per-request reply vectors.
//! std::thread + mpsc (no tokio offline — DESIGN.md §7).

use std::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{Context, Result};

use super::batcher::{next_batch, AdaptiveBatcher, BatcherConfig};
use super::metrics::Metrics;
use crate::api::{IoSignature, Session};
use crate::tensor::quant::QParams;

/// One in-flight request.
pub struct Request {
    pub input: Vec<i8>,
    pub enqueued: Instant,
    pub reply: Sender<Result<Vec<i8>>>,
}

/// Server configuration.
#[derive(Clone, Copy, Debug)]
pub struct ServerConfig {
    pub queue_depth: usize,
    pub batcher: BatcherConfig,
    /// Let each worker tune its own effective [`BatcherConfig`] from the
    /// observed queue depth (see
    /// [`AdaptiveBatcher`](super::batcher::AdaptiveBatcher)). Off by
    /// default; the fleet turns it on for its replica pools.
    pub adaptive: bool,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { queue_depth: 256, batcher: BatcherConfig::default(), adaptive: false }
    }
}

/// A serving endpoint for one model — one replica pool: worker threads
/// sharing a bounded queue. A [`Fleet`](super::fleet::Fleet) holds several
/// of these and dispatches across them.
pub struct Server {
    tx: SyncSender<Request>,
    workers: Vec<JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
    signature: IoSignature,
    input_len: usize,
    input_qparams: QParams,
    output_qparams: QParams,
    replicas: usize,
}

impl Server {
    /// Start a server over a set of session replicas (one worker each).
    ///
    /// Replicas are built with [`crate::api::Session::builder`]; mixing
    /// engines across replicas is allowed as long as they serve the same
    /// model signature.
    pub fn start(sessions: Vec<Session>, cfg: ServerConfig) -> Result<Server> {
        anyhow::ensure!(!sessions.is_empty(), "need at least one session");
        let sig = sessions[0].signature().clone();
        let input_len = sig.input_len();
        let input_qparams = sig.input.qparams;
        let output_qparams = sig.output.qparams;
        let replicas = sessions.len();
        for s in &sessions[1..] {
            anyhow::ensure!(
                *s.signature() == sig,
                "replica signatures diverge: {:?} vs {:?}",
                s.signature(),
                sig
            );
        }
        let metrics = Arc::new(Metrics::new());
        let (tx, rx) = sync_channel::<Request>(cfg.queue_depth);
        let shared_rx = Arc::new(std::sync::Mutex::new(rx));
        let mut workers = Vec::new();
        for mut session in sessions {
            let rx = Arc::clone(&shared_rx);
            let metrics = Arc::clone(&metrics);
            let bcfg = BatcherConfig {
                max_batch: cfg.batcher.max_batch.min(session.preferred_batch().max(1)),
                max_wait: cfg.batcher.max_wait,
            };
            let adaptive = cfg.adaptive;
            workers.push(std::thread::spawn(move || {
                worker_loop(&mut session, &rx, &bcfg, adaptive, replicas, &metrics);
            }));
        }
        Ok(Server {
            tx,
            workers,
            metrics,
            signature: sig,
            input_len,
            input_qparams,
            output_qparams,
            replicas,
        })
    }

    pub fn signature(&self) -> &IoSignature {
        &self.signature
    }

    pub fn input_len(&self) -> usize {
        self.input_len
    }

    /// Number of session replicas (worker threads) serving this pool.
    pub fn replicas(&self) -> usize {
        self.replicas
    }

    pub fn input_qparams(&self) -> QParams {
        self.input_qparams
    }

    pub fn output_qparams(&self) -> QParams {
        self.output_qparams
    }

    /// Submit a quantized request; returns the reply channel. Blocks when
    /// the queue is full (backpressure).
    pub fn submit(&self, input: Vec<i8>) -> Result<Receiver<Result<Vec<i8>>>> {
        anyhow::ensure!(input.len() == self.input_len, "input length");
        let (reply_tx, reply_rx) = std::sync::mpsc::channel();
        // count BEFORE the send: a worker may complete the request before
        // this thread resumes, and completed must never exceed submitted
        // (outstanding() would under-report and misroute fleet dispatch)
        self.metrics.record_submitted();
        if self.tx.send(Request { input, enqueued: Instant::now(), reply: reply_tx }).is_err() {
            // balance the counter so outstanding() stays accurate
            self.metrics.record_error();
            anyhow::bail!("server is shut down");
        }
        Ok(reply_rx)
    }

    /// Submit and wait (convenience).
    pub fn infer(&self, input: Vec<i8>) -> Result<Vec<i8>> {
        let rx = self.submit(input)?;
        rx.recv().context("worker dropped reply")?
    }

    /// Graceful shutdown: close the queue and join workers.
    pub fn shutdown(self) {
        drop(self.tx);
        for w in self.workers {
            let _ = w.join();
        }
    }
}

fn worker_loop(
    session: &mut Session,
    rx: &std::sync::Mutex<Receiver<Request>>,
    cfg: &BatcherConfig,
    adaptive: bool,
    replicas: usize,
    metrics: &Metrics,
) {
    let ilen = session.input_len();
    let olen = session.output_len();
    let mut tuner = AdaptiveBatcher::new(*cfg);
    // staging buffers grow to the largest batch once, then are reused
    let mut inputs: Vec<i8> = Vec::new();
    let mut outputs: Vec<i8> = Vec::new();
    loop {
        // hold the lock only while assembling a batch; workers alternate
        let bcfg = if adaptive { tuner.config() } else { *cfg };
        let batch = {
            let rx = rx.lock().unwrap();
            next_batch(&rx, &bcfg)
        };
        let Some(batch) = batch else { return };
        if adaptive {
            // queue-depth proxy right after the cut: outstanding beyond
            // the batch this worker just claimed, averaged per replica —
            // the pool-wide counter includes sibling workers' in-flight
            // batches, which would otherwise read as phantom queue depth
            let beyond = metrics.outstanding().saturating_sub(batch.len() as u64);
            tuner.observe(beyond / (replicas as u64).max(1));
        }
        let n = batch.len();
        metrics.record_batch(n);
        inputs.clear();
        for r in &batch {
            inputs.extend_from_slice(&r.input);
        }
        outputs.resize(n * olen, 0);
        debug_assert_eq!(inputs.len(), n * ilen);
        match session.run_batch_into(&inputs, n, &mut outputs[..n * olen]) {
            Ok(()) => {
                for (i, r) in batch.into_iter().enumerate() {
                    let out = outputs[i * olen..(i + 1) * olen].to_vec();
                    metrics.record(r.enqueued.elapsed());
                    let _ = r.reply.send(Ok(out));
                }
            }
            Err(e) => {
                let msg = format!("batch execution failed: {e:#}");
                for r in batch {
                    metrics.record_error();
                    let _ = r.reply.send(Err(anyhow::anyhow!(msg.clone())));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{Engine, Session};

    fn tiny_server(replicas: usize) -> Server {
        let sessions: Vec<Session> = (0..replicas)
            .map(|_| {
                Session::builder(crate::format::mfb::tests::tiny_mfb())
                    .engine(Engine::MicroFlow)
                    .build()
                    .unwrap()
            })
            .collect();
        Server::start(sessions, ServerConfig::default()).unwrap()
    }

    #[test]
    fn serves_requests_correctly() {
        let s = tiny_server(1);
        let out = s.infer(vec![3, 1]).unwrap();
        assert_eq!(out, vec![2, 0, 5]); // same as the engine unit test
        s.shutdown();
    }

    #[test]
    fn concurrent_submissions_all_complete() {
        let s = Arc::new(tiny_server(2));
        let mut handles = Vec::new();
        for t in 0..8 {
            let s = Arc::clone(&s);
            handles.push(std::thread::spawn(move || {
                for _ in 0..50 {
                    let out = s.infer(vec![t as i8, 1]).unwrap();
                    assert_eq!(out.len(), 3);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let snap = s.metrics.snapshot();
        assert_eq!(snap.completed, 400);
        assert_eq!(snap.errors, 0);
        if let Ok(s) = Arc::try_unwrap(s) {
            s.shutdown();
        }
    }

    #[test]
    fn adaptive_batching_serves_correctly() {
        let sessions = vec![Session::builder(crate::format::mfb::tests::tiny_mfb())
            .engine(Engine::MicroFlow)
            .build()
            .unwrap()];
        let cfg = ServerConfig { adaptive: true, ..ServerConfig::default() };
        let s = Server::start(sessions, cfg).unwrap();
        for _ in 0..30 {
            assert_eq!(s.infer(vec![3, 1]).unwrap(), vec![2, 0, 5]);
        }
        let snap = s.metrics.snapshot();
        assert_eq!(snap.submitted, 30);
        assert_eq!(snap.completed, 30);
        s.shutdown();
    }

    #[test]
    fn rejects_wrong_input_length() {
        let s = tiny_server(1);
        assert!(s.submit(vec![1, 2, 3]).is_err());
        s.shutdown();
    }

    #[test]
    fn mixed_engine_replicas_serve_together() {
        let bytes = crate::format::mfb::tests::tiny_mfb();
        let sessions = vec![
            Session::builder(bytes.clone()).engine(Engine::MicroFlow).build().unwrap(),
            Session::builder(bytes).engine(Engine::Interp).build().unwrap(),
        ];
        let s = Server::start(sessions, ServerConfig::default()).unwrap();
        for _ in 0..20 {
            let out = s.infer(vec![3, 1]).unwrap();
            // engines agree within ±1 (paper Sec. 6.2.1)
            for (got, want) in out.iter().zip(&[2i8, 0, 5]) {
                assert!((*got as i32 - *want as i32).abs() <= 1, "{out:?}");
            }
        }
        s.shutdown();
    }
}
