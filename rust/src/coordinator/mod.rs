//! Serving coordinator (DESIGN.md S16) — the L3 layer of the session
//! architecture.
//!
//! MicroFlow is a per-device inference engine; the coordinator is the host
//! process that serves inference requests over it (and over the PJRT
//! executables), vLLM-router style but sized for TinyML. The whole tier
//! runs on one **request lifecycle**: a typed [`Request`] (payload +
//! [`QosClass`] + optional deadline + id) goes in, a [`Ticket`] comes
//! back, and every stage in between reads the lifecycle fields:
//!
//! * [`request`] — the typed substrate: [`Request`], [`QosClass`]
//!   (Interactive | Bulk | Background), [`Ticket`] (`wait` / `try_wait` /
//!   `wait_deadline` / `cancel`), [`QosProfile`] (a pool's declared
//!   traffic affinity) and [`SubmitError`] (explicit backpressure:
//!   `try_submit` returns `QueueFull` instead of silently blocking;
//!   `submit` keeps the blocking semantics);
//! * execution — [`crate::api::Session`]: the unified session surface
//!   (native MicroFlow engine, TFLM-like interpreter, or PJRT executable);
//!   workers drive the allocation-free `run_batch_into` hot path;
//! * [`batcher`] — QoS-aware dynamic batching: single-class batches
//!   (Interactive cut at the latency posture, Bulk fills `max_batch`),
//!   expired-deadline and cancelled requests shed *before* execution;
//!   per-replica adaptive tuning shifts each worker between latency and
//!   throughput posture from the observed queue depth;
//! * [`server`]  — worker threads + bounded queues (std::thread + mpsc;
//!   tokio is unavailable offline — DESIGN.md §7);
//! * [`fleet`]   — heterogeneous replica pools for one model with
//!   SLO-aware dispatch: best [`QosProfile`] match first (native pool for
//!   Interactive, PJRT/interp pool for Bulk), least-outstanding-requests
//!   within the match set, spill across candidates on `try_submit`;
//! * [`autoscale`] — the SLO-driven control plane over the elastic
//!   server: a deterministic tick policy ([`AutoscalePolicy`] /
//!   [`PolicyState`]) reads windowed shed/missed/p95 signals and grows
//!   pools through a warm [`crate::api::ReplicaFactory`] or shrinks them
//!   via graceful drain ([`Fleet::tick`] is the loop body; every decision
//!   lands in [`FleetSnapshot`]);
//! * [`resilience`] — the fault-tolerance policy layer (PR 8): pure
//!   [`BreakerCore`]/[`BreakerPolicy`] circuit-breaker state machines
//!   (Closed → Open → HalfOpen, tick-counted like the autoscaler) and
//!   [`HealthPolicy`] replica-ejection thresholds; [`Fleet::tick`] wires
//!   both to live pools — failing replicas are quarantined, drained and
//!   warm-replaced, open breakers shed Background/Bulk at admission while
//!   Interactive traffic doubles as the recovery probe;
//! * [`stream`]  — the streaming affinity lane ([`StreamHost`]):
//!   stateful [`crate::stream::StreamSession`]s pinned to one replica
//!   (never split by the batcher), per-stream host-side ring buffers as
//!   durable truth, per-push lifecycle counters holding the exactly-once
//!   identity, and a health pass whose ejection migrates stream state to
//!   a replacement replica via ring replay — bit-exact continuation on
//!   the same pulse cadence;
//! * [`router`]  — model-name → fleet routing for multi-model
//!   deployments, plus the stream registry (`stream_open` / `stream_push`
//!   / `stream_close` route per-stream ids to their model's
//!   [`StreamHost`]);
//! * [`ingress`] — TCP wire protocol + blocking client: the v2 `MFR2`
//!   frame carries class + deadline, legacy v1 `MFRQ` frames are served
//!   with configurable defaults ([`IngressConfig`]), and the v3 `MFR3`
//!   frame-per-chunk protocol carries streaming open/push/close rounds
//!   with per-stream ids; declared payload lengths are bounds-checked
//!   against [`IngressConfig::max_payload`] before any allocation;
//! * [`metrics`] — per-class latency (p50/p95/p99) and lifecycle counters
//!   (completed, `failed`, `retried`, `shed`, `cancelled`,
//!   `deadline_missed`; `completed + shed + cancelled + failed ==
//!   submitted` always) plus the per-replica health registry
//!   ([`ReplicaHealth`]) feeding ejection, reported by the e2e example
//!   (`examples/serve_keywords.rs`). The windowed view is drained through
//!   a [`WindowConsumer`] token — minted once per pool, so the tick loop
//!   is provably the single consumer of each window cursor.
//!
//! The observability plane ([`crate::observe`]) rides on this tier
//! read-only: workers record [`crate::observe::Phase`] span events into
//! per-worker rings, [`Fleet::tick`] drains rings, windows and per-step
//! profiles into [`PoolTickReport`]s, and the exposition tier renders
//! only what the tick drained. No policy decision reads a span ring.

pub mod autoscale;
pub mod batcher;
pub mod fleet;
pub mod ingress;
pub mod metrics;
pub mod request;
pub mod resilience;
pub mod router;
pub mod server;
pub mod stream;

// the execution surface lives in `crate::api`; re-exported here because
// every server deployment needs it alongside the coordinator types
pub use crate::api::{
    Engine, FailureKind, FaultPlan, FaultySession, InferenceSession, InjectedFault, ReplicaFactory,
    Session, SessionBuilder, SessionCache,
};
pub use autoscale::{
    AutoscalePolicy, AutoscaleStatus, Decision, PolicyState, ScaleAction, ScaleReason, TickSignals,
};
pub use batcher::{AdaptiveBatcher, BatcherConfig};
pub use fleet::{Fleet, FleetSnapshot, PoolSnapshot, PoolSpec, PoolTickReport};
pub use ingress::{Client, Ingress, IngressConfig};
pub use metrics::{
    ClassSnapshot, ClassWindow, Metrics, MetricsSnapshot, ReplicaHealth, ReplicaHealthSnapshot,
    ReplicaPhase, WindowConsumer, WindowSnapshot,
};
pub use request::{
    QosClass, QosProfile, QueueEntry, ReplicaError, Request, SubmitError, Ticket,
};
pub use resilience::{BreakerCore, BreakerPolicy, BreakerState, HealthPolicy};
pub use router::Router;
pub use server::{Server, ServerConfig};
pub use stream::{
    StreamCounters, StreamFault, StreamHost, StreamHostConfig, StreamHostSnapshot, StreamPush,
    StreamSnapshot, StreamTickReport, StreamWorkerSnapshot,
};
