//! Serving coordinator (DESIGN.md S16) — the L3 layer of the session
//! architecture.
//!
//! MicroFlow is a per-device inference engine; the coordinator is the host
//! process that serves inference requests over it (and over the PJRT
//! executables), vLLM-router style but sized for TinyML:
//!
//! * execution — [`crate::api::Session`]: the unified session surface
//!   (native MicroFlow engine, TFLM-like interpreter, or PJRT executable)
//!   replaced the coordinator-private `Backend` trait; workers drive the
//!   allocation-free `run_batch_into` hot path;
//! * [`batcher`] — dynamic batching: requests accumulate until
//!   `max_batch` or `max_wait` elapses, then execute as one batch
//!   (fills the AOT'd batch variants of the PJRT path); per-replica
//!   adaptive tuning shifts each worker between latency and throughput
//!   posture from the observed queue depth;
//! * [`server`]  — worker threads + bounded queues (std::thread + mpsc;
//!   tokio is unavailable offline — DESIGN.md §7). Bounded channels give
//!   backpressure: submit blocks when the queue is full;
//! * [`fleet`]   — heterogeneous replica pools for one model with
//!   least-outstanding-requests dispatch across pools (e.g. a PJRT pool
//!   for bulk throughput next to a native pool for low latency);
//! * [`router`]  — model-name → fleet routing for multi-model
//!   deployments;
//! * [`ingress`] — TCP wire protocol + blocking client, so external
//!   processes can drive the router (the deployment surface);
//! * [`metrics`] — per-model latency (p50/p95/p99) and throughput
//!   counters, reported by the e2e example (`examples/serve_keywords.rs`).

pub mod batcher;
pub mod fleet;
pub mod ingress;
pub mod metrics;
pub mod router;
pub mod server;

// the execution surface lives in `crate::api`; re-exported here because
// every server deployment needs it alongside the coordinator types
pub use crate::api::{Engine, InferenceSession, Session, SessionBuilder, SessionCache};
pub use batcher::{AdaptiveBatcher, BatcherConfig};
pub use fleet::{Fleet, FleetSnapshot, PoolSpec};
pub use ingress::{Client, Ingress};
pub use metrics::{Metrics, MetricsSnapshot};
pub use router::Router;
pub use server::{Server, ServerConfig};
