//! TCP ingress (DESIGN.md S16): a wire protocol in front of the
//! coordinator, so external clients can drive inference — the serving
//! deployment surface (std::net; tokio is unavailable offline).
//!
//! ## Wire protocol (little-endian, length-prefixed)
//!
//! ```text
//! request:  magic "MFRQ" | u16 model-name len | name bytes
//!           | u32 payload len | i8 payload (quantized input)
//! response: magic "MFRS" | u8 status (0 ok, 1 error)
//!           | u32 payload len | i8 payload (quantized output)
//!             -- or, on error, utf8 message bytes
//! ```
//!
//! One request per connection round (connections may pipeline rounds
//! sequentially). The accept loop hands each connection to a handler
//! thread; inference requests flow through the [`Router`] into the
//! batched worker pools, so concurrent connections batch together.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{bail, Context, Result};

use super::router::Router;

/// A running TCP ingress.
pub struct Ingress {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
}

impl Ingress {
    /// Bind and serve `router` on `addr` (use port 0 for an ephemeral
    /// port; the bound address is in `self.addr`).
    pub fn start(addr: &str, router: Arc<Router>) -> Result<Ingress> {
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let accept_thread = std::thread::spawn(move || {
            let mut handlers: Vec<JoinHandle<()>> = Vec::new();
            while !stop2.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        // idle-read timeout so handler threads cannot
                        // outlive an abandoned connection indefinitely
                        let _ = stream.set_read_timeout(Some(std::time::Duration::from_secs(30)));
                        let router = Arc::clone(&router);
                        handlers.push(std::thread::spawn(move || {
                            let _ = handle_connection(stream, &router);
                        }));
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(2));
                    }
                    Err(_) => break,
                }
                handlers.retain(|h| !h.is_finished());
            }
            // handler threads are NOT joined: they exit on client EOF or
            // read timeout; joining here would deadlock shutdown against
            // clients that keep their connection open
        });
        Ok(Ingress { addr: local, stop, accept_thread: Some(accept_thread) })
    }

    /// Stop accepting and join the accept loop.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

fn handle_connection(mut stream: TcpStream, router: &Router) -> Result<()> {
    stream.set_nodelay(true).ok();
    loop {
        let mut magic = [0u8; 4];
        match stream.read_exact(&mut magic) {
            Ok(()) => {}
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::UnexpectedEof
                        | std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                ) =>
            {
                return Ok(())
            }
            Err(e) => return Err(e.into()),
        }
        if &magic != b"MFRQ" {
            write_error(&mut stream, "bad request magic")?;
            return Ok(());
        }
        let mut b2 = [0u8; 2];
        stream.read_exact(&mut b2)?;
        let name_len = u16::from_le_bytes(b2) as usize;
        if name_len > 256 {
            write_error(&mut stream, "model name too long")?;
            return Ok(());
        }
        let mut name = vec![0u8; name_len];
        stream.read_exact(&mut name)?;
        let name = String::from_utf8(name).context("model name utf8")?;
        let mut b4 = [0u8; 4];
        stream.read_exact(&mut b4)?;
        let payload_len = u32::from_le_bytes(b4) as usize;
        if payload_len > 16 * 1024 * 1024 {
            write_error(&mut stream, "payload too large")?;
            return Ok(());
        }
        let mut payload = vec![0u8; payload_len];
        stream.read_exact(&mut payload)?;
        let input: Vec<i8> = payload.iter().map(|&b| b as i8).collect();

        match router.infer(&name, input) {
            Ok(out) => {
                stream.write_all(b"MFRS")?;
                stream.write_all(&[0u8])?;
                stream.write_all(&(out.len() as u32).to_le_bytes())?;
                let bytes: Vec<u8> = out.iter().map(|&v| v as u8).collect();
                stream.write_all(&bytes)?;
            }
            Err(e) => write_error(&mut stream, &format!("{e:#}"))?,
        }
        stream.flush()?;
    }
}

fn write_error(stream: &mut TcpStream, msg: &str) -> Result<()> {
    stream.write_all(b"MFRS")?;
    stream.write_all(&[1u8])?;
    stream.write_all(&(msg.len() as u32).to_le_bytes())?;
    stream.write_all(msg.as_bytes())?;
    stream.flush()?;
    Ok(())
}

/// Minimal blocking client for tests, examples and the CLI.
pub struct Client {
    stream: TcpStream,
}

impl Client {
    pub fn connect(addr: impl std::net::ToSocketAddrs) -> Result<Client> {
        let stream = TcpStream::connect(addr).context("connect")?;
        stream.set_nodelay(true).ok();
        Ok(Client { stream })
    }

    /// One inference round-trip.
    pub fn infer(&mut self, model: &str, input: &[i8]) -> Result<Vec<i8>> {
        let s = &mut self.stream;
        s.write_all(b"MFRQ")?;
        s.write_all(&(model.len() as u16).to_le_bytes())?;
        s.write_all(model.as_bytes())?;
        s.write_all(&(input.len() as u32).to_le_bytes())?;
        let bytes: Vec<u8> = input.iter().map(|&v| v as u8).collect();
        s.write_all(&bytes)?;
        s.flush()?;

        let mut magic = [0u8; 4];
        s.read_exact(&mut magic)?;
        if &magic != b"MFRS" {
            bail!("bad response magic");
        }
        let mut status = [0u8; 1];
        s.read_exact(&mut status)?;
        let mut b4 = [0u8; 4];
        s.read_exact(&mut b4)?;
        let len = u32::from_le_bytes(b4) as usize;
        let mut payload = vec![0u8; len];
        s.read_exact(&mut payload)?;
        if status[0] != 0 {
            bail!("server error: {}", String::from_utf8_lossy(&payload));
        }
        Ok(payload.iter().map(|&b| b as i8).collect())
    }
}
