//! TCP ingress (DESIGN.md S16): a wire protocol in front of the
//! coordinator, so external clients can drive inference — the serving
//! deployment surface (std::net; tokio is unavailable offline).
//!
//! ## Wire protocol (little-endian, length-prefixed)
//!
//! Three request frames are accepted on the same port:
//!
//! ```text
//! v1 request: magic "MFRQ" | u16 model-name len | name bytes
//!             | u32 payload len | i8 payload (quantized input)
//! v2 request: magic "MFR2" | u8 class (0 interactive, 1 bulk, 2 background)
//!             | u32 deadline-ms (0 = none; relative to receipt)
//!             | u16 model-name len | name bytes
//!             | u32 payload len | i8 payload (quantized input)
//! response:   magic "MFRS" | u8 status (0 ok, 1 error)
//!             | u32 payload len | i8 payload (quantized output)
//!               -- or, on error, utf8 message bytes
//!
//! v3 stream:  magic "MFR3" | u8 op
//!   op 0 open:  u16 model-name len | name bytes
//!   op 1 push:  u64 stream id | u32 payload len | i8 frame (one chunk)
//!   op 2 close: u64 stream id
//! v3 reply:   magic "MFS3" | u8 status | u32 payload len | payload
//!   status 0 verdict    (payload: i8 quantized output)
//!   status 1 error      (payload: utf8 message)
//!   status 2 no-verdict (payload empty: warmup or mid-pulse)
//!   status 3 opened     (payload: u64 stream id)
//!   status 4 closed     (payload: six u64 lifecycle counters —
//!                        submitted, completed, shed, cancelled, failed,
//!                        verdicts)
//!
//! stats:      magic "STAT" (no body)
//! reply:      magic "MFST" | u32 payload len
//!             | utf8 Prometheus-text exposition snapshot
//! ```
//!
//! `STAT` is deliberately version-agnostic: it carries no body and its
//! reply is self-describing text, so any client generation can probe a
//! deployment's metrics without speaking the request framing. When no
//! exposition tier is attached the reply is a one-comment placeholder
//! body rather than an error (see [`Router::render_metrics`]).
//!
//! A v1 frame is served with the configured
//! [`IngressConfig::default_class`] and default deadline, so legacy
//! clients round-trip unchanged against the v2 ingress. A request shed for
//! a missed deadline (or cancelled server-side) comes back as a status-1
//! error frame naming the cause.
//!
//! The v3 frames drive the streaming lane ([`super::StreamHost`] via the
//! router's stream registry): one frame-per-chunk `push` per round, many
//! rounds per connection, interleaving freely with v1/v2 rounds. Every
//! declared payload length (all three versions) is bounds-checked against
//! [`IngressConfig::max_payload`] **before** any allocation; an oversized
//! declaration earns a typed error frame, never a buffer.
//!
//! One request per connection round (connections may pipeline rounds
//! sequentially). The accept loop hands each connection to a handler
//! thread and reaps finished handlers every iteration — joining them as
//! they finish, so a long-running server's handler set stays bounded by
//! the number of *live* connections rather than growing with every
//! connection ever accepted. Inference requests flow through the
//! [`Router`] into the batched worker pools, so concurrent connections
//! batch together.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::request::{QosClass, Request};
use super::router::Router;
use super::stream::{StreamCounters, StreamPush};

/// Ingress-side request-lifecycle defaults, applied to frames that do not
/// carry their own class/deadline (all v1 frames; v2 frames with
/// deadline-ms 0). Deployments pass it to [`Ingress::start_with`]; the
/// CLI's `--default-class` / `--shed-after-ms` flags apply the same
/// defaults to its synthetic load generator.
#[derive(Clone, Copy, Debug)]
pub struct IngressConfig {
    /// Class assigned to frames that name none (every v1 frame).
    pub default_class: QosClass,
    /// Deadline applied when a frame carries none: requests still queued
    /// past it are shed.
    pub default_deadline: Option<Duration>,
    /// Largest declared payload (bytes) any frame version may carry;
    /// checked before allocating the receive buffer. Oversized frames
    /// earn a typed error reply.
    pub max_payload: usize,
}

impl Default for IngressConfig {
    fn default() -> Self {
        // Bulk + no deadline: exactly the legacy ingress semantics
        IngressConfig {
            default_class: QosClass::Bulk,
            default_deadline: None,
            max_payload: 16 * 1024 * 1024,
        }
    }
}

/// A running TCP ingress.
pub struct Ingress {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
}

impl Ingress {
    /// Bind and serve `router` on `addr` with default lifecycle config
    /// (use port 0 for an ephemeral port; the bound address is in
    /// `self.addr`).
    pub fn start(addr: &str, router: Arc<Router>) -> Result<Ingress> {
        Ingress::start_with(addr, router, IngressConfig::default())
    }

    /// Bind and serve with explicit request-lifecycle defaults.
    pub fn start_with(addr: &str, router: Arc<Router>, cfg: IngressConfig) -> Result<Ingress> {
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let accept_thread = std::thread::spawn(move || {
            let mut handlers: Vec<JoinHandle<()>> = Vec::new();
            while !stop2.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        // idle-read timeout so handler threads cannot
                        // outlive an abandoned connection indefinitely
                        let _ = stream.set_read_timeout(Some(Duration::from_secs(30)));
                        let router = Arc::clone(&router);
                        handlers.push(std::thread::spawn(move || {
                            let _ = handle_connection(stream, &router, cfg);
                        }));
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(2));
                    }
                    Err(_) => break,
                }
                reap_finished(&mut handlers);
            }
            // live handler threads are NOT joined at shutdown: they exit
            // on client EOF or read timeout; joining here would deadlock
            // shutdown against clients that keep their connection open
        });
        Ok(Ingress { addr: local, stop, accept_thread: Some(accept_thread) })
    }

    /// Stop accepting and join the accept loop.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

/// Join every finished handler thread, keeping only live ones — the
/// accept loop calls this each iteration so the handler set stays bounded
/// by concurrent connections (joining a finished thread is immediate and
/// releases its stack instead of leaking a `JoinHandle` per connection
/// ever served).
fn reap_finished(handlers: &mut Vec<JoinHandle<()>>) {
    let mut i = 0;
    while i < handlers.len() {
        if handlers[i].is_finished() {
            let h = handlers.swap_remove(i);
            let _ = h.join();
        } else {
            i += 1;
        }
    }
}

fn read_u16(stream: &mut TcpStream) -> std::io::Result<u16> {
    let mut b = [0u8; 2];
    stream.read_exact(&mut b)?;
    Ok(u16::from_le_bytes(b))
}

fn read_u32(stream: &mut TcpStream) -> std::io::Result<u32> {
    let mut b = [0u8; 4];
    stream.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(stream: &mut TcpStream) -> std::io::Result<u64> {
    let mut b = [0u8; 8];
    stream.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn handle_connection(mut stream: TcpStream, router: &Router, cfg: IngressConfig) -> Result<()> {
    stream.set_nodelay(true).ok();
    loop {
        let mut magic = [0u8; 4];
        match stream.read_exact(&mut magic) {
            Ok(()) => {}
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::UnexpectedEof
                        | std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                ) =>
            {
                return Ok(())
            }
            Err(e) => return Err(e.into()),
        }
        // v3 rounds route to the streaming lane and pipeline like the rest
        if &magic == b"MFR3" {
            if handle_stream_op(&mut stream, router, cfg)? {
                continue;
            }
            return Ok(());
        }
        // stats rounds render the exposition snapshot and pipeline too
        if &magic == b"STAT" {
            let body = router.render_metrics();
            stream.write_all(b"MFST")?;
            stream.write_all(&(body.len() as u32).to_le_bytes())?;
            stream.write_all(body.as_bytes())?;
            stream.flush()?;
            continue;
        }
        // lifecycle header: v2 carries class + deadline, v1 uses defaults
        let (class, deadline_ms) = match &magic {
            b"MFRQ" => (cfg.default_class, 0u32),
            b"MFR2" => {
                let mut cb = [0u8; 1];
                stream.read_exact(&mut cb)?;
                let class = match QosClass::from_u8(cb[0]) {
                    Ok(c) => c,
                    Err(e) => {
                        write_error(&mut stream, &format!("{e:#}"))?;
                        return Ok(());
                    }
                };
                (class, read_u32(&mut stream)?)
            }
            _ => {
                write_error(&mut stream, "bad request magic")?;
                return Ok(());
            }
        };
        let name_len = read_u16(&mut stream)? as usize;
        if name_len > 256 {
            write_error(&mut stream, "model name too long")?;
            return Ok(());
        }
        let mut name = vec![0u8; name_len];
        stream.read_exact(&mut name)?;
        let name = String::from_utf8(name).context("model name utf8")?;
        // bounds-check the declared length BEFORE allocating the buffer
        let payload_len = read_u32(&mut stream)? as usize;
        if payload_len > cfg.max_payload {
            write_error(
                &mut stream,
                &format!("payload of {payload_len} bytes exceeds limit {}", cfg.max_payload),
            )?;
            return Ok(());
        }
        let mut payload = vec![0u8; payload_len];
        stream.read_exact(&mut payload)?;
        let input: Vec<i8> = payload.iter().map(|&b| b as i8).collect();

        // deadline is relative to receipt; 0 falls back to the configured
        // default (if any)
        let deadline = if deadline_ms > 0 {
            Some(Instant::now() + Duration::from_millis(deadline_ms as u64))
        } else {
            cfg.default_deadline.map(|d| Instant::now() + d)
        };
        let mut req = Request::new(input).with_class(class);
        if let Some(d) = deadline {
            req = req.with_deadline(d);
        }
        match router.submit(&name, req).and_then(|ticket| ticket.wait()) {
            Ok(out) => {
                stream.write_all(b"MFRS")?;
                stream.write_all(&[0u8])?;
                stream.write_all(&(out.len() as u32).to_le_bytes())?;
                let bytes: Vec<u8> = out.iter().map(|&v| v as u8).collect();
                stream.write_all(&bytes)?;
            }
            Err(e) => write_error(&mut stream, &format!("{e:#}"))?,
        }
        stream.flush()?;
    }
}

fn write_error(stream: &mut TcpStream, msg: &str) -> Result<()> {
    stream.write_all(b"MFRS")?;
    stream.write_all(&[1u8])?;
    stream.write_all(&(msg.len() as u32).to_le_bytes())?;
    stream.write_all(msg.as_bytes())?;
    stream.flush()?;
    Ok(())
}

/// v3 reply statuses (`MFS3`).
const S3_VERDICT: u8 = 0;
const S3_ERROR: u8 = 1;
const S3_NO_VERDICT: u8 = 2;
const S3_OPENED: u8 = 3;
const S3_CLOSED: u8 = 4;

fn write_stream_reply(stream: &mut TcpStream, status: u8, payload: &[u8]) -> Result<()> {
    stream.write_all(b"MFS3")?;
    stream.write_all(&[status])?;
    stream.write_all(&(payload.len() as u32).to_le_bytes())?;
    stream.write_all(payload)?;
    stream.flush()?;
    Ok(())
}

/// One v3 round (the magic has been consumed). Returns `true` to keep the
/// connection pipelining, `false` to drop it (malformed op).
fn handle_stream_op(stream: &mut TcpStream, router: &Router, cfg: IngressConfig) -> Result<bool> {
    let mut op = [0u8; 1];
    stream.read_exact(&mut op)?;
    match op[0] {
        0 => {
            // open: u16 name len | name
            let name_len = read_u16(stream)? as usize;
            if name_len > 256 {
                write_stream_reply(stream, S3_ERROR, b"model name too long")?;
                return Ok(false);
            }
            let mut name = vec![0u8; name_len];
            stream.read_exact(&mut name)?;
            let name = String::from_utf8(name).context("model name utf8")?;
            match router.stream_open(&name) {
                Ok(id) => write_stream_reply(stream, S3_OPENED, &id.to_le_bytes())?,
                Err(e) => write_stream_reply(stream, S3_ERROR, format!("{e:#}").as_bytes())?,
            }
            Ok(true)
        }
        1 => {
            // push: u64 stream id | u32 frame len | frame bytes
            let id = read_u64(stream)?;
            let frame_len = read_u32(stream)? as usize;
            if frame_len > cfg.max_payload {
                write_stream_reply(
                    stream,
                    S3_ERROR,
                    format!("frame of {frame_len} bytes exceeds limit {}", cfg.max_payload)
                        .as_bytes(),
                )?;
                return Ok(false);
            }
            let mut frame = vec![0u8; frame_len];
            stream.read_exact(&mut frame)?;
            let input: Vec<i8> = frame.iter().map(|&b| b as i8).collect();
            match router.stream_push(id, &input) {
                Ok(StreamPush::Verdict(out)) => {
                    let bytes: Vec<u8> = out.iter().map(|&v| v as u8).collect();
                    write_stream_reply(stream, S3_VERDICT, &bytes)?;
                }
                Ok(StreamPush::Pending) => write_stream_reply(stream, S3_NO_VERDICT, &[])?,
                Ok(StreamPush::Closed) => {
                    write_stream_reply(stream, S3_ERROR, b"stream cancelled")?
                }
                Ok(StreamPush::Shed) => write_stream_reply(
                    stream,
                    S3_ERROR,
                    b"push shed: replica quarantined (frame retained; keep pushing)",
                )?,
                Ok(StreamPush::Failed(msg)) => write_stream_reply(
                    stream,
                    S3_ERROR,
                    format!("push failed: {msg} (frame retained; keep pushing)").as_bytes(),
                )?,
                Err(e) => write_stream_reply(stream, S3_ERROR, format!("{e:#}").as_bytes())?,
            }
            Ok(true)
        }
        2 => {
            // close: u64 stream id → final lifecycle counters
            let id = read_u64(stream)?;
            match router.stream_close(id) {
                Ok(c) => {
                    let mut payload = Vec::with_capacity(48);
                    for v in [c.submitted, c.completed, c.shed, c.cancelled, c.failed, c.verdicts]
                    {
                        payload.extend_from_slice(&v.to_le_bytes());
                    }
                    write_stream_reply(stream, S3_CLOSED, &payload)?;
                }
                Err(e) => write_stream_reply(stream, S3_ERROR, format!("{e:#}").as_bytes())?,
            }
            Ok(true)
        }
        other => {
            write_stream_reply(stream, S3_ERROR, format!("bad stream op {other}").as_bytes())?;
            Ok(false)
        }
    }
}

/// Minimal blocking client for tests, examples and the CLI.
pub struct Client {
    stream: TcpStream,
}

impl Client {
    pub fn connect(addr: impl std::net::ToSocketAddrs) -> Result<Client> {
        let stream = TcpStream::connect(addr).context("connect")?;
        stream.set_nodelay(true).ok();
        Ok(Client { stream })
    }

    /// One inference round-trip on the legacy v1 `MFRQ` frame (no class,
    /// no deadline — the server applies its configured defaults). Kept
    /// deliberately: it doubles as the v1-compatibility probe.
    pub fn infer(&mut self, model: &str, input: &[i8]) -> Result<Vec<i8>> {
        let s = &mut self.stream;
        s.write_all(b"MFRQ")?;
        Self::write_body(s, model, input)?;
        Self::read_response(s)
    }

    /// One inference round-trip on the v2 `MFR2` frame with an explicit
    /// QoS class and optional deadline (milliseconds from server receipt;
    /// `None` leaves the server's default in force).
    pub fn infer_with(
        &mut self,
        model: &str,
        input: &[i8],
        class: QosClass,
        deadline_ms: Option<u32>,
    ) -> Result<Vec<i8>> {
        let s = &mut self.stream;
        s.write_all(b"MFR2")?;
        s.write_all(&[class.as_u8()])?;
        s.write_all(&deadline_ms.unwrap_or(0).to_le_bytes())?;
        Self::write_body(s, model, input)?;
        Self::read_response(s)
    }

    fn write_body(s: &mut TcpStream, model: &str, input: &[i8]) -> Result<()> {
        s.write_all(&(model.len() as u16).to_le_bytes())?;
        s.write_all(model.as_bytes())?;
        s.write_all(&(input.len() as u32).to_le_bytes())?;
        let bytes: Vec<u8> = input.iter().map(|&v| v as u8).collect();
        s.write_all(&bytes)?;
        s.flush()?;
        Ok(())
    }

    fn read_response(s: &mut TcpStream) -> Result<Vec<i8>> {
        let mut magic = [0u8; 4];
        s.read_exact(&mut magic)?;
        if &magic != b"MFRS" {
            bail!("bad response magic");
        }
        let mut status = [0u8; 1];
        s.read_exact(&mut status)?;
        let mut b4 = [0u8; 4];
        s.read_exact(&mut b4)?;
        let len = u32::from_le_bytes(b4) as usize;
        let mut payload = vec![0u8; len];
        s.read_exact(&mut payload)?;
        if status[0] != 0 {
            bail!("server error: {}", String::from_utf8_lossy(&payload));
        }
        Ok(payload.iter().map(|&b| b as i8).collect())
    }

    /// Open a v3 stream on `model`; the returned id addresses
    /// [`Client::push_frame`] / [`Client::close_stream`].
    pub fn open_stream(&mut self, model: &str) -> Result<u64> {
        let s = &mut self.stream;
        s.write_all(b"MFR3")?;
        s.write_all(&[0u8])?;
        s.write_all(&(model.len() as u16).to_le_bytes())?;
        s.write_all(model.as_bytes())?;
        s.flush()?;
        let (status, payload) = Self::read_stream_reply(s)?;
        match status {
            S3_OPENED if payload.len() == 8 => {
                Ok(u64::from_le_bytes(payload.try_into().unwrap()))
            }
            S3_ERROR => bail!("open failed: {}", String::from_utf8_lossy(&payload)),
            _ => bail!("unexpected open reply status {status}"),
        }
    }

    /// Push one frame (one chunk) to an open stream. `Ok(Some(verdict))`
    /// at pulse boundaries, `Ok(None)` while warming up or mid-pulse.
    pub fn push_frame(&mut self, id: u64, frame: &[i8]) -> Result<Option<Vec<i8>>> {
        let s = &mut self.stream;
        s.write_all(b"MFR3")?;
        s.write_all(&[1u8])?;
        s.write_all(&id.to_le_bytes())?;
        s.write_all(&(frame.len() as u32).to_le_bytes())?;
        let bytes: Vec<u8> = frame.iter().map(|&v| v as u8).collect();
        s.write_all(&bytes)?;
        s.flush()?;
        let (status, payload) = Self::read_stream_reply(s)?;
        match status {
            S3_VERDICT => Ok(Some(payload.iter().map(|&b| b as i8).collect())),
            S3_NO_VERDICT => Ok(None),
            S3_ERROR => bail!("push failed: {}", String::from_utf8_lossy(&payload)),
            _ => bail!("unexpected push reply status {status}"),
        }
    }

    /// End-of-stream close; returns the stream's final lifecycle
    /// counters.
    pub fn close_stream(&mut self, id: u64) -> Result<StreamCounters> {
        let s = &mut self.stream;
        s.write_all(b"MFR3")?;
        s.write_all(&[2u8])?;
        s.write_all(&id.to_le_bytes())?;
        s.flush()?;
        let (status, payload) = Self::read_stream_reply(s)?;
        match status {
            S3_CLOSED if payload.len() == 48 => {
                let mut vals = [0u64; 6];
                for (i, v) in vals.iter_mut().enumerate() {
                    *v = u64::from_le_bytes(payload[i * 8..(i + 1) * 8].try_into().unwrap());
                }
                Ok(StreamCounters {
                    submitted: vals[0],
                    completed: vals[1],
                    shed: vals[2],
                    cancelled: vals[3],
                    failed: vals[4],
                    verdicts: vals[5],
                })
            }
            S3_ERROR => bail!("close failed: {}", String::from_utf8_lossy(&payload)),
            _ => bail!("unexpected close reply status {status}"),
        }
    }

    /// One `STAT` round-trip: the deployment's current exposition
    /// snapshot as Prometheus text (or the placeholder comment when no
    /// exposition tier is attached).
    pub fn stats(&mut self) -> Result<String> {
        let s = &mut self.stream;
        s.write_all(b"STAT")?;
        s.flush()?;
        let mut magic = [0u8; 4];
        s.read_exact(&mut magic)?;
        if &magic != b"MFST" {
            bail!("bad stats reply magic");
        }
        let mut b4 = [0u8; 4];
        s.read_exact(&mut b4)?;
        let len = u32::from_le_bytes(b4) as usize;
        let mut payload = vec![0u8; len];
        s.read_exact(&mut payload)?;
        String::from_utf8(payload).context("stats body utf8")
    }

    fn read_stream_reply(s: &mut TcpStream) -> Result<(u8, Vec<u8>)> {
        let mut magic = [0u8; 4];
        s.read_exact(&mut magic)?;
        if &magic != b"MFS3" {
            bail!("bad stream reply magic");
        }
        let mut status = [0u8; 1];
        s.read_exact(&mut status)?;
        let mut b4 = [0u8; 4];
        s.read_exact(&mut b4)?;
        let len = u32::from_le_bytes(b4) as usize;
        let mut payload = vec![0u8; len];
        s.read_exact(&mut payload)?;
        Ok((status[0], payload))
    }
}
