//! Request lifecycle (DESIGN.md S16) — typed requests, QoS classes and
//! response tickets.
//!
//! The serving tier used to funnel everything through
//! `submit(Vec<i8>) -> Receiver<Result<Vec<i8>>>`: requests carried no
//! identity, no class, no deadline, could not be cancelled or shed, and
//! dispatch could only balance by load. This module is the typed substrate
//! the whole request path now runs on:
//!
//! * [`Request`] — payload + [`QosClass`] + optional deadline + unique id.
//!   Built with [`Request::new`] (Bulk, no deadline — the legacy
//!   semantics) and refined with `with_class` / `with_deadline_in`;
//! * [`Ticket`] — the response handle returned by every submit path
//!   (`wait`, `try_wait`, `wait_deadline`, `cancel`, `id`), replacing the
//!   raw mpsc `Receiver` in `Server`, `Fleet` and `Router`;
//! * [`QosProfile`] — a pool's declared affinity (native →
//!   Interactive-preferred, PJRT/interp → Bulk); the fleet routes each
//!   request to the best profile match first and balances by
//!   least-outstanding load only within that match set;
//! * [`SubmitError`] — explicit backpressure: `try_submit` returns
//!   [`SubmitError::QueueFull`] (handing the request back for retry or
//!   spill) instead of silently blocking;
//! * [`Pending`] — the queue entry behind a ticket (request + reply sender
//!   + enqueue timestamp); the batcher sheds expired-deadline and
//!   cancelled entries before execution, so a cancelled ticket's slot is
//!   never executed;
//! * [`QueueEntry`] — what actually travels on a server's bounded queue: a
//!   `Pending` request, or the retire sentinel the elastic server uses to
//!   drain one worker gracefully (see the enum docs for the protocol).
//!
//! Cancellation is cooperative and pre-execution: `cancel` flips a shared
//! flag that the batcher checks when it claims the entry. A request
//! already inside an executing batch completes normally (the result is
//! simply discarded by the caller); one still queued is dropped, counted
//! in `Metrics::cancelled`, and its ticket resolves to a "cancelled"
//! error.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::api::faulty::FailureKind;
use crate::api::Engine;

/// Quality-of-service class of one request — the routing and batching
/// signal (paper Sec. 2: "critical environments" need bounded latency as
/// much as throughput).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum QosClass {
    /// Small latency-sensitive request: routed to Interactive-preferred
    /// pools, batched under the latency posture (never held for the full
    /// batching window).
    Interactive,
    /// Throughput-oriented request: fills batches up to `max_batch` — the
    /// legacy submit semantics, and the default (so untyped callers
    /// behave exactly as before).
    #[default]
    Bulk,
    /// Deferrable work: today batched and routed exactly like Bulk, but
    /// tagged separately so its metrics lane stays distinct and future
    /// policies (priority queues, shedding order) can treat it as the
    /// first class to yield when capacity is short.
    Background,
}

impl QosClass {
    /// All classes, in `index()` order (per-class metrics lanes).
    pub const ALL: [QosClass; 3] = [QosClass::Interactive, QosClass::Bulk, QosClass::Background];

    /// Dense index for per-class counter arrays.
    pub fn index(self) -> usize {
        match self {
            QosClass::Interactive => 0,
            QosClass::Bulk => 1,
            QosClass::Background => 2,
        }
    }

    /// Stable lowercase name (CLI values, metrics labels).
    pub fn name(self) -> &'static str {
        match self {
            QosClass::Interactive => "interactive",
            QosClass::Bulk => "bulk",
            QosClass::Background => "background",
        }
    }

    /// Wire encoding for the `MFR2` request frame.
    pub fn as_u8(self) -> u8 {
        self.index() as u8
    }

    /// Decode the `MFR2` class byte.
    pub fn from_u8(b: u8) -> Result<QosClass> {
        match b {
            0 => Ok(QosClass::Interactive),
            1 => Ok(QosClass::Bulk),
            2 => Ok(QosClass::Background),
            other => bail!("unknown QoS class byte {other} (0 int | 1 bulk | 2 background)"),
        }
    }
}

impl std::fmt::Display for QosClass {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for QosClass {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "interactive" | "int" => QosClass::Interactive,
            "bulk" => QosClass::Bulk,
            "background" | "bg" => QosClass::Background,
            other => bail!("unknown QoS class {other:?} (interactive | bulk | background)"),
        })
    }
}

/// A replica pool's declared traffic affinity. The fleet routes each
/// request to pools preferring its class; only when no pool prefers it
/// does routing widen to [`QosProfile::Any`] pools, then to every pool.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QosProfile {
    /// Low-latency pool (e.g. native MicroFlow sessions): prefers
    /// Interactive traffic.
    Interactive,
    /// Throughput pool (e.g. PJRT batched execution, or the interpreter
    /// baseline as spill capacity): prefers Bulk and Background traffic.
    Bulk,
    /// No declared affinity: serves whatever dispatch sends (the default,
    /// and the pre-QoS behavior).
    Any,
}

impl QosProfile {
    /// Does this pool prefer requests of `class`? `Any` prefers nothing —
    /// it is the fallback tier, not a match.
    pub fn prefers(self, class: QosClass) -> bool {
        match self {
            QosProfile::Interactive => class == QosClass::Interactive,
            QosProfile::Bulk => matches!(class, QosClass::Bulk | QosClass::Background),
            QosProfile::Any => false,
        }
    }

    /// The natural profile for a pool of `engine` sessions: native engine
    /// pools are latency-preferred, PJRT/interpreter pools are
    /// throughput-preferred.
    pub fn for_engine(engine: Engine) -> QosProfile {
        match engine {
            Engine::MicroFlow => QosProfile::Interactive,
            Engine::Interp | Engine::Pjrt => QosProfile::Bulk,
        }
    }

    /// Stable lowercase name (metrics labels, CLI output).
    pub fn name(self) -> &'static str {
        match self {
            QosProfile::Interactive => "interactive",
            QosProfile::Bulk => "bulk",
            QosProfile::Any => "any",
        }
    }
}

impl std::fmt::Display for QosProfile {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Process-wide request id sequence (ids are unique per process, never 0).
static NEXT_ID: AtomicU64 = AtomicU64::new(1);

/// A typed inference request: quantized payload plus the lifecycle fields
/// dispatch, batching and shedding read. Construct with [`Request::new`];
/// the embedded cancel flag is shared with the [`Ticket`] once submitted.
pub struct Request {
    /// Quantized input, exactly `input_len` elements of the target model.
    pub payload: Vec<i8>,
    pub class: QosClass,
    /// Absolute shed deadline: a request still queued past this instant is
    /// dropped (counted, never executed) instead of wasting a batch slot.
    pub deadline: Option<Instant>,
    /// Process-unique id, embedded in error messages and the ticket.
    pub id: u64,
    /// Redispatch count: 0 on first submit, incremented each time a
    /// transient replica failure re-enqueues the request. Bounded by the
    /// server's retry budget; travels with the request so the budget
    /// survives re-enqueueing.
    pub(crate) attempt: u32,
    cancel: Arc<AtomicBool>,
}

impl Request {
    /// A Bulk request with no deadline — the legacy submit semantics.
    pub fn new(payload: Vec<i8>) -> Request {
        Request {
            payload,
            class: QosClass::default(),
            deadline: None,
            id: NEXT_ID.fetch_add(1, Ordering::Relaxed),
            attempt: 0,
            cancel: Arc::new(AtomicBool::new(false)),
        }
    }

    /// Times this request has been redispatched after a transient
    /// replica failure (0 = first attempt still pending).
    pub fn attempts(&self) -> u32 {
        self.attempt
    }

    /// An Interactive request (convenience for the common case).
    pub fn interactive(payload: Vec<i8>) -> Request {
        Request::new(payload).with_class(QosClass::Interactive)
    }

    pub fn with_class(mut self, class: QosClass) -> Request {
        self.class = class;
        self
    }

    pub fn with_deadline(mut self, deadline: Instant) -> Request {
        self.deadline = Some(deadline);
        self
    }

    /// Deadline `after` from now.
    pub fn with_deadline_in(self, after: Duration) -> Request {
        self.with_deadline(Instant::now() + after)
    }

    /// Cooperatively cancel. Effective while the request is still queued
    /// (before or after submit): the batcher drops it unexecuted. A
    /// request already executing completes and the result is discarded.
    pub fn cancel(&self) {
        self.cancel.store(true, Ordering::Relaxed);
    }

    pub fn is_cancelled(&self) -> bool {
        self.cancel.load(Ordering::Relaxed)
    }

    /// Split into the queue entry and the caller's response handle
    /// (called by the submit paths; one reply channel per request).
    pub(crate) fn into_pending(self) -> (Pending, Ticket) {
        let (reply_tx, reply_rx) = channel();
        let ticket = Ticket {
            id: self.id,
            class: self.class,
            rx: reply_rx,
            cancel: Arc::clone(&self.cancel),
        };
        (Pending { request: self, enqueued: Instant::now(), reply: reply_tx }, ticket)
    }
}

impl std::fmt::Debug for Request {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Request")
            .field("id", &self.id)
            .field("class", &self.class)
            .field("deadline", &self.deadline)
            .field("payload_len", &self.payload.len())
            .field("attempt", &self.attempt)
            .field("cancelled", &self.is_cancelled())
            .finish()
    }
}

/// One queued request: the [`Request`] plus its reply channel and enqueue
/// timestamp. Lives on the server's bounded channel; the batcher claims
/// it, sheds it (deadline expired), or drops it (cancelled).
#[derive(Debug)]
pub struct Pending {
    pub request: Request,
    pub enqueued: Instant,
    pub reply: Sender<Result<Vec<i8>>>,
}

impl Pending {
    pub fn is_cancelled(&self) -> bool {
        self.request.is_cancelled()
    }

    pub fn deadline_expired(&self, now: Instant) -> bool {
        self.request.deadline.is_some_and(|d| now >= d)
    }

    /// Recover the request (dropping the reply channel) — the
    /// `try_submit` full-queue path hands it back to the caller.
    pub fn into_request(self) -> Request {
        self.request
    }
}

/// One slot on a server's bounded queue: a request entry, or the **retire
/// sentinel** the elastic server uses to shrink its worker set.
///
/// Retirement protocol (the drain-graceful invariant): exactly one worker
/// claims a given `Retire` entry off the shared channel — inside its batch
/// assembly, under the receiver lock. That worker finishes the batch it
/// was assembling (accepted requests are **never** dropped by a
/// scale-down), executes it, and only then exits. Requests queued behind
/// the sentinel stay on the channel for the surviving workers.
pub enum QueueEntry {
    /// A queued request awaiting batching.
    Req(Pending),
    /// Poisoned sentinel: the claiming worker drains and exits.
    Retire,
}

/// The response handle for one submitted request — replaces the raw mpsc
/// `Receiver<Result<Vec<i8>>>` everywhere in the coordinator.
pub struct Ticket {
    id: u64,
    class: QosClass,
    rx: Receiver<Result<Vec<i8>>>,
    cancel: Arc<AtomicBool>,
}

impl Ticket {
    pub fn id(&self) -> u64 {
        self.id
    }

    pub fn class(&self) -> QosClass {
        self.class
    }

    /// Block until the result arrives (or the request is shed, cancelled
    /// or fails).
    pub fn wait(self) -> Result<Vec<i8>> {
        match self.rx.recv() {
            Ok(r) => r,
            Err(_) => Err(self.dropped_error()),
        }
    }

    /// Non-blocking poll: `Ok(None)` while the request is still in
    /// flight; at most one `Ok(Some(..))` is ever yielded.
    pub fn try_wait(&mut self) -> Result<Option<Vec<i8>>> {
        match self.rx.try_recv() {
            Ok(r) => r.map(Some),
            Err(TryRecvError::Empty) => Ok(None),
            Err(TryRecvError::Disconnected) => Err(self.dropped_error()),
        }
    }

    /// Block until the result arrives or `deadline` passes; `Ok(None)`
    /// means the deadline passed with the request still in flight (the
    /// ticket stays usable — callers may `cancel` or keep waiting).
    pub fn wait_deadline(&mut self, deadline: Instant) -> Result<Option<Vec<i8>>> {
        let timeout = deadline.saturating_duration_since(Instant::now());
        match self.rx.recv_timeout(timeout) {
            Ok(r) => r.map(Some),
            Err(RecvTimeoutError::Timeout) => Ok(None),
            Err(RecvTimeoutError::Disconnected) => Err(self.dropped_error()),
        }
    }

    /// Cooperatively cancel (see [`Request::cancel`]): a still-queued
    /// request is dropped unexecuted and this ticket resolves to a
    /// "cancelled" error.
    pub fn cancel(&self) {
        self.cancel.store(true, Ordering::Relaxed);
    }

    /// The reply sender was dropped without an answer: either the request
    /// was cancelled (batcher dropped it) or a worker died.
    fn dropped_error(&self) -> anyhow::Error {
        if self.cancel.load(Ordering::Relaxed) {
            anyhow!("request {} cancelled before execution", self.id)
        } else {
            anyhow!("request {}: worker dropped reply", self.id)
        }
    }
}

/// Typed replica execution failure — what every ticket in a failed batch
/// receives (replacing the old opaque `"batch execution failed: .."`
/// string). Carries the replica identity and the request id so a caller
/// holding thousands of tickets can attribute a failure without any
/// side-channel, plus the [`FailureKind`] the retry/ejection machinery
/// classified the error as.
#[derive(Debug, Clone)]
pub struct ReplicaError {
    /// Label of the replica whose batch failed (e.g. `native/3`).
    pub replica_label: String,
    /// Id of the request this error resolves.
    pub request_id: u64,
    /// Transient (retryable, replica stays unless health trips) or Fatal
    /// (the worker exited; the pool heals by warm re-provisioning).
    pub kind: FailureKind,
    /// The underlying engine error, flattened.
    pub detail: String,
}

impl std::fmt::Display for ReplicaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "request {} failed on replica {} ({}): {}",
            self.request_id, self.replica_label, self.kind, self.detail
        )
    }
}

impl std::error::Error for ReplicaError {}

/// Explicit backpressure and validation errors from `try_submit`. The
/// rejected request is handed back whenever it still exists, so callers
/// can retry, spill elsewhere, or shed it — never silently lose payloads.
/// The two exceptions carry no request: `BreakerOpen` *resolves* the
/// request (it is counted as shed — resubmitting would double-count) and
/// `Internal` guards a state the submit path cannot reach.
#[derive(Debug)]
pub enum SubmitError {
    /// The target queue(s) are full.
    QueueFull(Request),
    /// The server was shut down; the request never entered a queue.
    Shutdown(Request),
    /// Payload length does not match the model's input length.
    InputLength { expected: usize, got: usize },
    /// Brownout: every candidate pool's circuit breaker sheds this class
    /// at admission. The request is already counted `submitted` + `shed`
    /// on the shedding pool — it is resolved, not handed back.
    BreakerOpen { id: u64, class: QosClass, pool: String },
    /// Defensive arm for states the queue protocol makes unreachable
    /// (e.g. a `Retire` sentinel bounced back from `try_send`): reported
    /// as an error instead of a panic in the admission hot path.
    Internal { reason: &'static str },
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull(r) => {
                write!(f, "queue full: request {} ({}) rejected", r.id, r.class)
            }
            SubmitError::Shutdown(r) => {
                write!(f, "server is shut down: request {} ({}) rejected", r.id, r.class)
            }
            SubmitError::InputLength { expected, got } => {
                write!(f, "input length {got} != model input length {expected}")
            }
            SubmitError::BreakerOpen { id, class, pool } => {
                write!(f, "request {id} ({class}) shed at admission: circuit breaker open on pool {pool:?}")
            }
            SubmitError::Internal { reason } => {
                write!(f, "internal submit error: {reason}")
            }
        }
    }
}

impl std::error::Error for SubmitError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_are_unique_and_nonzero() {
        let a = Request::new(vec![1]);
        let b = Request::new(vec![2]);
        assert_ne!(a.id, 0);
        assert_ne!(a.id, b.id);
    }

    #[test]
    fn class_wire_byte_round_trips() {
        for class in QosClass::ALL {
            assert_eq!(QosClass::from_u8(class.as_u8()).unwrap(), class);
            assert_eq!(class.name().parse::<QosClass>().unwrap(), class);
        }
        assert!(QosClass::from_u8(7).is_err());
        assert!("warp".parse::<QosClass>().is_err());
    }

    #[test]
    fn profile_preference_matrix() {
        use QosClass::*;
        assert!(QosProfile::Interactive.prefers(Interactive));
        assert!(!QosProfile::Interactive.prefers(Bulk));
        assert!(QosProfile::Bulk.prefers(Bulk));
        assert!(QosProfile::Bulk.prefers(Background));
        assert!(!QosProfile::Bulk.prefers(Interactive));
        for c in QosClass::ALL {
            assert!(!QosProfile::Any.prefers(c), "Any must be fallback-only ({c})");
        }
        assert_eq!(QosProfile::for_engine(Engine::MicroFlow), QosProfile::Interactive);
        assert_eq!(QosProfile::for_engine(Engine::Interp), QosProfile::Bulk);
    }

    #[test]
    fn ticket_waits_and_polls() {
        let (pending, mut ticket) = Request::interactive(vec![1, 2]).into_pending();
        assert_eq!(ticket.class(), QosClass::Interactive);
        assert_eq!(ticket.id(), pending.request.id);
        assert!(ticket.try_wait().unwrap().is_none(), "nothing sent yet");
        let soon = Instant::now() + Duration::from_millis(1);
        assert!(ticket.wait_deadline(soon).unwrap().is_none(), "deadline passes unanswered");
        pending.reply.send(Ok(vec![7])).unwrap();
        assert_eq!(ticket.try_wait().unwrap(), Some(vec![7]));
    }

    #[test]
    fn cancelled_ticket_resolves_to_cancelled_error() {
        let req = Request::new(vec![0]);
        let (pending, ticket) = req.into_pending();
        ticket.cancel();
        assert!(pending.is_cancelled(), "cancel flag is shared with the queue entry");
        drop(pending); // the batcher drops a cancelled entry without replying
        let err = ticket.wait().unwrap_err().to_string();
        assert!(err.contains("cancelled"), "{err}");
    }

    #[test]
    fn cancel_before_submit_marks_the_queue_entry() {
        let req = Request::new(vec![0]);
        req.cancel();
        let (pending, _ticket) = req.into_pending();
        assert!(pending.is_cancelled());
    }

    #[test]
    fn deadline_expiry_is_inclusive() {
        let now = Instant::now();
        let (pending, _t) = Request::new(vec![0]).with_deadline(now).into_pending();
        assert!(pending.deadline_expired(now));
        let (fresh, _t2) =
            Request::new(vec![0]).with_deadline(now + Duration::from_secs(60)).into_pending();
        assert!(!fresh.deadline_expired(now));
    }

    #[test]
    fn submit_error_display_names_the_cause() {
        let full = SubmitError::QueueFull(Request::new(vec![0]).with_class(QosClass::Bulk));
        assert!(full.to_string().contains("queue full"), "{full}");
        let len = SubmitError::InputLength { expected: 4, got: 2 };
        assert!(len.to_string().contains('4'), "{len}");
        let down = SubmitError::Shutdown(Request::new(vec![0]));
        assert!(down.to_string().contains("shut down"), "{down}");
        let open = SubmitError::BreakerOpen { id: 9, class: QosClass::Background, pool: "p".into() };
        assert!(open.to_string().contains("shed"), "{open}");
        assert!(open.to_string().contains("breaker"), "{open}");
        let internal = SubmitError::Internal { reason: "retire sentinel bounced" };
        assert!(internal.to_string().contains("internal"), "{internal}");
    }

    #[test]
    fn replica_error_names_replica_request_and_kind() {
        let e = ReplicaError {
            replica_label: "native/3".into(),
            request_id: 42,
            kind: FailureKind::Transient,
            detail: "injected transient fault at call 5".into(),
        };
        let s = e.to_string();
        assert!(s.contains("native/3"), "{s}");
        assert!(s.contains("42"), "{s}");
        assert!(s.contains("transient"), "{s}");
        // downcastable through anyhow — the worker/ticket contract
        let any: anyhow::Error = e.into();
        assert_eq!(any.downcast_ref::<ReplicaError>().unwrap().request_id, 42);
    }

    #[test]
    fn wait_deadline_returns_error_when_worker_drops_reply_mid_batch() {
        // replica-death satellite: the owning worker exits without
        // answering — the ticket must resolve, not hang
        let (pending, mut ticket) = Request::new(vec![0]).into_pending();
        drop(pending); // sender gone, no reply ever sent, not cancelled
        let far = Instant::now() + Duration::from_secs(60);
        let err = ticket.wait_deadline(far).unwrap_err().to_string();
        assert!(err.contains("worker dropped reply"), "{err}");
    }

    #[test]
    fn retry_attempt_counter_travels_with_the_request() {
        let mut req = Request::new(vec![0]);
        assert_eq!(req.attempts(), 0);
        req.attempt += 1;
        let (pending, _t) = req.into_pending();
        assert_eq!(pending.request.attempts(), 1, "budget must survive re-enqueueing");
    }
}
