//! Hand-rolled CLI (clap is unavailable offline — DESIGN.md §7).
//!
//! Subcommands of the `microflow` binary:
//!
//! * `models`            — Table-3 style inventory from the artifacts;
//! * `predict <model>`   — run one inference on a dataset sample;
//! * `verify <model>`    — golden-vector cross-check of all engines;
//! * `deploy <model> <mcu>` — simulate a deployment: memory fit, timing,
//!   energy on one Table-4 device;
//! * `audit <model>`     — statically certify a compiled plan (shape,
//!   memory and overflow soundness; `compiler::verify`), print the
//!   certificate report;
//! * `serve <model>`     — spin up the coordinator under synthetic load,
//!   as a homogeneous replica set (`--replicas`) or a heterogeneous
//!   fleet (`--engine-mix microflow:2,tflm:1`); `--stream` serves pulsed
//!   streaming sessions over the v3 `MFR3` frame-per-chunk protocol;
//!   `--metrics-addr` attaches the exposition tier (Prometheus text over
//!   HTTP and the `STAT` wire op);
//! * `top <addr>`        — scrape a serving deployment's exposition
//!   snapshot and render it as per-pool lane/span/profile tables.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use crate::api::Engine;

/// Parsed command line: positional args + `--key value` / `--flag` options.
#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: HashMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    pub fn parse(raw: impl IntoIterator<Item = String>) -> Args {
        let mut args = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                // `--key=value`, `--key value`, or boolean `--flag`
                if let Some((k, v)) = key.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    args.options.insert(key.to_string(), it.next().unwrap());
                } else {
                    args.flags.push(key.to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> usize {
        self.opt(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn opt_f64(&self, name: &str, default: f64) -> f64 {
        self.opt(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

/// Parse a `--engine-mix` value: comma-separated `engine:replicas` pool
/// specs, e.g. `microflow:2,tflm:1` or `pjrt:1,microflow:4`. An omitted
/// count means one replica.
pub fn parse_engine_mix(s: &str) -> Result<Vec<(Engine, usize)>> {
    let mut mix = Vec::new();
    for part in s.split(',') {
        let part = part.trim();
        if part.is_empty() {
            bail!("empty pool spec in --engine-mix {s:?}");
        }
        let (engine, count) = match part.split_once(':') {
            Some((e, c)) => {
                let count: usize = c
                    .parse()
                    .with_context(|| format!("bad replica count {c:?} in --engine-mix {s:?}"))?;
                (e, count)
            }
            None => (part, 1),
        };
        if count == 0 {
            bail!("pool {engine:?} has 0 replicas in --engine-mix {s:?}");
        }
        mix.push((engine.parse::<Engine>()?, count));
    }
    Ok(mix)
}

/// Parse an `--autoscale` value: `min:max` replica bounds per pool, e.g.
/// `1:4`. A bare number pins both bounds (`3` == `3:3`).
pub fn parse_autoscale(s: &str) -> Result<(usize, usize)> {
    let (min, max) = match s.split_once(':') {
        Some((lo, hi)) => (
            lo.parse::<usize>().with_context(|| format!("bad min {lo:?} in --autoscale {s:?}"))?,
            hi.parse::<usize>().with_context(|| format!("bad max {hi:?} in --autoscale {s:?}"))?,
        ),
        None => {
            let n = s.parse::<usize>().with_context(|| format!("bad --autoscale {s:?}"))?;
            (n, n)
        }
    };
    if min == 0 {
        bail!("--autoscale min must be at least 1 (a pool always keeps one live replica)");
    }
    if max < min {
        bail!("--autoscale max {max} is below min {min}");
    }
    Ok((min, max))
}

/// Parse a `--chaos` value: `seed[:period]` for the seeded fault
/// injector, e.g. `7` (every 10th call on the wrapped replica fails
/// transiently, phase-shifted by seed 7) or `7:25` (every 25th).
pub fn parse_chaos(s: &str) -> Result<(u64, u64)> {
    let (seed, period) = match s.split_once(':') {
        Some((seed, period)) => (
            seed.parse::<u64>().with_context(|| format!("bad seed {seed:?} in --chaos {s:?}"))?,
            period
                .parse::<u64>()
                .with_context(|| format!("bad period {period:?} in --chaos {s:?}"))?,
        ),
        None => (s.parse::<u64>().with_context(|| format!("bad --chaos {s:?}"))?, 10),
    };
    if period == 0 {
        bail!("--chaos period must be at least 1 (every call failing wedges the replica)");
    }
    Ok((seed, period))
}

pub const USAGE: &str = "\
microflow — MicroFlow (Carnelos et al., 2024) reproduction CLI

All inference runs through the session API (microflow::api): pick an
engine, build a session, run. Engines: microflow | tflm | pjrt.

USAGE:
  microflow models                         list model inventory (Table 3)
  microflow predict <model> [--index N] [--engine E] [--paging]
                                           run one inference on a test sample
  microflow verify  <model>                golden cross-check of all engines
  microflow deploy  <model> <mcu> [--paging] [--engine microflow|tflm]
                                           simulate a Table-4 deployment
  microflow audit   <model|path.mfb> [--paging]
                                           statically certify the compiled plan
                                           and print the certificate report
                                           (peak RAM, per-step live bytes,
                                           worst-case accumulator headroom)
  microflow audit   --synth-zoo [--seed N] certify every synthetic-zoo model,
                                           paged and unpaged (CI gate)
  microflow audit   --codes                print the stable error-code table
                                           (V1xx plan / V2xx memory / V3xx
                                           arithmetic / V4xx pulse streaming /
                                           E4xx decode)
  microflow audit   <model|path.mfb> --profile [--paging] [--runs N]
                                           run N profiled inferences (default
                                           100) and print the per-step kernel
                                           profile (invocations, total ns,
                                           ns/call per plan step)
  microflow serve   <model> [--requests N] [--rate RPS] [--backend E]
                    [--replicas R] [--engine-mix MIX] [--batch B]
                    [--no-adaptive] [--paging] [--default-class C]
                    [--shed-after-ms MS] [--autoscale MIN:MAX]
                    [--slo-p95-ms MS] [--tick-ms MS] [--retries N]
                    [--no-breaker] [--chaos SEED[:P]]
                    [--metrics-addr ADDR] [--profile]
                                           serve synthetic load, print metrics
  microflow serve   <model|synth> --stream [--streams N] [--frames N]
                    [--stream-replicas R] [--seed N] [--chaos SEED[:P]]
                                           pulsed streaming over the v3 MFR3
                                           wire protocol (frame-per-chunk)

serve options (request lifecycle):
  Every request is typed: a QoS class (interactive | bulk | background), an
  optional shed deadline, and a unique id. Dispatch routes each request to
  the pool whose QoS profile prefers its class (native pools prefer
  interactive, tflm/pjrt pools prefer bulk+background), balancing by least
  outstanding requests within the match set. The batcher never mixes
  classes in one batch: interactive batches cut at the latency posture,
  bulk fills the batch target. Requests still queued past their deadline
  are shed (counted, never executed); cancelled tickets never execute.
  Backpressure is explicit: submit blocks on a full queue, try_submit
  hands the request back as QueueFull.

  --default-class C class of the synthetic requests: interactive | bulk |
                    background | mix (default mix: a deterministic blend,
                    exercising class-aware dispatch and per-class metrics)
  --shed-after-ms MS  give every request a deadline MS milliseconds after
                    submit; requests still queued past it are shed
  --replicas R      session replicas of --backend (one worker each; default 2)
  --engine-mix MIX  heterogeneous fleet instead of --backend/--replicas:
                    comma-separated engine:replicas pools, each pool with its
                    own queue, batcher, metrics and engine-derived QoS
                    profile — e.g. --engine-mix microflow:2,tflm:1
                    (pjrt pools need a `--features pjrt` build)
  --batch B         dynamic batcher target batch size (default 8)
  --no-adaptive     disable per-replica batcher tuning from observed queue depth
  --autoscale MIN:MAX  make every pool elastic: an SLO-driven controller
                    grows a pool (through the warm session cache — native
                    scale-up costs no recompile) when a tick window shows
                    shed or deadline-missed requests, or an interactive
                    windowed p95 over --slo-p95-ms; it retires one replica
                    after a sustained idle window via graceful drain
                    (in-flight and queued requests always finish). Bounds
                    are per pool; every decision is printed and shown in
                    the final snapshot.
  --slo-p95-ms MS   interactive p95 target per tick window (only with
                    --autoscale; without it, scaling reacts to shed/missed
                    counts alone)
  --tick-ms MS      autoscaler control-loop cadence (default 100)
  --retries N       transient-failure retry budget per request (default 1):
                    a failed request is re-dispatched to a sibling replica
                    unless its budget is spent, its deadline has passed or
                    it was cancelled; exhausted budgets resolve as failed
                    with a typed per-replica error
  --no-breaker      disable the per-pool circuit breaker (on by default:
                    a pool whose tick window shows >=50% failures opens —
                    bulk/background requests are shed at admission while
                    interactive traffic keeps flowing and doubles as the
                    probe that re-closes the breaker)
  --chaos SEED[:P]  wrap one replica per pool in the seeded fault injector:
                    every P-th call (default 10) on that replica fails
                    transiently, phase-shifted by SEED — deterministic
                    chaos exercising retry, health ejection and the
                    breaker without real hardware faults
  --metrics-addr ADDR  attach the observability exposition tier: serve a
                    Prometheus-text snapshot at http://ADDR (e.g.
                    127.0.0.1:9100; port 0 picks a free port) assembled
                    only from tick-drained windows, spans and profiles —
                    the same snapshot the STAT wire op and `microflow
                    top` read. Exported lane counters hold the identity
                    completed + shed + cancelled + failed == submitted.
  --profile         attach the per-step kernel profiler to every worker:
                    per-layer invocation counts and nanoseconds surface
                    as microflow_step_* metrics (native-engine pools)
  Replica sessions build through the warm session cache: repeated builds of
  the same model reuse one compiled plan (reported at startup). Metrics are
  reported per pool and per class (p50/p95/p99, shed/cancelled/late);
  long-running status lines use windowed rates, not lifetime counters.

serve --stream options (pulsed streaming):
  The model's pulse pass is planned and certified (V401-V405), a StreamHost
  pins each stream to one replica, and N client streams push frames over
  the v3 MFR3 protocol — one chunk per round, verdicts at the pulse
  cadence. Every stream's lifecycle identity (completed + shed + cancelled
  + failed == submitted) is checked at close. <model> may be `synth` for a
  seeded synthetic streaming model (no artifacts needed).
  --streams N           concurrent client streams (default 4)
  --frames N            frames pushed per stream (default 64)
  --stream-replicas R   pinned stream replicas (default 2)
  --seed N              synthetic model / frame-noise seed
  --chaos SEED[:P]      stream replica 0 fails every P-th push: exercises
                        quarantine, ejection and ring-replay migration

  microflow top <addr> [--wire]            scrape one exposition snapshot from
                                           a serving deployment and render it
                                           as per-pool request-lane, span and
                                           kernel-profile tables. <addr> is the
                                           --metrics-addr HTTP endpoint; with
                                           --wire it is the ingress address and
                                           the snapshot travels over the STAT
                                           wire op instead
  microflow help                           this text

Models: sine | speech | person (built by `make artifacts`)
MCUs:   ESP32 | ATSAMV71 | nRF52840 | LM3S6965 | ATmega328
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn positional_and_options() {
        let a = parse("deploy sine ESP32 --engine tflm --paging");
        assert_eq!(a.positional, vec!["deploy", "sine", "ESP32"]);
        assert_eq!(a.opt("engine"), Some("tflm"));
        assert!(a.flag("paging"));
    }

    #[test]
    fn equals_form() {
        let a = parse("serve speech --rate=100 --requests 500");
        assert_eq!(a.opt_f64("rate", 0.0), 100.0);
        assert_eq!(a.opt_usize("requests", 0), 500);
    }

    #[test]
    fn defaults_when_missing() {
        let a = parse("models");
        assert_eq!(a.opt_usize("index", 7), 7);
        assert!(!a.flag("paging"));
    }

    #[test]
    fn engine_mix_parses_pools() {
        let mix = parse_engine_mix("microflow:2,tflm:1").unwrap();
        assert_eq!(mix, vec![(Engine::MicroFlow, 2), (Engine::Interp, 1)]);
        // bare engine = one replica; whitespace tolerated
        let mix = parse_engine_mix("pjrt, native:3").unwrap();
        assert_eq!(mix, vec![(Engine::Pjrt, 1), (Engine::MicroFlow, 3)]);
    }

    #[test]
    fn engine_mix_rejects_malformed_specs() {
        assert!(parse_engine_mix("").is_err());
        assert!(parse_engine_mix("microflow:x").is_err());
        assert!(parse_engine_mix("microflow:0").is_err());
        assert!(parse_engine_mix("warp-drive:1").is_err());
        assert!(parse_engine_mix("microflow:1,,tflm:1").is_err());
    }

    #[test]
    fn autoscale_parses_bounds() {
        assert_eq!(parse_autoscale("1:4").unwrap(), (1, 4));
        assert_eq!(parse_autoscale("2:2").unwrap(), (2, 2));
        // a bare number pins both bounds
        assert_eq!(parse_autoscale("3").unwrap(), (3, 3));
    }

    #[test]
    fn chaos_parses_seed_and_period() {
        assert_eq!(parse_chaos("7").unwrap(), (7, 10));
        assert_eq!(parse_chaos("7:25").unwrap(), (7, 25));
        assert_eq!(parse_chaos("0:1").unwrap(), (0, 1));
    }

    #[test]
    fn chaos_rejects_malformed_specs() {
        assert!(parse_chaos("").is_err());
        assert!(parse_chaos("x").is_err());
        assert!(parse_chaos("7:").is_err());
        assert!(parse_chaos("7:0").is_err(), "period 0 would wedge the replica");
    }

    #[test]
    fn autoscale_rejects_malformed_bounds() {
        assert!(parse_autoscale("").is_err());
        assert!(parse_autoscale("0:4").is_err(), "min 0 would retire the last replica");
        assert!(parse_autoscale("4:1").is_err(), "max below min");
        assert!(parse_autoscale("a:b").is_err());
        assert!(parse_autoscale("1:2:3").is_err());
    }
}
