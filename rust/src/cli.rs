//! Hand-rolled CLI (clap is unavailable offline — DESIGN.md §7).
//!
//! Subcommands of the `microflow` binary:
//!
//! * `models`            — Table-3 style inventory from the artifacts;
//! * `predict <model>`   — run one inference on a dataset sample;
//! * `verify <model>`    — golden-vector cross-check of all engines;
//! * `deploy <model> <mcu>` — simulate a deployment: memory fit, timing,
//!   energy on one Table-4 device;
//! * `serve <model>`     — spin up the coordinator under synthetic load.

use std::collections::HashMap;

/// Parsed command line: positional args + `--key value` / `--flag` options.
#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: HashMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    pub fn parse(raw: impl IntoIterator<Item = String>) -> Args {
        let mut args = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                // `--key=value`, `--key value`, or boolean `--flag`
                if let Some((k, v)) = key.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    args.options.insert(key.to_string(), it.next().unwrap());
                } else {
                    args.flags.push(key.to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> usize {
        self.opt(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn opt_f64(&self, name: &str, default: f64) -> f64 {
        self.opt(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

pub const USAGE: &str = "\
microflow — MicroFlow (Carnelos et al., 2024) reproduction CLI

All inference runs through the session API (microflow::api): pick an
engine, build a session, run. Engines: microflow | tflm | pjrt.

USAGE:
  microflow models                         list model inventory (Table 3)
  microflow predict <model> [--index N] [--engine E] [--paging]
                                           run one inference on a test sample
  microflow verify  <model>                golden cross-check of all engines
  microflow deploy  <model> <mcu> [--paging] [--engine microflow|tflm]
                                           simulate a Table-4 deployment
  microflow serve   <model> [--requests N] [--rate RPS] [--backend E]
                    [--replicas R] [--batch B] [--paging]
                                           serve synthetic load, print metrics
  microflow help                           this text

Models: sine | speech | person (built by `make artifacts`)
MCUs:   ESP32 | ATSAMV71 | nRF52840 | LM3S6965 | ATmega328
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn positional_and_options() {
        let a = parse("deploy sine ESP32 --engine tflm --paging");
        assert_eq!(a.positional, vec!["deploy", "sine", "ESP32"]);
        assert_eq!(a.opt("engine"), Some("tflm"));
        assert!(a.flag("paging"));
    }

    #[test]
    fn equals_form() {
        let a = parse("serve speech --rate=100 --requests 500");
        assert_eq!(a.opt_f64("rate", 0.0), 100.0);
        assert_eq!(a.opt_usize("requests", 0), 500);
    }

    #[test]
    fn defaults_when_missing() {
        let a = parse("models");
        assert_eq!(a.opt_usize("index", 7), 7);
        assert!(!a.flag("paging"));
    }
}
