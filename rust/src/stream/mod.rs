//! `microflow::stream` — stateful pulsed inference over a sliding window
//! (the runtime half of the streaming subsystem; the planning half is
//! [`crate::compiler::pulse`]).
//!
//! A [`StreamSession`] consumes the input one frame (one `H` row of the
//! model's `[H,W,C]` input) at a time and emits a verdict whenever a full
//! window's worth of context is available at the pulse cadence:
//!
//! ```text
//! push(frame) -> None        while the window warms up / between pulses
//! push(frame) -> Some(out)   at seen == window, then every pulse_frames
//! ```
//!
//! Guarantees (the streaming contract, asserted by
//! `tests/stream_conformance.rs`):
//!
//! * **State ownership** — all cross-frame state (the input ring, the
//!   per-layer pulse states, the carry) lives inside the session; the
//!   model plan stays immutable and shared (`Arc<CompiledModel>`).
//! * **Bit-exactness vs replay** — every verdict of the pulsed native
//!   path equals, bit for bit, a full-window re-run of the same engine
//!   over the ring contents at that frame. A replay-mode session over
//!   any [`Session`] (including the interpreter) is the oracle.
//! * **Migration** — a session's future verdicts are a pure function of
//!   the frames in the ring: re-feeding the last window (plus any
//!   mid-pulse pending frames) into a fresh session reproduces the state,
//!   which is how the coordinator migrates streams off ejected replicas.
//!
//! Verdicts allocate (`Vec<i8>` per emission); the per-frame *compute*
//! path reuses the session's plan-sized buffers, and pays only the
//! incremental sub-kernels plus the (cheap) non-streamable tail.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::api::{ModelSource, Session};
use crate::compiler::plan::{CompileOptions, CompiledModel, StepKind};
use crate::compiler::pulse::{PulsePlan, PulseStepKind};
use crate::engine::{run_plan_from, Scratch};
use crate::kernels::microkernel::backend;
use crate::kernels::view::ConvGeometry;
use crate::kernels::{activation, average_pool2d, conv2d, depthwise_conv2d};

/// Fixed-capacity frame ring: the durable truth of a stream's recent
/// input. Pushing never allocates; reads materialize logical
/// (oldest-first) order from the modular layout.
#[derive(Clone, Debug)]
pub struct RingBuffer {
    buf: Vec<i8>,
    frame_len: usize,
    cap_frames: usize,
    /// Next write slot (frame index).
    head: usize,
    /// Frames currently held (`<= cap_frames`).
    filled: usize,
    /// Total frames ever pushed.
    seen: u64,
}

impl RingBuffer {
    pub fn new(cap_frames: usize, frame_len: usize) -> RingBuffer {
        assert!(cap_frames > 0 && frame_len > 0, "degenerate ring");
        RingBuffer {
            buf: vec![0; cap_frames * frame_len],
            frame_len,
            cap_frames,
            head: 0,
            filled: 0,
            seen: 0,
        }
    }

    pub fn push(&mut self, frame: &[i8]) {
        assert_eq!(frame.len(), self.frame_len, "frame length");
        let at = self.head * self.frame_len;
        self.buf[at..at + self.frame_len].copy_from_slice(frame);
        self.head = (self.head + 1) % self.cap_frames;
        self.filled = (self.filled + 1).min(self.cap_frames);
        self.seen += 1;
    }

    pub fn seen(&self) -> u64 {
        self.seen
    }

    pub fn filled(&self) -> usize {
        self.filled
    }

    pub fn frame_len(&self) -> usize {
        self.frame_len
    }

    pub fn cap_frames(&self) -> usize {
        self.cap_frames
    }

    /// Copy the newest `frames` frames into `out`, oldest of the selection
    /// first. Allocation-free; panics if more frames are asked for than
    /// held or `out` is missized.
    pub fn copy_last_into(&self, frames: usize, out: &mut [i8]) {
        assert!(frames <= self.filled, "ring holds {} < {frames} frames", self.filled);
        assert_eq!(out.len(), frames * self.frame_len, "output length");
        // physical slot of the oldest held frame
        let base = (self.head + self.cap_frames - self.filled) % self.cap_frames;
        let skip = self.filled - frames;
        for j in 0..frames {
            let slot = (base + skip + j) % self.cap_frames;
            let src = slot * self.frame_len;
            out[j * self.frame_len..(j + 1) * self.frame_len]
                .copy_from_slice(&self.buf[src..src + self.frame_len]);
        }
    }

    /// Newest `frames` frames, oldest-first (allocating convenience).
    pub fn last_frames(&self, frames: usize) -> Vec<i8> {
        let mut out = vec![0; frames * self.frame_len];
        self.copy_last_into(frames, &mut out);
        out
    }
}

/// Geometry of the spatial step kinds (executor-side mirror of the
/// planner's classification).
fn geo_of(kind: &StepKind) -> Option<ConvGeometry> {
    match kind {
        StepKind::Conv2D { geo, .. }
        | StepKind::DepthwiseConv2D { geo, .. }
        | StepKind::AveragePool2D { geo, .. } => Some(*geo),
        _ => None,
    }
}

/// Slide a row-major state buffer up by the delta's rows and append the
/// delta at the tail. When the delta alone exceeds the buffer, only its
/// newest rows are kept (a stride skipping more rows than the kernel
/// reads).
fn shift_append(buf: &mut [i8], row: usize, delta: &[i8]) {
    debug_assert_eq!(delta.len() % row, 0);
    debug_assert_eq!(buf.len() % row, 0);
    let cap = buf.len() / row;
    let d = delta.len() / row;
    if d >= cap {
        buf.copy_from_slice(&delta[(d - cap) * row..]);
    } else {
        buf.copy_within(d * row.., 0);
        buf[(cap - d) * row..].copy_from_slice(delta);
    }
}

/// The pulsed native executor: per-layer states + carry + delta buffers,
/// all sized once from the certified [`PulsePlan`].
struct PulseState {
    compiled: Arc<CompiledModel>,
    plan: PulsePlan,
    /// One state buffer per prefix step (`state_rows * in_row` elements;
    /// empty for pointwise steps) — the planned, disjoint state regions
    /// the `V403` obligation signs off on.
    states: Vec<Vec<i8>>,
    /// Full output of the last prefix step, shifted by `carry_delta` rows
    /// per pulse and re-fed to the tail.
    carry: Vec<i8>,
    /// Delta ping-pong (sized for the widest delta slice in the prefix).
    da: Vec<i8>,
    db: Vec<i8>,
    /// Kernel view staging for the incremental sub-runs.
    view: Vec<i8>,
    /// Tail-range execution buffers (parity-safe sizing).
    scratch: Scratch,
}

impl PulseState {
    fn new(compiled: Arc<CompiledModel>, plan: PulsePlan) -> PulseState {
        let states: Vec<Vec<i8>> =
            plan.prefix.iter().map(|ps| vec![0; ps.state_rows * ps.in_row]).collect();
        let carry = vec![0; plan.carry_rows * plan.carry_row];
        let delta_max = plan
            .prefix
            .iter()
            .flat_map(|ps| [ps.delta_in * ps.in_row, ps.delta_out * ps.out_row])
            .max()
            .unwrap_or(1);
        let view_max = plan
            .prefix
            .iter()
            .filter_map(|ps| geo_of(&compiled.steps[ps.step].kind))
            .map(|g| g.view_bytes())
            .max()
            .unwrap_or(0);
        let scratch = Scratch::for_plan_any_start(&compiled);
        PulseState {
            plan,
            states,
            carry,
            da: vec![0; delta_max],
            db: vec![0; delta_max],
            view: vec![0; view_max],
            scratch,
            compiled,
        }
    }

    /// Full-window run that fills every state buffer and the carry as a
    /// side effect (the first verdict, and the migration re-prime).
    fn prime(&mut self, window: &[i8]) -> Vec<i8> {
        let plan = &self.plan;
        let states = &mut self.states;
        let carry = &mut self.carry;
        let tail_start = plan.tail_start;
        let mut cb = |i: usize, y: &[i8]| {
            // step i's output is step i+1's input: keep its tail rows
            if let Some(ps) = plan.prefix.get(i + 1) {
                if ps.kind == PulseStepKind::Geo {
                    let keep = ps.state_rows * ps.in_row;
                    states[i + 1].copy_from_slice(&y[y.len() - keep..]);
                }
            }
            if i + 1 == tail_start {
                carry.copy_from_slice(y);
            }
        };
        let out =
            run_plan_from(&self.compiled, 0, window, &mut self.scratch, Some(&mut cb)).to_vec();
        // the first step's input is the window itself
        let ps0 = self.plan.prefix[0];
        if ps0.kind == PulseStepKind::Geo {
            let keep = ps0.state_rows * ps0.in_row;
            self.states[0].copy_from_slice(&window[window.len() - keep..]);
        }
        out
    }

    /// One pulse: `pulse_frames` fresh input rows in, one verdict out.
    /// Pays `delta_out`-row sub-kernels over the prefix plus a full tail
    /// re-run — exactly the work the plan's `V405` obligation accounts.
    fn pulse(&mut self, new_rows: &[i8]) -> Vec<i8> {
        debug_assert_eq!(new_rows.len(), self.plan.pulse_frames * self.plan.frame_len);
        let kb = backend::active();
        self.da[..new_rows.len()].copy_from_slice(new_rows);
        let mut cur_len = new_rows.len();
        for (idx, ps) in self.plan.prefix.iter().enumerate() {
            let step = &self.compiled.steps[ps.step];
            let out_len = ps.delta_out * ps.out_row;
            match &step.kind {
                StepKind::Relu { s_x, z_x, s_y, z_y } => {
                    activation::relu(
                        &self.da[..cur_len],
                        *s_x,
                        *z_x,
                        *s_y,
                        *z_y,
                        &mut self.db[..cur_len],
                    );
                }
                StepKind::Relu6 { s_x, z_x, s_y, z_y } => {
                    activation::relu6(
                        &self.da[..cur_len],
                        *s_x,
                        *z_x,
                        *s_y,
                        *z_y,
                        &mut self.db[..cur_len],
                    );
                }
                StepKind::Conv2D { geo, filters, z_x, pc } => {
                    let st = &mut self.states[idx];
                    shift_append(st, ps.in_row, &self.da[..cur_len]);
                    let mut g = *geo;
                    g.in_h = ps.need_rows;
                    g.out_h = ps.delta_out;
                    // the sub-geometry has no H boundary by construction
                    // (pad_top == 0, rows [0, need) all real); only W
                    // padding can demand the staging view
                    let vlen = if g.has_boundary() { g.view_bytes() } else { 0 };
                    conv2d::conv2d_microflow_with(
                        kb,
                        &st[..ps.need_rows * ps.in_row],
                        filters,
                        &g,
                        *z_x,
                        pc,
                        &mut self.view[..vlen],
                        &mut self.db[..out_len],
                    );
                }
                StepKind::DepthwiseConv2D { geo, depth_multiplier, filters, z_x, pc } => {
                    let st = &mut self.states[idx];
                    shift_append(st, ps.in_row, &self.da[..cur_len]);
                    let mut g = *geo;
                    g.in_h = ps.need_rows;
                    g.out_h = ps.delta_out;
                    depthwise_conv2d::depthwise_conv2d_microflow_with(
                        kb,
                        &st[..ps.need_rows * ps.in_row],
                        filters,
                        &g,
                        *depth_multiplier,
                        *z_x,
                        pc,
                        &mut self.view[..g.view_bytes()],
                        &mut self.db[..out_len],
                    );
                }
                StepKind::AveragePool2D { geo, z_x, ratio, z_y, act_min, act_max } => {
                    let st = &mut self.states[idx];
                    shift_append(st, ps.in_row, &self.da[..cur_len]);
                    let mut g = *geo;
                    g.in_h = ps.need_rows;
                    g.out_h = ps.delta_out;
                    average_pool2d::average_pool2d_microflow(
                        &st[..ps.need_rows * ps.in_row],
                        &g,
                        *z_x,
                        *ratio,
                        *z_y,
                        *act_min,
                        *act_max,
                        &mut self.view[..g.view_bytes()],
                        &mut self.db[..out_len],
                    );
                }
                other => unreachable!("unstreamable {} survived verification", other.name()),
            }
            std::mem::swap(&mut self.da, &mut self.db);
            cur_len = out_len;
        }
        shift_append(&mut self.carry, self.plan.carry_row, &self.da[..cur_len]);
        if self.plan.tail_start == self.compiled.steps.len() {
            return self.carry.clone();
        }
        run_plan_from(
            &self.compiled,
            self.plan.tail_start,
            &self.carry,
            &mut self.scratch,
            None,
        )
        .to_vec()
    }
}

/// Execution mode of a [`StreamSession`].
enum StreamBackend {
    /// Incremental native path driven by a certified [`PulsePlan`].
    Pulsed(PulseState),
    /// Full-window re-run of any engine session at the same cadence — the
    /// replay oracle, and the migration/fallback path.
    Replay(Session),
}

/// A stateful streaming session: frames in, verdicts out.
pub struct StreamSession {
    ring: RingBuffer,
    window_rows: usize,
    frame_len: usize,
    pulse_frames: usize,
    out_len: usize,
    /// Frames accumulated since the last verdict (the next pulse's delta).
    pending: Vec<i8>,
    /// Window materialization buffer (prime + replay runs).
    window_buf: Vec<i8>,
    backend: StreamBackend,
}

impl StreamSession {
    /// Pulsed native session over an already-compiled plan. Plans (and
    /// certifies — `V4xx`) the pulse pass; errors if the model has no
    /// streamable prefix.
    pub fn pulsed(compiled: Arc<CompiledModel>) -> Result<StreamSession> {
        let plan = PulsePlan::plan(&compiled)?;
        let (window_rows, frame_len, pulse_frames) =
            (plan.window_rows, plan.frame_len, plan.pulse_frames);
        let out_len = compiled.output_len();
        let state = PulseState::new(compiled, plan);
        Ok(StreamSession {
            ring: RingBuffer::new(window_rows, frame_len),
            window_rows,
            frame_len,
            pulse_frames,
            out_len,
            pending: Vec::with_capacity(pulse_frames * frame_len),
            window_buf: vec![0; window_rows * frame_len],
            backend: StreamBackend::Pulsed(state),
        })
    }

    /// Compile a model source and open a pulsed session over it
    /// (certified, non-paged).
    pub fn open(source: impl Into<ModelSource>) -> Result<StreamSession> {
        let model = source.into().into_model()?;
        let compiled = CompiledModel::compile(&model, CompileOptions::default())
            .context("compiling stream model")?;
        StreamSession::pulsed(Arc::new(compiled))
    }

    /// Replay session: a full-window re-run of `session` at every verdict
    /// point — same cadence contract as the pulsed path, over any engine.
    /// This is the oracle the pulsed path is asserted bit-exact against.
    pub fn replay(session: Session, pulse_frames: usize) -> Result<StreamSession> {
        let shape = session.signature().input.shape.clone();
        let [h, w, c] = shape[..] else {
            bail!("streaming needs a rank-3 [H,W,C] input, got {shape:?}");
        };
        if pulse_frames == 0 || pulse_frames > h {
            bail!("pulse of {pulse_frames} frames outside window {h}");
        }
        let frame_len = w * c;
        let out_len = session.output_len();
        Ok(StreamSession {
            ring: RingBuffer::new(h, frame_len),
            window_rows: h,
            frame_len,
            pulse_frames,
            out_len,
            pending: Vec::new(),
            window_buf: vec![0; h * frame_len],
            backend: StreamBackend::Replay(session),
        })
    }

    /// Feed one frame; `Some(verdict)` when a full window has been seen
    /// and the pulse cadence lands on this frame, `None` otherwise
    /// (warmup, or mid-pulse).
    pub fn push(&mut self, frame: &[i8]) -> Result<Option<Vec<i8>>> {
        if frame.len() != self.frame_len {
            bail!("frame length {} != {}", frame.len(), self.frame_len);
        }
        self.ring.push(frame);
        let seen = self.ring.seen();
        let w = self.window_rows as u64;
        if seen < w {
            return Ok(None);
        }
        if seen == w {
            // window just filled: the priming verdict
            self.ring.copy_last_into(self.window_rows, &mut self.window_buf);
            let v = match &mut self.backend {
                StreamBackend::Pulsed(ps) => ps.prime(&self.window_buf),
                StreamBackend::Replay(s) => s.run(&self.window_buf)?,
            };
            self.pending.clear();
            return Ok(Some(v));
        }
        self.pending.extend_from_slice(frame);
        if (seen - w) % self.pulse_frames as u64 != 0 {
            return Ok(None);
        }
        let v = match &mut self.backend {
            StreamBackend::Pulsed(ps) => ps.pulse(&self.pending),
            StreamBackend::Replay(s) => {
                self.ring.copy_last_into(self.window_rows, &mut self.window_buf);
                s.run(&self.window_buf)?
            }
        };
        self.pending.clear();
        Ok(Some(v))
    }

    /// Total frames this session has consumed.
    pub fn frames_seen(&self) -> u64 {
        self.ring.seen()
    }

    /// Frames pushed since the last verdict (`0` right after a verdict);
    /// a migration must re-feed this many frames past the last boundary
    /// window to land the fresh session on the same cadence.
    pub fn phase(&self) -> usize {
        if self.ring.seen() < self.window_rows as u64 {
            return 0;
        }
        ((self.ring.seen() - self.window_rows as u64) % self.pulse_frames as u64) as usize
    }

    pub fn window_rows(&self) -> usize {
        self.window_rows
    }

    pub fn frame_len(&self) -> usize {
        self.frame_len
    }

    pub fn pulse_frames(&self) -> usize {
        self.pulse_frames
    }

    pub fn out_len(&self) -> usize {
        self.out_len
    }

    /// The certified pulse plan (pulsed mode only).
    pub fn plan(&self) -> Option<&PulsePlan> {
        match &self.backend {
            StreamBackend::Pulsed(ps) => Some(&ps.plan),
            StreamBackend::Replay(_) => None,
        }
    }

    /// `"pulsed"` or `"replay"` (metrics / debug label).
    pub fn mode(&self) -> &'static str {
        match &self.backend {
            StreamBackend::Pulsed(_) => "pulsed",
            StreamBackend::Replay(_) => "replay",
        }
    }
}

impl std::fmt::Debug for StreamSession {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("StreamSession")
            .field("mode", &self.mode())
            .field("window_rows", &self.window_rows)
            .field("pulse_frames", &self.pulse_frames)
            .field("frames_seen", &self.ring.seen())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::Engine;
    use crate::util::Prng;

    #[test]
    fn ring_materializes_logical_order_across_wraps() {
        let mut r = RingBuffer::new(3, 2);
        for f in 0..7i8 {
            r.push(&[f, -f]);
        }
        assert_eq!(r.seen(), 7);
        assert_eq!(r.filled(), 3);
        assert_eq!(r.last_frames(3), vec![4, -4, 5, -5, 6, -6]);
        assert_eq!(r.last_frames(2), vec![5, -5, 6, -6]);
        let mut out = vec![0; 2];
        r.copy_last_into(1, &mut out);
        assert_eq!(out, vec![6, -6]);
    }

    #[test]
    fn shift_append_keeps_the_newest_rows() {
        let mut buf = vec![1, 2, 3, 4, 5, 6]; // 3 rows of 2
        shift_append(&mut buf, 2, &[7, 8]);
        assert_eq!(buf, vec![3, 4, 5, 6, 7, 8]);
        shift_append(&mut buf, 2, &[9, 10, 11, 12]);
        assert_eq!(buf, vec![7, 8, 9, 10, 11, 12]);
        // delta wider than the buffer: keep its newest rows only
        shift_append(&mut buf, 2, &[1, 2, 3, 4, 5, 6, 7, 8]);
        assert_eq!(buf, vec![3, 4, 5, 6, 7, 8]);
    }

    #[test]
    fn warmup_yields_none_then_primes() {
        let m = crate::synth::stream_conv_chain(&mut Prng::new(3), 1);
        let mut s = StreamSession::open(&m).unwrap();
        let mut rng = Prng::new(4);
        for i in 0..s.window_rows() - 1 {
            let frame = rng.i8_vec(s.frame_len());
            assert!(s.push(&frame).unwrap().is_none(), "verdict before window filled (frame {i})");
        }
        let frame = rng.i8_vec(s.frame_len());
        let v = s.push(&frame).unwrap().expect("priming verdict");
        assert_eq!(v.len(), s.out_len());
    }

    #[test]
    fn pulsed_matches_native_replay_on_every_frame() {
        let m = crate::synth::stream_conv_chain(&mut Prng::new(5), 2);
        let mut pulsed = StreamSession::open(&m).unwrap();
        let oracle =
            Session::builder(&m).engine(Engine::MicroFlow).build().unwrap();
        let mut replay = StreamSession::replay(oracle, pulsed.pulse_frames()).unwrap();
        let mut rng = Prng::new(6);
        let mut verdicts = 0;
        for i in 0..pulsed.window_rows() * 4 {
            let frame = rng.i8_vec(pulsed.frame_len());
            let a = pulsed.push(&frame).unwrap();
            let b = replay.push(&frame).unwrap();
            assert_eq!(a, b, "frame {i}");
            if a.is_some() {
                verdicts += 1;
            }
        }
        assert!(verdicts > 1, "cadence never fired");
    }
}
