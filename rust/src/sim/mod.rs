//! MCU substrate simulator (DESIGN.md S14, §4 Substitutions).
//!
//! The paper evaluates on five physical boards (Table 4). We have none of
//! them, so this module provides the closest synthetic equivalent that
//! exercises the same code paths:
//!
//! * [`mcu`]          — the Table-4 device roster with Flash/RAM/clock,
//!   architecture class, power draw and framework availability;
//! * [`cost`]         — a first-order cycle model mapping a compiled
//!   model's MAC/op counts to cycles per inference, per engine, per MCU.
//!   **Calibrated to the paper's reported *ratios*** (sine ~10x, speech
//!   +9/+15%, person −6%, nRF52840 ≈ 3x ESP32) — see `cost`;
//! * [`memory_model`] — Flash/RAM accounting driven by the *real* outputs
//!   of the static planner (`compiler::memory`) and the arena planner
//!   (`interp::arena`) plus per-architecture code-size constants;
//! * [`energy`]       — Table-6 energy = average power × modeled time;
//! * [`report`]       — text renderers shared by the fig/table benches.
//!
//! What is real vs modeled: memory numbers derive from the actual
//! planner/arena algorithms run on the actual models (plus code-size
//! constants); time and energy are calibrated models (we cannot measure
//! silicon we do not have). Host-measured wall-clock comparisons of the
//! two engines are reported separately by `benches/kernels_micro.rs`.

pub mod cost;
pub mod energy;
pub mod mcu;
pub mod memory_model;
pub mod report;
pub mod stack_guard;

pub use cost::{inference_cycles, inference_seconds, Engine};
pub use mcu::{Mcu, MCUS};
pub use memory_model::{FitError, MemoryFootprint};
