//! Text renderers for the figure/table benches (DESIGN.md S22).
//!
//! Every bench prints (a) a human-readable table mirroring the paper's
//! figure/table layout and (b) a machine-readable CSV/JSON block so the
//! numbers can be diffed across runs. EXPERIMENTS.md records these
//! outputs next to the paper's values.

use std::fmt::Write as _;

/// A simple aligned text table.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells);
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "## {}", self.title);
        let line = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join(" | ")
        };
        let _ = writeln!(out, "{}", line(&self.headers, &widths));
        let _ = writeln!(out, "{}", widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>().join("-+-"));
        for row in &self.rows {
            let _ = writeln!(out, "{}", line(row, &widths));
        }
        out
    }

    /// Render as CSV (headers + rows).
    pub fn to_csv(&self) -> String {
        let esc = |s: &String| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.clone()
            }
        };
        let mut out = String::new();
        let _ = writeln!(out, "{}", self.headers.iter().map(esc).collect::<Vec<_>>().join(","));
        for row in &self.rows {
            let _ = writeln!(out, "{}", row.iter().map(esc).collect::<Vec<_>>().join(","));
        }
        out
    }
}

/// Write a bench report to `target/bench-reports/<name>.{txt,csv}` and echo
/// the table to stdout.
pub fn emit(name: &str, table: &Table) {
    println!("{}", table.render());
    let dir = std::path::Path::new("target/bench-reports");
    if std::fs::create_dir_all(dir).is_ok() {
        let _ = std::fs::write(dir.join(format!("{name}.txt")), table.render());
        let _ = std::fs::write(dir.join(format!("{name}.csv")), table.to_csv());
    }
}

/// Write a machine-readable bench artifact to `<repo root>/<name>.json` —
/// the cross-PR perf trail (`BENCH_kernels.json`, `BENCH_fleet.json`).
/// The repo root is resolved from the crate manifest dir, so the path is
/// stable regardless of the invoking working directory.
pub fn emit_json(name: &str, doc: &crate::util::json::Json) {
    // the manifest dir is baked in at compile time; if the binary runs on
    // a machine where that path no longer exists (relocated checkout,
    // prebuilt bench binaries), fall back to the working directory rather
    // than silently dropping the artifact
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate lives under the repo root");
    let root = if root.is_dir() { root } else { std::path::Path::new(".") };
    let path = root.join(format!("{name}.json"));
    match std::fs::write(&path, doc.render() + "\n") {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_table() {
        let mut t = Table::new("demo", &["a", "bbb"]);
        t.row(vec!["x".into(), "1".into()]);
        t.row(vec!["longer".into(), "2".into()]);
        let r = t.render();
        assert!(r.contains("## demo"));
        assert!(r.contains("longer | 2"));
    }

    #[test]
    fn csv_escapes_commas() {
        let mut t = Table::new("d", &["a"]);
        t.row(vec!["x,y".into()]);
        assert!(t.to_csv().contains("\"x,y\""));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("d", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
